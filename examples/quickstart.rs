//! Quickstart: the 60-second tour of SPC5-RS.
//!
//! Builds a sparse matrix, inspects its block-fill profile, converts it
//! to a `β(r,c)` mask format (no zero padding), runs the AVX-512 SpMV,
//! and verifies against the reference — the core workflow of the paper.
//!
//! Run: `cargo run --release --example quickstart`

use spc5::formats::{csr_to_block, fill_crossover, BlockSize};
use spc5::kernels::{spmv_block, KernelKind, KernelSet};
use spc5::matrix::{suite, Coo};
use spc5::util::fmt_bytes;

fn main() -> anyhow::Result<()> {
    // 1. Assemble a matrix (COO → CSR). Any source works: MatrixMarket
    //    files (spc5::matrix::market), generators, or your own loops.
    let mut coo = Coo::new(8, 8);
    for (r, c, v) in [
        (0, 0, 1.0),
        (0, 1, 2.0),
        (0, 4, 3.0),
        (0, 6, 4.0),
        (1, 1, 5.0),
        (1, 2, 6.0),
        (1, 3, 7.0),
        (2, 2, 8.0),
        (2, 4, 9.0),
        (2, 6, 10.0),
        (3, 3, 11.0),
        (3, 4, 12.0),
        (4, 5, 13.0),
        (4, 6, 14.0),
        (6, 5, 15.0),
        (7, 0, 16.0),
        (7, 4, 17.0),
        (7, 7, 18.0),
    ] {
        coo.push(r, c, v);
    }
    let csr = coo.to_csr()?;
    println!("paper Fig. 1 matrix: {}x{}, {} nnz", csr.rows, csr.cols, csr.nnz());

    // 2. Convert to β(1,4) and β(2,2) — the paper's Fig. 2 examples —
    //    and print the storage the paper illustrates.
    for bs in [BlockSize::new(1, 4), BlockSize::new(2, 2)] {
        let bm = csr_to_block(&csr, bs)?;
        println!(
            "\nβ({},{}): {} blocks, avg {:.2} nnz/block ({:.0}% fill), {} \
             (CSR: {})",
            bs.r,
            bs.c,
            bm.n_blocks(),
            bm.avg_nnz_per_block(),
            100.0 * bm.fill_fraction(),
            fmt_bytes(bm.occupancy_bytes()),
            fmt_bytes(csr.occupancy_bytes()),
        );
        println!("  values       = {:?}", bm.values);
        println!("  block_colidx = {:?}", bm.block_colidx);
        println!("  block_rowptr = {:?}", bm.block_rowptr);
        println!(
            "  block_masks  = {:?}",
            bm.block_masks.iter().map(|m| format!("{m:0w$b}", w = bs.c)).collect::<Vec<_>>()
        );
    }

    // 3. Run the SpMV through the optimized kernel and verify.
    let bm = csr_to_block(&csr, BlockSize::new(1, 8))?;
    let x: Vec<f64> = (0..8).map(|i| 1.0 + i as f64 * 0.5).collect();
    let mut y = vec![0.0; 8];
    spmv_block(&bm, &x, &mut y, false);
    let mut want = vec![0.0; 8];
    csr.spmv_ref(&x, &mut want);
    assert_eq!(y, want);
    println!(
        "\nβ(1,8) SpMV (AVX-512 available: {}): y = {:?}",
        spc5::util::avx512_available(),
        y
    );

    // 4. On a realistic matrix: every kernel, one line each.
    let sm = suite::by_name("bone010").expect("suite matrix");
    println!(
        "\nsuite surrogate '{}' ({} rows, {} nnz):",
        sm.name,
        sm.csr.rows,
        sm.csr.nnz()
    );
    let set = KernelSet::prepare(sm.csr.clone(), &KernelKind::ALL);
    let x: Vec<f64> = (0..sm.csr.cols).map(|i| (i % 10) as f64 * 0.1).collect();
    let mut want = vec![0.0; sm.csr.rows];
    sm.csr.spmv_ref(&x, &mut want);
    for k in KernelKind::ALL {
        let m = spc5::bench::measure_sequential(&set, sm.name, k);
        let mut y = vec![0.0; sm.csr.rows];
        set.spmv(k, &x, &mut y);
        let max_err = y
            .iter()
            .zip(&want)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        println!(
            "  {:<12} {:>7.3} GFlop/s   max|err| = {:.2e}",
            m.kernel.to_string(),
            m.gflops,
            max_err
        );
        assert!(max_err < 1e-8);
    }

    // 5. The same stack at single precision: 16 floats per AVX-512
    //    register, u16 masks, blocks up to 16 columns wide (β32).
    let csr32 = sm.csr.to_precision::<f32>();
    let engine32 = spc5::SpmvEngine::builder(csr32.clone())
        .kernel(KernelKind::Beta(1, 16))
        .build()?;
    let x32: Vec<f32> = x.iter().map(|&v| v as f32).collect();
    let mut y32 = vec![0.0f32; csr32.rows];
    engine32.spmv_into(&x32, &mut y32);
    let max_err32 = y32
        .iter()
        .zip(&want)
        .map(|(a, b)| (*a as f64 - b).abs())
        .fold(0.0f64, f64::max);
    println!(
        "\nf32 β(1,16) through the same engine API: max|err vs f64| = \
         {max_err32:.2e} (storage: {} vs f64 {})",
        fmt_bytes(spc5::formats::csr_to_block(&csr32, BlockSize::new(1, 16))?.occupancy_bytes()),
        fmt_bytes(csr_to_block(&sm.csr, BlockSize::new(1, 8))?.occupancy_bytes()),
    );

    // 6. Eq. (4): when does the block storage beat CSR?
    println!("\nEq. (4) storage crossovers (min avg nnz/block):");
    for bs in BlockSize::PAPER_SIZES {
        println!("  {}: {:.2}", bs, fill_crossover(bs));
    }

    // 7. Inspector–executor: inspect once, serialize the decision,
    //    instantiate anywhere. The plan is plain JSON; `from_plan`
    //    fingerprint-checks the matrix and skips selection entirely.
    let plan = spc5::SpmvEngine::builder(sm.csr.clone())
        .kernel(KernelKind::Hybrid)
        .plan()?;
    let json = plan.to_json();
    let restored = spc5::SpmvPlan::from_json(&json)?;
    let engine = spc5::SpmvEngine::from_plan(sm.csr.clone(), &restored)?;
    println!(
        "\nplan round trip: kernel={} segments={} fingerprint={} ({} B of \
         JSON) -> engine serves {} rows",
        engine.plan().kernel,
        engine.plan().schedule.len(),
        engine.plan().fingerprint.key(),
        json.len(),
        engine.csr().rows
    );

    println!("\nquickstart OK");
    Ok(())
}
