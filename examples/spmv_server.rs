//! Serving demo: the SPC5 engine behind a micro-batching request loop.
//!
//! Starts an [`SpmvService`] over one converted matrix (the
//! iterative-solver deployment: structure fixed, many products). The
//! engine owns a persistent worker pool created once; the service adds
//! a dispatcher that **coalesces concurrent requests into multi-RHS
//! batches** served by one matrix traversal. Reports throughput, the
//! service-side latency percentiles and the batch-size histogram — the
//! "library in production" view of the paper's kernels.
//!
//! Run: `cargo run --release --example spmv_server`

use spc5::coordinator::{Request, SpmvEngine, SpmvService};
use spc5::kernels::KernelKind;
use spc5::matrix::suite;
use spc5::util::{Rng, Timer};

fn main() -> anyhow::Result<()> {
    let sm = suite::by_name("Si87H76").expect("suite matrix");
    let csr = sm.csr.clone();
    println!(
        "serving '{}' ({} rows, {} nnz)",
        sm.name,
        csr.rows,
        csr.nnz()
    );

    let threads = 2usize;
    let engine = SpmvEngine::builder(csr.clone())
        .kernel(KernelKind::Beta(4, 4))
        .threads(threads)
        .build()?;
    println!("kernel: {} | pool workers: {threads}", engine.kernel());

    let max_batch = 8usize;
    let service = SpmvService::start(engine, max_batch);
    println!("dispatcher max batch: {max_batch}");

    // Drive: 200 requests with distinct vectors, submitted in bursts so
    // the dispatcher has something to coalesce.
    let n_req = 200usize;
    let mut rng = Rng::new(0x5E6E);
    let t = Timer::start();
    let mut submitted = 0usize;
    let mut checked = 0usize;
    let mut received = 0usize;
    // Inputs retained for the spot-checked ids (every 50th request).
    let mut retained: std::collections::HashMap<u64, Vec<f64>> =
        std::collections::HashMap::new();
    while received < n_req {
        // Burst of up to 10 submissions, then drain what's ready.
        while submitted < n_req && submitted - received < 10 {
            let id = submitted as u64;
            let x: Vec<f64> =
                (0..csr.cols).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            if id % 50 == 0 {
                retained.insert(id, x.clone());
            }
            if let Err(e) = service.submit(Request { id, x }) {
                // A stopped service is a deployment event, not a panic.
                eprintln!("submit failed: {e}");
                return Err(anyhow::anyhow!("service rejected request: {e}"));
            }
            submitted += 1;
        }
        let resp = service.recv().expect("response");
        // Spot-check retained responses against the CSR reference.
        if let Some(x) = retained.remove(&resp.id) {
            let mut want = vec![0.0; csr.rows];
            csr.spmv_ref(&x, &mut want);
            for i in 0..csr.rows {
                assert!(
                    (resp.y[i] - want[i]).abs()
                        <= 1e-9 * want[i].abs().max(1.0),
                    "response {} row {i} disagrees with reference",
                    resp.id
                );
            }
            checked += 1;
        }
        received += 1;
    }
    let wall = t.elapsed_s();

    let stats = service.stats();
    println!("\n== results ==");
    println!("requests      : {n_req} ({checked} spot-checked)");
    println!("wall time     : {wall:.3}s");
    println!("throughput    : {:.1} SpMV/s", n_req as f64 / wall);
    println!(
        "               ({:.2} effective GFlop/s)",
        2.0 * csr.nnz() as f64 * n_req as f64 / wall / 1e9
    );
    println!("latency p50   : {:.2} ms", stats.p50_s * 1e3);
    println!("latency p95   : {:.2} ms", stats.p95_s * 1e3);
    println!("latency p99   : {:.2} ms", stats.p99_s * 1e3);
    println!(
        "  queued p50/p95  : {:.2}/{:.2} ms (time before dispatch)",
        stats.queue.p50_s * 1e3,
        stats.queue.p95_s * 1e3
    );
    println!(
        "  compute p50/p95 : {:.2}/{:.2} ms (batched kernel time)",
        stats.compute.p50_s * 1e3,
        stats.compute.p95_s * 1e3
    );
    println!("rejected      : {}", stats.rejected);
    println!("queue depth hw: {}", stats.queue_depth_high_water);
    println!("batches       : {}", stats.batches);
    print!("batch sizes   :");
    for (i, &count) in stats.batch_hist.iter().enumerate() {
        if count > 0 {
            print!(" {}×{count}", i + 1);
        }
    }
    println!();
    let served = service.shutdown();
    assert_eq!(served, n_req);
    println!("server drained cleanly ({served} served)");
    Ok(())
}
