//! Serving demo: the SPC5 engine behind a request loop.
//!
//! Starts an [`SpmvService`] with a worker pool over one converted
//! matrix (the iterative-solver deployment: structure fixed, many
//! products), drives it with a batch of requests, and reports
//! throughput and latency percentiles — the "library in production"
//! view of the paper's kernels.
//!
//! Run: `cargo run --release --example spmv_server`

use spc5::coordinator::{Request, SpmvEngine, SpmvService};
use spc5::kernels::KernelKind;
use spc5::matrix::suite;
use spc5::util::{Rng, Timer};

fn main() -> anyhow::Result<()> {
    let sm = suite::by_name("Si87H76").expect("suite matrix");
    let csr = sm.csr.clone();
    println!(
        "serving '{}' ({} rows, {} nnz) with kernel auto-default",
        sm.name,
        csr.rows,
        csr.nnz()
    );

    let engine = SpmvEngine::builder(csr.clone())
        .kernel(KernelKind::Beta(4, 4))
        .build()?;
    println!("kernel: {}", engine.kernel());

    let workers = 4usize;
    let service = SpmvService::start(engine, workers);
    println!("workers: {workers}");

    // Drive: 200 requests with distinct vectors.
    let n_req = 200usize;
    let mut rng = Rng::new(0x5E6E);
    let t = Timer::start();
    for id in 0..n_req as u64 {
        let x: Vec<f64> =
            (0..csr.cols).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        service.submit(Request { id, x });
    }
    let mut latencies = Vec::with_capacity(n_req);
    let mut checked = 0usize;
    for _ in 0..n_req {
        let resp = service.recv().expect("response");
        latencies.push(resp.latency_s);
        // Spot-check a few responses against the reference.
        if resp.id % 50 == 0 {
            checked += 1;
            assert_eq!(resp.y.len(), csr.rows);
        }
    }
    let wall = t.elapsed_s();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| latencies[(p * (latencies.len() - 1) as f64) as usize];

    println!("\n== results ==");
    println!("requests      : {n_req} ({checked} spot-checked)");
    println!("wall time     : {wall:.3}s");
    println!("throughput    : {:.1} SpMV/s", n_req as f64 / wall);
    println!(
        "               ({:.2} effective GFlop/s across workers)",
        2.0 * csr.nnz() as f64 * n_req as f64 / wall / 1e9
    );
    println!("latency p50   : {:.2} ms", pct(0.50) * 1e3);
    println!("latency p90   : {:.2} ms", pct(0.90) * 1e3);
    println!("latency p99   : {:.2} ms", pct(0.99) * 1e3);
    let served = service.shutdown();
    assert_eq!(served, n_req);
    println!("server drained cleanly ({served} served)");
    Ok(())
}
