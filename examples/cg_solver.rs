//! End-to-end driver: conjugate gradient on a 2D Poisson system through
//! **all three layers** of the stack (EXPERIMENTS.md §E2E).
//!
//! - L3 (this binary, Rust): builds the matrix, selects a kernel, runs
//!   the native CG; loads the AOT artifact and runs the XLA CG.
//! - L2 (JAX, build time): `python/compile/model.py::cg_graph` — the CG
//!   loop lowered to one executable.
//! - L1 (Pallas, build time): the mask-expand block SpMV inside every
//!   CG iteration of that executable.
//!
//! The two paths must agree on the solution; the run log (residual
//! curve, timings, SpMV GFlop/s) is what EXPERIMENTS.md §E2E records.
//!
//! Run: `make artifacts && cargo run --release --example cg_solver`

use spc5::coordinator::{cg_solve, SpmvEngine};
use spc5::kernels::KernelKind;
use spc5::matrix::suite;
use spc5::runtime::XlaEngine;
use spc5::util::{Rng, Timer};

fn main() -> anyhow::Result<()> {
    let n = 64usize; // must match python/compile/aot.py POISSON_N
    let iters = 200usize; // must match CG_ITERS
    let csr = suite::poisson2d(n);
    let dim = csr.rows;
    println!(
        "== E2E: CG on 2D Poisson {n}x{n} (dim {dim}, nnz {}) ==",
        csr.nnz()
    );

    let mut rng = Rng::new(0xE2E);
    let b: Vec<f64> = (0..dim).map(|_| rng.range_f64(-1.0, 1.0)).collect();

    // ---- native path: Rust coordinator + AVX-512 kernels -------------
    println!("\n-- native path (L3 only: rust kernels) --");
    let mut x_native = vec![0.0; dim];
    let mut native_time = 0.0;
    for kernel in [
        KernelKind::Beta(1, 8),
        KernelKind::Beta(2, 4),
        KernelKind::Beta(4, 4),
    ] {
        let engine = SpmvEngine::builder(csr.clone()).kernel(kernel).build()?;
        let mut x = vec![0.0; dim];
        let t = Timer::start();
        let report = cg_solve(&engine, &b, &mut x, iters, 1e-20);
        let secs = t.elapsed_s();
        let gflops =
            2.0 * csr.nnz() as f64 * report.spmv_count as f64 / secs / 1e9;
        println!(
            "  {kernel:<8} iters={:>3} residual²={:.3e} time={:.4}s \
             spmv={:.2} GFlop/s",
            report.iterations, report.residual_norm2, secs, gflops
        );
        x_native = x;
        native_time = secs;
    }

    // ---- XLA path: AOT artifact (L2 graph + L1 Pallas kernel) --------
    println!("\n-- xla path (L1+L2 compiled, L3 executes) --");
    let mut engine = match XlaEngine::new("artifacts") {
        Ok(e) => e,
        Err(e) => {
            eprintln!(
                "cannot load artifacts ({e}); run `make artifacts` first"
            );
            return Err(e);
        }
    };
    println!("  PJRT platform: {}", engine.platform());
    engine.validate_matrix("cg", &csr)?;
    let compile_t = Timer::start();
    let exe = engine.executor("cg")?;
    println!("  artifact compile: {:.3}s (cached afterwards)", compile_t.elapsed_s());

    let x0 = vec![0.0f64; dim];
    let t = Timer::start();
    let out = exe.run_f64(&[&csr.values, &b, &x0])?;
    let xla_time = t.elapsed_s();
    let x_xla = &out[0];
    let rs_xla = out[1][0];
    println!(
        "  cg artifact: iters={iters} residual²={rs_xla:.3e} time={xla_time:.4}s"
    );

    // ---- cross-validation --------------------------------------------
    println!("\n-- cross-validation --");
    let max_dx = x_native
        .iter()
        .zip(x_xla)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    let mut ax = vec![0.0; dim];
    csr.spmv_ref(x_xla, &mut ax);
    let res_xla: f64 = ax
        .iter()
        .zip(&b)
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f64>()
        .sqrt();
    println!("  max|x_native − x_xla| = {max_dx:.3e}");
    println!("  ‖A·x_xla − b‖        = {res_xla:.3e}");
    println!(
        "  native/xla wall ratio = {:.2} (xla path includes interpret-mode \
         Pallas overhead; see DESIGN.md §9)",
        xla_time / native_time
    );
    anyhow::ensure!(max_dx < 1e-6, "stacks disagree");
    anyhow::ensure!(res_xla < 1e-5, "xla CG did not converge");

    // ---- bonus: dominant eigenpair via the power artifact -------------
    if let Ok(exe) = engine.executor("power") {
        let v0: Vec<f64> = (0..dim).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        let out = exe.run_f64(&[&csr.values, &v0])?;
        println!(
            "\n-- power-iteration artifact: λ_max ≈ {:.6} (analytic {:.6}) --",
            out[1][0],
            8.0 * (std::f64::consts::PI * n as f64 / (2.0 * (n as f64 + 1.0)))
                .sin()
                .powi(2)
        );
    }

    println!("\nE2E OK: all three layers agree");
    Ok(())
}
