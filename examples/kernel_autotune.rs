//! Kernel auto-tuning demo — the paper's §"Performance prediction and
//! optimal kernel selection" as a user workflow:
//!
//! 1. benchmark the SPC5 kernels on a *training* set of matrices,
//!    recording `(Avg(r,c), GFlop/s)` per kernel;
//! 2. fit the per-kernel polynomial models (Fig. 5);
//! 3. for unseen matrices, predict the best kernel from the cheap
//!    block-count scan alone — before any conversion — and compare the
//!    choice with the measured optimum (Table 3's methodology).
//!
//! Run: `cargo run --release --example kernel_autotune`

use spc5::bench::{measure_sequential, to_record};
use spc5::formats::stats::block_stats;
use spc5::formats::BlockSize;
use spc5::kernels::{KernelKind, KernelSet};
use spc5::matrix::suite;
use spc5::predictor::{select_sequential, RecordStore};

fn avg_for(k: KernelKind, csr: &spc5::matrix::Csr) -> f64 {
    let bs = k.block_size().unwrap_or(BlockSize::new(1, 8));
    block_stats(csr, bs).avg_nnz_per_block
}

fn main() -> anyhow::Result<()> {
    let kernels = KernelKind::SPC5_KERNELS;

    // Training set: a slice of Set-A surrogates across structure classes.
    let train = ["atmosmodd", "bone010", "nd6k", "Si87H76", "circuit5M", "ns3Da", "pdb1HYS", "in-2004"];
    // Held-out evaluation: Set-B surrogates.
    let eval = ["Cube_Coup_dt0", "dielFilterV2real", "FullChip", "TSOPF_RS_b2383_c1"];

    println!("== training: measuring {} kernels on {} matrices ==", kernels.len(), train.len());
    let mut store = RecordStore::new();
    for name in train {
        let sm = suite::by_name(name).expect("suite matrix");
        let set = KernelSet::prepare(sm.csr.clone(), &kernels);
        for k in kernels {
            let m = measure_sequential(&set, name, k);
            let avg = avg_for(k, &sm.csr);
            println!("  {name:<18} {k:<12} avg={avg:>6.2}  {:.3} GFlop/s", m.gflops);
            store.push(to_record(&m, avg));
        }
    }

    println!("\n== evaluation on unseen matrices ==");
    println!(
        "{:<20} {:>14} {:>14} {:>10} {:>10} {:>8}",
        "matrix", "selected", "best", "pred GF/s", "real GF/s", "loss%"
    );
    for name in eval {
        let sm = suite::by_name(name).expect("suite matrix");
        let sel = select_sequential(&sm.csr, &store, &kernels)
            .expect("records available");

        // Measure every kernel to find the true optimum (Table 3 cols).
        let set = KernelSet::prepare(sm.csr.clone(), &kernels);
        let mut best = (kernels[0], 0.0f64);
        let mut selected_real = 0.0f64;
        for k in kernels {
            let m = measure_sequential(&set, name, k);
            if m.gflops > best.1 {
                best = (k, m.gflops);
            }
            if k == sel.kernel {
                selected_real = m.gflops;
            }
        }
        let loss = 100.0 * (best.1 - selected_real) / best.1;
        println!(
            "{:<20} {:>14} {:>14} {:>10.3} {:>10.3} {:>7.1}%",
            name,
            sel.kernel.to_string(),
            best.0.to_string(),
            sel.predicted_gflops,
            selected_real,
            loss
        );
    }
    println!(
        "\n(loss% is the paper's 'Speed difference' column: 0% = optimal \
         kernel selected; small values mean the prediction was good enough)"
    );
    Ok(())
}
