//! Regenerates paper **Fig. 3**: sequential double-precision GFlop/s
//! for the CSR baseline (MKL stand-in), CSR5, and the eight SPC5
//! kernels on every Set-A matrix, with the speedup of the best SPC5
//! kernel over the best baseline printed per matrix (the number above
//! the bars in the paper's figure).
//!
//! Also primes `records.json` — the training data for the prediction
//! benches (fig5 / table3) — exactly as the paper uses Set-A timings.

use spc5::bench::runner::{best_by_matrix, maybe_quick, run_sequential};
use spc5::bench::{append_records, Table};
use spc5::kernels::KernelKind;
use spc5::matrix::suite;

fn main() {
    let matrices = maybe_quick(suite::set_a());
    let kernels = KernelKind::ALL;
    eprintln!(
        "fig3: {} matrices x {} kernels (16-run means)...",
        matrices.len(),
        kernels.len()
    );
    let (ms, recs) = run_sequential(&matrices, &kernels);
    if let Err(e) = append_records(&recs) {
        eprintln!("warning: could not persist records: {e}");
    }

    let mut t = Table::new(
        "Fig. 3: sequential GFlop/s (double precision)",
        &[
            "matrix", "csr", "csr5", "b(1,8)", "b(1,8)t", "b(2,4)", "b(2,4)t",
            "b(2,8)", "b(4,4)", "b(4,8)", "b(8,4)", "best spc5 speedup",
        ],
    );
    let is_baseline =
        |k: KernelKind| matches!(k, KernelKind::Csr | KernelKind::Csr5);
    let best_base = best_by_matrix(&ms, |m| is_baseline(m.kernel));
    let best_spc5 = best_by_matrix(&ms, |m| !is_baseline(m.kernel));

    for sm in &matrices {
        let mut row = vec![sm.name.to_string()];
        for k in kernels {
            let g = ms
                .iter()
                .find(|m| m.matrix == sm.name && m.kernel == k)
                .map(|m| m.gflops)
                .unwrap_or(0.0);
            row.push(format!("{g:.2}"));
        }
        let speedup = best_spc5[sm.name].gflops / best_base[sm.name].gflops;
        row.push(format!("{:+.0}%", (speedup - 1.0) * 100.0));
        t.row(row);
    }
    t.emit("fig3");

    // Shape summary (the paper's qualitative claims).
    let wins = matrices
        .iter()
        .filter(|sm| best_spc5[sm.name].gflops > best_base[sm.name].gflops)
        .count();
    println!(
        "SPC5 beats the best baseline on {wins}/{} matrices (paper: \"often \
         up to 50%\", losing only on scatter-structured matrices like \
         kron/ns3Da)",
        matrices.len()
    );
}
