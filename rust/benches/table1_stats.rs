//! Regenerates paper **Table 1**: Set-A matrices with dim, nnz,
//! nnz/row, and `Avg(r,c)` + fill% for the six paper block sizes,
//! printing the paper's transcribed Avg next to ours so the surrogate
//! calibration is visible.

use spc5::bench::paper_ref::paper_avg;
use spc5::bench::Table;
use spc5::formats::stats::paper_profile;
use spc5::matrix::suite;

fn main() {
    run("Table 1 (Set-A): block statistics", suite::set_a(), "table1");
}

pub fn run(title: &str, ms: Vec<suite::SuiteMatrix>, slug: &str) {
    let mut t = Table::new(
        title,
        &[
            "name", "class", "dim", "nnz", "nnz/row", "b(1,8)", "b(2,4)",
            "b(2,8)", "b(4,4)", "b(4,8)", "b(8,4)",
        ],
    );
    for sm in spc5::bench::runner::maybe_quick(ms) {
        let prof = paper_profile(&sm.csr);
        let paper = paper_avg(sm.name);
        let mut row = vec![
            sm.name.to_string(),
            sm.class.to_string(),
            sm.csr.rows.to_string(),
            sm.csr.nnz().to_string(),
            format!("{:.1}", sm.csr.nnz_per_row()),
        ];
        for (i, st) in prof.iter().enumerate() {
            let ours = format!(
                "{:.1} ({:.0}%)",
                st.avg_nnz_per_block,
                100.0 * st.fill_fraction
            );
            let cell = match paper {
                Some(p) => format!("{ours} [paper {:.1}]", p[i]),
                None => ours,
            };
            row.push(cell);
        }
        t.row(row);
    }
    t.emit(slug);
}
