//! Regenerates paper **Fig. 6**: parallel kernel selection via the
//! nonlinear 2D regression `gflops ~ f(avg, threads)` fitted on Set-A
//! records at several thread counts, evaluated on Set-A ∪ Set-B.
//!
//! Three panels, like the paper:
//!   (A) was the optimal kernel selected? (green/red grid)
//!   (B) real performance difference selected vs best
//!   (C) |predicted − real| for the selected kernel

use spc5::bench::runner::{ensure_records, maybe_quick, run_parallel};
use spc5::bench::Table;
use spc5::kernels::KernelKind;
use spc5::matrix::suite;
use spc5::predictor::select_parallel;

fn main() {
    let set_a = maybe_quick(suite::set_a());
    let kernels = KernelKind::SPC5_KERNELS;
    // Fit records at 1, 2 and 4 threads (the paper used 1..52).
    let store = ensure_records(&set_a, &kernels, &[1, 2, 4])
        .expect("record store");

    let eval_threads = 4usize;
    let eval: Vec<_> = set_a
        .into_iter()
        .chain(maybe_quick(suite::set_b()))
        .collect();

    let mut t = Table::new(
        &format!(
            "Fig. 6: parallel selection at {eval_threads} threads \
             (A optimal? / B perf diff / C prediction err)"
        ),
        &[
            "matrix", "best", "selected", "A optimal", "B perf diff",
            "C |pred-real|",
        ],
    );
    let mut optimal = 0usize;
    let mut within10 = 0usize;
    for sm in &eval {
        let sel =
            select_parallel(&sm.csr, &store, &kernels, eval_threads)
                .expect("records fitted");
        let (ms, _) = run_parallel(
            &[suite::SuiteMatrix {
                name: sm.name,
                class: sm.class,
                csr: sm.csr.clone(),
            }],
            &kernels,
            &[eval_threads],
            &[false],
        );
        let best = ms
            .iter()
            .max_by(|a, b| a.gflops.partial_cmp(&b.gflops).unwrap())
            .unwrap();
        let real = ms
            .iter()
            .find(|m| m.kernel == sel.kernel)
            .map(|m| m.gflops)
            .unwrap_or(0.0);
        let diff = 100.0 * (best.gflops - real) / best.gflops;
        let pred_err = (sel.predicted_gflops - real).abs();
        if sel.kernel == best.kernel {
            optimal += 1;
        }
        if diff <= 10.0 {
            within10 += 1;
        }
        t.row(vec![
            sm.name.to_string(),
            best.kernel.to_string(),
            sel.kernel.to_string(),
            if sel.kernel == best.kernel { "green" } else { "red" }.into(),
            format!("{diff:.1}%"),
            format!("{pred_err:.2}"),
        ]);
    }
    t.emit("fig6");
    println!(
        "optimal selection: {optimal}/{}; within 10% of optimal: {within10}/{} \
         (paper Fig. 6: \"does not select the optimal kernels in most cases, \
         but the performance provided ... is close to the optimal — less \
         than 10 percent difference in most cases\")",
        eval.len(),
        eval.len()
    );
}
