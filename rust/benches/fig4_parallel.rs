//! Regenerates paper **Fig. 4**: parallel GFlop/s for the SPC5 kernels
//! with and without the NUMA-split optimization.
//!
//! The paper runs 52 threads on a 2-socket Skylake; this container has
//! one core, so the defaults are scaled (threads = {2, 4}, override
//! with SPC5_THREADS="2,4,8"). The *code paths* are identical — the
//! partitioner, per-thread working vectors, syncless merge and the
//! array-splitting NUMA mode all execute; what the host cannot show is
//! cross-socket memory latency (EXPERIMENTS.md discusses this).
//!
//! Also appends multi-thread records for the fig6 regression.

use spc5::bench::runner::{maybe_quick, run_parallel};
use spc5::bench::{append_records, Table};
use spc5::kernels::KernelKind;
use spc5::matrix::suite;

fn thread_counts() -> Vec<usize> {
    std::env::var("SPC5_THREADS")
        .ok()
        .map(|s| {
            s.split(',')
                .filter_map(|t| t.trim().parse().ok())
                .collect::<Vec<usize>>()
        })
        .filter(|v| !v.is_empty())
        .unwrap_or_else(|| vec![2, 4])
}

fn main() {
    let matrices = maybe_quick(suite::set_a());
    let kernels = KernelKind::SPC5_KERNELS;
    let threads = thread_counts();
    eprintln!(
        "fig4: {} matrices x {} kernels x threads {threads:?} x numa {{off,on}}...",
        matrices.len(),
        kernels.len()
    );
    let (ms, recs) = run_parallel(&matrices, &kernels, &threads, &[false, true]);
    if let Err(e) = append_records(&recs) {
        eprintln!("warning: could not persist records: {e}");
    }

    for &tc in &threads {
        let mut t = Table::new(
            &format!("Fig. 4: parallel GFlop/s, {tc} threads (plain / NUMA-split)"),
            &[
                "matrix", "b(1,8)", "b(1,8)t", "b(2,4)", "b(2,4)t", "b(2,8)",
                "b(4,4)", "b(4,8)", "b(8,4)",
            ],
        );
        for sm in &matrices {
            let mut row = vec![sm.name.to_string()];
            for k in kernels {
                let find = |numa: bool| {
                    ms.iter()
                        .find(|m| {
                            m.matrix == sm.name
                                && m.kernel == k
                                && m.threads == tc
                                && m.numa == numa
                        })
                        .map(|m| m.gflops)
                        .unwrap_or(0.0)
                };
                row.push(format!("{:.2} / {:.2}", find(false), find(true)));
            }
            t.row(row);
        }
        t.emit(&format!("fig4_t{tc}"));
    }
}
