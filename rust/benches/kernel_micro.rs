//! Microbenchmark / ablation: kernel GFlop/s as a controlled function
//! of block fill, plus AVX-512-vs-scalar and header-layout ablations —
//! the design-choice experiments DESIGN.md calls out (not a paper
//! figure, but the evidence behind the paper's §Design discussion).
//!
//! Workload: banded matrices whose in-band density sweeps 10%..100%,
//! so `Avg(r,c)` moves while dims and nnz structure stay comparable.

use spc5::bench::{bench_vector, runner, to_record, Measurement, Table, RUNS};
use spc5::coordinator::{
    QueuePolicy, RecvError, Request, ServiceError, ShardConfig,
    ShardedService, SpmvEngine,
};
use spc5::formats::{csr_to_block, BlockSize};
use spc5::kernels::{avx512, scalar, spmm, spmv_block, KernelKind, KernelSet};
use spc5::matrix::{reorder, suite, Csr};
use spc5::parallel::{ParallelSpmv, ParallelStrategy, WorkerPool};
use spc5::predictor::RecordStore;
use spc5::util::timer::{mean_of_runs, spmv_gflops};

fn main() {
    // `SPC5_ABLATION=<name>` runs a single section (CI runs `hybrid`
    // and `tile` to produce the BENCH_3.json / BENCH_4.json artifacts
    // without the full sweep).
    if let Ok(only) = std::env::var("SPC5_ABLATION") {
        match only.as_str() {
            "hybrid" => return hybrid_ablation(),
            "prefetch" => return prefetch_ablation(),
            "tile" => return tile_ablation(),
            "plan" => return plan_ablation(),
            "serve" => return serve_ablation(),
            "tune" => return tune_ablation(),
            "chaos" => return chaos_ablation(),
            "durable" => return durable_ablation(),
            "solve" => return solve_ablation(),
            other => {
                eprintln!("unknown SPC5_ABLATION='{other}', running all")
            }
        }
    }
    fill_sweep();
    simd_vs_scalar();
    prefetch_ablation();
    reorder_ablation();
    f32_vs_f64();
    spmm_ablation();
    xcopy_ablation();
    pool_handoff_ablation();
    batched_parallel_ablation();
    predictor_ablation();
    hybrid_ablation();
    tile_ablation();
    plan_ablation();
    serve_ablation();
    tune_ablation();
    chaos_ablation();
    durable_ablation();
    solve_ablation();
}

/// GFlop/s vs block fill for every kernel.
fn fill_sweep() {
    let mut t = Table::new(
        "Ablation A: GFlop/s vs in-band density (banded 40k, bw 24)",
        &["density", "avg(1,8)", "csr", "b(1,8)", "b(2,4)", "b(2,8)",
          "b(4,4)", "b(4,8)", "b(8,4)"],
    );
    for step in 1..=8 {
        let density = step as f64 / 8.0;
        let csr = suite::banded(40_000, 24, density, 77);
        let kernels = [
            KernelKind::Csr,
            KernelKind::Beta(1, 8),
            KernelKind::Beta(2, 4),
            KernelKind::Beta(2, 8),
            KernelKind::Beta(4, 4),
            KernelKind::Beta(4, 8),
            KernelKind::Beta(8, 4),
        ];
        let avg18 = spc5::formats::stats::block_stats(
            &csr,
            BlockSize::new(1, 8),
        )
        .avg_nnz_per_block;
        let set = KernelSet::prepare(csr, &kernels);
        let mut row =
            vec![format!("{:.0}%", density * 100.0), format!("{avg18:.2}")];
        for k in kernels {
            let m = spc5::bench::measure_sequential(&set, "banded", k);
            row.push(format!("{:.2}", m.gflops));
        }
        t.row(row);
        eprintln!("  density {:.0}%", density * 100.0);
    }
    t.emit("ablation_fill");
}

/// Software-prefetch ablation: the β hot loops issue `_mm_prefetch`
/// for upcoming header/value cache lines (on by default); this builds
/// one tuned engine per side — `TuneParams::BASELINE` vs
/// `TuneParams::NO_PREFETCH` — on a streaming-bound and a
/// cache-resident matrix to prove the hint is not a regression.
fn prefetch_ablation() {
    use spc5::TuneParams;
    let mut t = Table::new(
        "Ablation P: software prefetch in the β hot loops (on vs off)",
        &["matrix", "kernel", "pf on GF/s", "pf off GF/s", "on/off"],
    );
    let mats = [
        ("fem-30k", suite::fem_blocked(30_000, 3, 8, 5)),
        ("banded-40k", suite::banded(40_000, 24, 0.6, 77)),
    ];
    let kernels = [
        KernelKind::Beta(1, 8),
        KernelKind::Beta(2, 4),
        KernelKind::Beta(2, 8),
        KernelKind::Beta(4, 8),
        KernelKind::Beta(8, 4),
    ];
    for (name, csr) in &mats {
        let x = bench_vector(csr.cols, 0xBE7C);
        let mut y = vec![0.0f64; csr.rows];
        for &k in &kernels {
            let mut run = |tune: TuneParams| {
                let engine = SpmvEngine::builder(csr.clone())
                    .kernel(k)
                    .tune(tune)
                    .build()
                    .expect("β engine builds");
                let s = mean_of_runs(RUNS, || engine.spmv(&x, &mut y));
                std::hint::black_box(&y);
                spmv_gflops(csr.nnz(), s)
            };
            let g_on = run(TuneParams::BASELINE);
            let g_off = run(TuneParams::NO_PREFETCH);
            t.row(vec![
                name.to_string(),
                k.to_string(),
                format!("{g_on:.2}"),
                format!("{g_off:.2}"),
                format!("{:.3}x", g_on / g_off),
            ]);
        }
        eprintln!("  prefetch ablation: {name}");
    }
    t.emit("ablation_prefetch");
}

/// Machine-level tune sweep: every `VARIANT_TABLE` entry × β kernel on
/// the tuner's representative generators, via the same
/// `tuner::sweep` the `spc5 tune` subcommand runs offline. The table
/// shows each kernel's winning variant against the baseline; every
/// individual (matrix, kernel, variant) measurement is persisted to
/// `BENCH_7.json` (CI artifact next to BENCH_3..6), the `variant`
/// field carrying the tune label. `SPC5_QUICK=1` switches to the
/// smoke-sized sweep.
fn tune_ablation() {
    use spc5::tuner::{sweep, SweepConfig};
    let cfg = if std::env::var("SPC5_QUICK").is_ok() {
        SweepConfig::quick()
    } else {
        SweepConfig::full()
    };
    let (profile, records) = sweep(&cfg).expect("tune sweep");
    let mut t = Table::new(
        "Ablation N: kernel tune sweep (winning variant per β kernel)",
        &["kernel", "variant", "GF/s", "baseline GF/s", "vs baseline"],
    );
    for e in &profile.entries {
        let kernel = e.kernel.to_string();
        let variant = e.tune.label();
        t.row(vec![
            kernel,
            variant,
            format!("{:.2}", e.gflops),
            format!("{:.2}", e.baseline_gflops),
            format!("{:.3}x", e.gflops / e.baseline_gflops),
        ]);
    }
    t.emit("ablation_tune");
    eprintln!("  tune ablation: machine {}", profile.machine);

    // `seconds` is not part of a sweep record; 0 marks it unmeasured
    // (the per-variant GFlop/s is the quantity of interest).
    let all: Vec<Measurement> = records
        .iter()
        .map(|r| Measurement {
            matrix: r.matrix.clone(),
            kernel: r.kernel,
            threads: r.threads,
            numa: false,
            tile_cols: r.tile_cols,
            tune: r.tune,
            gflops: r.gflops,
            seconds: 0.0,
        })
        .collect();
    let out = std::env::var("SPC5_BENCH7_JSON")
        .unwrap_or_else(|_| "BENCH_7.json".to_string());
    match runner::write_bench_json(
        std::path::Path::new(&out),
        "kernel_micro/tune",
        &all,
    ) {
        Ok(()) => eprintln!("  wrote {out}"),
        Err(e) => eprintln!("warning: {e}"),
    }
}

/// Hybrid row-panel schedule vs every fixed kernel, on homogeneous
/// suite-class matrices (hybrid should tie the best fixed β) and on a
/// constructed mixed matrix — banded half + scattered half — where no
/// fixed kernel is right for both halves (hybrid should win outright).
/// Fixed-kernel measurements double as the predictor records that
/// drive the per-panel choice, and everything is persisted to
/// `BENCH_3.json` (CI uploads it as an artifact).
fn hybrid_ablation() {
    let mats: Vec<(&str, Csr)> = vec![
        ("banded-dense", suite::banded(20_000, 24, 1.0, 7)),
        ("banded-mid", suite::banded(20_000, 24, 0.5, 8)),
        ("fem-blocked", suite::fem_blocked(8_000, 3, 8, 9)),
        ("contact", suite::contact_runs(6_000, 3, 48, 10)),
        ("scatter", suite::uniform_scatter(20_000, 8, 11)),
        ("mixed-band-scatter", suite::mixed_band_scatter(24_000, 12)),
    ];
    let fixed = [
        KernelKind::Csr,
        KernelKind::Beta(1, 8),
        KernelKind::Beta(2, 4),
        KernelKind::Beta(2, 8),
        KernelKind::Beta(4, 4),
        KernelKind::Beta(4, 8),
        KernelKind::Beta(8, 4),
    ];

    // Pass 1: fixed kernels — measurements + predictor records.
    let mut store = RecordStore::new();
    let mut all: Vec<Measurement> = Vec::new();
    for (name, csr) in &mats {
        let set = KernelSet::prepare(csr.clone(), &fixed);
        for &k in &fixed {
            let m = spc5::bench::measure_sequential(&set, name, k);
            store.push(to_record(&m, runner::kernel_avg(k, csr)));
            all.push(m);
        }
        eprintln!("  hybrid ablation: measured fixed kernels on {name}");
    }

    // Pass 2: hybrid, per-panel choices driven by the records above.
    let mut t = Table::new(
        "Ablation J: hybrid row-panel schedule vs fixed kernels (sequential)",
        &[
            "matrix",
            "hybrid GF/s",
            "segments",
            "best fixed",
            "best GF/s",
            "hybrid/best",
            "best β GF/s",
            "hybrid/best-β",
        ],
    );
    for (name, csr) in &mats {
        let engine = SpmvEngine::builder(csr.clone())
            .kernel(KernelKind::Hybrid)
            .records(&store)
            .build()
            .expect("hybrid engine builds");
        let x = bench_vector(csr.cols, 0xBE7C);
        let mut y = vec![0.0f64; csr.rows];
        let seconds = mean_of_runs(RUNS, || engine.spmv(&x, &mut y));
        std::hint::black_box(&y);
        let gflops = spmv_gflops(csr.nnz(), seconds);
        let segments = engine.hybrid().map_or(0, |hm| hm.n_segments());
        all.push(Measurement {
            matrix: name.to_string(),
            kernel: KernelKind::Hybrid,
            threads: 1,
            numa: false,
            tile_cols: 0,
            tune: Default::default(),
            gflops,
            seconds,
        });

        let best = |pred: &dyn Fn(&Measurement) -> bool| {
            all.iter()
                .filter(|&m| m.matrix == *name && pred(m))
                .max_by(|a, b| a.gflops.total_cmp(&b.gflops))
                .expect("measured")
                .clone()
        };
        let best_fixed = best(&|m| m.kernel != KernelKind::Hybrid);
        let best_beta = best(&|m| {
            matches!(m.kernel, KernelKind::Beta(..) | KernelKind::BetaTest(..))
        });
        t.row(vec![
            name.to_string(),
            format!("{gflops:.2}"),
            format!("{segments}"),
            best_fixed.kernel.to_string(),
            format!("{:.2}", best_fixed.gflops),
            format!("{:.3}x", gflops / best_fixed.gflops),
            format!("{:.2}", best_beta.gflops),
            format!("{:.3}x", gflops / best_beta.gflops),
        ]);
        eprintln!("  hybrid ablation: {name} hybrid {gflops:.2} GF/s");
    }
    t.emit("ablation_hybrid");

    let out = std::env::var("SPC5_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_3.json".to_string());
    match runner::write_bench_json(
        std::path::Path::new(&out),
        "kernel_micro/hybrid",
        &all,
    ) {
        Ok(()) => eprintln!("  wrote {out}"),
        Err(e) => eprintln!("warning: {e}"),
    }
}

/// Tile-size ablation: the column-tiled `(panel, tile)` schedule
/// swept over tile widths (including "off" = the flat schedule and
/// "auto" = the detected L2 share) on matrices whose `x` working set
/// exceeds the cache — the `wide_random` generator — plus one
/// cache-resident control where tiling should be ≈neutral. Every
/// measurement is persisted to `BENCH_4.json` (CI uploads it next to
/// the hybrid ablation's BENCH_3.json), `tile = 0` marking the flat
/// rows, so the tiled-vs-flat locality win is machine-readable.
fn tile_ablation() {
    let mats: Vec<(&str, Csr)> = vec![
        // x = 400k doubles ≈ 3 MB: far past a per-core L2 share.
        ("wide-random", suite::wide_random(40_000, 400_000, 12)),
        // Control: banded x reuse is already cache-friendly.
        ("banded-20k", suite::banded(20_000, 24, 0.6, 77)),
    ];
    // Width 0 spells "auto" in KernelKind::Tiled; the resolved width
    // is recorded per measurement from the built engine.
    let widths: [u32; 5] = [0, 2048, 8192, 32768, 131072];

    let mut all: Vec<Measurement> = Vec::new();
    let mut t = Table::new(
        "Ablation K: column-tile width sweep, tiled vs flat \
         (hybrid schedule + b(1,8), sequential)",
        &["matrix", "schedule", "tile cols", "GF/s", "vs flat"],
    );
    for (name, csr) in &mats {
        let x = bench_vector(csr.cols, 0xBE7C);
        let mut y = vec![0.0f64; csr.rows];
        let nnz = csr.nnz();
        let mut measure = |engine: &SpmvEngine, kernel: KernelKind| {
            let seconds = mean_of_runs(RUNS, || engine.spmv(&x, &mut y));
            std::hint::black_box(&y);
            let m = Measurement {
                matrix: name.to_string(),
                kernel,
                threads: 1,
                numa: false,
                tile_cols: engine.tile_cols().unwrap_or(0),
                tune: Default::default(),
                gflops: spmv_gflops(nnz, seconds),
                seconds,
            };
            all.push(m.clone());
            m
        };

        // Flat hybrid baseline.
        let flat = SpmvEngine::builder(csr.clone())
            .kernel(KernelKind::Hybrid)
            .build()
            .expect("hybrid engine builds");
        let flat_g = measure(&flat, KernelKind::Hybrid).gflops;
        t.row(vec![
            name.to_string(),
            "hybrid".into(),
            "off".into(),
            format!("{flat_g:.2}"),
            "1.000x".into(),
        ]);
        drop(flat);

        // Tiled hybrid across the width sweep.
        for &w in &widths {
            let engine = SpmvEngine::builder(csr.clone())
                .kernel(KernelKind::Tiled(w))
                .build()
                .expect("tiled engine builds");
            let m = measure(&engine, KernelKind::Tiled(w));
            let label = if w == 0 {
                format!("auto ({})", m.tile_cols)
            } else {
                format!("{w}")
            };
            t.row(vec![
                name.to_string(),
                "tiled hybrid".into(),
                label,
                format!("{:.2}", m.gflops),
                format!("{:.3}x", m.gflops / flat_g),
            ]);
        }

        // Flat vs tiled β(1,8) — the pure-kernel view of the same
        // lever (builder.tile_cols path).
        let flat_b = SpmvEngine::builder(csr.clone())
            .kernel(KernelKind::Beta(1, 8))
            .build()
            .expect("β engine builds");
        let flat_bg = measure(&flat_b, KernelKind::Beta(1, 8)).gflops;
        t.row(vec![
            name.to_string(),
            "b(1,8)".into(),
            "off".into(),
            format!("{flat_bg:.2}"),
            "1.000x".into(),
        ]);
        drop(flat_b);
        let tiled_b = SpmvEngine::builder(csr.clone())
            .kernel(KernelKind::Beta(1, 8))
            .tile_auto()
            .build()
            .expect("tiled β engine builds");
        let m = measure(&tiled_b, KernelKind::Beta(1, 8));
        t.row(vec![
            name.to_string(),
            "b(1,8) tiled".into(),
            format!("auto ({})", m.tile_cols),
            format!("{:.2}", m.gflops),
            format!("{:.3}x", m.gflops / flat_bg),
        ]);
        eprintln!("  tile ablation: {name}");
    }
    t.emit("ablation_tile");

    let out = std::env::var("SPC5_BENCH4_JSON")
        .unwrap_or_else(|_| "BENCH_4.json".to_string());
    match runner::write_bench_json(
        std::path::Path::new(&out),
        "kernel_micro/tile",
        &all,
    ) {
        Ok(()) => eprintln!("  wrote {out}"),
        Err(e) => eprintln!("warning: {e}"),
    }
}

/// Plan-vs-cold ablation: what the inspector–executor split is worth
/// on the *build* path. Cold `build()` pays selection + hybrid panel
/// ranking + conversion on every call; `plan()` isolates the
/// inspection cost; `from_plan()` isolates instantiation; and a warmed
/// `PlanCache` (`builder.plan_cache(path)`) is the serving scenario —
/// repeat workloads skip inspection entirely. Build times are
/// persisted to `BENCH_5.json` (`gflops` is 0 for these rows — the
/// measured quantity is `seconds` per engine build; the phase is
/// encoded in the matrix label suffix), uploaded by CI next to
/// BENCH_3/BENCH_4.
fn plan_ablation() {
    let mats: Vec<(&str, Csr)> = vec![
        ("fem-8k", suite::fem_blocked(8_000, 3, 8, 9)),
        ("mixed-band-scatter", suite::mixed_band_scatter(16_000, 12)),
    ];
    // Fitted surfaces make the inspection phase do real predictor
    // work (per-panel ranking against the fitted CSR/β curves).
    let mut store = RecordStore::new();
    for i in 0..16 {
        let avg = 1.0 + i as f64 * 2.0;
        for (kernel, gflops) in [
            (KernelKind::Csr, 1.4),
            (KernelKind::Beta(1, 8), 0.9 + 0.08 * avg),
            (KernelKind::Beta(2, 8), 0.6 + 0.10 * avg),
            (KernelKind::Beta(4, 8), 0.4 + 0.12 * avg),
        ] {
            store.push(spc5::predictor::PerfRecord {
                matrix: format!("train{i}"),
                kernel,
                avg_nnz_per_block: avg,
                threads: 1,
                tile_cols: 0,
                tune: Default::default(),
                gflops,
            });
        }
    }

    let dir = std::env::temp_dir().join("spc5_plan_ablation");
    std::fs::create_dir_all(&dir).ok();

    let mut all: Vec<Measurement> = Vec::new();
    let mut t = Table::new(
        "Ablation L: engine build time, cold vs planned vs cached \
         (hybrid kernel, sequential)",
        &["matrix", "phase", "ms per build", "vs cold"],
    );
    for (name, csr) in &mats {
        let mk = || {
            SpmvEngine::builder(csr.clone())
                .kernel(KernelKind::Hybrid)
                .records(&store)
        };
        let mut record = |phase: &str, seconds: f64| {
            all.push(Measurement {
                matrix: format!("{name}/{phase}"),
                kernel: KernelKind::Hybrid,
                threads: 1,
                numa: false,
                tile_cols: 0,
                tune: Default::default(),
                gflops: 0.0,
                seconds,
            });
        };

        // Cold: inspection + instantiation fused (what every repeat
        // workload used to pay).
        let s_cold = mean_of_runs(RUNS, || {
            std::hint::black_box(&mk().build().expect("cold build"));
        });
        record("cold-build", s_cold);

        // Inspection alone (scans + predictor + panel ranking).
        let s_plan = mean_of_runs(RUNS, || {
            std::hint::black_box(&mk().plan().expect("plan"));
        });
        record("plan-only", s_plan);

        // Instantiation from a ready plan (fingerprint + conversion).
        let plan = mk().plan().expect("plan");
        let s_inst = mean_of_runs(RUNS, || {
            std::hint::black_box(
                &SpmvEngine::from_plan(csr.clone(), &plan)
                    .expect("from_plan"),
            );
        });
        record("from-plan", s_inst);

        // The serving path: a warmed PlanCache on disk.
        let cache_path = dir.join(format!("{name}.json"));
        std::fs::remove_file(&cache_path).ok();
        std::hint::black_box(
            &mk().plan_cache(&cache_path).build().expect("cache warmup"),
        );
        let s_cached = mean_of_runs(RUNS, || {
            std::hint::black_box(
                &mk()
                    .plan_cache(&cache_path)
                    .build()
                    .expect("cached build"),
            );
        });
        record("cached-build", s_cached);

        for (phase, s) in [
            ("cold build()", s_cold),
            ("plan() only", s_plan),
            ("from_plan()", s_inst),
            ("warmed plan_cache build()", s_cached),
        ] {
            t.row(vec![
                name.to_string(),
                phase.into(),
                format!("{:.3}", s * 1e3),
                format!("{:.3}x", s / s_cold),
            ]);
        }
        eprintln!("  plan ablation: {name}");
    }
    t.emit("ablation_plan");

    let out = std::env::var("SPC5_BENCH5_JSON")
        .unwrap_or_else(|_| "BENCH_5.json".to_string());
    match runner::write_bench_json(
        std::path::Path::new(&out),
        "kernel_micro/plan",
        &all,
    ) {
        Ok(()) => eprintln!("  wrote {out}"),
        Err(e) => eprintln!("warning: {e}"),
    }
}

/// Serving-tier ablation: offered load through the sharded,
/// admission-controlled front-end, sweeping shards × queue policy ×
/// burst size on one blocked FEM matrix. Bursts larger than the
/// admission capacity are where the policies diverge: `reject` sheds
/// the overflow (counted), `block` applies backpressure (the driver
/// clamps its burst to capacity — a blocking submit with no concurrent
/// consumer would deadlock). Served throughput per configuration is
/// persisted to `BENCH_6.json` (CI artifact next to BENCH_3/4/5).
fn serve_ablation() {
    let csr = suite::fem_blocked(8_000, 3, 8, 9);
    let nnz = csr.nnz();
    let requests = 160usize;
    let capacity = 8usize;

    let mut all: Vec<Measurement> = Vec::new();
    let mut t = Table::new(
        "Ablation M: sharded serving — shards × admission policy × burst \
         (fem-8k, b(1,8), capacity 8, 160 offered requests)",
        &["shards", "policy", "burst", "served", "rejected", "in-flight hw",
          "GF/s"],
    );
    for shards in [1usize, 2, 4] {
        for (policy_name, policy) in [
            ("block(8)", QueuePolicy::Block { capacity }),
            ("reject(8)", QueuePolicy::Reject { capacity }),
        ] {
            for burst in [4usize, 16] {
                let service = ShardedService::start(
                    csr.clone(),
                    ShardConfig {
                        shards,
                        kernel: Some(KernelKind::Beta(1, 8)),
                        max_batch: 8,
                        queue: policy,
                        ..ShardConfig::default()
                    },
                )
                .expect("sharded service starts");
                let eff_burst = match policy {
                    QueuePolicy::Block { .. } => burst.min(capacity),
                    _ => burst,
                };
                let timer = spc5::util::Timer::start();
                let mut rejected = 0usize;
                let mut id = 0u64;
                while (id as usize) < requests {
                    let mut outstanding = 0usize;
                    for _ in 0..eff_burst {
                        if id as usize >= requests {
                            break;
                        }
                        let x = bench_vector(csr.cols, 0xBE7C ^ id);
                        match service.submit(Request { id, x }) {
                            Ok(()) => outstanding += 1,
                            Err(ServiceError::Overloaded { .. }) => {
                                rejected += 1
                            }
                            Err(e) => panic!("submit failed: {e}"),
                        }
                        id += 1;
                    }
                    for _ in 0..outstanding {
                        service.recv().expect("response");
                    }
                }
                let wall = timer.elapsed_s();
                let stats = service.stats();
                let served = stats.served;
                let hw = stats.in_flight_high_water;
                service.shutdown();
                let gflops = 2.0 * nnz as f64 * served as f64 / wall / 1e9;
                all.push(Measurement {
                    matrix: format!(
                        "fem-8k/shards={shards}/queue={policy_name}\
                         /burst={burst}"
                    ),
                    kernel: KernelKind::Beta(1, 8),
                    threads: shards,
                    numa: false,
                    tile_cols: 0,
                    tune: Default::default(),
                    gflops,
                    seconds: wall,
                });
                t.row(vec![
                    format!("{shards}"),
                    policy_name.to_string(),
                    format!("{burst}"),
                    format!("{served}"),
                    format!("{rejected}"),
                    format!("{hw}"),
                    format!("{gflops:.2}"),
                ]);
                eprintln!(
                    "  serve ablation: shards={shards} {policy_name} \
                     burst={burst} served={served} rejected={rejected}"
                );
            }
        }
    }
    t.emit("ablation_serve");

    let out = std::env::var("SPC5_BENCH6_JSON")
        .unwrap_or_else(|_| "BENCH_6.json".to_string());
    match runner::write_bench_json(
        std::path::Path::new(&out),
        "kernel_micro/serve",
        &all,
    ) {
        Ok(()) => eprintln!("  wrote {out}"),
        Err(e) => eprintln!("warning: {e}"),
    }
}

/// Chaos ablation: (a) the cost of the always-compiled fault-check on
/// the fault-free hot path — no plan vs an installed plan whose rules
/// never match (the check still runs on every site hit); (b) client-
/// observable recovery latency when a shard dispatcher is killed
/// mid-stream — from the receive that detects the failure to the
/// first good response off the restarted shard (`gflops = 0` for the
/// latency row, like BENCH_5's plan-stage rows). Persisted to
/// `BENCH_8.json` (CI artifact next to BENCH_3..7).
fn chaos_ablation() {
    use spc5::faults::{Action, FaultPlan, FaultRule, SiteKind};
    use std::sync::Arc;
    use std::time::Instant;

    let csr = suite::fem_blocked(8_000, 3, 8, 9);
    let nnz = csr.nnz();
    let requests = 160usize;
    let capacity = 8usize;

    // Drives bursts through `service`, tolerating injected shard
    // failures; reports (wall, served-this-run failures, recovery
    // seconds) where recovery spans the failure-detecting receive to
    // the first good response after it.
    let drive = |service: &ShardedService, requests: usize| {
        let timer = spc5::util::Timer::start();
        let mut failed = 0usize;
        let mut recovery_s = 0.0f64;
        let mut fault_at: Option<Instant> = None;
        let mut id = 0u64;
        while (id as usize) < requests {
            let mut outstanding = 0usize;
            for _ in 0..capacity {
                if id as usize >= requests {
                    break;
                }
                let x = bench_vector(csr.cols, 0xBE7C ^ id);
                match service.submit(Request { id, x }) {
                    Ok(()) => outstanding += 1,
                    Err(ServiceError::ShardFailed { .. }) => failed += 1,
                    Err(e) => panic!("submit failed: {e}"),
                }
                id += 1;
            }
            for _ in 0..outstanding {
                let call = Instant::now();
                match service.recv() {
                    Ok(_) => {
                        if let Some(t0) = fault_at.take() {
                            recovery_s = t0.elapsed().as_secs_f64();
                        }
                    }
                    Err(RecvError::Failed { .. }) => {
                        failed += 1;
                        fault_at.get_or_insert(call);
                    }
                    Err(e) => panic!("recv failed: {e}"),
                }
            }
        }
        (timer.elapsed_s(), failed, recovery_s)
    };

    let start = |faults: Option<Arc<FaultPlan>>| {
        ShardedService::start(
            csr.clone(),
            ShardConfig {
                shards: 2,
                kernel: Some(KernelKind::Beta(1, 8)),
                max_batch: 8,
                queue: QueuePolicy::Block { capacity },
                faults,
                ..ShardConfig::default()
            },
        )
        .expect("sharded service starts")
    };

    // A plan that is installed (so every site pays the full matching
    // walk) but can never fire: no shard index matches usize::MAX.
    let idle_plan = Arc::new(FaultPlan::new(
        vec![FaultRule::new(SiteKind::Compute, Action::Panic)
            .shard(usize::MAX)],
        0xC0FF,
    ));
    // Kills shard 0's 11th batch: with 160 requests in bursts of 8
    // over 2 shards there are ~20 batches per shard, so the fault
    // lands mid-stream and the run finishes on the restarted shard.
    let kill_plan = Arc::new(FaultPlan::new(
        vec![FaultRule::new(SiteKind::Compute, Action::Panic)
            .shard(0)
            .nth(10)],
        0xC0FF,
    ));

    let mut all: Vec<Measurement> = Vec::new();
    let mut t = Table::new(
        "Ablation N: chaos — fault-check overhead + recovery latency \
         (fem-8k, b(1,8), 2 shards, 160 offered requests)",
        &["config", "served", "failed", "restarts", "GF/s",
          "recovery ms"],
    );
    let configs: [(&str, Option<Arc<FaultPlan>>); 3] = [
        ("off", None),
        ("armed-idle", Some(Arc::clone(&idle_plan))),
        ("kill-shard0", Some(Arc::clone(&kill_plan))),
    ];
    for (name, faults) in configs {
        let service = start(faults);
        let (wall, failed, recovery_s) = drive(&service, requests);
        let stats = service.stats();
        let served = stats.served;
        let restarts = stats.restarts;
        service.shutdown();
        let gflops = 2.0 * nnz as f64 * served as f64 / wall / 1e9;
        all.push(Measurement {
            matrix: format!("fem-8k/chaos={name}"),
            kernel: KernelKind::Beta(1, 8),
            threads: 2,
            numa: false,
            tile_cols: 0,
            tune: Default::default(),
            gflops,
            seconds: wall,
        });
        if recovery_s > 0.0 {
            all.push(Measurement {
                matrix: format!("fem-8k/chaos={name}/recovery"),
                kernel: KernelKind::Beta(1, 8),
                threads: 2,
                numa: false,
                tile_cols: 0,
                tune: Default::default(),
                gflops: 0.0,
                seconds: recovery_s,
            });
        }
        t.row(vec![
            name.to_string(),
            format!("{served}"),
            format!("{failed}"),
            format!("{restarts}"),
            format!("{gflops:.2}"),
            if recovery_s > 0.0 {
                format!("{:.3}", recovery_s * 1e3)
            } else {
                "-".to_string()
            },
        ]);
        eprintln!(
            "  chaos ablation: {name} served={served} failed={failed} \
             restarts={restarts} recovery={:.3}ms",
            recovery_s * 1e3
        );
    }
    t.emit("ablation_chaos");

    let out = std::env::var("SPC5_BENCH8_JSON")
        .unwrap_or_else(|_| "BENCH_8.json".to_string());
    match runner::write_bench_json(
        std::path::Path::new(&out),
        "kernel_micro/chaos",
        &all,
    ) {
        Ok(()) => eprintln!("  wrote {out}"),
        Err(e) => eprintln!("warning: {e}"),
    }
}

/// Durable-state ablation: what the checksummed envelope + atomic
/// rename path costs over raw `fs::write`/`fs::read`, and what the
/// bounded-memory streaming MatrixMarket parser sustains on a
/// multi-megabyte upload. Per-op latency is persisted to
/// `BENCH_9.json` (`gflops` is 0 for these rows — the measured
/// quantity is `seconds` per operation; the op is encoded in the
/// matrix label suffix), uploaded by CI next to BENCH_3..8.
fn durable_ablation() {
    use spc5::matrix::{market, Coo};
    use spc5::util::durable;

    // A realistic multi-megabyte ASCII corpus: banded 60k matrix,
    // 8 entries per row, serialized through the crate's own
    // MatrixMarket writer. The same bytes exercise both the envelope
    // (as a state payload) and the streaming parser (as an upload).
    let n = 60_000usize;
    let mut coo = Coo::<f64>::new(n, n);
    for r in 0..n {
        for d in 0..8usize {
            let c = (r + d * 7) % n;
            let v = ((r * 31 + d * 17) % 97) as f64 - 48.0;
            coo.push(r, c, v);
        }
    }
    let mut mtx = Vec::new();
    market::write_coo(&mut mtx, &coo).expect("serialize corpus");
    let mb = mtx.len() as f64 / 1e6;
    let payload = String::from_utf8(mtx).expect("corpus is ASCII");

    let dir = std::env::temp_dir().join("spc5_durable_ablation");
    std::fs::create_dir_all(&dir).ok();
    let env_path = dir.join("state.envelope");
    let raw_path = dir.join("state.raw");

    // Envelope save: wrap + checksum + temp-sibling + fsync + rename.
    let s_save_env = mean_of_runs(RUNS, || {
        durable::save_state("bench-ablation", &env_path, &payload)
            .expect("durable save");
    });
    // Raw save: one unchecked fs::write (the pre-hardening path).
    let s_save_raw = mean_of_runs(RUNS, || {
        std::fs::write(&raw_path, payload.as_bytes()).expect("raw save");
    });
    // Envelope load: read + frame parse + checksum verify.
    let s_load_env = mean_of_runs(RUNS, || {
        match durable::read_state("bench-ablation", &env_path)
            .expect("durable load")
        {
            durable::RawState::Payload { text, .. } => {
                std::hint::black_box(&text);
            }
            _ => panic!("envelope file should load as a payload"),
        }
    });
    let s_load_raw = mean_of_runs(RUNS, || {
        std::hint::black_box(
            &std::fs::read_to_string(&raw_path).expect("raw load"),
        );
    });
    // Checksum+frame alone, no I/O: the pure CPU cost of the envelope.
    let s_wrap = mean_of_runs(RUNS, || {
        std::hint::black_box(&durable::wrap(payload.as_bytes()));
    });
    // Streaming parse of the same corpus (line cap, overflow checks,
    // bounded preallocation all engaged).
    let s_parse = mean_of_runs(RUNS, || {
        std::hint::black_box(
            &market::read_coo::<f64, _>(payload.as_bytes())
                .expect("corpus parses"),
        );
    });

    let mut all: Vec<Measurement> = Vec::new();
    let mut record = |op: &str, seconds: f64| {
        all.push(Measurement {
            matrix: format!("mtx-corpus/{op}"),
            kernel: KernelKind::Csr,
            threads: 1,
            numa: false,
            tile_cols: 0,
            tune: Default::default(),
            gflops: 0.0,
            seconds,
        });
    };
    record("save-durable", s_save_env);
    record("save-raw", s_save_raw);
    record("load-durable", s_load_env);
    record("load-raw", s_load_raw);
    record("wrap-only", s_wrap);
    record("parse-stream", s_parse);

    let mut t = Table::new(
        &format!(
            "Ablation O: durable state — envelope + atomic rename vs raw \
             I/O, streaming .mtx parse ({mb:.1} MB corpus)"
        ),
        &["op", "ms", "MB/s", "vs raw"],
    );
    for (op, s, base) in [
        ("save durable (envelope+rename)", s_save_env, Some(s_save_raw)),
        ("save raw fs::write", s_save_raw, None),
        ("load durable (verify)", s_load_env, Some(s_load_raw)),
        ("load raw fs::read", s_load_raw, None),
        ("wrap+checksum only", s_wrap, None),
        ("parse .mtx streaming", s_parse, None),
    ] {
        t.row(vec![
            op.to_string(),
            format!("{:.3}", s * 1e3),
            format!("{:.1}", mb / s),
            match base {
                Some(b) => format!("{:.3}x", s / b),
                None => "-".to_string(),
            },
        ]);
    }
    t.emit("ablation_durable");
    eprintln!("  durable ablation: {mb:.1} MB corpus");

    let out = std::env::var("SPC5_BENCH9_JSON")
        .unwrap_or_else(|_| "BENCH_9.json".to_string());
    match runner::write_bench_json(
        std::path::Path::new(&out),
        "kernel_micro/durable",
        &all,
    ) {
        Ok(()) => eprintln!("  wrote {out}"),
        Err(e) => eprintln!("warning: {e}"),
    }
}

/// Triangular-solve ablation: (a) the SpTRSV execution paths — CSR
/// reference vs the masked block walk over β storage vs the
/// level-scheduled run on the pool — plus one SymGS sweep (sequential
/// vs level-scheduled), timed on the strict lower triangle of
/// poisson2d(60); (b) the preconditioner sweep — PCG with
/// none/jacobi/symgs/ilu0 on the ill-conditioned scaled-Poisson
/// system, the iteration count and convergence encoded in the matrix
/// label (`gflops` is the substitution throughput for the SpTRSV/SymGS
/// rows and 0 for the solver rows, whose measured quantity is
/// `seconds` to converge). Persisted to `BENCH_10.json` (CI artifact
/// next to BENCH_3..9; set `SPC5_BENCH10_JSON` to override the path).
fn solve_ablation() {
    use spc5::coordinator::{cg_solve, pcg_with, PrecondKind};
    use spc5::kernels::sptrsv::{
        sptrsv_lower_block, sptrsv_lower_levels, sptrsv_lower_ref,
    };
    use spc5::kernels::symgs::{symgs, symgs_levels};
    use spc5::matrix::Coo;
    use spc5::parallel::{lower_levels, upper_levels};

    let mut all: Vec<Measurement> = Vec::new();

    // (a) SpTRSV / SymGS paths on the poisson2d(60) split.
    let csr = suite::poisson2d(60);
    let split = csr.triangular_split().expect("square split");
    let n = split.n();
    // 2 flops per strict-lower entry + the diagonal division per row.
    let work = split.lower.nnz() + n;
    let b = bench_vector(n, 0x7125);
    let mut x = vec![0.0f64; n];
    let pool = WorkerPool::new(4);
    let fwd = lower_levels(&split.lower);
    let bwd = upper_levels(&split.upper);
    let bm = csr_to_block(&split.lower, BlockSize::new(2, 4)).unwrap();

    let mut t = Table::new(
        "Ablation Q: SpTRSV paths + SymGS sweep on poisson2d(60) \
         (lower triangle, 4 pool workers for the level paths)",
        &["path", "ms", "GF/s"],
    );
    {
        let mut rec = |label: &str,
                       kernel: KernelKind,
                       threads: usize,
                       seconds: f64,
                       gflops: f64| {
            all.push(Measurement {
                matrix: format!("poisson2d-60/{label}"),
                kernel,
                threads,
                numa: false,
                tile_cols: 0,
                tune: Default::default(),
                gflops,
                seconds,
            });
            t.row(vec![
                label.to_string(),
                format!("{:.3}", seconds * 1e3),
                format!("{gflops:.2}"),
            ]);
        };
        let s = mean_of_runs(RUNS, || {
            sptrsv_lower_ref(&split.lower, &split.diag, &b, &mut x);
            std::hint::black_box(&x);
        });
        rec("sptrsv-csr-ref", KernelKind::Csr, 1, s, spmv_gflops(work, s));
        let s = mean_of_runs(RUNS, || {
            sptrsv_lower_block(&bm, &split.diag, &b, &mut x);
            std::hint::black_box(&x);
        });
        rec(
            "sptrsv-block",
            KernelKind::Beta(2, 4),
            1,
            s,
            spmv_gflops(work, s),
        );
        let s = mean_of_runs(RUNS, || {
            sptrsv_lower_levels(
                &split.lower,
                &split.diag,
                &fwd,
                &pool,
                &b,
                &mut x,
            );
            std::hint::black_box(&x);
        });
        rec("sptrsv-levels", KernelKind::Csr, 4, s, spmv_gflops(work, s));
        // One symmetric sweep touches both triangles + two divisions.
        let gs_work = 2 * (split.lower.nnz() + split.upper.nnz() + n);
        let s = mean_of_runs(RUNS, || {
            symgs(&split, &b, &mut x, 1);
            std::hint::black_box(&x);
        });
        rec("symgs-seq", KernelKind::Csr, 1, s, spmv_gflops(gs_work, s));
        let s = mean_of_runs(RUNS, || {
            symgs_levels(&split, &fwd, &bwd, &pool, &b, &mut x, 1);
            std::hint::black_box(&x);
        });
        rec("symgs-levels", KernelKind::Csr, 4, s, spmv_gflops(gs_work, s));
    }
    t.emit("ablation_solve_paths");
    eprintln!("  solve ablation: SpTRSV/SymGS paths measured");

    // (b) Preconditioner sweep on the ill-conditioned scaled Poisson
    // system (symmetric diagonal scaling, condition ~1e6).
    let a = suite::poisson2d(24);
    let dim = a.rows;
    let scale: Vec<f64> =
        (0..dim).map(|i| 10f64.powi(((i % 7) / 2) as i32)).collect();
    let mut coo = Coo::new(dim, dim);
    for r in 0..dim {
        for k in a.row_range(r) {
            let c = a.colidx[k] as usize;
            coo.push(r, c, scale[r] * a.values[k] * scale[c]);
        }
    }
    let ill = coo.to_csr().expect("scaled poisson");
    let engine = SpmvEngine::builder(ill)
        .kernel(KernelKind::Beta(2, 4))
        .build()
        .expect("solve engine builds");
    let rhs = bench_vector(dim, 0x7126);
    let max_iters = 30_000;
    let tol2 = 1e-12;

    let mut t = Table::new(
        "Ablation R: PCG preconditioner sweep on scaled poisson2d(24) \
         (b(2,4) engine, tol² 1e-12)",
        &["precond", "iterations", "converged", "ms"],
    );
    for kind in [
        PrecondKind::None,
        PrecondKind::Jacobi,
        PrecondKind::SymGs { sweeps: 1 },
        PrecondKind::Ilu0,
    ] {
        let m =
            kind.build(engine.csr(), engine.pool()).expect("precond builds");
        let mut x = vec![0.0; dim];
        let timer = spc5::util::Timer::start();
        let rep = if kind == PrecondKind::None {
            cg_solve(&engine, &rhs, &mut x, max_iters, tol2)
        } else {
            pcg_with(&engine, m.as_ref(), &rhs, &mut x, max_iters, tol2)
        };
        let secs = timer.elapsed_s();
        all.push(Measurement {
            matrix: format!(
                "scaled-poisson-24/precond={kind}/iters={}/converged={}",
                rep.iterations, rep.converged
            ),
            kernel: KernelKind::Beta(2, 4),
            threads: 1,
            numa: false,
            tile_cols: 0,
            tune: Default::default(),
            gflops: 0.0,
            seconds: secs,
        });
        t.row(vec![
            kind.to_string(),
            format!("{}", rep.iterations),
            format!("{}", rep.converged),
            format!("{:.3}", secs * 1e3),
        ]);
        eprintln!(
            "  solve ablation: precond={kind} iters={} converged={}",
            rep.iterations, rep.converged
        );
    }
    t.emit("ablation_solve_precond");

    let out = std::env::var("SPC5_BENCH10_JSON")
        .unwrap_or_else(|_| "BENCH_10.json".to_string());
    match runner::write_bench_json(
        std::path::Path::new(&out),
        "kernel_micro/solve",
        &all,
    ) {
        Ok(()) => eprintln!("  wrote {out}"),
        Err(e) => eprintln!("warning: {e}"),
    }
}

/// Reordering ablation (paper §Matrix permutation: "any improvement to
/// the shape of the matrix will certainly improve the efficiency of
/// our kernels by reducing the number of blocks"): shuffle a structured
/// matrix, then recover with RCM / column packing and measure fill +
/// GFlop/s.
fn reorder_ablation() {
    let m = suite::contact_runs(4_000, 3, 48, 0xAB1);
    let mut rng = spc5::util::Rng::new(13);
    let mut perm: Vec<u32> = (0..m.rows as u32).collect();
    rng.shuffle(&mut perm);
    let shuffle = reorder::Permutation { perm };
    let shuffled = reorder::permute(&m, &shuffle, &shuffle);
    let rcm = reorder::cuthill_mckee(&shuffled);
    let restored = reorder::permute(&shuffled, &rcm, &rcm);
    let cp = reorder::column_pack(&shuffled);
    let packed = reorder::permute(
        &shuffled,
        &reorder::Permutation::identity(shuffled.rows),
        &cp,
    );

    let mut t = Table::new(
        "Ablation C: reordering vs b(2,8) fill and GFlop/s (contact 4k, shuffled)",
        &["variant", "avg(2,8)", "gflops b(2,8)"],
    );
    for (name, csr) in [
        ("original", &m),
        ("shuffled", &shuffled),
        ("rcm", &restored),
        ("column-pack", &packed),
    ] {
        let avg = spc5::formats::stats::block_stats(csr, BlockSize::new(2, 8))
            .avg_nnz_per_block;
        let set = KernelSet::prepare(csr.clone(), &[KernelKind::Beta(2, 8)]);
        let meas =
            spc5::bench::measure_sequential(&set, name, KernelKind::Beta(2, 8));
        t.row(vec![
            name.to_string(),
            format!("{avg:.2}"),
            format!("{:.2}", meas.gflops),
        ]);
    }
    t.emit("ablation_reorder");
}

/// f32 sixteen-lane kernels vs the f64 eight-lane kernels — both
/// served by the same generic stack (`csr_to_block::<T>` +
/// `spmv_block::<T>`).
fn f32_vs_f64() {
    let csr = suite::contact_runs(6_000, 3, 48, 21);
    let csr32 = csr.to_precision::<f32>();
    let x64 = bench_vector(csr.cols, 4);
    let x32: Vec<f32> = x64.iter().map(|&v| v as f32).collect();
    let mut t = Table::new(
        "Ablation D: f32 vexpandps (c=16) vs f64 vexpandpd (c=8)",
        &["kernel", "GFlop/s", "bytes/nnz"],
    );
    for (name, bs64) in
        [("f64 b(1,8)", BlockSize::new(1, 8)), ("f64 b(4,8)", BlockSize::new(4, 8))]
    {
        let bm = csr_to_block(&csr, bs64).unwrap();
        let mut y = vec![0.0f64; csr.rows];
        let s = mean_of_runs(RUNS, || {
            spmv_block(&bm, &x64, &mut y, false);
        });
        t.row(vec![
            name.into(),
            format!("{:.2}", spmv_gflops(bm.nnz(), s)),
            format!("{:.1}", bm.occupancy_bytes() as f64 / bm.nnz() as f64),
        ]);
    }
    for (name, bs32) in [
        ("f32 b(1,16)", BlockSize::new(1, 16)),
        ("f32 b(4,16)", BlockSize::new(4, 16)),
    ] {
        let bm = csr_to_block(&csr32, bs32).unwrap();
        let mut y = vec![0.0f32; csr32.rows];
        let s = mean_of_runs(RUNS, || spmv_block(&bm, &x32, &mut y, false));
        t.row(vec![
            name.into(),
            format!("{:.2}", spmv_gflops(bm.nnz(), s)),
            format!("{:.1}", bm.occupancy_bytes() as f64 / bm.nnz() as f64),
        ]);
    }
    t.emit("ablation_f32");
}

/// Multi-vector SpMM: effective GFlop/s per vector as k grows.
fn spmm_ablation() {
    let csr = suite::fem_blocked(20_000, 3, 8, 31);
    let bm = csr_to_block(&csr, BlockSize::new(2, 8)).unwrap();
    let mut t = Table::new(
        "Ablation E: multi-vector SpMM b(2,8) (x reuse across k vectors)",
        &["k", "total GFlop/s", "GFlop/s per vector"],
    );
    // k = 1 via the SpMV dispatch (AVX-512 when available, scalar
    // otherwise — never a silent no-op).
    let x1 = bench_vector(csr.cols, 6);
    let mut y1 = vec![0.0f64; csr.rows];
    let s1 = mean_of_runs(RUNS, || {
        spmv_block(&bm, &x1, &mut y1, false);
    });
    let g1 = spmv_gflops(bm.nnz(), s1);
    t.row(vec!["1 (spmv)".into(), format!("{g1:.2}"), format!("{g1:.2}")]);
    // k = 8 via the SpMM kernel.
    let x8 = bench_vector(csr.cols * 8, 6);
    let mut y8 = vec![0.0f64; csr.rows * 8];
    let s8 = mean_of_runs(RUNS, || {
        spmm::spmm_k8(&bm, &x8, &mut y8);
    });
    let g8 = 8.0 * spmv_gflops(bm.nnz(), s8);
    t.row(vec!["8 (spmm)".into(), format!("{g8:.2}"), format!("{:.2}", g8 / 8.0)]);
    t.emit("ablation_spmm");
}

/// NUMA x-duplication (paper conclusion): copy cost vs local reads.
fn xcopy_ablation() {
    let csr = suite::fem_blocked(24_000, 3, 8, 41);
    let bm = csr_to_block(&csr, BlockSize::new(2, 8)).unwrap();
    let mut t = Table::new(
        "Ablation F: parallel strategies at 4 threads (1-core host: copy \
         costs visible, NUMA latency benefits are not)",
        &["strategy", "GFlop/s"],
    );
    for (name, strategy) in [
        ("shared", ParallelStrategy::Shared),
        ("numa-split", ParallelStrategy::NumaSplit),
        ("numa-split + x copy", ParallelStrategy::NumaSplitXCopy),
    ] {
        let p = ParallelSpmv::new(bm.clone(), 4, strategy, false);
        let m = spc5::bench::measure_parallel(&p, "fem", KernelKind::Beta(2, 8));
        t.row(vec![name.into(), format!("{:.2}", m.gflops)]);
    }
    t.emit("ablation_xcopy");
}

/// Pool epoch handoff vs per-call thread spawning — the dispatch
/// overhead an iterative solver pays on *every* SpMV (the reason the
/// runtime keeps its workers alive; paper: the threads "do not wait",
/// SPC5 keeps them across the whole run).
fn pool_handoff_ablation() {
    const DISPATCHES: usize = 200;
    let threads = 4usize;
    let pool = WorkerPool::new(threads);
    let mut t = Table::new(
        "Ablation H: per-SpMV dispatch cost, persistent pool vs \
         thread::scope spawn (4 workers, empty task)",
        &["mechanism", "µs per dispatch"],
    );
    let s_pool = mean_of_runs(RUNS, || {
        for _ in 0..DISPATCHES {
            pool.run(|_ctx| {});
        }
    });
    t.row(vec![
        "pool epoch handoff".into(),
        format!("{:.2}", s_pool / DISPATCHES as f64 * 1e6),
    ]);
    let s_scope = mean_of_runs(RUNS, || {
        for _ in 0..DISPATCHES {
            std::thread::scope(|scope| {
                for _ in 0..threads {
                    scope.spawn(|| {});
                }
            });
        }
    });
    t.row(vec![
        "scoped spawn (old runtime)".into(),
        format!("{:.2}", s_scope / DISPATCHES as f64 * 1e6),
    ]);
    t.emit("ablation_pool_handoff");
}

/// Batched multi-RHS through the parallel runtime: requests/s a server
/// gets from coalescing k clients into one traversal vs k separate
/// parallel SpMVs on the same pool.
fn batched_parallel_ablation() {
    let csr = suite::fem_blocked(20_000, 3, 8, 47);
    let bm = csr_to_block(&csr, BlockSize::new(2, 8)).unwrap();
    let p = ParallelSpmv::new(bm, 4, ParallelStrategy::Shared, false);
    let k = 8usize;
    let mut t = Table::new(
        "Ablation I: serving k=8 requests, batched spmm vs k spmv \
         (b(2,8), 4 pool workers)",
        &["path", "total GFlop/s", "per-request GFlop/s"],
    );
    let nnz = p.matrix().nnz();
    let x1 = bench_vector(csr.cols, 3);
    let mut y1 = vec![0.0f64; csr.rows];
    let s_seq = mean_of_runs(RUNS, || {
        for _ in 0..k {
            y1.iter_mut().for_each(|v| *v = 0.0);
            p.spmv(&x1, &mut y1);
        }
    });
    let g_seq = k as f64 * spmv_gflops(nnz, s_seq);
    t.row(vec![
        "k × spmv".into(),
        format!("{g_seq:.2}"),
        format!("{:.2}", g_seq / k as f64),
    ]);
    let xk = bench_vector(csr.cols * k, 3);
    let mut yk = vec![0.0f64; csr.rows * k];
    let s_bat = mean_of_runs(RUNS, || {
        yk.iter_mut().for_each(|v| *v = 0.0);
        p.spmm(&xk, &mut yk, k);
    });
    let g_bat = k as f64 * spmv_gflops(nnz, s_bat);
    t.row(vec![
        "1 × spmm(k=8)".into(),
        format!("{g_bat:.2}"),
        format!("{:.2}", g_bat / k as f64),
    ]);
    t.emit("ablation_batched_parallel");
}

/// Record-based vs analytic-model kernel selection.
fn predictor_ablation() {
    use spc5::predictor::model::{calibrate, select_by_model};
    let kinds = KernelKind::SPC5_KERNELS;
    // Calibrate the model from one CSR measurement.
    let cal = suite::by_name("bone010").unwrap();
    let set = KernelSet::prepare(cal.csr.clone(), &[KernelKind::Csr]);
    let csr_meas =
        spc5::bench::measure_sequential(&set, "bone010", KernelKind::Csr);
    let machine = calibrate(csr_meas.gflops);

    let mut t = Table::new(
        "Ablation G: analytic-model selection (no training records)",
        &["matrix", "model pick", "measured best", "loss%"],
    );
    for name in ["nd6k", "ns3Da", "pwtk", "kron_g500-logn21", "Dense-8000"] {
        let sm = suite::by_name(name).unwrap();
        let (pick, _) = select_by_model(&sm.csr, &machine, &kinds);
        let set = KernelSet::prepare(sm.csr.clone(), &kinds);
        let mut best = (kinds[0], 0.0f64);
        let mut pick_g = 0.0f64;
        for k in kinds {
            let m = spc5::bench::measure_sequential(&set, name, k);
            if m.gflops > best.1 {
                best = (k, m.gflops);
            }
            if k == pick {
                pick_g = m.gflops;
            }
        }
        t.row(vec![
            name.into(),
            pick.to_string(),
            best.0.to_string(),
            format!("{:.1}%", 100.0 * (best.1 - pick_g) / best.1),
        ]);
        eprintln!("  ablation G: {name}");
    }
    t.emit("ablation_model");
}

/// AVX-512 vexpand kernels vs the scalar Algorithm-1 on one matrix.
fn simd_vs_scalar() {
    let csr = suite::fem_blocked(30_000, 3, 8, 5);
    let x = bench_vector(csr.cols, 9);
    let mut t = Table::new(
        "Ablation B: AVX-512 vexpand vs scalar Algorithm 1 (bone010-class)",
        &["block size", "scalar GF/s", "avx512 GF/s", "speedup"],
    );
    for bs in BlockSize::PAPER_SIZES {
        let bm = csr_to_block(&csr, bs).unwrap();
        let mut y = vec![0.0; csr.rows];
        let s_scalar = mean_of_runs(RUNS, || {
            scalar::spmv_generic(&bm, &x, &mut y);
        });
        let g_scalar = spmv_gflops(bm.nnz(), s_scalar);
        let (g_simd, speedup) = if spc5::util::avx512_available() {
            let s_simd = mean_of_runs(RUNS, || {
                avx512::spmv(&bm, &x, &mut y, false);
            });
            let g = spmv_gflops(bm.nnz(), s_simd);
            (g, g / g_scalar)
        } else {
            (f64::NAN, f64::NAN)
        };
        t.row(vec![
            bs.to_string(),
            format!("{g_scalar:.2}"),
            format!("{g_simd:.2}"),
            format!("{speedup:.2}x"),
        ]);
    }
    t.emit("ablation_simd");
}
