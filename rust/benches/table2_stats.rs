//! Regenerates paper **Table 2**: Set-B matrices (the independent
//! prediction-evaluation set) with the same statistics as Table 1.

use spc5::matrix::suite;

#[path = "table1_stats.rs"]
#[allow(dead_code)]
mod table1;

fn main() {
    table1::run("Table 2 (Set-B): block statistics", suite::set_b(), "table2");
}
