//! Regenerates paper **Table 3**: sequential kernel selection quality.
//!
//! Models are fitted on Set-A records (polynomial interpolation, Fig. 5);
//! then for every matrix of Set-A ∪ Set-B the bench reports the best
//! kernel (measured), the selected kernel, the predicted and real speed
//! of the selection, and the speed difference — 0% means the optimal
//! kernel was selected.

use spc5::bench::runner::{ensure_records, kernel_avg, maybe_quick, run_sequential};
use spc5::bench::Table;
use spc5::kernels::KernelKind;
use spc5::matrix::suite;
use spc5::predictor::select_sequential;

fn main() {
    let set_a = maybe_quick(suite::set_a());
    let kernels = KernelKind::SPC5_KERNELS;
    // Fit on Set-A (baselines included in the store but selection ranks
    // only the SPC5 kernels, as in the paper's Table 3).
    let store = ensure_records(&set_a, &KernelKind::ALL, &[1])
        .expect("record store");

    let eval: Vec<_> = set_a
        .into_iter()
        .chain(maybe_quick(suite::set_b()))
        .collect();

    let mut t = Table::new(
        "Table 3: sequential kernel selection (Set-A fitted, Set-A+B evaluated)",
        &[
            "matrix", "best kernel", "best speed", "selected", "predicted",
            "real speed", "speed diff",
        ],
    );
    let mut exact = 0usize;
    let mut close = 0usize;
    for sm in &eval {
        let sel = select_sequential(&sm.csr, &store, &kernels)
            .expect("records fitted");
        // Measure all candidates to find the ground-truth optimum.
        let (ms, _) = run_sequential(
            &[suite::SuiteMatrix {
                name: sm.name,
                class: sm.class,
                csr: sm.csr.clone(),
            }],
            &kernels,
        );
        let best = ms
            .iter()
            .max_by(|a, b| a.gflops.partial_cmp(&b.gflops).unwrap())
            .unwrap();
        let real = ms
            .iter()
            .find(|m| m.kernel == sel.kernel)
            .map(|m| m.gflops)
            .unwrap_or(0.0);
        let diff = 100.0 * (best.gflops - real) / best.gflops;
        if sel.kernel == best.kernel {
            exact += 1;
        }
        if diff <= 10.0 {
            close += 1;
        }
        t.row(vec![
            sm.name.to_string(),
            best.kernel.to_string(),
            format!("{:.2}", best.gflops),
            sel.kernel.to_string(),
            format!("{:.2}", sel.predicted_gflops),
            format!("{real:.2}"),
            format!("{diff:.2}%"),
        ]);
    }
    t.emit("table3");
    println!(
        "selection exact-optimal on {exact}/{} matrices; within 10% on \
         {close}/{} (paper: optimal or near-optimal in most cases)",
        eval.len(),
        eval.len()
    );
}
