//! Regenerates the paper's §Test-matrices claim: "The time taken to
//! convert any of the matrices from Set-A from the CSR format to one of
//! ours is around twice the time of a single SpMV in sequential."
//!
//! Reports, per matrix and block size, conversion time / one sequential
//! CSR SpMV.

use spc5::bench::runner::maybe_quick;
use spc5::bench::{bench_vector, Table, RUNS};
use spc5::formats::{csr_to_block, BlockSize};
use spc5::matrix::suite;
use spc5::util::timer::mean_of_runs;

fn main() {
    let matrices = maybe_quick(suite::set_a());
    let mut t = Table::new(
        "Conversion cost: CSR->b(r,c) time as multiple of one CSR SpMV",
        &["matrix", "spmv ms", "b(1,8)", "b(2,4)", "b(2,8)", "b(4,4)",
          "b(4,8)", "b(8,4)"],
    );
    let mut ratios: Vec<f64> = Vec::new();
    for sm in &matrices {
        let x = bench_vector(sm.csr.cols, 3);
        let mut y = vec![0.0; sm.csr.rows];
        let spmv_s = mean_of_runs(RUNS, || {
            spc5::kernels::csr::spmv(&sm.csr, &x, &mut y);
        });
        let mut row =
            vec![sm.name.to_string(), format!("{:.3}", spmv_s * 1e3)];
        for bs in BlockSize::PAPER_SIZES {
            let conv_s = mean_of_runs(4, || {
                std::hint::black_box(csr_to_block(&sm.csr, bs).unwrap());
            });
            let ratio = conv_s / spmv_s;
            ratios.push(ratio);
            row.push(format!("{ratio:.1}x"));
        }
        t.row(row);
        eprintln!("  measured {}", sm.name);
    }
    t.emit("conversion_cost");
    ratios.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!(
        "conversion/spmv ratio: median {:.1}x, p90 {:.1}x (paper: ~2x)",
        ratios[ratios.len() / 2],
        ratios[ratios.len() * 9 / 10]
    );
}
