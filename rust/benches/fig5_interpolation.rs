//! Regenerates paper **Fig. 5**: per-kernel polynomial interpolation of
//! sequential GFlop/s against the average number of NNZ per block,
//! fitted on Set-A records.
//!
//! Prints the fitted coefficients, the RMSE on the training dots, and
//! a sampled curve per kernel (the CSV is the plot data).

use spc5::bench::runner::{ensure_records, maybe_quick};
use spc5::bench::Table;
use spc5::kernels::KernelKind;
use spc5::matrix::suite;
use spc5::predictor::select::fit_sequential;

fn main() {
    let matrices = maybe_quick(suite::set_a());
    let kernels = KernelKind::ALL;
    let store =
        ensure_records(&matrices, &kernels, &[1]).expect("record store");

    let models = fit_sequential(&store, &kernels);

    let mut t = Table::new(
        "Fig. 5: polynomial fit gflops ~ avg nnz/block (sequential, Set-A)",
        &["kernel", "#dots", "coeffs (c0..c3)", "rmse"],
    );
    for k in kernels {
        let recs = store.for_kernel(k, 1);
        let Some(m) = models.get(&k) else { continue };
        let xs: Vec<f64> = recs.iter().map(|r| r.avg_nnz_per_block).collect();
        let ys: Vec<f64> = recs.iter().map(|r| r.gflops).collect();
        t.row(vec![
            k.to_string(),
            xs.len().to_string(),
            m.coeffs
                .iter()
                .map(|c| format!("{c:.4}"))
                .collect::<Vec<_>>()
                .join(", "),
            format!("{:.3}", m.rmse(&xs, &ys)),
        ]);
    }
    t.emit("fig5_models");

    // Sampled curves: gflops prediction at avg = 1..32 per kernel.
    let mut curve = Table::new(
        "Fig. 5 curves: predicted GFlop/s vs avg nnz/block",
        &["avg", "csr", "csr5", "b(1,8)", "b(1,8)t", "b(2,4)", "b(2,4)t",
          "b(2,8)", "b(4,4)", "b(4,8)", "b(8,4)"],
    );
    for step in 0..32 {
        let avg = 1.0 + step as f64;
        let mut row = vec![format!("{avg:.0}")];
        for k in kernels {
            let v = models.get(&k).map(|m| m.eval(avg)).unwrap_or(f64::NAN);
            row.push(format!("{v:.2}"));
        }
        curve.row(row);
    }
    curve.emit("fig5_curves");

    // The paper's qualitative observation: dots correlate with avg.
    for k in [KernelKind::Beta(1, 8), KernelKind::Beta(4, 8)] {
        let recs = store.for_kernel(k, 1);
        if recs.len() < 4 {
            continue;
        }
        let lo: Vec<f64> = recs
            .iter()
            .filter(|r| r.avg_nnz_per_block < 3.0)
            .map(|r| r.gflops)
            .collect();
        let hi: Vec<f64> = recs
            .iter()
            .filter(|r| r.avg_nnz_per_block >= 3.0)
            .map(|r| r.gflops)
            .collect();
        if !lo.is_empty() && !hi.is_empty() {
            let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
            println!(
                "{k}: mean gflops at avg<3 = {:.2}, at avg>=3 = {:.2} \
                 (paper: clear positive correlation)",
                mean(&lo),
                mean(&hi)
            );
        }
    }
}
