//! The unified executor surface: every compiled sparse storage behind
//! one object-safe [`SparseStorage`] trait.
//!
//! Before this module the engine kept a closed enum of storages and
//! three parallel match ladders (`spmv`, `spmm`, accessors) that had to
//! grow a new arm for every format. The trait collapses them: a built
//! [`crate::SpmvEngine`] holds exactly one `Box<dyn SparseStorage<T>>`
//! and dispatches products without ever inspecting the kernel kind.
//!
//! Implementors:
//!
//! - [`BlockMatrix`] — the sequential `β(r,c)` kernel (the parallel β
//!   runtime is [`ParallelSpmv`], which also implements the trait and
//!   self-schedules on its own pool attachment).
//! - [`HybridMatrix`] / [`TiledHybrid`] — the row-panel schedule, flat
//!   and cache-blocked; pooled execution splits *segments* by nnz.
//! - [`TiledMatrix`] — cache-blocked β spans; pooled execution splits
//!   row *panels* by nnz, tiles stay the inner sequential loop.
//! - [`BetaTestStorage`] — the Algorithm-2 `test` execution of a flat
//!   or tiled β storage.
//! - [`CsrStorage`] / [`Csr5Storage`] — the paper's comparators. CSR
//!   runs row-chunked on the pool; CSR5 is sequential by construction.
//!   Neither has a native multi-RHS kernel, so their `spmm` is the
//!   de-interleaved per-vector fallback through storage-owned scratch
//!   (no per-batch allocation on the serving path).
//!
//! Pooled entry points receive a [`PoolExec`]: the engine's persistent
//! [`WorkerPool`], the **precomputed** nnz-balanced chunk split (from
//! [`SparseStorage::par_split`], computed once at build so the hot
//! path never re-balances), and the attach id for per-worker scratch.

use super::hybrid::HybridMatrix;
use super::tiled::{TiledHybrid, TiledMatrix};
use super::{BlockMatrix, FormatError};
use crate::kernels::csr5::Csr5Matrix;
use crate::kernels::{csr as csr_kernel, spmm, spmv_block, KernelKind};
use crate::matrix::Csr;
use crate::parallel::{
    balanced_prefix_split, ParallelSpmv, SendSlice, WorkerCtx, WorkerPool,
};
use crate::scalar::Scalar;
use std::any::Any;
use std::sync::{Arc, Mutex};

/// Execution context for a pooled product: the engine's persistent
/// worker pool, the prebalanced chunk split (one `(begin, end)` work
/// range per worker, in the storage's own work units — rows, panels or
/// segments), and the attach id for per-worker scratch vectors.
#[derive(Clone, Copy)]
pub struct PoolExec<'a> {
    pub pool: &'a WorkerPool,
    pub chunks: &'a [(usize, usize)],
    pub scratch_attach: u64,
}

/// A compiled sparse storage ready to serve products — the executor
/// half of the inspector–executor split. Object-safe: the engine holds
/// one `Box<dyn SparseStorage<T>>` and never matches on the kind.
pub trait SparseStorage<T: Scalar>: Send + Sync {
    /// The kernel class this storage executes (what a
    /// [`crate::coordinator::SpmvPlan`] records).
    fn kernel_kind(&self) -> KernelKind;

    /// Sequential `y += A·x`.
    fn spmv_seq(&self, x: &[T], y: &mut [T]);

    /// Parallel `y += A·x` on the engine's pool. `exec.chunks` must be
    /// this storage's own [`SparseStorage::par_split`] for the pool's
    /// worker count.
    fn spmv_pooled(&self, exec: PoolExec<'_>, x: &[T], y: &mut [T]);

    /// Multi-RHS `Y += A·X` (`x` row-major `[cols × k]`, `y`
    /// `[rows × k]`), pooled when `exec` is supplied.
    fn spmm(&self, exec: Option<PoolExec<'_>>, x: &[T], y: &mut [T], k: usize);

    /// Structural invariants of the compiled storage.
    fn validate(&self) -> Result<(), FormatError>;

    /// The nnz-balanced split of this storage's parallel work units
    /// for `n` workers. Empty = no chunked pooled path (the storage
    /// either runs sequentially or, like [`ParallelSpmv`], schedules
    /// itself). Called once at engine build; the result is what
    /// [`PoolExec::chunks`] carries on every call.
    fn par_split(&self, n: usize) -> Vec<(usize, usize)> {
        let _ = n;
        Vec::new()
    }

    /// Resolved column tile width when the storage executes
    /// cache-blocked (`None` = flat schedule).
    fn tile_cols(&self) -> Option<usize> {
        None
    }

    /// Downcast support for the per-kind convenience accessors
    /// (`engine.hybrid()`, `engine.tiled()`, ...).
    fn as_any(&self) -> &dyn Any;
}

/// Splits an ordered work list into `n` contiguous runs of
/// approximately equal weight via the paper's prefix rule — the one
/// balancing routine behind every `par_split` here.
pub fn nnz_chunks(
    nnzs: impl Iterator<Item = usize>,
    n: usize,
) -> Vec<(usize, usize)> {
    let mut prefix = vec![0u32];
    let mut acc = 0u64;
    for w in nnzs {
        acc += w as u64;
        prefix.push(u32::try_from(acc).expect("nnz fits the u32 prefix"));
    }
    balanced_prefix_split(&prefix, n)
}

// ---------------------------------------------------------------- β --

impl<T: Scalar> SparseStorage<T> for BlockMatrix<T> {
    fn kernel_kind(&self) -> KernelKind {
        KernelKind::Beta(self.bs.r as u8, self.bs.c as u8)
    }

    fn spmv_seq(&self, x: &[T], y: &mut [T]) {
        spmv_block(self, x, y, false);
    }

    /// The flat block matrix has no chunked pooled path — parallel β
    /// execution is [`ParallelSpmv`] (per-worker working vectors, NUMA
    /// strategies). `par_split` stays empty so this is never reached
    /// through the engine; a direct call degrades to sequential.
    fn spmv_pooled(&self, _exec: PoolExec<'_>, x: &[T], y: &mut [T]) {
        self.spmv_seq(x, y);
    }

    fn spmm(
        &self,
        _exec: Option<PoolExec<'_>>,
        x: &[T],
        y: &mut [T],
        k: usize,
    ) {
        spmm::spmm_auto(self, x, y, k);
    }

    fn validate(&self) -> Result<(), FormatError> {
        BlockMatrix::validate(self)
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
}

/// Algorithm-2 `test` execution of a β storage, flat or cache-blocked.
/// A thin marker wrapper: the underlying formats are identical, only
/// the kernel's single-value fast path differs, and multi-RHS products
/// use the standard SpMM traversal (Algorithm 2 has no `k > 1` form).
pub enum BetaTestStorage<T: Scalar> {
    Flat(BlockMatrix<T>),
    Tiled(TiledMatrix<T>),
}

impl<T: Scalar> BetaTestStorage<T> {
    fn bs(&self) -> super::BlockSize {
        match self {
            BetaTestStorage::Flat(bm) => bm.bs,
            BetaTestStorage::Tiled(tm) => tm.bs,
        }
    }
}

impl<T: Scalar> SparseStorage<T> for BetaTestStorage<T> {
    fn kernel_kind(&self) -> KernelKind {
        let bs = self.bs();
        KernelKind::BetaTest(bs.r as u8, bs.c as u8)
    }

    fn spmv_seq(&self, x: &[T], y: &mut [T]) {
        match self {
            BetaTestStorage::Flat(bm) => spmv_block(bm, x, y, true),
            BetaTestStorage::Tiled(tm) => tm.spmv(x, y, true),
        }
    }

    fn spmv_pooled(&self, exec: PoolExec<'_>, x: &[T], y: &mut [T]) {
        match self {
            // Flat parallel test kernels run through ParallelSpmv.
            BetaTestStorage::Flat(bm) => spmv_block(bm, x, y, true),
            BetaTestStorage::Tiled(tm) => {
                tiled_block_pooled(tm, exec, x, y, 1, true)
            }
        }
    }

    fn spmm(
        &self,
        exec: Option<PoolExec<'_>>,
        x: &[T],
        y: &mut [T],
        k: usize,
    ) {
        match (self, exec) {
            (BetaTestStorage::Flat(bm), _) => spmm::spmm_auto(bm, x, y, k),
            (BetaTestStorage::Tiled(tm), None) => tm.spmm(x, y, k),
            (BetaTestStorage::Tiled(tm), Some(exec)) => {
                tiled_block_pooled(tm, exec, x, y, k, true)
            }
        }
    }

    fn validate(&self) -> Result<(), FormatError> {
        match self {
            BetaTestStorage::Flat(bm) => bm.validate(),
            BetaTestStorage::Tiled(tm) => tm.validate(),
        }
    }

    fn par_split(&self, n: usize) -> Vec<(usize, usize)> {
        match self {
            BetaTestStorage::Flat(_) => Vec::new(),
            BetaTestStorage::Tiled(tm) => {
                nnz_chunks(tm.panels.iter().map(|p| p.nnz), n)
            }
        }
    }

    fn tile_cols(&self) -> Option<usize> {
        match self {
            BetaTestStorage::Flat(_) => None,
            BetaTestStorage::Tiled(tm) => Some(tm.tile_cols),
        }
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
}

/// The parallel β runtime is its own scheduler: it attached to the
/// engine's pool at construction, owns per-worker working vectors and
/// the NUMA array-split strategies, so both entry points run the same
/// epoch handoff and `par_split` stays empty.
impl<T: Scalar> SparseStorage<T> for ParallelSpmv<T> {
    fn kernel_kind(&self) -> KernelKind {
        let bs = self.matrix().bs;
        if self.algo2_test() {
            KernelKind::BetaTest(bs.r as u8, bs.c as u8)
        } else {
            KernelKind::Beta(bs.r as u8, bs.c as u8)
        }
    }

    fn spmv_seq(&self, x: &[T], y: &mut [T]) {
        self.spmv(x, y);
    }

    fn spmv_pooled(&self, _exec: PoolExec<'_>, x: &[T], y: &mut [T]) {
        self.spmv(x, y);
    }

    fn spmm(
        &self,
        _exec: Option<PoolExec<'_>>,
        x: &[T],
        y: &mut [T],
        k: usize,
    ) {
        ParallelSpmv::spmm(self, x, y, k);
    }

    fn validate(&self) -> Result<(), FormatError> {
        self.matrix().validate()
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
}

// ----------------------------------------------------------- hybrid --

impl<T: Scalar> SparseStorage<T> for HybridMatrix<T> {
    fn kernel_kind(&self) -> KernelKind {
        KernelKind::Hybrid
    }

    fn spmv_seq(&self, x: &[T], y: &mut [T]) {
        HybridMatrix::spmv(self, x, y);
    }

    fn spmv_pooled(&self, exec: PoolExec<'_>, x: &[T], y: &mut [T]) {
        hybrid_pooled(self, exec, x, y, 1);
    }

    fn spmm(
        &self,
        exec: Option<PoolExec<'_>>,
        x: &[T],
        y: &mut [T],
        k: usize,
    ) {
        match exec {
            None => HybridMatrix::spmm(self, x, y, k),
            Some(exec) => hybrid_pooled(self, exec, x, y, k),
        }
    }

    fn validate(&self) -> Result<(), FormatError> {
        HybridMatrix::validate(self)
    }

    fn par_split(&self, n: usize) -> Vec<(usize, usize)> {
        nnz_chunks(self.segments.iter().map(|s| s.nnz), n)
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
}

impl<T: Scalar> SparseStorage<T> for TiledMatrix<T> {
    fn kernel_kind(&self) -> KernelKind {
        KernelKind::Beta(self.bs.r as u8, self.bs.c as u8)
    }

    fn spmv_seq(&self, x: &[T], y: &mut [T]) {
        TiledMatrix::spmv(self, x, y, false);
    }

    fn spmv_pooled(&self, exec: PoolExec<'_>, x: &[T], y: &mut [T]) {
        tiled_block_pooled(self, exec, x, y, 1, false);
    }

    fn spmm(
        &self,
        exec: Option<PoolExec<'_>>,
        x: &[T],
        y: &mut [T],
        k: usize,
    ) {
        match exec {
            None => TiledMatrix::spmm(self, x, y, k),
            Some(exec) => tiled_block_pooled(self, exec, x, y, k, false),
        }
    }

    fn validate(&self) -> Result<(), FormatError> {
        TiledMatrix::validate(self)
    }

    fn par_split(&self, n: usize) -> Vec<(usize, usize)> {
        nnz_chunks(self.panels.iter().map(|p| p.nnz), n)
    }

    fn tile_cols(&self) -> Option<usize> {
        Some(self.tile_cols)
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
}

impl<T: Scalar> SparseStorage<T> for TiledHybrid<T> {
    fn kernel_kind(&self) -> KernelKind {
        KernelKind::Tiled(self.tile_cols as u32)
    }

    fn spmv_seq(&self, x: &[T], y: &mut [T]) {
        TiledHybrid::spmv(self, x, y);
    }

    fn spmv_pooled(&self, exec: PoolExec<'_>, x: &[T], y: &mut [T]) {
        tiled_hybrid_pooled(self, exec, x, y, 1);
    }

    fn spmm(
        &self,
        exec: Option<PoolExec<'_>>,
        x: &[T],
        y: &mut [T],
        k: usize,
    ) {
        match exec {
            None => TiledHybrid::spmm(self, x, y, k),
            Some(exec) => tiled_hybrid_pooled(self, exec, x, y, k),
        }
    }

    fn validate(&self) -> Result<(), FormatError> {
        TiledHybrid::validate(self)
    }

    fn par_split(&self, n: usize) -> Vec<(usize, usize)> {
        nnz_chunks(self.segments.iter().map(|s| s.nnz), n)
    }

    fn tile_cols(&self) -> Option<usize> {
        Some(self.tile_cols)
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
}

// -------------------------------------------------------- baselines --

/// The CSR baseline storage: the matrix itself (shared with the engine
/// — no second copy) plus the de-interleave scratch its multi-RHS
/// fallback reuses across batches.
pub struct CsrStorage<T: Scalar> {
    csr: Arc<Csr<T>>,
    /// Reusable `(xj, yj)` buffers for the per-vector SpMM fallback —
    /// storage-owned so the micro-batching service does not allocate
    /// two fresh vectors per batch. Uncontended in practice (products
    /// on one engine are serialized by their callers); the lock only
    /// keeps `spmm(&self, ..)` shareable.
    spmm_scratch: Mutex<(Vec<T>, Vec<T>)>,
}

impl<T: Scalar> CsrStorage<T> {
    pub fn new(csr: Arc<Csr<T>>) -> Self {
        CsrStorage { csr, spmm_scratch: Mutex::new((Vec::new(), Vec::new())) }
    }
}

impl<T: Scalar> SparseStorage<T> for CsrStorage<T> {
    fn kernel_kind(&self) -> KernelKind {
        KernelKind::Csr
    }

    fn spmv_seq(&self, x: &[T], y: &mut [T]) {
        csr_kernel::spmv(&self.csr, x, y);
    }

    /// Row-chunked parallel CSR: each pool worker owns a disjoint
    /// contiguous row range (balanced by nnz at build time) and writes
    /// its own `y` slice — same syncless-merge shape as the β runtime,
    /// on the same persistent workers.
    fn spmv_pooled(&self, exec: PoolExec<'_>, x: &[T], y: &mut [T]) {
        assert_eq!(x.len(), self.csr.cols);
        assert_eq!(y.len(), self.csr.rows);
        debug_assert_eq!(exec.chunks.len(), exec.pool.n_threads());
        let y_all = SendSlice::new(y);
        let csr = &*self.csr;
        exec.pool.run(|ctx: WorkerCtx<'_>| {
            let (r0, r1) = exec.chunks[ctx.tid];
            if r0 == r1 {
                return;
            }
            // SAFETY: chunks are contiguous and disjoint across
            // workers; the borrow outlives the blocked `run` call.
            let part = unsafe { y_all.subslice_mut(r0, r1) };
            csr_kernel::spmv_rows(csr, r0, r1, x, part);
        });
    }

    fn spmm(
        &self,
        exec: Option<PoolExec<'_>>,
        x: &[T],
        y: &mut [T],
        k: usize,
    ) {
        baseline_spmm(
            &self.spmm_scratch,
            self.csr.rows,
            self.csr.cols,
            x,
            y,
            k,
            |xj, yj| match exec {
                Some(exec) => self.spmv_pooled(exec, xj, yj),
                None => self.spmv_seq(xj, yj),
            },
        );
    }

    fn validate(&self) -> Result<(), FormatError> {
        let c = &self.csr;
        if c.rowptr.len() != c.rows + 1
            || c.colidx.len() != c.values.len()
            || *c.rowptr.last().unwrap_or(&0) as usize != c.values.len()
        {
            return Err(FormatError::Inconsistent(
                "csr rowptr/colidx/values lengths disagree".into(),
            ));
        }
        Ok(())
    }

    fn par_split(&self, n: usize) -> Vec<(usize, usize)> {
        balanced_prefix_split(&self.csr.rowptr, n)
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
}

/// The CSR5 comparator storage — sequential by construction (the
/// reference kernel carries open-row state across tiles), so
/// `par_split` stays empty and the pooled entry degrades to the
/// sequential kernel.
pub struct Csr5Storage<T: Scalar> {
    m: Csr5Matrix<T>,
    spmm_scratch: Mutex<(Vec<T>, Vec<T>)>,
}

impl<T: Scalar> Csr5Storage<T> {
    pub fn new(m: Csr5Matrix<T>) -> Self {
        Csr5Storage { m, spmm_scratch: Mutex::new((Vec::new(), Vec::new())) }
    }

    /// The wrapped CSR5 matrix.
    pub fn matrix(&self) -> &Csr5Matrix<T> {
        &self.m
    }
}

impl<T: Scalar> SparseStorage<T> for Csr5Storage<T> {
    fn kernel_kind(&self) -> KernelKind {
        KernelKind::Csr5
    }

    fn spmv_seq(&self, x: &[T], y: &mut [T]) {
        self.m.spmv(x, y);
    }

    fn spmv_pooled(&self, _exec: PoolExec<'_>, x: &[T], y: &mut [T]) {
        self.m.spmv(x, y);
    }

    fn spmm(
        &self,
        _exec: Option<PoolExec<'_>>,
        x: &[T],
        y: &mut [T],
        k: usize,
    ) {
        baseline_spmm(
            &self.spmm_scratch,
            self.m.rows,
            self.m.cols,
            x,
            y,
            k,
            |xj, yj| self.m.spmv(xj, yj),
        );
    }

    fn validate(&self) -> Result<(), FormatError> {
        // CSR5 conversion is validated by construction (the tiled part
        // and CSR tail partition the nnz exactly); nothing structural
        // is exposed to re-check here.
        Ok(())
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
}

// ------------------------------------------------ shared exec bodies --

/// Parallel hybrid pass: each pool worker owns a contiguous run of
/// schedule segments (balanced by nnz at build time) and writes the
/// disjoint `y` rows those segments cover — the same syncless-merge
/// shape as the other parallel paths. Serves both SpMV (`k == 1`) and
/// SpMM (`k > 1`) epochs.
fn hybrid_pooled<T: Scalar>(
    hm: &HybridMatrix<T>,
    exec: PoolExec<'_>,
    x: &[T],
    y: &mut [T],
    k: usize,
) {
    debug_assert_eq!(exec.chunks.len(), exec.pool.n_threads());
    let y_all = SendSlice::new(y);
    exec.pool.run(|ctx: WorkerCtx<'_>| {
        let (s0, s1) = exec.chunks[ctx.tid];
        for seg in &hm.segments[s0..s1] {
            // SAFETY: segments are ordered and disjoint in rows, and
            // chunks are contiguous disjoint segment ranges, so no two
            // workers touch the same `y` rows; the borrow outlives the
            // blocked `run` call.
            let part = unsafe {
                y_all.subslice_mut(seg.row_begin * k, seg.row_end * k)
            };
            if k == 1 {
                seg.spmv(x, part);
            } else {
                seg.spmm(x, part, k);
            }
        }
    });
}

/// Parallel tiled-β pass: the 2-D `(panel, tile)` schedule on the
/// pool. Workers own disjoint contiguous **row-panel** ranges
/// (balanced by nnz at build time) so no two workers touch the same
/// `y` rows and no atomics are needed; each worker walks its panels'
/// column tiles as an inner sequential loop, which is what keeps its
/// `x` window cache-resident.
fn tiled_block_pooled<T: Scalar>(
    tm: &TiledMatrix<T>,
    exec: PoolExec<'_>,
    x: &[T],
    y: &mut [T],
    k: usize,
    test: bool,
) {
    debug_assert_eq!(exec.chunks.len(), exec.pool.n_threads());
    let y_all = SendSlice::new(y);
    let attach = exec.scratch_attach;
    exec.pool.run(|ctx: WorkerCtx<'_>| {
        let (p0, p1) = exec.chunks[ctx.tid];
        if p0 == p1 {
            return;
        }
        let row_begin = tm.panels[p0].row_begin;
        let row_end = tm.panels[p1 - 1].row_end;
        // SAFETY: panels are ordered and disjoint in rows and chunks
        // are contiguous disjoint panel ranges, so no two workers touch
        // the same `y` rows; the borrow outlives the blocked `run`
        // call.
        let part = unsafe { y_all.subslice_mut(row_begin * k, row_end * k) };
        if k == 1 {
            tm.spmv_panels(p0, p1, x, part, test);
        } else {
            let sums = ctx.locals.get_or_insert_with(attach, Vec::<T>::new);
            tm.spmm_panels(p0, p1, x, part, k, sums);
        }
    });
}

/// Parallel tiled-hybrid pass: workers own disjoint contiguous runs of
/// tiled segments (the same nnz-balanced split as the flat hybrid
/// path); within a segment the `(panel, tile)` walk is sequential for
/// locality.
fn tiled_hybrid_pooled<T: Scalar>(
    th: &TiledHybrid<T>,
    exec: PoolExec<'_>,
    x: &[T],
    y: &mut [T],
    k: usize,
) {
    debug_assert_eq!(exec.chunks.len(), exec.pool.n_threads());
    let y_all = SendSlice::new(y);
    let attach = exec.scratch_attach;
    exec.pool.run(|ctx: WorkerCtx<'_>| {
        let (s0, s1) = exec.chunks[ctx.tid];
        let sums = ctx.locals.get_or_insert_with(attach, Vec::<T>::new);
        for seg in &th.segments[s0..s1] {
            // SAFETY: segments are ordered and disjoint in rows and
            // chunks are contiguous disjoint segment ranges; the borrow
            // outlives the blocked `run` call.
            let part = unsafe {
                y_all.subslice_mut(seg.row_begin * k, seg.row_end * k)
            };
            if k == 1 {
                seg.spmv(x, part);
            } else {
                seg.spmm(x, part, k, sums);
            }
        }
    });
}

/// The baselines' multi-RHS fallback: no native SpMM kernel, so run
/// `k` de-interleaved single-vector products through storage-owned
/// scratch (allocating two vectors per batch here used to be the
/// serving layer's hot-path allocation).
fn baseline_spmm<T: Scalar>(
    scratch: &Mutex<(Vec<T>, Vec<T>)>,
    rows: usize,
    cols: usize,
    x: &[T],
    y: &mut [T],
    k: usize,
    mut spmv: impl FnMut(&[T], &mut [T]),
) {
    let mut guard = scratch.lock().unwrap_or_else(|e| e.into_inner());
    let (xj, yj) = &mut *guard;
    xj.clear();
    xj.resize(cols, T::ZERO);
    yj.clear();
    yj.resize(rows, T::ZERO);
    for j in 0..k {
        for c in 0..cols {
            xj[c] = x[c * k + j];
        }
        yj.iter_mut().for_each(|v| *v = T::ZERO);
        spmv(xj, yj);
        for r in 0..rows {
            y[r * k + j] += yj[r];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::suite;

    #[test]
    fn csr_par_split_covers_disjointly() {
        let csr = Arc::new(suite::circuit(3_000, 3, 4, 11));
        let st = CsrStorage::new(csr.clone());
        for n in [1usize, 2, 5, 16] {
            let chunks = SparseStorage::<f64>::par_split(&st, n);
            assert_eq!(chunks.len(), n);
            assert_eq!(chunks[0].0, 0);
            assert_eq!(chunks.last().unwrap().1, csr.rows);
            for w in chunks.windows(2) {
                assert_eq!(w[0].1, w[1].0);
            }
        }
    }

    #[test]
    fn kernel_kinds_reported() {
        let csr = suite::poisson2d(12);
        let bm = crate::formats::csr_to_block(
            &csr,
            crate::formats::BlockSize::new(2, 4),
        )
        .unwrap();
        assert_eq!(
            SparseStorage::<f64>::kernel_kind(&bm),
            KernelKind::Beta(2, 4)
        );
        let test = BetaTestStorage::Flat(bm);
        assert_eq!(test.kernel_kind(), KernelKind::BetaTest(2, 4));
        let st = CsrStorage::new(Arc::new(csr.clone()));
        assert_eq!(st.kernel_kind(), KernelKind::Csr);
        st.validate().unwrap();
        let hm = HybridMatrix::from_csr(
            &csr,
            &crate::formats::HybridConfig::for_scalar::<f64>(),
            None,
        )
        .unwrap();
        assert_eq!(
            SparseStorage::<f64>::kernel_kind(&hm),
            KernelKind::Hybrid
        );
        assert_eq!(SparseStorage::<f64>::tile_cols(&hm), None);
    }
}
