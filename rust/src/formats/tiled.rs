//! Column-tiled (cache-blocked) execution schedules over the β and
//! hybrid storages.
//!
//! The β(r,c) kernels stream their matrix arrays perfectly, but the
//! `x`-vector loads are indexed by block column: once `x` outgrows the
//! last-level cache, every `vexpandpd`/`vexpandps` window load is a
//! potential memory-latency stall — the regime where wide-SIMD sparse
//! formats lose to plain CSR (Kreutzer et al.'s SELL-C-σ analysis),
//! best attacked with explicit cache blocking (Chen et al. on KNL/KNM).
//!
//! [`TiledMatrix`] reorders an existing [`BlockMatrix`] into
//! `(row-panel, column-tile)` groups: the rows are cut into fixed
//! panels (like the hybrid schedule), and inside each panel the blocks
//! are bucketed by the column tile containing their anchor column.
//! Execution walks panels outermost and tiles innermost, so
//!
//! - each tile pass touches only a `tile_cols`-sized window of `x`
//!   (sized to an L2 share by [`TileCols::Auto`], or fixed by the
//!   caller), which stays cache-resident across the whole pass, and
//! - `y` rows of the current panel stay hot across all of its tiles
//!   (the interval accumulators flush into the same panel-local rows
//!   once per tile).
//!
//! Each `(panel, tile)` group is stored as a self-contained **span** —
//! the same [`crate::kernels::avx512::Span`] the parallel runtime
//! already feeds to the kernels — with its header `colidx` rewritten
//! relative to the tile's first column. Running a span through the
//! existing masked kernels then only needs the `x` slice to start at
//! the tile base ([`crate::kernels::avx512::spmv_span_at`] /
//! [`crate::kernels::spmm::spmm_span_at`]): no kernel body changes at
//! all, for SpMV and the multi-RHS SpMM alike.
//!
//! [`TiledCsr`] applies the same `(panel, tile)` bucketing to a CSR
//! storage (tile-relative `colidx`, per-span row prefixes), and
//! [`TiledHybrid`] lifts a compiled [`HybridMatrix`] schedule into the
//! tiled world segment by segment — β segments become [`TiledMatrix`]
//! storages, CSR segments become [`TiledCsr`] — so the *whole* kernel
//! stack is cache-blocked, not just the homogeneous β path.
//!
//! Every container has a `validate()` proving the tiling is a
//! permutation of the source storage: spans are ordered and
//! non-overlapping, their arrays partition the backing storage exactly,
//! and the per-interval (per-row) block/entry counts match the counts
//! recorded from the source at conversion time — i.e. every block
//! lands in exactly one span.

use super::{
    csr_to_block, BlockMatrix, BlockSize, FormatError, HybridMatrix,
    PanelKernel, SegmentStorage,
};
use crate::kernels::avx512::{Span, TuneParams};
use crate::matrix::Csr;
use crate::scalar::{MaskWord, Scalar};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Once;

/// Default panel height for tiled schedules (same as the hybrid
/// default: a multiple of 8, so every kernel interval height divides
/// panel boundaries).
pub use super::hybrid::DEFAULT_PANEL_ROWS;

/// Smallest tile width the auto-sizer will pick: below this the
/// per-span dispatch overhead dominates any locality win.
pub const MIN_TILE_COLS: usize = 1024;

/// Auto-sized tile widths are rounded down to a multiple of this
/// (a cache line of f64).
const TILE_ALIGN: usize = 64;

/// L2 share assumed when the cache hierarchy cannot be detected.
const DEFAULT_L2_BYTES: usize = 1 << 20;

static L2_ONCE: Once = Once::new();
static L2_BYTES: AtomicUsize = AtomicUsize::new(0);

/// Detected per-core L2 size in bytes, resolved once per process:
/// the `SPC5_L2_BYTES` environment variable when set, else the Linux
/// sysfs cache hierarchy (`cpu0/cache/index2/size`), else a 1 MiB
/// fallback.
pub fn l2_cache_bytes() -> usize {
    L2_ONCE.call_once(|| {
        let bytes = std::env::var("SPC5_L2_BYTES")
            .ok()
            .and_then(|s| s.trim().parse::<usize>().ok())
            .filter(|&b| b > 0)
            .or_else(read_sysfs_l2)
            .unwrap_or(DEFAULT_L2_BYTES);
        L2_BYTES.store(bytes, Ordering::Relaxed);
    });
    L2_BYTES.load(Ordering::Relaxed)
}

fn read_sysfs_l2() -> Option<usize> {
    let text = std::fs::read_to_string(
        "/sys/devices/system/cpu/cpu0/cache/index2/size",
    )
    .ok()?;
    parse_cache_size(text.trim())
}

/// Parses the sysfs cache-size spelling (`"1024K"`, `"2M"`, plain
/// bytes).
fn parse_cache_size(s: &str) -> Option<usize> {
    if s.is_empty() {
        return None;
    }
    let (num, mult) = match s.as_bytes()[s.len() - 1] {
        b'K' | b'k' => (&s[..s.len() - 1], 1024usize),
        b'M' | b'm' => (&s[..s.len() - 1], 1024 * 1024),
        b'G' | b'g' => (&s[..s.len() - 1], 1024 * 1024 * 1024),
        _ => (s, 1),
    };
    num.trim()
        .parse::<usize>()
        .ok()
        .and_then(|n| n.checked_mul(mult))
        .filter(|&b| b > 0)
}

/// The tile width an `x` window of scalar `T` should use so half the
/// detected L2 holds it (the other half is left to the streamed
/// header/value arrays and the panel's `y` rows), clamped to
/// `[MIN_TILE_COLS, cols]` and cache-line aligned.
pub fn auto_tile_cols<T: Scalar>(cols: usize) -> usize {
    let budget = l2_cache_bytes() / 2;
    let mut tile = (budget / T::BYTES).max(MIN_TILE_COLS);
    tile -= tile % TILE_ALIGN;
    tile.min(cols.max(1)).max(1)
}

/// How wide the column tiles are.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TileCols {
    /// Size the tile to an L2 share detected at runtime
    /// ([`auto_tile_cols`]).
    Auto,
    /// Fixed width in columns (manual override).
    Fixed(usize),
}

impl TileCols {
    /// The concrete tile width for a matrix with `cols` columns at
    /// scalar `T`.
    pub fn resolve<T: Scalar>(self, cols: usize) -> usize {
        match self {
            TileCols::Auto => auto_tile_cols::<T>(cols),
            TileCols::Fixed(n) => n.max(1),
        }
    }
}

/// Configuration of a tiled conversion.
#[derive(Clone, Debug)]
pub struct TiledConfig {
    /// Rows per panel (positive multiple of 8, like the hybrid
    /// schedule).
    pub panel_rows: usize,
    /// Column tile width.
    pub tile_cols: TileCols,
}

impl Default for TiledConfig {
    fn default() -> Self {
        TiledConfig {
            panel_rows: DEFAULT_PANEL_ROWS,
            tile_cols: TileCols::Auto,
        }
    }
}

fn validate_panel_rows(panel_rows: usize) -> Result<(), FormatError> {
    if panel_rows == 0 || panel_rows % 8 != 0 {
        return Err(FormatError::Inconsistent(format!(
            "panel_rows must be a positive multiple of 8, got {panel_rows}"
        )));
    }
    Ok(())
}

/// One row panel of a tiled schedule: a contiguous row range plus the
/// range of spans (column tiles) that cover its nonzeros.
#[derive(Clone, Copy, Debug)]
pub struct TilePanel {
    /// First matrix row (inclusive); a multiple of `panel_rows`.
    pub row_begin: usize,
    /// One past the last matrix row of the panel.
    pub row_end: usize,
    /// Nonzeros in the panel (the parallel split weight).
    pub nnz: usize,
    /// Range `[span_begin, span_end)` into the container's span list.
    pub span_begin: usize,
    pub span_end: usize,
}

/// One `(panel, tile)` group of a [`TiledMatrix`]: a self-contained
/// kernel span whose header `colidx` are relative to `col_begin`.
///
/// The span's interval prefix covers only the **occupied window**
/// `[it_begin, it_begin + n_its)` of the panel's intervals (first to
/// last interval owning a block in this tile), not the whole panel —
/// on structured matrices a tile is touched by a narrow row band, and
/// a dense whole-panel prefix per span would make the metadata rival
/// the matrix data. (On uniformly scattered matrices the window stays
/// wide; very small manual tile widths there still pay a metadata
/// cost ∝ spans × window — prefer auto sizing, whose ≥1024-column
/// floor keeps the span count low.)
#[derive(Clone, Copy, Debug)]
pub struct TileSpan {
    /// Column tile index.
    pub tile: usize,
    /// First column of the tile (`tile * tile_cols`); the `x` window
    /// the span's kernel call starts at.
    pub col_begin: usize,
    /// First panel-local interval of the occupied window.
    pub it_begin: usize,
    /// Intervals in the occupied window (≥ 1; first and last are
    /// non-empty).
    pub n_its: usize,
    /// Blocks in the span.
    pub n_blocks: usize,
    /// Stored nonzeros in the span.
    pub nnz: usize,
    /// Start of the span's `n_its + 1` local block prefix inside the
    /// container's `rowptr` array.
    pub rowptr_begin: usize,
    /// Byte offset of the span's interleaved headers.
    pub header_begin: usize,
    /// Offset of the span's values.
    pub val_begin: usize,
}

/// A `β(r,c)` matrix reordered into `(row-panel, column-tile)` spans —
/// the cache-blocked execution layout (see the module docs).
pub struct TiledMatrix<T: Scalar = f64> {
    pub rows: usize,
    pub cols: usize,
    pub bs: BlockSize,
    /// Effective panel height: the requested height rounded down to a
    /// multiple of the interval height `r`, so panel boundaries always
    /// sit on interval boundaries (identical to the request for the
    /// kernel sizes, where `r | 8 | panel_rows`).
    pub panel_rows: usize,
    /// Concrete column tile width.
    pub tile_cols: usize,
    /// Number of column tiles (`ceil(cols / tile_cols)`).
    pub n_tiles: usize,
    /// Panels in row order, covering `0..rows` contiguously.
    pub panels: Vec<TilePanel>,
    /// Spans grouped by panel, tiles ascending within a panel; empty
    /// `(panel, tile)` combinations are omitted.
    pub spans: Vec<TileSpan>,
    /// Concatenated per-span local block prefixes (`span.n_its + 1`
    /// entries each, starting at 0 — only the span's occupied
    /// interval window, see [`TileSpan`]).
    pub rowptr: Vec<u32>,
    /// Concatenated per-span interleaved headers
    /// (`colidx:4B | masks:r·mask_bytes`, colidx **tile-relative**).
    pub headers: Vec<u8>,
    /// Values reordered into span order (still unpadded).
    pub values: Vec<T>,
    /// Per-interval block counts of the *source* conversion, kept so
    /// [`TiledMatrix::validate`] can prove every source block landed in
    /// exactly one span.
    pub source_blocks_per_interval: Vec<u32>,
    /// Kernel variant the span kernels run (inherited from the source
    /// block matrix; resolved once, dispatched per span).
    pub tune: TuneParams,
}

impl<T: Scalar> TiledMatrix<T> {
    /// Converts CSR → β(r,c) → tiled layout in one call.
    pub fn from_csr(
        csr: &Csr<T>,
        bs: BlockSize,
        cfg: &TiledConfig,
    ) -> Result<TiledMatrix<T>, FormatError> {
        let bm = csr_to_block(csr, bs)?;
        let tile_cols = cfg.tile_cols.resolve::<T>(csr.cols);
        TiledMatrix::from_block(&bm, cfg.panel_rows, tile_cols)
    }

    /// Reorders an existing block matrix into the tiled layout.
    pub fn from_block(
        bm: &BlockMatrix<T>,
        panel_rows: usize,
        tile_cols: usize,
    ) -> Result<TiledMatrix<T>, FormatError> {
        validate_panel_rows(panel_rows)?;
        if tile_cols == 0 {
            return Err(FormatError::Inconsistent(
                "tile_cols must be positive".into(),
            ));
        }
        let r = bm.bs.r;
        // Effective panel height: the largest multiple of the interval
        // height not exceeding the requested panel_rows, so panel
        // boundaries always align with interval boundaries. For the
        // kernel sizes (r ∈ {1,2,4,8}) this equals the request; the
        // generic sizes (e.g. β(3,5)) round down (64 → 63).
        let ipp = (panel_rows / r).max(1); // intervals per panel
        let panel_rows = ipp * r;
        let n_intervals = bm.intervals();
        let n_panels = crate::util::ceil_div(bm.rows, panel_rows);
        let n_tiles = crate::util::ceil_div(bm.cols.max(1), tile_cols);
        let stride = bm.header_stride();

        // Per-block value offsets (prefix of block popcounts), so each
        // span can gather its values from the source block order.
        let mut val_off = Vec::with_capacity(bm.n_blocks() + 1);
        val_off.push(0usize);
        let mut acc = 0usize;
        for b in 0..bm.n_blocks() {
            let mut pop = 0u32;
            for i in 0..r {
                pop += bm.block_masks[b * r + i].count_ones();
            }
            acc += pop as usize;
            val_off.push(acc);
        }

        let source_blocks_per_interval: Vec<u32> = (0..n_intervals)
            .map(|it| bm.block_rowptr[it + 1] - bm.block_rowptr[it])
            .collect();

        let mut panels = Vec::with_capacity(n_panels);
        let mut spans: Vec<TileSpan> = Vec::new();
        let mut rowptr: Vec<u32> = Vec::new();
        let mut headers: Vec<u8> = Vec::with_capacity(bm.headers.len());
        let mut values: Vec<T> = Vec::with_capacity(bm.values.len());
        // Scratch: one panel's blocks as (tile, local interval, block).
        let mut bucket: Vec<(u32, u32, u32)> = Vec::new();

        for p in 0..n_panels {
            let it0 = p * ipp;
            let it1 = ((p + 1) * ipp).min(n_intervals);
            let row_begin = p * panel_rows;
            let row_end = (row_begin + panel_rows).min(bm.rows);

            bucket.clear();
            for it in it0..it1 {
                let (a, b) = (
                    bm.block_rowptr[it] as usize,
                    bm.block_rowptr[it + 1] as usize,
                );
                for blk in a..b {
                    let tile = bm.block_colidx[blk] as usize / tile_cols;
                    bucket.push((tile as u32, (it - it0) as u32, blk as u32));
                }
            }
            // Stable sort: within a tile the (interval, column) order of
            // the source conversion is preserved.
            bucket.sort_by_key(|&(tile, _, _)| tile);

            let span_begin = spans.len();
            let mut panel_nnz = 0usize;
            let mut i = 0usize;
            while i < bucket.len() {
                let tile = bucket[i].0 as usize;
                let mut j = i;
                while j < bucket.len() && bucket[j].0 as usize == tile {
                    j += 1;
                }
                let col_begin = tile * tile_cols;
                let rowptr_begin = rowptr.len();
                let header_begin = headers.len();
                let val_begin = values.len();

                // Occupied interval window of this tile: entries within
                // a tile group keep the (interval, column) push order,
                // so the first/last entries bound it.
                let it_b = bucket[i].1 as usize;
                let it_e = bucket[j - 1].1 as usize + 1;
                let n_its_span = it_e - it_b;

                // Local block prefix over the window's intervals.
                let rp_base = rowptr.len();
                rowptr.resize(rp_base + n_its_span + 1, 0);
                for &(_, itl, _) in &bucket[i..j] {
                    rowptr[rp_base + (itl as usize - it_b) + 1] += 1;
                }
                for m in 0..n_its_span {
                    rowptr[rp_base + m + 1] += rowptr[rp_base + m];
                }

                // Headers (colidx rewritten tile-relative) and values.
                for &(_, _, blk) in &bucket[i..j] {
                    let blk = blk as usize;
                    let h = &bm.headers[blk * stride..(blk + 1) * stride];
                    let rel = bm.block_colidx[blk] as usize - col_begin;
                    headers.extend_from_slice(&(rel as u32).to_le_bytes());
                    headers.extend_from_slice(&h[4..]);
                    values.extend_from_slice(
                        &bm.values[val_off[blk]..val_off[blk + 1]],
                    );
                }

                let nnz = values.len() - val_begin;
                panel_nnz += nnz;
                spans.push(TileSpan {
                    tile,
                    col_begin,
                    it_begin: it_b,
                    n_its: n_its_span,
                    n_blocks: j - i,
                    nnz,
                    rowptr_begin,
                    header_begin,
                    val_begin,
                });
                i = j;
            }

            panels.push(TilePanel {
                row_begin,
                row_end,
                nnz: panel_nnz,
                span_begin,
                span_end: spans.len(),
            });
        }

        let tm = TiledMatrix {
            rows: bm.rows,
            cols: bm.cols,
            bs: bm.bs,
            panel_rows,
            tile_cols,
            n_tiles,
            panels,
            spans,
            rowptr,
            headers,
            values,
            source_blocks_per_interval,
            tune: bm.tune,
        };
        debug_assert!(tm.validate().is_ok(), "{:?}", tm.validate().err());
        Ok(tm)
    }

    /// Bytes per interleaved header entry.
    #[inline]
    pub fn header_stride(&self) -> usize {
        4 + <T::Mask as MaskWord>::BYTES * self.bs.r
    }

    /// Stored nonzeros.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Number of `(panel, tile)` spans.
    #[inline]
    pub fn n_spans(&self) -> usize {
        self.spans.len()
    }

    /// Number of row panels.
    #[inline]
    pub fn n_panels(&self) -> usize {
        self.panels.len()
    }

    /// The kernel [`Span`] of one `(panel, tile)` group, covering only
    /// the span's occupied interval window; `y` handed to it must
    /// start at panel-local row `s.it_begin * r`.
    fn span(&self, panel: &TilePanel, s: &TileSpan) -> Span<'_, T> {
        let stride = self.header_stride();
        let r = self.bs.r;
        let panel_len = panel.row_end - panel.row_begin;
        // Window rows, clamping the last interval at the matrix tail.
        let rows = ((s.it_begin + s.n_its) * r).min(panel_len) - s.it_begin * r;
        Span {
            rowptr: &self.rowptr
                [s.rowptr_begin..s.rowptr_begin + s.n_its + 1],
            headers: &self.headers
                [s.header_begin..s.header_begin + s.n_blocks * stride],
            values: &self.values[s.val_begin..s.val_begin + s.nnz],
            rows,
            r,
        }
    }

    /// Sequential `y += A·x`: panels outermost, tiles innermost, each
    /// tile pass re-reading only its `x` window. `test` selects the
    /// Algorithm-2 kernel variants where they exist.
    pub fn spmv(&self, x: &[T], y: &mut [T], test: bool) {
        assert_eq!(x.len(), self.cols, "x length mismatch");
        assert_eq!(y.len(), self.rows, "y length mismatch");
        self.spmv_panels(0, self.panels.len(), x, y, test);
    }

    /// Runs panels `[p0, p1)`; `y` is local to the range (`y[0]` is
    /// matrix row `panels[p0].row_begin`) — the worker-pool entry
    /// point, workers owning disjoint panel ranges.
    pub fn spmv_panels(
        &self,
        p0: usize,
        p1: usize,
        x: &[T],
        y: &mut [T],
        test: bool,
    ) {
        let base = match self.panels.get(p0) {
            Some(p) => p.row_begin,
            None => return,
        };
        for panel in &self.panels[p0..p1] {
            let y0 = panel.row_begin - base;
            for s in &self.spans[panel.span_begin..panel.span_end] {
                let span = self.span(panel, s);
                let w0 = y0 + s.it_begin * self.bs.r;
                let yp = &mut y[w0..w0 + span.rows];
                if !crate::kernels::avx512::spmv_span_at_tuned(
                    span,
                    self.bs,
                    s.col_begin,
                    x,
                    yp,
                    test,
                    self.tune,
                ) {
                    crate::kernels::scalar::spmv_generic_span(
                        span,
                        self.bs,
                        &x[s.col_begin..],
                        yp,
                    );
                }
            }
        }
    }

    /// Sequential multi-RHS `Y += A·X` (row-major `[cols × k]` /
    /// `[rows × k]`, see [`crate::kernels::spmm`]).
    pub fn spmm(&self, x: &[T], y: &mut [T], k: usize) {
        assert!(k > 0);
        assert_eq!(x.len(), self.cols * k, "x must be cols*k");
        assert_eq!(y.len(), self.rows * k, "y must be rows*k");
        let mut sums = Vec::new();
        self.spmm_panels(0, self.panels.len(), x, y, k, &mut sums);
    }

    /// Multi-RHS form of [`TiledMatrix::spmv_panels`]; `sums` is the
    /// reusable accumulator scratch of the portable SpMM span kernel
    /// (per-worker in the pool).
    pub fn spmm_panels(
        &self,
        p0: usize,
        p1: usize,
        x: &[T],
        y: &mut [T],
        k: usize,
        sums: &mut Vec<T>,
    ) {
        let base = match self.panels.get(p0) {
            Some(p) => p.row_begin,
            None => return,
        };
        for panel in &self.panels[p0..p1] {
            let y0 = panel.row_begin - base;
            for s in &self.spans[panel.span_begin..panel.span_end] {
                let span = self.span(panel, s);
                let w0 = (y0 + s.it_begin * self.bs.r) * k;
                let yp = &mut y[w0..w0 + span.rows * k];
                crate::kernels::spmm::spmm_span_at_tuned(
                    span,
                    self.bs,
                    s.col_begin,
                    x,
                    yp,
                    k,
                    sums,
                    self.tune,
                );
            }
        }
    }

    /// Checks every structural invariant of the tiled layout and proves
    /// the tiling is exactly-once: spans partition the backing arrays,
    /// tiles are ordered and block columns stay inside the matrix, and
    /// the per-interval block counts across all spans equal the counts
    /// recorded from the source conversion.
    pub fn validate(&self) -> Result<(), FormatError> {
        let fail = |msg: String| Err(FormatError::Inconsistent(msg));
        self.bs.validate_for::<T>()?;
        let r = self.bs.r;
        // `panel_rows` is the *effective* (interval-aligned) height
        // computed at conversion, so it is a positive multiple of r —
        // not necessarily of 8 for the generic block sizes.
        if self.panel_rows == 0 || self.panel_rows % r != 0 {
            return fail(format!(
                "panel_rows {} not a positive multiple of r={r}",
                self.panel_rows
            ));
        }
        if self.tile_cols == 0 {
            return fail("tile_cols must be positive".into());
        }
        if self.n_tiles
            != crate::util::ceil_div(self.cols.max(1), self.tile_cols)
        {
            return fail("n_tiles inconsistent with cols".into());
        }
        let mb = <T::Mask as MaskWord>::BYTES;
        let stride = self.header_stride();
        let ipp = self.panel_rows / r;
        let n_intervals = crate::util::ceil_div(self.rows, r);
        if self.source_blocks_per_interval.len() != n_intervals {
            return fail("source interval counts length mismatch".into());
        }
        let n_panels = crate::util::ceil_div(self.rows, self.panel_rows);
        if self.panels.len() != n_panels {
            return fail(format!(
                "panel count {} != {n_panels}",
                self.panels.len()
            ));
        }

        let mut per_interval = vec![0u32; n_intervals];
        let mut expect_row = 0usize;
        let mut expect_span = 0usize;
        let mut expect_rowptr = 0usize;
        let mut expect_header = 0usize;
        let mut expect_val = 0usize;

        for (p_idx, panel) in self.panels.iter().enumerate() {
            if panel.row_begin != expect_row
                || panel.row_begin != p_idx * self.panel_rows
            {
                return fail(format!("panel {p_idx} row_begin wrong"));
            }
            if panel.row_end <= panel.row_begin
                || panel.row_end > self.rows
            {
                return fail(format!("panel {p_idx} bad row range"));
            }
            if p_idx + 1 < n_panels
                && panel.row_end - panel.row_begin != self.panel_rows
            {
                return fail(format!("panel {p_idx} not full height"));
            }
            if panel.span_begin != expect_span
                || panel.span_end < panel.span_begin
                || panel.span_end > self.spans.len()
            {
                return fail(format!("panel {p_idx} span range wrong"));
            }
            let panel_its =
                crate::util::ceil_div(panel.row_end - panel.row_begin, r);
            let it0 = p_idx * ipp;
            let mut prev_tile: Option<usize> = None;
            let mut panel_nnz = 0usize;

            for (s_idx, s) in self.spans
                [panel.span_begin..panel.span_end]
                .iter()
                .enumerate()
            {
                if let Some(pt) = prev_tile {
                    if s.tile <= pt {
                        return fail(format!(
                            "panel {p_idx} span {s_idx}: tiles out of order"
                        ));
                    }
                }
                prev_tile = Some(s.tile);
                if s.tile >= self.n_tiles
                    || s.col_begin != s.tile * self.tile_cols
                {
                    return fail(format!(
                        "panel {p_idx} span {s_idx}: bad tile"
                    ));
                }
                if s.n_its == 0 || s.it_begin + s.n_its > panel_its {
                    return fail(format!(
                        "panel {p_idx} span {s_idx}: interval window out \
                         of the panel"
                    ));
                }
                if s.rowptr_begin != expect_rowptr
                    || s.header_begin != expect_header
                    || s.val_begin != expect_val
                {
                    return fail(format!(
                        "panel {p_idx} span {s_idx}: arrays not contiguous"
                    ));
                }
                expect_rowptr += s.n_its + 1;
                expect_header += s.n_blocks * stride;
                expect_val += s.nnz;
                if expect_rowptr > self.rowptr.len()
                    || expect_header > self.headers.len()
                    || expect_val > self.values.len()
                {
                    return fail(format!(
                        "panel {p_idx} span {s_idx}: arrays overflow"
                    ));
                }
                let rp = &self.rowptr
                    [s.rowptr_begin..s.rowptr_begin + s.n_its + 1];
                if rp[0] != 0 || rp[s.n_its] as usize != s.n_blocks {
                    return fail(format!(
                        "panel {p_idx} span {s_idx}: rowptr does not span \
                         the blocks"
                    ));
                }
                // The window must be tight: its first and last
                // intervals hold at least one block each.
                if rp[1] == 0 || rp[s.n_its] == rp[s.n_its - 1] {
                    return fail(format!(
                        "panel {p_idx} span {s_idx}: interval window not \
                         tight"
                    ));
                }
                let mut pop_total = 0usize;
                let mut hp = s.header_begin;
                for m in 0..s.n_its {
                    if rp[m + 1] < rp[m] {
                        return fail(format!(
                            "panel {p_idx} span {s_idx}: rowptr not monotone"
                        ));
                    }
                    let nb = (rp[m + 1] - rp[m]) as usize;
                    per_interval[it0 + s.it_begin + m] += nb as u32;
                    let mut prev_end: i64 = -1;
                    for _ in 0..nb {
                        let h = &self.headers[hp..hp + stride];
                        let rel =
                            u32::from_le_bytes([h[0], h[1], h[2], h[3]])
                                as usize;
                        if rel >= self.tile_cols {
                            return fail(format!(
                                "panel {p_idx} span {s_idx}: block anchored \
                                 outside its tile"
                            ));
                        }
                        if (rel as i64) <= prev_end {
                            return fail(format!(
                                "panel {p_idx} span {s_idx}: blocks overlap \
                                 or out of order"
                            ));
                        }
                        if s.col_begin + rel + 1 > self.cols {
                            return fail(format!(
                                "panel {p_idx} span {s_idx}: block col out \
                                 of range"
                            ));
                        }
                        prev_end = rel as i64 + self.bs.c as i64 - 1;
                        let mut bpop = 0u32;
                        for i in 0..r {
                            let m_ = <T::Mask as MaskWord>::read_le(
                                &h[4 + mb * i..],
                            );
                            if m_.any_above(self.bs.c) {
                                return fail(format!(
                                    "panel {p_idx} span {s_idx}: mask bits \
                                     beyond c"
                                ));
                            }
                            bpop += m_.count_ones();
                        }
                        if bpop == 0 {
                            return fail(format!(
                                "panel {p_idx} span {s_idx}: empty block"
                            ));
                        }
                        pop_total += bpop as usize;
                        hp += stride;
                    }
                }
                if pop_total != s.nnz {
                    return fail(format!(
                        "panel {p_idx} span {s_idx}: popcount sum != nnz"
                    ));
                }
                panel_nnz += s.nnz;
            }
            if panel_nnz != panel.nnz {
                return fail(format!("panel {p_idx} nnz mismatch"));
            }
            expect_span = panel.span_end;
            expect_row = panel.row_end;
        }
        if expect_row != self.rows {
            return fail(format!(
                "panels cover rows 0..{expect_row}, matrix has {}",
                self.rows
            ));
        }
        if expect_span != self.spans.len()
            || expect_rowptr != self.rowptr.len()
            || expect_header != self.headers.len()
            || expect_val != self.values.len()
        {
            return fail("spans do not partition the arrays".into());
        }
        if per_interval[..] != self.source_blocks_per_interval[..] {
            return fail(
                "blocks not covered exactly once (per-interval counts \
                 diverge from the source conversion)"
                    .into(),
            );
        }
        Ok(())
    }
}

/// One `(panel, tile)` group of a [`TiledCsr`]: `colidx` are
/// tile-relative; the entry prefix covers only the span's occupied
/// row window (first to last panel row with an entry in this tile),
/// like [`TileSpan`]'s interval window.
#[derive(Clone, Copy, Debug)]
pub struct CsrTileSpan {
    pub tile: usize,
    pub col_begin: usize,
    /// First panel-local row of the occupied window.
    pub lr_begin: usize,
    /// Rows in the occupied window (≥ 1; first and last are
    /// non-empty).
    pub n_rows: usize,
    pub nnz: usize,
    /// Start of the span's `n_rows + 1` local entry prefix inside the
    /// container's `rowptr`.
    pub rowptr_begin: usize,
    /// Offset of the span's entries in `colidx`/`values`.
    pub idx_begin: usize,
}

/// A CSR storage reordered into `(row-panel, column-tile)` spans — the
/// cache-blocked companion of [`TiledMatrix`] used for the CSR
/// segments of a tiled hybrid schedule.
pub struct TiledCsr<T: Scalar = f64> {
    pub rows: usize,
    pub cols: usize,
    pub panel_rows: usize,
    pub tile_cols: usize,
    pub n_tiles: usize,
    pub panels: Vec<TilePanel>,
    pub spans: Vec<CsrTileSpan>,
    /// Concatenated per-span local entry prefixes (`span.n_rows + 1`
    /// entries each, starting at 0 — only the span's occupied row
    /// window).
    pub rowptr: Vec<u32>,
    /// Tile-relative column indices, span order.
    pub colidx: Vec<u32>,
    pub values: Vec<T>,
    /// Source per-row entry counts, for the exactly-once proof.
    pub source_nnz_per_row: Vec<u32>,
}

impl<T: Scalar> TiledCsr<T> {
    /// Buckets a CSR matrix into `(panel, tile)` spans.
    pub fn from_csr(
        csr: &Csr<T>,
        panel_rows: usize,
        tile_cols: usize,
    ) -> Result<TiledCsr<T>, FormatError> {
        validate_panel_rows(panel_rows)?;
        if tile_cols == 0 {
            return Err(FormatError::Inconsistent(
                "tile_cols must be positive".into(),
            ));
        }
        let n_panels = crate::util::ceil_div(csr.rows, panel_rows);
        let n_tiles = crate::util::ceil_div(csr.cols.max(1), tile_cols);
        let source_nnz_per_row: Vec<u32> = (0..csr.rows)
            .map(|row| csr.rowptr[row + 1] - csr.rowptr[row])
            .collect();

        let mut panels = Vec::with_capacity(n_panels);
        let mut spans: Vec<CsrTileSpan> = Vec::new();
        let mut rowptr: Vec<u32> = Vec::new();
        let mut colidx: Vec<u32> = Vec::with_capacity(csr.nnz());
        let mut values: Vec<T> = Vec::with_capacity(csr.nnz());
        // Scratch: one panel's entries as (tile, local row, entry).
        let mut bucket: Vec<(u32, u32, u32)> = Vec::new();

        for p in 0..n_panels {
            let row_begin = p * panel_rows;
            let row_end = (row_begin + panel_rows).min(csr.rows);

            bucket.clear();
            for row in row_begin..row_end {
                for idx in csr.row_range(row) {
                    let tile = csr.colidx[idx] as usize / tile_cols;
                    bucket.push((
                        tile as u32,
                        (row - row_begin) as u32,
                        idx as u32,
                    ));
                }
            }
            bucket.sort_by_key(|&(tile, _, _)| tile);

            let span_begin = spans.len();
            let mut panel_nnz = 0usize;
            let mut i = 0usize;
            while i < bucket.len() {
                let tile = bucket[i].0 as usize;
                let mut j = i;
                while j < bucket.len() && bucket[j].0 as usize == tile {
                    j += 1;
                }
                let col_begin = tile * tile_cols;
                let rowptr_begin = rowptr.len();
                let idx_begin = values.len();

                // Occupied row window (entries within a tile keep the
                // row-then-column push order).
                let lr_b = bucket[i].1 as usize;
                let lr_e = bucket[j - 1].1 as usize + 1;
                let n_rows_span = lr_e - lr_b;

                let rp_base = rowptr.len();
                rowptr.resize(rp_base + n_rows_span + 1, 0);
                for &(_, lr, _) in &bucket[i..j] {
                    rowptr[rp_base + (lr as usize - lr_b) + 1] += 1;
                }
                for m in 0..n_rows_span {
                    rowptr[rp_base + m + 1] += rowptr[rp_base + m];
                }
                for &(_, _, idx) in &bucket[i..j] {
                    let idx = idx as usize;
                    colidx.push(csr.colidx[idx] - col_begin as u32);
                    values.push(csr.values[idx]);
                }

                let nnz = values.len() - idx_begin;
                panel_nnz += nnz;
                spans.push(CsrTileSpan {
                    tile,
                    col_begin,
                    lr_begin: lr_b,
                    n_rows: n_rows_span,
                    nnz,
                    rowptr_begin,
                    idx_begin,
                });
                i = j;
            }

            panels.push(TilePanel {
                row_begin,
                row_end,
                nnz: panel_nnz,
                span_begin,
                span_end: spans.len(),
            });
        }

        let tc = TiledCsr {
            rows: csr.rows,
            cols: csr.cols,
            panel_rows,
            tile_cols,
            n_tiles,
            panels,
            spans,
            rowptr,
            colidx,
            values,
            source_nnz_per_row,
        };
        debug_assert!(tc.validate().is_ok(), "{:?}", tc.validate().err());
        Ok(tc)
    }

    /// Stored nonzeros.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Number of `(panel, tile)` spans.
    #[inline]
    pub fn n_spans(&self) -> usize {
        self.spans.len()
    }

    /// Sequential `y += A·x`, panels outermost, tiles innermost.
    pub fn spmv(&self, x: &[T], y: &mut [T]) {
        assert_eq!(x.len(), self.cols, "x length mismatch");
        assert_eq!(y.len(), self.rows, "y length mismatch");
        self.spmv_panels(0, self.panels.len(), x, y);
    }

    /// Runs panels `[p0, p1)`; `y` local to the range.
    pub fn spmv_panels(&self, p0: usize, p1: usize, x: &[T], y: &mut [T]) {
        let base = match self.panels.get(p0) {
            Some(p) => p.row_begin,
            None => return,
        };
        for panel in &self.panels[p0..p1] {
            let y0 = panel.row_begin - base;
            for s in &self.spans[panel.span_begin..panel.span_end] {
                let xs = &x[s.col_begin..];
                let rp = &self.rowptr
                    [s.rowptr_begin..s.rowptr_begin + s.n_rows + 1];
                for lr in 0..s.n_rows {
                    let (a, b) = (rp[lr] as usize, rp[lr + 1] as usize);
                    if a == b {
                        continue;
                    }
                    let mut sum = T::ZERO;
                    for e in a..b {
                        let idx = s.idx_begin + e;
                        sum += self.values[idx]
                            * xs[self.colidx[idx] as usize];
                    }
                    y[y0 + s.lr_begin + lr] += sum;
                }
            }
        }
    }

    /// Sequential multi-RHS `Y += A·X`.
    pub fn spmm(&self, x: &[T], y: &mut [T], k: usize) {
        assert!(k > 0);
        assert_eq!(x.len(), self.cols * k, "x must be cols*k");
        assert_eq!(y.len(), self.rows * k, "y must be rows*k");
        self.spmm_panels(0, self.panels.len(), x, y, k);
    }

    /// Multi-RHS form of [`TiledCsr::spmv_panels`].
    pub fn spmm_panels(
        &self,
        p0: usize,
        p1: usize,
        x: &[T],
        y: &mut [T],
        k: usize,
    ) {
        let base = match self.panels.get(p0) {
            Some(p) => p.row_begin,
            None => return,
        };
        for panel in &self.panels[p0..p1] {
            let y0 = panel.row_begin - base;
            for s in &self.spans[panel.span_begin..panel.span_end] {
                let xs = &x[s.col_begin * k..];
                let rp = &self.rowptr
                    [s.rowptr_begin..s.rowptr_begin + s.n_rows + 1];
                for lr in 0..s.n_rows {
                    let (a, b) = (rp[lr] as usize, rp[lr + 1] as usize);
                    let row = y0 + s.lr_begin + lr;
                    let yrow = &mut y[row * k..(row + 1) * k];
                    for e in a..b {
                        let idx = s.idx_begin + e;
                        let v = self.values[idx];
                        let c = self.colidx[idx] as usize;
                        let xrow = &xs[c * k..(c + 1) * k];
                        for jj in 0..k {
                            yrow[jj] += v * xrow[jj];
                        }
                    }
                }
            }
        }
    }

    /// Structural invariants + exactly-once proof (per-row entry
    /// counts across spans equal the source CSR's).
    pub fn validate(&self) -> Result<(), FormatError> {
        let fail = |msg: String| Err(FormatError::Inconsistent(msg));
        validate_panel_rows(self.panel_rows)?;
        if self.tile_cols == 0 {
            return fail("tile_cols must be positive".into());
        }
        if self.source_nnz_per_row.len() != self.rows {
            return fail("source row counts length mismatch".into());
        }
        let n_panels = crate::util::ceil_div(self.rows, self.panel_rows);
        if self.panels.len() != n_panels {
            return fail("panel count mismatch".into());
        }
        if self.colidx.len() != self.values.len() {
            return fail("colidx/values length mismatch".into());
        }
        let mut per_row = vec![0u32; self.rows];
        let mut expect_row = 0usize;
        let mut expect_span = 0usize;
        let mut expect_rowptr = 0usize;
        let mut expect_idx = 0usize;
        for (p_idx, panel) in self.panels.iter().enumerate() {
            if panel.row_begin != expect_row
                || panel.row_begin != p_idx * self.panel_rows
                || panel.row_end <= panel.row_begin
                || panel.row_end > self.rows
            {
                return fail(format!("panel {p_idx} bad row range"));
            }
            if panel.span_begin != expect_span
                || panel.span_end < panel.span_begin
                || panel.span_end > self.spans.len()
            {
                return fail(format!("panel {p_idx} span range wrong"));
            }
            let panel_len = panel.row_end - panel.row_begin;
            let mut prev_tile: Option<usize> = None;
            let mut panel_nnz = 0usize;
            for s in &self.spans[panel.span_begin..panel.span_end] {
                if let Some(pt) = prev_tile {
                    if s.tile <= pt {
                        return fail(format!(
                            "panel {p_idx}: tiles out of order"
                        ));
                    }
                }
                prev_tile = Some(s.tile);
                if s.tile >= self.n_tiles
                    || s.col_begin != s.tile * self.tile_cols
                {
                    return fail(format!("panel {p_idx}: bad tile"));
                }
                if s.n_rows == 0 || s.lr_begin + s.n_rows > panel_len {
                    return fail(format!(
                        "panel {p_idx}: row window out of the panel"
                    ));
                }
                if s.rowptr_begin != expect_rowptr
                    || s.idx_begin != expect_idx
                {
                    return fail(format!(
                        "panel {p_idx}: arrays not contiguous"
                    ));
                }
                expect_rowptr += s.n_rows + 1;
                expect_idx += s.nnz;
                if expect_rowptr > self.rowptr.len()
                    || expect_idx > self.values.len()
                {
                    return fail(format!("panel {p_idx}: arrays overflow"));
                }
                let rp = &self.rowptr
                    [s.rowptr_begin..s.rowptr_begin + s.n_rows + 1];
                if rp[0] != 0 || rp[s.n_rows] as usize != s.nnz {
                    return fail(format!(
                        "panel {p_idx}: rowptr does not span the entries"
                    ));
                }
                if rp[1] == 0 || rp[s.n_rows] == rp[s.n_rows - 1] {
                    return fail(format!(
                        "panel {p_idx}: row window not tight"
                    ));
                }
                for lr in 0..s.n_rows {
                    if rp[lr + 1] < rp[lr] {
                        return fail(format!(
                            "panel {p_idx}: rowptr not monotone"
                        ));
                    }
                    let (a, b) = (rp[lr] as usize, rp[lr + 1] as usize);
                    per_row[panel.row_begin + s.lr_begin + lr] +=
                        (b - a) as u32;
                    let mut prev: i64 = -1;
                    for e in a..b {
                        let rel = self.colidx[s.idx_begin + e] as usize;
                        if rel >= self.tile_cols
                            || s.col_begin + rel >= self.cols
                        {
                            return fail(format!(
                                "panel {p_idx}: colidx out of range"
                            ));
                        }
                        if rel as i64 <= prev {
                            return fail(format!(
                                "panel {p_idx}: colidx out of order"
                            ));
                        }
                        prev = rel as i64;
                    }
                }
                panel_nnz += s.nnz;
            }
            if panel_nnz != panel.nnz {
                return fail(format!("panel {p_idx} nnz mismatch"));
            }
            expect_span = panel.span_end;
            expect_row = panel.row_end;
        }
        if expect_row != self.rows
            || expect_span != self.spans.len()
            || expect_rowptr != self.rowptr.len()
            || expect_idx != self.values.len()
        {
            return fail("spans do not partition the arrays".into());
        }
        if per_row[..] != self.source_nnz_per_row[..] {
            return fail(
                "entries not covered exactly once (per-row counts diverge \
                 from the source CSR)"
                    .into(),
            );
        }
        Ok(())
    }
}

/// A hybrid segment's tiled storage.
pub enum TiledSegmentStorage<T: Scalar> {
    /// β segment → tiled block spans.
    Block(TiledMatrix<T>),
    /// CSR segment → tiled CSR spans.
    Csr(TiledCsr<T>),
}

/// One segment of a tiled hybrid schedule (same row geometry as the
/// flat [`crate::formats::HybridSegment`]).
pub struct TiledHybridSegment<T: Scalar> {
    pub row_begin: usize,
    pub row_end: usize,
    pub nnz: usize,
    pub kernel: PanelKernel,
    pub storage: TiledSegmentStorage<T>,
}

impl<T: Scalar> TiledHybridSegment<T> {
    /// `y += A_seg·x`, `y` segment-local.
    #[inline]
    pub fn spmv(&self, x: &[T], y: &mut [T]) {
        match &self.storage {
            TiledSegmentStorage::Block(tm) => tm.spmv(x, y, false),
            TiledSegmentStorage::Csr(tc) => tc.spmv(x, y),
        }
    }

    /// Multi-RHS `Y += A_seg·X`, `y` segment-local; `sums` is the β
    /// span kernel's reusable accumulator scratch.
    #[inline]
    pub fn spmm(&self, x: &[T], y: &mut [T], k: usize, sums: &mut Vec<T>) {
        match &self.storage {
            TiledSegmentStorage::Block(tm) => {
                tm.spmm_panels(0, tm.panels.len(), x, y, k, sums)
            }
            TiledSegmentStorage::Csr(tc) => tc.spmm(x, y, k),
        }
    }
}

/// A compiled hybrid schedule lifted into the column-tiled world: the
/// per-panel β/CSR choices are untouched, but every segment's storage
/// is re-bucketed into `(row-panel, column-tile)` spans so the whole
/// heterogeneous schedule is cache-blocked.
pub struct TiledHybrid<T: Scalar = f64> {
    pub rows: usize,
    pub cols: usize,
    pub panel_rows: usize,
    pub tile_cols: usize,
    /// Per-panel decisions inherited from the flat schedule.
    pub choices: Vec<PanelKernel>,
    pub segments: Vec<TiledHybridSegment<T>>,
}

impl<T: Scalar> TiledHybrid<T> {
    /// Compiles CSR → hybrid schedule → tiled segments.
    pub fn from_csr(
        csr: &Csr<T>,
        cfg: &super::HybridConfig,
        models: Option<
            &std::collections::HashMap<
                crate::kernels::KernelKind,
                crate::predictor::PolyModel,
            >,
        >,
        tile_cols: TileCols,
    ) -> Result<TiledHybrid<T>, FormatError> {
        let hm = HybridMatrix::from_csr(csr, cfg, models)?;
        TiledHybrid::from_hybrid(&hm, tile_cols)
    }

    /// Tiles every segment of an existing hybrid schedule.
    pub fn from_hybrid(
        hm: &HybridMatrix<T>,
        tile_cols: TileCols,
    ) -> Result<TiledHybrid<T>, FormatError> {
        let tc = tile_cols.resolve::<T>(hm.cols);
        let mut segments = Vec::with_capacity(hm.segments.len());
        for seg in &hm.segments {
            let storage = match &seg.storage {
                SegmentStorage::Block(bm) => TiledSegmentStorage::Block(
                    TiledMatrix::from_block(bm, hm.panel_rows, tc)?,
                ),
                SegmentStorage::Csr(c) => TiledSegmentStorage::Csr(
                    TiledCsr::from_csr(c, hm.panel_rows, tc)?,
                ),
            };
            segments.push(TiledHybridSegment {
                row_begin: seg.row_begin,
                row_end: seg.row_end,
                nnz: seg.nnz,
                kernel: seg.kernel,
                storage,
            });
        }
        let th = TiledHybrid {
            rows: hm.rows,
            cols: hm.cols,
            panel_rows: hm.panel_rows,
            tile_cols: tc,
            choices: hm.choices.clone(),
            segments,
        };
        debug_assert!(th.validate().is_ok(), "{:?}", th.validate().err());
        Ok(th)
    }

    /// Total stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.segments.iter().map(|s| s.nnz).sum()
    }

    /// Number of segments.
    pub fn n_segments(&self) -> usize {
        self.segments.len()
    }

    /// Total `(panel, tile)` spans across all segments.
    pub fn n_spans(&self) -> usize {
        self.segments
            .iter()
            .map(|s| match &s.storage {
                TiledSegmentStorage::Block(tm) => tm.n_spans(),
                TiledSegmentStorage::Csr(tc) => tc.n_spans(),
            })
            .sum()
    }

    /// Distinct kernels in the schedule, row order, deduped runs.
    pub fn kernels_used(&self) -> Vec<PanelKernel> {
        let mut out: Vec<PanelKernel> = Vec::new();
        for s in &self.segments {
            if out.last() != Some(&s.kernel) {
                out.push(s.kernel);
            }
        }
        out
    }

    /// Sequential `y += A·x`.
    pub fn spmv(&self, x: &[T], y: &mut [T]) {
        assert_eq!(x.len(), self.cols, "x length mismatch");
        assert_eq!(y.len(), self.rows, "y length mismatch");
        for seg in &self.segments {
            seg.spmv(x, &mut y[seg.row_begin..seg.row_end]);
        }
    }

    /// Sequential multi-RHS `Y += A·X`.
    pub fn spmm(&self, x: &[T], y: &mut [T], k: usize) {
        assert!(k > 0);
        assert_eq!(x.len(), self.cols * k, "x must be cols*k");
        assert_eq!(y.len(), self.rows * k, "y must be rows*k");
        let mut sums = Vec::new();
        for seg in &self.segments {
            seg.spmm(
                x,
                &mut y[seg.row_begin * k..seg.row_end * k],
                k,
                &mut sums,
            );
        }
    }

    /// Segments contiguous over `0..rows`, per-segment storages valid
    /// (each proving its own exactly-once coverage), geometry and nnz
    /// consistent, one tile width everywhere.
    pub fn validate(&self) -> Result<(), FormatError> {
        let fail = |msg: String| Err(FormatError::Inconsistent(msg));
        let mut expect_row = 0usize;
        for (i, seg) in self.segments.iter().enumerate() {
            if seg.row_begin != expect_row {
                return fail(format!(
                    "segment {i} begins at {} (expected {expect_row})",
                    seg.row_begin
                ));
            }
            if seg.row_end <= seg.row_begin || seg.row_end > self.rows {
                return fail(format!("segment {i} has bad row range"));
            }
            let seg_rows = seg.row_end - seg.row_begin;
            match &seg.storage {
                TiledSegmentStorage::Block(tm) => {
                    if !matches!(seg.kernel, PanelKernel::Beta(bs) if bs == tm.bs)
                    {
                        return fail(format!(
                            "segment {i} kernel/storage mismatch"
                        ));
                    }
                    if tm.rows != seg_rows
                        || tm.cols != self.cols
                        || tm.nnz() != seg.nnz
                        || tm.tile_cols != self.tile_cols
                    {
                        return fail(format!("segment {i} geometry wrong"));
                    }
                    tm.validate()?;
                }
                TiledSegmentStorage::Csr(tc) => {
                    if seg.kernel != PanelKernel::Csr {
                        return fail(format!(
                            "segment {i} kernel/storage mismatch"
                        ));
                    }
                    if tc.rows != seg_rows
                        || tc.cols != self.cols
                        || tc.nnz() != seg.nnz
                        || tc.tile_cols != self.tile_cols
                    {
                        return fail(format!("segment {i} geometry wrong"));
                    }
                    tc.validate()?;
                }
            }
            expect_row = seg.row_end;
        }
        if expect_row != self.rows {
            return fail(format!(
                "segments cover rows 0..{expect_row}, matrix has {}",
                self.rows
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::suite;

    #[test]
    fn cache_size_spellings_parse() {
        assert_eq!(parse_cache_size("1024K"), Some(1024 * 1024));
        assert_eq!(parse_cache_size("2M"), Some(2 * 1024 * 1024));
        assert_eq!(parse_cache_size("32768"), Some(32768));
        assert_eq!(parse_cache_size("1g"), Some(1 << 30));
        assert_eq!(parse_cache_size(""), None);
        assert_eq!(parse_cache_size("xK"), None);
    }

    #[test]
    fn auto_tile_is_bounded_and_aligned() {
        let t = auto_tile_cols::<f64>(10_000_000);
        assert!(t >= MIN_TILE_COLS);
        assert_eq!(t % TILE_ALIGN, 0);
        // Never wider than the matrix.
        assert_eq!(auto_tile_cols::<f64>(100), 100);
        // f32 windows fit twice the columns in the same bytes.
        assert!(auto_tile_cols::<f32>(10_000_000) >= t);
    }

    #[test]
    fn tile_cols_resolution() {
        assert_eq!(TileCols::Fixed(96).resolve::<f64>(1 << 20), 96);
        assert_eq!(TileCols::Fixed(0).resolve::<f64>(1 << 20), 1);
        let auto = TileCols::Auto.resolve::<f64>(1 << 20);
        assert!(auto >= MIN_TILE_COLS);
    }

    #[test]
    fn tiled_block_matches_flat_kernel() {
        let csr = suite::banded(1_200, 10, 0.5, 3);
        let x: Vec<f64> =
            (0..csr.cols).map(|i| ((i * 13) % 17) as f64 - 8.0).collect();
        for bs in BlockSize::PAPER_SIZES {
            let bm = csr_to_block(&csr, bs).unwrap();
            let mut want = vec![0.0; csr.rows];
            crate::kernels::spmv_block(&bm, &x, &mut want, false);
            for tile_cols in [64usize, 200, 4096] {
                let tm = TiledMatrix::from_block(&bm, 64, tile_cols).unwrap();
                tm.validate().unwrap();
                assert_eq!(tm.nnz(), bm.nnz());
                let mut got = vec![0.0; csr.rows];
                tm.spmv(&x, &mut got, false);
                for i in 0..csr.rows {
                    assert!(
                        (got[i] - want[i]).abs()
                            <= 1e-12 * want[i].abs().max(1.0),
                        "{bs} tile={tile_cols} row {i}"
                    );
                }
            }
        }
    }

    #[test]
    fn single_tile_is_bit_identical_to_flat() {
        // With one tile covering every column the span order equals the
        // flat conversion's block order, so the accumulation order — and
        // therefore every bit of the result — is identical.
        let csr = suite::fem_blocked(300, 3, 6, 9);
        let x: Vec<f64> =
            (0..csr.cols).map(|i| ((i * 7) % 23) as f64 * 0.5 - 5.0).collect();
        for bs in BlockSize::PAPER_SIZES {
            let bm = csr_to_block(&csr, bs).unwrap();
            let mut want = vec![0.0; csr.rows];
            crate::kernels::spmv_block(&bm, &x, &mut want, false);
            let tm =
                TiledMatrix::from_block(&bm, 512, csr.cols.max(1)).unwrap();
            assert_eq!(tm.n_tiles, 1);
            let mut got = vec![0.0; csr.rows];
            tm.spmv(&x, &mut got, false);
            assert_eq!(got, want, "{bs}");
        }
    }

    #[test]
    fn generic_block_sizes_get_interval_aligned_panels() {
        // r = 3 does not divide the requested panel height: the
        // effective height must round down to a multiple of r (64 →
        // 63) and the schedule must stay correct end to end.
        let csr = suite::banded(500, 7, 0.5, 19);
        let bm = csr_to_block(&csr, BlockSize::new(3, 5)).unwrap();
        let tm = TiledMatrix::from_block(&bm, 64, 90).unwrap();
        assert_eq!(tm.panel_rows, 63);
        tm.validate().unwrap();
        let x: Vec<f64> =
            (0..csr.cols).map(|i| ((i * 3) % 11) as f64 - 5.0).collect();
        let mut want = vec![0.0; csr.rows];
        csr.spmv_ref(&x, &mut want);
        let mut got = vec![0.0; csr.rows];
        tm.spmv(&x, &mut got, false);
        crate::testkit::assert_close(&got, &want, 1e-9, "b(3,5) tiled");
    }

    #[test]
    fn tiled_spmm_matches_k_spmvs() {
        let csr = suite::quantum_clusters(500, 3, 8, 5, 7);
        let bm = csr_to_block(&csr, BlockSize::new(2, 8)).unwrap();
        let tm = TiledMatrix::from_block(&bm, 64, 128).unwrap();
        let k = 3usize;
        let x: Vec<f64> = (0..csr.cols * k)
            .map(|i| ((i * 5) % 19) as f64 * 0.1 - 0.9)
            .collect();
        let mut y = vec![0.0; csr.rows * k];
        tm.spmm(&x, &mut y, k);
        for j in 0..k {
            let xj: Vec<f64> = (0..csr.cols).map(|c| x[c * k + j]).collect();
            let mut want = vec![0.0; csr.rows];
            csr.spmv_ref(&xj, &mut want);
            for r in 0..csr.rows {
                assert!(
                    (y[r * k + j] - want[r]).abs()
                        <= 1e-9 * want[r].abs().max(1.0),
                    "j={j} row {r}"
                );
            }
        }
    }

    #[test]
    fn panel_ranges_compose_to_full() {
        let csr = suite::banded(900, 8, 0.4, 5);
        let bm = csr_to_block(&csr, BlockSize::new(4, 4)).unwrap();
        let tm = TiledMatrix::from_block(&bm, 128, 96).unwrap();
        let x: Vec<f64> = (0..csr.cols).map(|i| (i % 5) as f64).collect();
        let mut want = vec![0.0; csr.rows];
        tm.spmv(&x, &mut want, false);
        // Stitch from two disjoint panel ranges.
        let cut = tm.panels.len() / 2;
        let mut got = vec![0.0; csr.rows];
        let mid_row = tm.panels[cut].row_begin;
        tm.spmv_panels(0, cut, &x, &mut got[..mid_row], false);
        tm.spmv_panels(cut, tm.panels.len(), &x, &mut got[mid_row..], false);
        assert_eq!(got, want);
    }

    #[test]
    fn validate_catches_corruption() {
        let csr = suite::banded(400, 6, 0.6, 11);
        let bm = csr_to_block(&csr, BlockSize::new(2, 4)).unwrap();
        let good = TiledMatrix::from_block(&bm, 64, 100).unwrap();
        good.validate().unwrap();

        // A block moved across spans (count drift) must be caught.
        let mut bad = TiledMatrix::from_block(&bm, 64, 100).unwrap();
        if bad.spans.len() >= 2 {
            bad.spans[0].n_blocks += 1;
            assert!(bad.validate().is_err(), "span block count drift");
        }

        // A value dropped breaks the popcount/nnz proof.
        let mut bad = TiledMatrix::from_block(&bm, 64, 100).unwrap();
        bad.values.pop();
        assert!(bad.validate().is_err(), "values truncated");

        // Tile-relative colidx beyond the tile width.
        let mut bad = TiledMatrix::from_block(&bm, 64, 100).unwrap();
        let w = (bad.tile_cols as u32 + 5).to_le_bytes();
        bad.headers[..4].copy_from_slice(&w);
        assert!(bad.validate().is_err(), "colidx outside tile");

        // Per-interval coverage drift (block claimed twice).
        let mut bad = TiledMatrix::from_block(&bm, 64, 100).unwrap();
        bad.source_blocks_per_interval[0] += 1;
        assert!(bad.validate().is_err(), "coverage count drift");
    }

    #[test]
    fn tiled_csr_matches_reference() {
        let csr = suite::circuit(1_500, 3, 3, 13);
        let tc = TiledCsr::from_csr(&csr, 64, 200).unwrap();
        tc.validate().unwrap();
        assert_eq!(tc.nnz(), csr.nnz());
        let x: Vec<f64> =
            (0..csr.cols).map(|i| ((i * 11) % 13) as f64 - 6.0).collect();
        let mut want = vec![0.0; csr.rows];
        csr.spmv_ref(&x, &mut want);
        let mut got = vec![0.0; csr.rows];
        tc.spmv(&x, &mut got);
        crate::testkit::assert_close(&got, &want, 1e-9, "tiled csr");
        // Multi-RHS path.
        let k = 4usize;
        let xk: Vec<f64> = (0..csr.cols * k)
            .map(|i| ((i * 3) % 31) as f64 * 0.125 - 2.0)
            .collect();
        let mut yk = vec![0.0; csr.rows * k];
        tc.spmm(&xk, &mut yk, k);
        for j in 0..k {
            let xj: Vec<f64> = (0..csr.cols).map(|c| xk[c * k + j]).collect();
            let mut wj = vec![0.0; csr.rows];
            csr.spmv_ref(&xj, &mut wj);
            for r in 0..csr.rows {
                assert!(
                    (yk[r * k + j] - wj[r]).abs()
                        <= 1e-9 * wj[r].abs().max(1.0),
                    "spmm j={j} row {r}"
                );
            }
        }
    }

    #[test]
    fn tiled_hybrid_matches_reference() {
        let csr = suite::mixed_band_scatter(2_048, 9);
        let cfg = super::super::HybridConfig {
            panel_rows: 128,
            ..super::super::HybridConfig::for_scalar::<f64>()
        };
        let th =
            TiledHybrid::from_csr(&csr, &cfg, None, TileCols::Fixed(256))
                .unwrap();
        th.validate().unwrap();
        assert_eq!(th.nnz(), csr.nnz());
        // The mixed matrix must keep both kernel classes after tiling.
        let used = th.kernels_used();
        assert!(used.iter().any(|k| matches!(k, PanelKernel::Beta(_))));
        assert!(used.contains(&PanelKernel::Csr));
        let x: Vec<f64> =
            (0..csr.cols).map(|i| (i % 9) as f64 - 4.0).collect();
        let mut want = vec![0.0; csr.rows];
        csr.spmv_ref(&x, &mut want);
        let mut got = vec![0.0; csr.rows];
        th.spmv(&x, &mut got);
        crate::testkit::assert_close(&got, &want, 1e-9, "tiled hybrid");
    }

    #[test]
    fn f32_tiled_block_matches_reference() {
        let csr32 = suite::banded(1_024, 12, 0.8, 4).to_precision::<f32>();
        let bm = csr_to_block(&csr32, BlockSize::new(2, 16)).unwrap();
        let tm = TiledMatrix::from_block(&bm, 64, 160).unwrap();
        tm.validate().unwrap();
        let x: Vec<f32> =
            (0..csr32.cols).map(|i| (i % 5) as f32 * 0.5 - 1.0).collect();
        let mut want = vec![0.0f32; csr32.rows];
        csr32.spmv_ref(&x, &mut want);
        let mut got = vec![0.0f32; csr32.rows];
        tm.spmv(&x, &mut got, false);
        for i in 0..csr32.rows {
            assert!(
                (got[i] - want[i]).abs() <= 2e-4 * want[i].abs().max(1.0),
                "row {i}"
            );
        }
    }

    #[test]
    fn empty_matrix_tiles() {
        let csr =
            Csr::<f64>::from_raw(16, 16, vec![0; 17], vec![], vec![]).unwrap();
        let tm = TiledMatrix::from_csr(
            &csr,
            BlockSize::new(2, 4),
            &TiledConfig { panel_rows: 8, tile_cols: TileCols::Fixed(4) },
        )
        .unwrap();
        tm.validate().unwrap();
        assert_eq!(tm.nnz(), 0);
        let x = vec![1.0; 16];
        let mut y = vec![0.0; 16];
        tm.spmv(&x, &mut y, false);
        assert!(y.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn bad_configs_rejected() {
        let csr = suite::poisson2d(8);
        let bm = csr_to_block(&csr, BlockSize::new(1, 8)).unwrap();
        assert!(TiledMatrix::from_block(&bm, 12, 64).is_err());
        assert!(TiledMatrix::from_block(&bm, 0, 64).is_err());
        assert!(TiledMatrix::from_block(&bm, 64, 0).is_err());
        assert!(TiledCsr::from_csr(&csr, 12, 64).is_err());
    }
}
