//! Single-precision `β32(r,c)` format — the 16-lane variant.
//!
//! The paper notes AVX-512 holds "16 single precision or eight double
//! precision floating point values"; all its kernels are double. This
//! module completes the picture: blocks up to **16 columns wide** with
//! one `u16` mask per block row, and `vexpandps` kernels
//! ([`crate::kernels::avx512f32`]) that inflate 16 packed floats at a
//! time. Everything else (row alignment, greedy anchor cover, no value
//! padding) matches the f64 format.

use super::{BlockSize, FormatError};
use crate::matrix::Csr;

/// Bytes of colidx inside an interleaved f32 block header.
pub const HEADER32_COLIDX_BYTES: usize = 4;

/// A sparse matrix in `β32(r,c)` (single precision, c ≤ 16).
#[derive(Clone, Debug, PartialEq)]
pub struct BlockMatrix32 {
    pub rows: usize,
    pub cols: usize,
    pub bs: BlockSize,
    pub values: Vec<f32>,
    pub block_colidx: Vec<u32>,
    pub block_rowptr: Vec<u32>,
    /// One 16-bit mask per block row.
    pub block_masks: Vec<u16>,
    /// Interleaved stream: `colidx(4B LE) | masks(2·r B LE)` per block.
    pub headers: Vec<u8>,
}

impl BlockMatrix32 {
    #[inline]
    pub fn intervals(&self) -> usize {
        crate::util::ceil_div(self.rows, self.bs.r)
    }

    #[inline]
    pub fn n_blocks(&self) -> usize {
        self.block_colidx.len()
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    #[inline]
    pub fn header_stride(&self) -> usize {
        HEADER32_COLIDX_BYTES + 2 * self.bs.r
    }

    /// `Avg(r,c)` (same metric as the f64 format).
    pub fn avg_nnz_per_block(&self) -> f64 {
        if self.n_blocks() == 0 {
            0.0
        } else {
            self.nnz() as f64 / self.n_blocks() as f64
        }
    }

    /// Measured storage bytes (f32 values + u32 colidx/rowptr + u16
    /// masks).
    pub fn occupancy_bytes(&self) -> usize {
        self.values.len() * 4
            + self.block_colidx.len() * 4
            + self.block_rowptr.len() * 4
            + self.block_masks.len() * 2
    }

    /// Validates the structural invariants (mask bits within c, popcount
    /// sum == nnz, ordered non-overlapping blocks, header mirror).
    pub fn validate(&self) -> Result<(), FormatError> {
        if self.bs.c > 16 || self.bs.c == 0 || self.bs.r == 0 {
            return Err(FormatError::BadBlockSize(self.bs));
        }
        let nb = self.n_blocks();
        let fail = |m: String| Err(FormatError::Inconsistent(m));
        if self.block_rowptr.len() != self.intervals() + 1
            || self.block_rowptr[self.intervals()] as usize != nb
        {
            return fail("rowptr shape".into());
        }
        if self.block_masks.len() != nb * self.bs.r {
            return fail("mask count".into());
        }
        let lane_mask: u16 = if self.bs.c == 16 {
            0xFFFF
        } else {
            (1u16 << self.bs.c) - 1
        };
        let mut pop = 0usize;
        for (b, chunk) in self.block_masks.chunks(self.bs.r).enumerate() {
            let mut block_pop = 0u32;
            for &m in chunk {
                if m & !lane_mask != 0 {
                    return fail(format!("mask beyond c in block {b}"));
                }
                block_pop += m.count_ones();
            }
            if block_pop == 0 {
                return fail(format!("empty block {b}"));
            }
            pop += block_pop as usize;
        }
        if pop != self.nnz() {
            return fail("popcount != nnz".into());
        }
        for it in 0..self.intervals() {
            let (a, b) =
                (self.block_rowptr[it] as usize, self.block_rowptr[it + 1] as usize);
            let mut prev_end: i64 = -1;
            for k in a..b {
                let col = self.block_colidx[k] as i64;
                if col <= prev_end || col as usize >= self.cols {
                    return fail(format!("block order in interval {it}"));
                }
                prev_end = col + self.bs.c as i64 - 1;
            }
        }
        let stride = self.header_stride();
        if self.headers.len() != nb * stride {
            return fail("header length".into());
        }
        for b in 0..nb {
            let h = &self.headers[b * stride..(b + 1) * stride];
            if u32::from_le_bytes([h[0], h[1], h[2], h[3]]) != self.block_colidx[b]
            {
                return fail(format!("header col at {b}"));
            }
            for i in 0..self.bs.r {
                let m = u16::from_le_bytes([h[4 + 2 * i], h[5 + 2 * i]]);
                if m != self.block_masks[b * self.bs.r + i] {
                    return fail(format!("header mask at {b}"));
                }
            }
        }
        Ok(())
    }
}

/// Converts a (double-precision) CSR matrix into `β32(r,c)` storage,
/// truncating values to f32. Same greedy anchor cover as the f64 path.
pub fn csr_to_block32(csr: &Csr, bs: BlockSize) -> Result<BlockMatrix32, FormatError> {
    if bs.c > 16 || bs.c == 0 || bs.r == 0 || bs.r > 8 {
        return Err(FormatError::BadBlockSize(bs));
    }
    let (r, c) = (bs.r, bs.c);
    let intervals = crate::util::ceil_div(csr.rows, r);
    let mut values: Vec<f32> = Vec::with_capacity(csr.nnz());
    let mut block_colidx = Vec::new();
    let mut block_rowptr = Vec::with_capacity(intervals + 1);
    let mut block_masks: Vec<u16> = Vec::new();
    block_rowptr.push(0u32);
    let mut cursor = vec![0usize; r];
    for it in 0..intervals {
        let row0 = it * r;
        let rows_here = r.min(csr.rows - row0);
        for (i, cur) in cursor.iter_mut().enumerate().take(rows_here) {
            *cur = csr.rowptr[row0 + i] as usize;
        }
        loop {
            let mut min_col = u32::MAX;
            for i in 0..rows_here {
                let end = csr.rowptr[row0 + i + 1] as usize;
                if cursor[i] < end {
                    min_col = min_col.min(csr.colidx[cursor[i]]);
                }
            }
            if min_col == u32::MAX {
                break;
            }
            let col_end = min_col as usize + c;
            block_colidx.push(min_col);
            for i in 0..rows_here {
                let end = csr.rowptr[row0 + i + 1] as usize;
                let mut mask = 0u16;
                while cursor[i] < end
                    && (csr.colidx[cursor[i]] as usize) < col_end
                {
                    let k = cursor[i];
                    mask |= 1 << (csr.colidx[k] - min_col);
                    values.push(csr.values[k] as f32);
                    cursor[i] += 1;
                }
                block_masks.push(mask);
            }
            for _ in rows_here..r {
                block_masks.push(0);
            }
        }
        block_rowptr.push(block_colidx.len() as u32);
    }
    let stride = HEADER32_COLIDX_BYTES + 2 * r;
    let mut headers = Vec::with_capacity(block_colidx.len() * stride);
    for b in 0..block_colidx.len() {
        headers.extend_from_slice(&block_colidx[b].to_le_bytes());
        for i in 0..r {
            headers.extend_from_slice(&block_masks[b * r + i].to_le_bytes());
        }
    }
    let bm = BlockMatrix32 {
        rows: csr.rows,
        cols: csr.cols,
        bs,
        values,
        block_colidx,
        block_rowptr,
        block_masks,
        headers,
    };
    debug_assert!(bm.validate().is_ok(), "{:?}", bm.validate());
    Ok(bm)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::suite;

    #[test]
    fn convert_and_validate_c16() {
        for sm in suite::test_subset().iter().take(5) {
            for bs in [BlockSize::new(1, 16), BlockSize::new(2, 16), BlockSize::new(4, 16)] {
                let bm = csr_to_block32(&sm.csr, bs).unwrap();
                bm.validate().unwrap();
                assert_eq!(bm.nnz(), sm.csr.nnz());
            }
        }
    }

    #[test]
    fn c16_produces_fewer_blocks_than_c8() {
        let sm = &suite::test_subset()[2]; // contact: long runs
        let b8 = csr_to_block32(&sm.csr, BlockSize::new(1, 8)).unwrap();
        let b16 = csr_to_block32(&sm.csr, BlockSize::new(1, 16)).unwrap();
        assert!(b16.n_blocks() < b8.n_blocks());
    }

    #[test]
    fn occupancy_beats_f64_format() {
        let sm = &suite::test_subset()[1];
        let b32 = csr_to_block32(&sm.csr, BlockSize::new(1, 8)).unwrap();
        let b64 =
            crate::formats::csr_to_block(&sm.csr, BlockSize::new(1, 8)).unwrap();
        assert!(b32.occupancy_bytes() < b64.occupancy_bytes());
    }

    #[test]
    fn rejects_too_wide() {
        let csr = suite::poisson2d(4);
        assert!(csr_to_block32(&csr, BlockSize::new(1, 17)).is_err());
    }

    #[test]
    fn validate_catches_mask_corruption() {
        let csr = suite::poisson2d(6);
        let mut bm = csr_to_block32(&csr, BlockSize::new(1, 16)).unwrap();
        bm.block_masks[0] = 0;
        assert!(bm.validate().is_err());
    }
}
