//! Block statistics — the `Avg(r,c)` / fill profile that drives the
//! occupancy model (Eq. 2) and the kernel predictor (Fig. 5 / 6), and
//! the contents of the paper's Tables 1 and 2.

use super::BlockSize;
use crate::matrix::Csr;
use crate::scalar::Scalar;

/// Per-(matrix, block-size) statistics.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BlockStats {
    pub bs: BlockSize,
    pub n_blocks: usize,
    /// `N_NNZ / N_blocks(r,c)` — Table 1/2 main column.
    pub avg_nnz_per_block: f64,
    /// `avg / (r·c)` — Table 1/2 parenthesized percentage.
    pub fill_fraction: f64,
}

/// Counts the blocks of a `β(r,c)` cover *without materializing the
/// format* — the cheap scan the predictor runs before any conversion
/// ("The Avg.NNZ/blocks numbers can be obtained without converting the
/// matrices into a block-based storage").
pub fn count_blocks<T: Scalar>(csr: &Csr<T>, bs: BlockSize) -> usize {
    let (r, c) = (bs.r, bs.c);
    let intervals = crate::util::ceil_div(csr.rows, r);
    let mut n_blocks = 0usize;
    let mut cursor = vec![0usize; r];
    for it in 0..intervals {
        let row0 = it * r;
        let rows_here = r.min(csr.rows - row0);
        for (i, cur) in cursor.iter_mut().enumerate().take(rows_here) {
            *cur = csr.rowptr[row0 + i] as usize;
        }
        loop {
            let mut min_col = u32::MAX;
            for i in 0..rows_here {
                let end = csr.rowptr[row0 + i + 1] as usize;
                if cursor[i] < end {
                    min_col = min_col.min(csr.colidx[cursor[i]]);
                }
            }
            if min_col == u32::MAX {
                break;
            }
            n_blocks += 1;
            let col_end = min_col as usize + c;
            for i in 0..rows_here {
                let end = csr.rowptr[row0 + i + 1] as usize;
                while cursor[i] < end
                    && (csr.colidx[cursor[i]] as usize) < col_end
                {
                    cursor[i] += 1;
                }
            }
        }
    }
    n_blocks
}

/// Computes the stats for one block size (cheap scan, no conversion).
pub fn block_stats<T: Scalar>(csr: &Csr<T>, bs: BlockSize) -> BlockStats {
    let n_blocks = count_blocks(csr, bs);
    let avg = if n_blocks == 0 {
        0.0
    } else {
        csr.nnz() as f64 / n_blocks as f64
    };
    BlockStats {
        bs,
        n_blocks,
        avg_nnz_per_block: avg,
        fill_fraction: avg / bs.bits() as f64,
    }
}

/// Stats for all six paper block sizes — one Table 1/2 row.
pub fn paper_profile<T: Scalar>(csr: &Csr<T>) -> Vec<BlockStats> {
    BlockSize::PAPER_SIZES
        .iter()
        .map(|&bs| block_stats(csr, bs))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::super::csr_to_block;
    use super::*;
    use crate::matrix::suite;

    #[test]
    fn count_matches_materialized() {
        for sm in suite::test_subset() {
            for bs in BlockSize::PAPER_SIZES {
                let counted = count_blocks(&sm.csr, bs);
                let bm = csr_to_block(&sm.csr, bs).unwrap();
                assert_eq!(
                    counted,
                    bm.n_blocks(),
                    "{} {bs}: scan disagrees with conversion",
                    sm.name
                );
            }
        }
    }

    #[test]
    fn dense_profile_is_full() {
        let csr = suite::dense(64, 4);
        for st in paper_profile(&csr) {
            assert!((st.fill_fraction - 1.0).abs() < 1e-9, "{}", st.bs);
        }
    }

    #[test]
    fn avg_at_least_one() {
        for sm in suite::test_subset() {
            for st in paper_profile(&sm.csr) {
                assert!(st.avg_nnz_per_block >= 1.0 || sm.csr.nnz() == 0);
                assert!(st.fill_fraction <= 1.0 + 1e-12);
            }
        }
    }

    #[test]
    fn wider_blocks_fewer_blocks() {
        // For the same r, growing c can only reduce (or keep) the number
        // of blocks.
        for sm in suite::test_subset() {
            for r in [1usize, 2, 4] {
                let n4 = count_blocks(&sm.csr, BlockSize::new(r, 4));
                let n8 = count_blocks(&sm.csr, BlockSize::new(r, 8));
                assert!(n8 <= n4, "{}: r={r}", sm.name);
            }
        }
    }

    #[test]
    fn class_fill_ordering_matches_paper() {
        // Structural sanity of the suite surrogates: contact/fem classes
        // must fill β(1,8) blocks far better than rmat/scatter classes —
        // the property Table 1 documents (e.g. nd6k 81% vs kron 13%).
        let fill18 = |name: &str| {
            let sm = suite::by_name(name).unwrap();
            block_stats(&sm.csr, BlockSize::new(1, 8)).fill_fraction
        };
        assert!(fill18("nd6k") > 0.6);
        assert!(fill18("bone010") > 0.35);
        assert!(fill18("kron_g500-logn21") < 0.25);
        assert!(fill18("ns3Da") < 0.25);
        assert!(fill18("nd6k") > 2.0 * fill18("kron_g500-logn21"));
    }
}
