//! CSR ⇄ `β(r,c)` conversion, generic over the element precision.
//!
//! The forward conversion implements SPC5's greedy cover: inside each
//! row interval (r consecutive rows) blocks are created left-to-right,
//! each block anchored at the leftmost not-yet-covered nonzero of the
//! interval. Blocks are row-aligned but can start at any column —
//! the paper's "partially avoid aligning the block vertically".
//!
//! The same routine serves both precisions: the mask word and the
//! maximum block width come from the scalar (`u8`/8 for f64, `u16`/16
//! for f32).

use super::{BlockMatrix, BlockSize, FormatError};
use crate::matrix::{Coo, Csr};
use crate::scalar::{MaskWord, Scalar};

/// Converts a CSR matrix into the `β(r,c)` format.
///
/// Complexity is `O(nnz + intervals·r)`; the paper reports ≈2× one
/// SpMV, which `benches/conversion_cost.rs` verifies for this
/// implementation.
pub fn csr_to_block<T: Scalar>(
    csr: &Csr<T>,
    bs: BlockSize,
) -> Result<BlockMatrix<T>, FormatError> {
    bs.validate_for::<T>()?;
    if bs.r == 1 {
        // Fast path: one row per block ⇒ the values array is the CSR
        // values array verbatim (paper: "This array remains unchanged
        // compared to the CSR format if we have one row per block"),
        // and masks come from a single linear walk. This keeps the
        // conversion cost near the paper's "≈2× one SpMV".
        return Ok(csr_to_block_r1(csr, bs));
    }
    let (r, c) = (bs.r, bs.c);
    let intervals = crate::util::ceil_div(csr.rows, r);

    let mut values: Vec<T> = Vec::with_capacity(csr.nnz());
    let mut block_colidx: Vec<u32> = Vec::with_capacity(csr.nnz() / 2 + 8);
    let mut block_rowptr: Vec<u32> = Vec::with_capacity(intervals + 1);
    let mut block_masks: Vec<T::Mask> =
        Vec::with_capacity(r * (csr.nnz() / 2 + 8));
    block_rowptr.push(0);

    // Per-row cursor into csr.colidx/values.
    let mut cursor = vec![0usize; r];

    for it in 0..intervals {
        let row0 = it * r;
        let rows_here = r.min(csr.rows - row0);
        for (i, cur) in cursor.iter_mut().enumerate().take(rows_here) {
            *cur = csr.rowptr[row0 + i] as usize;
        }

        loop {
            // Leftmost uncovered column across the interval's rows.
            let mut min_col = u32::MAX;
            for i in 0..rows_here {
                let end = csr.rowptr[row0 + i + 1] as usize;
                if cursor[i] < end {
                    min_col = min_col.min(csr.colidx[cursor[i]]);
                }
            }
            if min_col == u32::MAX {
                break; // interval fully covered
            }

            let col_end = min_col + c as u32;
            block_colidx.push(min_col);
            // Row-major inside the block: row i's covered values first.
            let colidx = &csr.colidx[..];
            for i in 0..rows_here {
                let end = csr.rowptr[row0 + i + 1] as usize;
                let mut k = cursor[i];
                let mut mask = <T::Mask as MaskWord>::ZERO;
                while k < end && colidx[k] < col_end {
                    mask.set((colidx[k] - min_col) as usize);
                    k += 1;
                }
                values.extend_from_slice(&csr.values[cursor[i]..k]);
                cursor[i] = k;
                block_masks.push(mask);
            }
            // Short interval at the matrix tail: pad the *mask array*
            // (not the values) so every block owns exactly r mask words.
            for _ in rows_here..r {
                block_masks.push(<T::Mask as MaskWord>::ZERO);
            }
            // A block is created only at an existing nonzero, so it can
            // never be empty — guaranteed by construction.
        }
        block_rowptr.push(block_colidx.len() as u32);
    }

    let mut bm = BlockMatrix {
        rows: csr.rows,
        cols: csr.cols,
        bs,
        values,
        block_colidx,
        block_rowptr,
        block_masks,
        headers: Vec::new(),
        tune: crate::kernels::avx512::default_tune(),
    };
    bm.rebuild_headers();
    debug_assert!(bm.validate().is_ok(), "{:?}", bm.validate());
    Ok(bm)
}

/// Specialized `r = 1` conversion: single pass over `colidx`, values
/// copied wholesale, headers built inline.
fn csr_to_block_r1<T: Scalar>(csr: &Csr<T>, bs: BlockSize) -> BlockMatrix<T> {
    let c = bs.c as u32;
    let rows = csr.rows;
    let mut block_colidx: Vec<u32> = Vec::with_capacity(csr.nnz() / 2 + 8);
    let mut block_rowptr: Vec<u32> = Vec::with_capacity(rows + 1);
    let mut block_masks: Vec<T::Mask> = Vec::with_capacity(csr.nnz() / 2 + 8);
    block_rowptr.push(0);
    let colidx = &csr.colidx[..];
    for row in 0..rows {
        let mut k = csr.rowptr[row] as usize;
        let end = csr.rowptr[row + 1] as usize;
        while k < end {
            let anchor = colidx[k];
            let mut mask = <T::Mask as MaskWord>::bit(0); // anchor bit
            k += 1;
            while k < end && colidx[k] - anchor < c {
                mask.set((colidx[k] - anchor) as usize);
                k += 1;
            }
            block_colidx.push(anchor);
            block_masks.push(mask);
        }
        block_rowptr.push(block_colidx.len() as u32);
    }
    // Interleaved headers in one pass.
    let stride = super::HEADER_COLIDX_BYTES + <T::Mask as MaskWord>::BYTES;
    let mut headers = Vec::with_capacity(block_colidx.len() * stride);
    for b in 0..block_colidx.len() {
        headers.extend_from_slice(&block_colidx[b].to_le_bytes());
        block_masks[b].push_le(&mut headers);
    }
    let bm = BlockMatrix {
        rows,
        cols: csr.cols,
        bs,
        values: csr.values.clone(),
        block_colidx,
        block_rowptr,
        block_masks,
        headers,
        tune: crate::kernels::avx512::default_tune(),
    };
    debug_assert!(bm.validate().is_ok(), "{:?}", bm.validate());
    bm
}

/// Converts a `β(r,c)` matrix back to CSR (exact inverse of
/// [`csr_to_block`]; property-tested as a round trip).
pub fn block_to_csr<T: Scalar>(
    bm: &BlockMatrix<T>,
) -> Result<Csr<T>, FormatError> {
    let (r, c) = (bm.bs.r, bm.bs.c);
    let mut coo = Coo::new(bm.rows, bm.cols);
    let mut idx_val = 0usize;
    for it in 0..bm.intervals() {
        let row0 = it * r;
        let (a, b) =
            (bm.block_rowptr[it] as usize, bm.block_rowptr[it + 1] as usize);
        for blk in a..b {
            let col0 = bm.block_colidx[blk] as usize;
            for i in 0..r {
                let mask = bm.block_masks[blk * r + i];
                for k in 0..c {
                    if mask.test(k) {
                        coo.push(row0 + i, col0 + k, bm.values[idx_val]);
                        idx_val += 1;
                    }
                }
            }
        }
    }
    if idx_val != bm.values.len() {
        return Err(FormatError::Inconsistent(format!(
            "consumed {idx_val} values, stored {}",
            bm.values.len()
        )));
    }
    coo.to_csr()
        .map_err(|e| FormatError::Inconsistent(e.to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::suite;

    fn fig1() -> Csr {
        let rowptr = vec![0, 4, 7, 10, 12, 14, 14, 15, 18];
        let colidx = vec![0, 1, 4, 6, 1, 2, 3, 2, 4, 6, 3, 4, 5, 6, 5, 0, 4, 7];
        let values: Vec<f64> = (1..=18).map(|v| v as f64).collect();
        Csr::from_raw(8, 8, rowptr, colidx, values).unwrap()
    }

    #[test]
    fn roundtrip_fig1_all_paper_sizes() {
        let csr = fig1();
        for bs in BlockSize::PAPER_SIZES {
            let bm = csr_to_block(&csr, bs).unwrap();
            bm.validate().unwrap();
            let back = block_to_csr(&bm).unwrap();
            assert_eq!(csr, back, "roundtrip failed for {bs}");
        }
    }

    #[test]
    fn roundtrip_f32_wide_sizes() {
        let csr32: Csr<f32> = fig1().to_precision();
        for bs in BlockSize::PAPER_SIZES
            .into_iter()
            .chain(BlockSize::F32_WIDE_SIZES)
        {
            let bm = csr_to_block(&csr32, bs).unwrap();
            bm.validate().unwrap();
            let back = block_to_csr(&bm).unwrap();
            assert_eq!(csr32, back, "f32 roundtrip failed for {bs}");
        }
    }

    #[test]
    fn roundtrip_suite_subset() {
        for sm in suite::test_subset() {
            for bs in [BlockSize::new(1, 8), BlockSize::new(4, 4), BlockSize::new(8, 4)]
            {
                let bm = csr_to_block(&sm.csr, bs).unwrap();
                bm.validate().unwrap();
                let back = block_to_csr(&bm).unwrap();
                assert_eq!(sm.csr, back, "roundtrip failed for {} {bs}", sm.name);
            }
        }
    }

    #[test]
    fn wide_blocks_reduce_block_count() {
        // c=16 can only merge more columns per block than c=8.
        for sm in suite::test_subset().iter().take(5) {
            let csr32 = sm.csr.to_precision::<f32>();
            let b8 = csr_to_block(&csr32, BlockSize::new(1, 8)).unwrap();
            let b16 = csr_to_block(&csr32, BlockSize::new(1, 16)).unwrap();
            assert!(b16.n_blocks() <= b8.n_blocks(), "{}", sm.name);
        }
    }

    #[test]
    fn wide_sizes_rejected_for_f64() {
        let csr = fig1();
        assert!(csr_to_block(&csr, BlockSize::new(1, 16)).is_err());
        let csr32: Csr<f32> = csr.to_precision();
        assert!(csr_to_block(&csr32, BlockSize::new(1, 17)).is_err());
    }

    #[test]
    fn beta_1_keeps_values_order() {
        // r = 1 ⇒ values array identical to CSR (paper §"Block-based
        // storage": "This array remains unchanged compared to the CSR
        // format if we have one row per block").
        let csr = fig1();
        for c in [4usize, 8] {
            let bm = csr_to_block(&csr, BlockSize::new(1, c)).unwrap();
            assert_eq!(bm.values, csr.values);
        }
    }

    #[test]
    fn empty_matrix() {
        let csr = Csr::<f64>::from_raw(6, 6, vec![0; 7], vec![], vec![]).unwrap();
        let bm = csr_to_block(&csr, BlockSize::new(2, 4)).unwrap();
        assert_eq!(bm.n_blocks(), 0);
        assert_eq!(bm.nnz(), 0);
        bm.validate().unwrap();
        let back = block_to_csr(&bm).unwrap();
        assert_eq!(csr, back);
    }

    #[test]
    fn empty_rows_inside() {
        // Row 5 of fig1 is empty; also craft a matrix with an entirely
        // empty interval.
        let csr = Csr::from_raw(
            8,
            8,
            vec![0, 1, 1, 1, 1, 1, 1, 1, 2],
            vec![3, 7],
            vec![1.0, 2.0],
        )
        .unwrap();
        for bs in BlockSize::PAPER_SIZES {
            let bm = csr_to_block(&csr, bs).unwrap();
            bm.validate().unwrap();
            assert_eq!(block_to_csr(&bm).unwrap(), csr);
        }
    }

    #[test]
    fn rows_not_multiple_of_r() {
        // 5 rows with r=4 → last interval has one real row.
        let mut coo = Coo::new(5, 10);
        for r in 0..5 {
            coo.push(r, r, 1.0 + r as f64);
            coo.push(r, 9, -1.0);
        }
        let csr = coo.to_csr().unwrap();
        for bs in BlockSize::PAPER_SIZES {
            let bm = csr_to_block(&csr, bs).unwrap();
            bm.validate().unwrap();
            assert_eq!(block_to_csr(&bm).unwrap(), csr);
        }
    }

    #[test]
    fn blocks_anchor_at_leftmost_nnz() {
        // Single value at column 5 with c=4 → block starts exactly at 5.
        let mut coo = Coo::new(1, 12);
        coo.push(0, 5, 3.0);
        let csr = coo.to_csr().unwrap();
        let bm = csr_to_block(&csr, BlockSize::new(1, 4)).unwrap();
        assert_eq!(bm.block_colidx, vec![5]);
        assert_eq!(bm.block_masks, vec![0b0001]);
    }

    #[test]
    fn block_near_right_edge() {
        // Nonzero at the last column: block extends past the matrix edge
        // logically but only in-bounds bits may be set.
        let mut coo = Coo::new(2, 9);
        coo.push(0, 8, 1.0);
        coo.push(1, 8, 2.0);
        let csr = coo.to_csr().unwrap();
        for bs in BlockSize::PAPER_SIZES {
            let bm = csr_to_block(&csr, bs).unwrap();
            bm.validate().unwrap();
            assert_eq!(block_to_csr(&bm).unwrap(), csr);
        }
    }

    #[test]
    fn dense_blocks_fully_filled() {
        let csr = suite::dense(16, 1);
        let bm = csr_to_block(&csr, BlockSize::new(4, 4)).unwrap();
        assert!((bm.fill_fraction() - 1.0).abs() < 1e-12);
        assert_eq!(bm.n_blocks(), (16 / 4) * (16 / 4));
    }

    #[test]
    fn avg_matches_paper_dense_expectation() {
        // Paper Table 1, Dense-8000 row: Avg = r*c exactly (fill 100%).
        let csr = suite::dense(64, 2);
        for bs in BlockSize::PAPER_SIZES {
            let bm = csr_to_block(&csr, bs).unwrap();
            assert!(
                (bm.avg_nnz_per_block() - bs.bits() as f64).abs() < 1e-9,
                "{bs}"
            );
        }
    }
}
