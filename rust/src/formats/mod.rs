//! The paper's contribution: `β(r,c)` block-based sparse formats
//! **without zero padding** (DESIGN.md §6), generic over the element
//! precision.
//!
//! A `β(r,c)` matrix covers the nonzeros with `r×c` blocks that are
//! *row-aligned* (block row start ≡ 0 mod r) but start at any column.
//! Instead of padding each block to density, one mask word per block
//! row records which positions hold a value; the `values` array stores
//! only true nonzeros, in block order and row-major inside each block.
//!
//! The mask word is the scalar's [`crate::scalar::MaskWord`]: `u8`
//! (8 lanes) for `f64`, `u16` (16 lanes) for `f32` — so `β(1,16)` and
//! friends (the "β32" sizes) exist only in the single-precision
//! instantiation, where one AVX-512 register holds 16 floats.

pub mod block;
pub mod convert;
pub mod hybrid;
pub mod occupancy;
pub mod stats;
pub mod storage;
pub mod tiled;

pub use block::{BlockMatrix, HEADER_COLIDX_BYTES};
pub use convert::{block_to_csr, csr_to_block};
pub use hybrid::{
    HybridConfig, HybridMatrix, HybridSegment, PanelKernel, ScheduleEntry,
    SegmentStorage,
};
pub use occupancy::{beta_occupancy_bytes, csr_occupancy_bytes, fill_crossover};
pub use stats::BlockStats;
pub use storage::{
    BetaTestStorage, Csr5Storage, CsrStorage, PoolExec, SparseStorage,
};
pub use tiled::{
    auto_tile_cols, TileCols, TiledConfig, TiledCsr, TiledHybrid,
    TiledMatrix,
};

/// A block size `r×c`. The paper's optimized f64 kernels cover the six
/// sizes in [`BlockSize::PAPER_SIZES`]; the f32 stack adds the 16-lane
/// sizes in [`BlockSize::F32_WIDE_SIZES`]; the generic scalar kernel
/// accepts any `r ≤ 8`, `c ≤` mask width.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BlockSize {
    pub r: usize,
    pub c: usize,
}

impl BlockSize {
    pub const fn new(r: usize, c: usize) -> Self {
        BlockSize { r, c }
    }

    /// The six block sizes the paper ships optimized kernels for
    /// (§"Optimized kernel implementation").
    pub const PAPER_SIZES: [BlockSize; 6] = [
        BlockSize::new(1, 8),
        BlockSize::new(2, 4),
        BlockSize::new(2, 8),
        BlockSize::new(4, 4),
        BlockSize::new(4, 8),
        BlockSize::new(8, 4),
    ];

    /// The 16-column sizes only the f32 instantiation supports (one
    /// `vexpandps` per block row inflates 16 packed floats).
    pub const F32_WIDE_SIZES: [BlockSize; 3] = [
        BlockSize::new(1, 16),
        BlockSize::new(2, 16),
        BlockSize::new(4, 16),
    ];

    /// Bits in one block mask.
    pub const fn bits(&self) -> usize {
        self.r * self.c
    }

    /// Validates against an explicit mask width: `1 ≤ c ≤ mask_bits`
    /// and `1 ≤ r ≤ 8` (one mask word per block row, at most 8 rows per
    /// interval).
    pub fn validate_for_mask(&self, mask_bits: usize) -> Result<(), FormatError> {
        if self.r == 0 || self.c == 0 || self.r > 8 || self.c > mask_bits {
            return Err(FormatError::BadBlockSize(*self, mask_bits));
        }
        Ok(())
    }

    /// Validates for the scalar `T` (`c ≤ 8` for f64, `c ≤ 16` for f32).
    pub fn validate_for<T: crate::scalar::Scalar>(
        &self,
    ) -> Result<(), FormatError> {
        self.validate_for_mask(
            <T::Mask as crate::scalar::MaskWord>::BITS,
        )
    }

    /// Validates for the default double-precision format (`c ≤ 8`).
    pub fn validate(&self) -> Result<(), FormatError> {
        self.validate_for_mask(8)
    }
}

impl std::fmt::Display for BlockSize {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "b({},{})", self.r, self.c)
    }
}

/// Errors produced by the format layer.
#[derive(Debug)]
pub enum FormatError {
    /// Block size outside `1<=r<=8`, `1<=c<=mask_bits`.
    BadBlockSize(BlockSize, usize),
    /// Structural invariant violation.
    Inconsistent(String),
}

impl std::fmt::Display for FormatError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FormatError::BadBlockSize(bs, mask_bits) => write!(
                f,
                "unsupported block size {bs} (need 1<=r<=8, 1<=c<={mask_bits} \
                 for this precision)"
            ),
            FormatError::Inconsistent(msg) => {
                write!(f, "inconsistent block storage: {msg}")
            }
        }
    }
}

impl std::error::Error for FormatError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_sizes_are_valid() {
        for bs in BlockSize::PAPER_SIZES {
            bs.validate().unwrap();
            bs.validate_for::<f64>().unwrap();
            bs.validate_for::<f32>().unwrap();
            assert!(bs.bits() <= 64);
        }
    }

    #[test]
    fn wide_sizes_are_f32_only() {
        for bs in BlockSize::F32_WIDE_SIZES {
            assert!(bs.validate_for::<f64>().is_err(), "{bs}");
            bs.validate_for::<f32>().unwrap();
        }
    }

    #[test]
    fn invalid_sizes_rejected() {
        assert!(BlockSize::new(0, 4).validate().is_err());
        assert!(BlockSize::new(1, 0).validate().is_err());
        assert!(BlockSize::new(1, 9).validate().is_err());
        assert!(BlockSize::new(16, 8).validate().is_err());
        assert!(BlockSize::new(1, 17).validate_for::<f32>().is_err());
        assert!(BlockSize::new(16, 16).validate_for::<f32>().is_err());
    }

    #[test]
    fn display_format() {
        assert_eq!(BlockSize::new(2, 8).to_string(), "b(2,8)");
        assert_eq!(BlockSize::new(1, 16).to_string(), "b(1,16)");
    }
}
