//! The paper's contribution: `β(r,c)` block-based sparse formats
//! **without zero padding** (DESIGN.md §6).
//!
//! A `β(r,c)` matrix covers the nonzeros with `r×c` blocks that are
//! *row-aligned* (block row start ≡ 0 mod r) but start at any column.
//! Instead of padding each block to density, one `r·c`-bit mask per
//! block records which positions hold a value; the `values` array
//! stores only true nonzeros, in block order and row-major inside each
//! block.

pub mod block;
pub mod block32;
pub mod convert;
pub mod occupancy;
pub mod stats;

pub use block::{BlockMatrix, HEADER_COLIDX_BYTES};
pub use convert::{block_to_csr, csr_to_block};
pub use occupancy::{beta_occupancy_bytes, csr_occupancy_bytes, fill_crossover};
pub use stats::BlockStats;

/// A block size `r×c`. The paper's optimized kernels cover the six
/// sizes below; the generic scalar kernel accepts any `r·c ≤ 64`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BlockSize {
    pub r: usize,
    pub c: usize,
}

impl BlockSize {
    pub const fn new(r: usize, c: usize) -> Self {
        BlockSize { r, c }
    }

    /// The six block sizes the paper ships optimized kernels for
    /// (§"Optimized kernel implementation").
    pub const PAPER_SIZES: [BlockSize; 6] = [
        BlockSize::new(1, 8),
        BlockSize::new(2, 4),
        BlockSize::new(2, 8),
        BlockSize::new(4, 4),
        BlockSize::new(4, 8),
        BlockSize::new(8, 4),
    ];

    /// Bits in one block mask.
    pub const fn bits(&self) -> usize {
        self.r * self.c
    }

    /// Validates `r·c ≤ 64` and `c ≤ 8` (one mask byte per block row).
    pub fn validate(&self) -> Result<(), FormatError> {
        if self.r == 0 || self.c == 0 {
            return Err(FormatError::BadBlockSize(*self));
        }
        if self.c > 8 || self.bits() > 64 {
            return Err(FormatError::BadBlockSize(*self));
        }
        Ok(())
    }
}

impl std::fmt::Display for BlockSize {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "b({},{})", self.r, self.c)
    }
}

/// Errors produced by the format layer.
#[derive(Debug, thiserror::Error)]
pub enum FormatError {
    #[error("unsupported block size {0} (need 1<=c<=8, r*c<=64)")]
    BadBlockSize(BlockSize),
    #[error("inconsistent block storage: {0}")]
    Inconsistent(String),
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_sizes_are_valid() {
        for bs in BlockSize::PAPER_SIZES {
            bs.validate().unwrap();
            assert!(bs.bits() <= 64);
        }
    }

    #[test]
    fn invalid_sizes_rejected() {
        assert!(BlockSize::new(0, 4).validate().is_err());
        assert!(BlockSize::new(1, 0).validate().is_err());
        assert!(BlockSize::new(1, 9).validate().is_err());
        assert!(BlockSize::new(16, 8).validate().is_err());
    }

    #[test]
    fn display_format() {
        assert_eq!(BlockSize::new(2, 8).to_string(), "b(2,8)");
    }
}
