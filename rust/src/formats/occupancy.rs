//! The paper's memory-occupancy model, Eq. (1)–(4).
//!
//! `O(r,c) = O_values + O_block_colidx + O_block_rowptr + O_block_masks`
//! with the crossover against CSR at
//! `Avg(r,c) > 1 + r·c / (8·S_integer)` (Eq. 4).

use super::{BlockMatrix, BlockSize};
use crate::matrix::Csr;

/// Size of the integer type in the storage arrays (bytes).
pub const S_INTEGER: usize = 4;
/// Size of a double-precision value (bytes).
pub const S_FLOAT: usize = 8;

/// Analytical `β(r,c)` occupancy in bytes — paper Eq. (1):
/// `nnz·S_f + ceil(rows/r)·S_i + n_blocks·S_i + n_blocks·r·c/8`.
pub fn beta_occupancy_bytes(
    nnz: usize,
    rows: usize,
    n_blocks: usize,
    bs: BlockSize,
) -> usize {
    let o_values = nnz * S_FLOAT;
    // The implementation stores intervals+1 prefix entries; the paper's
    // Eq. 1 approximates this as rows/r. We model what we store.
    let o_rowptr = (crate::util::ceil_div(rows, bs.r) + 1) * S_INTEGER;
    let o_colidx = n_blocks * S_INTEGER;
    let o_masks = crate::util::ceil_div(n_blocks * bs.bits(), 8);
    o_values + o_rowptr + o_colidx + o_masks
}

/// CSR occupancy — paper Eq. (3).
pub fn csr_occupancy_bytes(nnz: usize, rows: usize) -> usize {
    nnz * (S_INTEGER + S_FLOAT) + S_INTEGER * (rows + 1)
}

/// Eq. (4): the average block fill above which `β(r,c)` stores fewer
/// bytes than CSR (ignoring the rowptr term, as the paper does).
pub fn fill_crossover(bs: BlockSize) -> f64 {
    1.0 + (bs.bits() as f64) / (8.0 * S_INTEGER as f64)
}

/// Compares measured vs analytical occupancy for a converted matrix.
/// Returns `(analytical, measured)`.
pub fn occupancy_check(bm: &BlockMatrix) -> (usize, usize) {
    let analytical =
        beta_occupancy_bytes(bm.nnz(), bm.rows, bm.n_blocks(), bm.bs);
    (analytical, bm.occupancy_bytes())
}

/// Storage ratio `β(r,c) / CSR` for a given matrix (― <1 means the
/// block format is smaller, the paper's headline storage claim for
/// well-blocked matrices).
pub fn storage_ratio(csr: &Csr, bm: &BlockMatrix) -> f64 {
    bm.occupancy_bytes() as f64 / csr.occupancy_bytes() as f64
}

#[cfg(test)]
mod tests {
    use super::super::csr_to_block;
    use super::*;
    use crate::matrix::suite;

    #[test]
    fn crossover_values_match_paper() {
        // Paper: "average filling of at least 1+1/4 for β(1,8), 1+1/2
        // for β(2,8) and β(4,4), and 2 for β(4,8) and β(8,4)".
        assert!((fill_crossover(BlockSize::new(1, 8)) - 1.25).abs() < 1e-12);
        assert!((fill_crossover(BlockSize::new(2, 8)) - 1.5).abs() < 1e-12);
        assert!((fill_crossover(BlockSize::new(4, 4)) - 1.5).abs() < 1e-12);
        assert!((fill_crossover(BlockSize::new(4, 8)) - 2.0).abs() < 1e-12);
        assert!((fill_crossover(BlockSize::new(8, 4)) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn analytical_equals_measured() {
        for sm in suite::test_subset() {
            for bs in BlockSize::PAPER_SIZES {
                let bm = csr_to_block(&sm.csr, bs).unwrap();
                let (analytical, measured) = occupancy_check(&bm);
                // Masks are stored one byte per block row (not packed to
                // the bit), so measured >= analytical with bounded slack.
                assert!(
                    measured >= analytical,
                    "{}: measured {measured} < analytical {analytical}",
                    sm.name
                );
                let slack = measured - analytical;
                // Slack only comes from byte-vs-bit mask rounding: at
                // most 1 byte per block row when c=4.
                assert!(
                    slack <= bm.n_blocks() * bm.bs.r,
                    "{}: slack too large",
                    sm.name
                );
            }
        }
    }

    #[test]
    fn dense_beats_csr_storage() {
        // Fully-filled blocks: β storage must be well below CSR (the
        // colidx array shrinks by ~r·c).
        let csr = suite::dense(128, 9);
        let bm = csr_to_block(&csr, BlockSize::new(4, 8)).unwrap();
        let ratio = storage_ratio(&csr, &bm);
        assert!(ratio < 0.8, "ratio {ratio}");
    }

    #[test]
    fn scatter_loses_to_csr_when_below_crossover() {
        // Fill ≈ 1 → β(4,8) must use MORE bytes than CSR (Eq. 4).
        let csr = suite::uniform_scatter(800, 8, 3);
        let bm = csr_to_block(&csr, BlockSize::new(4, 8)).unwrap();
        if bm.avg_nnz_per_block() < fill_crossover(BlockSize::new(4, 8)) {
            assert!(storage_ratio(&csr, &bm) > 1.0);
        }
    }

    #[test]
    fn eq4_predicts_measured_crossover() {
        // Eq. 4 with the *stored* mask size (one byte per block row, so
        // the effective per-block overhead is 4+r bytes): the measured
        // crossover is Avg = 1 + r/4 for every c. If Avg exceeds it by a
        // margin, β must be smaller than CSR; if far below, larger.
        for sm in suite::test_subset() {
            for bs in BlockSize::PAPER_SIZES {
                let bm = csr_to_block(&sm.csr, bs).unwrap();
                let avg = bm.avg_nnz_per_block();
                let cross = 1.0 + bs.r as f64 / 4.0;
                let ratio = storage_ratio(&sm.csr, &bm);
                if avg > cross * 1.25 {
                    assert!(
                        ratio < 1.0,
                        "{} {bs}: avg {avg:.2} >> crossover {cross:.2} but ratio {ratio:.3}",
                        sm.name
                    );
                } else if avg < cross * 0.85 {
                    assert!(
                        ratio > 1.0,
                        "{} {bs}: avg {avg:.2} << crossover {cross:.2} but ratio {ratio:.3}",
                        sm.name
                    );
                }
            }
        }
    }
}
