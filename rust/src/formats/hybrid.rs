//! Heterogeneous row-panel storage: per-panel `β(r,c)` / CSR kernel
//! selection.
//!
//! The paper's own conclusion — the optimal `β(r,c)` depends on the
//! matrix — is applied by the engine at whole-matrix granularity, but
//! real matrices are heterogeneous *within* themselves (a banded FEM
//! region next to a scattered coupling region fills blocks very
//! differently). [`HybridMatrix`] cuts the rows into fixed-height
//! **panels** (a multiple of 8 rows, tunable via
//! [`HybridConfig::panel_rows`]) and picks a storage independently per
//! panel:
//!
//! 1. for every candidate block size run the cheap no-conversion scan
//!    ([`crate::formats::stats::block_stats`]) on the panel,
//! 2. gate candidates by the paper's storage crossover
//!    ([`super::occupancy::fill_crossover`], Eq. 4): a β size whose
//!    panel fill is below the crossover stores more bytes than CSR and
//!    is never chosen,
//! 3. rank the surviving candidates (and CSR) on the predictor's
//!    fitted GFlop/s surface when performance records exist, or on the
//!    analytic bandwidth model ([`crate::predictor::model`]) otherwise.
//!
//! A schedule compiler then merges adjacent same-choice panels into
//! **segments** and converts each segment once, so the hot loop is a
//! flat walk over precompiled `(kernel, row span)` segments with zero
//! per-panel branching: β segments run through the existing AVX-512
//! span kernels ([`crate::kernels::avx512::spmv_span`] via
//! [`crate::kernels::spmv_block`]), CSR segments through the tuned CSR
//! row loop. When [`HybridConfig::split`] asks for more parallelism
//! than the merge produced, merged runs are re-cut into nnz-balanced
//! pieces at panel boundaries. The engine's parallel path splits the
//! segment list by nnz with
//! [`crate::parallel::balanced_prefix_split`] and runs the chunks on
//! its [`crate::parallel::WorkerPool`].
//!
//! This is the same design move as SELL-C-σ's row-chunk-local format
//! decisions (Kreutzer et al.) and Fukaya et al.'s part-wise kernel
//! assignment, expressed in SPC5's block-without-padding world.

use super::occupancy::fill_crossover;
use super::stats::block_stats;
use super::{csr_to_block, BlockMatrix, BlockSize, FormatError};
use crate::kernels::KernelKind;
use crate::matrix::Csr;
use crate::predictor::model::{predict, MachineModel};
use crate::predictor::PolyModel;
use crate::scalar::{MaskWord, Scalar};
use std::collections::HashMap;

/// Per-panel storage decision.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PanelKernel {
    /// The panel is stored as `β(r,c)` blocks and served by the block
    /// kernels.
    Beta(BlockSize),
    /// The panel stays CSR and is served by the CSR row loop.
    Csr,
}

impl std::fmt::Display for PanelKernel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PanelKernel::Beta(bs) => write!(f, "{bs}"),
            PanelKernel::Csr => write!(f, "csr"),
        }
    }
}

impl PanelKernel {
    /// Parses the [`Display`](std::fmt::Display) spelling back (`csr`
    /// or `b(r,c)`) — what serialized plans store per segment.
    pub fn parse(s: &str) -> Option<PanelKernel> {
        match KernelKind::parse(s)? {
            KernelKind::Csr => Some(PanelKernel::Csr),
            KernelKind::Beta(r, c) => Some(PanelKernel::Beta(
                BlockSize::new(r as usize, c as usize),
            )),
            _ => None,
        }
    }
}

/// Configuration of the panel cut and the candidate β sizes.
#[derive(Clone, Debug)]
pub struct HybridConfig {
    /// Rows per panel; must be a positive multiple of 8 so every
    /// kernel row-interval height (`r ∈ {1,2,4,8}`) divides panel
    /// boundaries.
    pub panel_rows: usize,
    /// Candidate block sizes a panel may choose from.
    pub candidates: Vec<BlockSize>,
    /// Minimum segment count the schedule compiler aims for: merged
    /// same-choice runs are re-cut (at panel boundaries, nnz-balanced)
    /// so a schedule has roughly this many segments to distribute.
    /// The parallel engine sets it to the worker count — otherwise a
    /// homogeneous matrix compiles to one segment and would occupy a
    /// single worker. `1` (the default) merges maximally.
    pub split: usize,
}

/// Default panel height: small enough to separate structurally
/// different regions of the suite matrices, large enough that segment
/// dispatch cost vanishes against the per-panel work.
pub const DEFAULT_PANEL_ROWS: usize = 512;

impl HybridConfig {
    /// Default configuration for scalar `T`: the paper's six sizes at
    /// 8 mask lanes (f64), the three 16-wide sizes at 16 lanes (f32 —
    /// only those have AVX-512 specializations).
    pub fn for_scalar<T: Scalar>() -> Self {
        let candidates = if <T::Mask as MaskWord>::BITS >= 16 {
            BlockSize::F32_WIDE_SIZES.to_vec()
        } else {
            BlockSize::PAPER_SIZES.to_vec()
        };
        HybridConfig { panel_rows: DEFAULT_PANEL_ROWS, candidates, split: 1 }
    }

    fn validate<T: Scalar>(&self) -> Result<(), FormatError> {
        if self.panel_rows == 0 || self.panel_rows % 8 != 0 {
            return Err(FormatError::Inconsistent(format!(
                "panel_rows must be a positive multiple of 8, got {}",
                self.panel_rows
            )));
        }
        if self.candidates.is_empty() {
            return Err(FormatError::Inconsistent(
                "hybrid needs at least one candidate block size".into(),
            ));
        }
        for bs in &self.candidates {
            bs.validate_for::<T>()?;
        }
        Ok(())
    }
}

/// One planned — not yet converted — schedule entry: a contiguous row
/// range bound to its chosen kernel. The decision half of the
/// inspector–executor split: [`HybridMatrix::plan_schedule`] produces
/// these (cheap scans only), [`HybridMatrix::from_schedule`] converts
/// them. A serialized [`crate::coordinator::SpmvPlan`] records exactly
/// this list, so a cached plan reproduces the schedule bit-for-bit
/// without re-ranking panels.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ScheduleEntry {
    /// First matrix row (inclusive); always a panel boundary.
    pub row_begin: usize,
    /// One past the last matrix row.
    pub row_end: usize,
    /// The merged panel decision for this row range.
    pub kernel: PanelKernel,
    /// Kernel variant override for this segment's β storage; `None`
    /// inherits the plan-level (or process-default) tune. The engine
    /// resolves this before instantiation so a serialized schedule
    /// reproduces the exact variant.
    pub tune: Option<crate::kernels::avx512::TuneParams>,
}

/// Storage of one compiled segment (a run of same-choice panels).
pub enum SegmentStorage<T: Scalar> {
    /// Converted block storage; `rows` counts the segment's rows,
    /// `cols` the full matrix width (x is indexed globally).
    Block(BlockMatrix<T>),
    /// Row-sliced CSR with segment-local rowptr.
    Csr(Csr<T>),
}

/// One entry of the compiled schedule: a contiguous row range bound to
/// its converted storage and kernel.
pub struct HybridSegment<T: Scalar> {
    /// First matrix row (inclusive); always a panel boundary.
    pub row_begin: usize,
    /// One past the last matrix row.
    pub row_end: usize,
    /// Nonzeros in the segment (the parallel split weight).
    pub nnz: usize,
    /// The merged panel decision this segment was compiled from.
    pub kernel: PanelKernel,
    pub storage: SegmentStorage<T>,
}

impl<T: Scalar> HybridSegment<T> {
    /// `y += A_seg · x` with `y` segment-local (`row_end - row_begin`
    /// entries) and `x` the full input vector.
    #[inline]
    pub fn spmv(&self, x: &[T], y: &mut [T]) {
        match &self.storage {
            SegmentStorage::Block(bm) => {
                crate::kernels::spmv_block(bm, x, y, false)
            }
            SegmentStorage::Csr(c) => crate::kernels::csr::spmv(c, x, y),
        }
    }

    /// Multi-RHS `Y += A_seg · X` (`x` row-major `[cols × k]`, `y`
    /// segment-local `[rows × k]`).
    #[inline]
    pub fn spmm(&self, x: &[T], y: &mut [T], k: usize) {
        match &self.storage {
            SegmentStorage::Block(bm) => {
                crate::kernels::spmm::spmm_auto(bm, x, y, k)
            }
            SegmentStorage::Csr(c) => crate::kernels::csr::spmm(c, x, y, k),
        }
    }
}

/// A sparse matrix compiled into a flat schedule of per-row-panel
/// kernel segments. See the module docs for the selection rules.
pub struct HybridMatrix<T: Scalar = f64> {
    pub rows: usize,
    pub cols: usize,
    /// The panel height the schedule was compiled with.
    pub panel_rows: usize,
    /// Per-panel decision, before merging (one entry per
    /// `ceil(rows / panel_rows)` panel) — kept for introspection,
    /// tests and the stats report.
    pub choices: Vec<PanelKernel>,
    /// The compiled schedule: ordered, contiguous, disjoint row
    /// segments covering `0..rows`.
    pub segments: Vec<HybridSegment<T>>,
}

impl<T: Scalar> HybridMatrix<T> {
    /// Compiles `csr` into a hybrid schedule. `models` is the
    /// predictor's fitted sequential GFlop/s surface per kernel
    /// (from [`crate::predictor::select::fit_sequential`]); pass
    /// `None` to rank candidates with the analytic bandwidth model.
    pub fn from_csr(
        csr: &Csr<T>,
        cfg: &HybridConfig,
        models: Option<&HashMap<KernelKind, PolyModel>>,
    ) -> Result<HybridMatrix<T>, FormatError> {
        let schedule = Self::plan_schedule(csr, cfg, models)?;
        Self::from_schedule_trusted(csr, cfg.panel_rows, &schedule)
    }

    /// The **inspection** half of the compile: decide every panel and
    /// merge/re-cut the runs, returning the planned schedule without
    /// converting anything. Cheap block-count scans only — this is
    /// what [`crate::coordinator::SpmvPlan`] records so a later
    /// [`HybridMatrix::from_schedule`] reproduces the exact same
    /// segments without the predictor.
    pub fn plan_schedule(
        csr: &Csr<T>,
        cfg: &HybridConfig,
        models: Option<&HashMap<KernelKind, PolyModel>>,
    ) -> Result<Vec<ScheduleEntry>, FormatError> {
        cfg.validate::<T>()?;
        let rows = csr.rows;
        let n_panels = crate::util::ceil_div(rows, cfg.panel_rows);

        // Phase 1: decide each panel independently.
        let mut choices = Vec::with_capacity(n_panels);
        for p in 0..n_panels {
            let r0 = p * cfg.panel_rows;
            let r1 = (r0 + cfg.panel_rows).min(rows);
            let sub = csr.row_slice(r0, r1);
            if sub.nnz() == 0 {
                // Empty panels carry no work: inherit the previous
                // choice so they never break a mergeable run.
                choices.push(*choices.last().unwrap_or(&PanelKernel::Csr));
            } else {
                choices.push(choose_panel(&sub, &cfg.candidates, models));
            }
        }

        // Phase 2: merge adjacent same-choice panels, re-cut each
        // merged run into nnz-balanced pieces (still at panel
        // boundaries) when `cfg.split` asks for more segments than the
        // merge produced — so the parallel path can feed every worker
        // even on a homogeneous matrix.
        let target_nnz =
            crate::util::ceil_div(csr.nnz().max(1), cfg.split.max(1));
        let mut schedule: Vec<ScheduleEntry> = Vec::new();
        let mut begin = 0usize;
        while begin < n_panels {
            let choice = choices[begin];
            let mut end = begin + 1;
            while end < n_panels && choices[end] == choice {
                end += 1;
            }
            // nnz prefix over the run's panel boundaries (fits u32:
            // the whole rowptr is u32).
            let base = csr.rowptr[begin * cfg.panel_rows];
            let prefix: Vec<u32> = (begin..=end)
                .map(|p| {
                    let row = (p * cfg.panel_rows).min(rows);
                    csr.rowptr[row] - base
                })
                .collect();
            let run_nnz = *prefix.last().unwrap() as usize;
            let parts = crate::util::ceil_div(run_nnz, target_nnz)
                .clamp(1, end - begin);
            for (p0, p1) in crate::parallel::balanced_prefix_split(
                &prefix, parts,
            ) {
                if p0 == p1 {
                    continue; // degenerate chunk (weights too skewed)
                }
                schedule.push(ScheduleEntry {
                    row_begin: (begin + p0) * cfg.panel_rows,
                    row_end: ((begin + p1) * cfg.panel_rows).min(rows),
                    kernel: choice,
                    tune: None,
                });
            }
            begin = end;
        }
        Ok(schedule)
    }

    /// The **instantiation** half: convert a planned schedule into the
    /// executable segment storages. `schedule` may come from a
    /// deserialized plan, so every structural invariant is re-checked
    /// (via [`HybridMatrix::validate`]) rather than trusted.
    pub fn from_schedule(
        csr: &Csr<T>,
        panel_rows: usize,
        schedule: &[ScheduleEntry],
    ) -> Result<HybridMatrix<T>, FormatError> {
        let hm = Self::assemble(csr, panel_rows, schedule)?;
        hm.validate()?;
        Ok(hm)
    }

    /// Fast path for schedules produced in-process by
    /// [`HybridMatrix::plan_schedule`] during the same build: skips
    /// the O(nnz) re-validation a deserialized schedule needs (debug
    /// builds still assert).
    pub(crate) fn from_schedule_trusted(
        csr: &Csr<T>,
        panel_rows: usize,
        schedule: &[ScheduleEntry],
    ) -> Result<HybridMatrix<T>, FormatError> {
        let hm = Self::assemble(csr, panel_rows, schedule)?;
        debug_assert!(hm.validate().is_ok(), "{:?}", hm.validate().err());
        Ok(hm)
    }

    fn assemble(
        csr: &Csr<T>,
        panel_rows: usize,
        schedule: &[ScheduleEntry],
    ) -> Result<HybridMatrix<T>, FormatError> {
        if panel_rows == 0 || panel_rows % 8 != 0 {
            return Err(FormatError::Inconsistent(format!(
                "panel_rows must be a positive multiple of 8, got \
                 {panel_rows}"
            )));
        }
        let rows = csr.rows;
        let n_panels = crate::util::ceil_div(rows, panel_rows);
        let mut segments: Vec<HybridSegment<T>> =
            Vec::with_capacity(schedule.len());
        let mut choices: Vec<PanelKernel> = Vec::with_capacity(n_panels);
        for entry in schedule {
            if entry.row_end <= entry.row_begin || entry.row_end > rows {
                return Err(FormatError::Inconsistent(format!(
                    "schedule entry rows {}..{} out of range",
                    entry.row_begin, entry.row_end
                )));
            }
            let sub = csr.row_slice(entry.row_begin, entry.row_end);
            let nnz = sub.nnz();
            let storage = match entry.kernel {
                PanelKernel::Beta(bs) => {
                    let mut bm = csr_to_block(&sub, bs)?;
                    if let Some(t) = entry.tune {
                        bm.tune = t;
                    }
                    SegmentStorage::Block(bm)
                }
                PanelKernel::Csr => SegmentStorage::Csr(sub),
            };
            // The per-panel choice is the kernel of the segment
            // covering it (identical to the phase-1 decisions:
            // segments are runs of equal-choice panels).
            while choices.len() * panel_rows < entry.row_end {
                choices.push(entry.kernel);
            }
            segments.push(HybridSegment {
                row_begin: entry.row_begin,
                row_end: entry.row_end,
                nnz,
                kernel: entry.kernel,
                storage,
            });
        }
        Ok(HybridMatrix {
            rows,
            cols: csr.cols,
            panel_rows,
            choices,
            segments,
        })
    }

    /// Total stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.segments.iter().map(|s| s.nnz).sum()
    }

    /// Number of compiled segments.
    pub fn n_segments(&self) -> usize {
        self.segments.len()
    }

    /// Distinct kernels in the schedule, in row order (deduped runs).
    pub fn kernels_used(&self) -> Vec<PanelKernel> {
        let mut out: Vec<PanelKernel> = Vec::new();
        for s in &self.segments {
            if out.last() != Some(&s.kernel) {
                out.push(s.kernel);
            }
        }
        out
    }

    /// Sequential `y += A·x`: a flat walk over the compiled segments.
    pub fn spmv(&self, x: &[T], y: &mut [T]) {
        assert_eq!(x.len(), self.cols, "x length mismatch");
        assert_eq!(y.len(), self.rows, "y length mismatch");
        for seg in &self.segments {
            seg.spmv(x, &mut y[seg.row_begin..seg.row_end]);
        }
    }

    /// Sequential multi-RHS `Y += A·X` (`x` row-major `[cols × k]`,
    /// `y` `[rows × k]`; see [`crate::kernels::spmm`]).
    pub fn spmm(&self, x: &[T], y: &mut [T], k: usize) {
        assert!(k > 0);
        assert_eq!(x.len(), self.cols * k, "x must be cols*k");
        assert_eq!(y.len(), self.rows * k, "y must be rows*k");
        for seg in &self.segments {
            seg.spmm(x, &mut y[seg.row_begin * k..seg.row_end * k], k);
        }
    }

    /// Checks every structural invariant of the compiled schedule:
    /// segments are ordered, contiguous, disjoint, start on panel
    /// boundaries and cover `0..rows` exactly once; per-segment
    /// storages are internally consistent and their nnz sum to the
    /// matrix total.
    pub fn validate(&self) -> Result<(), FormatError> {
        let fail = |msg: String| Err(FormatError::Inconsistent(msg));
        if self.panel_rows == 0 || self.panel_rows % 8 != 0 {
            return fail(format!("bad panel_rows {}", self.panel_rows));
        }
        let n_panels = crate::util::ceil_div(self.rows, self.panel_rows);
        if self.choices.len() != n_panels {
            return fail(format!(
                "choices length {} != panels {n_panels}",
                self.choices.len()
            ));
        }
        let mut expect_row = 0usize;
        for (i, s) in self.segments.iter().enumerate() {
            if s.row_begin != expect_row {
                return fail(format!(
                    "segment {i} begins at {} (expected {expect_row}) — \
                     rows covered more or less than once",
                    s.row_begin
                ));
            }
            if s.row_end <= s.row_begin || s.row_end > self.rows {
                return fail(format!("segment {i} has bad row range"));
            }
            if s.row_begin % self.panel_rows != 0 {
                return fail(format!(
                    "segment {i} does not start on a panel boundary"
                ));
            }
            let seg_rows = s.row_end - s.row_begin;
            match &s.storage {
                SegmentStorage::Block(bm) => {
                    if !matches!(s.kernel, PanelKernel::Beta(bs) if bs == bm.bs)
                    {
                        return fail(format!(
                            "segment {i} kernel/storage mismatch"
                        ));
                    }
                    if bm.rows != seg_rows || bm.cols != self.cols {
                        return fail(format!("segment {i} block dims wrong"));
                    }
                    if bm.nnz() != s.nnz {
                        return fail(format!("segment {i} nnz mismatch"));
                    }
                    bm.validate()?;
                }
                SegmentStorage::Csr(c) => {
                    if s.kernel != PanelKernel::Csr {
                        return fail(format!(
                            "segment {i} kernel/storage mismatch"
                        ));
                    }
                    if c.rows != seg_rows || c.cols != self.cols {
                        return fail(format!("segment {i} csr dims wrong"));
                    }
                    if c.nnz() != s.nnz {
                        return fail(format!("segment {i} nnz mismatch"));
                    }
                }
            }
            expect_row = s.row_end;
        }
        if expect_row != self.rows {
            return fail(format!(
                "segments cover rows 0..{expect_row}, matrix has {}",
                self.rows
            ));
        }
        Ok(())
    }
}

/// Picks the kernel for one panel. Candidates below the Eq.-4 storage
/// crossover are discarded; survivors and CSR are ranked on the fitted
/// GFlop/s surface when `models` covers CSR, otherwise on the analytic
/// bandwidth model (whose machine scale cancels out of the argmax).
fn choose_panel<T: Scalar>(
    sub: &Csr<T>,
    candidates: &[BlockSize],
    models: Option<&HashMap<KernelKind, PolyModel>>,
) -> PanelKernel {
    // Fitted predictions are only comparable to each other, so the
    // fitted path is taken as a whole or not at all: it needs a CSR
    // model to rank β choices against.
    let fitted = models.filter(|m| m.contains_key(&KernelKind::Csr));
    let analytic = MachineModel::default();

    let avg18 = block_stats(sub, BlockSize::new(1, 8)).avg_nnz_per_block;
    let csr_score = match fitted {
        Some(m) => m[&KernelKind::Csr].eval(avg18),
        None => predict(&analytic, KernelKind::Csr, avg18),
    };

    let mut best: Option<(BlockSize, f64)> = None;
    for &bs in candidates {
        let avg = block_stats(sub, bs).avg_nnz_per_block;
        if avg < fill_crossover(bs) {
            continue; // stores more bytes than CSR (paper Eq. 4)
        }
        let kind = KernelKind::Beta(bs.r as u8, bs.c as u8);
        let score = match fitted {
            Some(m) => match m.get(&kind) {
                Some(poly) => poly.eval(avg),
                None => continue, // no surface for this kernel
            },
            None => predict(&analytic, kind, avg),
        };
        if !score.is_finite() {
            continue;
        }
        let better = match best {
            None => true,
            Some((_, s)) => score > s,
        };
        if better {
            best = Some((bs, score));
        }
    }

    match best {
        Some((bs, score)) if !csr_score.is_finite() || score > csr_score => {
            PanelKernel::Beta(bs)
        }
        _ => PanelKernel::Csr,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::suite;

    fn cfg(panel_rows: usize) -> HybridConfig {
        HybridConfig { panel_rows, ..HybridConfig::for_scalar::<f64>() }
    }

    #[test]
    fn panel_rows_must_be_multiple_of_8() {
        let csr = suite::poisson2d(8);
        for bad in [0usize, 4, 7, 12] {
            assert!(
                HybridMatrix::from_csr(&csr, &cfg(bad), None).is_err(),
                "panel_rows {bad} accepted"
            );
        }
        HybridMatrix::from_csr(&csr, &cfg(8), None).unwrap();
    }

    #[test]
    fn schedule_is_contiguous_and_validates() {
        for sm in suite::test_subset().iter().take(6) {
            for panel in [8usize, 64, 512] {
                let hm =
                    HybridMatrix::from_csr(&sm.csr, &cfg(panel), None).unwrap();
                hm.validate().unwrap();
                assert_eq!(hm.nnz(), sm.csr.nnz(), "{} p={panel}", sm.name);
            }
        }
    }

    #[test]
    fn homogeneous_matrix_compiles_to_one_segment() {
        // A uniformly dense band: every panel should make the same
        // choice, and merging should collapse them into one segment.
        let csr = suite::banded(4_000, 16, 1.0, 3);
        let hm = HybridMatrix::from_csr(&csr, &cfg(256), None).unwrap();
        assert_eq!(hm.n_segments(), 1, "choices: {:?}", hm.kernels_used());
        assert!(matches!(hm.segments[0].kernel, PanelKernel::Beta(_)));
    }

    #[test]
    fn split_hint_subdivides_homogeneous_runs() {
        // With a split hint, the same homogeneous matrix must be cut
        // into nnz-balanced same-kernel segments for the worker pool.
        let csr = suite::banded(4_000, 16, 1.0, 3);
        let cfg4 = HybridConfig { split: 4, ..cfg(256) };
        let hm = HybridMatrix::from_csr(&csr, &cfg4, None).unwrap();
        hm.validate().unwrap();
        assert!(hm.n_segments() >= 3, "{} segments", hm.n_segments());
        assert_eq!(hm.kernels_used().len(), 1, "one kernel class expected");
        let max = hm.segments.iter().map(|s| s.nnz).max().unwrap();
        let min = hm.segments.iter().map(|s| s.nnz).min().unwrap();
        assert!(
            max <= min * 2 + csr.nnz() / 4,
            "segments unbalanced: min {min} max {max}"
        );
    }

    #[test]
    fn scatter_matrix_stays_csr() {
        // Avg fill ≈ 1: every β size is below its crossover.
        let csr = suite::uniform_scatter(3_000, 4, 5);
        let hm = HybridMatrix::from_csr(&csr, &cfg(256), None).unwrap();
        assert_eq!(hm.n_segments(), 1);
        assert_eq!(hm.segments[0].kernel, PanelKernel::Csr);
    }

    #[test]
    fn mixed_matrix_uses_both_kernel_classes() {
        let csr = suite::mixed_band_scatter(4_096, 9);
        let hm = HybridMatrix::from_csr(&csr, &cfg(256), None).unwrap();
        let used = hm.kernels_used();
        assert!(
            used.iter().any(|k| matches!(k, PanelKernel::Beta(_))),
            "no β segment: {used:?}"
        );
        assert!(
            used.contains(&PanelKernel::Csr),
            "no CSR segment: {used:?}"
        );
        // Merging must compress ~16 panels into a handful of segments.
        assert!(hm.n_segments() <= 4, "{} segments", hm.n_segments());
    }

    #[test]
    fn spmv_matches_reference() {
        for sm in suite::test_subset().iter().take(8) {
            let hm = HybridMatrix::from_csr(&sm.csr, &cfg(64), None).unwrap();
            let x: Vec<f64> = (0..sm.csr.cols)
                .map(|i| ((i * 13) % 17) as f64 - 8.0)
                .collect();
            let mut want = vec![0.0; sm.csr.rows];
            sm.csr.spmv_ref(&x, &mut want);
            let mut got = vec![0.0; sm.csr.rows];
            hm.spmv(&x, &mut got);
            crate::testkit::assert_close(&got, &want, 1e-9, sm.name);
        }
    }

    #[test]
    fn spmm_matches_k_spmvs() {
        let csr = suite::mixed_band_scatter(1_024, 2);
        let hm = HybridMatrix::from_csr(&csr, &cfg(64), None).unwrap();
        let k = 3usize;
        let x: Vec<f64> = (0..csr.cols * k)
            .map(|i| ((i * 7) % 19) as f64 * 0.1 - 0.9)
            .collect();
        let mut y = vec![0.0; csr.rows * k];
        hm.spmm(&x, &mut y, k);
        for j in 0..k {
            let xj: Vec<f64> = (0..csr.cols).map(|c| x[c * k + j]).collect();
            let mut want = vec![0.0; csr.rows];
            csr.spmv_ref(&xj, &mut want);
            for r in 0..csr.rows {
                assert!(
                    (y[r * k + j] - want[r]).abs()
                        <= 1e-9 * want[r].abs().max(1.0),
                    "j={j} row {r}"
                );
            }
        }
    }

    #[test]
    fn empty_matrix_compiles() {
        let csr =
            Csr::<f64>::from_raw(16, 16, vec![0; 17], vec![], vec![]).unwrap();
        let hm = HybridMatrix::from_csr(&csr, &cfg(8), None).unwrap();
        hm.validate().unwrap();
        let x = vec![1.0; 16];
        let mut y = vec![0.0; 16];
        hm.spmv(&x, &mut y);
        assert!(y.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn fitted_surface_drives_choice() {
        use crate::predictor::select::fit_sequential;
        use crate::predictor::{PerfRecord, RecordStore};
        // Records that make CSR dominate everything: the schedule must
        // be all-CSR even on a block-friendly matrix.
        let mut store = RecordStore::new();
        for i in 0..8 {
            let avg = 1.0 + i as f64;
            store.push(PerfRecord {
                matrix: format!("m{i}"),
                kernel: KernelKind::Csr,
                avg_nnz_per_block: avg,
                threads: 1,
                tile_cols: 0,
                tune: Default::default(),
                gflops: 50.0,
            });
            for bs in BlockSize::PAPER_SIZES {
                store.push(PerfRecord {
                    matrix: format!("m{i}"),
                    kernel: KernelKind::Beta(bs.r as u8, bs.c as u8),
                    avg_nnz_per_block: avg * (bs.bits() as f64 / 8.0),
                    threads: 1,
                    tile_cols: 0,
                    tune: Default::default(),
                    gflops: 0.1,
                });
            }
        }
        let kinds: Vec<KernelKind> = std::iter::once(KernelKind::Csr)
            .chain(
                BlockSize::PAPER_SIZES
                    .iter()
                    .map(|bs| KernelKind::Beta(bs.r as u8, bs.c as u8)),
            )
            .collect();
        let models = fit_sequential(&store, &kinds);
        let csr = suite::banded(2_048, 16, 1.0, 1);
        let hm =
            HybridMatrix::from_csr(&csr, &cfg(256), Some(&models)).unwrap();
        assert_eq!(hm.kernels_used(), vec![PanelKernel::Csr]);
    }

    #[test]
    fn f32_hybrid_uses_wide_candidates() {
        let csr32 = suite::banded(2_048, 16, 1.0, 4).to_precision::<f32>();
        let cfg32 = HybridConfig::for_scalar::<f32>();
        assert_eq!(cfg32.candidates, BlockSize::F32_WIDE_SIZES.to_vec());
        let hm = HybridMatrix::from_csr(&csr32, &cfg32, None).unwrap();
        hm.validate().unwrap();
        let x: Vec<f32> =
            (0..csr32.cols).map(|i| (i % 5) as f32 * 0.5 - 1.0).collect();
        let mut want = vec![0.0f32; csr32.rows];
        csr32.spmv_ref(&x, &mut want);
        let mut got = vec![0.0f32; csr32.rows];
        hm.spmv(&x, &mut got);
        for i in 0..csr32.rows {
            assert!(
                (got[i] - want[i]).abs() <= 2e-4 * want[i].abs().max(1.0),
                "row {i}"
            );
        }
    }
}
