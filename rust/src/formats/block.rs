//! The `β(r,c)` block matrix container (paper Fig. 2), generic over
//! the element precision.
//!
//! For `T = f64` this is the paper's format verbatim (one `u8` mask
//! per block row, 8 lanes). For `T = f32` the same layout widens to
//! `u16` masks and up to 16 columns — the "β32" variant the paper
//! mentions ("16 single precision values") but never ships.

use super::{BlockSize, FormatError};
use crate::kernels::avx512::TuneParams;
use crate::scalar::{MaskWord, Scalar};

/// Bytes used for the column index inside an interleaved block header.
pub const HEADER_COLIDX_BYTES: usize = 4;

/// A sparse matrix in the `β(r,c)` format.
///
/// Four arrays, exactly as the paper describes:
/// - `values`    — the nonzeros, block order, row-major inside a block,
///   **no zero padding**;
/// - `block_colidx` — leftmost column of each block;
/// - `block_rowptr` — CSR-style prefix: blocks of row interval `i` are
///   `block_rowptr[i]..block_rowptr[i+1]` (one interval = `r` rows);
/// - `block_masks`  — `r` mask words per block, word `i` holding the
///   c-bit mask of block row `i` (bit `k` set ⇔ value at column
///   `col0 + k`).
///
/// Additionally [`BlockMatrix::headers`] provides the interleaved
/// `colidx(4B) | masks(r · mask_bytes)` stream that the paper's
/// assembly kernels walk with a single pointer; the AVX-512 kernels in
/// [`crate::kernels::avx512`] consume that layout.
#[derive(Clone, Debug, PartialEq)]
pub struct BlockMatrix<T: Scalar = f64> {
    pub rows: usize,
    pub cols: usize,
    pub bs: BlockSize,
    pub values: Vec<T>,
    pub block_colidx: Vec<u32>,
    pub block_rowptr: Vec<u32>,
    pub block_masks: Vec<T::Mask>,
    /// Interleaved per-block header stream: for each block, 4 bytes of
    /// little-endian `colidx` followed by `r` little-endian mask words.
    pub headers: Vec<u8>,
    /// Kernel variant the SIMD span kernels run for this matrix —
    /// resolved once (at conversion or plan instantiation), read per
    /// span call, never per block.
    pub tune: TuneParams,
}

impl<T: Scalar> BlockMatrix<T> {
    /// Number of row intervals (`ceil(rows / r)`).
    #[inline]
    pub fn intervals(&self) -> usize {
        crate::util::ceil_div(self.rows, self.bs.r)
    }

    /// Number of blocks.
    #[inline]
    pub fn n_blocks(&self) -> usize {
        self.block_colidx.len()
    }

    /// Stored nonzeros.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Bytes per interleaved header entry.
    #[inline]
    pub fn header_stride(&self) -> usize {
        HEADER_COLIDX_BYTES + <T::Mask as MaskWord>::BYTES * self.bs.r
    }

    /// Average nonzeros per block — the paper's `Avg(r,c)` metric that
    /// drives both the occupancy model and the kernel predictor.
    pub fn avg_nnz_per_block(&self) -> f64 {
        if self.n_blocks() == 0 {
            0.0
        } else {
            self.nnz() as f64 / self.n_blocks() as f64
        }
    }

    /// Block fill fraction in `[0, 1]` (Table 1 parenthesized column).
    pub fn fill_fraction(&self) -> f64 {
        if self.n_blocks() == 0 {
            0.0
        } else {
            self.avg_nnz_per_block() / self.bs.bits() as f64
        }
    }

    /// Validates every structural invariant of the format. Used by
    /// tests and by debug assertions in the conversion path.
    pub fn validate(&self) -> Result<(), FormatError> {
        self.bs.validate_for::<T>()?;
        let nb = self.n_blocks();
        let intervals = self.intervals();
        let fail = |msg: String| Err(FormatError::Inconsistent(msg));

        if self.block_rowptr.len() != intervals + 1 {
            return fail(format!(
                "block_rowptr length {} != intervals+1 ({})",
                self.block_rowptr.len(),
                intervals + 1
            ));
        }
        if self.block_rowptr[0] != 0
            || self.block_rowptr[intervals] as usize != nb
        {
            return fail("block_rowptr does not span [0, n_blocks]".into());
        }
        if self.block_masks.len() != nb * self.bs.r {
            return fail(format!(
                "block_masks length {} != n_blocks*r ({})",
                self.block_masks.len(),
                nb * self.bs.r
            ));
        }
        if self.headers.len() != nb * self.header_stride() {
            return fail("headers length mismatch".into());
        }

        // Masks: bits beyond c must be clear; popcounts must sum to nnz;
        // every block must be non-empty.
        let mut pop_total = 0usize;
        for b in 0..nb {
            let mut block_pop = 0u32;
            for i in 0..self.bs.r {
                let m = self.block_masks[b * self.bs.r + i];
                if m.any_above(self.bs.c) {
                    return fail(format!("mask bits beyond c in block {b}"));
                }
                block_pop += m.count_ones();
            }
            if block_pop == 0 {
                return fail(format!("empty block {b}"));
            }
            pop_total += block_pop as usize;
        }
        if pop_total != self.nnz() {
            return fail(format!(
                "mask popcount sum {pop_total} != nnz {}",
                self.nnz()
            ));
        }

        // Per interval: blocks must be in strictly ascending, non-overlapping
        // column order and inside the matrix.
        for it in 0..intervals {
            let (a, b) =
                (self.block_rowptr[it] as usize, self.block_rowptr[it + 1] as usize);
            if b < a {
                return fail(format!("block_rowptr not monotone at {it}"));
            }
            let mut prev_end: i64 = -1;
            for k in a..b {
                let col = self.block_colidx[k] as i64;
                if col <= prev_end {
                    return fail(format!(
                        "blocks overlap or out of order in interval {it}"
                    ));
                }
                if col as usize + 1 > self.cols {
                    return fail(format!("block col out of range in {it}"));
                }
                prev_end = col + self.bs.c as i64 - 1;
            }
        }

        // Headers must mirror (colidx, masks).
        let stride = self.header_stride();
        let mb = <T::Mask as MaskWord>::BYTES;
        for b in 0..nb {
            let h = &self.headers[b * stride..(b + 1) * stride];
            let col = u32::from_le_bytes([h[0], h[1], h[2], h[3]]);
            if col != self.block_colidx[b] {
                return fail(format!("header colidx mismatch at block {b}"));
            }
            for i in 0..self.bs.r {
                let m = <T::Mask as MaskWord>::read_le(&h[HEADER_COLIDX_BYTES + mb * i..]);
                if m != self.block_masks[b * self.bs.r + i] {
                    return fail(format!("header mask mismatch at block {b}"));
                }
            }
        }
        Ok(())
    }

    /// Rebuilds the interleaved header stream from `block_colidx` +
    /// `block_masks`.
    pub fn rebuild_headers(&mut self) {
        let stride = self.header_stride();
        let nb = self.n_blocks();
        let mut headers = Vec::with_capacity(nb * stride);
        for b in 0..nb {
            headers.extend_from_slice(&self.block_colidx[b].to_le_bytes());
            for i in 0..self.bs.r {
                self.block_masks[b * self.bs.r + i].push_le(&mut headers);
            }
        }
        self.headers = headers;
    }

    /// Total bytes of the four storage arrays (measured occupancy; the
    /// analytical model is in [`super::occupancy`]). The interleaved
    /// header stream duplicates colidx+masks, so it is *not* counted —
    /// a deployment keeps either the split arrays or the headers.
    pub fn occupancy_bytes(&self) -> usize {
        self.values.len() * T::BYTES
            + self.block_colidx.len() * 4
            + self.block_rowptr.len() * 4
            + self.block_masks.len() * <T::Mask as MaskWord>::BYTES
    }
}

#[cfg(test)]
mod tests {
    use super::super::csr_to_block;
    use super::*;
    use crate::matrix::Csr;

    /// The paper's Fig. 1 matrix.
    fn fig1() -> Csr {
        let rowptr = vec![0, 4, 7, 10, 12, 14, 14, 15, 18];
        let colidx = vec![0, 1, 4, 6, 1, 2, 3, 2, 4, 6, 3, 4, 5, 6, 5, 0, 4, 7];
        let values: Vec<f64> = (1..=18).map(|v| v as f64).collect();
        Csr::from_raw(8, 8, rowptr, colidx, values).unwrap()
    }

    #[test]
    fn fig2a_beta_1_4() {
        // Paper Fig. 2A: β(1,4) of the Fig. 1 matrix.
        let b = csr_to_block(&fig1(), BlockSize::new(1, 4)).unwrap();
        b.validate().unwrap();
        // Row 0: cols {0,1,4,6} → blocks at 0 (mask 0011) and 4 (mask 0101).
        assert_eq!(b.block_rowptr[0], 0);
        assert_eq!(b.block_colidx[0], 0);
        assert_eq!(b.block_masks[0], 0b0011);
        assert_eq!(b.block_colidx[1], 4);
        assert_eq!(b.block_masks[1], 0b0101);
        // Values unchanged vs CSR (r = 1).
        assert_eq!(b.values, fig1().values);
    }

    #[test]
    fn fig2b_beta_2_2() {
        // Paper Fig. 2B: β(2,2).
        let b = csr_to_block(&fig1(), BlockSize::new(2, 2)).unwrap();
        b.validate().unwrap();
        // Interval 0 = rows 0,1: cols row0={0,1,4,6}, row1={1,2,3}.
        assert_eq!(b.block_colidx[0], 0);
        // mask byte per block row: row0 of block@0 = {0,1} → 0b11,
        // row1 = {1} → 0b10.
        assert_eq!(b.block_masks[0], 0b11);
        assert_eq!(b.block_masks[1], 0b10);
        assert_eq!(b.nnz(), 18);
    }

    #[test]
    fn headers_mirror_arrays() {
        let b = csr_to_block(&fig1(), BlockSize::new(2, 4)).unwrap();
        let stride = b.header_stride();
        assert_eq!(stride, 6);
        for blk in 0..b.n_blocks() {
            let h = &b.headers[blk * stride..(blk + 1) * stride];
            assert_eq!(
                u32::from_le_bytes([h[0], h[1], h[2], h[3]]),
                b.block_colidx[blk]
            );
        }
    }

    #[test]
    fn f32_headers_use_two_byte_masks() {
        let csr32: Csr<f32> = fig1().to_precision();
        let b = csr_to_block(&csr32, BlockSize::new(2, 16)).unwrap();
        b.validate().unwrap();
        // 4 colidx bytes + 2 rows × 2 mask bytes.
        assert_eq!(b.header_stride(), 8);
        assert_eq!(b.nnz(), 18);
        // f32 values + u16 masks store fewer bytes than the f64 format.
        let b64 = csr_to_block(&fig1(), BlockSize::new(2, 8)).unwrap();
        assert!(b.occupancy_bytes() < b64.occupancy_bytes());
    }

    #[test]
    fn validate_catches_corruption() {
        let good = csr_to_block(&fig1(), BlockSize::new(1, 8)).unwrap();

        let mut bad = good.clone();
        bad.block_masks[0] = 0; // empty block
        assert!(bad.validate().is_err());

        let mut bad = good.clone();
        bad.values.pop(); // popcount sum != nnz
        assert!(bad.validate().is_err());

        let mut bad = good.clone();
        bad.block_rowptr[1] = 100;
        assert!(bad.validate().is_err());

        let mut bad = good.clone();
        bad.headers[0] ^= 0xFF; // header desync
        assert!(bad.validate().is_err());

        // Out-of-order blocks *within one interval*: β(1,4) gives row 0
        // two blocks (cols 0 and 4) — swapping them must be rejected.
        let mut bad = csr_to_block(&fig1(), BlockSize::new(1, 4)).unwrap();
        assert!(bad.block_rowptr[1] >= 2, "row 0 should have 2 blocks");
        bad.block_colidx.swap(0, 1);
        bad.rebuild_headers();
        assert!(bad.validate().is_err());
    }

    #[test]
    fn mask_bits_beyond_c_detected() {
        let mut b = csr_to_block(&fig1(), BlockSize::new(1, 4)).unwrap();
        b.block_masks[0] |= 0b1_0000; // bit 4 invalid for c=4
        b.rebuild_headers();
        assert!(b.validate().is_err());

        let csr32: Csr<f32> = fig1().to_precision();
        let mut b = csr_to_block(&csr32, BlockSize::new(1, 12)).unwrap();
        b.block_masks[0] |= 1 << 12; // bit 12 invalid for c=12
        b.rebuild_headers();
        assert!(b.validate().is_err());
    }

    #[test]
    fn fill_and_avg() {
        let b = csr_to_block(&fig1(), BlockSize::new(1, 8)).unwrap();
        let avg = b.avg_nnz_per_block();
        assert!(avg > 1.0 && avg <= 8.0);
        assert!((b.fill_fraction() - avg / 8.0).abs() < 1e-12);
    }
}
