//! Performance prediction and optimal kernel selection
//! (paper §"Performance prediction and optimal kernel selection").
//!
//! - [`records`] — the persistent store of `(kernel, matrix, Avg(r,c),
//!   threads, GFlop/s)` measurements from previous executions.
//! - [`polyfit`] — least-squares polynomial interpolation of
//!   `gflops ~ Avg(r,c)` per kernel (sequential selection, Fig. 5).
//! - [`regression2d`] — the nonlinear 2D regression
//!   `gflops ~ f(Avg(r,c), threads)` per kernel (parallel selection,
//!   Fig. 6).
//! - [`select`] — ties it together: compute the cheap `Avg(r,c)` scan
//!   for every candidate block size (no conversion needed), evaluate
//!   the fitted model, pick the argmax (Table 3).

pub mod model;
pub mod polyfit;
pub mod records;
pub mod regression2d;
pub mod select;

pub use polyfit::PolyModel;
pub use records::{PerfRecord, RecordStore};
pub use regression2d::Reg2dModel;
pub use select::{select_parallel, select_sequential, Selection};
