//! Least-squares polynomial fit (sequential prediction, paper Fig. 5).
//!
//! Fits `gflops ≈ Σ w_k · avg^k` per kernel with normal equations
//! solved by Gaussian elimination with partial pivoting. Degree 3 by
//! default (the paper's interpolation curves are low-order).

/// A fitted polynomial model `y(x) = Σ coeffs[k]·x^k`.
#[derive(Clone, Debug, PartialEq)]
pub struct PolyModel {
    pub coeffs: Vec<f64>,
}

impl PolyModel {
    /// Fits a degree-`deg` polynomial to `(x, y)` samples by least
    /// squares. Returns `None` when there are no samples. With fewer
    /// samples than coefficients the degree is reduced automatically.
    pub fn fit(xs: &[f64], ys: &[f64], deg: usize) -> Option<PolyModel> {
        assert_eq!(xs.len(), ys.len());
        if xs.is_empty() {
            return None;
        }
        let deg = deg.min(xs.len() - 1);
        let n = deg + 1;
        // Normal equations: (VᵀV) w = Vᵀy with V the Vandermonde matrix.
        let mut ata = vec![0.0f64; n * n];
        let mut aty = vec![0.0f64; n];
        for (&x, &y) in xs.iter().zip(ys) {
            let mut powers = Vec::with_capacity(n);
            let mut p = 1.0;
            for _ in 0..n {
                powers.push(p);
                p *= x;
            }
            for i in 0..n {
                aty[i] += powers[i] * y;
                for j in 0..n {
                    ata[i * n + j] += powers[i] * powers[j];
                }
            }
        }
        let coeffs = solve(&mut ata, &mut aty, n)?;
        Some(PolyModel { coeffs })
    }

    /// Evaluates the polynomial at `x`.
    pub fn eval(&self, x: f64) -> f64 {
        // Horner.
        self.coeffs.iter().rev().fold(0.0, |acc, &c| acc * x + c)
    }

    /// Root-mean-square error on a sample set.
    pub fn rmse(&self, xs: &[f64], ys: &[f64]) -> f64 {
        if xs.is_empty() {
            return 0.0;
        }
        let se: f64 = xs
            .iter()
            .zip(ys)
            .map(|(&x, &y)| (self.eval(x) - y).powi(2))
            .sum();
        (se / xs.len() as f64).sqrt()
    }
}

/// Solves `A w = b` in place (n×n, row-major) with partial pivoting.
/// Returns `None` for singular systems.
pub(crate) fn solve(a: &mut [f64], b: &mut [f64], n: usize) -> Option<Vec<f64>> {
    for col in 0..n {
        // Pivot.
        let mut piv = col;
        for r in col + 1..n {
            if a[r * n + col].abs() > a[piv * n + col].abs() {
                piv = r;
            }
        }
        if a[piv * n + col].abs() < 1e-12 {
            return None;
        }
        if piv != col {
            for k in 0..n {
                a.swap(col * n + k, piv * n + k);
            }
            b.swap(col, piv);
        }
        // Eliminate below.
        let d = a[col * n + col];
        for r in col + 1..n {
            let f = a[r * n + col] / d;
            if f == 0.0 {
                continue;
            }
            for k in col..n {
                a[r * n + k] -= f * a[col * n + k];
            }
            b[r] -= f * b[col];
        }
    }
    // Back substitution.
    let mut w = vec![0.0f64; n];
    for col in (0..n).rev() {
        let mut s = b[col];
        for k in col + 1..n {
            s -= a[col * n + k] * w[k];
        }
        w[col] = s / a[col * n + col];
    }
    Some(w)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_on_polynomial_data() {
        // y = 2 - x + 0.5x²
        let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|&x| 2.0 - x + 0.5 * x * x).collect();
        let m = PolyModel::fit(&xs, &ys, 2).unwrap();
        assert!((m.coeffs[0] - 2.0).abs() < 1e-8);
        assert!((m.coeffs[1] + 1.0).abs() < 1e-8);
        assert!((m.coeffs[2] - 0.5).abs() < 1e-8);
        assert!(m.rmse(&xs, &ys) < 1e-8);
    }

    #[test]
    fn degree_reduced_for_few_samples() {
        let m = PolyModel::fit(&[1.0, 2.0], &[3.0, 5.0], 5).unwrap();
        assert_eq!(m.coeffs.len(), 2); // linear
        assert!((m.eval(1.5) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn empty_input_is_none() {
        assert!(PolyModel::fit(&[], &[], 3).is_none());
    }

    #[test]
    fn noisy_fit_reasonable() {
        // y = 1 + 0.3x with deterministic "noise".
        let xs: Vec<f64> = (0..50).map(|i| i as f64 * 0.2).collect();
        let ys: Vec<f64> = xs
            .iter()
            .enumerate()
            .map(|(i, &x)| 1.0 + 0.3 * x + if i % 2 == 0 { 0.05 } else { -0.05 })
            .collect();
        let m = PolyModel::fit(&xs, &ys, 1).unwrap();
        assert!((m.coeffs[1] - 0.3).abs() < 0.02);
        assert!(m.rmse(&xs, &ys) < 0.06);
    }

    #[test]
    fn solve_identity() {
        let mut a = vec![1.0, 0.0, 0.0, 1.0];
        let mut b = vec![7.0, -2.0];
        let w = solve(&mut a, &mut b, 2).unwrap();
        assert_eq!(w, vec![7.0, -2.0]);
    }

    #[test]
    fn solve_singular_none() {
        let mut a = vec![1.0, 2.0, 2.0, 4.0];
        let mut b = vec![1.0, 2.0];
        assert!(solve(&mut a, &mut b, 2).is_none());
    }

    #[test]
    fn horner_matches_naive() {
        let m = PolyModel { coeffs: vec![1.0, -2.0, 0.25, 3.0] };
        for x in [-2.0f64, 0.0, 0.7, 5.0] {
            let naive: f64 = m
                .coeffs
                .iter()
                .enumerate()
                .map(|(k, &c)| c * x.powi(k as i32))
                .sum();
            assert!((m.eval(x) - naive).abs() < 1e-10);
        }
    }
}
