//! Analytic performance model — a *no-training* baseline for the
//! record-based predictor (paper §Conclusions: "more sophisticated
//! best kernel prediction methods with multiple inputs, such as
//! statistics on the blocks and some hardware properties, the cache
//! size, the memory bandwidth" — this is the memory-bandwidth member
//! of that family).
//!
//! SpMV is bandwidth-bound; the model predicts
//! `gflops = 2 · BW_eff / bytes_per_nnz`, where `bytes_per_nnz` is the
//! exact stream traffic of a `β(r,c)` kernel:
//!
//! - 8 B for the value itself (read once, unpadded — the format's
//!   whole point),
//! - `(4 + r) / avg` B of header (colidx + r mask bytes, amortized
//!   over the block's `avg` values),
//! - `8·c·u / avg` B of `x` window, with `u` the *useful-lane* factor
//!   (masked loads touch only set lanes; we charge the union width),
//! - rowptr and `y` traffic, amortized per row.
//!
//! `BW_eff` is calibrated once per machine from a single measured CSR
//! run ([`calibrate`]); the comparison bench (`kernel_micro` ablation
//! D) evaluates model-selection vs record-selection quality.

use crate::formats::stats::block_stats;
use crate::formats::BlockSize;
use crate::kernels::KernelKind;
use crate::matrix::Csr;

/// Calibrated machine parameters.
#[derive(Clone, Copy, Debug)]
pub struct MachineModel {
    /// Effective stream bandwidth in bytes/s seen by the CSR kernel.
    pub bw_eff: f64,
    /// Fixed per-block overhead in seconds (pipeline + reduce costs),
    /// folded into an equivalent byte count per block.
    pub block_overhead_bytes: f64,
}

impl Default for MachineModel {
    fn default() -> Self {
        // Conservative single-core numbers; `calibrate` replaces them.
        MachineModel { bw_eff: 12e9, block_overhead_bytes: 24.0 }
    }
}

/// Calibrates `bw_eff` from one measured CSR SpMV (GFlop/s on a matrix
/// large enough to stream from memory).
pub fn calibrate(csr_gflops: f64) -> MachineModel {
    // CSR traffic: 12 B per nnz (8 value + 4 colidx) + x gather ≈ 8 B.
    let bytes_per_nnz = 12.0 + 8.0;
    MachineModel {
        bw_eff: csr_gflops * 1e9 / 2.0 * bytes_per_nnz,
        block_overhead_bytes: 24.0,
    }
}

/// Predicted traffic per nonzero for a β kernel at a given `Avg(r,c)`.
pub fn bytes_per_nnz(bs: BlockSize, avg: f64) -> f64 {
    let avg = avg.max(1.0);
    let header = (4.0 + bs.r as f64) / avg;
    // The union x window: masked lanes cost nothing on skipped cache
    // lines only when whole lines are masked; charge the full window
    // scaled by a 0.75 locality discount (neighbouring blocks share
    // lines of x).
    let x_traffic = 8.0 * bs.c as f64 * 0.75 / avg;
    8.0 + header + x_traffic
}

/// Predicted GFlop/s for a kernel on a matrix profile.
pub fn predict(m: &MachineModel, kind: KernelKind, avg: f64) -> f64 {
    match kind {
        KernelKind::Csr => 2.0 * m.bw_eff / (12.0 + 8.0) / 1e9,
        KernelKind::Csr5 => 2.0 * m.bw_eff / (12.0 + 8.0) / 1e9 * 0.9,
        // The hybrid schedule picks at least CSR per panel, so CSR's
        // prediction is its safe lower bound (the panel compiler does
        // its own per-panel ranking — see `formats::hybrid`). The tiled
        // schedule executes the same choices cache-blocked: the
        // bandwidth model carries no cache term, so it shares the
        // bound (fitted records are what distinguish tiled from flat).
        KernelKind::Hybrid | KernelKind::Tiled(_) => {
            2.0 * m.bw_eff / (12.0 + 8.0) / 1e9
        }
        KernelKind::Beta(..) | KernelKind::BetaTest(..) => {
            let bs = kind.block_size().unwrap();
            let mut bytes =
                bytes_per_nnz(bs, avg) + m.block_overhead_bytes / avg.max(1.0);
            // The Algorithm-2 test variant skips the vector machinery on
            // mask==1 blocks: model as a discount that grows as avg→1.
            if matches!(kind, KernelKind::BetaTest(..)) {
                let single_fraction = (2.0 - avg).clamp(0.0, 1.0);
                bytes -= single_fraction * (8.0 * bs.c as f64 * 0.75 - 8.0) / avg.max(1.0);
            }
            2.0 * m.bw_eff / bytes / 1e9
        }
    }
}

/// Model-based selection: argmax of [`predict`] over candidates, using
/// the cheap block-count scan (no conversion) — same contract as
/// [`super::select_sequential`] but requiring zero training records.
pub fn select_by_model(
    csr: &Csr,
    m: &MachineModel,
    kinds: &[KernelKind],
) -> (KernelKind, f64) {
    let mut best = (kinds[0], f64::MIN);
    for &k in kinds {
        let bs = k.block_size().unwrap_or(BlockSize::new(1, 8));
        let avg = block_stats(csr, bs).avg_nnz_per_block;
        let p = predict(m, k, avg);
        if p > best.1 {
            best = (k, p);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::suite;

    #[test]
    fn traffic_decreases_with_fill() {
        let bs = BlockSize::new(4, 8);
        assert!(bytes_per_nnz(bs, 1.0) > bytes_per_nnz(bs, 8.0));
        assert!(bytes_per_nnz(bs, 8.0) > bytes_per_nnz(bs, 32.0));
        // Asymptote: value bytes only.
        assert!(bytes_per_nnz(bs, 1e9) - 8.0 < 1e-6);
    }

    #[test]
    fn beta_beats_csr_when_filled() {
        let m = MachineModel::default();
        let high = predict(&m, KernelKind::Beta(4, 8), 24.0);
        let csr = predict(&m, KernelKind::Csr, 1.0);
        assert!(high > csr, "filled blocks must beat CSR in the model");
    }

    #[test]
    fn csr_beats_empty_blocks() {
        let m = MachineModel::default();
        let low = predict(&m, KernelKind::Beta(4, 8), 1.0);
        let csr = predict(&m, KernelKind::Csr, 1.0);
        assert!(csr > low, "empty blocks must lose to CSR in the model");
    }

    #[test]
    fn test_variant_wins_at_avg_one() {
        let m = MachineModel::default();
        let plain = predict(&m, KernelKind::Beta(1, 8), 1.05);
        let test = predict(&m, KernelKind::BetaTest(1, 8), 1.05);
        assert!(test > plain);
        // ...but not at high fill.
        let plain_hi = predict(&m, KernelKind::Beta(1, 8), 6.0);
        let test_hi = predict(&m, KernelKind::BetaTest(1, 8), 6.0);
        assert!((test_hi - plain_hi).abs() < 1e-9);
    }

    #[test]
    fn model_selection_sane_on_suite() {
        let m = calibrate(1.3);
        let kinds = KernelKind::SPC5_KERNELS;
        // Dense: must select a tall block (r ≥ 4 amortizes the header
        // and x traffic best); scatter: a test variant.
        let (k_dense, _) = select_by_model(&suite::dense(64, 1), &m, &kinds);
        assert!(
            matches!(k_dense, KernelKind::Beta(r, _) if r >= 4),
            "{k_dense}"
        );
        let (k_scatter, _) =
            select_by_model(&suite::uniform_scatter(500, 5, 2), &m, &kinds);
        assert!(
            matches!(k_scatter, KernelKind::BetaTest(..)),
            "{k_scatter}"
        );
    }

    #[test]
    fn calibrate_roundtrip() {
        let m = calibrate(1.5);
        let csr_pred = predict(&m, KernelKind::Csr, 1.0);
        assert!((csr_pred - 1.5).abs() < 1e-9);
    }
}
