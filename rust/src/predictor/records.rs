//! Performance-record store.
//!
//! The paper's prediction system is "record-based": the models are
//! fitted on measurements of previous executions (Set-A). Records are
//! persisted as JSON so the CLI's `bench` runs feed later `predict`
//! invocations.

use crate::kernels::{KernelKind, TuneParams};
use crate::util::durable::{self, RawState, StateError, StateErrorKind};
use crate::util::json::Json;
use std::path::Path;

/// One measurement.
#[derive(Clone, Debug, PartialEq)]
pub struct PerfRecord {
    pub matrix: String,
    pub kernel: KernelKind,
    /// `Avg(r,c)` of the kernel's block size on this matrix (for CSR /
    /// CSR5 the paper's plots use the β(1,8) average; we store whatever
    /// the producer computed).
    pub avg_nnz_per_block: f64,
    pub threads: usize,
    /// Column tile width the measurement ran with (`0` = flat /
    /// untiled execution). Together with the `tiled(n)` kernel
    /// spelling this lets the fitted surfaces rank tiled vs. flat
    /// schedules per matrix.
    pub tile_cols: usize,
    /// Kernel variant the measurement ran with (baseline for the CSR /
    /// CSR5 comparators, which take no tuning). Pre-autotuner stores
    /// have no tuning keys and load as the baseline variant.
    pub tune: TuneParams,
    pub gflops: f64,
}

/// A set of records with JSON persistence.
#[derive(Clone, Debug, Default)]
pub struct RecordStore {
    pub records: Vec<PerfRecord>,
}

impl RecordStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Adds a measurement, deduplicating by
    /// `(matrix, kernel, threads, tile_cols, tune)`: a re-measurement
    /// of the same configuration replaces the old record (latest
    /// wins), so a store fed by repeated bench runs stays bounded
    /// instead of growing without limit — and the fitted surfaces see
    /// current hardware behavior, not a mixture of stale and fresh
    /// samples. Distinct kernel variants are distinct configurations:
    /// the tuner's per-variant sweeps coexist in one store.
    pub fn push(&mut self, r: PerfRecord) {
        let key = self.records.iter().position(|p| {
            p.matrix == r.matrix
                && p.kernel == r.kernel
                && p.threads == r.threads
                && p.tile_cols == r.tile_cols
                && p.tune == r.tune
        });
        match key {
            Some(i) => self.records[i] = r,
            None => self.records.push(r),
        }
    }

    /// All records of one kernel at a given thread count.
    pub fn for_kernel(
        &self,
        kernel: KernelKind,
        threads: usize,
    ) -> Vec<&PerfRecord> {
        self.records
            .iter()
            .filter(|r| r.kernel == kernel && r.threads == threads)
            .collect()
    }

    /// All records of one kernel across thread counts.
    pub fn for_kernel_all_threads(&self, kernel: KernelKind) -> Vec<&PerfRecord> {
        self.records.iter().filter(|r| r.kernel == kernel).collect()
    }

    /// Serializes to JSON text.
    pub fn to_json(&self) -> String {
        let arr: Vec<Json> = self
            .records
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("matrix", Json::Str(r.matrix.clone())),
                    ("kernel", Json::Str(r.kernel.to_string())),
                    ("avg", Json::Num(r.avg_nnz_per_block)),
                    ("threads", Json::Num(r.threads as f64)),
                    ("tile", Json::Num(r.tile_cols as f64)),
                    ("hpd", Json::Num(r.tune.header_prefetch_dist as f64)),
                    ("vpd", Json::Num(r.tune.value_prefetch_dist as f64)),
                    ("pfx", Json::Bool(r.tune.prefetch_x)),
                    ("unroll", Json::Num(r.tune.unroll as f64)),
                    ("gflops", Json::Num(r.gflops)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("version", Json::Num(1.0)),
            ("records", Json::Arr(arr)),
        ])
        .to_string()
    }

    /// Parses from JSON text.
    pub fn from_json(text: &str) -> anyhow::Result<Self> {
        let v = Json::parse(text)?;
        let mut store = RecordStore::new();
        let arr = v
            .get("records")
            .and_then(|r| r.as_arr())
            .ok_or_else(|| anyhow::anyhow!("missing 'records' array"))?;
        for (i, item) in arr.iter().enumerate() {
            let field = |k: &str| {
                item.get(k)
                    .ok_or_else(|| anyhow::anyhow!("record {i}: missing {k}"))
            };
            let kernel_s = field("kernel")?
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("record {i}: kernel not str"))?;
            let kernel = KernelKind::parse(kernel_s)
                .ok_or_else(|| anyhow::anyhow!("record {i}: bad kernel"))?;
            let num = |k: &str| -> anyhow::Result<f64> {
                field(k)?
                    .as_f64()
                    .ok_or_else(|| anyhow::anyhow!("record {i}: {k} not num"))
            };
            // `tile` is absent in pre-tiling stores: default to flat.
            let tile_cols = item
                .get("tile")
                .and_then(|t| t.as_f64())
                .unwrap_or(0.0) as usize;
            // Tuning keys are absent in pre-autotuner stores: default
            // to the baseline variant (what those runs measured).
            let base = TuneParams::BASELINE;
            let tune = TuneParams {
                header_prefetch_dist: item
                    .get("hpd")
                    .and_then(|t| t.as_f64())
                    .unwrap_or(base.header_prefetch_dist as f64)
                    as u8,
                value_prefetch_dist: item
                    .get("vpd")
                    .and_then(|t| t.as_f64())
                    .unwrap_or(base.value_prefetch_dist as f64)
                    as u8,
                prefetch_x: item
                    .get("pfx")
                    .and_then(|t| t.as_bool())
                    .unwrap_or(base.prefetch_x),
                unroll: item
                    .get("unroll")
                    .and_then(|t| t.as_f64())
                    .unwrap_or(base.unroll as f64) as u8,
            };
            store.push(PerfRecord {
                matrix: field("matrix")?
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("record {i}: matrix"))?
                    .to_string(),
                kernel,
                avg_nnz_per_block: num("avg")?,
                threads: num("threads")? as usize,
                tile_cols,
                tune,
                gflops: num("gflops")?,
            });
        }
        Ok(store)
    }

    /// Artifact label used in [`StateError`] and degradation events.
    pub const ARTIFACT: &'static str = "record-store";

    /// Saves to a file, envelope-framed and atomically (see
    /// [`crate::util::durable`]).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<(), StateError> {
        durable::save_state(Self::ARTIFACT, path.as_ref(), &self.to_json())
    }

    /// Loads from a file. A missing file is an error (callers that
    /// want missing-as-fresh check first); an empty or
    /// whitespace-only file is a fresh store with a warning; a
    /// corrupt file is quarantined and reported as a typed
    /// [`StateError`] — callers degrade to the analytic model.
    /// Legacy (pre-envelope) files load unverified.
    pub fn load(path: impl AsRef<Path>) -> Result<Self, StateError> {
        let path = path.as_ref();
        match durable::read_state(Self::ARTIFACT, path)? {
            RawState::Missing => Err(StateError {
                artifact: Self::ARTIFACT,
                path: path.to_path_buf(),
                kind: StateErrorKind::Io(std::io::Error::new(
                    std::io::ErrorKind::NotFound,
                    "no such file",
                )),
                quarantined_to: None,
            }),
            RawState::Empty => {
                eprintln!(
                    "spc5: record store {} is empty; starting fresh",
                    path.display()
                );
                Ok(RecordStore::new())
            }
            RawState::Payload { text, .. } => Self::from_json(&text)
                .map_err(|e| {
                    durable::quarantined(
                        Self::ARTIFACT,
                        path,
                        StateErrorKind::Malformed(e.to_string()),
                    )
                }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> RecordStore {
        let mut s = RecordStore::new();
        for (m, k, a, t, tile, g) in [
            ("m1", KernelKind::Beta(1, 8), 2.4, 1, 0, 3.0),
            ("m1", KernelKind::Beta(4, 4), 6.6, 1, 0, 3.02),
            ("m2", KernelKind::Csr, 1.0, 4, 0, 1.2),
            ("m2", KernelKind::BetaTest(2, 4), 1.9, 4, 0, 2.2),
            ("m2", KernelKind::Tiled(4096), 1.9, 1, 4096, 2.8),
        ] {
            s.push(PerfRecord {
                matrix: m.to_string(),
                kernel: k,
                avg_nnz_per_block: a,
                threads: t,
                tile_cols: tile,
                tune: TuneParams::default(),
                gflops: g,
            });
        }
        // One tuned record: the variant fields must round-trip too.
        s.push(PerfRecord {
            matrix: "m1".to_string(),
            kernel: KernelKind::Beta(1, 8),
            avg_nnz_per_block: 2.4,
            threads: 1,
            tile_cols: 0,
            tune: crate::kernels::VARIANT_TABLE[3],
            gflops: 3.4,
        });
        s
    }

    #[test]
    fn json_roundtrip() {
        let s = sample();
        let text = s.to_json();
        let back = RecordStore::from_json(&text).unwrap();
        assert_eq!(s.records, back.records);
    }

    #[test]
    fn file_roundtrip() {
        let s = sample();
        let dir = std::env::temp_dir().join("spc5_test_records");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("r.json");
        s.save(&path).unwrap();
        let back = RecordStore::load(&path).unwrap();
        assert_eq!(s.records, back.records);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn filters() {
        let s = sample();
        assert_eq!(s.for_kernel(KernelKind::Beta(1, 8), 1).len(), 1);
        assert_eq!(s.for_kernel(KernelKind::Beta(1, 8), 4).len(), 0);
        assert_eq!(
            s.for_kernel_all_threads(KernelKind::BetaTest(2, 4)).len(),
            1
        );
    }

    #[test]
    fn tile_field_defaults_to_flat_on_old_stores() {
        // Pre-tiling stores have no "tile" key: records must load with
        // tile_cols = 0, and tiled kernel spellings must round-trip.
        let s = RecordStore::from_json(
            r#"{"records":[{"matrix":"m","kernel":"b(2,8)","avg":3.5,"threads":1,"gflops":2.0}]}"#,
        )
        .unwrap();
        assert_eq!(s.records[0].tile_cols, 0);
        // Pre-autotuner stores have no tuning keys either: they must
        // load as the baseline variant, which is what those runs ran.
        assert_eq!(s.records[0].tune, TuneParams::BASELINE);
        let s = RecordStore::from_json(
            r#"{"records":[{"matrix":"m","kernel":"tiled(4096)","avg":1.5,"threads":1,"tile":4096,"gflops":2.5}]}"#,
        )
        .unwrap();
        assert_eq!(s.records[0].kernel, KernelKind::Tiled(4096));
        assert_eq!(s.records[0].tile_cols, 4096);
    }

    #[test]
    fn push_dedupes_by_configuration() {
        // Re-measuring the same (matrix, kernel, threads, tile_cols)
        // must replace, not append — bench runs used to grow the store
        // without bound.
        let mut s = RecordStore::new();
        let rec = |gflops: f64| PerfRecord {
            matrix: "m".to_string(),
            kernel: KernelKind::Beta(2, 8),
            avg_nnz_per_block: 3.0,
            threads: 2,
            tile_cols: 0,
            tune: TuneParams::default(),
            gflops,
        };
        s.push(rec(1.0));
        s.push(rec(2.5)); // same key: replaces
        assert_eq!(s.records.len(), 1);
        assert_eq!(s.records[0].gflops, 2.5, "latest record wins");
        // Any key component differing appends a separate record —
        // including the kernel variant, so the tuner's per-variant
        // sweep records coexist.
        s.push(PerfRecord { threads: 4, ..rec(3.0) });
        s.push(PerfRecord { tile_cols: 4096, ..rec(3.1) });
        s.push(PerfRecord { kernel: KernelKind::Csr, ..rec(3.2) });
        s.push(PerfRecord { matrix: "other".into(), ..rec(3.3) });
        s.push(PerfRecord {
            tune: crate::kernels::VARIANT_TABLE[1],
            ..rec(3.4)
        });
        assert_eq!(s.records.len(), 6);
        // Re-measuring the tuned configuration replaces it in place.
        s.push(PerfRecord {
            tune: crate::kernels::VARIANT_TABLE[1],
            ..rec(3.5)
        });
        assert_eq!(s.records.len(), 6);
        // Saturation: pushing the whole set again leaves it unchanged
        // in size (the "repeated bench run" scenario).
        let before = s.records.len();
        for r in s.records.clone() {
            s.push(r);
        }
        assert_eq!(s.records.len(), before);
    }

    #[test]
    fn rejects_malformed() {
        assert!(RecordStore::from_json("{}").is_err());
        assert!(RecordStore::from_json(r#"{"records":[{"matrix":"m"}]}"#)
            .is_err());
        assert!(RecordStore::from_json(
            r#"{"records":[{"matrix":"m","kernel":"bogus","avg":1,"threads":1,"gflops":1}]}"#
        )
        .is_err());
    }
}
