//! Kernel selection (paper Table 3 / Fig. 6 flow).
//!
//! Given a matrix we have *not* converted yet:
//! 1. run the cheap block-count scan for every candidate block size
//!    ([`crate::formats::stats::block_stats`] — no conversion, as the
//!    paper requires),
//! 2. evaluate the per-kernel fitted model at that `Avg(r,c)` (and
//!    thread count, for the parallel models),
//! 3. return the kernel with the highest predicted GFlop/s.

use super::{PolyModel, RecordStore, Reg2dModel};
use crate::formats::stats::block_stats;
use crate::formats::BlockSize;
use crate::kernels::KernelKind;
use crate::matrix::Csr;
use crate::scalar::Scalar;
use std::collections::HashMap;

/// Result of a selection.
#[derive(Clone, Debug)]
pub struct Selection {
    pub kernel: KernelKind,
    pub predicted_gflops: f64,
    /// Predictions for every candidate, sorted best-first (for the
    /// Table 3 "selected vs best" analysis).
    pub all: Vec<(KernelKind, f64)>,
}

/// The `Avg(r,c)` feature a kernel's model is evaluated at. CSR/CSR5
/// have no block size; the paper's plots use them as flat references —
/// we evaluate their models at the β(1,8) average for continuity.
fn kernel_avg(kind: KernelKind, stats: &HashMap<BlockSize, f64>) -> f64 {
    let bs = kind.block_size().unwrap_or(BlockSize::new(1, 8));
    *stats.get(&bs).unwrap_or(&1.0)
}

/// Computes the per-size `Avg(r,c)` map with the cheap scan.
pub fn avg_profile<T: Scalar>(
    csr: &Csr<T>,
    kinds: &[KernelKind],
) -> HashMap<BlockSize, f64> {
    let mut sizes: Vec<BlockSize> = kinds
        .iter()
        .map(|k| k.block_size().unwrap_or(BlockSize::new(1, 8)))
        .collect();
    sizes.sort_unstable();
    sizes.dedup();
    sizes
        .into_iter()
        .map(|bs| (bs, block_stats(csr, bs).avg_nnz_per_block))
        .collect()
}

/// Fits per-kernel sequential polynomial models from the record store
/// (degree-3, the paper's choice) and returns them.
pub fn fit_sequential(
    store: &RecordStore,
    kinds: &[KernelKind],
) -> HashMap<KernelKind, PolyModel> {
    let mut models = HashMap::new();
    for &k in kinds {
        // A run recorded against a β/CSR kind but executed tiled (the
        // builder.tile_cols path) must not pool into that kind's flat
        // surface; `tiled(w)` kinds accept their own records at any
        // resolved width.
        let tiled_kind = matches!(k, KernelKind::Tiled(_));
        let recs: Vec<_> = store
            .for_kernel(k, 1)
            .into_iter()
            .filter(|r| tiled_kind || r.tile_cols == 0)
            .collect();
        let xs: Vec<f64> = recs.iter().map(|r| r.avg_nnz_per_block).collect();
        let ys: Vec<f64> = recs.iter().map(|r| r.gflops).collect();
        if let Some(m) = PolyModel::fit(&xs, &ys, 3) {
            models.insert(k, m);
        }
    }
    models
}

/// Fits per-kernel 2D models (avg × threads) from the record store.
pub fn fit_parallel(
    store: &RecordStore,
    kinds: &[KernelKind],
) -> HashMap<KernelKind, Reg2dModel> {
    let mut models = HashMap::new();
    for &k in kinds {
        // Same tiled/flat separation as `fit_sequential`.
        let tiled_kind = matches!(k, KernelKind::Tiled(_));
        let samples: Vec<(f64, f64, f64)> = store
            .for_kernel_all_threads(k)
            .iter()
            .filter(|r| tiled_kind || r.tile_cols == 0)
            .map(|r| (r.avg_nnz_per_block, r.threads as f64, r.gflops))
            .collect();
        if let Some(m) = Reg2dModel::fit(&samples) {
            models.insert(k, m);
        }
    }
    models
}

/// Sequential selection: argmax over the candidates' predicted speed.
pub fn select_sequential<T: Scalar>(
    csr: &Csr<T>,
    store: &RecordStore,
    kinds: &[KernelKind],
) -> Option<Selection> {
    let models = fit_sequential(store, kinds);
    let stats = avg_profile(csr, kinds);
    rank(kinds, &stats, |k, avg| models.get(&k).map(|m| m.eval(avg)))
}

/// Parallel selection at a given thread count.
pub fn select_parallel<T: Scalar>(
    csr: &Csr<T>,
    store: &RecordStore,
    kinds: &[KernelKind],
    threads: usize,
) -> Option<Selection> {
    let models = fit_parallel(store, kinds);
    let stats = avg_profile(csr, kinds);
    rank(kinds, &stats, |k, avg| {
        models.get(&k).map(|m| m.eval(avg, threads as f64))
    })
}

fn rank(
    kinds: &[KernelKind],
    stats: &HashMap<BlockSize, f64>,
    predict: impl Fn(KernelKind, f64) -> Option<f64>,
) -> Option<Selection> {
    // A degenerate fitted model (e.g. collinear training records) can
    // predict NaN/±inf; such kernels are non-candidates, not panics.
    let mut all: Vec<(KernelKind, f64)> = kinds
        .iter()
        .filter_map(|&k| predict(k, kernel_avg(k, stats)).map(|p| (k, p)))
        .filter(|(_, p)| p.is_finite())
        .collect();
    if all.is_empty() {
        return None;
    }
    all.sort_by(|a, b| b.1.partial_cmp(&a.1).expect("finite predictions"));
    Some(Selection {
        kernel: all[0].0,
        predicted_gflops: all[0].1,
        all,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::suite;
    use crate::predictor::PerfRecord;

    /// Builds a synthetic store where kernel quality is a planted
    /// function of avg: β(4,8) wins at high fill, β(1,8)test at low.
    fn planted_store() -> RecordStore {
        let mut store = RecordStore::new();
        let kernels = [
            KernelKind::Csr,
            KernelKind::Beta(1, 8),
            KernelKind::BetaTest(1, 8),
            KernelKind::Beta(4, 8),
        ];
        for i in 0..24 {
            let avg18 = 1.0 + i as f64 * 0.3; // β(1,8) avg range 1..8
            let avg48 = avg18 * 3.5; // correlated higher avg for (4,8)
            for k in kernels {
                let (a, g) = match k {
                    KernelKind::Csr => (avg18, 1.5),
                    KernelKind::Beta(1, 8) => (avg18, 0.8 + 0.25 * avg18),
                    KernelKind::BetaTest(1, 8) => (avg18, 1.6 + 0.05 * avg18),
                    KernelKind::Beta(4, 8) => (avg48, 0.3 + 0.11 * avg48),
                    _ => unreachable!(),
                };
                for t in [1usize, 2, 4] {
                    store.push(PerfRecord {
                        matrix: format!("m{i}"),
                        kernel: k,
                        avg_nnz_per_block: a,
                        threads: t,
                        tile_cols: 0,
                        tune: Default::default(),
                        gflops: g * (t as f64).sqrt(),
                    });
                }
            }
        }
        store
    }

    #[test]
    fn selects_block_kernel_for_dense() {
        let store = planted_store();
        let kinds = [
            KernelKind::Csr,
            KernelKind::Beta(1, 8),
            KernelKind::BetaTest(1, 8),
            KernelKind::Beta(4, 8),
        ];
        let dense = suite::dense(64, 1);
        let sel = select_sequential(&dense, &store, &kinds).unwrap();
        // Dense: avg(4,8)=32 → planted winner is β(4,8) (0.3+0.11·32≈3.8).
        assert_eq!(sel.kernel, KernelKind::Beta(4, 8), "{:?}", sel.all);
    }

    #[test]
    fn selects_low_fill_kernel_for_scatter() {
        let store = planted_store();
        let kinds = [
            KernelKind::Csr,
            KernelKind::Beta(1, 8),
            KernelKind::BetaTest(1, 8),
            KernelKind::Beta(4, 8),
        ];
        let scatter = suite::uniform_scatter(600, 6, 2);
        let sel = select_sequential(&scatter, &store, &kinds).unwrap();
        // avg ≈ 1 → planted winner is the test variant (1.65 vs 1.5 CSR
        // vs ~1.05 β(1,8) vs ~0.7 β(4,8)).
        assert_eq!(sel.kernel, KernelKind::BetaTest(1, 8), "{:?}", sel.all);
    }

    #[test]
    fn parallel_selection_scales_with_threads() {
        let store = planted_store();
        let kinds = [KernelKind::Csr, KernelKind::Beta(1, 8)];
        let m = suite::poisson2d(24);
        let s1 = select_parallel(&m, &store, &kinds, 1).unwrap();
        let s4 = select_parallel(&m, &store, &kinds, 4).unwrap();
        assert!(s4.predicted_gflops > s1.predicted_gflops);
    }

    #[test]
    fn non_finite_predictions_are_not_candidates() {
        // Regression: `rank` used `partial_cmp(..).unwrap()`, so one
        // NaN-predicting model panicked the whole selector.
        let stats = avg_profile(
            &suite::poisson2d(8),
            &[KernelKind::Beta(1, 8), KernelKind::Beta(4, 8)],
        );
        let kinds = [
            KernelKind::Csr,
            KernelKind::Beta(1, 8),
            KernelKind::Beta(4, 8),
        ];
        let sel = rank(&kinds, &stats, |k, _avg| match k {
            KernelKind::Csr => Some(f64::NAN),
            KernelKind::Beta(1, 8) => Some(f64::INFINITY),
            _ => Some(2.5),
        })
        .expect("finite candidate remains");
        assert_eq!(sel.kernel, KernelKind::Beta(4, 8));
        assert_eq!(sel.all.len(), 1, "NaN/inf kernels dropped");

        // Every prediction non-finite → no selection at all (the
        // caller falls back to the β(1,8) default).
        assert!(rank(&kinds, &stats, |_, _| Some(f64::NAN)).is_none());
    }

    #[test]
    fn tiled_runs_do_not_pool_into_flat_fits() {
        // Records of a β kernel executed tiled (tile_cols > 0) must be
        // excluded from that kernel's flat surface...
        let mut store = RecordStore::new();
        for i in 0..8 {
            store.push(PerfRecord {
                matrix: format!("m{i}"),
                kernel: KernelKind::Beta(1, 8),
                avg_nnz_per_block: 1.0 + i as f64,
                threads: 1,
                tile_cols: 4096,
                tune: Default::default(),
                gflops: 99.0,
            });
        }
        let models = fit_sequential(&store, &[KernelKind::Beta(1, 8)]);
        assert!(models.is_empty(), "only tiled records — no flat surface");
        // ...while tiled kernel kinds keep their own records at any
        // resolved width (auto runs record the real window).
        for i in 0..8 {
            store.push(PerfRecord {
                matrix: format!("t{i}"),
                kernel: KernelKind::Tiled(0),
                avg_nnz_per_block: 1.0 + i as f64,
                threads: 1,
                tile_cols: 65536,
                tune: Default::default(),
                gflops: 2.0 + i as f64 * 0.1,
            });
        }
        let models = fit_sequential(
            &store,
            &[KernelKind::Beta(1, 8), KernelKind::Tiled(0)],
        );
        assert!(models.contains_key(&KernelKind::Tiled(0)));
        assert!(!models.contains_key(&KernelKind::Beta(1, 8)));
    }

    #[test]
    fn empty_store_gives_none() {
        let store = RecordStore::new();
        let m = suite::poisson2d(8);
        assert!(select_sequential(&m, &store, &[KernelKind::Csr]).is_none());
    }

    #[test]
    fn ranking_is_sorted() {
        let store = planted_store();
        let kinds = [
            KernelKind::Csr,
            KernelKind::Beta(1, 8),
            KernelKind::Beta(4, 8),
        ];
        let m = suite::fem_blocked(200, 3, 5, 9);
        let sel = select_sequential(&m, &store, &kinds).unwrap();
        for w in sel.all.windows(2) {
            assert!(w[0].1 >= w[1].1);
        }
        assert_eq!(sel.kernel, sel.all[0].0);
    }
}
