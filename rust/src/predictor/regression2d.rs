//! Nonlinear 2D regression for the parallel predictor (paper Fig. 6):
//! `gflops ≈ f(avg, threads)` per kernel, fitted on Set-A records at
//! several thread counts.
//!
//! Basis: `{1, a, a², t, t², a·t, a·log2(t), log2(t)}` with
//! `a = Avg(r,c)`, `t = threads` — a small nonlinear feature map whose
//! weights are solved by linear least squares (the paper's "non-linear
//! 2D regression").

use super::polyfit::solve;

/// Number of basis functions.
const NBASIS: usize = 8;

fn basis(avg: f64, threads: f64) -> [f64; NBASIS] {
    let lt = threads.max(1.0).log2();
    [
        1.0,
        avg,
        avg * avg,
        threads,
        threads * threads,
        avg * threads,
        avg * lt,
        lt,
    ]
}

/// A fitted 2D model.
#[derive(Clone, Debug, PartialEq)]
pub struct Reg2dModel {
    pub weights: Vec<f64>,
}

impl Reg2dModel {
    /// Least-squares fit on `(avg, threads, gflops)` samples. Returns
    /// `None` for an empty or degenerate sample set.
    pub fn fit(samples: &[(f64, f64, f64)]) -> Option<Reg2dModel> {
        if samples.is_empty() {
            return None;
        }
        let n = NBASIS;
        let mut ata = vec![0.0f64; n * n];
        let mut aty = vec![0.0f64; n];
        for &(a, t, y) in samples {
            let phi = basis(a, t);
            for i in 0..n {
                aty[i] += phi[i] * y;
                for j in 0..n {
                    ata[i * n + j] += phi[i] * phi[j];
                }
            }
        }
        // Ridge damping keeps the system well-posed when the sample set
        // is small or collinear (e.g. all records at one thread count).
        for i in 0..n {
            ata[i * n + i] += 1e-6;
        }
        let weights = solve(&mut ata, &mut aty, n)?;
        Some(Reg2dModel { weights })
    }

    /// Predicted GFlop/s at `(avg, threads)`.
    pub fn eval(&self, avg: f64, threads: f64) -> f64 {
        basis(avg, threads)
            .iter()
            .zip(&self.weights)
            .map(|(p, w)| p * w)
            .sum()
    }

    /// RMSE over a sample set.
    pub fn rmse(&self, samples: &[(f64, f64, f64)]) -> f64 {
        if samples.is_empty() {
            return 0.0;
        }
        let se: f64 = samples
            .iter()
            .map(|&(a, t, y)| (self.eval(a, t) - y).powi(2))
            .sum();
        (se / samples.len() as f64).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recovers_planted_model() {
        // y = 0.5 + 0.2a + 0.1·a·log2(t)
        let mut samples = Vec::new();
        for ai in 1..20 {
            for &t in &[1.0f64, 2.0, 4.0, 8.0, 16.0] {
                let a = ai as f64 * 0.5;
                samples.push((a, t, 0.5 + 0.2 * a + 0.1 * a * t.log2()));
            }
        }
        let m = Reg2dModel::fit(&samples).unwrap();
        assert!(m.rmse(&samples) < 1e-6);
        assert!((m.eval(4.0, 8.0) - (0.5 + 0.8 + 1.2)).abs() < 1e-4);
    }

    #[test]
    fn empty_is_none() {
        assert!(Reg2dModel::fit(&[]).is_none());
    }

    #[test]
    fn single_thread_records_still_fit() {
        // Degenerate in t (all t=1): ridge keeps it solvable; the model
        // must still interpolate over `a` sensibly.
        let samples: Vec<(f64, f64, f64)> =
            (1..30).map(|i| (i as f64 * 0.3, 1.0, i as f64 * 0.1)).collect();
        let m = Reg2dModel::fit(&samples).unwrap();
        assert!(m.rmse(&samples) < 0.05);
    }

    #[test]
    fn interpolates_between_thread_counts() {
        let mut samples = Vec::new();
        for &t in &[1.0f64, 4.0, 16.0] {
            for ai in 1..16 {
                let a = ai as f64;
                samples.push((a, t, a * t.sqrt() * 0.1));
            }
        }
        let m = Reg2dModel::fit(&samples).unwrap();
        // Not exact (sqrt is outside the basis) but monotone-ish and
        // bounded error on the fitted domain.
        assert!(m.rmse(&samples) < 0.35);
        assert!(m.eval(8.0, 16.0) > m.eval(8.0, 1.0));
    }
}
