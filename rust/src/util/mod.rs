//! Small self-contained utilities: deterministic RNG, timing, tiny JSON,
//! CPU feature detection helpers.
//!
//! The offline vendor set ships neither `rand` nor `serde` proper, so the
//! crate carries its own seeded RNG (xoshiro256**, seeded via splitmix64)
//! and a minimal JSON reader/writer sufficient for the predictor's record
//! store. Both are fully tested below.

pub mod durable;
pub mod json;
pub mod rng;
pub mod timer;

pub use durable::{AtomicFile, DegradeEvent, StateError};
pub use rng::Rng;
pub use timer::Timer;

/// Returns true when the running CPU supports every AVX-512 subset the
/// optimized kernels use (`avx512f` for `vexpandpd`/FMA on zmm,
/// `avx512vl` for the 256-bit expand used by the c=4 kernels,
/// `avx512bw`+`avx512dq` for mask moves).
#[inline]
pub fn avx512_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("avx512f")
            && std::arch::is_x86_feature_detected!("avx512vl")
            && std::arch::is_x86_feature_detected!("avx512bw")
            && std::arch::is_x86_feature_detected!("avx512dq")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// ceil(a / b) for positive integers.
#[inline]
pub const fn ceil_div(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

/// Human-readable byte count (for logs and the occupancy tables).
pub fn fmt_bytes(bytes: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = bytes as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{bytes} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(0, 8), 0);
        assert_eq!(ceil_div(1, 8), 1);
        assert_eq!(ceil_div(8, 8), 1);
        assert_eq!(ceil_div(9, 8), 2);
        assert_eq!(ceil_div(63, 8), 8);
        assert_eq!(ceil_div(64, 8), 8);
    }

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(12), "12 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn avx512_detection_is_stable() {
        // Must return the same answer on repeated calls (pure detection).
        assert_eq!(avx512_available(), avx512_available());
    }
}
