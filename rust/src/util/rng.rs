//! Deterministic pseudo-random number generator.
//!
//! xoshiro256** (Blackman & Vigna) seeded through splitmix64; small,
//! fast and reproducible across platforms — all synthetic matrices in
//! [`crate::matrix::suite`] derive from fixed seeds so every benchmark
//! table is regenerated bit-identically.

/// xoshiro256** generator state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Creates a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits → [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, bound)`; `bound` must be > 0.
    #[inline]
    pub fn next_below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        // Lemire's multiply-shift rejection-free variant is overkill here;
        // 128-bit multiply gives a negligible-bias mapping.
        ((self.next_u64() as u128 * bound as u128) >> 64) as usize
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Nonzero value for synthetic matrices: uniform in [-1, 1] excluding
    /// a small band around 0 so entries never vanish accidentally.
    #[inline]
    pub fn nnz_value(&mut self) -> f64 {
        let v = self.range_f64(-1.0, 1.0);
        if v.abs() < 1e-3 {
            if v >= 0.0 {
                v + 1e-3
            } else {
                v - 1e-3
            }
        } else {
            v
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn next_below_respects_bound() {
        let mut r = Rng::new(9);
        for bound in [1usize, 2, 3, 7, 100, 12345] {
            for _ in 0..200 {
                assert!(r.next_below(bound) < bound);
            }
        }
    }

    #[test]
    fn next_below_covers_range() {
        let mut r = Rng::new(11);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            seen[r.next_below(8)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn mean_is_roughly_half() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let sum: f64 = (0..n).map(|_| r.next_f64()).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn nnz_value_never_zero() {
        let mut r = Rng::new(8);
        for _ in 0..10_000 {
            assert!(r.nnz_value().abs() >= 1e-3);
        }
    }
}
