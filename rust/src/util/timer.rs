//! Wall-clock timing helpers used by the benchmark harness.

use std::time::Instant;

/// Simple wall-clock timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Starts a new timer.
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    /// Elapsed seconds since start.
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Elapsed nanoseconds since start.
    pub fn elapsed_ns(&self) -> u128 {
        self.start.elapsed().as_nanos()
    }

    /// Restarts the timer and returns the previous elapsed seconds.
    pub fn lap_s(&mut self) -> f64 {
        let e = self.elapsed_s();
        self.start = Instant::now();
        e
    }
}

/// Times `f`, returning `(result, seconds)`.
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let r = f();
    (r, t.elapsed_s())
}

/// The paper measures "an average of 16 consecutive runs without
/// accessing the matrix before the first run". This replicates that
/// protocol: run `f` `runs` times, return the mean seconds per run.
pub fn mean_of_runs(runs: usize, mut f: impl FnMut()) -> f64 {
    assert!(runs > 0);
    let t = Timer::start();
    for _ in 0..runs {
        f();
    }
    t.elapsed_s() / runs as f64
}

/// FLOPS metric used throughout the paper: `2 × nnz / T`.
pub fn spmv_gflops(nnz: usize, seconds: f64) -> f64 {
    2.0 * nnz as f64 / seconds / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        let a = t.elapsed_ns();
        let b = t.elapsed_ns();
        assert!(b >= a);
    }

    #[test]
    fn time_it_returns_value() {
        let (v, s) = time_it(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }

    #[test]
    fn mean_of_runs_counts() {
        let mut n = 0;
        let _ = mean_of_runs(16, || n += 1);
        assert_eq!(n, 16);
    }

    #[test]
    fn gflops_formula() {
        // 1e9 nnz in 2 seconds → 2*1e9/2/1e9 = 1 GFlop/s
        let g = spmv_gflops(1_000_000_000, 2.0);
        assert!((g - 1.0).abs() < 1e-12);
    }
}
