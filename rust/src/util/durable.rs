//! Durable persistence: atomic writes, checksummed envelopes,
//! quarantine, and the crate-wide degradation log.
//!
//! Every JSON artifact the serving stack persists (`PlanCache`,
//! `RecordStore`, `TuneProfile`, saved `SpmvPlan`s, bench reports)
//! goes through this module, which provides four guarantees:
//!
//! 1. **Atomic writes** — [`AtomicFile`] writes to a temp sibling,
//!    fsyncs, then renames over the destination, so a crash mid-save
//!    leaves either the old file or the new file, never a torn mix.
//! 2. **Checksummed envelope** — payloads are framed by a versioned
//!    header (`SPC5STATEv1 <len>`) and an FNV-1a footer
//!    (`SPC5SUM <hex>`), so any single corrupted byte is detected at
//!    load instead of surfacing as a confusing JSON error (or worse,
//!    silently wrong state). Files *without* the magic are treated as
//!    trusted-legacy and parsed as bare payload, so pre-envelope
//!    artifacts keep loading.
//! 3. **Quarantine** — a file that fails envelope or payload
//!    validation is renamed to `<name>.corrupt-<n>` (first free `n`),
//!    preserving the evidence while guaranteeing the next cold start
//!    does not trip over the same corpse.
//! 4. **Observable degradation** — callers that fall back (re-plan,
//!    baseline tune, analytic model) record a [`DegradeEvent`] in a
//!    process-global log surfaced through `TenantRegistry` stats and
//!    the `spc5 serve` / `spc5 tune` CLIs.
//!
//! The write path checks the `io_write` fault site and honors the
//! `torn{at}` action (see [`crate::faults`]): a torn write emulates a
//! crash mid-write of a *non-atomic* writer by leaving exactly the
//! first `at` bytes at the destination — the deterministic substrate
//! the crash-consistency suite replays.

use std::fmt;
use std::io::{self, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::faults::{self, Site};

/// Envelope magic. The version suffix is parsed separately so a
/// future `SPC5STATEv2` is rejected as [`StateErrorKind::WrongVersion`]
/// rather than mistaken for a legacy bare payload.
pub const MAGIC: &str = "SPC5STATE";
/// Current envelope format version.
pub const VERSION: u32 = 1;
const FOOTER_MAGIC: &str = "SPC5SUM";

/// FNV-1a over `bytes` — the same hash `MatrixFingerprint` uses, so
/// the crate carries exactly one checksum primitive.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

// --- Typed errors -------------------------------------------------------

/// Why a persisted artifact failed to load or save.
#[derive(Debug)]
pub enum StateErrorKind {
    /// Filesystem error (missing file, permissions, injected torn
    /// write). `is_missing` distinguishes not-found so callers can
    /// keep "missing profile" a hard error while degrading on
    /// corruption.
    Io(io::Error),
    /// File starts with the envelope magic but an unsupported version.
    WrongVersion(String),
    /// Envelope header present but unparsable (corrupted length field
    /// or footer framing).
    BadEnvelope(String),
    /// Fewer payload/footer bytes than the header promised.
    Truncated { expected: usize, got: usize },
    /// Payload bytes do not hash to the recorded checksum.
    ChecksumMismatch { expected: u64, got: u64 },
    /// Envelope (or legacy file) verified but the payload failed the
    /// artifact's own parser (malformed JSON, wrong schema version).
    Malformed(String),
}

/// A typed load/save failure for a persisted artifact: which artifact,
/// which file, what went wrong, and where the corpse was quarantined
/// (when it was).
#[derive(Debug)]
pub struct StateError {
    /// Artifact class, e.g. `"plan-cache"`, `"tune-profile"`.
    pub artifact: &'static str,
    /// The file involved.
    pub path: PathBuf,
    pub kind: StateErrorKind,
    /// Where the corrupt file was moved, when quarantine succeeded.
    pub quarantined_to: Option<PathBuf>,
}

impl StateError {
    fn new(
        artifact: &'static str,
        path: &Path,
        kind: StateErrorKind,
    ) -> StateError {
        StateError {
            artifact,
            path: path.to_path_buf(),
            kind,
            quarantined_to: None,
        }
    }

    /// True when the underlying cause is a missing file (callers that
    /// treat missing-as-fresh branch on this, not on corruption).
    pub fn is_missing(&self) -> bool {
        matches!(&self.kind, StateErrorKind::Io(e)
            if e.kind() == io::ErrorKind::NotFound)
    }
}

impl fmt::Display for StateError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} {}: ", self.artifact, self.path.display())?;
        match &self.kind {
            StateErrorKind::Io(e) => write!(f, "{e}")?,
            StateErrorKind::WrongVersion(v) => {
                write!(f, "unsupported envelope version {v:?} (have v{VERSION})")?
            }
            StateErrorKind::BadEnvelope(msg) => {
                write!(f, "corrupt envelope: {msg}")?
            }
            StateErrorKind::Truncated { expected, got } => write!(
                f,
                "truncated: header promises {expected} payload bytes, {got} present"
            )?,
            StateErrorKind::ChecksumMismatch { expected, got } => write!(
                f,
                "checksum mismatch: recorded {expected:016x}, computed {got:016x}"
            )?,
            StateErrorKind::Malformed(msg) => write!(f, "{msg}")?,
        }
        if let Some(q) = &self.quarantined_to {
            write!(f, " (quarantined to {})", q.display())?;
        }
        Ok(())
    }
}

impl std::error::Error for StateError {}

/// `Result` specialized to [`StateError`] — converts into the crate's
/// `anyhow::Result` through `?`.
pub type Result<T> = std::result::Result<T, StateError>;

// --- Envelope -----------------------------------------------------------

/// Frames `payload` in the versioned checksummed envelope:
///
/// ```text
/// SPC5STATEv1 <payload-len>\n
/// <payload bytes>
/// SPC5SUM <fnv1a-of-payload, 16 hex digits>\n
/// ```
pub fn wrap(payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(payload.len() + 64);
    out.extend_from_slice(
        format!("{MAGIC}v{VERSION} {}\n", payload.len()).as_bytes(),
    );
    out.extend_from_slice(payload);
    out.extend_from_slice(
        format!("\n{FOOTER_MAGIC} {:016x}\n", fnv1a(payload)).as_bytes(),
    );
    out
}

/// A verified payload plus whether it came from a legacy (unwrapped)
/// file.
pub struct Unwrapped {
    pub payload: Vec<u8>,
    pub legacy: bool,
}

/// Verifies the envelope and returns the payload. Input without the
/// magic prefix is trusted-legacy: returned whole, unverified.
pub fn unwrap(bytes: &[u8]) -> std::result::Result<Unwrapped, StateErrorKind> {
    if !bytes.starts_with(MAGIC.as_bytes()) {
        return Ok(Unwrapped { payload: bytes.to_vec(), legacy: true });
    }
    let nl = bytes.iter().position(|&b| b == b'\n').ok_or_else(|| {
        StateErrorKind::BadEnvelope("header line missing newline".into())
    })?;
    let header = std::str::from_utf8(&bytes[..nl]).map_err(|_| {
        StateErrorKind::BadEnvelope("header is not UTF-8".into())
    })?;
    let (tag, len_s) = header.split_once(' ').ok_or_else(|| {
        StateErrorKind::BadEnvelope("header missing length field".into())
    })?;
    let version = &tag[MAGIC.len()..];
    if version != format!("v{VERSION}") {
        return Err(StateErrorKind::WrongVersion(version.to_string()));
    }
    let len: usize = len_s.trim().parse().map_err(|_| {
        StateErrorKind::BadEnvelope(format!(
            "payload length {len_s:?} is not an integer"
        ))
    })?;
    let rest = &bytes[nl + 1..];
    if rest.len() < len {
        return Err(StateErrorKind::Truncated {
            expected: len,
            got: rest.len(),
        });
    }
    let payload = &rest[..len];
    let footer = &rest[len..];
    // Footer: `\nSPC5SUM <16 hex>\n` (trailing newline optional so a
    // final-byte truncation still reports *which* check failed).
    let footer = std::str::from_utf8(footer).map_err(|_| {
        StateErrorKind::BadEnvelope("footer is not UTF-8".into())
    })?;
    let footer = footer.strip_prefix('\n').ok_or_else(|| {
        StateErrorKind::BadEnvelope("footer missing separator".into())
    })?;
    let sum_s = footer
        .strip_prefix(FOOTER_MAGIC)
        .and_then(|s| s.strip_prefix(' '))
        .ok_or_else(|| {
            StateErrorKind::BadEnvelope("footer magic missing".into())
        })?;
    // The final newline is optional (a last-byte truncation still
    // verifies), but the digits are exactly 16 lowercase hex — any
    // looser and single-bit flips of the checksum text itself (case
    // flips, whitespace lookalikes) could slip through verification.
    let sum_s = sum_s.strip_suffix('\n').unwrap_or(sum_s);
    if sum_s.len() != 16
        || !sum_s
            .bytes()
            .all(|b| b.is_ascii_digit() || (b'a'..=b'f').contains(&b))
    {
        return Err(StateErrorKind::BadEnvelope(format!(
            "footer checksum {sum_s:?} is not 16 lowercase hex digits"
        )));
    }
    let expected = u64::from_str_radix(sum_s, 16).map_err(|_| {
        StateErrorKind::BadEnvelope(format!(
            "footer checksum {sum_s:?} is not hex"
        ))
    })?;
    let got = fnv1a(payload);
    if got != expected {
        return Err(StateErrorKind::ChecksumMismatch { expected, got });
    }
    Ok(Unwrapped { payload: payload.to_vec(), legacy: false })
}

// --- Atomic writes ------------------------------------------------------

static TMP_COUNTER: AtomicU64 = AtomicU64::new(0);

/// Crash-safe file replacement: bytes land in a temp sibling, are
/// fsynced, and the sibling is renamed over the destination. The
/// parent directory is fsynced best-effort so the rename itself is
/// durable.
pub struct AtomicFile {
    dest: PathBuf,
}

impl AtomicFile {
    pub fn new(dest: &Path) -> AtomicFile {
        AtomicFile { dest: dest.to_path_buf() }
    }

    /// Writes `bytes` atomically to the destination.
    pub fn write(&self, bytes: &[u8]) -> io::Result<()> {
        let name = self
            .dest
            .file_name()
            .and_then(|n| n.to_str())
            .unwrap_or("state");
        let tmp = self.dest.with_file_name(format!(
            ".{name}.tmp-{}-{}",
            std::process::id(),
            TMP_COUNTER.fetch_add(1, Ordering::Relaxed),
        ));
        let result = (|| {
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(bytes)?;
            f.sync_all()?;
            std::fs::rename(&tmp, &self.dest)?;
            if let Some(dir) = self.dest.parent() {
                // Directory fsync is advisory: not all filesystems
                // allow opening a directory for sync.
                if let Ok(d) = std::fs::File::open(dir) {
                    let _ = d.sync_all();
                }
            }
            Ok(())
        })();
        if result.is_err() {
            let _ = std::fs::remove_file(&tmp);
        }
        result
    }
}

// --- Quarantine ---------------------------------------------------------

/// Renames `path` to the first free `<name>.corrupt-<n>` sibling and
/// returns the destination. The original file is preserved as
/// evidence; the original path is freed for a rebuilt replacement.
pub fn quarantine(path: &Path) -> io::Result<PathBuf> {
    let name = path
        .file_name()
        .and_then(|n| n.to_str())
        .unwrap_or("state")
        .to_string();
    for n in 0..10_000u32 {
        let dest = path.with_file_name(format!("{name}.corrupt-{n}"));
        if !dest.exists() {
            std::fs::rename(path, &dest)?;
            return Ok(dest);
        }
    }
    Err(io::Error::new(
        io::ErrorKind::AlreadyExists,
        "10000 quarantine slots already taken",
    ))
}

// --- Load / save --------------------------------------------------------

/// What `read_state` found at a path.
pub enum RawState {
    /// No file. Callers decide whether that is fresh (caches) or a
    /// hard error (an explicitly named profile).
    Missing,
    /// Zero-length or whitespace-only file — treated as fresh with a
    /// warning, never a parse error.
    Empty,
    /// A verified payload (envelope checked, or trusted-legacy).
    Payload { text: String, legacy: bool },
}

/// Reads and envelope-verifies `path`. Envelope failures quarantine
/// the file and return a typed error; a missing or empty file is a
/// non-error [`RawState`] variant. Checks the `io_read` fault site.
pub fn read_state(artifact: &'static str, path: &Path) -> Result<RawState> {
    faults::check_io_global(Site::IoRead);
    let mut bytes = Vec::new();
    match std::fs::File::open(path) {
        Ok(mut f) => {
            if let Err(e) = f.read_to_end(&mut bytes) {
                return Err(StateError::new(
                    artifact,
                    path,
                    StateErrorKind::Io(e),
                ));
            }
        }
        Err(e) if e.kind() == io::ErrorKind::NotFound => {
            return Ok(RawState::Missing)
        }
        Err(e) => {
            return Err(StateError::new(artifact, path, StateErrorKind::Io(e)))
        }
    }
    if bytes.iter().all(|b| b.is_ascii_whitespace()) {
        return Ok(RawState::Empty);
    }
    match unwrap(&bytes) {
        Ok(u) => match String::from_utf8(u.payload) {
            Ok(text) => Ok(RawState::Payload { text, legacy: u.legacy }),
            Err(_) => Err(quarantined(
                artifact,
                path,
                StateErrorKind::Malformed("payload is not UTF-8".into()),
            )),
        },
        Err(kind) => Err(quarantined(artifact, path, kind)),
    }
}

/// Builds a [`StateError`] for `path` after attempting quarantine.
/// Use for payload-level failures (malformed JSON after a clean
/// envelope check) as well as envelope failures.
pub fn quarantined(
    artifact: &'static str,
    path: &Path,
    kind: StateErrorKind,
) -> StateError {
    let mut err = StateError::new(artifact, path, kind);
    if let Ok(dest) = quarantine(path) {
        err.quarantined_to = Some(dest);
    }
    err
}

/// Envelope-wraps `payload` and writes it atomically. Checks the
/// `io_write` fault site: a firing `torn{at}` rule leaves exactly the
/// first `at` bytes at the destination (the crash a pre-durable
/// `fs::write` could leave) and returns an error.
pub fn save_state(
    artifact: &'static str,
    path: &Path,
    payload: &str,
) -> Result<()> {
    let bytes = wrap(payload.as_bytes());
    if let Some(at) = faults::check_io_global(Site::IoWrite) {
        let n = (at as usize).min(bytes.len());
        let _ = std::fs::write(path, &bytes[..n]);
        return Err(StateError::new(
            artifact,
            path,
            StateErrorKind::Io(io::Error::new(
                io::ErrorKind::Interrupted,
                format!("injected torn write after {n} bytes"),
            )),
        ));
    }
    AtomicFile::new(path)
        .write(&bytes)
        .map_err(|e| StateError::new(artifact, path, StateErrorKind::Io(e)))
}

// --- Degradation log ----------------------------------------------------

/// One recorded fallback: which artifact degraded, why, and what the
/// caller fell back to.
#[derive(Clone, Debug)]
pub struct DegradeEvent {
    /// Artifact class (`"plan-cache"`, `"tune-profile"`, …).
    pub artifact: String,
    /// The file involved.
    pub path: String,
    /// What failed (typed-error text).
    pub reason: String,
    /// What the caller did instead (`"re-plan"`, `"baseline tune"`, …).
    pub fallback: String,
}

impl fmt::Display for DegradeEvent {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "degraded {} ({}): {} -> {}",
            self.artifact, self.path, self.reason, self.fallback
        )
    }
}

static DEGRADE_LOG: Mutex<Vec<DegradeEvent>> = Mutex::new(Vec::new());

/// Records a degradation in the process-global log (and mirrors it to
/// stderr so non-serving paths surface it too).
pub fn record_degrade(event: DegradeEvent) {
    eprintln!("spc5: {event}");
    DEGRADE_LOG.lock().unwrap_or_else(|e| e.into_inner()).push(event);
}

/// Snapshot of every degradation recorded so far, oldest first.
pub fn degrade_events() -> Vec<DegradeEvent> {
    DEGRADE_LOG.lock().unwrap_or_else(|e| e.into_inner()).clone()
}

/// Number of degradations recorded so far.
pub fn degrade_count() -> usize {
    DEGRADE_LOG.lock().unwrap_or_else(|e| e.into_inner()).len()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wrap_unwrap_round_trips() {
        let payload = br#"{"k": [1, 2, 3]}"#;
        let framed = wrap(payload);
        let u = unwrap(&framed).unwrap();
        assert!(!u.legacy);
        assert_eq!(u.payload, payload);
    }

    #[test]
    fn bare_payload_is_legacy() {
        let u = unwrap(b"{\"plans\": []}\n").unwrap();
        assert!(u.legacy);
        assert_eq!(u.payload, b"{\"plans\": []}\n");
    }

    #[test]
    fn every_single_byte_corruption_is_detected() {
        let framed = wrap(br#"{"answer": 42}"#);
        for i in 0..framed.len() {
            for bit in 0..8 {
                let mut bad = framed.clone();
                bad[i] ^= 1 << bit;
                match unwrap(&bad) {
                    // A flipped magic byte demotes the file to legacy;
                    // the payload then carries framing bytes that no
                    // artifact parser accepts — still a typed failure,
                    // exercised by the durability integration suite.
                    Ok(u) => assert!(
                        u.legacy && i < MAGIC.len(),
                        "corruption at byte {i} bit {bit} verified"
                    ),
                    Err(_) => {}
                }
            }
        }
    }

    #[test]
    fn truncation_is_typed() {
        let framed = wrap(b"0123456789");
        for cut in 0..framed.len() {
            let r = unwrap(&framed[..cut]);
            if cut < MAGIC.len() {
                // Shorter than the magic (including empty): cannot be
                // distinguished from a legacy bare payload. Artifact
                // parsers reject the fragment downstream.
                assert!(r.unwrap().legacy);
            } else if cut == framed.len() - 1 {
                // Only the final newline lost: the checksum is whole
                // and still verifies.
                assert!(!r.unwrap().legacy);
            } else {
                assert!(r.is_err(), "cut at {cut} accepted");
            }
        }
    }

    #[test]
    fn future_version_is_rejected_not_legacy() {
        let mut framed = wrap(b"x");
        let hdr = String::from_utf8(framed.clone()).unwrap();
        let hdr = hdr.replacen("SPC5STATEv1", "SPC5STATEv9", 1);
        framed = hdr.into_bytes();
        assert!(matches!(
            unwrap(&framed),
            Err(StateErrorKind::WrongVersion(v)) if v == "v9"
        ));
    }

    #[test]
    fn atomic_write_then_read_state() {
        let dir = std::env::temp_dir().join("spc5_durable_unit");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.json");
        save_state("unit", &path, "{\"v\": 1}").unwrap();
        match read_state("unit", &path).unwrap() {
            RawState::Payload { text, legacy } => {
                assert_eq!(text, "{\"v\": 1}");
                assert!(!legacy);
            }
            _ => panic!("expected payload"),
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_and_empty_are_not_errors() {
        let dir = std::env::temp_dir().join("spc5_durable_unit");
        std::fs::create_dir_all(&dir).unwrap();
        assert!(matches!(
            read_state("unit", &dir.join("nope.json")).unwrap(),
            RawState::Missing
        ));
        let empty = dir.join("empty.json");
        std::fs::write(&empty, "  \n\t\n").unwrap();
        assert!(matches!(
            read_state("unit", &empty).unwrap(),
            RawState::Empty
        ));
        std::fs::remove_file(&empty).ok();
    }

    #[test]
    fn corrupt_file_is_quarantined_with_typed_error() {
        let dir = std::env::temp_dir().join("spc5_durable_quarantine");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("store.json");
        let mut framed = wrap(b"{\"records\": []}");
        let mid = framed.len() / 2;
        framed[mid] ^= 0x40;
        std::fs::write(&path, &framed).unwrap();
        let err = match read_state("record-store", &path) {
            Err(e) => e,
            Ok(_) => panic!("corruption accepted"),
        };
        assert_eq!(err.artifact, "record-store");
        let q = err.quarantined_to.clone().expect("quarantined");
        assert!(q.exists());
        assert!(!path.exists(), "original path freed");
        assert!(err.to_string().contains("record-store"));
        std::fs::remove_file(&q).ok();
    }

    #[test]
    fn quarantine_picks_the_first_free_slot() {
        let dir = std::env::temp_dir().join("spc5_durable_slots");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("a.json");
        std::fs::write(&path, "x").unwrap();
        std::fs::write(dir.join("a.json.corrupt-0"), "old").unwrap();
        let dest = quarantine(&path).unwrap();
        assert!(dest.to_string_lossy().ends_with("a.json.corrupt-1"));
        std::fs::remove_file(dir.join("a.json.corrupt-0")).ok();
        std::fs::remove_file(dest).ok();
    }

    #[test]
    fn fnv1a_matches_reference_vectors() {
        // Offset-basis for empty input, and the classic "a" vector.
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
    }
}
