//! Minimal JSON value model, parser and writer.
//!
//! The predictor persists performance records to disk; the offline
//! vendor set has no `serde`, so this module implements the small JSON
//! subset we need (objects, arrays, strings, f64 numbers, bools, null)
//! with precise error reporting. Round-trip is covered by unit and
//! property tests.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Numbers are stored as `f64` (sufficient for records).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Convenience accessor: object field.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Convenience accessor: number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Convenience accessor: boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Convenience accessor: string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Convenience accessor: array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Builds an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Parses a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000C}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = &self.b[self.i..];
                    let ch = std::str::from_utf8(rest)
                        .ok()
                        .and_then(|t| t.chars().next())
                        .ok_or_else(|| self.err("invalid utf8"))?;
                    s.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_json(self, &mut s);
        f.write_str(&s)
    }
}

fn write_json(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => escape(s, out),
        Json::Arr(xs) => {
            out.push('[');
            for (i, x) in xs.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(x, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape(k, out);
                out.push(':');
                write_json(x, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_f64().unwrap(), 2.0);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"k":[1,2.5,true,null,"s\"q"],"m":{"n":-3}}"#;
        let v = Json::parse(src).unwrap();
        let printed = v.to_string();
        assert_eq!(Json::parse(&printed).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "tru", "{\"a\"}", "1 2", "\"unterminated"] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
    }

    #[test]
    fn whitespace_tolerant() {
        let v = Json::parse(" {\n\t\"a\" : [ 1 , 2 ] } ").unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.25).to_string(), "3.25");
    }
}
