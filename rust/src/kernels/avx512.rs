//! AVX-512 SpMV kernels — the paper's optimized routines
//! (§"Optimized kernel implementation", Code 1), one per block size,
//! for **both precisions** behind one span abstraction.
//!
//! Each kernel walks the interleaved header stream
//! (`colidx:4B | masks:r·mask_bytes` per block — the exact memory
//! layout the published assembly reads with a single pointer), and per
//! block:
//!
//! 1. `kmov`-loads the mask word(s),
//! 2. `vexpandpd` / `vexpandps` (`_mm512_maskz_expandloadu_pd/ps`)
//!    inflates the next `popcnt(mask)` values from the *unpadded*
//!    values stream into the lanes selected by the mask — the paper's
//!    central trick,
//! 3. a masked load pulls the `x` window (masked lanes are never
//!    touched, which both avoids reading past the end of `x` and
//!    implements the paper's "use the block mask to avoid useless
//!    memory load"),
//! 4. one FMA per block row accumulates into per-row accumulators that
//!    live across the whole row interval and are horizontally reduced
//!    into `y` once per interval — like `vpxorq`/`vaddsd` in Code 1.
//!
//! **f64** (8 lanes, `u8` masks): the paper's six sizes. `c = 4`
//! kernels pack two block rows into one 512-bit operation (combined
//! 8-bit mask `m_lo | m_hi << 4`, `x` window broadcast to both 256-bit
//! halves). The Algorithm-2 `test` variants keep two separate inner
//! loops (scalar for `mask == 1` blocks, vector otherwise) and jump
//! between them exactly like the paper's `goto` structure.
//!
//! **f32** (16 lanes, `u16` masks): `vexpandps` inflates 16 packed
//! floats per block row — the paper's "16 single precision values"
//! lane count, which it mentions but never ships kernels for.
//! Specializations: β(1,16), β(2,16), β(4,16); other sizes fall back
//! to the generic scalar kernel.
//!
//! All kernels operate on a [`Span`] — a contiguous range of row
//! intervals with its header/value sub-streams — so the same code
//! serves the sequential path (one span = whole matrix) and each
//! thread of the parallel runtime (paper §Parallelization). Dispatch
//! is routed per scalar through
//! [`crate::scalar::Scalar::spmv_span_simd`].

#![allow(unsafe_code)]

use crate::formats::{BlockMatrix, BlockSize};
use crate::scalar::Scalar;
use std::sync::atomic::{AtomicU8, Ordering};

#[cfg(target_arch = "x86_64")]
use std::arch::x86_64::*;

/// Machine-level tuning knobs for one β kernel invocation — the
/// parameter space the `spc5 tune` sweep searches (ROADMAP open
/// item 2). The knobs are pure *scheduling* hints: every combination
/// computes bit-identical results (unrolling keeps the single
/// accumulator chain, prefetches never change data), so the tuner can
/// pick freely on throughput alone.
///
/// Kernels are monomorphized per [`VARIANT_TABLE`] entry and dispatched
/// **once per span call** — the per-block hot path carries no branch
/// and reads no global state. Parameters outside the table fall back
/// to the baseline variant.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TuneParams {
    /// Header-stream prefetch distance in *blocks* ahead of the walk
    /// (`0` = no header prefetch).
    pub header_prefetch_dist: u8,
    /// Values-stream prefetch distance in 64-byte cache lines
    /// (`0` = no values prefetch).
    pub value_prefetch_dist: u8,
    /// Also prefetch the block's `x` window as soon as its column is
    /// decoded (helps scatter-heavy matrices, wasted on banded ones).
    pub prefetch_x: bool,
    /// Block-loop unroll depth (1 or 2).
    pub unroll: u8,
}

impl TuneParams {
    /// The hand-tuned defaults the kernels shipped with (8 blocks of
    /// headers, two cache lines of values ahead) — variant 0.
    pub const BASELINE: TuneParams = TuneParams {
        header_prefetch_dist: 8,
        value_prefetch_dist: 2,
        prefetch_x: false,
        unroll: 1,
    };
    /// All software prefetch off — variant 1, what the deprecated
    /// `SPC5_NO_PREFETCH` spelled.
    pub const NO_PREFETCH: TuneParams = TuneParams {
        header_prefetch_dist: 0,
        value_prefetch_dist: 0,
        prefetch_x: false,
        unroll: 1,
    };

    /// Index of this exact parameter set in [`VARIANT_TABLE`], when it
    /// is one of the monomorphized variants.
    pub fn variant_index(&self) -> Option<usize> {
        VARIANT_TABLE.iter().position(|t| t == self)
    }

    /// The variant the dispatcher will actually run: the table index,
    /// or the baseline for out-of-table parameters.
    pub fn resolved_variant(&self) -> usize {
        self.variant_index().unwrap_or(0)
    }

    /// Compact display form `h<dist>v<dist><x><u2>` (e.g. `h8v2`,
    /// `h16v4x`, `h0v0u2`) — used in bench labels and profiles.
    pub fn label(&self) -> String {
        let mut s = format!(
            "h{}v{}",
            self.header_prefetch_dist, self.value_prefetch_dist
        );
        if self.prefetch_x {
            s.push('x');
        }
        if self.unroll > 1 {
            s.push_str(&format!("u{}", self.unroll));
        }
        s
    }
}

impl Default for TuneParams {
    fn default() -> Self {
        TuneParams::BASELINE
    }
}

/// The monomorphized kernel variants: every β kernel is compiled once
/// per entry, and a span call dispatches by table index. Kept small on
/// purpose — 8 variants × ~10 kernels is already ~80 instantiations.
pub const VARIANT_TABLE: [TuneParams; 8] = [
    // 0: baseline — the distances the kernels always shipped with.
    TuneParams::BASELINE,
    // 1: no prefetch (the old SPC5_NO_PREFETCH ablation point).
    TuneParams::NO_PREFETCH,
    // 2: near prefetch — half the baseline distances.
    TuneParams {
        header_prefetch_dist: 4,
        value_prefetch_dist: 1,
        prefetch_x: false,
        unroll: 1,
    },
    // 3: far prefetch — double the baseline distances.
    TuneParams {
        header_prefetch_dist: 16,
        value_prefetch_dist: 4,
        prefetch_x: false,
        unroll: 1,
    },
    // 4: baseline + x-window prefetch.
    TuneParams {
        header_prefetch_dist: 8,
        value_prefetch_dist: 2,
        prefetch_x: true,
        unroll: 1,
    },
    // 5: far + x-window prefetch.
    TuneParams {
        header_prefetch_dist: 16,
        value_prefetch_dist: 4,
        prefetch_x: true,
        unroll: 1,
    },
    // 6: baseline, block loop unrolled ×2.
    TuneParams {
        header_prefetch_dist: 8,
        value_prefetch_dist: 2,
        prefetch_x: false,
        unroll: 2,
    },
    // 7: no prefetch, unrolled ×2 (pure pipelining effect).
    TuneParams {
        header_prefetch_dist: 0,
        value_prefetch_dist: 0,
        prefetch_x: false,
        unroll: 2,
    },
];

/// Process-default variant index: 0 (baseline) unless the deprecated
/// `SPC5_NO_PREFETCH` env hook or [`set_prefetch`] shim changed it.
/// Read once per *span dispatch* on the untuned compatibility entries,
/// never inside a block loop.
static DEFAULT_VARIANT: AtomicU8 = AtomicU8::new(0);
static DEFAULT_ENV: std::sync::Once = std::sync::Once::new();

/// The process-default [`TuneParams`] — what untuned call sites and
/// freshly converted matrices run with. Honors the deprecated
/// `SPC5_NO_PREFETCH` environment variable (mapped to the no-prefetch
/// variant) for backward compatibility.
pub fn default_tune() -> TuneParams {
    DEFAULT_ENV.call_once(|| {
        if std::env::var_os("SPC5_NO_PREFETCH").is_some() {
            DEFAULT_VARIANT.store(1, Ordering::Relaxed);
        }
    });
    VARIANT_TABLE[DEFAULT_VARIANT.load(Ordering::Relaxed) as usize]
}

/// Deprecated shim over the process-default [`TuneParams`]: `true`
/// restores the baseline variant, `false` the no-prefetch variant.
/// Only affects call sites that never resolved an explicit tune — the
/// kernels themselves no longer read any global in the hot loop.
#[deprecated(
    since = "0.2.0",
    note = "prefetch is a per-call TuneParams now; pass an explicit \
            tune (SpmvEngineBuilder::tune / spmv_span_tuned) instead"
)]
pub fn set_prefetch(enabled: bool) {
    // Consume the env hook first so it cannot override this later.
    DEFAULT_ENV.call_once(|| {});
    DEFAULT_VARIANT.store(if enabled { 0 } else { 1 }, Ordering::Relaxed);
}

/// Deprecated: whether the *process-default* variant prefetches. Per
/// call sites may run any [`TuneParams`] regardless of this value.
#[deprecated(
    since = "0.2.0",
    note = "prefetch is a per-call TuneParams now; inspect \
            default_tune() / a plan's tune field instead"
)]
pub fn prefetch_enabled() -> bool {
    default_tune().header_prefetch_dist != 0
}

/// Const-folded view of one [`VARIANT_TABLE`] entry: the kernels read
/// their knobs through these associated consts so every `if` on them
/// disappears at monomorphization.
#[cfg(target_arch = "x86_64")]
pub(crate) struct Var<const V: usize>;

#[cfg(target_arch = "x86_64")]
impl<const V: usize> Var<V> {
    const P: TuneParams = VARIANT_TABLE[V];
    /// Header prefetch distance in blocks (0 = off).
    pub(crate) const HPD: usize = Self::P.header_prefetch_dist as usize;
    /// Values prefetch distance in bytes (0 = off).
    pub(crate) const VPD: usize = Self::P.value_prefetch_dist as usize * 64;
    /// Prefetch the current block's x window.
    pub(crate) const PX: bool = Self::P.prefetch_x;
    /// Unroll the block loop ×2.
    pub(crate) const UNROLL2: bool = Self::P.unroll == 2;
}

/// Issues T0 prefetches for the streams a β kernel walks linearly: the
/// interleaved header stream and the unpadded values stream, at the
/// variant's distances (a zero distance compiles the prefetch away).
/// The `x` window is handled separately ([`TuneParams::prefetch_x`])
/// because its address depends on the block's colidx. Near the span
/// tail the computed addresses run past the end of the streams:
/// `wrapping_add` keeps the pointer arithmetic defined (plain `add`
/// would be UB out of bounds even without a dereference), and the
/// prefetch instruction itself never faults on any address.
#[cfg(target_arch = "x86_64")]
#[inline(always)]
pub(crate) unsafe fn prefetch_streams<T, const V: usize>(
    h: *const u8,
    stride: usize,
    vals: *const T,
) {
    if Var::<V>::HPD != 0 {
        _mm_prefetch::<_MM_HINT_T0>(
            h.wrapping_add(Var::<V>::HPD * stride) as *const i8
        );
    }
    if Var::<V>::VPD != 0 {
        _mm_prefetch::<_MM_HINT_T0>(
            (vals as *const i8).wrapping_add(Var::<V>::VPD),
        );
    }
}

/// Prefetches the current block's `x` window when the variant asks for
/// it (compiled away otherwise). `wrapping_add` for the same reason as
/// [`prefetch_streams`].
#[cfg(target_arch = "x86_64")]
#[inline(always)]
pub(crate) unsafe fn prefetch_x<T, const V: usize>(xp: *const T, col: usize) {
    if Var::<V>::PX {
        _mm_prefetch::<_MM_HINT_T0>(xp.wrapping_add(col) as *const i8);
    }
}

/// A contiguous run of row intervals plus the sub-streams that cover
/// exactly its blocks. `rowptr` holds `n_intervals+1` *absolute* block
/// counters (only differences are used); `headers` starts at the span's
/// first block; `values` at its first value. `y` passed to the kernels
/// is local to the span (`y[0]` = first row of the span) and holds
/// `rows` entries.
#[derive(Clone, Copy)]
pub struct Span<'a, T: Scalar = f64> {
    pub rowptr: &'a [u32],
    pub headers: &'a [u8],
    pub values: &'a [T],
    /// Rows covered by the span (may be < intervals·r at the matrix tail).
    pub rows: usize,
    /// Block rows per interval (`r`).
    pub r: usize,
}

impl<'a, T: Scalar> Span<'a, T> {
    /// The whole matrix as a single span.
    pub fn full(bm: &'a BlockMatrix<T>) -> Span<'a, T> {
        Span {
            rowptr: &bm.block_rowptr,
            headers: &bm.headers,
            values: &bm.values,
            rows: bm.rows,
            r: bm.bs.r,
        }
    }

    /// A thread's sub-span `[interval_begin, interval_end)`.
    pub fn slice(
        bm: &'a BlockMatrix<T>,
        interval_begin: usize,
        interval_end: usize,
        block_begin: usize,
        block_end: usize,
        val_begin: usize,
        val_end: usize,
    ) -> Span<'a, T> {
        let stride = bm.header_stride();
        let row_begin = interval_begin * bm.bs.r;
        let row_end = (interval_end * bm.bs.r).min(bm.rows);
        Span {
            rowptr: &bm.block_rowptr[interval_begin..=interval_end],
            headers: &bm.headers[block_begin * stride..block_end * stride],
            values: &bm.values[val_begin..val_end],
            rows: row_end - row_begin,
            r: bm.bs.r,
        }
    }

    #[inline]
    fn intervals(&self) -> usize {
        self.rowptr.len() - 1
    }

    #[inline]
    fn blocks_in(&self, it: usize) -> usize {
        (self.rowptr[it + 1] - self.rowptr[it]) as usize
    }
}

/// Dispatches the whole-matrix SpMV to the specialized kernel for
/// `bm.bs` through the scalar's dispatch hook, running the matrix's
/// resolved [`TuneParams`] (`bm.tune`). Returns `false` when the block
/// size has no AVX-512 specialization for `T` or the host lacks
/// AVX-512 (caller falls back to the scalar kernel).
pub fn spmv<T: Scalar>(
    bm: &BlockMatrix<T>,
    x: &[T],
    y: &mut [T],
    test: bool,
) -> bool {
    T::spmv_span_simd(Span::full(bm), bm.bs, x, y, test, bm.tune)
}

/// Runs one span through the scalar's AVX-512 dispatch with the
/// process-default tune. `bs` must match the span's underlying format;
/// `y` is span-local. Returns `false` if no specialization exists.
pub fn spmv_span<T: Scalar>(
    span: Span<'_, T>,
    bs: BlockSize,
    x: &[T],
    y: &mut [T],
    test: bool,
) -> bool {
    T::spmv_span_simd(span, bs, x, y, test, default_tune())
}

/// [`spmv_span`] with an explicit kernel variant — the tuned span
/// entry the schedules dispatch through (resolved once per span, never
/// per block).
pub fn spmv_span_tuned<T: Scalar>(
    span: Span<'_, T>,
    bs: BlockSize,
    x: &[T],
    y: &mut [T],
    test: bool,
    tune: TuneParams,
) -> bool {
    T::spmv_span_simd(span, bs, x, y, test, tune)
}

/// [`spmv_span`] with a column-base offset — the column-tiled
/// execution hook ([`crate::formats::tiled`]). A tile-local span
/// stores its header `colidx` relative to the tile's first column
/// `col_base`; starting the `x` window at `col_base` lets every
/// existing masked kernel run unchanged (the masked loads only ever
/// touch lanes of in-matrix columns, so the shortened slice is always
/// long enough).
pub fn spmv_span_at<T: Scalar>(
    span: Span<'_, T>,
    bs: BlockSize,
    col_base: usize,
    x: &[T],
    y: &mut [T],
    test: bool,
) -> bool {
    T::spmv_span_simd(span, bs, &x[col_base..], y, test, default_tune())
}

/// [`spmv_span_at`] with an explicit kernel variant.
pub fn spmv_span_at_tuned<T: Scalar>(
    span: Span<'_, T>,
    bs: BlockSize,
    col_base: usize,
    x: &[T],
    y: &mut [T],
    test: bool,
    tune: TuneParams,
) -> bool {
    T::spmv_span_simd(span, bs, &x[col_base..], y, test, tune)
}

/// Expands one `$f::<V>(..)` call per [`VARIANT_TABLE`] entry —
/// the once-per-span variant dispatch (out-of-table parameters run
/// the baseline).
#[cfg(target_arch = "x86_64")]
macro_rules! dispatch_variant {
    ($v:expr, $f:ident($($args:expr),* $(,)?)) => {
        match $v {
            1 => $f::<1>($($args),*),
            2 => $f::<2>($($args),*),
            3 => $f::<3>($($args),*),
            4 => $f::<4>($($args),*),
            5 => $f::<5>($($args),*),
            6 => $f::<6>($($args),*),
            7 => $f::<7>($($args),*),
            _ => $f::<0>($($args),*),
        }
    };
}

#[cfg(target_arch = "x86_64")]
pub(crate) use dispatch_variant;

/// Double-precision dispatch: the paper's six `vexpandpd` kernels plus
/// the two Algorithm-2 `test` variants, each monomorphized per
/// [`VARIANT_TABLE`] entry and selected here, once per span.
pub fn spmv_span_f64(
    span: Span<'_, f64>,
    bs: BlockSize,
    x: &[f64],
    y: &mut [f64],
    test: bool,
    tune: TuneParams,
) -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        if !crate::util::avx512_available() {
            return false;
        }
        assert!(y.len() >= span.rows);
        let v = tune.resolved_variant();
        // SAFETY: format invariants (validated at conversion) guarantee
        // every masked lane maps inside `x`, every expand stays inside
        // `values`, and every interval row written exists in `y`.
        unsafe {
            match (bs.r, bs.c, test) {
                (1, 8, false) => dispatch_variant!(v, spmv_1x8(span, x, y)),
                (1, 8, true) => spmv_1x8_test(span, x, y),
                (2, 8, false) => dispatch_variant!(v, spmv_2x8(span, x, y)),
                (4, 8, false) => dispatch_variant!(v, spmv_4x8(span, x, y)),
                (2, 4, false) => dispatch_variant!(v, spmv_2x4(span, x, y)),
                (2, 4, true) => spmv_2x4_test(span, x, y),
                (4, 4, false) => dispatch_variant!(v, spmv_4x4(span, x, y)),
                (8, 4, false) => dispatch_variant!(v, spmv_8x4(span, x, y)),
                _ => return false,
            }
        }
        true
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        let _ = (span, bs, x, y, test, tune);
        false
    }
}

/// Single-precision dispatch: the 16-lane `vexpandps` kernels
/// (β(1,16), β(2,16), β(4,16)). There are no Algorithm-2 `test`
/// specializations at 16 lanes — `test = true` falls back to the
/// portable Algorithm-2 kernel by returning `false`.
pub fn spmv_span_f32(
    span: Span<'_, f32>,
    bs: BlockSize,
    x: &[f32],
    y: &mut [f32],
    test: bool,
    tune: TuneParams,
) -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        if test || !crate::util::avx512_available() {
            return false;
        }
        if bs.c != 16 {
            return false;
        }
        assert!(y.len() >= span.rows);
        let v = tune.resolved_variant();
        // SAFETY: same format invariants as the f64 path, with u16
        // masks (validated at conversion: c = 16 lanes, in-bounds).
        unsafe {
            match bs.r {
                1 => dispatch_variant!(v, spmv_f32_1x16(span, x, y)),
                2 => dispatch_variant!(v, spmv_f32_2x16(span, x, y)),
                4 => dispatch_variant!(v, spmv_f32_4x16(span, x, y)),
                _ => return false,
            }
        }
        true
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        let _ = (span, bs, x, y, test, tune);
        false
    }
}

#[cfg(target_arch = "x86_64")]
#[inline(always)]
unsafe fn header_col(h: *const u8) -> usize {
    u32::from_le_bytes([*h, *h.add(1), *h.add(2), *h.add(3)]) as usize
}

#[cfg(target_arch = "x86_64")]
#[inline(always)]
unsafe fn header_mask16(h: *const u8, i: usize) -> u16 {
    u16::from_le_bytes([*h.add(4 + 2 * i), *h.add(5 + 2 * i)])
}

/// Runs a kernel's block loop at the variant's unroll depth. The
/// unrolled pass repeats the *same* body, so the accumulator chain and
/// FMA order are untouched — results stay bit-identical; only the loop
/// control amortizes.
#[cfg(target_arch = "x86_64")]
macro_rules! block_loop {
    ($v:ty, $nb:expr, $body:block) => {{
        let mut b = $nb;
        if <$v>::UNROLL2 {
            while b >= 2 {
                $body
                $body
                b -= 2;
            }
        }
        while b > 0 {
            $body
            b -= 1;
        }
    }};
}

#[cfg(target_arch = "x86_64")]
pub(crate) use block_loop;

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512vl,avx512bw,avx512dq")]
unsafe fn spmv_1x8<const V: usize>(span: Span<'_>, x: &[f64], y: &mut [f64]) {
    let stride = 5;
    let mut h = span.headers.as_ptr();
    let mut vals = span.values.as_ptr();
    let xp = x.as_ptr();
    for row in 0..span.intervals() {
        let nb = span.blocks_in(row);
        if nb == 0 {
            continue;
        }
        let mut acc = _mm512_setzero_pd();
        block_loop!(Var::<V>, nb, {
            prefetch_streams::<_, V>(h, stride, vals);
            let col = header_col(h);
            prefetch_x::<_, V>(xp, col);
            let mask = *h.add(4);
            let v = _mm512_maskz_expandloadu_pd(mask, vals);
            let xv = _mm512_maskz_loadu_pd(mask, xp.add(col));
            acc = _mm512_fmadd_pd(v, xv, acc);
            vals = vals.add(mask.count_ones() as usize);
            h = h.add(stride);
        });
        y[row] += _mm512_reduce_add_pd(acc);
    }
}

/// β(1,8) with the Algorithm-2 test: blocks whose mask is exactly 1
/// (single value at the anchor column — anchoring guarantees bit 0 is
/// always set for r=1) take a scalar multiply; others the vector path.
/// Two loops with cross-jumps, like the paper's `goto` code.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512vl,avx512bw,avx512dq")]
unsafe fn spmv_1x8_test(span: Span<'_>, x: &[f64], y: &mut [f64]) {
    let stride = 5;
    let mut h = span.headers.as_ptr();
    let mut vals = span.values.as_ptr();
    let xp = x.as_ptr();
    for row in 0..span.intervals() {
        let nb = span.blocks_in(row);
        if nb == 0 {
            continue;
        }
        let mut acc = _mm512_setzero_pd();
        let mut sum_scalar = 0.0f64;
        let mut k = 0usize;
        // "loop-for-1": stay scalar while masks are 1.
        loop {
            while k < nb {
                let mask = *h.add(4);
                if mask != 1 {
                    break; // jump to "loop-not-1"
                }
                sum_scalar += *xp.add(header_col(h)) * *vals;
                vals = vals.add(1);
                h = h.add(stride);
                k += 1;
            }
            if k == nb {
                break;
            }
            // "loop-not-1": stay vectorized while masks are not 1.
            while k < nb {
                let mask = *h.add(4);
                if mask == 1 {
                    break; // jump back to "loop-for-1"
                }
                let v = _mm512_maskz_expandloadu_pd(mask, vals);
                let xv = _mm512_maskz_loadu_pd(mask, xp.add(header_col(h)));
                acc = _mm512_fmadd_pd(v, xv, acc);
                vals = vals.add(mask.count_ones() as usize);
                h = h.add(stride);
                k += 1;
            }
            if k == nb {
                break;
            }
        }
        y[row] += sum_scalar + _mm512_reduce_add_pd(acc);
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512vl,avx512bw,avx512dq")]
unsafe fn spmv_2x8<const V: usize>(span: Span<'_>, x: &[f64], y: &mut [f64]) {
    let stride = 6;
    let mut h = span.headers.as_ptr();
    let mut vals = span.values.as_ptr();
    let xp = x.as_ptr();
    for it in 0..span.intervals() {
        let nb = span.blocks_in(it);
        if nb == 0 {
            continue;
        }
        let mut acc0 = _mm512_setzero_pd();
        let mut acc1 = _mm512_setzero_pd();
        block_loop!(Var::<V>, nb, {
            prefetch_streams::<_, V>(h, stride, vals);
            let col = header_col(h);
            prefetch_x::<_, V>(xp, col);
            let m0 = *h.add(4);
            let m1 = *h.add(5);
            let xv = _mm512_maskz_loadu_pd(m0 | m1, xp.add(col));
            let v0 = _mm512_maskz_expandloadu_pd(m0, vals);
            acc0 = _mm512_fmadd_pd(v0, xv, acc0);
            vals = vals.add(m0.count_ones() as usize);
            let v1 = _mm512_maskz_expandloadu_pd(m1, vals);
            acc1 = _mm512_fmadd_pd(v1, xv, acc1);
            vals = vals.add(m1.count_ones() as usize);
            h = h.add(stride);
        });
        let row0 = it * 2;
        let q = _mm256_hadd_pd(fold256(acc0), fold256(acc1));
        let r01 = _mm_add_pd(
            _mm256_castpd256_pd128(q),
            _mm256_extractf128_pd::<1>(q),
        );
        if row0 + 1 < span.rows {
            let yp = y.as_mut_ptr().add(row0);
            _mm_storeu_pd(yp, _mm_add_pd(_mm_loadu_pd(yp), r01));
        } else {
            let mut buf = [0.0f64; 2];
            _mm_storeu_pd(buf.as_mut_ptr(), r01);
            y[row0] += buf[0];
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512vl,avx512bw,avx512dq")]
unsafe fn spmv_4x8<const V: usize>(span: Span<'_>, x: &[f64], y: &mut [f64]) {
    let stride = 8;
    let mut h = span.headers.as_ptr();
    let mut vals = span.values.as_ptr();
    let xp = x.as_ptr();
    for it in 0..span.intervals() {
        let nb = span.blocks_in(it);
        if nb == 0 {
            continue;
        }
        let mut acc = [_mm512_setzero_pd(); 4];
        block_loop!(Var::<V>, nb, {
            prefetch_streams::<_, V>(h, stride, vals);
            let col = header_col(h);
            prefetch_x::<_, V>(xp, col);
            let m = [*h.add(4), *h.add(5), *h.add(6), *h.add(7)];
            let xv =
                _mm512_maskz_loadu_pd(m[0] | m[1] | m[2] | m[3], xp.add(col));
            for i in 0..4 {
                if m[i] != 0 {
                    let v = _mm512_maskz_expandloadu_pd(m[i], vals);
                    acc[i] = _mm512_fmadd_pd(v, xv, acc[i]);
                    vals = vals.add(m[i].count_ones() as usize);
                }
            }
            h = h.add(stride);
        });
        let row0 = it * 4;
        let rows_here = 4.min(span.rows - row0);
        let sums = hsum4_256(
            fold256(acc[0]),
            fold256(acc[1]),
            fold256(acc[2]),
            fold256(acc[3]),
        );
        if rows_here == 4 {
            let yp = y.as_mut_ptr().add(row0);
            _mm256_storeu_pd(yp, _mm256_add_pd(_mm256_loadu_pd(yp), sums));
        } else {
            let mut buf = [0.0f64; 4];
            _mm256_storeu_pd(buf.as_mut_ptr(), sums);
            for i in 0..rows_here {
                y[row0 + i] += buf[i];
            }
        }
    }
}

/// Sums the low (`lo = true`) or high 256-bit half of a 512-bit
/// accumulator — used by the c=4 kernels that pack two block rows per
/// zmm register.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512vl,avx512bw,avx512dq")]
#[inline]
unsafe fn hsum_half(acc: __m512d, lo: bool) -> f64 {
    let mask: __mmask8 = if lo { 0x0F } else { 0xF0 };
    _mm512_mask_reduce_add_pd(mask, acc)
}

/// Folds a 512-bit accumulator into the sum of its two 256-bit halves.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512vl,avx512bw,avx512dq")]
#[inline]
unsafe fn fold256(a: __m512d) -> __m256d {
    _mm256_add_pd(_mm512_castpd512_pd256(a), _mm512_extractf64x4_pd::<1>(a))
}

/// Tree-reduces four row accumulators (256-bit each) into `[r0..r3]`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512vl,avx512bw,avx512dq")]
#[inline]
unsafe fn hsum4_256(
    p0: __m256d,
    p1: __m256d,
    p2: __m256d,
    p3: __m256d,
) -> __m256d {
    let q01 = _mm256_hadd_pd(p0, p1);
    let q23 = _mm256_hadd_pd(p2, p3);
    let lo = _mm256_permute2f128_pd::<0x20>(q01, q23);
    let hi = _mm256_permute2f128_pd::<0x31>(q01, q23);
    _mm256_add_pd(lo, hi)
}

/// Horizontal tree-reduction of two packed-pair accumulators into the
/// four per-row sums `[r0, r1, r2, r3]` (§Perf change 2: one hadd tree
/// instead of four `mask_reduce_add` sequences, enabling a vector `y`
/// update per interval).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512vl,avx512bw,avx512dq")]
#[inline]
unsafe fn hsum4_rows(acc01: __m512d, acc23: __m512d) -> __m256d {
    let p0 = _mm512_castpd512_pd256(acc01); // row 0 partials
    let p1 = _mm512_extractf64x4_pd::<1>(acc01); // row 1
    let p2 = _mm512_castpd512_pd256(acc23); // row 2
    let p3 = _mm512_extractf64x4_pd::<1>(acc23); // row 3
    hsum4_256(p0, p1, p2, p3)
}

/// Broadcasts one masked 4-wide `x` window into both 256-bit halves of
/// a zmm register — shared by every row pair of a c=4 block.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512vl,avx512bw,avx512dq")]
#[inline]
unsafe fn x_window_4(union_mask: u8, xp: *const f64, col: usize) -> __m512d {
    let xv4 = _mm256_maskz_loadu_pd(union_mask, xp.add(col));
    _mm512_insertf64x4::<1>(_mm512_castpd256_pd512(xv4), xv4)
}

/// Shared inner step of the c=4 kernels: one block's pair of rows
/// `(i, i+1)` → combined-mask expand + FMA against the pre-broadcast
/// `x` window (loaded once per block, not per pair — §Perf change 1).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512vl,avx512bw,avx512dq")]
#[inline]
unsafe fn fma_pair_4(
    m_lo: u8,
    m_hi: u8,
    xv: __m512d,
    vals: &mut *const f64,
    acc: __m512d,
) -> __m512d {
    let combined = m_lo | (m_hi << 4);
    if combined == 0 {
        return acc;
    }
    // One expand pulls both rows' values: row i in lanes 0..4 (mask
    // low nibble), row i+1 in lanes 4..8 (high nibble).
    let v = _mm512_maskz_expandloadu_pd(combined, *vals);
    *vals = vals.add(combined.count_ones() as usize);
    _mm512_fmadd_pd(v, xv, acc)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512vl,avx512bw,avx512dq")]
unsafe fn spmv_2x4<const V: usize>(span: Span<'_>, x: &[f64], y: &mut [f64]) {
    let stride = 6;
    let mut h = span.headers.as_ptr();
    let mut vals = span.values.as_ptr();
    let xp = x.as_ptr();
    for it in 0..span.intervals() {
        let nb = span.blocks_in(it);
        if nb == 0 {
            continue;
        }
        let mut acc = _mm512_setzero_pd();
        block_loop!(Var::<V>, nb, {
            prefetch_streams::<_, V>(h, stride, vals);
            let col = header_col(h);
            prefetch_x::<_, V>(xp, col);
            let (m0, m1) = (*h.add(4), *h.add(5));
            let xv = x_window_4(m0 | m1, xp, col);
            acc = fma_pair_4(m0, m1, xv, &mut vals, acc);
            h = h.add(stride);
        });
        let row0 = it * 2;
        let q = _mm256_hadd_pd(
            _mm512_castpd512_pd256(acc),
            _mm512_extractf64x4_pd::<1>(acc),
        );
        let r01 = _mm_add_pd(
            _mm256_castpd256_pd128(q),
            _mm256_extractf128_pd::<1>(q),
        );
        if row0 + 1 < span.rows {
            let yp = y.as_mut_ptr().add(row0);
            _mm_storeu_pd(yp, _mm_add_pd(_mm_loadu_pd(yp), r01));
        } else {
            let mut buf = [0.0f64; 2];
            _mm_storeu_pd(buf.as_mut_ptr(), r01);
            y[row0] += buf[0];
        }
    }
}

/// β(2,4) with the Algorithm-2 test (single-value blocks take the
/// scalar path).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512vl,avx512bw,avx512dq")]
unsafe fn spmv_2x4_test(span: Span<'_>, x: &[f64], y: &mut [f64]) {
    let stride = 6;
    let mut h = span.headers.as_ptr();
    let mut vals = span.values.as_ptr();
    let xp = x.as_ptr();
    for it in 0..span.intervals() {
        let nb = span.blocks_in(it);
        if nb == 0 {
            continue;
        }
        let mut acc = _mm512_setzero_pd();
        let mut s0 = 0.0f64;
        let mut s1 = 0.0f64;
        let mut k = 0usize;
        loop {
            // Scalar loop: combined mask has a single bit.
            while k < nb {
                let (m0, m1) = (*h.add(4), *h.add(5));
                if (m0 | (m1 << 4)).count_ones() != 1 {
                    break;
                }
                let col = header_col(h);
                if m0 != 0 {
                    s0 += *xp.add(col + m0.trailing_zeros() as usize) * *vals;
                } else {
                    s1 += *xp.add(col + m1.trailing_zeros() as usize) * *vals;
                }
                vals = vals.add(1);
                h = h.add(stride);
                k += 1;
            }
            if k == nb {
                break;
            }
            // Vector loop.
            while k < nb {
                let (m0, m1) = (*h.add(4), *h.add(5));
                if (m0 | (m1 << 4)).count_ones() == 1 {
                    break;
                }
                let col = header_col(h);
                let xv = x_window_4(m0 | m1, xp, col);
                acc = fma_pair_4(m0, m1, xv, &mut vals, acc);
                h = h.add(stride);
                k += 1;
            }
            if k == nb {
                break;
            }
        }
        let row0 = it * 2;
        y[row0] += s0 + hsum_half(acc, true);
        if row0 + 1 < span.rows {
            y[row0 + 1] += s1 + hsum_half(acc, false);
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512vl,avx512bw,avx512dq")]
unsafe fn spmv_4x4<const V: usize>(span: Span<'_>, x: &[f64], y: &mut [f64]) {
    let stride = 8;
    let mut h = span.headers.as_ptr();
    let mut vals = span.values.as_ptr();
    let xp = x.as_ptr();
    for it in 0..span.intervals() {
        let nb = span.blocks_in(it);
        if nb == 0 {
            continue;
        }
        let mut acc01 = _mm512_setzero_pd();
        let mut acc23 = _mm512_setzero_pd();
        block_loop!(Var::<V>, nb, {
            prefetch_streams::<_, V>(h, stride, vals);
            let col = header_col(h);
            prefetch_x::<_, V>(xp, col);
            let m = [*h.add(4), *h.add(5), *h.add(6), *h.add(7)];
            let xv = x_window_4(m[0] | m[1] | m[2] | m[3], xp, col);
            acc01 = fma_pair_4(m[0], m[1], xv, &mut vals, acc01);
            acc23 = fma_pair_4(m[2], m[3], xv, &mut vals, acc23);
            h = h.add(stride);
        });
        let row0 = it * 4;
        let rows_here = 4.min(span.rows - row0);
        let sums = hsum4_rows(acc01, acc23);
        if rows_here == 4 {
            // Vector y update: one masked load/add/store for the interval.
            let yp = y.as_mut_ptr().add(row0);
            let cur = _mm256_loadu_pd(yp);
            _mm256_storeu_pd(yp, _mm256_add_pd(cur, sums));
        } else {
            let mut buf = [0.0f64; 4];
            _mm256_storeu_pd(buf.as_mut_ptr(), sums);
            for i in 0..rows_here {
                y[row0 + i] += buf[i];
            }
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512vl,avx512bw,avx512dq")]
unsafe fn spmv_8x4<const V: usize>(span: Span<'_>, x: &[f64], y: &mut [f64]) {
    let stride = 12;
    let mut h = span.headers.as_ptr();
    let mut vals = span.values.as_ptr();
    let xp = x.as_ptr();
    for it in 0..span.intervals() {
        let nb = span.blocks_in(it);
        if nb == 0 {
            continue;
        }
        let mut acc = [_mm512_setzero_pd(); 4];
        block_loop!(Var::<V>, nb, {
            prefetch_streams::<_, V>(h, stride, vals);
            let col = header_col(h);
            prefetch_x::<_, V>(xp, col);
            let m: [u8; 8] = [
                *h.add(4),
                *h.add(5),
                *h.add(6),
                *h.add(7),
                *h.add(8),
                *h.add(9),
                *h.add(10),
                *h.add(11),
            ];
            let union = m.iter().fold(0u8, |a, &b| a | b);
            let xv = x_window_4(union, xp, col);
            for p in 0..4 {
                acc[p] = fma_pair_4(m[2 * p], m[2 * p + 1], xv, &mut vals, acc[p]);
            }
            h = h.add(stride);
        });
        let row0 = it * 8;
        let rows_here = 8.min(span.rows - row0);
        let sums0 = hsum4_rows(acc[0], acc[1]);
        let sums1 = hsum4_rows(acc[2], acc[3]);
        if rows_here == 8 {
            let yp = y.as_mut_ptr().add(row0);
            _mm256_storeu_pd(yp, _mm256_add_pd(_mm256_loadu_pd(yp), sums0));
            let yp4 = yp.add(4);
            _mm256_storeu_pd(yp4, _mm256_add_pd(_mm256_loadu_pd(yp4), sums1));
        } else {
            let mut buf = [0.0f64; 8];
            _mm256_storeu_pd(buf.as_mut_ptr(), sums0);
            _mm256_storeu_pd(buf.as_mut_ptr().add(4), sums1);
            for i in 0..rows_here {
                y[row0 + i] += buf[i];
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Single-precision 16-lane kernels (`vexpandps`, u16 masks).
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512vl,avx512bw,avx512dq")]
unsafe fn spmv_f32_1x16<const V: usize>(
    span: Span<'_, f32>,
    x: &[f32],
    y: &mut [f32],
) {
    let stride = 6; // 4B colidx + one u16 mask
    let mut h = span.headers.as_ptr();
    let mut vals = span.values.as_ptr();
    let xp = x.as_ptr();
    for row in 0..span.intervals() {
        let nb = span.blocks_in(row);
        if nb == 0 {
            continue;
        }
        let mut acc = _mm512_setzero_ps();
        block_loop!(Var::<V>, nb, {
            prefetch_streams::<_, V>(h, stride, vals);
            let col = header_col(h);
            prefetch_x::<_, V>(xp, col);
            let mask = header_mask16(h, 0);
            let v = _mm512_maskz_expandloadu_ps(mask, vals);
            let xv = _mm512_maskz_loadu_ps(mask, xp.add(col));
            acc = _mm512_fmadd_ps(v, xv, acc);
            vals = vals.add(mask.count_ones() as usize);
            h = h.add(stride);
        });
        y[row] += _mm512_reduce_add_ps(acc);
    }
}

/// Shared r×16 kernel body for r ∈ {2, 4} (const-generic unrolled).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512vl,avx512bw,avx512dq")]
unsafe fn spmv_f32_rx16<const R: usize, const V: usize>(
    span: Span<'_, f32>,
    x: &[f32],
    y: &mut [f32],
) {
    let stride = 4 + 2 * R;
    let mut h = span.headers.as_ptr();
    let mut vals = span.values.as_ptr();
    let xp = x.as_ptr();
    for it in 0..span.intervals() {
        let nb = span.blocks_in(it);
        if nb == 0 {
            continue;
        }
        let mut acc = [_mm512_setzero_ps(); R];
        block_loop!(Var::<V>, nb, {
            prefetch_streams::<_, V>(h, stride, vals);
            let col = header_col(h);
            prefetch_x::<_, V>(xp, col);
            let mut union = 0u16;
            let mut masks = [0u16; R];
            for i in 0..R {
                masks[i] = header_mask16(h, i);
                union |= masks[i];
            }
            let xv = _mm512_maskz_loadu_ps(union, xp.add(col));
            for i in 0..R {
                if masks[i] != 0 {
                    let v = _mm512_maskz_expandloadu_ps(masks[i], vals);
                    acc[i] = _mm512_fmadd_ps(v, xv, acc[i]);
                    vals = vals.add(masks[i].count_ones() as usize);
                }
            }
            h = h.add(stride);
        });
        let row0 = it * R;
        let rows_here = R.min(span.rows - row0);
        for i in 0..rows_here {
            y[row0 + i] += _mm512_reduce_add_ps(acc[i]);
        }
    }
}

/// [`spmv_f32_rx16`] at `R = 2` — a named alias so the variant
/// dispatch macro can instantiate it per table entry.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512vl,avx512bw,avx512dq")]
unsafe fn spmv_f32_2x16<const V: usize>(
    span: Span<'_, f32>,
    x: &[f32],
    y: &mut [f32],
) {
    spmv_f32_rx16::<2, V>(span, x, y)
}

/// [`spmv_f32_rx16`] at `R = 4`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512vl,avx512bw,avx512dq")]
unsafe fn spmv_f32_4x16<const V: usize>(
    span: Span<'_, f32>,
    x: &[f32],
    y: &mut [f32],
) {
    spmv_f32_rx16::<4, V>(span, x, y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::csr_to_block;
    use crate::matrix::{suite, Coo, Csr};

    fn check(csr: &Csr, bs: BlockSize, test: bool) {
        if !crate::util::avx512_available() {
            return; // skipped on non-AVX-512 hosts
        }
        let bm = csr_to_block(csr, bs).unwrap();
        let x: Vec<f64> =
            (0..csr.cols).map(|i| ((i * 13) % 17) as f64 - 8.0).collect();
        let mut want = vec![0.0; csr.rows];
        csr.spmv_ref(&x, &mut want);
        let mut got = vec![0.0; csr.rows];
        assert!(spmv(&bm, &x, &mut got, test), "no kernel for {bs} test={test}");
        for i in 0..csr.rows {
            assert!(
                (got[i] - want[i]).abs() <= 1e-9 * want[i].abs().max(1.0),
                "{bs} test={test} row {i}: {} vs {}",
                got[i],
                want[i]
            );
        }
    }

    fn check_f32(csr: &Csr, bs: BlockSize) {
        if !crate::util::avx512_available() {
            return;
        }
        let csr32: Csr<f32> = csr.to_precision();
        let bm = csr_to_block(&csr32, bs).unwrap();
        let x: Vec<f32> =
            (0..csr.cols).map(|i| ((i * 7) % 9) as f32 * 0.25 - 1.0).collect();
        // f64 reference on the f32-truncated values for a fair compare.
        let x64: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        let want64 = csr32.to_dense().matvec(&x64);
        let mut got = vec![0.0f32; csr.rows];
        assert!(spmv(&bm, &x, &mut got, false), "no f32 kernel for {bs}");
        for i in 0..csr.rows {
            let w = want64[i] as f32;
            assert!(
                (got[i] - w).abs() <= 2e-4 * w.abs().max(1.0),
                "f32 {bs} row {i}: {} vs {w}",
                got[i]
            );
        }
    }

    #[test]
    fn all_kernels_match_reference() {
        for sm in suite::test_subset() {
            for bs in BlockSize::PAPER_SIZES {
                check(&sm.csr, bs, false);
            }
            check(&sm.csr, BlockSize::new(1, 8), true);
            check(&sm.csr, BlockSize::new(2, 4), true);
        }
    }

    #[test]
    fn every_variant_is_bit_identical() {
        // Tuning knobs are pure scheduling hints: every monomorphized
        // variant must produce bit-identical sums on every block size
        // (prefetches touch no data; unroll ×2 repeats the same body so
        // the accumulator chain — and FP rounding — is unchanged).
        let csr = suite::fem_blocked(400, 3, 6, 21);
        let x: Vec<f64> = (0..csr.cols).map(|i| (i % 11) as f64 - 5.0).collect();
        for bs in BlockSize::PAPER_SIZES {
            let bm = csr_to_block(&csr, bs).unwrap();
            let mut y0 = vec![0.0; csr.rows];
            let ran0 = spmv_span_tuned(
                Span::full(&bm),
                bs,
                &x,
                &mut y0,
                false,
                VARIANT_TABLE[0],
            );
            for (v, &tune) in VARIANT_TABLE.iter().enumerate().skip(1) {
                let mut y = vec![0.0; csr.rows];
                let ran = spmv_span_tuned(
                    Span::full(&bm),
                    bs,
                    &x,
                    &mut y,
                    false,
                    tune,
                );
                assert_eq!(ran0, ran, "{bs} variant {v}");
                if ran0 {
                    assert_eq!(y0, y, "{bs} variant {v} ({})", tune.label());
                }
            }
        }
    }

    #[test]
    fn variant_table_roundtrips_and_is_distinct() {
        for (i, t) in VARIANT_TABLE.iter().enumerate() {
            assert_eq!(t.variant_index(), Some(i));
            assert_eq!(t.resolved_variant(), i);
        }
        // Out-of-table parameters run the baseline variant.
        let odd = TuneParams {
            header_prefetch_dist: 3,
            value_prefetch_dist: 7,
            prefetch_x: true,
            unroll: 2,
        };
        assert_eq!(odd.variant_index(), None);
        assert_eq!(odd.resolved_variant(), 0);
        assert_eq!(TuneParams::default(), VARIANT_TABLE[0]);
        assert_eq!(TuneParams::NO_PREFETCH, VARIANT_TABLE[1]);
        assert_eq!(VARIANT_TABLE[0].label(), "h8v2");
        assert_eq!(VARIANT_TABLE[5].label(), "h16v4x");
        assert_eq!(VARIANT_TABLE[7].label(), "h0v0u2");
    }

    #[test]
    fn f32_kernels_match_reference() {
        for sm in suite::test_subset().iter().take(6) {
            if sm.csr.rows > 3000 {
                continue; // dense oracle stays small
            }
            for bs in BlockSize::F32_WIDE_SIZES {
                check_f32(&sm.csr, bs);
            }
        }
    }

    #[test]
    fn f32_edge_column_masked_load() {
        let mut coo = Coo::new(5, 17);
        for r in 0..5 {
            coo.push(r, 16, 1.5 + r as f64);
        }
        let csr = coo.to_csr().unwrap();
        for bs in [BlockSize::new(1, 16), BlockSize::new(4, 16)] {
            check_f32(&csr, bs);
        }
    }

    #[test]
    fn f32_non_specialized_sizes_return_false() {
        let csr32: Csr<f32> = suite::poisson2d(6).to_precision();
        let bm = csr_to_block(&csr32, BlockSize::new(2, 8)).unwrap();
        let x = vec![1.0f32; csr32.cols];
        let mut y = vec![0.0f32; csr32.rows];
        // c != 16 has no f32 AVX-512 specialization.
        assert!(!spmv(&bm, &x, &mut y, false));
    }

    #[test]
    fn block_at_last_column() {
        // Block anchored at the very last column: the masked x load must
        // not fault or read junk.
        let mut coo = Coo::new(16, 9);
        for r in 0..16 {
            coo.push(r, 8, (r + 1) as f64);
        }
        let csr = coo.to_csr().unwrap();
        for bs in BlockSize::PAPER_SIZES {
            check(&csr, bs, false);
        }
        check(&csr, BlockSize::new(1, 8), true);
        check(&csr, BlockSize::new(2, 4), true);
    }

    #[test]
    fn single_row_matrix() {
        let mut coo = Coo::new(1, 64);
        for c in [0usize, 3, 9, 10, 11, 40, 63] {
            coo.push(0, c, c as f64 + 0.5);
        }
        let csr = coo.to_csr().unwrap();
        for bs in BlockSize::PAPER_SIZES {
            check(&csr, bs, false);
        }
        for bs in BlockSize::F32_WIDE_SIZES {
            check_f32(&csr, bs);
        }
    }

    #[test]
    fn rows_not_multiple_of_r() {
        let mut coo = Coo::new(13, 20);
        for r in 0..13 {
            coo.push(r, r, 1.0);
            coo.push(r, 19, 2.0);
        }
        let csr = coo.to_csr().unwrap();
        for bs in BlockSize::PAPER_SIZES {
            check(&csr, bs, false);
        }
        for bs in BlockSize::F32_WIDE_SIZES {
            check_f32(&csr, bs);
        }
    }

    #[test]
    fn alternating_single_multi_blocks_test_variant() {
        // Worst case for Algorithm 2: block kinds alternate, forcing a
        // jump at every block.
        let mut coo = Coo::new(1, 400);
        let mut col = 0usize;
        let mut toggle = false;
        while col + 8 < 400 {
            if toggle {
                for k in 0..5 {
                    coo.push(0, col + k, (col + k) as f64 * 0.1 + 1.0);
                }
            } else {
                coo.push(0, col, col as f64 * 0.1 + 1.0);
            }
            toggle = !toggle;
            col += 16;
        }
        let csr = coo.to_csr().unwrap();
        check(&csr, BlockSize::new(1, 8), true);
        check(&csr, BlockSize::new(2, 4), true);
    }

    #[test]
    fn dense_matrix_full_masks() {
        let csr = suite::dense(32, 5);
        for bs in BlockSize::PAPER_SIZES {
            check(&csr, bs, false);
        }
        for bs in BlockSize::F32_WIDE_SIZES {
            check_f32(&csr, bs);
        }
    }

    #[test]
    fn empty_and_sparse_intervals() {
        // Rows with no blocks at all (paper Fig. 1 row 5).
        let csr = Csr::from_raw(
            9,
            9,
            vec![0, 2, 2, 2, 3, 3, 3, 3, 3, 4],
            vec![0, 8, 4, 0],
            vec![1.0, 2.0, 3.0, 4.0],
        )
        .unwrap();
        for bs in BlockSize::PAPER_SIZES {
            check(&csr, bs, false);
        }
        check(&csr, BlockSize::new(1, 8), true);
        check(&csr, BlockSize::new(2, 4), true);
        for bs in BlockSize::F32_WIDE_SIZES {
            check_f32(&csr, bs);
        }
    }

    #[test]
    fn span_slices_compose_to_full() {
        if !crate::util::avx512_available() {
            return;
        }
        // Running two half-spans must equal the full-matrix result.
        let csr = suite::poisson2d(24);
        for bs in [BlockSize::new(2, 4), BlockSize::new(4, 8)] {
            let bm = csr_to_block(&csr, bs).unwrap();
            let x: Vec<f64> = (0..csr.cols).map(|i| (i % 5) as f64).collect();
            let spans = crate::parallel::partition_intervals(&bm, 2);
            let mut y = vec![0.0; csr.rows];
            for s in &spans {
                let val_end = spans
                    .iter()
                    .find(|t| t.interval_begin == s.interval_end)
                    .map(|t| t.val_begin)
                    .unwrap_or(bm.values.len());
                let sp = Span::slice(
                    &bm,
                    s.interval_begin,
                    s.interval_end,
                    s.block_begin,
                    s.block_end,
                    s.val_begin,
                    val_end,
                );
                assert!(spmv_span(
                    sp,
                    bs,
                    &x,
                    &mut y[s.row_begin..s.row_end],
                    false
                ));
            }
            let mut want = vec![0.0; csr.rows];
            csr.spmv_ref(&x, &mut want);
            for i in 0..csr.rows {
                assert!((y[i] - want[i]).abs() < 1e-9, "{bs} row {i}");
            }
        }
    }
}
