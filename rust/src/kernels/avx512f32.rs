//! Single-precision AVX-512 kernels: `vexpandps` over 16-lane blocks.
//!
//! The f32 counterpart of [`super::avx512`] for the `β32(r,c)` format
//! (`c ≤ 16`, `u16` masks): `_mm512_maskz_expandloadu_ps` inflates up
//! to 16 packed floats per block row — the paper's "16 single
//! precision values" lane count, which it mentions but never ships
//! kernels for. Specializations: β32(1,16), β32(2,16), β32(4,16);
//! other sizes fall back to [`spmv32_generic`].

#![allow(unsafe_code)]

use crate::formats::block32::BlockMatrix32;

#[cfg(target_arch = "x86_64")]
use std::arch::x86_64::*;

/// Scalar reference / fallback for any `β32(r,c)`.
pub fn spmv32_generic(bm: &BlockMatrix32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), bm.cols);
    assert_eq!(y.len(), bm.rows);
    let (r, c) = (bm.bs.r, bm.bs.c);
    let mut idx_val = 0usize;
    let mut sums = vec![0.0f32; r];
    for it in 0..bm.intervals() {
        let row0 = it * r;
        let (a, b) =
            (bm.block_rowptr[it] as usize, bm.block_rowptr[it + 1] as usize);
        sums.iter_mut().for_each(|s| *s = 0.0);
        for blk in a..b {
            let col0 = bm.block_colidx[blk] as usize;
            for i in 0..r {
                let mask = bm.block_masks[blk * r + i];
                if mask == 0 {
                    continue;
                }
                for k in 0..c {
                    if mask & (1 << k) != 0 {
                        sums[i] += x[col0 + k] * bm.values[idx_val];
                        idx_val += 1;
                    }
                }
            }
        }
        let rows_here = r.min(bm.rows - row0);
        for i in 0..rows_here {
            y[row0 + i] += sums[i];
        }
    }
    debug_assert_eq!(idx_val, bm.values.len());
}

/// Dispatch: AVX-512 when available and specialized, else scalar.
pub fn spmv32(bm: &BlockMatrix32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), bm.cols);
    assert_eq!(y.len(), bm.rows);
    #[cfg(target_arch = "x86_64")]
    {
        if crate::util::avx512_available() && bm.bs.c == 16 && bm.bs.r <= 4 {
            // SAFETY: format invariants validated at conversion.
            unsafe {
                match bm.bs.r {
                    1 => spmv32_1x16(bm, x, y),
                    2 => spmv32_rx16::<2>(bm, x, y),
                    4 => spmv32_rx16::<4>(bm, x, y),
                    _ => unreachable!(),
                }
            }
            return;
        }
    }
    spmv32_generic(bm, x, y);
}

#[cfg(target_arch = "x86_64")]
#[inline(always)]
unsafe fn header32_col(h: *const u8) -> usize {
    u32::from_le_bytes([*h, *h.add(1), *h.add(2), *h.add(3)]) as usize
}

#[cfg(target_arch = "x86_64")]
#[inline(always)]
unsafe fn header32_mask(h: *const u8, i: usize) -> u16 {
    u16::from_le_bytes([*h.add(4 + 2 * i), *h.add(5 + 2 * i)])
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512vl,avx512bw,avx512dq")]
unsafe fn spmv32_1x16(bm: &BlockMatrix32, x: &[f32], y: &mut [f32]) {
    let stride = bm.header_stride(); // 6
    let mut h = bm.headers.as_ptr();
    let mut vals = bm.values.as_ptr();
    let xp = x.as_ptr();
    for row in 0..bm.intervals() {
        let nb = (bm.block_rowptr[row + 1] - bm.block_rowptr[row]) as usize;
        if nb == 0 {
            continue;
        }
        let mut acc = _mm512_setzero_ps();
        for _ in 0..nb {
            let col = header32_col(h);
            let mask = header32_mask(h, 0);
            let v = _mm512_maskz_expandloadu_ps(mask, vals);
            let xv = _mm512_maskz_loadu_ps(mask, xp.add(col));
            acc = _mm512_fmadd_ps(v, xv, acc);
            vals = vals.add(mask.count_ones() as usize);
            h = h.add(stride);
        }
        y[row] += _mm512_reduce_add_ps(acc);
    }
}

/// Shared r×16 kernel body for r ∈ {2, 4} (const-generic unrolled).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512vl,avx512bw,avx512dq")]
unsafe fn spmv32_rx16<const R: usize>(
    bm: &BlockMatrix32,
    x: &[f32],
    y: &mut [f32],
) {
    let stride = bm.header_stride(); // 4 + 2R
    let mut h = bm.headers.as_ptr();
    let mut vals = bm.values.as_ptr();
    let xp = x.as_ptr();
    for it in 0..bm.intervals() {
        let nb = (bm.block_rowptr[it + 1] - bm.block_rowptr[it]) as usize;
        if nb == 0 {
            continue;
        }
        let mut acc = [_mm512_setzero_ps(); R];
        for _ in 0..nb {
            let col = header32_col(h);
            let mut union = 0u16;
            let mut masks = [0u16; R];
            for i in 0..R {
                masks[i] = header32_mask(h, i);
                union |= masks[i];
            }
            let xv = _mm512_maskz_loadu_ps(union, xp.add(col));
            for i in 0..R {
                if masks[i] != 0 {
                    let v = _mm512_maskz_expandloadu_ps(masks[i], vals);
                    acc[i] = _mm512_fmadd_ps(v, xv, acc[i]);
                    vals = vals.add(masks[i].count_ones() as usize);
                }
            }
            h = h.add(stride);
        }
        let row0 = it * R;
        let rows_here = R.min(bm.rows - row0);
        for i in 0..rows_here {
            y[row0 + i] += _mm512_reduce_add_ps(acc[i]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::block32::csr_to_block32;
    use crate::formats::BlockSize;
    use crate::matrix::{suite, Coo};

    fn check(csr: &crate::matrix::Csr, bs: BlockSize) {
        let bm = csr_to_block32(csr, bs).unwrap();
        let x: Vec<f32> =
            (0..csr.cols).map(|i| ((i * 7) % 9) as f32 * 0.25 - 1.0).collect();
        let x64: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        let mut want64 = vec![0.0f64; csr.rows];
        // f64 reference on the f32-truncated values for a fair compare.
        let mut idx = 0usize;
        let mut csr32 = csr.clone();
        for v in &mut csr32.values {
            *v = *v as f32 as f64;
            idx += 1;
        }
        assert_eq!(idx, csr.nnz());
        csr32.spmv_ref(&x64, &mut want64);

        let mut got = vec![0.0f32; csr.rows];
        spmv32(&bm, &x, &mut got);
        for i in 0..csr.rows {
            let w = want64[i] as f32;
            assert!(
                (got[i] - w).abs() <= 2e-4 * w.abs().max(1.0),
                "{bs} row {i}: {} vs {w}",
                got[i]
            );
        }
        // Scalar path must agree with the dispatched path bit-for-bit
        // in structure (same summation order per row), so compare
        // loosely as well.
        let mut got_scalar = vec![0.0f32; csr.rows];
        spmv32_generic(&bm, &x, &mut got_scalar);
        for i in 0..csr.rows {
            assert!(
                (got[i] - got_scalar[i]).abs()
                    <= 2e-4 * got_scalar[i].abs().max(1.0),
                "{bs} scalar/simd row {i}"
            );
        }
    }

    #[test]
    fn f32_kernels_match_reference() {
        for sm in suite::test_subset().iter().take(6) {
            for bs in [
                BlockSize::new(1, 16),
                BlockSize::new(2, 16),
                BlockSize::new(4, 16),
                BlockSize::new(2, 8), // generic fallback path
            ] {
                check(&sm.csr, bs);
            }
        }
    }

    #[test]
    fn edge_column_masked_load() {
        let mut coo = Coo::new(5, 17);
        for r in 0..5 {
            coo.push(r, 16, 1.5 + r as f64);
        }
        let csr = coo.to_csr().unwrap();
        for bs in [BlockSize::new(1, 16), BlockSize::new(4, 16)] {
            check(&csr, bs);
        }
    }

    #[test]
    fn sixteen_wide_blocks_halve_block_count() {
        let csr = suite::dense(64, 3);
        let b8 = csr_to_block32(&csr, BlockSize::new(1, 8)).unwrap();
        let b16 = csr_to_block32(&csr, BlockSize::new(1, 16)).unwrap();
        assert_eq!(b16.n_blocks() * 2, b8.n_blocks());
    }
}
