//! SpMV kernels behind one precision-generic dispatch.
//!
//! - [`scalar`] — the generic Algorithm 1 for any `β(r,c)` plus the
//!   Algorithm 2 "test" variants; portable, used as fallback and as the
//!   differential-testing reference.
//! - [`avx512`] — the optimized kernels: the paper's `vexpandpd`
//!   routines for the six f64 block sizes and the 16-lane `vexpandps`
//!   routines for the f32 `β(r,16)` sizes, walking the interleaved
//!   header stream exactly like the published assembly (Code 1).
//! - [`csr`] — tuned CSR baseline (the "Intel MKL" stand-in).
//! - [`csr5`] — re-implementation of the CSR5 format and kernel
//!   (Liu & Vinter 2015), the paper's second comparator.
//! - [`sptrsv`] — masked triangular solves (forward/backward
//!   substitution) over the same β block storage, optionally
//!   level-scheduled on the worker pool.
//! - [`symgs`] — Gauss–Seidel sweeps (forward/backward/symmetric) over
//!   a [`crate::matrix::TriangularSplit`], the SymGS preconditioner
//!   workhorse.
//!
//! All SpMV kernels compute `y += A·x` (accumulating, like the paper's
//! `vaddsd` into `y`), so callers zero `y` when they need `y = A·x`.

pub mod avx512;
pub mod csr;
pub mod csr5;
pub mod scalar;
pub mod spmm;
pub mod sptrsv;
pub mod symgs;

pub use avx512::{default_tune, TuneParams, VARIANT_TABLE};

use crate::formats::{BlockMatrix, BlockSize};
use crate::matrix::Csr;
use crate::scalar::Scalar;

/// Identifies one of the kernels benchmarked in the paper (Fig. 3/4
/// legend). `Test` variants are Algorithm 2 (scalar/vector dual loop).
///
/// The kind is precision-agnostic: `Beta(1, 16)` is only *servable* by
/// the f32 stack (16 lanes), which the format layer enforces at
/// conversion time.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum KernelKind {
    /// CSR row loop — the MKL stand-in baseline.
    Csr,
    /// CSR5 (Liu & Vinter 2015) comparator.
    Csr5,
    /// `β(r,c)` kernel without the single-value test.
    Beta(u8, u8),
    /// `β(r,c)` kernel with the Algorithm-2 test.
    BetaTest(u8, u8),
    /// Heterogeneous row-panel schedule: each panel independently
    /// chooses a `β(r,c)` blocking or stays CSR
    /// ([`crate::formats::HybridMatrix`]).
    Hybrid,
    /// Column-tiled (cache-blocked) hybrid schedule
    /// ([`crate::formats::TiledHybrid`]): the hybrid row-panel choices
    /// executed `(panel, tile)`-wise so each pass touches only a
    /// tile-sized window of `x`. The payload is the tile width in
    /// columns; `0` means auto-size to the detected L2 share
    /// ([`crate::formats::auto_tile_cols`]). Spelled `tiled` /
    /// `tiled(n)`.
    Tiled(u32),
}

impl KernelKind {
    /// The eight SPC5 kernels of the paper's evaluation:
    /// β(1,8), β(1,8)test, β(2,4), β(2,4)test, β(2,8), β(4,4), β(4,8), β(8,4).
    pub const SPC5_KERNELS: [KernelKind; 8] = [
        KernelKind::Beta(1, 8),
        KernelKind::BetaTest(1, 8),
        KernelKind::Beta(2, 4),
        KernelKind::BetaTest(2, 4),
        KernelKind::Beta(2, 8),
        KernelKind::Beta(4, 4),
        KernelKind::Beta(4, 8),
        KernelKind::Beta(8, 4),
    ];

    /// All kernels including baselines (the full Fig. 3 bar group).
    pub const ALL: [KernelKind; 10] = [
        KernelKind::Csr,
        KernelKind::Csr5,
        KernelKind::Beta(1, 8),
        KernelKind::BetaTest(1, 8),
        KernelKind::Beta(2, 4),
        KernelKind::BetaTest(2, 4),
        KernelKind::Beta(2, 8),
        KernelKind::Beta(4, 4),
        KernelKind::Beta(4, 8),
        KernelKind::Beta(8, 4),
    ];

    /// The 16-lane kernels only the f32 stack serves:
    /// β(1,16), β(2,16), β(4,16).
    pub const F32_WIDE_KERNELS: [KernelKind; 3] = [
        KernelKind::Beta(1, 16),
        KernelKind::Beta(2, 16),
        KernelKind::Beta(4, 16),
    ];

    /// Block size of a β kernel, if any.
    pub fn block_size(&self) -> Option<BlockSize> {
        match *self {
            KernelKind::Beta(r, c) | KernelKind::BetaTest(r, c) => {
                Some(BlockSize::new(r as usize, c as usize))
            }
            _ => None,
        }
    }

    /// Tile width of a tiled kernel (`0` = flat / auto-sized).
    pub fn tile_width(&self) -> usize {
        match *self {
            KernelKind::Tiled(w) => w as usize,
            _ => 0,
        }
    }

    /// Parses e.g. `csr`, `csr5`, `b(2,8)`, `b(1,8)test`, the f32
    /// spellings `b32(1,16)` / `beta32(2,16)test`, and the tiled
    /// schedule `tiled` / `tiled(4096)`. Trailing garbage (`b(2,8)x`,
    /// `b(2,8,9)`) is rejected.
    pub fn parse(s: &str) -> Option<KernelKind> {
        let t = s.trim().to_ascii_lowercase();
        match t.as_str() {
            "csr" => return Some(KernelKind::Csr),
            "csr5" => return Some(KernelKind::Csr5),
            "hybrid" => return Some(KernelKind::Hybrid),
            "tiled" => return Some(KernelKind::Tiled(0)),
            _ => {}
        }
        if let Some(inner) =
            t.strip_prefix("tiled(").and_then(|s| s.strip_suffix(')'))
        {
            let w: u32 = inner.trim().parse().ok()?;
            return Some(KernelKind::Tiled(w));
        }
        let (body, test) = match t.strip_suffix("test") {
            Some(b) => (b.trim_end_matches('_').to_string(), true),
            None => (t, false),
        };
        let inner = body
            .strip_prefix("b32(")
            .or_else(|| body.strip_prefix("beta32("))
            .or_else(|| body.strip_prefix("b("))
            .or_else(|| body.strip_prefix("beta("))?
            .strip_suffix(')')?;
        let mut parts = inner.split(',');
        let r: u8 = parts.next()?.trim().parse().ok()?;
        let c: u8 = parts.next()?.trim().parse().ok()?;
        if parts.next().is_some() {
            return None; // `b(2,8,9)`-style garbage
        }
        Some(if test {
            KernelKind::BetaTest(r, c)
        } else {
            KernelKind::Beta(r, c)
        })
    }
}

impl std::fmt::Display for KernelKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            KernelKind::Csr => write!(f, "csr"),
            KernelKind::Csr5 => write!(f, "csr5"),
            KernelKind::Beta(r, c) => write!(f, "b({r},{c})"),
            KernelKind::BetaTest(r, c) => write!(f, "b({r},{c})test"),
            KernelKind::Hybrid => write!(f, "hybrid"),
            KernelKind::Tiled(0) => write!(f, "tiled"),
            KernelKind::Tiled(w) => write!(f, "tiled({w})"),
        }
    }
}

/// Executes the β-format SpMV `y += A·x`, dispatching to the scalar's
/// AVX-512 specialization when the CPU supports it and the block size
/// is one of the optimized ones (`vexpandpd` at `T = f64`, `vexpandps`
/// at `T = f32`), otherwise to the generic scalar kernel. `test`
/// selects the Algorithm-2 variant (vectorized for β(1,8) and β(2,4)
/// at f64, as in the paper; portable elsewhere).
pub fn spmv_block<T: Scalar>(
    bm: &BlockMatrix<T>,
    x: &[T],
    y: &mut [T],
    test: bool,
) {
    assert_eq!(x.len(), bm.cols, "x length mismatch");
    assert_eq!(y.len(), bm.rows, "y length mismatch");
    if crate::util::avx512_available() && avx512::spmv(bm, x, y, test) {
        return;
    }
    if test {
        scalar::spmv_generic_test(bm, x, y);
    } else {
        scalar::spmv_generic(bm, x, y);
    }
}

/// Pre-converted storage bundle: run any [`KernelKind`] on one matrix.
/// Conversion happens once in [`KernelSet::prepare`] so benchmark loops
/// measure only the SpMV itself (the paper's protocol).
pub struct KernelSet<T: Scalar = f64> {
    pub csr: Csr<T>,
    blocks: std::collections::HashMap<BlockSize, BlockMatrix<T>>,
    csr5: Option<csr5::Csr5Matrix<T>>,
    hybrid: Option<crate::formats::HybridMatrix<T>>,
    /// Tiled hybrid schedules keyed by tile width (`0` = auto).
    tiled: std::collections::HashMap<u32, crate::formats::TiledHybrid<T>>,
}

impl<T: Scalar> KernelSet<T> {
    /// Prepares every storage needed to run `kinds` on `csr`.
    ///
    /// Panics when a β size is invalid for this precision (e.g.
    /// `Beta(1, 16)` at `T = f64`); use [`crate::SpmvEngine`] for
    /// fallible construction.
    pub fn prepare(csr: Csr<T>, kinds: &[KernelKind]) -> Self {
        let mut blocks = std::collections::HashMap::new();
        let mut csr5 = None;
        let mut hybrid = None;
        let mut tiled = std::collections::HashMap::new();
        for k in kinds {
            match *k {
                KernelKind::Csr5 => {
                    if csr5.is_none() {
                        csr5 = Some(csr5::Csr5Matrix::from_csr(&csr));
                    }
                }
                // Default hybrid compile: analytic panel ranking (use
                // the engine to supply a fitted predictor surface
                // instead).
                KernelKind::Hybrid => {
                    if hybrid.is_none() {
                        hybrid = Some(
                            crate::formats::HybridMatrix::from_csr(
                                &csr,
                                &crate::formats::HybridConfig::for_scalar::<T>(
                                ),
                                None,
                            )
                            .expect(
                                "default hybrid config valid for this \
                                 precision",
                            ),
                        );
                    }
                }
                KernelKind::Tiled(w) => {
                    tiled.entry(w).or_insert_with(|| {
                        let tc = if w == 0 {
                            crate::formats::TileCols::Auto
                        } else {
                            crate::formats::TileCols::Fixed(w as usize)
                        };
                        crate::formats::TiledHybrid::from_csr(
                            &csr,
                            &crate::formats::HybridConfig::for_scalar::<T>(),
                            None,
                            tc,
                        )
                        .expect("default tiled config valid")
                    });
                }
                _ => {
                    if let Some(bs) = k.block_size() {
                        blocks.entry(bs).or_insert_with(|| {
                            crate::formats::csr_to_block(&csr, bs)
                                .expect("block size valid for this precision")
                        });
                    }
                }
            }
        }
        KernelSet { csr, blocks, csr5, hybrid, tiled }
    }

    /// Runs `y += A·x` with the chosen kernel.
    pub fn spmv(&self, kind: KernelKind, x: &[T], y: &mut [T]) {
        match kind {
            KernelKind::Csr => csr::spmv(&self.csr, x, y),
            KernelKind::Csr5 => {
                self.csr5.as_ref().expect("csr5 prepared").spmv(x, y)
            }
            KernelKind::Hybrid => {
                self.hybrid.as_ref().expect("hybrid prepared").spmv(x, y)
            }
            KernelKind::Tiled(w) => self
                .tiled
                .get(&w)
                .expect("tiled storage prepared for kernel")
                .spmv(x, y),
            KernelKind::Beta(..) | KernelKind::BetaTest(..) => {
                let bs = kind.block_size().unwrap();
                let bm = self
                    .blocks
                    .get(&bs)
                    .expect("block storage prepared for kernel");
                spmv_block(bm, x, y, matches!(kind, KernelKind::BetaTest(..)));
            }
        }
    }

    /// Access a prepared block matrix (for stats/occupancy reporting).
    pub fn block(&self, bs: BlockSize) -> Option<&BlockMatrix<T>> {
        self.blocks.get(&bs)
    }

    /// Resolved column tile width a kernel runs at in this set (`0` =
    /// flat execution) — for `tiled` (auto) the width actually chosen
    /// at preparation, so measurements record the real window size.
    pub fn tile_cols(&self, kind: KernelKind) -> usize {
        match kind {
            KernelKind::Tiled(w) => self
                .tiled
                .get(&w)
                .map_or(kind.tile_width(), |th| th.tile_cols),
            _ => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for k in KernelKind::ALL {
            assert_eq!(KernelKind::parse(&k.to_string()), Some(k));
        }
        assert_eq!(KernelKind::parse("B(4,8)"), Some(KernelKind::Beta(4, 8)));
        assert_eq!(
            KernelKind::parse("beta(1,8)test"),
            Some(KernelKind::BetaTest(1, 8))
        );
        assert_eq!(KernelKind::parse("nope"), None);
        assert_eq!(KernelKind::parse("b(x,8)"), None);
    }

    #[test]
    fn parse_accepts_hybrid() {
        assert_eq!(KernelKind::parse("hybrid"), Some(KernelKind::Hybrid));
        assert_eq!(KernelKind::parse(" Hybrid "), Some(KernelKind::Hybrid));
        assert_eq!(
            KernelKind::parse(&KernelKind::Hybrid.to_string()),
            Some(KernelKind::Hybrid)
        );
        assert_eq!(KernelKind::parse("hybrid2"), None);
        assert_eq!(KernelKind::Hybrid.block_size(), None);
    }

    #[test]
    fn parse_accepts_tiled() {
        assert_eq!(KernelKind::parse("tiled"), Some(KernelKind::Tiled(0)));
        assert_eq!(KernelKind::parse(" TILED "), Some(KernelKind::Tiled(0)));
        assert_eq!(
            KernelKind::parse("tiled(4096)"),
            Some(KernelKind::Tiled(4096))
        );
        assert_eq!(KernelKind::parse("tiled(0)"), Some(KernelKind::Tiled(0)));
        assert_eq!(KernelKind::Tiled(0).to_string(), "tiled");
        assert_eq!(KernelKind::Tiled(4096).to_string(), "tiled(4096)");
        for k in [KernelKind::Tiled(0), KernelKind::Tiled(1024)] {
            assert_eq!(KernelKind::parse(&k.to_string()), Some(k));
        }
        assert_eq!(KernelKind::parse("tiledx"), None);
        assert_eq!(KernelKind::parse("tiled(4096"), None);
        assert_eq!(KernelKind::parse("tiled(a)"), None);
        assert_eq!(KernelKind::parse("tiled(4096)x"), None);
        assert_eq!(KernelKind::Tiled(64).block_size(), None);
        assert_eq!(KernelKind::Tiled(64).tile_width(), 64);
        assert_eq!(KernelKind::Hybrid.tile_width(), 0);
    }

    #[test]
    fn kernel_set_runs_tiled() {
        let csr = crate::matrix::suite::mixed_band_scatter(1_024, 3);
        let kinds = [KernelKind::Tiled(0), KernelKind::Tiled(128)];
        let set = KernelSet::prepare(csr.clone(), &kinds);
        let x: Vec<f64> = (0..csr.cols).map(|i| (i % 7) as f64 - 3.0).collect();
        let mut want = vec![0.0; csr.rows];
        csr.spmv_ref(&x, &mut want);
        for k in kinds {
            let mut y = vec![0.0; csr.rows];
            set.spmv(k, &x, &mut y);
            crate::testkit::assert_close(&y, &want, 1e-9, &k.to_string());
        }
    }

    #[test]
    fn parse_accepts_f32_spellings() {
        // β32 names and their Display round trip.
        for k in KernelKind::F32_WIDE_KERNELS {
            assert_eq!(KernelKind::parse(&k.to_string()), Some(k));
        }
        assert_eq!(
            KernelKind::parse("b32(1,16)"),
            Some(KernelKind::Beta(1, 16))
        );
        assert_eq!(
            KernelKind::parse("B32(2,16)"),
            Some(KernelKind::Beta(2, 16))
        );
        assert_eq!(
            KernelKind::parse("beta32(4,16)"),
            Some(KernelKind::Beta(4, 16))
        );
        assert_eq!(
            KernelKind::parse("b32(2,16)test"),
            Some(KernelKind::BetaTest(2, 16))
        );
    }

    #[test]
    fn parse_rejects_trailing_garbage() {
        assert_eq!(KernelKind::parse("b(2,8)x"), None);
        assert_eq!(KernelKind::parse("b(2,8,9)"), None);
        assert_eq!(KernelKind::parse("b(2,)"), None);
        assert_eq!(KernelKind::parse("b(,8)"), None);
        assert_eq!(KernelKind::parse("b32(1,16)junk"), None);
        assert_eq!(KernelKind::parse("csr5 extra"), None);
        assert_eq!(KernelKind::parse("b(2,8)testx"), None);
    }

    #[test]
    fn kernel_set_runs_all() {
        let csr = crate::matrix::suite::poisson2d(20);
        let set = KernelSet::prepare(csr.clone(), &KernelKind::ALL);
        let x: Vec<f64> = (0..csr.cols).map(|i| (i % 7) as f64 - 3.0).collect();
        let mut want = vec![0.0; csr.rows];
        csr.spmv_ref(&x, &mut want);
        for k in KernelKind::ALL {
            let mut y = vec![0.0; csr.rows];
            set.spmv(k, &x, &mut y);
            for i in 0..y.len() {
                assert!(
                    (y[i] - want[i]).abs() <= 1e-9 * want[i].abs().max(1.0),
                    "{k} row {i}: {} vs {}",
                    y[i],
                    want[i]
                );
            }
        }
    }

    #[test]
    fn f32_kernel_set_runs_wide_and_baselines() {
        let csr = crate::matrix::suite::poisson2d(20);
        let csr32: Csr<f32> = csr.to_precision();
        let kinds: Vec<KernelKind> = KernelKind::ALL
            .into_iter()
            .chain(KernelKind::F32_WIDE_KERNELS)
            .collect();
        let set = KernelSet::prepare(csr32.clone(), &kinds);
        let x: Vec<f32> =
            (0..csr32.cols).map(|i| (i % 7) as f32 - 3.0).collect();
        let mut want = vec![0.0f32; csr32.rows];
        csr32.spmv_ref(&x, &mut want);
        for k in kinds {
            let mut y = vec![0.0f32; csr32.rows];
            set.spmv(k, &x, &mut y);
            for i in 0..y.len() {
                assert!(
                    (y[i] - want[i]).abs() <= 2e-4 * want[i].abs().max(1.0),
                    "{k} row {i}: {} vs {}",
                    y[i],
                    want[i]
                );
            }
        }
    }
}
