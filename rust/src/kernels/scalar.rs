//! Portable scalar kernels — the paper's Algorithm 1 (generic `β(r,c)`
//! SpMV) and Algorithm 2 (the `test` variant with separate scalar /
//! vector inner loops).
//!
//! These are the semantic reference for the AVX-512 specializations and
//! the fallback on non-AVX-512 hosts.

use super::avx512::Span;
use crate::formats::{BlockMatrix, BlockSize};

/// Algorithm 1: generic scalar SpMV for any block size, `y += A·x`.
///
/// Iterates row intervals with step `r`; inside an interval walks the
/// blocks left-to-right, accumulating one partial sum per block row and
/// flushing into `y` at interval end — exactly the structure the
/// vectorized kernels replicate.
pub fn spmv_generic(bm: &BlockMatrix, x: &[f64], y: &mut [f64]) {
    let (r, c) = (bm.bs.r, bm.bs.c);
    let mut idx_val = 0usize;
    let mut sums = vec![0.0f64; r];
    for it in 0..bm.intervals() {
        let row0 = it * r;
        let (a, b) =
            (bm.block_rowptr[it] as usize, bm.block_rowptr[it + 1] as usize);
        sums.iter_mut().for_each(|s| *s = 0.0);
        for blk in a..b {
            let col0 = bm.block_colidx[blk] as usize;
            for i in 0..r {
                let mask = bm.block_masks[blk * r + i];
                if mask == 0 {
                    continue;
                }
                let mut sum = sums[i];
                for k in 0..c {
                    if mask & (1 << k) != 0 {
                        sum += x[col0 + k] * bm.values[idx_val];
                        idx_val += 1;
                    }
                }
                sums[i] = sum;
            }
        }
        let rows_here = r.min(bm.rows - row0);
        for i in 0..rows_here {
            y[row0 + i] += sums[i];
        }
    }
    debug_assert_eq!(idx_val, bm.values.len());
}

/// Algorithm 2: the `test` variant. Blocks whose mask has exactly one
/// set bit are handled by a scalar multiply (no vector load of `x`, no
/// expand); denser blocks take the block path. The two inner loops and
/// the jump between them mirror the paper's goto structure: the state
/// machine stays in one mode across consecutive blocks of the same
/// kind, which is what makes the branch predictable.
pub fn spmv_generic_test(bm: &BlockMatrix, x: &[f64], y: &mut [f64]) {
    let (r, c) = (bm.bs.r, bm.bs.c);
    let mut idx_val = 0usize;
    let mut sums = vec![0.0f64; r];
    for it in 0..bm.intervals() {
        let row0 = it * r;
        let (a, b) =
            (bm.block_rowptr[it] as usize, bm.block_rowptr[it + 1] as usize);
        sums.iter_mut().for_each(|s| *s = 0.0);

        let mut blk = a;
        // Mode flag emulating the two jump-connected loops of Alg. 2.
        // `single` ⇔ currently in the "mask has one bit" loop.
        let mut single = true;
        while blk < b {
            let col0 = bm.block_colidx[blk] as usize;
            // Popcount over the whole block (all r mask bytes).
            let mut pop = 0u32;
            for i in 0..r {
                pop += bm.block_masks[blk * r + i].count_ones();
            }
            if pop == 1 {
                if !single {
                    single = true; // jump: vector loop → scalar loop
                }
                // Single value: locate its (row, lane) and multiply.
                for i in 0..r {
                    let mask = bm.block_masks[blk * r + i];
                    if mask != 0 {
                        let k = mask.trailing_zeros() as usize;
                        sums[i] += x[col0 + k] * bm.values[idx_val];
                        idx_val += 1;
                        break;
                    }
                }
            } else {
                if single {
                    single = false; // jump: scalar loop → vector loop
                }
                for i in 0..r {
                    let mask = bm.block_masks[blk * r + i];
                    if mask == 0 {
                        continue;
                    }
                    let mut sum = sums[i];
                    for k in 0..c {
                        if mask & (1 << k) != 0 {
                            sum += x[col0 + k] * bm.values[idx_val];
                            idx_val += 1;
                        }
                    }
                    sums[i] = sum;
                }
            }
            blk += 1;
        }
        let rows_here = r.min(bm.rows - row0);
        for i in 0..rows_here {
            y[row0 + i] += sums[i];
        }
    }
    debug_assert_eq!(idx_val, bm.values.len());
}

/// Span-based Algorithm 1 (the portable counterpart of
/// [`super::avx512::spmv_span`], used by the parallel runtime on
/// non-AVX-512 hosts). `y` is span-local.
pub fn spmv_generic_span(span: Span<'_>, bs: BlockSize, x: &[f64], y: &mut [f64]) {
    let (r, c) = (bs.r, bs.c);
    let stride = 4 + r;
    let intervals = span.rowptr.len() - 1;
    let mut idx_val = 0usize;
    let mut hp = 0usize;
    let mut sums = vec![0.0f64; r];
    for it in 0..intervals {
        let nb = (span.rowptr[it + 1] - span.rowptr[it]) as usize;
        if nb == 0 {
            continue;
        }
        sums.iter_mut().for_each(|s| *s = 0.0);
        for _ in 0..nb {
            let h = &span.headers[hp..hp + stride];
            let col0 = u32::from_le_bytes([h[0], h[1], h[2], h[3]]) as usize;
            for i in 0..r {
                let mask = h[4 + i];
                if mask == 0 {
                    continue;
                }
                let mut sum = sums[i];
                for k in 0..c {
                    if mask & (1 << k) != 0 {
                        sum += x[col0 + k] * span.values[idx_val];
                        idx_val += 1;
                    }
                }
                sums[i] = sum;
            }
            hp += stride;
        }
        let row0 = it * r;
        let rows_here = r.min(span.rows - row0);
        for i in 0..rows_here {
            y[row0 + i] += sums[i];
        }
    }
    debug_assert_eq!(idx_val, span.values.len());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::csr_to_block;
    use crate::matrix::{suite, Csr};

    fn check(csr: &Csr, bs: BlockSize, test: bool) {
        let bm = csr_to_block(csr, bs).unwrap();
        let x: Vec<f64> =
            (0..csr.cols).map(|i| ((i * 37) % 11) as f64 - 5.0).collect();
        let mut want = vec![0.0; csr.rows];
        csr.spmv_ref(&x, &mut want);
        let mut got = vec![0.0; csr.rows];
        if test {
            spmv_generic_test(&bm, &x, &mut got);
        } else {
            spmv_generic(&bm, &x, &mut got);
        }
        for i in 0..csr.rows {
            assert!(
                (got[i] - want[i]).abs() <= 1e-9 * want[i].abs().max(1.0),
                "{bs} test={test} row {i}: {} vs {}",
                got[i],
                want[i]
            );
        }
    }

    #[test]
    fn generic_matches_csr_all_sizes() {
        for sm in suite::test_subset() {
            for bs in BlockSize::PAPER_SIZES {
                check(&sm.csr, bs, false);
            }
        }
    }

    #[test]
    fn test_variant_matches_csr_all_sizes() {
        for sm in suite::test_subset() {
            for bs in BlockSize::PAPER_SIZES {
                check(&sm.csr, bs, true);
            }
        }
    }

    #[test]
    fn non_paper_sizes_work_too() {
        // Generic kernel accepts any r*c<=64, c<=8 (e.g. the paper's
        // Fig. 2 β(1,4)/β(2,2) illustrations).
        let sm = &suite::test_subset()[1];
        for bs in [
            BlockSize::new(1, 4),
            BlockSize::new(2, 2),
            BlockSize::new(3, 5),
            BlockSize::new(8, 8),
        ] {
            check(&sm.csr, bs, false);
            check(&sm.csr, bs, true);
        }
    }

    #[test]
    fn span_version_matches_full() {
        let csr = suite::poisson2d(16);
        for bs in BlockSize::PAPER_SIZES {
            let bm = csr_to_block(&csr, bs).unwrap();
            let x: Vec<f64> = (0..csr.cols).map(|i| (i % 5) as f64).collect();
            let mut want = vec![0.0; csr.rows];
            spmv_generic(&bm, &x, &mut want);
            let mut got = vec![0.0; csr.rows];
            spmv_generic_span(Span::full(&bm), bs, &x, &mut got);
            for i in 0..csr.rows {
                assert!((got[i] - want[i]).abs() < 1e-12, "{bs} row {i}");
            }
        }
    }

    #[test]
    fn accumulates_into_y() {
        let csr = suite::poisson2d(8);
        let bm = csr_to_block(&csr, BlockSize::new(2, 4)).unwrap();
        let x = vec![1.0; csr.cols];
        let mut y = vec![10.0; csr.rows];
        spmv_generic(&bm, &x, &mut y);
        let mut want = vec![10.0; csr.rows];
        csr.spmv_ref(&x, &mut want);
        assert_eq!(y, want);
    }
}
