//! Portable scalar kernels — the paper's Algorithm 1 (generic `β(r,c)`
//! SpMV) and Algorithm 2 (the `test` variant with separate scalar /
//! vector inner loops) — generic over the element precision.
//!
//! These are the semantic reference for the AVX-512 specializations and
//! the fallback on non-AVX-512 hosts (and for block sizes without a
//! vectorized specialization, e.g. any f32 size with `c != 16`).

use super::avx512::Span;
use crate::formats::{BlockMatrix, BlockSize};
use crate::scalar::{MaskWord, Scalar};

/// Algorithm 1: generic scalar SpMV for any block size, `y += A·x`.
///
/// Iterates row intervals with step `r`; inside an interval walks the
/// blocks left-to-right, accumulating one partial sum per block row and
/// flushing into `y` at interval end — exactly the structure the
/// vectorized kernels replicate.
pub fn spmv_generic<T: Scalar>(bm: &BlockMatrix<T>, x: &[T], y: &mut [T]) {
    let (r, c) = (bm.bs.r, bm.bs.c);
    let mut idx_val = 0usize;
    let mut sums = vec![T::ZERO; r];
    for it in 0..bm.intervals() {
        let row0 = it * r;
        let (a, b) =
            (bm.block_rowptr[it] as usize, bm.block_rowptr[it + 1] as usize);
        sums.iter_mut().for_each(|s| *s = T::ZERO);
        for blk in a..b {
            let col0 = bm.block_colidx[blk] as usize;
            for i in 0..r {
                let mask = bm.block_masks[blk * r + i];
                if mask.is_zero() {
                    continue;
                }
                let mut sum = sums[i];
                for k in 0..c {
                    if mask.test(k) {
                        sum += x[col0 + k] * bm.values[idx_val];
                        idx_val += 1;
                    }
                }
                sums[i] = sum;
            }
        }
        let rows_here = r.min(bm.rows - row0);
        for i in 0..rows_here {
            y[row0 + i] += sums[i];
        }
    }
    debug_assert_eq!(idx_val, bm.values.len());
}

/// Algorithm 2: the `test` variant. Blocks whose mask has exactly one
/// set bit are handled by a scalar multiply (no vector load of `x`, no
/// expand); denser blocks take the block path. The two inner loops and
/// the jump between them mirror the paper's goto structure: the state
/// machine stays in one mode across consecutive blocks of the same
/// kind, which is what makes the branch predictable.
pub fn spmv_generic_test<T: Scalar>(bm: &BlockMatrix<T>, x: &[T], y: &mut [T]) {
    let (r, c) = (bm.bs.r, bm.bs.c);
    let mut idx_val = 0usize;
    let mut sums = vec![T::ZERO; r];
    for it in 0..bm.intervals() {
        let row0 = it * r;
        let (a, b) =
            (bm.block_rowptr[it] as usize, bm.block_rowptr[it + 1] as usize);
        sums.iter_mut().for_each(|s| *s = T::ZERO);

        let mut blk = a;
        // Mode flag emulating the two jump-connected loops of Alg. 2.
        // `single` ⇔ currently in the "mask has one bit" loop.
        let mut single = true;
        while blk < b {
            let col0 = bm.block_colidx[blk] as usize;
            // Popcount over the whole block (all r mask words).
            let mut pop = 0u32;
            for i in 0..r {
                pop += bm.block_masks[blk * r + i].count_ones();
            }
            if pop == 1 {
                if !single {
                    single = true; // jump: vector loop → scalar loop
                }
                // Single value: locate its (row, lane) and multiply.
                for i in 0..r {
                    let mask = bm.block_masks[blk * r + i];
                    if !mask.is_zero() {
                        let k = mask.trailing_zeros() as usize;
                        sums[i] += x[col0 + k] * bm.values[idx_val];
                        idx_val += 1;
                        break;
                    }
                }
            } else {
                if single {
                    single = false; // jump: scalar loop → vector loop
                }
                for i in 0..r {
                    let mask = bm.block_masks[blk * r + i];
                    if mask.is_zero() {
                        continue;
                    }
                    let mut sum = sums[i];
                    for k in 0..c {
                        if mask.test(k) {
                            sum += x[col0 + k] * bm.values[idx_val];
                            idx_val += 1;
                        }
                    }
                    sums[i] = sum;
                }
            }
            blk += 1;
        }
        let rows_here = r.min(bm.rows - row0);
        for i in 0..rows_here {
            y[row0 + i] += sums[i];
        }
    }
    debug_assert_eq!(idx_val, bm.values.len());
}

/// Span-based Algorithm 1 (the portable counterpart of
/// [`super::avx512::spmv_span`], used by the parallel runtime on
/// non-AVX-512 hosts). `y` is span-local.
pub fn spmv_generic_span<T: Scalar>(
    span: Span<'_, T>,
    bs: BlockSize,
    x: &[T],
    y: &mut [T],
) {
    let (r, c) = (bs.r, bs.c);
    let mb = <T::Mask as MaskWord>::BYTES;
    let stride = 4 + mb * r;
    let intervals = span.rowptr.len() - 1;
    let mut idx_val = 0usize;
    let mut hp = 0usize;
    let mut sums = vec![T::ZERO; r];
    for it in 0..intervals {
        let nb = (span.rowptr[it + 1] - span.rowptr[it]) as usize;
        if nb == 0 {
            continue;
        }
        sums.iter_mut().for_each(|s| *s = T::ZERO);
        for _ in 0..nb {
            let h = &span.headers[hp..hp + stride];
            let col0 = u32::from_le_bytes([h[0], h[1], h[2], h[3]]) as usize;
            for i in 0..r {
                let mask = <T::Mask as MaskWord>::read_le(&h[4 + mb * i..]);
                if mask.is_zero() {
                    continue;
                }
                let mut sum = sums[i];
                for k in 0..c {
                    if mask.test(k) {
                        sum += x[col0 + k] * span.values[idx_val];
                        idx_val += 1;
                    }
                }
                sums[i] = sum;
            }
            hp += stride;
        }
        let row0 = it * r;
        let rows_here = r.min(span.rows - row0);
        for i in 0..rows_here {
            y[row0 + i] += sums[i];
        }
    }
    debug_assert_eq!(idx_val, span.values.len());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::csr_to_block;
    use crate::matrix::{suite, Csr};

    fn check(csr: &Csr, bs: BlockSize, test: bool) {
        let bm = csr_to_block(csr, bs).unwrap();
        let x: Vec<f64> =
            (0..csr.cols).map(|i| ((i * 37) % 11) as f64 - 5.0).collect();
        let mut want = vec![0.0; csr.rows];
        csr.spmv_ref(&x, &mut want);
        let mut got = vec![0.0; csr.rows];
        if test {
            spmv_generic_test(&bm, &x, &mut got);
        } else {
            spmv_generic(&bm, &x, &mut got);
        }
        for i in 0..csr.rows {
            assert!(
                (got[i] - want[i]).abs() <= 1e-9 * want[i].abs().max(1.0),
                "{bs} test={test} row {i}: {} vs {}",
                got[i],
                want[i]
            );
        }
    }

    #[test]
    fn generic_matches_csr_all_sizes() {
        for sm in suite::test_subset() {
            for bs in BlockSize::PAPER_SIZES {
                check(&sm.csr, bs, false);
            }
        }
    }

    #[test]
    fn test_variant_matches_csr_all_sizes() {
        for sm in suite::test_subset() {
            for bs in BlockSize::PAPER_SIZES {
                check(&sm.csr, bs, true);
            }
        }
    }

    #[test]
    fn non_paper_sizes_work_too() {
        // Generic kernel accepts any r<=8, c<=8 (e.g. the paper's
        // Fig. 2 β(1,4)/β(2,2) illustrations).
        let sm = &suite::test_subset()[1];
        for bs in [
            BlockSize::new(1, 4),
            BlockSize::new(2, 2),
            BlockSize::new(3, 5),
            BlockSize::new(8, 8),
        ] {
            check(&sm.csr, bs, false);
            check(&sm.csr, bs, true);
        }
    }

    #[test]
    fn f32_generic_and_test_variants_agree() {
        // The f32 instantiation of Algorithms 1 and 2 must agree with
        // the f32 CSR reference, including at 16-wide sizes.
        let sm = &suite::test_subset()[2];
        let csr32: Csr<f32> = sm.csr.to_precision();
        let x: Vec<f32> =
            (0..csr32.cols).map(|i| ((i * 5) % 7) as f32 * 0.5 - 1.5).collect();
        let mut want = vec![0.0f32; csr32.rows];
        csr32.spmv_ref(&x, &mut want);
        for bs in [
            BlockSize::new(1, 16),
            BlockSize::new(2, 16),
            BlockSize::new(4, 12),
            BlockSize::new(2, 8),
        ] {
            let bm = csr_to_block(&csr32, bs).unwrap();
            let mut got = vec![0.0f32; csr32.rows];
            spmv_generic(&bm, &x, &mut got);
            let mut got_test = vec![0.0f32; csr32.rows];
            spmv_generic_test(&bm, &x, &mut got_test);
            for i in 0..csr32.rows {
                let tol = 2e-4 * want[i].abs().max(1.0);
                assert!((got[i] - want[i]).abs() <= tol, "{bs} row {i}");
                assert!((got_test[i] - want[i]).abs() <= tol, "{bs} test row {i}");
            }
        }
    }

    #[test]
    fn span_version_matches_full() {
        let csr = suite::poisson2d(16);
        for bs in BlockSize::PAPER_SIZES {
            let bm = csr_to_block(&csr, bs).unwrap();
            let x: Vec<f64> = (0..csr.cols).map(|i| (i % 5) as f64).collect();
            let mut want = vec![0.0; csr.rows];
            spmv_generic(&bm, &x, &mut want);
            let mut got = vec![0.0; csr.rows];
            spmv_generic_span(Span::full(&bm), bs, &x, &mut got);
            for i in 0..csr.rows {
                assert!((got[i] - want[i]).abs() < 1e-12, "{bs} row {i}");
            }
        }
    }

    #[test]
    fn f32_span_version_matches_full() {
        let csr32: Csr<f32> = suite::poisson2d(16).to_precision();
        for bs in BlockSize::F32_WIDE_SIZES {
            let bm = csr_to_block(&csr32, bs).unwrap();
            let x: Vec<f32> = (0..csr32.cols).map(|i| (i % 5) as f32).collect();
            let mut want = vec![0.0f32; csr32.rows];
            spmv_generic(&bm, &x, &mut want);
            let mut got = vec![0.0f32; csr32.rows];
            spmv_generic_span(Span::full(&bm), bs, &x, &mut got);
            for i in 0..csr32.rows {
                assert!((got[i] - want[i]).abs() < 1e-6, "{bs} row {i}");
            }
        }
    }

    #[test]
    fn accumulates_into_y() {
        let csr = suite::poisson2d(8);
        let bm = csr_to_block(&csr, BlockSize::new(2, 4)).unwrap();
        let x = vec![1.0; csr.cols];
        let mut y = vec![10.0; csr.rows];
        spmv_generic(&bm, &x, &mut y);
        let mut want = vec![10.0; csr.rows];
        csr.spmv_ref(&x, &mut want);
        assert_eq!(y, want);
    }
}
