//! Multi-vector SpMV (SpMM): `Y += A · X` with `X` holding `k` dense
//! vectors — the "multiplication by multiple vectors" optimization the
//! paper's background cites from the SPARSITY work (Im, Yelick &
//! Vuduc) as a known lever on top of register blocking.
//!
//! Layout: `X` and `Y` are row-major `[cols × k]` / `[rows × k]` —
//! entry `X[c*k + j]` is vector `j`'s value at position `c`. With this
//! layout a nonzero `a_{rc}` contributes `a_{rc} · X[c, :]`, a dense
//! k-wide AXPY that vectorizes without any expand at all: the block
//! mask's job shifts from lane selection to *skipping the X rows that
//! are not touched*, which preserves the paper's "no useless memory
//! load" property in the multi-vector regime.
//!
//! Two kernels:
//! - [`spmm_generic`] — scalar reference for any `(r, c, k)`;
//! - [`spmm_k8`] — AVX-512 specialization for `k = 8` (one zmm per X
//!   row; broadcast-FMA per nonzero), any β block size.

use crate::formats::BlockMatrix;
use crate::scalar::{MaskWord, Scalar};

#[cfg(target_arch = "x86_64")]
use std::arch::x86_64::*;

/// Scalar SpMM for any block size and vector count `k`, generic over
/// the element precision.
pub fn spmm_generic<T: Scalar>(bm: &BlockMatrix<T>, x: &[T], y: &mut [T], k: usize) {
    assert_eq!(x.len(), bm.cols * k, "x must be cols*k");
    assert_eq!(y.len(), bm.rows * k, "y must be rows*k");
    let (r, c) = (bm.bs.r, bm.bs.c);
    let mut idx_val = 0usize;
    // Per-interval accumulators: r rows × k lanes.
    let mut sums = vec![T::ZERO; r * k];
    for it in 0..bm.intervals() {
        let row0 = it * r;
        let (a, b) =
            (bm.block_rowptr[it] as usize, bm.block_rowptr[it + 1] as usize);
        sums.iter_mut().for_each(|s| *s = T::ZERO);
        for blk in a..b {
            let col0 = bm.block_colidx[blk] as usize;
            for i in 0..r {
                let mask = bm.block_masks[blk * r + i];
                if mask.is_zero() {
                    continue;
                }
                for lane in 0..c {
                    if mask.test(lane) {
                        let v = bm.values[idx_val];
                        idx_val += 1;
                        let xrow = &x[(col0 + lane) * k..(col0 + lane + 1) * k];
                        let srow = &mut sums[i * k..(i + 1) * k];
                        for j in 0..k {
                            srow[j] += v * xrow[j];
                        }
                    }
                }
            }
        }
        let rows_here = r.min(bm.rows - row0);
        for i in 0..rows_here {
            let yrow = &mut y[(row0 + i) * k..(row0 + i + 1) * k];
            for j in 0..k {
                yrow[j] += sums[i * k + j];
            }
        }
    }
    debug_assert_eq!(idx_val, bm.values.len());
}

/// AVX-512 SpMM for `k = 8`: one zmm accumulator per block row, one
/// broadcast-FMA per nonzero. Falls back to [`spmm_generic`] on
/// non-AVX-512 hosts.
pub fn spmm_k8(bm: &BlockMatrix, x: &[f64], y: &mut [f64]) {
    #[cfg(target_arch = "x86_64")]
    {
        if crate::util::avx512_available() {
            // SAFETY: same format invariants as the SpMV kernels; X/Y
            // lengths asserted inside.
            unsafe { spmm_k8_avx512(bm, x, y) };
            return;
        }
    }
    spmm_generic(bm, x, y, 8);
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512vl,avx512bw,avx512dq")]
unsafe fn spmm_k8_avx512(bm: &BlockMatrix, x: &[f64], y: &mut [f64]) {
    const K: usize = 8;
    assert_eq!(x.len(), bm.cols * K);
    assert_eq!(y.len(), bm.rows * K);
    let (r, c) = (bm.bs.r, bm.bs.c);
    let stride = bm.header_stride();
    let mut h = bm.headers.as_ptr();
    let mut vals = bm.values.as_ptr();
    let xp = x.as_ptr();
    // r ≤ 8 accumulators (one zmm per block row).
    let mut acc = [_mm512_setzero_pd(); 8];
    for it in 0..bm.intervals() {
        let row0 = it * r;
        let nb = (bm.block_rowptr[it + 1] - bm.block_rowptr[it]) as usize;
        if nb == 0 {
            continue;
        }
        for a in acc.iter_mut().take(r) {
            *a = _mm512_setzero_pd();
        }
        for _ in 0..nb {
            let col0 = u32::from_le_bytes([*h, *h.add(1), *h.add(2), *h.add(3)])
                as usize;
            for i in 0..r {
                let mut mask = *h.add(4 + i) as u32;
                while mask != 0 {
                    let lane = mask.trailing_zeros() as usize;
                    mask &= mask - 1;
                    let v = _mm512_set1_pd(*vals);
                    vals = vals.add(1);
                    let xrow = _mm512_loadu_pd(xp.add((col0 + lane) * K));
                    acc[i] = _mm512_fmadd_pd(v, xrow, acc[i]);
                }
            }
            h = h.add(stride);
        }
        let rows_here = r.min(bm.rows - row0);
        for i in 0..rows_here {
            let yp = y.as_mut_ptr().add((row0 + i) * K);
            _mm512_storeu_pd(yp, _mm512_add_pd(_mm512_loadu_pd(yp), acc[i]));
        }
    }
    debug_assert_eq!(
        vals as usize,
        bm.values.as_ptr() as usize + bm.values.len() * 8
    );
    let _ = c;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::{csr_to_block, BlockSize};
    use crate::matrix::suite;
    use crate::util::Rng;

    fn dense_spmm(
        csr: &crate::matrix::Csr,
        x: &[f64],
        k: usize,
    ) -> Vec<f64> {
        let mut y = vec![0.0; csr.rows * k];
        for r in 0..csr.rows {
            for idx in csr.row_range(r) {
                let c = csr.colidx[idx] as usize;
                let v = csr.values[idx];
                for j in 0..k {
                    y[r * k + j] += v * x[c * k + j];
                }
            }
        }
        y
    }

    #[test]
    fn generic_matches_dense_all_sizes() {
        let csr = suite::quantum_clusters(200, 3, 8, 5, 11);
        let mut rng = Rng::new(5);
        for k in [1usize, 3, 8] {
            let x: Vec<f64> =
                (0..csr.cols * k).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            let want = dense_spmm(&csr, &x, k);
            for bs in BlockSize::PAPER_SIZES {
                let bm = csr_to_block(&csr, bs).unwrap();
                let mut y = vec![0.0; csr.rows * k];
                spmm_generic(&bm, &x, &mut y, k);
                crate::testkit::assert_close(
                    &y,
                    &want,
                    1e-9,
                    &format!("{bs} k={k}"),
                );
            }
        }
    }

    #[test]
    fn avx512_k8_matches_generic() {
        let csr = suite::fem_blocked(150, 3, 6, 13);
        let mut rng = Rng::new(6);
        let x: Vec<f64> =
            (0..csr.cols * 8).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        let want = dense_spmm(&csr, &x, 8);
        for bs in BlockSize::PAPER_SIZES {
            let bm = csr_to_block(&csr, bs).unwrap();
            let mut y = vec![0.0; csr.rows * 8];
            spmm_k8(&bm, &x, &mut y);
            crate::testkit::assert_close(&y, &want, 1e-9, &format!("{bs} k8"));
        }
    }

    #[test]
    fn accumulates_into_y() {
        let csr = suite::poisson2d(6);
        let bm = csr_to_block(&csr, BlockSize::new(2, 4)).unwrap();
        let x = vec![1.0; csr.cols * 8];
        let mut y = vec![2.0; csr.rows * 8];
        spmm_k8(&bm, &x, &mut y);
        let mut want = vec![0.0; csr.rows * 8];
        spmm_generic(&bm, &x, &mut want, 8);
        for (a, b) in y.iter().zip(&want) {
            assert!((a - (b + 2.0)).abs() < 1e-12);
        }
    }

    #[test]
    fn k1_equals_spmv() {
        let csr = suite::banded(300, 6, 0.4, 17);
        let bm = csr_to_block(&csr, BlockSize::new(1, 8)).unwrap();
        let mut rng = Rng::new(7);
        let x: Vec<f64> =
            (0..csr.cols).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        let mut y_spmm = vec![0.0; csr.rows];
        spmm_generic(&bm, &x, &mut y_spmm, 1);
        let mut y_spmv = vec![0.0; csr.rows];
        super::super::spmv_block(&bm, &x, &mut y_spmv, false);
        crate::testkit::assert_close(&y_spmm, &y_spmv, 1e-12, "k=1");
    }
}
