//! Multi-vector SpMV (SpMM): `Y += A · X` with `X` holding `k` dense
//! vectors — the "multiplication by multiple vectors" optimization the
//! paper's background cites from the SPARSITY work (Im, Yelick &
//! Vuduc) as a known lever on top of register blocking.
//!
//! Layout: `X` and `Y` are row-major `[cols × k]` / `[rows × k]` —
//! entry `X[c*k + j]` is vector `j`'s value at position `c`. With this
//! layout a nonzero `a_{rc}` contributes `a_{rc} · X[c, :]`, a dense
//! k-wide AXPY that vectorizes without any expand at all: the block
//! mask's job shifts from lane selection to *skipping the X rows that
//! are not touched*, which preserves the paper's "no useless memory
//! load" property in the multi-vector regime.
//!
//! Kernels:
//! - [`spmm_generic`] — scalar reference for any `(r, c, k)`;
//! - [`spmm_generic_span`] — the span form of the same loop, used by
//!   each worker of the parallel runtime (one span per thread, `y`
//!   span-local — the SpMM counterpart of
//!   [`crate::kernels::scalar::spmv_generic_span`]);
//! - [`spmm_k8`] — AVX-512 specialization for `k = 8` (one zmm per X
//!   row; broadcast-FMA per nonzero), any β block size;
//! - [`spmm_span`] / [`spmm_auto`] — the dispatch entries (SIMD when
//!   the scalar has a specialization for this `k`, portable
//!   otherwise), span-wise and whole-matrix.

use super::avx512::{default_tune, Span, TuneParams};
use crate::formats::{BlockMatrix, BlockSize};
use crate::scalar::{MaskWord, Scalar};

#[cfg(target_arch = "x86_64")]
use super::avx512::{
    block_loop, dispatch_variant, prefetch_streams, prefetch_x, Var,
};
#[cfg(target_arch = "x86_64")]
use std::arch::x86_64::*;

/// Scalar SpMM for any block size and vector count `k`, generic over
/// the element precision.
pub fn spmm_generic<T: Scalar>(bm: &BlockMatrix<T>, x: &[T], y: &mut [T], k: usize) {
    assert_eq!(x.len(), bm.cols * k, "x must be cols*k");
    assert_eq!(y.len(), bm.rows * k, "y must be rows*k");
    let (r, c) = (bm.bs.r, bm.bs.c);
    let mut idx_val = 0usize;
    // Per-interval accumulators: r rows × k lanes.
    let mut sums = vec![T::ZERO; r * k];
    for it in 0..bm.intervals() {
        let row0 = it * r;
        let (a, b) =
            (bm.block_rowptr[it] as usize, bm.block_rowptr[it + 1] as usize);
        sums.iter_mut().for_each(|s| *s = T::ZERO);
        for blk in a..b {
            let col0 = bm.block_colidx[blk] as usize;
            for i in 0..r {
                let mask = bm.block_masks[blk * r + i];
                if mask.is_zero() {
                    continue;
                }
                for lane in 0..c {
                    if mask.test(lane) {
                        let v = bm.values[idx_val];
                        idx_val += 1;
                        let xrow = &x[(col0 + lane) * k..(col0 + lane + 1) * k];
                        let srow = &mut sums[i * k..(i + 1) * k];
                        for j in 0..k {
                            srow[j] += v * xrow[j];
                        }
                    }
                }
            }
        }
        let rows_here = r.min(bm.rows - row0);
        for i in 0..rows_here {
            let yrow = &mut y[(row0 + i) * k..(row0 + i + 1) * k];
            for j in 0..k {
                yrow[j] += sums[i * k + j];
            }
        }
    }
    debug_assert_eq!(idx_val, bm.values.len());
}

/// Span-based scalar SpMM: one worker's share of the multi-RHS product
/// (`y` is span-local, `[span.rows × k]` row-major; `x` is the full
/// `[cols × k]` input). Same traversal as [`spmm_generic`], but walking
/// the span's interleaved header sub-stream.
pub fn spmm_generic_span<T: Scalar>(
    span: Span<'_, T>,
    bs: BlockSize,
    x: &[T],
    y: &mut [T],
    k: usize,
) {
    let mut sums = Vec::new();
    spmm_generic_span_scratch(span, bs, x, y, k, &mut sums);
}

/// [`spmm_generic_span`] with a caller-owned accumulator buffer, so a
/// persistent worker reuses its scratch across epochs instead of
/// allocating `r·k` accumulators per call.
pub fn spmm_generic_span_scratch<T: Scalar>(
    span: Span<'_, T>,
    bs: BlockSize,
    x: &[T],
    y: &mut [T],
    k: usize,
    sums: &mut Vec<T>,
) {
    if span.rowptr.len() < 2 {
        return;
    }
    let (r, c) = (bs.r, bs.c);
    let mb = <T::Mask as MaskWord>::BYTES;
    let stride = 4 + mb * r;
    let intervals = span.rowptr.len() - 1;
    let mut idx_val = 0usize;
    let mut hp = 0usize;
    // Per-interval accumulators: r rows × k lanes.
    sums.clear();
    sums.resize(r * k, T::ZERO);
    for it in 0..intervals {
        let nb = (span.rowptr[it + 1] - span.rowptr[it]) as usize;
        if nb == 0 {
            continue;
        }
        sums.iter_mut().for_each(|s| *s = T::ZERO);
        for _ in 0..nb {
            let h = &span.headers[hp..hp + stride];
            let col0 = u32::from_le_bytes([h[0], h[1], h[2], h[3]]) as usize;
            for i in 0..r {
                let mask = <T::Mask as MaskWord>::read_le(&h[4 + mb * i..]);
                if mask.is_zero() {
                    continue;
                }
                for lane in 0..c {
                    if mask.test(lane) {
                        let v = span.values[idx_val];
                        idx_val += 1;
                        let xrow =
                            &x[(col0 + lane) * k..(col0 + lane + 1) * k];
                        let srow = &mut sums[i * k..(i + 1) * k];
                        for j in 0..k {
                            srow[j] += v * xrow[j];
                        }
                    }
                }
            }
            hp += stride;
        }
        let row0 = it * r;
        let rows_here = r.min(span.rows - row0);
        for i in 0..rows_here {
            let yrow = &mut y[(row0 + i) * k..(row0 + i + 1) * k];
            for j in 0..k {
                yrow[j] += sums[i * k + j];
            }
        }
    }
    debug_assert_eq!(idx_val, span.values.len());
}

/// Span-wise SpMM dispatch: the scalar's SIMD specialization when one
/// exists for this `k` (AVX-512 `k = 8` at f64), the portable span
/// kernel otherwise. Runs the process-default tune.
pub fn spmm_span<T: Scalar>(
    span: Span<'_, T>,
    bs: BlockSize,
    x: &[T],
    y: &mut [T],
    k: usize,
) {
    let mut sums = Vec::new();
    spmm_span_scratch_tuned(span, bs, x, y, k, &mut sums, default_tune());
}

/// [`spmm_span`] with a caller-owned accumulator for the portable
/// fallback — what each pool worker runs, keeping the per-epoch path
/// allocation-free (the SIMD path needs no scratch at all).
pub fn spmm_span_scratch<T: Scalar>(
    span: Span<'_, T>,
    bs: BlockSize,
    x: &[T],
    y: &mut [T],
    k: usize,
    sums: &mut Vec<T>,
) {
    spmm_span_scratch_tuned(span, bs, x, y, k, sums, default_tune())
}

/// [`spmm_span_scratch`] with an explicit kernel variant — resolved
/// once per span call, like the SpMV side.
pub fn spmm_span_scratch_tuned<T: Scalar>(
    span: Span<'_, T>,
    bs: BlockSize,
    x: &[T],
    y: &mut [T],
    k: usize,
    sums: &mut Vec<T>,
    tune: TuneParams,
) {
    if span.rowptr.len() < 2 {
        return;
    }
    if T::spmm_span_simd(span, bs, x, y, k, tune) {
        return;
    }
    spmm_generic_span_scratch(span, bs, x, y, k, sums);
}

/// [`spmm_span_scratch`] with a column-base offset — the SpMM side of
/// the column-tiled execution hook (see
/// [`crate::kernels::avx512::spmv_span_at`]). The span's `colidx` are
/// relative to `col_base`; with the row-major `[cols × k]` layout the
/// `x` panel simply starts `col_base · k` elements in, and both the
/// SIMD `k = 8` kernel and the portable fallback run unchanged.
pub fn spmm_span_at<T: Scalar>(
    span: Span<'_, T>,
    bs: BlockSize,
    col_base: usize,
    x: &[T],
    y: &mut [T],
    k: usize,
    sums: &mut Vec<T>,
) {
    spmm_span_scratch(span, bs, &x[col_base * k..], y, k, sums)
}

/// [`spmm_span_at`] with an explicit kernel variant.
#[allow(clippy::too_many_arguments)]
pub fn spmm_span_at_tuned<T: Scalar>(
    span: Span<'_, T>,
    bs: BlockSize,
    col_base: usize,
    x: &[T],
    y: &mut [T],
    k: usize,
    sums: &mut Vec<T>,
    tune: TuneParams,
) {
    spmm_span_scratch_tuned(span, bs, &x[col_base * k..], y, k, sums, tune)
}

/// Whole-matrix SpMM dispatch (`Y += A·X`, `X`/`Y` row-major): SIMD
/// when available for this `(T, k)`, portable otherwise. Runs the
/// matrix's resolved tune (`bm.tune`).
pub fn spmm_auto<T: Scalar>(
    bm: &BlockMatrix<T>,
    x: &[T],
    y: &mut [T],
    k: usize,
) {
    assert_eq!(x.len(), bm.cols * k, "x must be cols*k");
    assert_eq!(y.len(), bm.rows * k, "y must be rows*k");
    let mut sums = Vec::new();
    spmm_span_scratch_tuned(
        Span::full(bm),
        bm.bs,
        x,
        y,
        k,
        &mut sums,
        bm.tune,
    );
}

/// AVX-512 SpMM for `k = 8`: one zmm accumulator per block row, one
/// broadcast-FMA per nonzero. Falls back to [`spmm_generic`] on
/// non-AVX-512 hosts.
pub fn spmm_k8(bm: &BlockMatrix, x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), bm.cols * 8);
    assert_eq!(y.len(), bm.rows * 8);
    spmm_auto(bm, x, y, 8);
}

/// The f64 SIMD hook behind [`crate::scalar::Scalar::spmm_span_simd`]:
/// handles `k = 8` on AVX-512 hosts at the resolved kernel variant,
/// declines everything else.
pub fn spmm_span_simd_f64(
    span: Span<'_, f64>,
    bs: BlockSize,
    x: &[f64],
    y: &mut [f64],
    k: usize,
    tune: TuneParams,
) -> bool {
    let _ = bs;
    #[cfg(target_arch = "x86_64")]
    {
        if k == 8 && crate::util::avx512_available() {
            let v = tune.resolved_variant();
            // SAFETY: same format invariants as the SpMV span kernels;
            // the span's sub-streams cover exactly its blocks.
            unsafe {
                dispatch_variant!(v, spmm_k8_span_avx512(span, x, y));
            }
            return true;
        }
    }
    let _ = (span, x, y, k, tune);
    false
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512vl,avx512bw,avx512dq")]
unsafe fn spmm_k8_span_avx512<const V: usize>(
    span: Span<'_, f64>,
    x: &[f64],
    y: &mut [f64],
) {
    const K: usize = 8;
    let r = span.r;
    let stride = 4 + r; // f64 header: colidx:4B | r × u8 masks
    let intervals = span.rowptr.len() - 1;
    let mut h = span.headers.as_ptr();
    let mut vals = span.values.as_ptr();
    let xp = x.as_ptr();
    // r ≤ 8 accumulators (one zmm per block row).
    let mut acc = [_mm512_setzero_pd(); 8];
    for it in 0..intervals {
        let row0 = it * r;
        let nb = (span.rowptr[it + 1] - span.rowptr[it]) as usize;
        if nb == 0 {
            continue;
        }
        for a in acc.iter_mut().take(r) {
            *a = _mm512_setzero_pd();
        }
        block_loop!(Var::<V>, nb, {
            prefetch_streams::<_, V>(h, stride, vals);
            let col0 = u32::from_le_bytes([*h, *h.add(1), *h.add(2), *h.add(3)])
                as usize;
            // The x "window" here is the k-wide row panel at col0.
            prefetch_x::<_, V>(xp, col0 * K);
            for i in 0..r {
                let mut mask = *h.add(4 + i) as u32;
                while mask != 0 {
                    let lane = mask.trailing_zeros() as usize;
                    mask &= mask - 1;
                    let v = _mm512_set1_pd(*vals);
                    vals = vals.add(1);
                    let xrow = _mm512_loadu_pd(xp.add((col0 + lane) * K));
                    acc[i] = _mm512_fmadd_pd(v, xrow, acc[i]);
                }
            }
            h = h.add(stride);
        });
        let rows_here = r.min(span.rows - row0);
        for i in 0..rows_here {
            let yp = y.as_mut_ptr().add((row0 + i) * K);
            _mm512_storeu_pd(yp, _mm512_add_pd(_mm512_loadu_pd(yp), acc[i]));
        }
    }
    debug_assert_eq!(
        vals as usize,
        span.values.as_ptr() as usize + span.values.len() * 8
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::{csr_to_block, BlockSize};
    use crate::matrix::suite;
    use crate::util::Rng;

    fn dense_spmm(
        csr: &crate::matrix::Csr,
        x: &[f64],
        k: usize,
    ) -> Vec<f64> {
        let mut y = vec![0.0; csr.rows * k];
        for r in 0..csr.rows {
            for idx in csr.row_range(r) {
                let c = csr.colidx[idx] as usize;
                let v = csr.values[idx];
                for j in 0..k {
                    y[r * k + j] += v * x[c * k + j];
                }
            }
        }
        y
    }

    #[test]
    fn generic_matches_dense_all_sizes() {
        let csr = suite::quantum_clusters(200, 3, 8, 5, 11);
        let mut rng = Rng::new(5);
        for k in [1usize, 3, 8] {
            let x: Vec<f64> =
                (0..csr.cols * k).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            let want = dense_spmm(&csr, &x, k);
            for bs in BlockSize::PAPER_SIZES {
                let bm = csr_to_block(&csr, bs).unwrap();
                let mut y = vec![0.0; csr.rows * k];
                spmm_generic(&bm, &x, &mut y, k);
                crate::testkit::assert_close(
                    &y,
                    &want,
                    1e-9,
                    &format!("{bs} k={k}"),
                );
            }
        }
    }

    #[test]
    fn avx512_k8_matches_generic() {
        let csr = suite::fem_blocked(150, 3, 6, 13);
        let mut rng = Rng::new(6);
        let x: Vec<f64> =
            (0..csr.cols * 8).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        let want = dense_spmm(&csr, &x, 8);
        for bs in BlockSize::PAPER_SIZES {
            let bm = csr_to_block(&csr, bs).unwrap();
            let mut y = vec![0.0; csr.rows * 8];
            spmm_k8(&bm, &x, &mut y);
            crate::testkit::assert_close(&y, &want, 1e-9, &format!("{bs} k8"));
        }
    }

    #[test]
    fn accumulates_into_y() {
        let csr = suite::poisson2d(6);
        let bm = csr_to_block(&csr, BlockSize::new(2, 4)).unwrap();
        let x = vec![1.0; csr.cols * 8];
        let mut y = vec![2.0; csr.rows * 8];
        spmm_k8(&bm, &x, &mut y);
        let mut want = vec![0.0; csr.rows * 8];
        spmm_generic(&bm, &x, &mut want, 8);
        for (a, b) in y.iter().zip(&want) {
            assert!((a - (b + 2.0)).abs() < 1e-12);
        }
    }

    #[test]
    fn auto_dispatch_matches_generic_any_k() {
        let csr = suite::quantum_clusters(180, 3, 7, 4, 9);
        let mut rng = Rng::new(11);
        for k in [1usize, 2, 5, 8] {
            let x: Vec<f64> =
                (0..csr.cols * k).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            for bs in [BlockSize::new(1, 8), BlockSize::new(4, 4)] {
                let bm = csr_to_block(&csr, bs).unwrap();
                let mut want = vec![0.0; csr.rows * k];
                spmm_generic(&bm, &x, &mut want, k);
                let mut got = vec![0.0; csr.rows * k];
                spmm_auto(&bm, &x, &mut got, k);
                // 1e-9: the k=8 AVX-512 path uses FMA, the generic
                // kernel rounds the multiply separately.
                crate::testkit::assert_close(
                    &got,
                    &want,
                    1e-9,
                    &format!("{bs} auto k={k}"),
                );
            }
        }
    }

    #[test]
    fn f32_spmm_matches_widened_oracle() {
        let csr = suite::banded(250, 8, 0.5, 7);
        let csr32 = csr.to_precision::<f32>();
        let k = 4usize;
        let x32: Vec<f32> = (0..csr32.cols * k)
            .map(|i| ((i * 13) % 29) as f32 * 0.05 - 0.7)
            .collect();
        let bm = csr_to_block(&csr32, BlockSize::new(2, 16)).unwrap();
        let mut y = vec![0.0f32; csr32.rows * k];
        spmm_auto(&bm, &x32, &mut y, k);
        // Oracle: k single-vector f32 reference products.
        for j in 0..k {
            let xj: Vec<f32> = (0..csr32.cols).map(|c| x32[c * k + j]).collect();
            let mut want = vec![0.0f32; csr32.rows];
            csr32.spmv_ref(&xj, &mut want);
            for r in 0..csr32.rows {
                assert!(
                    (y[r * k + j] - want[r]).abs()
                        <= 2e-4 * want[r].abs().max(1.0),
                    "j={j} row {r}"
                );
            }
        }
    }

    #[test]
    fn span_form_matches_full_matrix() {
        use crate::parallel::partition_intervals;
        let csr = suite::fem_blocked(220, 3, 5, 3);
        let bm = csr_to_block(&csr, BlockSize::new(2, 8)).unwrap();
        let k = 3usize;
        let mut rng = Rng::new(21);
        let x: Vec<f64> =
            (0..csr.cols * k).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        let mut want = vec![0.0; csr.rows * k];
        spmm_generic(&bm, &x, &mut want, k);
        // Stitch the full product from 3 disjoint spans.
        let spans = partition_intervals(&bm, 3);
        let mut got = vec![0.0; csr.rows * k];
        for (i, s) in spans.iter().enumerate() {
            let val_end = if i + 1 < spans.len() {
                spans[i + 1].val_begin
            } else {
                bm.values.len()
            };
            let span = Span::slice(
                &bm,
                s.interval_begin,
                s.interval_end,
                s.block_begin,
                s.block_end,
                s.val_begin,
                val_end,
            );
            spmm_generic_span(
                span,
                bm.bs,
                &x,
                &mut got[s.row_begin * k..s.row_end * k],
                k,
            );
        }
        crate::testkit::assert_close(&got, &want, 1e-12, "span stitch");
    }

    #[test]
    fn k1_equals_spmv() {
        let csr = suite::banded(300, 6, 0.4, 17);
        let bm = csr_to_block(&csr, BlockSize::new(1, 8)).unwrap();
        let mut rng = Rng::new(7);
        let x: Vec<f64> =
            (0..csr.cols).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        let mut y_spmm = vec![0.0; csr.rows];
        spmm_generic(&bm, &x, &mut y_spmm, 1);
        let mut y_spmv = vec![0.0; csr.rows];
        super::super::spmv_block(&bm, &x, &mut y_spmv, false);
        crate::testkit::assert_close(&y_spmm, &y_spmv, 1e-12, "k=1");
    }
}
