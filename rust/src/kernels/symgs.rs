//! Gauss–Seidel sweeps (SymGS) over a [`TriangularSplit`] — the
//! smoother/preconditioner companion of the triangular solves in
//! [`super::sptrsv`].
//!
//! A forward sweep updates rows ascending with
//! `x[r] ← (b[r] − L·x_new − U·x_old) / d[r]`; a backward sweep
//! mirrors it descending; a symmetric sweep is one of each. Per row,
//! the off-diagonal sum accumulates the strict-lower entries then the
//! strict-upper entries — exactly the ascending-column order a full
//! CSR row walk would use (every lower column < r < every upper
//! column), so the split-based sweep is **bit-identical** to classic
//! in-place CSR Gauss–Seidel.
//!
//! The level-scheduled variants ([`gs_forward_levels`] /
//! [`gs_backward_levels`]) read the *previous* iterate from a
//! snapshot for the not-yet-swept side: a sequential forward sweep at
//! row `r` reads `x_old` for columns `> r`, and the snapshot is
//! exactly `x_old` — while the swept side's columns live in strictly
//! earlier levels and are final. The parallel sweep is therefore
//! bit-identical to the sequential one (at the cost of one vector
//! copy per half-sweep), not merely tolerance-close — important for
//! the chaotic-relaxation trap where same-level rows of a
//! structurally non-symmetric pattern would otherwise race.

use crate::matrix::TriangularSplit;
use crate::parallel::levels::LevelSchedule;
use crate::parallel::{run_levels, WorkerPool};
use crate::scalar::Scalar;

/// One forward Gauss–Seidel sweep, in place:
/// `x ← (D + L)⁻¹ (b − U x)` computed row-by-row ascending.
pub fn gs_forward<T: Scalar>(split: &TriangularSplit<T>, b: &[T], x: &mut [T]) {
    let n = split.n();
    assert!(b.len() == n && x.len() == n);
    for r in 0..n {
        let mut s = T::ZERO;
        for k in split.lower.row_range(r) {
            s += split.lower.values[k] * x[split.lower.colidx[k] as usize];
        }
        for k in split.upper.row_range(r) {
            s += split.upper.values[k] * x[split.upper.colidx[k] as usize];
        }
        x[r] = (b[r] - s) / split.diag[r];
    }
}

/// One backward Gauss–Seidel sweep, in place:
/// `x ← (D + U)⁻¹ (b − L x)` computed row-by-row descending.
pub fn gs_backward<T: Scalar>(
    split: &TriangularSplit<T>,
    b: &[T],
    x: &mut [T],
) {
    let n = split.n();
    assert!(b.len() == n && x.len() == n);
    for r in (0..n).rev() {
        let mut s = T::ZERO;
        for k in split.lower.row_range(r) {
            s += split.lower.values[k] * x[split.lower.colidx[k] as usize];
        }
        for k in split.upper.row_range(r) {
            s += split.upper.values[k] * x[split.upper.colidx[k] as usize];
        }
        x[r] = (b[r] - s) / split.diag[r];
    }
}

/// `sweeps` symmetric Gauss–Seidel sweeps (forward + backward each),
/// in place.
pub fn symgs<T: Scalar>(
    split: &TriangularSplit<T>,
    b: &[T],
    x: &mut [T],
    sweeps: usize,
) {
    for _ in 0..sweeps {
        gs_forward(split, b, x);
        gs_backward(split, b, x);
    }
}

/// Level-scheduled forward sweep: bit-identical to [`gs_forward`] (see
/// the module docs for the snapshot argument). `sched` must be the
/// lower-triangle levels ([`crate::parallel::lower_levels`]).
pub fn gs_forward_levels<T: Scalar>(
    split: &TriangularSplit<T>,
    sched: &LevelSchedule,
    pool: &WorkerPool,
    b: &[T],
    x: &mut [T],
) {
    let n = split.n();
    assert!(b.len() == n && x.len() == n);
    let snap = x.to_vec();
    run_levels(pool, sched, x, |row, rd| {
        let mut s = T::ZERO;
        for k in split.lower.row_range(row) {
            // Swept side: columns < row live in earlier levels — final.
            s += split.lower.values[k] * rd.get(split.lower.colidx[k] as usize);
        }
        for k in split.upper.row_range(row) {
            // Unswept side: the previous iterate, from the snapshot.
            s += split.upper.values[k] * snap[split.upper.colidx[k] as usize];
        }
        (b[row] - s) / split.diag[row]
    });
}

/// Level-scheduled backward sweep: bit-identical to [`gs_backward`].
/// `sched` must be the upper-triangle levels
/// ([`crate::parallel::upper_levels`]).
pub fn gs_backward_levels<T: Scalar>(
    split: &TriangularSplit<T>,
    sched: &LevelSchedule,
    pool: &WorkerPool,
    b: &[T],
    x: &mut [T],
) {
    let n = split.n();
    assert!(b.len() == n && x.len() == n);
    let snap = x.to_vec();
    run_levels(pool, sched, x, |row, rd| {
        let mut s = T::ZERO;
        for k in split.lower.row_range(row) {
            s += split.lower.values[k] * snap[split.lower.colidx[k] as usize];
        }
        for k in split.upper.row_range(row) {
            s += split.upper.values[k] * rd.get(split.upper.colidx[k] as usize);
        }
        (b[row] - s) / split.diag[row]
    });
}

/// `sweeps` level-scheduled symmetric sweeps — bit-identical to
/// [`symgs`].
pub fn symgs_levels<T: Scalar>(
    split: &TriangularSplit<T>,
    fwd: &LevelSchedule,
    bwd: &LevelSchedule,
    pool: &WorkerPool,
    b: &[T],
    x: &mut [T],
    sweeps: usize,
) {
    for _ in 0..sweeps {
        gs_forward_levels(split, fwd, pool, b, x);
        gs_backward_levels(split, bwd, pool, b, x);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::suite;
    use crate::parallel::{lower_levels, upper_levels};

    /// In-place Gauss–Seidel straight off the full CSR matrix — the
    /// classic formulation the split-based sweep must reproduce
    /// bit-for-bit.
    fn gs_forward_csr(csr: &crate::matrix::Csr, b: &[f64], x: &mut [f64]) {
        for r in 0..csr.rows {
            let mut s = 0.0;
            let mut d = 0.0;
            for k in csr.row_range(r) {
                let c = csr.colidx[k] as usize;
                if c == r {
                    d = csr.values[k];
                } else {
                    s += csr.values[k] * x[c];
                }
            }
            x[r] = (b[r] - s) / d;
        }
    }

    #[test]
    fn forward_sweep_bit_identical_to_csr_walk() {
        let csr = suite::poisson2d(14);
        let split = csr.triangular_split().unwrap();
        let n = csr.rows;
        let b: Vec<f64> = (0..n).map(|i| ((i * 5) % 9) as f64 - 4.0).collect();
        let mut want = vec![0.25; n];
        gs_forward_csr(&csr, &b, &mut want);
        let mut got = vec![0.25; n];
        gs_forward(&split, &b, &mut got);
        assert_eq!(got, want);
    }

    #[test]
    fn sweeps_reduce_residual_monotonically_on_poisson() {
        let csr = suite::poisson2d(12);
        let split = csr.triangular_split().unwrap();
        let n = csr.rows;
        let b = vec![1.0; n];
        let residual = |x: &[f64]| -> f64 {
            let mut ax = vec![0.0; n];
            csr.spmv_ref(x, &mut ax);
            (0..n).map(|i| (b[i] - ax[i]).powi(2)).sum::<f64>()
        };
        let mut x = vec![0.0; n];
        let mut last = residual(&x);
        for sweep in 0..5 {
            symgs(&split, &b, &mut x, 1);
            let now = residual(&x);
            assert!(now < last, "sweep {sweep}: {now} !< {last}");
            last = now;
        }
    }

    #[test]
    fn level_scheduled_sweeps_bit_identical() {
        let split = suite::poisson2d(18).triangular_split().unwrap();
        let n = split.n();
        let b: Vec<f64> = (0..n).map(|i| ((i * 11) % 7) as f64 - 3.0).collect();
        let fwd = lower_levels(&split.lower);
        let bwd = upper_levels(&split.upper);
        let pool = WorkerPool::new(4);
        let mut want = vec![0.5; n];
        symgs(&split, &b, &mut want, 3);
        let mut got = vec![0.5; n];
        symgs_levels(&split, &fwd, &bwd, &pool, &b, &mut got, 3);
        assert_eq!(got, want);
    }
}
