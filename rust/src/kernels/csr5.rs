//! CSR5 (Liu & Vinter, ICS 2015) — the paper's second comparator.
//!
//! Re-implementation of the format's defining features:
//!
//! - the nnz stream is partitioned into 2D tiles of `ω×σ` (ω = SIMD
//!   lanes = 8 doubles, σ = 16), each tile stored **transposed**
//!   (column-major) so lane `j` owns the contiguous nnz chunk
//!   `[tile_start + j·σ, tile_start + (j+1)·σ)` while memory reads of
//!   `value/colidx` stay unit-stride across lanes;
//! - a per-tile descriptor holds the `bit_flag` (one bit per position,
//!   set at row starts) plus the rows that start inside the tile;
//! - SpMV runs a two-phase tile kernel: a vectorizable product phase
//!   over the transposed arrays and a segmented-sum phase driven by the
//!   bit flags, with an open-row carry across tile boundaries (no
//!   atomics — tiles are processed in order, as in the sequential CSR5
//!   kernel);
//! - the tail that does not fill a whole tile falls back to the CSR row
//!   loop, as in the reference implementation.

use crate::matrix::Csr;
use crate::scalar::Scalar;

/// SIMD lanes (doubles in a 512-bit vector).
pub const OMEGA: usize = 8;
/// Default tile height.
pub const SIGMA: usize = 16;

/// One ω×σ tile descriptor.
#[derive(Clone, Debug)]
struct Tile {
    /// Bit `p` set ⇔ the nnz at in-tile position `p` (original order)
    /// starts a new row. ω·σ = 128 bits.
    bit_flag: [u64; 2],
    /// Row indices of the flagged positions, in order.
    flag_rows: Vec<u32>,
}

/// A matrix converted to CSR5 (generic over the element precision).
pub struct Csr5Matrix<T: Scalar = f64> {
    pub rows: usize,
    pub cols: usize,
    /// Transposed per-tile values: tile t, element (i, j) at
    /// `t·ωσ + i·ω + j` holding original nnz `t·ωσ + j·σ + i`.
    vals_t: Vec<T>,
    cols_t: Vec<u32>,
    tiles: Vec<Tile>,
    /// Row open at the entry of each tile (the row the first element
    /// continues, before any flag fires).
    tile_open_row: Vec<u32>,
    /// CSR tail (entries beyond the last full tile).
    tail: Csr<T>,
    /// Row where the tail starts (its first partial row).
    nnz: usize,
}

impl<T: Scalar> Csr5Matrix<T> {
    /// Builds CSR5 storage from CSR.
    pub fn from_csr(m: &Csr<T>) -> Self {
        let tile_elems = OMEGA * SIGMA;
        let n_tiles = m.nnz() / tile_elems;
        let tiled_nnz = n_tiles * tile_elems;

        // Row of each nnz position (expanded rowptr) for the tiled part,
        // plus flags.
        let mut vals_t = vec![T::ZERO; tiled_nnz];
        let mut cols_t = vec![0u32; tiled_nnz];
        let mut tiles = Vec::with_capacity(n_tiles);
        let mut tile_open_row = Vec::with_capacity(n_tiles);

        // Walk rows and positions simultaneously.
        let mut row_of = vec![0u32; tiled_nnz.min(m.nnz())];
        {
            let mut r = 0usize;
            for p in 0..tiled_nnz {
                while m.rowptr[r + 1] as usize <= p {
                    r += 1;
                }
                row_of[p] = r as u32;
            }
        }

        for t in 0..n_tiles {
            let base = t * tile_elems;
            let mut bit_flag = [0u64; 2];
            let mut flag_rows = Vec::new();
            tile_open_row.push(row_of[base]);
            for p in 0..tile_elems {
                let g = base + p; // global nnz index, original order
                let r = row_of[g] as usize;
                if m.rowptr[r] as usize == g {
                    // `g` is the first nnz of row r → row start flag.
                    bit_flag[p / 64] |= 1u64 << (p % 64);
                    flag_rows.push(r as u32);
                }
                // Transpose: original in-tile position p = j·σ + i goes
                // to storage slot i·ω + j.
                let (j, i) = (p / SIGMA, p % SIGMA);
                vals_t[base + i * OMEGA + j] = m.values[g];
                cols_t[base + i * OMEGA + j] = m.colidx[g];
            }
            tiles.push(Tile { bit_flag, flag_rows });
        }

        // Tail: remaining entries as a small CSR over the original rows.
        let tail = if tiled_nnz < m.nnz() {
            build_tail(m, tiled_nnz)
        } else {
            Csr { rows: 0, cols: m.cols, rowptr: vec![0], colidx: vec![], values: vec![] }
        };

        Csr5Matrix {
            rows: m.rows,
            cols: m.cols,
            vals_t,
            cols_t,
            tiles,
            tile_open_row,
            tail,
            nnz: m.nnz(),
        }
    }

    /// Stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.nnz
    }

    /// `y += A·x`.
    pub fn spmv(&self, x: &[T], y: &mut [T]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        let tile_elems = OMEGA * SIGMA;
        let mut prod = [T::ZERO; OMEGA * SIGMA];

        // Open-row carry across tiles: (open_row, open_sum) flow from
        // tile to tile; a flag closes the open segment into y.
        let mut open_sum = T::ZERO;
        let mut open_row = self
            .tile_open_row
            .first()
            .copied()
            .unwrap_or(0) as usize;
        for (t, tile) in self.tiles.iter().enumerate() {
            let base = t * tile_elems;
            // Phase 1 (vectorizable): products in transposed layout —
            // unit-stride over vals_t/cols_t.
            let vt = &self.vals_t[base..base + tile_elems];
            let ct = &self.cols_t[base..base + tile_elems];
            for s in 0..tile_elems {
                prod[s] = vt[s] * x[ct[s] as usize];
            }
            // Phase 2: segmented sum in original order, lane by lane.
            let mut fr = 0usize; // next flag_rows entry
            for j in 0..OMEGA {
                for i in 0..SIGMA {
                    let p = j * SIGMA + i;
                    if tile.bit_flag[p / 64] & (1u64 << (p % 64)) != 0 {
                        // Row start: close the open segment.
                        y[open_row] += open_sum;
                        open_sum = T::ZERO;
                        open_row = tile.flag_rows[fr] as usize;
                        fr += 1;
                    }
                    open_sum += prod[p % SIGMA * OMEGA + p / SIGMA];
                }
            }
            // Keep (open_row, open_sum) flowing into the next tile: the
            // next tile's open row equals this one, enforced at build.
        }
        if !self.tiles.is_empty() {
            // Flush the final open segment of the tiled part.
            y[open_row] += open_sum;
        }

        // Tail via the CSR row loop.
        if self.tail.nnz() > 0 {
            for r in 0..self.tail.rows {
                let mut s = T::ZERO;
                for k in self.tail.row_range(r) {
                    s += self.tail.values[k] * x[self.tail.colidx[k] as usize];
                }
                // tail rows are (row_offset + r) in the original matrix,
                // encoded via cols of rowptr — see build_tail.
                y[self.tail_row_base() + r] += s;
            }
        }
    }

    fn tail_row_base(&self) -> usize {
        self.rows - self.tail.rows
    }
}

/// Builds the tail CSR: all nnz at positions `>= start` (the last
/// partial tile). The tail covers complete trailing rows plus possibly
/// one partial row at its head; partial sums simply accumulate into the
/// same `y` row, so correctness is preserved.
fn build_tail<T: Scalar>(m: &Csr<T>, start: usize) -> Csr<T> {
    // First row that has entries at position >= start.
    let mut first_row = match m.rowptr.binary_search(&(start as u32)) {
        Ok(mut r) => {
            // Skip empty rows mapping to the same position.
            while r + 1 < m.rowptr.len() && m.rowptr[r + 1] as usize == start {
                r += 1;
            }
            r
        }
        Err(ins) => ins - 1,
    };
    first_row = first_row.min(m.rows.saturating_sub(1));
    let rows = m.rows - first_row;
    let mut rowptr = Vec::with_capacity(rows + 1);
    rowptr.push(0u32);
    for r in first_row..m.rows {
        let a = (m.rowptr[r] as usize).max(start);
        let b = (m.rowptr[r + 1] as usize).max(start);
        rowptr.push(rowptr.last().unwrap() + (b - a) as u32);
    }
    Csr {
        rows,
        cols: m.cols,
        rowptr,
        colidx: m.colidx[start..].to_vec(),
        values: m.values[start..].to_vec(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::{suite, Coo};

    fn check(csr: &Csr) {
        let c5 = Csr5Matrix::from_csr(csr);
        let x: Vec<f64> =
            (0..csr.cols).map(|i| ((i * 7) % 13) as f64 - 6.0).collect();
        let mut want = vec![0.0; csr.rows];
        csr.spmv_ref(&x, &mut want);
        let mut got = vec![0.0; csr.rows];
        c5.spmv(&x, &mut got);
        for i in 0..csr.rows {
            assert!(
                (got[i] - want[i]).abs() <= 1e-9 * want[i].abs().max(1.0),
                "row {i}: {} vs {}",
                got[i],
                want[i]
            );
        }
    }

    #[test]
    fn matches_reference_on_suite() {
        for sm in suite::test_subset() {
            check(&sm.csr);
        }
    }

    #[test]
    fn nnz_smaller_than_one_tile() {
        // Entire matrix in the tail path.
        let mut coo = Coo::new(5, 5);
        for i in 0..5 {
            coo.push(i, i, i as f64 + 1.0);
        }
        check(&coo.to_csr().unwrap());
    }

    #[test]
    fn nnz_exact_tile_multiple() {
        // 128 nnz = exactly one tile, no tail.
        let mut coo = Coo::new(16, 16);
        for r in 0..16 {
            for k in 0..8 {
                coo.push(r, (r + k) % 16, (r * 8 + k) as f64 * 0.1 + 1.0);
            }
        }
        let csr = coo.to_csr().unwrap();
        assert_eq!(csr.nnz(), 128);
        let c5 = Csr5Matrix::from_csr(&csr);
        assert_eq!(c5.tiles.len(), 1);
        assert_eq!(c5.tail.nnz(), 0);
        check(&csr);
    }

    #[test]
    fn row_spanning_multiple_tiles() {
        // A single row with 1000 nnz spans many tiles: the open-row
        // carry must flow across tile boundaries.
        let mut coo = Coo::new(3, 1200);
        for c in 0..1000 {
            coo.push(1, c, (c % 10) as f64 + 0.5);
        }
        coo.push(0, 0, 2.0);
        coo.push(2, 5, 3.0);
        check(&coo.to_csr().unwrap());
    }

    #[test]
    fn empty_rows_between_tiles() {
        let mut coo = Coo::new(400, 64);
        // Rows 0..100 dense-ish, 100..300 empty, 300..400 sparse.
        for r in 0..100 {
            for k in 0..4 {
                coo.push(r, (r + k * 16) % 64, 1.0 + k as f64);
            }
        }
        for r in 300..400 {
            coo.push(r, r % 64, -1.0);
        }
        check(&coo.to_csr().unwrap());
    }

    #[test]
    fn empty_matrix() {
        let csr = Csr::from_raw(4, 4, vec![0; 5], vec![], vec![]).unwrap();
        check(&csr);
    }
}
