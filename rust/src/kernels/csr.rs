//! Tuned CSR SpMV — the "vendor library" baseline standing in for
//! Intel MKL's `mkl_dcsrmv` in the paper's comparisons.
//!
//! A plain row loop with 4-way unrolled accumulation; rustc+LLVM
//! auto-vectorizes the gather-free parts. This is deliberately the
//! *strong* version of the CSR kernel so the β speedups we report are
//! not against a strawman. Generic over the element precision, and
//! row-range addressable so the engine can row-chunk it across
//! threads.

use crate::matrix::Csr;
use crate::scalar::Scalar;

/// `y += A·x` over CSR.
pub fn spmv<T: Scalar>(m: &Csr<T>, x: &[T], y: &mut [T]) {
    assert_eq!(x.len(), m.cols);
    assert_eq!(y.len(), m.rows);
    spmv_rows(m, 0, m.rows, x, y);
}

/// `y[i - r0] += (A·x)[i]` for rows `i ∈ [r0, r1)` — the row-chunked
/// form the parallel engine path feeds one disjoint `y` slice per
/// thread.
pub fn spmv_rows<T: Scalar>(
    m: &Csr<T>,
    r0: usize,
    r1: usize,
    x: &[T],
    y: &mut [T],
) {
    assert!(r0 <= r1 && r1 <= m.rows);
    assert!(y.len() >= r1 - r0);
    let colidx = &m.colidx[..];
    let values = &m.values[..];
    for r in r0..r1 {
        let a = m.rowptr[r] as usize;
        let b = m.rowptr[r + 1] as usize;
        // 4-way unroll with independent partial sums to break the FMA
        // dependency chain.
        let mut s0 = T::ZERO;
        let mut s1 = T::ZERO;
        let mut s2 = T::ZERO;
        let mut s3 = T::ZERO;
        let mut k = a;
        while k + 4 <= b {
            s0 += values[k] * x[colidx[k] as usize];
            s1 += values[k + 1] * x[colidx[k + 1] as usize];
            s2 += values[k + 2] * x[colidx[k + 2] as usize];
            s3 += values[k + 3] * x[colidx[k + 3] as usize];
            k += 4;
        }
        let mut s = (s0 + s1) + (s2 + s3);
        while k < b {
            s += values[k] * x[colidx[k] as usize];
            k += 1;
        }
        y[r - r0] += s;
    }
}

/// Multi-RHS `Y += A·X` over CSR with the row-major `[cols × k]` /
/// `[rows × k]` layout of [`crate::kernels::spmm`]: each nonzero is a
/// dense k-wide AXPY, so no de-interleaving pass is needed (used by
/// the hybrid schedule's CSR segments).
pub fn spmm<T: Scalar>(m: &Csr<T>, x: &[T], y: &mut [T], k: usize) {
    assert!(k > 0);
    assert_eq!(x.len(), m.cols * k, "x must be cols*k");
    assert_eq!(y.len(), m.rows * k, "y must be rows*k");
    for r in 0..m.rows {
        let yrow = &mut y[r * k..(r + 1) * k];
        for idx in m.row_range(r) {
            let v = m.values[idx];
            let c = m.colidx[idx] as usize;
            let xrow = &x[c * k..(c + 1) * k];
            for j in 0..k {
                yrow[j] += v * xrow[j];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::suite;

    #[test]
    fn spmm_matches_k_spmvs() {
        let sm = &suite::test_subset()[2];
        let csr = &sm.csr;
        let k = 3usize;
        let x: Vec<f64> = (0..csr.cols * k)
            .map(|i| ((i * 11) % 23) as f64 * 0.2 - 2.0)
            .collect();
        let mut y = vec![0.0; csr.rows * k];
        spmm(csr, &x, &mut y, k);
        for j in 0..k {
            let xj: Vec<f64> = (0..csr.cols).map(|c| x[c * k + j]).collect();
            let mut want = vec![0.0; csr.rows];
            csr.spmv_ref(&xj, &mut want);
            for r in 0..csr.rows {
                assert!(
                    (y[r * k + j] - want[r]).abs()
                        <= 1e-9 * want[r].abs().max(1.0),
                    "j={j} row {r}"
                );
            }
        }
    }

    #[test]
    fn matches_reference_on_suite() {
        for sm in suite::test_subset() {
            let x: Vec<f64> =
                (0..sm.csr.cols).map(|i| ((i % 9) as f64) - 4.0).collect();
            let mut want = vec![0.0; sm.csr.rows];
            sm.csr.spmv_ref(&x, &mut want);
            let mut got = vec![0.0; sm.csr.rows];
            spmv(&sm.csr, &x, &mut got);
            for i in 0..got.len() {
                assert!(
                    (got[i] - want[i]).abs() <= 1e-9 * want[i].abs().max(1.0),
                    "{} row {i}",
                    sm.name
                );
            }
        }
    }

    #[test]
    fn f32_matches_reference() {
        let sm = &suite::test_subset()[4];
        let csr32: Csr<f32> = sm.csr.to_precision();
        let x: Vec<f32> =
            (0..csr32.cols).map(|i| ((i % 9) as f32) - 4.0).collect();
        let mut want = vec![0.0f32; csr32.rows];
        csr32.spmv_ref(&x, &mut want);
        let mut got = vec![0.0f32; csr32.rows];
        spmv(&csr32, &x, &mut got);
        for i in 0..got.len() {
            assert!((got[i] - want[i]).abs() <= 2e-4 * want[i].abs().max(1.0));
        }
    }

    #[test]
    fn row_chunks_compose_to_full() {
        let sm = &suite::test_subset()[1];
        let csr = &sm.csr;
        let x: Vec<f64> = (0..csr.cols).map(|i| (i % 5) as f64 * 0.3).collect();
        let mut want = vec![0.0; csr.rows];
        spmv(csr, &x, &mut want);
        let mid = csr.rows / 3;
        let mut got = vec![0.0; csr.rows];
        spmv_rows(csr, 0, mid, &x, &mut got[..mid]);
        spmv_rows(csr, mid, csr.rows, &x, &mut got[mid..]);
        for i in 0..csr.rows {
            assert!((got[i] - want[i]).abs() < 1e-12, "row {i}");
        }
    }

    #[test]
    fn row_lengths_around_unroll_boundary() {
        // Rows of length 0..=9 hit every unroll tail case.
        use crate::matrix::Coo;
        let mut coo = Coo::new(10, 16);
        for r in 0..10 {
            for k in 0..r {
                coo.push(r, k, (r * 16 + k) as f64 * 0.01 + 1.0);
            }
        }
        let csr = coo.to_csr().unwrap();
        let x: Vec<f64> = (0..16).map(|i| i as f64 - 7.5).collect();
        let mut want = vec![0.0; 10];
        csr.spmv_ref(&x, &mut want);
        let mut got = vec![0.0; 10];
        spmv(&csr, &x, &mut got);
        for i in 0..10 {
            assert!((got[i] - want[i]).abs() < 1e-12);
        }
    }
}
