//! Tuned CSR SpMV — the "vendor library" baseline standing in for
//! Intel MKL's `mkl_dcsrmv` in the paper's comparisons.
//!
//! A plain row loop with 4-way unrolled accumulation; rustc+LLVM
//! auto-vectorizes the gather-free parts. This is deliberately the
//! *strong* version of the CSR kernel so the β speedups we report are
//! not against a strawman.

use crate::matrix::Csr;

/// `y += A·x` over CSR.
pub fn spmv(m: &Csr, x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), m.cols);
    assert_eq!(y.len(), m.rows);
    let colidx = &m.colidx[..];
    let values = &m.values[..];
    for r in 0..m.rows {
        let a = m.rowptr[r] as usize;
        let b = m.rowptr[r + 1] as usize;
        // 4-way unroll with independent partial sums to break the FMA
        // dependency chain.
        let mut s0 = 0.0f64;
        let mut s1 = 0.0f64;
        let mut s2 = 0.0f64;
        let mut s3 = 0.0f64;
        let mut k = a;
        while k + 4 <= b {
            s0 += values[k] * x[colidx[k] as usize];
            s1 += values[k + 1] * x[colidx[k + 1] as usize];
            s2 += values[k + 2] * x[colidx[k + 2] as usize];
            s3 += values[k + 3] * x[colidx[k + 3] as usize];
            k += 4;
        }
        let mut s = (s0 + s1) + (s2 + s3);
        while k < b {
            s += values[k] * x[colidx[k] as usize];
            k += 1;
        }
        y[r] += s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::suite;

    #[test]
    fn matches_reference_on_suite() {
        for sm in suite::test_subset() {
            let x: Vec<f64> =
                (0..sm.csr.cols).map(|i| ((i % 9) as f64) - 4.0).collect();
            let mut want = vec![0.0; sm.csr.rows];
            sm.csr.spmv_ref(&x, &mut want);
            let mut got = vec![0.0; sm.csr.rows];
            spmv(&sm.csr, &x, &mut got);
            for i in 0..got.len() {
                assert!(
                    (got[i] - want[i]).abs() <= 1e-9 * want[i].abs().max(1.0),
                    "{} row {i}",
                    sm.name
                );
            }
        }
    }

    #[test]
    fn row_lengths_around_unroll_boundary() {
        // Rows of length 0..=9 hit every unroll tail case.
        use crate::matrix::Coo;
        let mut coo = Coo::new(10, 16);
        for r in 0..10 {
            for k in 0..r {
                coo.push(r, k, (r * 16 + k) as f64 * 0.01 + 1.0);
            }
        }
        let csr = coo.to_csr().unwrap();
        let x: Vec<f64> = (0..16).map(|i| i as f64 - 7.5).collect();
        let mut want = vec![0.0; 10];
        csr.spmv_ref(&x, &mut want);
        let mut got = vec![0.0; 10];
        spmv(&csr, &x, &mut got);
        for i in 0..10 {
            assert!((got[i] - want[i]).abs() < 1e-12);
        }
    }
}
