//! Sparse triangular solve (SpTRSV) — forward/backward substitution on
//! the strict-triangular parts of a [`TriangularSplit`]
//! (`(L + D) x = b` and `(D + U) x = b`).
//!
//! Three executions of the same recurrence:
//!
//! - **CSR reference** ([`sptrsv_lower_ref`] / [`sptrsv_upper_ref`]) —
//!   the semantic definition, a plain row loop.
//! - **Masked block-based** ([`sptrsv_lower_block`] /
//!   [`sptrsv_upper_block`]) — consumes the *same* β storage as the
//!   SpMV kernels: the interleaved header stream (4-byte block column
//!   + `r` mask words, [`crate::formats::HEADER_COLIDX_BYTES`]) and
//!   the padding-free value stream. Unlike SpMV, the solve recurrence
//!   is sequential *within* a row chain, so the walk is scalar — the
//!   win is operating on the solver's resident format with zero
//!   conversion, not SIMD.
//! - **Level-scheduled** ([`sptrsv_lower_levels`] /
//!   [`sptrsv_upper_levels`]) — the CSR row recurrence executed
//!   level-parallel on a [`WorkerPool`] via
//!   [`crate::parallel::levels`].
//!
//! All three accumulate each row's off-diagonal sum in ascending
//! column order, so they are **bit-identical** to each other: the
//! block walk visits blocks left-to-right and mask bits
//! low-to-high, and the level executor never changes the per-row
//! accumulation, only which rows run concurrently.
//!
//! Diagonals must be nonzero; rows listed by
//! [`TriangularSplit::missing_diagonals`] make the solve produce
//! non-finite values (the preconditioner constructors reject such
//! matrices up front with a typed error).

use crate::formats::{BlockMatrix, HEADER_COLIDX_BYTES};
use crate::matrix::{Csr, TriangularSplit};
use crate::parallel::levels::LevelSchedule;
use crate::parallel::{run_levels, WorkerPool};
use crate::scalar::{MaskWord, Scalar};

/// Reference forward substitution: solves `(L + D) x = b` where
/// `lower` is the strict lower triangle and `diag` the diagonal.
pub fn sptrsv_lower_ref<T: Scalar>(
    lower: &Csr<T>,
    diag: &[T],
    b: &[T],
    x: &mut [T],
) {
    let n = lower.rows;
    assert_eq!(lower.cols, n);
    assert!(diag.len() == n && b.len() == n && x.len() == n);
    for r in 0..n {
        let mut s = T::ZERO;
        for k in lower.row_range(r) {
            s += lower.values[k] * x[lower.colidx[k] as usize];
        }
        x[r] = (b[r] - s) / diag[r];
    }
}

/// Reference backward substitution: solves `(D + U) x = b` where
/// `upper` is the strict upper triangle and `diag` the diagonal.
pub fn sptrsv_upper_ref<T: Scalar>(
    upper: &Csr<T>,
    diag: &[T],
    b: &[T],
    x: &mut [T],
) {
    let n = upper.rows;
    assert_eq!(upper.cols, n);
    assert!(diag.len() == n && b.len() == n && x.len() == n);
    for r in (0..n).rev() {
        let mut s = T::ZERO;
        for k in upper.row_range(r) {
            s += upper.values[k] * x[upper.colidx[k] as usize];
        }
        x[r] = (b[r] - s) / diag[r];
    }
}

/// First value index of every block: the running popcount over the
/// padding-free value stream (values are laid out block-by-block,
/// row-major within a block — the β layout invariant).
fn value_bases<T: Scalar>(bm: &BlockMatrix<T>) -> Vec<usize> {
    let r = bm.bs.r;
    let mut bases = Vec::with_capacity(bm.n_blocks());
    let mut acc = 0usize;
    for blk in 0..bm.n_blocks() {
        bases.push(acc);
        for i in 0..r {
            acc += bm.block_masks[blk * r + i].count_ones() as usize;
        }
    }
    debug_assert_eq!(acc, bm.values.len());
    bases
}

/// Row `i`'s sum contribution from one block of the header stream:
/// walks the mask bits low-to-high (ascending columns), consuming
/// values from `off`. Returns the updated sum.
#[inline]
fn block_row_sum<T: Scalar>(
    bm: &BlockMatrix<T>,
    h: &[u8],
    base: usize,
    i: usize,
    x: &[T],
    mut s: T,
) -> T {
    let c = bm.bs.c;
    let mb = <T::Mask as MaskWord>::BYTES;
    let mask = <T::Mask as MaskWord>::read_le(&h[HEADER_COLIDX_BYTES + mb * i..]);
    if mask.is_zero() {
        return s;
    }
    let col0 = u32::from_le_bytes([h[0], h[1], h[2], h[3]]) as usize;
    // Skip the values of the block's earlier rows.
    let mut off = base;
    for j in 0..i {
        let mj =
            <T::Mask as MaskWord>::read_le(&h[HEADER_COLIDX_BYTES + mb * j..]);
        off += mj.count_ones() as usize;
    }
    for k in 0..c {
        if mask.test(k) {
            s += bm.values[off] * x[col0 + k];
            off += 1;
        }
    }
    s
}

/// Forward substitution over β storage of the **strict lower**
/// triangle: solves `(L + D) x = b`. Bit-identical to
/// [`sptrsv_lower_ref`] on the same split (see the module docs).
pub fn sptrsv_lower_block<T: Scalar>(
    bm: &BlockMatrix<T>,
    diag: &[T],
    b: &[T],
    x: &mut [T],
) {
    let n = bm.rows;
    assert_eq!(bm.cols, n);
    assert!(diag.len() == n && b.len() == n && x.len() == n);
    let r = bm.bs.r;
    let mb = <T::Mask as MaskWord>::BYTES;
    let stride = HEADER_COLIDX_BYTES + mb * r;
    let bases = value_bases(bm);
    for it in 0..bm.intervals() {
        let row0 = it * r;
        let (a, bk) =
            (bm.block_rowptr[it] as usize, bm.block_rowptr[it + 1] as usize);
        let rows_here = r.min(n - row0);
        for i in 0..rows_here {
            let row = row0 + i;
            let mut s = T::ZERO;
            // Blocks are stored left-to-right: ascending columns, so
            // the accumulation order matches the CSR reference. Rows
            // solved earlier this interval (cols in [row0, row)) are
            // already final because `i` ascends.
            for blk in a..bk {
                let h = &bm.headers[blk * stride..(blk + 1) * stride];
                s = block_row_sum(bm, h, bases[blk], i, x, s);
            }
            x[row] = (b[row] - s) / diag[row];
        }
    }
}

/// Backward substitution over β storage of the **strict upper**
/// triangle: solves `(D + U) x = b`. Bit-identical to
/// [`sptrsv_upper_ref`] on the same split.
pub fn sptrsv_upper_block<T: Scalar>(
    bm: &BlockMatrix<T>,
    diag: &[T],
    b: &[T],
    x: &mut [T],
) {
    let n = bm.rows;
    assert_eq!(bm.cols, n);
    assert!(diag.len() == n && b.len() == n && x.len() == n);
    let r = bm.bs.r;
    let mb = <T::Mask as MaskWord>::BYTES;
    let stride = HEADER_COLIDX_BYTES + mb * r;
    let bases = value_bases(bm);
    for it in (0..bm.intervals()).rev() {
        let row0 = it * r;
        let (a, bk) =
            (bm.block_rowptr[it] as usize, bm.block_rowptr[it + 1] as usize);
        let rows_here = r.min(n - row0);
        // Rows descend: row `row0 + i` only references columns > it,
        // which later iterations of this loop (or later intervals)
        // have already finalized.
        for i in (0..rows_here).rev() {
            let row = row0 + i;
            let mut s = T::ZERO;
            for blk in a..bk {
                let h = &bm.headers[blk * stride..(blk + 1) * stride];
                s = block_row_sum(bm, h, bases[blk], i, x, s);
            }
            x[row] = (b[row] - s) / diag[row];
        }
    }
}

/// Level-scheduled forward substitution: the CSR recurrence of
/// [`sptrsv_lower_ref`] with the rows of each dependency level
/// ([`crate::parallel::lower_levels`]) solved across the pool's
/// workers. Bit-identical to the sequential solve.
pub fn sptrsv_lower_levels<T: Scalar>(
    lower: &Csr<T>,
    diag: &[T],
    sched: &LevelSchedule,
    pool: &WorkerPool,
    b: &[T],
    x: &mut [T],
) {
    let n = lower.rows;
    assert!(diag.len() == n && b.len() == n && x.len() == n);
    run_levels(pool, sched, x, |row, rd| {
        let mut s = T::ZERO;
        for k in lower.row_range(row) {
            s += lower.values[k] * rd.get(lower.colidx[k] as usize);
        }
        (b[row] - s) / diag[row]
    });
}

/// Level-scheduled backward substitution
/// ([`crate::parallel::upper_levels`] ordering). Bit-identical to the
/// sequential solve.
pub fn sptrsv_upper_levels<T: Scalar>(
    upper: &Csr<T>,
    diag: &[T],
    sched: &LevelSchedule,
    pool: &WorkerPool,
    b: &[T],
    x: &mut [T],
) {
    let n = upper.rows;
    assert!(diag.len() == n && b.len() == n && x.len() == n);
    run_levels(pool, sched, x, |row, rd| {
        let mut s = T::ZERO;
        for k in upper.row_range(row) {
            s += upper.values[k] * rd.get(upper.colidx[k] as usize);
        }
        (b[row] - s) / diag[row]
    });
}

/// Convenience: solves `(L + D) x = b` then `(D + U) y = x` on a full
/// split — the two-solve shape an ILU/SSOR-style application uses.
pub fn sptrsv_split<T: Scalar>(
    split: &TriangularSplit<T>,
    b: &[T],
    scratch: &mut [T],
    x: &mut [T],
) {
    sptrsv_lower_ref(&split.lower, &split.diag, b, scratch);
    sptrsv_upper_ref(&split.upper, &split.diag, scratch, x);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::{csr_to_block, BlockSize};
    use crate::matrix::suite;

    /// Residual check `(L + D) x = b` against the split itself.
    fn check_lower_residual(
        split: &TriangularSplit<f64>,
        b: &[f64],
        x: &[f64],
        tol: f64,
    ) {
        let n = split.n();
        let mut ax = vec![0.0; n];
        split.lower.spmv_ref(x, &mut ax);
        for r in 0..n {
            ax[r] += split.diag[r] * x[r];
            assert!(
                (ax[r] - b[r]).abs() <= tol * b[r].abs().max(1.0),
                "row {r}: {} vs {}",
                ax[r],
                b[r]
            );
        }
    }

    #[test]
    fn lower_ref_solves_poisson_split() {
        let split = suite::poisson2d(12).triangular_split().unwrap();
        let n = split.n();
        let b: Vec<f64> = (0..n).map(|i| ((i * 7) % 5) as f64 - 2.0).collect();
        let mut x = vec![0.0; n];
        sptrsv_lower_ref(&split.lower, &split.diag, &b, &mut x);
        check_lower_residual(&split, &b, &x, 1e-12);
    }

    #[test]
    fn upper_ref_solves_poisson_split() {
        let split = suite::poisson2d(12).triangular_split().unwrap();
        let n = split.n();
        let b: Vec<f64> = (0..n).map(|i| ((i * 3) % 7) as f64 - 3.0).collect();
        let mut x = vec![0.0; n];
        sptrsv_upper_ref(&split.upper, &split.diag, &b, &mut x);
        let mut ax = vec![0.0; n];
        split.upper.spmv_ref(&x, &mut ax);
        for r in 0..n {
            ax[r] += split.diag[r] * x[r];
            assert!((ax[r] - b[r]).abs() <= 1e-12 * b[r].abs().max(1.0));
        }
    }

    #[test]
    fn block_solvers_bit_identical_to_refs() {
        let split = suite::poisson2d(15).triangular_split().unwrap();
        let n = split.n();
        let b: Vec<f64> =
            (0..n).map(|i| ((i * 13) % 11) as f64 * 0.5 - 2.0).collect();
        for bs in BlockSize::PAPER_SIZES {
            let lo = csr_to_block(&split.lower, bs).unwrap();
            let up = csr_to_block(&split.upper, bs).unwrap();
            let mut want = vec![0.0; n];
            sptrsv_lower_ref(&split.lower, &split.diag, &b, &mut want);
            let mut got = vec![0.0; n];
            sptrsv_lower_block(&lo, &split.diag, &b, &mut got);
            assert_eq!(got, want, "lower {bs}");
            let mut want = vec![0.0; n];
            sptrsv_upper_ref(&split.upper, &split.diag, &b, &mut want);
            let mut got = vec![0.0; n];
            sptrsv_upper_block(&up, &split.diag, &b, &mut got);
            assert_eq!(got, want, "upper {bs}");
        }
    }

    #[test]
    fn level_scheduled_bit_identical_to_ref() {
        let split = suite::poisson2d(20).triangular_split().unwrap();
        let n = split.n();
        let b: Vec<f64> = (0..n).map(|i| ((i * 31) % 13) as f64 - 6.0).collect();
        let pool = WorkerPool::new(4);
        let fwd = crate::parallel::lower_levels(&split.lower);
        let bwd = crate::parallel::upper_levels(&split.upper);
        let mut want = vec![0.0; n];
        sptrsv_lower_ref(&split.lower, &split.diag, &b, &mut want);
        let mut got = vec![0.0; n];
        sptrsv_lower_levels(&split.lower, &split.diag, &fwd, &pool, &b, &mut got);
        assert_eq!(got, want, "lower levels");
        let mut want = vec![0.0; n];
        sptrsv_upper_ref(&split.upper, &split.diag, &b, &mut want);
        let mut got = vec![0.0; n];
        sptrsv_upper_levels(&split.upper, &split.diag, &bwd, &pool, &b, &mut got);
        assert_eq!(got, want, "upper levels");
    }

    #[test]
    fn split_solve_round_trips() {
        let split = suite::poisson2d(10).triangular_split().unwrap();
        let n = split.n();
        let b: Vec<f64> = (0..n).map(|i| (i % 4) as f64 + 0.5).collect();
        let mut scratch = vec![0.0; n];
        let mut x = vec![0.0; n];
        sptrsv_split(&split, &b, &mut scratch, &mut x);
        // (D + U) x = scratch and (L + D) scratch = b.
        check_lower_residual(&split, &b, &scratch, 1e-12);
    }
}
