//! Serializable solve plans: the inspector–executor split
//! ([`super::SpmvPlan`]) extended to a whole preconditioned solve.
//!
//! A [`SolvePlan`] records the solver, the preconditioner choice and
//! the level-schedule decision **next to** the inner SpMV plan, so a
//! repeat solve on the same matrix skips both the SpMV inspection
//! (kernel selection, tile sizing) and the triangular level analysis:
//! [`solve_from_plan`] rebuilds the engine with
//! [`SpmvEngine::from_plan`] and the preconditioner with
//! [`super::PrecondKind::build_planned`]. The inner plan's
//! [`super::MatrixFingerprint`] still refuses instantiation against
//! the wrong matrix, and plans persist through the same
//! checksummed-envelope files as every other durable artifact
//! ([`crate::util::durable`]).

use std::path::Path;

use super::engine::SpmvEngine;
use super::plan::SpmvPlan;
use super::precond::{PrecondKind, Preconditioner};
use crate::matrix::Csr;
use crate::parallel::LevelSummary;
use crate::scalar::Scalar;
use crate::util::durable::{self, RawState, StateError, StateErrorKind};
use crate::util::json::Json;

/// Current solve-plan schema version.
pub const SOLVE_PLAN_VERSION: u32 = 1;

/// Which Krylov driver a solve plan runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SolverKind {
    /// Unpreconditioned conjugate gradient ([`super::cg_solve`]).
    Cg,
    /// Preconditioned conjugate gradient ([`super::pcg_with`]).
    Pcg,
    /// BiCGSTAB for general square systems ([`super::bicgstab`]).
    BiCgStab,
}

impl SolverKind {
    /// Parses `cg`, `pcg`, `bicgstab`.
    pub fn parse(s: &str) -> Option<SolverKind> {
        match s.trim().to_ascii_lowercase().as_str() {
            "cg" => Some(SolverKind::Cg),
            "pcg" => Some(SolverKind::Pcg),
            "bicgstab" => Some(SolverKind::BiCgStab),
            _ => None,
        }
    }
}

impl std::fmt::Display for SolverKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SolverKind::Cg => write!(f, "cg"),
            SolverKind::Pcg => write!(f, "pcg"),
            SolverKind::BiCgStab => write!(f, "bicgstab"),
        }
    }
}

/// Every decision of a preconditioned solve, as a plain serializable
/// record — see the module docs for the lifecycle.
#[derive(Clone, Debug, PartialEq)]
pub struct SolvePlan {
    /// Schema version ([`SOLVE_PLAN_VERSION`]).
    pub version: u32,
    /// The Krylov driver.
    pub solver: SolverKind,
    /// The preconditioner choice (buildable against the matrix).
    pub precond: PrecondKind,
    /// The persisted level-schedule decision for triangular-solve
    /// preconditioners (`None` for `none`/`jacobi`): a planned build
    /// reuses the sequential-vs-parallel verdict instead of
    /// re-analyzing the dependency levels.
    pub levels: Option<LevelSummary>,
    /// The inner SpMV plan (kernel, threads, tile width, tuning, and
    /// the matrix fingerprint that gates instantiation).
    pub spmv: SpmvPlan,
}

impl SolvePlan {
    /// Artifact label used in [`StateError`] and degradation events.
    pub const ARTIFACT: &'static str = "solve-plan";

    /// The identity of the matrix this plan was inspected on.
    pub fn fingerprint(&self) -> super::plan::MatrixFingerprint {
        self.spmv.fingerprint
    }

    /// Serializes to JSON text.
    pub fn to_json(&self) -> String {
        let mut fields = vec![
            ("version", Json::Num(self.version as f64)),
            ("solver", Json::Str(self.solver.to_string())),
            ("precond", Json::Str(self.precond.to_string())),
        ];
        if let Some(l) = self.levels {
            fields.push((
                "levels",
                Json::obj(vec![
                    ("n_levels", Json::Num(l.n_levels as f64)),
                    ("max_width", Json::Num(l.max_width as f64)),
                    ("parallel", Json::Bool(l.parallel)),
                ]),
            ));
        }
        fields.push((
            "spmv",
            Json::parse(&self.spmv.to_json()).expect("plan emits valid json"),
        ));
        Json::obj(fields).to_string()
    }

    /// Parses from JSON text, rejecting malformed plans with a
    /// descriptive error.
    pub fn from_json(text: &str) -> anyhow::Result<SolvePlan> {
        let v = Json::parse(text)?;
        let dim = |k: &str| -> anyhow::Result<usize> {
            let n = v
                .get(k)
                .and_then(|n| n.as_f64())
                .ok_or_else(|| anyhow::anyhow!("solve plan: missing {k}"))?;
            anyhow::ensure!(
                n >= 0.0 && n.fract() == 0.0,
                "solve plan: {k} must be a non-negative integer, got {n}"
            );
            Ok(n as usize)
        };
        let version = dim("version")? as u32;
        anyhow::ensure!(
            version >= 1 && version <= SOLVE_PLAN_VERSION,
            "solve plan: unsupported version {version} (this build \
             understands 1..={SOLVE_PLAN_VERSION})"
        );
        let solver_s = v
            .get("solver")
            .and_then(|s| s.as_str())
            .ok_or_else(|| anyhow::anyhow!("solve plan: missing solver"))?;
        let solver = SolverKind::parse(solver_s).ok_or_else(|| {
            anyhow::anyhow!("solve plan: unknown solver '{solver_s}'")
        })?;
        let precond_s = v
            .get("precond")
            .and_then(|s| s.as_str())
            .ok_or_else(|| anyhow::anyhow!("solve plan: missing precond"))?;
        let precond = PrecondKind::parse(precond_s).ok_or_else(|| {
            anyhow::anyhow!("solve plan: unknown preconditioner '{precond_s}'")
        })?;
        let levels = match v.get("levels") {
            None => None,
            Some(l) => {
                let num = |k: &str| -> anyhow::Result<usize> {
                    let n =
                        l.get(k).and_then(|n| n.as_f64()).ok_or_else(|| {
                            anyhow::anyhow!("solve plan: levels: missing {k}")
                        })?;
                    anyhow::ensure!(
                        n >= 0.0 && n.fract() == 0.0,
                        "solve plan: levels: {k} must be a non-negative \
                         integer"
                    );
                    Ok(n as usize)
                };
                Some(LevelSummary {
                    n_levels: num("n_levels")?,
                    max_width: num("max_width")?,
                    parallel: matches!(
                        l.get("parallel"),
                        Some(Json::Bool(true))
                    ),
                })
            }
        };
        let spmv = SpmvPlan::from_json_value(
            v.get("spmv")
                .ok_or_else(|| anyhow::anyhow!("solve plan: missing spmv"))?,
        )?;
        Ok(SolvePlan { version, solver, precond, levels, spmv })
    }

    /// Saves the plan to a file, envelope-framed and atomically (see
    /// [`crate::util::durable`]).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<(), StateError> {
        durable::save_state(
            Self::ARTIFACT,
            path.as_ref(),
            &format!("{}\n", self.to_json()),
        )
    }

    /// Loads a plan from a file. A missing file is an error (a plan
    /// path is always explicitly named); a corrupt file is
    /// quarantined and reported as a typed [`StateError`].
    pub fn load(path: impl AsRef<Path>) -> Result<SolvePlan, StateError> {
        let path = path.as_ref();
        match durable::read_state(Self::ARTIFACT, path)? {
            RawState::Missing => Err(StateError {
                artifact: Self::ARTIFACT,
                path: path.to_path_buf(),
                kind: StateErrorKind::Io(std::io::Error::new(
                    std::io::ErrorKind::NotFound,
                    "no such file",
                )),
                quarantined_to: None,
            }),
            RawState::Empty => Err(StateError {
                artifact: Self::ARTIFACT,
                path: path.to_path_buf(),
                kind: StateErrorKind::Malformed("file is empty".into()),
                quarantined_to: None,
            }),
            RawState::Payload { text, .. } => {
                Self::from_json(&text).map_err(|e| {
                    durable::quarantined(
                        Self::ARTIFACT,
                        path,
                        StateErrorKind::Malformed(e.to_string()),
                    )
                })
            }
        }
    }
}

/// The executor half of a persisted solve: instantiates the engine
/// from the inner SpMV plan (no kernel selection) and the
/// preconditioner from the recorded choice (no level re-analysis when
/// the plan ran sequentially).
///
/// Reordered engine plans are refused: under a reordering the
/// engine's resident matrix is the *permuted* one, while the solve's
/// right-hand side and the preconditioner's vectors live in original
/// index space.
pub fn solve_from_plan<T: Scalar>(
    csr: Csr<T>,
    plan: &SolvePlan,
) -> anyhow::Result<(SpmvEngine<T>, Box<dyn Preconditioner<T>>)> {
    anyhow::ensure!(
        plan.spmv.reorder.is_none(),
        "solve plan: reordered engines are not supported for \
         preconditioned solves"
    );
    let engine = SpmvEngine::from_plan(csr, &plan.spmv)?;
    let m = plan
        .precond
        .build_planned(engine.csr(), engine.pool(), plan.levels)
        .map_err(|e| anyhow::anyhow!("solve plan: preconditioner: {e}"))?;
    Ok((engine, m))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::KernelKind;
    use crate::matrix::suite;

    fn plan_for(csr: &crate::matrix::Csr) -> SolvePlan {
        let spmv = SpmvEngine::builder(csr.clone())
            .kernel(KernelKind::Beta(2, 4))
            .plan()
            .unwrap();
        SolvePlan {
            version: SOLVE_PLAN_VERSION,
            solver: SolverKind::Pcg,
            precond: PrecondKind::SymGs { sweeps: 2 },
            levels: Some(LevelSummary {
                n_levels: 23,
                max_width: 12,
                parallel: false,
            }),
            spmv,
        }
    }

    #[test]
    fn solver_kind_round_trips() {
        for k in [SolverKind::Cg, SolverKind::Pcg, SolverKind::BiCgStab] {
            assert_eq!(SolverKind::parse(&k.to_string()), Some(k));
        }
        assert_eq!(SolverKind::parse("gmres"), None);
    }

    #[test]
    fn json_round_trip() {
        let csr = suite::poisson2d(12);
        let p = plan_for(&csr);
        let back = SolvePlan::from_json(&p.to_json()).unwrap();
        assert_eq!(p, back);
        // Without a level summary (jacobi).
        let mut q = plan_for(&csr);
        q.precond = PrecondKind::Jacobi;
        q.levels = None;
        let back = SolvePlan::from_json(&q.to_json()).unwrap();
        assert_eq!(q, back);
    }

    #[test]
    fn json_rejects_malformed() {
        let csr = suite::poisson2d(10);
        let good = plan_for(&csr).to_json();
        let bad = good.replace("\"pcg\"", "\"gmres\"");
        assert!(SolvePlan::from_json(&bad).is_err());
        let bad = good.replace("symgs(2)", "turboprecond");
        assert!(SolvePlan::from_json(&bad).is_err());
        let bad = good.replace("\"version\":1", "\"version\":99");
        assert!(SolvePlan::from_json(&bad).is_err());
        assert!(SolvePlan::from_json("{").is_err());
    }

    #[test]
    fn executor_refuses_wrong_matrix_and_reorder() {
        let csr = suite::poisson2d(12);
        let p = plan_for(&csr);
        // Wrong matrix: fingerprint mismatch surfaces from the inner
        // SpMV plan.
        let other = suite::poisson2d(13);
        assert!(solve_from_plan(other, &p).is_err());
        // Reordered inner plan: refused outright.
        let mut q = p.clone();
        q.spmv.reorder = Some(crate::matrix::ReorderKind::Rcm);
        assert!(solve_from_plan(csr, &q).is_err());
    }

    #[test]
    fn executor_rebuilds_engine_and_preconditioner() {
        let csr = suite::poisson2d(12);
        let fresh = PrecondKind::SymGs { sweeps: 2 }.build(&csr, None).unwrap();
        let mut p = plan_for(&csr);
        p.levels = fresh.level_summary();
        let (engine, m) = solve_from_plan(csr.clone(), &p).unwrap();
        assert_eq!(engine.plan().kernel, KernelKind::Beta(2, 4));
        let n = csr.rows;
        let r: Vec<f64> = (0..n).map(|i| (i % 5) as f64 - 2.0).collect();
        let mut z1 = vec![0.0; n];
        fresh.apply(&r, &mut z1);
        let mut z2 = vec![0.0; n];
        m.apply(&r, &mut z2);
        assert_eq!(z1, z2);
        assert_eq!(m.level_summary(), p.levels);
    }

    #[test]
    fn save_and_load_round_trip() {
        let dir = std::env::temp_dir().join(format!(
            "spc5-solve-plan-{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("plan.json");
        let csr = suite::poisson2d(10);
        let p = plan_for(&csr);
        p.save(&path).unwrap();
        let back = SolvePlan::load(&path).unwrap();
        assert_eq!(p, back);
        // A missing file is a typed error, not a default.
        assert!(SolvePlan::load(dir.join("absent.json")).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
