//! Conjugate-gradient solver on the native kernels — the paper's
//! motivating workload ("iterative solvers based on Krylov subspaces,
//! such as the popular CG method"), used by the CG example to compare
//! the pure-Rust path against the AOT-compiled XLA path (which runs the
//! same algorithm lowered from JAX — see python/compile/model.py).
//!
//! Generic over the engine's precision: vectors are `T`, while the
//! Krylov scalars (dot products, α, β, residual norms) accumulate in
//! f64 — the mixed-precision shape single-precision solvers need to
//! stay stable.
//!
//! Every `spmv_into` inside the iteration loop reuses the engine's
//! **persistent worker pool**: a 500-iteration solve wakes the same
//! long-lived workers 500 times instead of spawning (and tearing down)
//! 500 × `threads` threads, and the per-worker working vectors are
//! allocated once, not per call (see `rust/tests/runtime_pool.rs` for
//! the thread-count regression test).

use super::engine::SpmvEngine;
use crate::scalar::Scalar;

/// Outcome of a CG solve.
#[derive(Clone, Debug)]
pub struct CgReport {
    pub iterations: usize,
    /// Final squared residual norm ‖b − A·x‖².
    pub residual_norm2: f64,
    pub converged: bool,
    /// Total SpMV count (1 initial + 1 per iteration).
    pub spmv_count: usize,
    /// The iteration stopped on a numerical breakdown (zero
    /// denominator / ρ / ω) with the residual still above tolerance —
    /// distinguishable from simply running out of iterations.
    pub breakdown: bool,
}

/// f64-accumulated dot product of two `T` vectors.
pub(crate) fn dot_f64<T: Scalar>(a: &[T], b: &[T]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x.to_f64() * y.to_f64()).sum()
}

/// Solves the SPD system `A·x = b` with (unpreconditioned) CG through
/// the engine's SpMV. `x` holds the initial guess on entry, the
/// solution on exit. Stops at `max_iters` or when the squared residual
/// drops below `tol2`.
pub fn cg_solve<T: Scalar>(
    engine: &SpmvEngine<T>,
    b: &[T],
    x: &mut [T],
    max_iters: usize,
    tol2: f64,
) -> CgReport {
    let n = b.len();
    assert_eq!(x.len(), n);
    let mut spmv_count = 0usize;

    // r = b − A·x
    let mut r = vec![T::ZERO; n];
    engine.spmv_into(x, &mut r);
    spmv_count += 1;
    for i in 0..n {
        r[i] = b[i] - r[i];
    }
    let mut p = r.clone();
    let mut rs: f64 = dot_f64(&r, &r);
    let mut ap = vec![T::ZERO; n];

    let mut iterations = 0usize;
    let mut broke = false;
    while iterations < max_iters && rs > tol2 {
        engine.spmv_into(&p, &mut ap);
        spmv_count += 1;
        let denom: f64 = dot_f64(&p, &ap);
        if denom == 0.0 {
            broke = true;
            break;
        }
        let alpha = rs / denom;
        let alpha_t = T::from_f64(alpha);
        for i in 0..n {
            x[i] += alpha_t * p[i];
            r[i] -= alpha_t * ap[i];
        }
        let rs_new: f64 = dot_f64(&r, &r);
        let beta_t = T::from_f64(rs_new / rs);
        for i in 0..n {
            p[i] = r[i] + beta_t * p[i];
        }
        rs = rs_new;
        iterations += 1;
    }

    CgReport {
        iterations,
        residual_norm2: rs,
        converged: rs <= tol2,
        spmv_count,
        breakdown: broke && rs > tol2,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::KernelKind;
    use crate::matrix::{suite, Csr};
    use crate::util::Rng;

    fn solve_poisson(
        n: usize,
        kernel: KernelKind,
        threads: usize,
    ) -> (Vec<f64>, CgReport, Csr) {
        let csr = suite::poisson2d(n);
        let engine = SpmvEngine::builder(csr.clone())
            .threads(threads)
            .kernel(kernel)
            .build()
            .unwrap();
        let mut rng = Rng::new(33);
        let b: Vec<f64> = (0..csr.rows).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        let mut x = vec![0.0; csr.rows];
        let report = cg_solve(&engine, &b, &mut x, 2000, 1e-20);
        // Check A·x ≈ b.
        let mut ax = vec![0.0; csr.rows];
        csr.spmv_ref(&x, &mut ax);
        for i in 0..csr.rows {
            assert!((ax[i] - b[i]).abs() < 1e-7, "row {i}");
        }
        (x, report, csr)
    }

    #[test]
    fn converges_on_poisson_seq() {
        let (_, report, _) = solve_poisson(12, KernelKind::Beta(1, 8), 1);
        assert!(report.converged, "{report:?}");
        assert!(report.iterations < 600);
        assert_eq!(report.spmv_count, report.iterations + 1);
    }

    #[test]
    fn converges_on_poisson_parallel() {
        let (_, report, _) = solve_poisson(12, KernelKind::Beta(4, 4), 4);
        assert!(report.converged, "{report:?}");
    }

    #[test]
    fn converges_through_csr_baseline() {
        // CG through the engine's CSR (and CSR5) dispatch — possible
        // only now that the facade serves the baselines.
        let (x_csr, report, _) = solve_poisson(10, KernelKind::Csr, 1);
        assert!(report.converged, "{report:?}");
        let (x_csr5, report5, _) = solve_poisson(10, KernelKind::Csr5, 1);
        assert!(report5.converged, "{report5:?}");
        crate::testkit::assert_close(&x_csr5, &x_csr, 1e-6, "csr vs csr5");
    }

    #[test]
    fn same_solution_across_kernels() {
        let (x1, _, _) = solve_poisson(10, KernelKind::Beta(1, 8), 1);
        let (x2, _, _) = solve_poisson(10, KernelKind::Beta(8, 4), 1);
        crate::testkit::assert_close(&x2, &x1, 1e-6, "kernel choice");
    }

    #[test]
    fn f32_cg_converges_loosely() {
        // Single-precision CG with f64 Krylov scalars: converges to an
        // f32-appropriate tolerance on a small SPD system.
        let csr32: Csr<f32> = suite::poisson2d(8).to_precision();
        let engine = SpmvEngine::builder(csr32.clone())
            .kernel(KernelKind::Beta(1, 16))
            .build()
            .unwrap();
        let mut rng = Rng::new(7);
        let b: Vec<f32> = (0..csr32.rows)
            .map(|_| rng.range_f64(-1.0, 1.0) as f32)
            .collect();
        let mut x = vec![0.0f32; csr32.rows];
        let report = cg_solve(&engine, &b, &mut x, 2000, 1e-8);
        assert!(report.converged, "{report:?}");
        let mut ax = vec![0.0f32; csr32.rows];
        csr32.spmv_ref(&x, &mut ax);
        for i in 0..csr32.rows {
            assert!((ax[i] - b[i]).abs() < 1e-3, "row {i}");
        }
    }

    #[test]
    fn zero_rhs_converges_immediately() {
        let csr = suite::poisson2d(6);
        let engine = SpmvEngine::builder(csr.clone()).build().unwrap();
        let b = vec![0.0; csr.rows];
        let mut x = vec![0.0; csr.rows];
        let report = cg_solve(&engine, &b, &mut x, 100, 1e-20);
        assert_eq!(report.iterations, 0);
        assert!(report.converged);
        assert!(!report.breakdown);
    }

    #[test]
    fn respects_max_iters() {
        let csr = suite::poisson2d(16);
        let engine = SpmvEngine::builder(csr.clone()).build().unwrap();
        let b = vec![1.0; csr.rows];
        let mut x = vec![0.0; csr.rows];
        let report = cg_solve(&engine, &b, &mut x, 3, 1e-30);
        assert_eq!(report.iterations, 3);
        assert!(!report.converged);
        // Ran out of iterations — not a numerical breakdown.
        assert!(!report.breakdown);
    }
}
