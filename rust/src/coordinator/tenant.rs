//! Multi-tenant hosting: many matrices served from one process.
//!
//! A [`TenantRegistry`] keys running services by
//! [`MatrixFingerprint`] — the same structural identity the plan
//! cache uses — so a server can host thousands of matrices and route
//! each request to its tenant by fingerprint. Registration cold-starts
//! through the shared in-memory [`PlanCache`]: a tenant whose
//! structure was planned before (by any earlier tenant, or persisted
//! in an earlier process) instantiates straight from the cached plan
//! and skips inspection entirely; a miss plans once and feeds the
//! cache for the next arrival. [`TenantStats::from_cache`] and
//! [`TenantStats::cold_start_s`] make the difference observable.
//!
//! Tenants choose their serving shape at registration: a single
//! micro-batching [`SpmvService`] (default) or a row-sharded
//! [`ShardedService`] for `shards > 1`, each with its own admission
//! [`QueuePolicy`]. Per-tenant operations are independent; operations
//! on one tenant never block another's. Blocking calls (`recv`,
//! `recv_timeout`, a `Block`-policy `submit`) clone the tenant's
//! `Arc`'d service handle and release the registry lock *before*
//! waiting, so a stalled receiver never wedges registration,
//! deregistration or another tenant's traffic — deregistering a
//! tenant wakes its blocked receivers with "stopped".
//!
//! The fingerprint is value-blind (structure + precision): two
//! matrices with identical sparsity patterns are the *same* tenant.
//! Registering the second is reported as an error rather than
//! silently replacing the first.

use super::cluster::{ShardConfig, ShardedService};
use super::engine::SpmvEngine;
use super::plan::{MatrixFingerprint, PlanCache, SpmvPlan};
use super::service::{
    HealthReport, RecvError, Request, Response, ServiceError,
    ServiceStats, ShardHealth, SpmvService,
};
use super::serving::QueuePolicy;
use crate::kernels::KernelKind;
use crate::matrix::Csr;
use crate::scalar::Scalar;
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

/// Per-tenant serving shape, chosen at registration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TenantConfig {
    /// Worker threads (per shard when `shards > 1`).
    pub threads: usize,
    /// Kernel override; `None` = inspector's choice.
    pub kernel: Option<KernelKind>,
    /// Micro-batching limit (as [`SpmvService::start`]).
    pub max_batch: usize,
    /// Admission policy for this tenant's queue.
    pub queue: QueuePolicy,
    /// `> 1` serves through a [`ShardedService`] with this many
    /// row shards (plan cache unused there: shard sub-matrices have
    /// their own fingerprints).
    pub shards: usize,
    /// First-touch NUMA placement (per shard when sharded).
    pub numa_split: bool,
}

impl Default for TenantConfig {
    fn default() -> Self {
        TenantConfig {
            threads: 1,
            kernel: None,
            max_batch: 8,
            queue: QueuePolicy::default(),
            shards: 1,
            numa_split: false,
        }
    }
}

/// Either serving shape behind one dispatch surface.
enum Serving<T: Scalar> {
    Single(SpmvService<T>),
    Sharded(ShardedService<T>),
}

impl<T: Scalar> Serving<T> {
    fn submit(&self, req: Request<T>) -> Result<(), ServiceError> {
        match self {
            Serving::Single(s) => s.submit(req),
            Serving::Sharded(s) => s.submit(req),
        }
    }

    fn recv(&self) -> Result<Response<T>, RecvError> {
        match self {
            Serving::Single(s) => s.recv(),
            Serving::Sharded(s) => s.recv(),
        }
    }

    fn recv_timeout(
        &self,
        wait: Duration,
    ) -> Result<Response<T>, RecvError> {
        match self {
            Serving::Single(s) => s.recv_timeout(wait),
            Serving::Sharded(s) => s.recv_timeout(wait),
        }
    }

    /// Per-shard health (one entry for a single service).
    fn health(&self) -> Vec<HealthReport> {
        match self {
            Serving::Single(s) => vec![s.health()],
            Serving::Sharded(s) => s.health(),
        }
    }

    fn stats(&self) -> ServiceStats {
        match self {
            Serving::Single(s) => s.stats(),
            Serving::Sharded(s) => s.stats().rollup(),
        }
    }

    /// Shared-reference shutdown: the handle lives in an `Arc` that
    /// blocked receivers may still hold clones of, so it can never be
    /// taken by value. Closing + joining wakes those receivers with
    /// "stopped".
    fn shutdown(&self) -> usize {
        match self {
            Serving::Single(s) => s.shutdown_ref(),
            Serving::Sharded(s) => s.shutdown_ref(),
        }
    }
}

struct Tenant<T: Scalar> {
    name: String,
    fingerprint: MatrixFingerprint,
    /// `Arc` so blocking calls can clone the handle and drop the
    /// registry lock before waiting (see the module docs).
    serving: Arc<Serving<T>>,
    /// Whether registration instantiated from a cached plan.
    from_cache: bool,
    /// Wall time of engine construction (plan or cache hit +
    /// conversion + pool spawn), the cold-start the plan cache cuts.
    cold_start_s: f64,
}

/// One tenant's public snapshot.
#[derive(Clone, Debug)]
pub struct TenantStats {
    pub name: String,
    pub fingerprint: MatrixFingerprint,
    /// Whether this tenant cold-started from a cached plan.
    pub from_cache: bool,
    /// Registration wall time in seconds.
    pub cold_start_s: f64,
    pub stats: ServiceStats,
    /// Per-shard health (one entry for single-service tenants).
    pub health: Vec<HealthReport>,
}

/// Registry-wide rollup: every tenant plus summed counters.
#[derive(Clone, Debug)]
pub struct RegistryStats {
    /// Per-tenant snapshots, sorted by tenant name.
    pub tenants: Vec<TenantStats>,
    /// Total requests served across tenants.
    pub served: usize,
    /// Total submissions refused across tenants.
    pub rejected: usize,
    /// Process-wide durable-state degradations (quarantined caches,
    /// profiles downgraded to baseline, ...) observed so far — see
    /// [`TenantRegistry::degrade_events`] for the individual events.
    pub degraded: usize,
}

/// The multi-tenant host (see module docs). `Sync`: registration and
/// routing may come from any thread.
pub struct TenantRegistry<T: Scalar = f64> {
    tenants: RwLock<HashMap<MatrixFingerprint, Tenant<T>>>,
    cache: Mutex<PlanCache>,
    /// When set, the shared cache is persisted here after every plan
    /// miss (so future *processes* cold-start warm too).
    cache_path: Option<PathBuf>,
}

impl<T: Scalar> TenantRegistry<T> {
    /// An empty registry with a process-local plan cache.
    pub fn new() -> TenantRegistry<T> {
        TenantRegistry {
            tenants: RwLock::new(HashMap::new()),
            cache: Mutex::new(PlanCache::new()),
            cache_path: None,
        }
    }

    /// An empty registry whose plan cache is loaded from — and
    /// persisted back to — the JSON store at `path` (a missing file is
    /// an empty cache). A *corrupt* store is not fatal either: `load`
    /// quarantines it, a degradation event is recorded, and the
    /// registry starts with an empty cache — the next plan miss
    /// persists a repaired store to the same path.
    pub fn with_cache(
        path: impl Into<PathBuf>,
    ) -> anyhow::Result<TenantRegistry<T>> {
        let path = path.into();
        let cache = match PlanCache::load(&path) {
            Ok(cache) => cache,
            Err(e) => {
                crate::util::durable::record_degrade(
                    crate::util::durable::DegradeEvent {
                        artifact: PlanCache::ARTIFACT.into(),
                        path: path.display().to_string(),
                        reason: e.to_string(),
                        fallback: "re-plan and persist repaired cache"
                            .into(),
                    },
                );
                PlanCache::new()
            }
        };
        Ok(TenantRegistry {
            tenants: RwLock::new(HashMap::new()),
            cache: Mutex::new(cache),
            cache_path: Some(path),
        })
    }

    fn tenants_read(
        &self,
    ) -> std::sync::RwLockReadGuard<'_, HashMap<MatrixFingerprint, Tenant<T>>>
    {
        self.tenants.read().unwrap_or_else(|e| e.into_inner())
    }

    fn tenants_write(
        &self,
    ) -> std::sync::RwLockWriteGuard<'_, HashMap<MatrixFingerprint, Tenant<T>>>
    {
        self.tenants.write().unwrap_or_else(|e| e.into_inner())
    }

    /// Registers `csr` under `name` and starts its service, returning
    /// the fingerprint requests must be routed with. Single-service
    /// tenants cold-start through the shared plan cache; sharded
    /// tenants build per-shard engines directly. Fails if a tenant
    /// with the same structural fingerprint is already registered.
    pub fn register(
        &self,
        name: impl Into<String>,
        csr: Csr<T>,
        cfg: TenantConfig,
    ) -> anyhow::Result<MatrixFingerprint> {
        let name = name.into();
        let fingerprint = MatrixFingerprint::of(&csr);
        anyhow::ensure!(
            !self.tenants_read().contains_key(&fingerprint),
            "a tenant with this matrix structure is already registered \
             ({}x{}, {} nnz)",
            csr.rows,
            csr.cols,
            csr.nnz()
        );

        let t0 = Instant::now();
        let (serving, from_cache) = if cfg.shards > 1 {
            let shard_cfg = ShardConfig {
                shards: cfg.shards,
                threads_per_shard: cfg.threads,
                numa_split: cfg.numa_split,
                kernel: cfg.kernel,
                max_batch: cfg.max_batch,
                queue: cfg.queue,
                ..ShardConfig::default()
            };
            (Serving::Sharded(ShardedService::start(csr, shard_cfg)?), false)
        } else {
            let mut builder = SpmvEngine::builder(csr)
                .threads(cfg.threads)
                .numa_split(cfg.numa_split);
            if let Some(kernel) = cfg.kernel {
                builder = builder.kernel(kernel);
            }
            // Hold the shared cache lock only for the cheap plan
            // lookup; the expensive cold start (inspection,
            // conversion, worker-pool spawn) runs outside it so
            // concurrent registrations do not serialize. A miss
            // re-locks to publish the freshly inspected plan (and
            // persist it) — `insert` replaces same-config entries, so
            // two racing misses for one structure converge on a
            // single cache slot.
            let cached = {
                let cache =
                    self.cache.lock().unwrap_or_else(|e| e.into_inner());
                builder.cached_plan(&cache)
            };
            let hit = cached.is_some();
            let engine = match cached {
                Some(plan) => builder.build_from_plan(&plan)?,
                None => {
                    let engine = builder.build()?;
                    let mut cache =
                        self.cache.lock().unwrap_or_else(|e| e.into_inner());
                    cache.insert(engine.plan().clone());
                    if let Some(path) = &self.cache_path {
                        cache.save(path)?;
                    }
                    engine
                }
            };
            let service = SpmvService::start_with_policy(
                engine,
                cfg.max_batch,
                cfg.queue,
            );
            (Serving::Single(service), hit)
        };
        let cold_start_s = t0.elapsed().as_secs_f64();

        let serving = Arc::new(serving);
        let mut tenants = self.tenants_write();
        // Registration raced another thread for the same structure:
        // the loser shuts its freshly started service down.
        if tenants.contains_key(&fingerprint) {
            drop(tenants);
            serving.shutdown();
            anyhow::bail!(
                "a tenant with this matrix structure was registered \
                 concurrently"
            );
        }
        tenants.insert(
            fingerprint,
            Tenant { name, fingerprint, serving, from_cache, cold_start_s },
        );
        Ok(fingerprint)
    }

    /// Registers `csr` served straight from a saved [`SpmvPlan`] —
    /// the fastest cold-start, no inspection and no cache lookup. The
    /// plan's fingerprint guard still applies: a plan for a different
    /// structure (e.g. another shard's sub-matrix) is refused. The
    /// plan fixes threads/kernel; `cfg.threads`, `cfg.kernel` and
    /// `cfg.numa_split` are ignored, and `cfg.shards > 1` is an error.
    pub fn register_plan(
        &self,
        name: impl Into<String>,
        csr: Csr<T>,
        plan: &SpmvPlan,
        cfg: TenantConfig,
    ) -> anyhow::Result<MatrixFingerprint> {
        anyhow::ensure!(
            cfg.shards <= 1,
            "register_plan serves a single engine; a plan cannot drive \
             {} shards (their sub-matrices have different fingerprints)",
            cfg.shards
        );
        let name = name.into();
        let fingerprint = MatrixFingerprint::of(&csr);
        anyhow::ensure!(
            !self.tenants_read().contains_key(&fingerprint),
            "a tenant with this matrix structure is already registered"
        );
        let t0 = Instant::now();
        let engine = SpmvEngine::from_plan(csr, plan)?;
        let service =
            SpmvService::start_with_policy(engine, cfg.max_batch, cfg.queue);
        let cold_start_s = t0.elapsed().as_secs_f64();
        let mut tenants = self.tenants_write();
        if tenants.contains_key(&fingerprint) {
            drop(tenants);
            service.shutdown();
            anyhow::bail!(
                "a tenant with this matrix structure was registered \
                 concurrently"
            );
        }
        tenants.insert(
            fingerprint,
            Tenant {
                name,
                fingerprint,
                serving: Arc::new(Serving::Single(service)),
                from_cache: true,
                cold_start_s,
            },
        );
        Ok(fingerprint)
    }

    /// Clones the tenant's serving handle under a *short* read lock.
    /// Every potentially blocking operation goes through this so the
    /// registry lock is never held across a wait — a stalled receiver
    /// must not block `register`/`deregister` (which need the write
    /// lock) or any other tenant's traffic.
    fn serving(&self, fp: &MatrixFingerprint) -> Option<Arc<Serving<T>>> {
        self.tenants_read().get(fp).map(|t| Arc::clone(&t.serving))
    }

    /// Routes a request to the tenant registered under `fp`.
    pub fn submit(
        &self,
        fp: &MatrixFingerprint,
        req: Request<T>,
    ) -> Result<(), ServiceError> {
        let serving =
            self.serving(fp).ok_or(ServiceError::UnknownTenant)?;
        serving.submit(req)
    }

    /// Blocks for the tenant's next response.
    /// [`RecvError::Stopped`] when the tenant is unknown or its
    /// service stopped cleanly (a blocked receiver wakes with it when
    /// its tenant is deregistered); [`RecvError::Failed`] when a
    /// shard failure aborted a request.
    pub fn recv(
        &self,
        fp: &MatrixFingerprint,
    ) -> Result<Response<T>, RecvError> {
        let serving = self.serving(fp).ok_or(RecvError::Stopped)?;
        serving.recv()
    }

    /// Waits up to `wait` for the tenant's next response. An unknown
    /// fingerprint reports [`RecvError::Stopped`].
    pub fn recv_timeout(
        &self,
        fp: &MatrixFingerprint,
        wait: Duration,
    ) -> Result<Response<T>, RecvError> {
        let serving = self.serving(fp).ok_or(RecvError::Stopped)?;
        serving.recv_timeout(wait)
    }

    /// [`submit`](Self::submit) with bounded retries: transient
    /// refusals — [`ServiceError::Overloaded`] and
    /// [`ServiceError::ShardFailed`] (a supervised restart in
    /// progress) — are retried up to `retries` times with linear
    /// backoff (`attempt × backoff` before attempt `attempt`); other
    /// errors fail immediately. The tenant handle is re-resolved per
    /// attempt, so a tenant re-registered mid-retry is picked up.
    pub fn submit_with_retry(
        &self,
        fp: &MatrixFingerprint,
        req: Request<T>,
        retries: usize,
        backoff: Duration,
    ) -> Result<(), ServiceError> {
        let Request { id, x } = req;
        let mut last = ServiceError::UnknownTenant;
        for attempt in 0..=retries {
            if attempt > 0 {
                std::thread::sleep(backoff.saturating_mul(attempt as u32));
            }
            let serving =
                self.serving(fp).ok_or(ServiceError::UnknownTenant)?;
            match serving.submit(Request { id, x: x.clone() }) {
                Ok(()) => return Ok(()),
                Err(
                    e @ (ServiceError::Overloaded { .. }
                    | ServiceError::ShardFailed { .. }),
                ) => last = e,
                Err(e) => return Err(e),
            }
        }
        Err(last)
    }

    /// Per-shard health of one tenant, or `None` when unknown.
    pub fn tenant_health(
        &self,
        fp: &MatrixFingerprint,
    ) -> Option<Vec<HealthReport>> {
        Some(self.serving(fp)?.health())
    }

    /// One tenant's snapshot, or `None` when unknown.
    pub fn tenant_stats(
        &self,
        fp: &MatrixFingerprint,
    ) -> Option<TenantStats> {
        let tenants = self.tenants_read();
        let t = tenants.get(fp)?;
        Some(TenantStats {
            name: t.name.clone(),
            fingerprint: t.fingerprint,
            from_cache: t.from_cache,
            cold_start_s: t.cold_start_s,
            stats: t.serving.stats(),
            health: t.serving.health(),
        })
    }

    /// Registry-wide rollup across every tenant.
    pub fn stats(&self) -> RegistryStats {
        let tenants = self.tenants_read();
        let mut per: Vec<TenantStats> = tenants
            .values()
            .map(|t| TenantStats {
                name: t.name.clone(),
                fingerprint: t.fingerprint,
                from_cache: t.from_cache,
                cold_start_s: t.cold_start_s,
                stats: t.serving.stats(),
                health: t.serving.health(),
            })
            .collect();
        per.sort_by(|a, b| a.name.cmp(&b.name));
        let served = per.iter().map(|t| t.stats.served).sum();
        let rejected = per.iter().map(|t| t.stats.rejected).sum();
        RegistryStats {
            tenants: per,
            served,
            rejected,
            degraded: crate::util::durable::degrade_count(),
        }
    }

    /// Durable-state degradations observed by this process: every time
    /// a persisted artifact (plan cache, tune profile, record store)
    /// failed verification and a fallback was taken, one event was
    /// recorded here. Operators watch this to learn that state was
    /// quarantined and rebuilt — the service stayed up, but cold-start
    /// or tuning quality may have regressed until the repaired store
    /// was persisted.
    pub fn degrade_events(&self) -> Vec<crate::util::DegradeEvent> {
        crate::util::durable::degrade_events()
    }

    /// Shuts the tenant down (draining accepted requests) and removes
    /// it; returns its served count, or `None` when unknown. The
    /// write lock is held only for the map removal — the drain runs
    /// after it is released, and wakes any of the tenant's blocked
    /// receivers with "stopped".
    pub fn deregister(&self, fp: &MatrixFingerprint) -> Option<usize> {
        let tenant = self.tenants_write().remove(fp)?;
        Some(tenant.serving.shutdown())
    }

    /// Registered tenant count.
    pub fn len(&self) -> usize {
        self.tenants_read().len()
    }

    /// Whether no tenants are registered.
    pub fn is_empty(&self) -> bool {
        self.tenants_read().is_empty()
    }

    /// Whether a tenant is registered under `fp`.
    pub fn contains(&self, fp: &MatrixFingerprint) -> bool {
        self.tenants_read().contains_key(fp)
    }

    /// Plans currently held by the shared cold-start cache.
    pub fn plan_cache_len(&self) -> usize {
        self.cache.lock().unwrap_or_else(|e| e.into_inner()).len()
    }
}

impl<T: Scalar> Default for TenantRegistry<T> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::suite;

    #[test]
    fn registry_routes_by_fingerprint() {
        let registry: TenantRegistry = TenantRegistry::new();
        let a = suite::poisson2d(10);
        let b = suite::fem_blocked(120, 3, 5, 3);
        let fa = registry
            .register("poisson", a.clone(), TenantConfig::default())
            .unwrap();
        let fb = registry
            .register("fem", b.clone(), TenantConfig::default())
            .unwrap();
        assert_ne!(fa, fb);
        assert_eq!(registry.len(), 2);

        let xa = vec![1.0; a.cols];
        let xb = vec![0.5; b.cols];
        registry.submit(&fa, Request { id: 1, x: xa.clone() }).unwrap();
        registry.submit(&fb, Request { id: 2, x: xb.clone() }).unwrap();

        let ra = registry.recv(&fa).expect("poisson response");
        assert_eq!(ra.id, 1);
        let mut want = vec![0.0; a.rows];
        a.spmv_ref(&xa, &mut want);
        crate::testkit::assert_close(&ra.y, &want, 1e-9, "tenant a");

        let rb = registry.recv(&fb).expect("fem response");
        assert_eq!(rb.id, 2);
        let mut want = vec![0.0; b.rows];
        b.spmv_ref(&xb, &mut want);
        crate::testkit::assert_close(&rb.y, &want, 1e-9, "tenant b");

        let stats = registry.stats();
        assert_eq!(stats.served, 2);
        assert_eq!(stats.rejected, 0);
        assert_eq!(stats.tenants.len(), 2);
        // Sorted by name: fem before poisson.
        assert_eq!(stats.tenants[0].name, "fem");
        assert_eq!(stats.tenants[1].name, "poisson");

        assert_eq!(registry.deregister(&fa), Some(1));
        assert_eq!(registry.deregister(&fa), None);
        assert_eq!(registry.len(), 1);
        assert_eq!(registry.deregister(&fb), Some(1));
        assert!(registry.is_empty());
    }

    #[test]
    fn unknown_tenant_is_an_error_not_a_panic() {
        let registry: TenantRegistry = TenantRegistry::new();
        let ghost = MatrixFingerprint::of(&suite::poisson2d(4));
        assert_eq!(
            registry.submit(&ghost, Request { id: 0, x: vec![1.0; 16] }),
            Err(ServiceError::UnknownTenant)
        );
        assert_eq!(registry.recv(&ghost).unwrap_err(), RecvError::Stopped);
        assert_eq!(
            registry
                .recv_timeout(&ghost, Duration::from_millis(1))
                .unwrap_err(),
            RecvError::Stopped
        );
        assert!(registry.tenant_stats(&ghost).is_none());
        assert!(!registry.contains(&ghost));
    }

    #[test]
    fn duplicate_structure_is_rejected() {
        let registry: TenantRegistry = TenantRegistry::new();
        let csr = suite::poisson2d(8);
        registry
            .register("first", csr.clone(), TenantConfig::default())
            .unwrap();
        // Identical structure (even with different values) is the
        // same fingerprint, hence the same tenant.
        let mut same_structure = csr;
        for v in same_structure.values.iter_mut() {
            *v *= 2.0;
        }
        assert!(registry
            .register("second", same_structure, TenantConfig::default())
            .is_err());
        assert_eq!(registry.len(), 1);
    }

    #[test]
    fn second_tenant_with_same_plan_shape_hits_shared_cache() {
        let registry: TenantRegistry = TenantRegistry::new();
        let a = suite::poisson2d(9);
        let fa = registry
            .register("a", a, TenantConfig::default())
            .unwrap();
        assert!(!registry.tenant_stats(&fa).unwrap().from_cache);
        assert_eq!(registry.plan_cache_len(), 1);
        // Same structure re-registered after deregistration: the plan
        // survives in the shared cache, so the restart is warm.
        assert_eq!(registry.deregister(&fa), Some(0));
        let fa2 = registry
            .register("a-restarted", suite::poisson2d(9), TenantConfig::default())
            .unwrap();
        assert_eq!(fa, fa2);
        assert!(registry.tenant_stats(&fa2).unwrap().from_cache);
        assert_eq!(registry.plan_cache_len(), 1);
    }

    #[test]
    fn blocked_receiver_does_not_wedge_the_registry() {
        // A receiver blocked with nothing outstanding used to hold the
        // registry read lock forever: register/deregister (write lock)
        // queued behind it and the whole registry wedged. The handle
        // clone must keep writes responsive, and deregistering the
        // stalled tenant must wake its receiver with "stopped".
        let registry: TenantRegistry = TenantRegistry::new();
        let fa = registry
            .register("a", suite::poisson2d(8), TenantConfig::default())
            .unwrap();
        std::thread::scope(|s| {
            let blocked = s.spawn(|| registry.recv(&fa));
            std::thread::sleep(Duration::from_millis(30));
            // Write-lock operations proceed while the receiver waits.
            let fb = registry
                .register("b", suite::poisson2d(6), TenantConfig::default())
                .unwrap();
            assert_eq!(registry.len(), 2);
            assert_eq!(registry.deregister(&fa), Some(0));
            // The stalled receiver observed the shutdown, not a hang.
            assert_eq!(
                blocked.join().unwrap().unwrap_err(),
                RecvError::Stopped
            );
            assert_eq!(registry.deregister(&fb), Some(0));
        });
    }

    #[test]
    fn sharded_tenant_serves_through_registry() {
        let registry: TenantRegistry = TenantRegistry::new();
        let csr = suite::fem_blocked(300, 3, 5, 3);
        let cfg = TenantConfig {
            shards: 2,
            kernel: Some(KernelKind::Beta(1, 8)),
            ..TenantConfig::default()
        };
        let fp = registry.register("wide", csr.clone(), cfg).unwrap();
        let x = vec![0.25; csr.cols];
        registry.submit(&fp, Request { id: 9, x: x.clone() }).unwrap();
        let resp = registry
            .recv_timeout(&fp, Duration::from_secs(30))
            .expect("sharded tenant response");
        assert_eq!(resp.id, 9);
        let mut want = vec![0.0; csr.rows];
        csr.spmv_ref(&x, &mut want);
        crate::testkit::assert_close(&resp.y, &want, 1e-9, "sharded tenant");
        assert_eq!(registry.deregister(&fp), Some(1));
    }

    #[test]
    fn register_plan_cold_starts_without_inspection() {
        let registry: TenantRegistry = TenantRegistry::new();
        let csr = suite::poisson2d(10);
        let plan = SpmvEngine::builder(csr.clone())
            .kernel(KernelKind::Beta(2, 8))
            .plan()
            .unwrap();
        let fp = registry
            .register_plan("planned", csr.clone(), &plan, TenantConfig::default())
            .unwrap();
        let snap = registry.tenant_stats(&fp).unwrap();
        assert!(snap.from_cache);
        let x = vec![1.5; csr.cols];
        registry.submit(&fp, Request { id: 3, x: x.clone() }).unwrap();
        let resp = registry.recv(&fp).unwrap();
        let mut want = vec![0.0; csr.rows];
        csr.spmv_ref(&x, &mut want);
        crate::testkit::assert_close(&resp.y, &want, 1e-9, "planned tenant");
        // The fingerprint guard: the same plan refuses a different
        // structure.
        let other = suite::poisson2d(12);
        assert!(registry
            .register_plan("mismatch", other, &plan, TenantConfig::default())
            .is_err());
    }

    #[test]
    fn submit_with_retry_rides_through_overload() {
        let registry: TenantRegistry = TenantRegistry::new();
        let csr = suite::poisson2d(8);
        let cfg = TenantConfig {
            queue: QueuePolicy::Reject { capacity: 1 },
            ..TenantConfig::default()
        };
        let fp = registry.register("tight", csr.clone(), cfg).unwrap();
        let x = vec![1.0; csr.cols];
        // Fill the single admission slot; a plain submit now sheds.
        registry.submit(&fp, Request { id: 1, x: x.clone() }).unwrap();
        assert!(matches!(
            registry.submit(&fp, Request { id: 2, x: x.clone() }),
            Err(ServiceError::Overloaded { .. })
        ));
        // Bounded retries give up with the transient error intact.
        assert!(matches!(
            registry.submit_with_retry(
                &fp,
                Request { id: 2, x: x.clone() },
                2,
                Duration::from_millis(1),
            ),
            Err(ServiceError::Overloaded { .. })
        ));
        std::thread::scope(|s| {
            let retried = s.spawn(|| {
                registry.submit_with_retry(
                    &fp,
                    Request { id: 2, x: x.clone() },
                    200,
                    Duration::from_millis(2),
                )
            });
            // Free the slot while the retry loop is backing off.
            std::thread::sleep(Duration::from_millis(20));
            assert_eq!(registry.recv(&fp).unwrap().id, 1);
            retried.join().unwrap().unwrap();
        });
        assert_eq!(registry.recv(&fp).unwrap().id, 2);
        // Non-transient errors fail immediately.
        let ghost = MatrixFingerprint::of(&suite::poisson2d(4));
        assert_eq!(
            registry.submit_with_retry(
                &ghost,
                Request { id: 0, x: vec![1.0; 16] },
                3,
                Duration::from_millis(1),
            ),
            Err(ServiceError::UnknownTenant)
        );
        registry.deregister(&fp);
    }

    #[test]
    fn tenant_health_reports_per_shard() {
        let registry: TenantRegistry = TenantRegistry::new();
        let single = registry
            .register("single", suite::poisson2d(8), TenantConfig::default())
            .unwrap();
        let sharded = registry
            .register(
                "sharded",
                suite::fem_blocked(300, 3, 5, 3),
                TenantConfig { shards: 2, ..TenantConfig::default() },
            )
            .unwrap();
        let h1 = registry.tenant_health(&single).unwrap();
        assert_eq!(h1.len(), 1);
        assert_eq!(h1[0].health, ShardHealth::Up);
        assert_eq!(h1[0].restarts, 0);
        let h2 = registry.tenant_health(&sharded).unwrap();
        assert_eq!(h2.len(), 2);
        assert!(h2.iter().all(|h| h.health == ShardHealth::Up));
        // The same reports ride along in the stats snapshot.
        let snap = registry.tenant_stats(&sharded).unwrap();
        assert_eq!(snap.health, h2);
        let ghost = MatrixFingerprint::of(&suite::poisson2d(4));
        assert!(registry.tenant_health(&ghost).is_none());
        registry.deregister(&single);
        registry.deregister(&sharded);
    }
}
