//! Additional Krylov solvers on top of the engine's SpMV — the
//! workloads the paper's introduction motivates ("iterative solvers
//! based on Krylov subspaces"): preconditioned CG for SPD systems
//! (any [`Preconditioner`] via [`pcg_with`]) and BiCGSTAB for general
//! square systems. Both touch the matrix exclusively through
//! [`SpmvEngine::spmv_into`], so every iteration exercises the
//! paper's kernels — at either precision (vectors in `T`, Krylov
//! scalars accumulated in f64) — and, on a parallel engine, runs on
//! the engine's persistent worker pool (one pool for the whole solve,
//! no per-iteration thread spawning).

use super::cg::{dot_f64, CgReport};
use super::engine::SpmvEngine;
use super::precond::Preconditioner;
use crate::scalar::Scalar;

/// Preconditioned conjugate gradient for SPD systems: CG on
/// `M⁻¹A x = M⁻¹b` with `M` supplied as any [`Preconditioner`]
/// (Jacobi, SymGS, ILU(0), or the identity). `x` holds the initial
/// guess on entry and the solution on exit. Stops at `max_iters` or
/// when the squared residual drops below `tol2`; a zero `p·Ap`
/// denominator stops early with [`CgReport::breakdown`] set.
pub fn pcg_with<T: Scalar>(
    engine: &SpmvEngine<T>,
    m: &dyn Preconditioner<T>,
    b: &[T],
    x: &mut [T],
    max_iters: usize,
    tol2: f64,
) -> CgReport {
    let n = b.len();
    assert_eq!(x.len(), n);

    let mut r = vec![T::ZERO; n];
    engine.spmv_into(x, &mut r);
    let mut spmv_count = 1usize;
    for i in 0..n {
        r[i] = b[i] - r[i];
    }
    let mut z = vec![T::ZERO; n];
    m.apply(&r, &mut z);
    let mut p = z.clone();
    let mut rz = dot_f64(&r, &z);
    let mut ap = vec![T::ZERO; n];

    let mut iterations = 0usize;
    let mut broke = false;
    let mut rs: f64 = dot_f64(&r, &r);
    while iterations < max_iters && rs > tol2 {
        engine.spmv_into(&p, &mut ap);
        spmv_count += 1;
        let denom = dot_f64(&p, &ap);
        if denom == 0.0 {
            broke = true;
            break;
        }
        let alpha = T::from_f64(rz / denom);
        for i in 0..n {
            x[i] += alpha * p[i];
            r[i] -= alpha * ap[i];
        }
        m.apply(&r, &mut z);
        let rz_new = dot_f64(&r, &z);
        let beta = T::from_f64(rz_new / rz);
        for i in 0..n {
            p[i] = z[i] + beta * p[i];
        }
        rz = rz_new;
        rs = dot_f64(&r, &r);
        iterations += 1;
    }
    CgReport {
        iterations,
        residual_norm2: rs,
        converged: rs <= tol2,
        spmv_count,
        breakdown: broke && rs > tol2,
    }
}

/// The historical lenient Jacobi: rows with a zero or missing
/// diagonal get `1` substituted. Kept only for [`pcg_jacobi`]
/// compatibility — [`super::Jacobi`] rejects such rows with a typed
/// error instead.
struct LenientJacobi<T: Scalar> {
    dinv: Vec<T>,
}

impl<T: Scalar> Preconditioner<T> for LenientJacobi<T> {
    fn apply(&self, r: &[T], z: &mut [T]) {
        for i in 0..z.len() {
            z[i] = r[i] * self.dinv[i];
        }
    }
    fn name(&self) -> String {
        "jacobi(lenient)".into()
    }
}

/// Jacobi-preconditioned conjugate gradient for SPD systems.
/// `x` holds the initial guess on entry and the solution on exit.
///
/// Deprecation note: this shim keeps the historical behavior of
/// silently treating zero/missing diagonal entries as `1` — which can
/// mask a broken preconditioner behind slow convergence. New code
/// should build a [`super::Jacobi`] (which returns a typed
/// [`super::PrecondError::ZeroDiagonal`] error instead) and call
/// [`pcg_with`].
pub fn pcg_jacobi<T: Scalar>(
    engine: &SpmvEngine<T>,
    b: &[T],
    x: &mut [T],
    max_iters: usize,
    tol2: f64,
) -> CgReport {
    let csr = engine.csr();
    let mut dinv = vec![T::ONE; csr.rows];
    for r in 0..csr.rows {
        for k in csr.row_range(r) {
            if csr.colidx[k] as usize == r && csr.values[k] != T::ZERO {
                dinv[r] = T::ONE / csr.values[k];
            }
        }
    }
    pcg_with(engine, &LenientJacobi { dinv }, b, x, max_iters, tol2)
}

/// BiCGSTAB for general (non-symmetric) square systems.
pub fn bicgstab<T: Scalar>(
    engine: &SpmvEngine<T>,
    b: &[T],
    x: &mut [T],
    max_iters: usize,
    tol2: f64,
) -> CgReport {
    let n = b.len();
    let mut r = vec![T::ZERO; n];
    engine.spmv_into(x, &mut r);
    let mut spmv_count = 1usize;
    for i in 0..n {
        r[i] = b[i] - r[i];
    }
    let r0 = r.clone();
    let mut rho = 1.0f64;
    let mut alpha = 1.0f64;
    let mut omega = 1.0f64;
    let mut v = vec![T::ZERO; n];
    let mut p = vec![T::ZERO; n];
    let mut s = vec![T::ZERO; n];
    let mut t = vec![T::ZERO; n];

    let mut iterations = 0usize;
    let mut broke = false;
    let mut rs = dot_f64(&r, &r);
    while iterations < max_iters && rs > tol2 {
        let rho_new = dot_f64(&r0, &r);
        if rho_new == 0.0 {
            broke = true; // ρ breakdown
            break;
        }
        let beta = T::from_f64((rho_new / rho) * (alpha / omega));
        let omega_t = T::from_f64(omega);
        for i in 0..n {
            p[i] = r[i] + beta * (p[i] - omega_t * v[i]);
        }
        engine.spmv_into(&p, &mut v);
        spmv_count += 1;
        let r0v = dot_f64(&r0, &v);
        if r0v == 0.0 {
            broke = true; // r₀·v breakdown
            break;
        }
        alpha = rho_new / r0v;
        let alpha_t = T::from_f64(alpha);
        for i in 0..n {
            s[i] = r[i] - alpha_t * v[i];
        }
        engine.spmv_into(&s, &mut t);
        spmv_count += 1;
        let tt = dot_f64(&t, &t);
        omega = if tt != 0.0 { dot_f64(&t, &s) / tt } else { 0.0 };
        let omega_t = T::from_f64(omega);
        for i in 0..n {
            x[i] += alpha_t * p[i] + omega_t * s[i];
            r[i] = s[i] - omega_t * t[i];
        }
        rho = rho_new;
        rs = dot_f64(&r, &r);
        iterations += 1;
        if omega == 0.0 {
            broke = true; // ω breakdown (stagnated half-step)
            break;
        }
    }
    CgReport {
        iterations,
        residual_norm2: rs,
        converged: rs <= tol2,
        spmv_count,
        breakdown: broke && rs > tol2,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::KernelKind;
    use crate::matrix::{suite, Coo, Csr};
    use crate::util::Rng;

    fn engine_for(csr: Csr, kernel: KernelKind) -> SpmvEngine {
        SpmvEngine::builder(csr).kernel(kernel).build().unwrap()
    }

    #[test]
    fn pcg_converges_faster_than_cg_on_illconditioned() {
        // Symmetric scaling D·A·D spreads the diagonal over 3 orders of
        // magnitude while keeping SPD: Jacobi undoes it, so PCG needs
        // far fewer iterations than plain CG.
        let base = suite::poisson2d(14);
        let scale =
            |i: usize| -> f64 { 10f64.powf((i % 7) as f64 / 2.0) };
        let mut coo = Coo::new(base.rows, base.cols);
        for r in 0..base.rows {
            for k in base.row_range(r) {
                let c = base.colidx[k] as usize;
                coo.push(r, c, base.values[k] * scale(r) * scale(c));
            }
        }
        let scaled = coo.to_csr().unwrap();
        let engine = engine_for(scaled.clone(), KernelKind::Beta(2, 4));
        let mut rng = Rng::new(12);
        let b: Vec<f64> =
            (0..scaled.rows).map(|_| rng.range_f64(-1.0, 1.0)).collect();

        let mut x_pcg = vec![0.0; scaled.rows];
        let pcg = pcg_jacobi(&engine, &b, &mut x_pcg, 6000, 1e-16);
        assert!(pcg.converged, "{pcg:?}");
        let mut x_cg = vec![0.0; scaled.rows];
        let cg =
            super::super::cg::cg_solve(&engine, &b, &mut x_cg, 6000, 1e-16);
        assert!(
            pcg.iterations < cg.iterations,
            "pcg {} vs cg {}",
            pcg.iterations,
            cg.iterations
        );
    }

    #[test]
    fn pcg_matches_cg_solution_on_spd() {
        let csr = suite::poisson2d(12);
        let engine = engine_for(csr.clone(), KernelKind::Beta(1, 8));
        let mut rng = Rng::new(3);
        let b: Vec<f64> =
            (0..csr.rows).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        let mut x1 = vec![0.0; csr.rows];
        let r1 = super::super::cg::cg_solve(&engine, &b, &mut x1, 3000, 1e-22);
        let mut x2 = vec![0.0; csr.rows];
        let r2 = pcg_jacobi(&engine, &b, &mut x2, 3000, 1e-22);
        assert!(r1.converged && r2.converged);
        crate::testkit::assert_close(&x2, &x1, 1e-6, "pcg vs cg");
    }

    #[test]
    fn bicgstab_solves_nonsymmetric() {
        // Circuit matrices are non-symmetric with dominant diagonal.
        let csr = suite::circuit(800, 3, 2, 9);
        let engine = engine_for(csr.clone(), KernelKind::Beta(2, 8));
        let mut rng = Rng::new(8);
        let b: Vec<f64> =
            (0..csr.rows).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        let mut x = vec![0.0; csr.rows];
        let report = bicgstab(&engine, &b, &mut x, 4000, 1e-18);
        assert!(report.converged, "{report:?}");
        let mut ax = vec![0.0; csr.rows];
        csr.spmv_ref(&x, &mut ax);
        for i in 0..csr.rows {
            assert!((ax[i] - b[i]).abs() < 1e-6, "row {i}");
        }
    }

    #[test]
    fn bicgstab_through_csr5_baseline() {
        let csr = suite::circuit(600, 3, 2, 5);
        let engine = engine_for(csr.clone(), KernelKind::Csr5);
        let mut rng = Rng::new(4);
        let b: Vec<f64> =
            (0..csr.rows).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        let mut x = vec![0.0; csr.rows];
        let report = bicgstab(&engine, &b, &mut x, 4000, 1e-18);
        assert!(report.converged, "{report:?}");
    }

    #[test]
    fn f32_pcg_jacobi_converges() {
        let csr32: Csr<f32> = suite::poisson2d(10).to_precision();
        let engine = SpmvEngine::builder(csr32.clone())
            .kernel(KernelKind::Beta(1, 16))
            .build()
            .unwrap();
        let mut rng = Rng::new(21);
        let b: Vec<f32> = (0..csr32.rows)
            .map(|_| rng.range_f64(-1.0, 1.0) as f32)
            .collect();
        let mut x = vec![0.0f32; csr32.rows];
        let report = pcg_jacobi(&engine, &b, &mut x, 3000, 1e-8);
        assert!(report.converged, "{report:?}");
        let mut ax = vec![0.0f32; csr32.rows];
        csr32.spmv_ref(&x, &mut ax);
        for i in 0..csr32.rows {
            assert!((ax[i] - b[i]).abs() < 1e-3, "row {i}");
        }
    }

    #[test]
    fn solvers_report_spmv_counts() {
        let csr = suite::poisson2d(8);
        let engine = engine_for(csr.clone(), KernelKind::Beta(1, 8));
        let b = vec![1.0; csr.rows];
        let mut x = vec![0.0; csr.rows];
        let r = pcg_jacobi(&engine, &b, &mut x, 10, 1e-30);
        assert_eq!(r.spmv_count, r.iterations + 1);
        assert!(!r.breakdown);
        let mut x = vec![0.0; csr.rows];
        let r = bicgstab(&engine, &b, &mut x, 10, 1e-30);
        assert_eq!(r.spmv_count, 2 * r.iterations + 1);
        // Max-iters exit, not a numerical breakdown.
        assert!(!r.breakdown);
    }

    #[test]
    fn pcg_flags_breakdown_on_indefinite_system() {
        // diag(1, −1) makes p·Ap vanish on the first iteration for
        // b = (1, 1): the solver must report breakdown, not just
        // "didn't converge".
        let a = Csr::from_raw(
            2,
            2,
            vec![0, 1, 2],
            vec![0, 1],
            vec![1.0, -1.0],
        )
        .unwrap();
        let engine = engine_for(a, KernelKind::Csr);
        let b = vec![1.0, 1.0];
        let mut x = vec![0.0, 0.0];
        let r = pcg_jacobi(&engine, &b, &mut x, 50, 1e-20);
        assert!(r.breakdown, "{r:?}");
        assert!(!r.converged);
        let mut x = vec![0.0, 0.0];
        let r = super::super::cg::cg_solve(&engine, &b, &mut x, 50, 1e-20);
        assert!(r.breakdown, "{r:?}");
        assert!(!r.converged);
    }

    #[test]
    fn pcg_jacobi_shim_stays_lenient_on_zero_diagonal() {
        // Historical behavior regression: a zero diagonal entry gets
        // the identity substituted, so the shim still runs (and CG on
        // this SPD-after-substitution system converges) where the
        // typed `Jacobi::new` refuses.
        let a = Csr::from_raw(
            2,
            2,
            vec![0, 2, 4],
            vec![0, 1, 0, 1],
            vec![0.0, 1.0, 1.0, 0.0],
        )
        .unwrap();
        assert!(matches!(
            crate::coordinator::Jacobi::new(&a).err(),
            Some(crate::coordinator::PrecondError::ZeroDiagonal { row: 0 })
        ));
        let engine = engine_for(a, KernelKind::Csr);
        let b = vec![1.0, 2.0];
        let mut x = vec![0.0, 0.0];
        // A = [[0,1],[1,0]] is a permutation: solution (2, 1).
        let r = pcg_jacobi(&engine, &b, &mut x, 50, 1e-24);
        assert!(r.converged, "{r:?}");
        assert!((x[0] - 2.0).abs() < 1e-9 && (x[1] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn pcg_with_symgs_and_ilu0_beat_jacobi_and_plain_cg() {
        // The acceptance fixture: the ill-conditioned scaled Poisson
        // system. Stronger preconditioners must take strictly fewer
        // iterations: ilu0 ≤ symgs ≤ jacobi < none.
        let base = suite::poisson2d(14);
        let scale = |i: usize| -> f64 { 10f64.powf((i % 7) as f64 / 2.0) };
        let mut coo = Coo::new(base.rows, base.cols);
        for r in 0..base.rows {
            for k in base.row_range(r) {
                let c = base.colidx[k] as usize;
                coo.push(r, c, base.values[k] * scale(r) * scale(c));
            }
        }
        let scaled = coo.to_csr().unwrap();
        let engine = engine_for(scaled.clone(), KernelKind::Beta(2, 4));
        let mut rng = Rng::new(12);
        let b: Vec<f64> =
            (0..scaled.rows).map(|_| rng.range_f64(-1.0, 1.0)).collect();

        let iters_with = |kind: crate::coordinator::PrecondKind| -> usize {
            let m = kind.build(engine.csr(), None).unwrap();
            let mut x = vec![0.0; scaled.rows];
            let r = pcg_with(&engine, m.as_ref(), &b, &mut x, 6000, 1e-16);
            assert!(r.converged, "{kind}: {r:?}");
            // Every preconditioned path reaches the same solution.
            let mut ax = vec![0.0; scaled.rows];
            scaled.spmv_ref(&x, &mut ax);
            for i in 0..scaled.rows {
                assert!((ax[i] - b[i]).abs() < 1e-5, "{kind} row {i}");
            }
            r.iterations
        };
        let mut x = vec![0.0; scaled.rows];
        let cg =
            super::super::cg::cg_solve(&engine, &b, &mut x, 6000, 1e-16);
        assert!(cg.converged, "{cg:?}");
        let jacobi = iters_with(crate::coordinator::PrecondKind::Jacobi);
        let symgs =
            iters_with(crate::coordinator::PrecondKind::SymGs { sweeps: 1 });
        let ilu0 = iters_with(crate::coordinator::PrecondKind::Ilu0);
        assert!(
            jacobi < cg.iterations,
            "jacobi {jacobi} vs cg {}",
            cg.iterations
        );
        assert!(symgs < jacobi, "symgs {symgs} vs jacobi {jacobi}");
        assert!(ilu0 <= symgs, "ilu0 {ilu0} vs symgs {symgs}");
        assert!(ilu0 < cg.iterations && symgs < cg.iterations);
    }
}
