//! Additional Krylov solvers on top of the engine's SpMV — the
//! workloads the paper's introduction motivates ("iterative solvers
//! based on Krylov subspaces"): Jacobi-preconditioned CG for SPD
//! systems and BiCGSTAB for general square systems. Both touch the
//! matrix exclusively through [`SpmvEngine::spmv_into`], so every
//! iteration exercises the paper's kernels — at either precision
//! (vectors in `T`, Krylov scalars accumulated in f64) — and, on a
//! parallel engine, runs on the engine's persistent worker pool (one
//! pool for the whole solve, no per-iteration thread spawning).

use super::cg::{dot_f64, CgReport};
use super::engine::SpmvEngine;
use crate::scalar::Scalar;

/// Extracts the diagonal of the engine's matrix (Jacobi preconditioner).
fn diagonal<T: Scalar>(engine: &SpmvEngine<T>) -> Vec<T> {
    let csr = engine.csr();
    let mut d = vec![T::ZERO; csr.rows];
    for r in 0..csr.rows {
        for k in csr.row_range(r) {
            if csr.colidx[k] as usize == r {
                d[r] = csr.values[k];
            }
        }
    }
    d
}

/// Jacobi-preconditioned conjugate gradient for SPD systems.
/// `x` holds the initial guess on entry and the solution on exit.
pub fn pcg_jacobi<T: Scalar>(
    engine: &SpmvEngine<T>,
    b: &[T],
    x: &mut [T],
    max_iters: usize,
    tol2: f64,
) -> CgReport {
    let n = b.len();
    let d = diagonal(engine);
    let dinv: Vec<T> = d
        .iter()
        .map(|&v| if v != T::ZERO { T::ONE / v } else { T::ONE })
        .collect();

    let mut r = vec![T::ZERO; n];
    engine.spmv_into(x, &mut r);
    let mut spmv_count = 1usize;
    for i in 0..n {
        r[i] = b[i] - r[i];
    }
    let mut z: Vec<T> = r.iter().zip(&dinv).map(|(&ri, &di)| ri * di).collect();
    let mut p = z.clone();
    let mut rz = dot_f64(&r, &z);
    let mut ap = vec![T::ZERO; n];

    let mut iterations = 0usize;
    let mut rs: f64 = dot_f64(&r, &r);
    while iterations < max_iters && rs > tol2 {
        engine.spmv_into(&p, &mut ap);
        spmv_count += 1;
        let denom = dot_f64(&p, &ap);
        if denom == 0.0 {
            break;
        }
        let alpha = T::from_f64(rz / denom);
        for i in 0..n {
            x[i] += alpha * p[i];
            r[i] -= alpha * ap[i];
        }
        for i in 0..n {
            z[i] = r[i] * dinv[i];
        }
        let rz_new = dot_f64(&r, &z);
        let beta = T::from_f64(rz_new / rz);
        for i in 0..n {
            p[i] = z[i] + beta * p[i];
        }
        rz = rz_new;
        rs = dot_f64(&r, &r);
        iterations += 1;
    }
    CgReport {
        iterations,
        residual_norm2: rs,
        converged: rs <= tol2,
        spmv_count,
    }
}

/// BiCGSTAB for general (non-symmetric) square systems.
pub fn bicgstab<T: Scalar>(
    engine: &SpmvEngine<T>,
    b: &[T],
    x: &mut [T],
    max_iters: usize,
    tol2: f64,
) -> CgReport {
    let n = b.len();
    let mut r = vec![T::ZERO; n];
    engine.spmv_into(x, &mut r);
    let mut spmv_count = 1usize;
    for i in 0..n {
        r[i] = b[i] - r[i];
    }
    let r0 = r.clone();
    let mut rho = 1.0f64;
    let mut alpha = 1.0f64;
    let mut omega = 1.0f64;
    let mut v = vec![T::ZERO; n];
    let mut p = vec![T::ZERO; n];
    let mut s = vec![T::ZERO; n];
    let mut t = vec![T::ZERO; n];

    let mut iterations = 0usize;
    let mut rs = dot_f64(&r, &r);
    while iterations < max_iters && rs > tol2 {
        let rho_new = dot_f64(&r0, &r);
        if rho_new == 0.0 {
            break; // breakdown
        }
        let beta = T::from_f64((rho_new / rho) * (alpha / omega));
        let omega_t = T::from_f64(omega);
        for i in 0..n {
            p[i] = r[i] + beta * (p[i] - omega_t * v[i]);
        }
        engine.spmv_into(&p, &mut v);
        spmv_count += 1;
        let r0v = dot_f64(&r0, &v);
        if r0v == 0.0 {
            break;
        }
        alpha = rho_new / r0v;
        let alpha_t = T::from_f64(alpha);
        for i in 0..n {
            s[i] = r[i] - alpha_t * v[i];
        }
        engine.spmv_into(&s, &mut t);
        spmv_count += 1;
        let tt = dot_f64(&t, &t);
        omega = if tt != 0.0 { dot_f64(&t, &s) / tt } else { 0.0 };
        let omega_t = T::from_f64(omega);
        for i in 0..n {
            x[i] += alpha_t * p[i] + omega_t * s[i];
            r[i] = s[i] - omega_t * t[i];
        }
        rho = rho_new;
        rs = dot_f64(&r, &r);
        iterations += 1;
        if omega == 0.0 {
            break;
        }
    }
    CgReport {
        iterations,
        residual_norm2: rs,
        converged: rs <= tol2,
        spmv_count,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::KernelKind;
    use crate::matrix::{suite, Coo, Csr};
    use crate::util::Rng;

    fn engine_for(csr: Csr, kernel: KernelKind) -> SpmvEngine {
        SpmvEngine::builder(csr).kernel(kernel).build().unwrap()
    }

    #[test]
    fn pcg_converges_faster_than_cg_on_illconditioned() {
        // Symmetric scaling D·A·D spreads the diagonal over 3 orders of
        // magnitude while keeping SPD: Jacobi undoes it, so PCG needs
        // far fewer iterations than plain CG.
        let base = suite::poisson2d(14);
        let scale =
            |i: usize| -> f64 { 10f64.powf((i % 7) as f64 / 2.0) };
        let mut coo = Coo::new(base.rows, base.cols);
        for r in 0..base.rows {
            for k in base.row_range(r) {
                let c = base.colidx[k] as usize;
                coo.push(r, c, base.values[k] * scale(r) * scale(c));
            }
        }
        let scaled = coo.to_csr().unwrap();
        let engine = engine_for(scaled.clone(), KernelKind::Beta(2, 4));
        let mut rng = Rng::new(12);
        let b: Vec<f64> =
            (0..scaled.rows).map(|_| rng.range_f64(-1.0, 1.0)).collect();

        let mut x_pcg = vec![0.0; scaled.rows];
        let pcg = pcg_jacobi(&engine, &b, &mut x_pcg, 6000, 1e-16);
        assert!(pcg.converged, "{pcg:?}");
        let mut x_cg = vec![0.0; scaled.rows];
        let cg =
            super::super::cg::cg_solve(&engine, &b, &mut x_cg, 6000, 1e-16);
        assert!(
            pcg.iterations < cg.iterations,
            "pcg {} vs cg {}",
            pcg.iterations,
            cg.iterations
        );
    }

    #[test]
    fn pcg_matches_cg_solution_on_spd() {
        let csr = suite::poisson2d(12);
        let engine = engine_for(csr.clone(), KernelKind::Beta(1, 8));
        let mut rng = Rng::new(3);
        let b: Vec<f64> =
            (0..csr.rows).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        let mut x1 = vec![0.0; csr.rows];
        let r1 = super::super::cg::cg_solve(&engine, &b, &mut x1, 3000, 1e-22);
        let mut x2 = vec![0.0; csr.rows];
        let r2 = pcg_jacobi(&engine, &b, &mut x2, 3000, 1e-22);
        assert!(r1.converged && r2.converged);
        crate::testkit::assert_close(&x2, &x1, 1e-6, "pcg vs cg");
    }

    #[test]
    fn bicgstab_solves_nonsymmetric() {
        // Circuit matrices are non-symmetric with dominant diagonal.
        let csr = suite::circuit(800, 3, 2, 9);
        let engine = engine_for(csr.clone(), KernelKind::Beta(2, 8));
        let mut rng = Rng::new(8);
        let b: Vec<f64> =
            (0..csr.rows).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        let mut x = vec![0.0; csr.rows];
        let report = bicgstab(&engine, &b, &mut x, 4000, 1e-18);
        assert!(report.converged, "{report:?}");
        let mut ax = vec![0.0; csr.rows];
        csr.spmv_ref(&x, &mut ax);
        for i in 0..csr.rows {
            assert!((ax[i] - b[i]).abs() < 1e-6, "row {i}");
        }
    }

    #[test]
    fn bicgstab_through_csr5_baseline() {
        let csr = suite::circuit(600, 3, 2, 5);
        let engine = engine_for(csr.clone(), KernelKind::Csr5);
        let mut rng = Rng::new(4);
        let b: Vec<f64> =
            (0..csr.rows).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        let mut x = vec![0.0; csr.rows];
        let report = bicgstab(&engine, &b, &mut x, 4000, 1e-18);
        assert!(report.converged, "{report:?}");
    }

    #[test]
    fn f32_pcg_jacobi_converges() {
        let csr32: Csr<f32> = suite::poisson2d(10).to_precision();
        let engine = SpmvEngine::builder(csr32.clone())
            .kernel(KernelKind::Beta(1, 16))
            .build()
            .unwrap();
        let mut rng = Rng::new(21);
        let b: Vec<f32> = (0..csr32.rows)
            .map(|_| rng.range_f64(-1.0, 1.0) as f32)
            .collect();
        let mut x = vec![0.0f32; csr32.rows];
        let report = pcg_jacobi(&engine, &b, &mut x, 3000, 1e-8);
        assert!(report.converged, "{report:?}");
        let mut ax = vec![0.0f32; csr32.rows];
        csr32.spmv_ref(&x, &mut ax);
        for i in 0..csr32.rows {
            assert!((ax[i] - b[i]).abs() < 1e-3, "row {i}");
        }
    }

    #[test]
    fn solvers_report_spmv_counts() {
        let csr = suite::poisson2d(8);
        let engine = engine_for(csr.clone(), KernelKind::Beta(1, 8));
        let b = vec![1.0; csr.rows];
        let mut x = vec![0.0; csr.rows];
        let r = pcg_jacobi(&engine, &b, &mut x, 10, 1e-30);
        assert_eq!(r.spmv_count, r.iterations + 1);
        let mut x = vec![0.0; csr.rows];
        let r = bicgstab(&engine, &b, &mut x, 10, 1e-30);
        assert_eq!(r.spmv_count, 2 * r.iterations + 1);
    }
}
