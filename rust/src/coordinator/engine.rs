//! `SpmvEngine<T>` — the user-facing facade tying the library together.
//!
//! Built through the fluent [`SpmvEngine::builder`]:
//!
//! ```no_run
//! use spc5::{Csr, KernelKind, SpmvEngine};
//! # fn demo(csr: Csr, store: &spc5::predictor::RecordStore) -> anyhow::Result<()> {
//! let engine = SpmvEngine::builder(csr)
//!     .threads(4)
//!     .numa_split(true)
//!     .records(store)                      // predictor picks the kernel
//!     .candidates(&KernelKind::ALL)        // ... among these
//!     .build()?;
//! # Ok(()) }
//! ```
//!
//! Given a CSR matrix, the engine:
//! 1. computes the cheap `Avg(r,c)` profile (no conversion),
//! 2. consults the record store to select the most promising kernel
//!    (paper §Performance prediction) — or takes an explicit override,
//! 3. converts once into the selected storage,
//! 4. serves `spmv` calls sequentially or through the parallel runtime.
//!
//! The engine serves **every** [`KernelKind`]: the `β(r,c)` kernels
//! (sequential or block-balanced parallel), the CSR baseline
//! (row-chunked across threads), the CSR5 comparator (sequential —
//! the reference CSR5 kernel carries open-row state across tiles),
//! and the hybrid row-panel schedule
//! ([`crate::formats::HybridMatrix`]: per-panel β/CSR choice driven by
//! the fill crossover and the predictor's fitted surface, parallel by
//! nnz-balanced segment chunks on the pool).
//!
//! Two build-time levers ride on the builder:
//! [`SpmvEngineBuilder::panel_rows`] tunes the hybrid panel height and
//! [`SpmvEngineBuilder::reorder`] applies RCM / column-packing before
//! profiling and conversion (products transparently permute x/y, so
//! callers keep their original index space).
//!
//! With `threads > 1` the engine owns **one** [`WorkerPool`] for its
//! lifetime: the β runtime attaches to it, the row-chunked CSR path
//! runs on it, and every `spmv`/`spmm` afterwards — including each
//! iteration of the Krylov solvers and each batch of the serving layer
//! — is an epoch handoff to the same long-lived workers. No per-call
//! thread spawning anywhere on the hot path.
//!
//! [`SpmvEngine::spmm`] is the multi-RHS entry (`Y += A·X`, `k`
//! right-hand sides in one matrix traversal) that the service's
//! micro-batching dispatcher coalesces concurrent requests into.

use crate::formats::stats::paper_profile;
use crate::formats::{
    csr_to_block, BlockMatrix, BlockSize, HybridConfig, HybridMatrix,
    TileCols, TiledHybrid, TiledMatrix,
};
use crate::kernels::{csr as csr_kernel, csr5, spmm, spmv_block, KernelKind};
use crate::matrix::reorder::{self, Permutation, ReorderKind};
use crate::matrix::Csr;
use crate::parallel::{
    balanced_prefix_split, ParallelSpmv, ParallelStrategy, SendSlice,
    WorkerPool,
};
use crate::predictor::{select_parallel, select_sequential, RecordStore};
use crate::scalar::Scalar;
use std::sync::{Arc, Mutex};

/// The storage a built engine dispatches to.
enum Storage<T: Scalar> {
    /// Sequential β kernel over one converted block matrix.
    Block(BlockMatrix<T>),
    /// Parallel β kernel (paper §Parallelization).
    BlockParallel(ParallelSpmv<T>),
    /// CSR baseline; `chunks` holds the nnz-balanced row split when
    /// `threads > 1` (empty = sequential).
    Csr { chunks: Vec<(usize, usize)> },
    /// CSR5 comparator (sequential by construction).
    Csr5(csr5::Csr5Matrix<T>),
    /// Heterogeneous row-panel schedule; `chunks` holds the
    /// nnz-balanced *segment* split when `threads > 1`.
    Hybrid { hm: HybridMatrix<T>, chunks: Vec<(usize, usize)> },
    /// Column-tiled β storage (cache-blocked `(panel, tile)` walk);
    /// `chunks` holds the nnz-balanced *panel* split when
    /// `threads > 1` — workers own disjoint row panels, tiles are
    /// their inner sequential loop.
    TiledBlock { tm: TiledMatrix<T>, chunks: Vec<(usize, usize)> },
    /// Column-tiled hybrid schedule; `chunks` splits *segments* like
    /// the flat hybrid path.
    TiledHybrid { th: TiledHybrid<T>, chunks: Vec<(usize, usize)> },
}

/// The permutations a reordering engine applies around every product:
/// the bound matrix is `B[i,j] = A[rows[i], cols[j]]`, so `x` is
/// gathered through `cols` on the way in and `y` scattered through
/// `rows` on the way out — callers keep the original index space.
struct ReorderState<T: Scalar> {
    kind: ReorderKind,
    rows: Permutation,
    cols: Permutation,
    /// Reusable gather/scatter buffers `(xp, yp)` — allocating them
    /// per call would reintroduce the hot-path allocation the pool
    /// runtime removed. The lock is uncontended in practice (products
    /// on one engine are serialized by their callers); it exists so
    /// `spmv(&self, ..)` stays shareable.
    scratch: Mutex<(Vec<T>, Vec<T>)>,
}

impl<T: Scalar> ReorderState<T> {
    fn new(kind: ReorderKind, rows: Permutation, cols: Permutation) -> Self {
        ReorderState { kind, rows, cols, scratch: Mutex::new((Vec::new(), Vec::new())) }
    }
}

/// A matrix bound to its chosen kernel and storage, ready to serve.
pub struct SpmvEngine<T: Scalar = f64> {
    csr: Csr<T>,
    kernel: KernelKind,
    predicted_gflops: Option<f64>,
    storage: Storage<T>,
    threads: usize,
    /// The persistent runtime every parallel path runs on, created
    /// once at build time (`None` when `threads == 1`).
    pool: Option<Arc<WorkerPool>>,
    /// Build-time reordering; when present, `csr` is the *permuted*
    /// matrix and every `spmv`/`spmm` transparently permutes x/y.
    reorder: Option<ReorderState<T>>,
    /// Reusable de-interleave buffers `(xj, yj)` for the CSR/CSR5
    /// multi-RHS fallback — engine-owned so the micro-batching service
    /// does not allocate two fresh vectors per batch. Uncontended like
    /// the reorder scratch; the lock only keeps `spmm(&self, ..)`
    /// shareable.
    baseline_spmm_scratch: Mutex<(Vec<T>, Vec<T>)>,
    /// Pool attach id for per-worker SpMM accumulator scratch on the
    /// tiled parallel paths.
    scratch_attach: u64,
}

/// Fluent configuration for [`SpmvEngine`] — replaces the old
/// `EngineConfig` + `SpmvEngine::new(csr, &cfg, records)` triple.
pub struct SpmvEngineBuilder<'r, T: Scalar = f64> {
    csr: Csr<T>,
    threads: usize,
    numa_split: bool,
    kernel: Option<KernelKind>,
    candidates: Vec<KernelKind>,
    records: Option<&'r RecordStore>,
    panel_rows: usize,
    reorder: Option<ReorderKind>,
    tiling: Option<TileCols>,
}

impl<T: Scalar> SpmvEngine<T> {
    /// Starts building an engine for `csr`. Defaults: 1 thread, no
    /// NUMA split, predictor-driven kernel selection over
    /// [`KernelKind::SPC5_KERNELS`] (falling back to β(1,8) — the
    /// cheapest conversion, as the paper recommends — when no records
    /// are supplied).
    pub fn builder(csr: Csr<T>) -> SpmvEngineBuilder<'static, T> {
        SpmvEngineBuilder {
            csr,
            threads: 1,
            numa_split: false,
            kernel: None,
            candidates: KernelKind::SPC5_KERNELS.to_vec(),
            records: None,
            panel_rows: crate::formats::hybrid::DEFAULT_PANEL_ROWS,
            reorder: None,
            tiling: None,
        }
    }

    /// The kernel serving this matrix.
    pub fn kernel(&self) -> KernelKind {
        self.kernel
    }

    /// Predicted GFlop/s, when the predictor made the choice.
    pub fn predicted_gflops(&self) -> Option<f64> {
        self.predicted_gflops
    }

    /// The bound matrix.
    pub fn csr(&self) -> &Csr<T> {
        &self.csr
    }

    /// Worker threads.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The engine's persistent worker pool (`None` when sequential).
    /// Shared by the β runtime, the chunked CSR path, the solvers and
    /// the serving layer for the engine's whole lifetime.
    pub fn pool(&self) -> Option<&Arc<WorkerPool>> {
        self.pool.as_ref()
    }

    /// The reordering applied at build time, if any.
    pub fn reorder_kind(&self) -> Option<ReorderKind> {
        self.reorder.as_ref().map(|r| r.kind)
    }

    /// For hybrid engines: the compiled panel schedule.
    pub fn hybrid(&self) -> Option<&HybridMatrix<T>> {
        match &self.storage {
            Storage::Hybrid { hm, .. } => Some(hm),
            _ => None,
        }
    }

    /// For tiled β engines: the `(panel, tile)` schedule.
    pub fn tiled(&self) -> Option<&TiledMatrix<T>> {
        match &self.storage {
            Storage::TiledBlock { tm, .. } => Some(tm),
            _ => None,
        }
    }

    /// For tiled hybrid engines: the tiled segment schedule.
    pub fn tiled_hybrid(&self) -> Option<&TiledHybrid<T>> {
        match &self.storage {
            Storage::TiledHybrid { th, .. } => Some(th),
            _ => None,
        }
    }

    /// Resolved column tile width, when the engine runs cache-blocked
    /// (`None` = flat schedule).
    pub fn tile_cols(&self) -> Option<usize> {
        match &self.storage {
            Storage::TiledBlock { tm, .. } => Some(tm.tile_cols),
            Storage::TiledHybrid { th, .. } => Some(th.tile_cols),
            _ => None,
        }
    }

    /// `y += A·x` through the chosen kernel and runtime. When the
    /// engine was built with a reordering, `x`/`y` stay in the
    /// caller's original index space — the permutation is applied
    /// internally around the product.
    pub fn spmv(&self, x: &[T], y: &mut [T]) {
        match &self.reorder {
            None => self.spmv_permuted(x, y),
            Some(st) => {
                let mut guard = st.scratch.lock().expect("scratch poisoned");
                let (xp, yp) = &mut *guard;
                xp.clear();
                xp.extend(st.cols.perm.iter().map(|&old| x[old as usize]));
                yp.clear();
                yp.resize(self.csr.rows, T::ZERO);
                self.spmv_permuted(xp, yp);
                for (new_r, &old_r) in st.rows.perm.iter().enumerate() {
                    y[old_r as usize] += yp[new_r];
                }
            }
        }
    }

    /// `y += B·x` in the bound (possibly permuted) index space.
    fn spmv_permuted(&self, x: &[T], y: &mut [T]) {
        match &self.storage {
            Storage::Block(bm) => spmv_block(
                bm,
                x,
                y,
                matches!(self.kernel, KernelKind::BetaTest(..)),
            ),
            Storage::BlockParallel(p) => p.spmv(x, y),
            Storage::Csr { chunks } => {
                if chunks.is_empty() {
                    csr_kernel::spmv(&self.csr, x, y);
                } else {
                    self.spmv_csr_parallel(chunks, x, y);
                }
            }
            Storage::Csr5(m) => m.spmv(x, y),
            Storage::Hybrid { hm, chunks } => {
                if chunks.is_empty() {
                    hm.spmv(x, y);
                } else {
                    self.hybrid_parallel(hm, chunks, x, y, 1);
                }
            }
            Storage::TiledBlock { tm, chunks } => {
                let test = matches!(self.kernel, KernelKind::BetaTest(..));
                if chunks.is_empty() {
                    tm.spmv(x, y, test);
                } else {
                    self.tiled_block_parallel(tm, chunks, x, y, 1, test);
                }
            }
            Storage::TiledHybrid { th, chunks } => {
                if chunks.is_empty() {
                    th.spmv(x, y);
                } else {
                    self.tiled_hybrid_parallel(th, chunks, x, y, 1);
                }
            }
        }
    }

    /// `y = A·x` (zeroing first).
    pub fn spmv_into(&self, x: &[T], y: &mut [T]) {
        y.iter_mut().for_each(|v| *v = T::ZERO);
        self.spmv(x, y);
    }

    /// Multi-RHS `Y += A·X`: `x` holds `k` right-hand sides row-major
    /// (`x[c*k + j]` = vector `j` at position `c`, see
    /// [`crate::kernels::spmm`]), `y` likewise `[rows × k]`. The block
    /// storages traverse the matrix **once** for all `k` vectors — the
    /// batching lever the serving layer uses; the CSR/CSR5 baselines
    /// fall back to `k` single-vector passes. For `BetaTest` kernels
    /// the `k > 1` path uses the standard SpMM traversal (Algorithm 2
    /// has no multi-RHS form); results are identical.
    pub fn spmm(&self, x: &[T], y: &mut [T], k: usize) {
        assert!(k > 0);
        assert_eq!(x.len(), self.csr.cols * k, "x must be cols*k");
        assert_eq!(y.len(), self.csr.rows * k, "y must be rows*k");
        if k == 1 {
            return self.spmv(x, y);
        }
        match &self.reorder {
            None => self.spmm_permuted(x, y, k),
            Some(st) => {
                let mut guard = st.scratch.lock().expect("scratch poisoned");
                let (xp, yp) = &mut *guard;
                xp.clear();
                xp.resize(x.len(), T::ZERO);
                for (new_c, &old_c) in st.cols.perm.iter().enumerate() {
                    let old_c = old_c as usize;
                    xp[new_c * k..(new_c + 1) * k]
                        .copy_from_slice(&x[old_c * k..(old_c + 1) * k]);
                }
                yp.clear();
                yp.resize(y.len(), T::ZERO);
                self.spmm_permuted(xp, yp, k);
                for (new_r, &old_r) in st.rows.perm.iter().enumerate() {
                    let old_r = old_r as usize;
                    for j in 0..k {
                        y[old_r * k + j] += yp[new_r * k + j];
                    }
                }
            }
        }
    }

    /// Multi-RHS product in the bound (possibly permuted) index space.
    fn spmm_permuted(&self, x: &[T], y: &mut [T], k: usize) {
        match &self.storage {
            Storage::Block(bm) => spmm::spmm_auto(bm, x, y, k),
            Storage::BlockParallel(p) => p.spmm(x, y, k),
            Storage::Hybrid { hm, chunks } => {
                if chunks.is_empty() {
                    hm.spmm(x, y, k);
                } else {
                    self.hybrid_parallel(hm, chunks, x, y, k);
                }
            }
            Storage::TiledBlock { tm, chunks } => {
                let test = matches!(self.kernel, KernelKind::BetaTest(..));
                if chunks.is_empty() {
                    tm.spmm(x, y, k);
                } else {
                    self.tiled_block_parallel(tm, chunks, x, y, k, test);
                }
            }
            Storage::TiledHybrid { th, chunks } => {
                if chunks.is_empty() {
                    th.spmm(x, y, k);
                } else {
                    self.tiled_hybrid_parallel(th, chunks, x, y, k);
                }
            }
            Storage::Csr { .. } | Storage::Csr5(_) => {
                // No native multi-RHS kernel for the baselines: run k
                // de-interleaved single-vector products through
                // engine-owned scratch (allocating two vectors per
                // batch here used to be the serving layer's hot-path
                // allocation).
                let (rows, cols) = (self.csr.rows, self.csr.cols);
                let mut guard = self
                    .baseline_spmm_scratch
                    .lock()
                    .expect("spmm scratch poisoned");
                let (xj, yj) = &mut *guard;
                xj.clear();
                xj.resize(cols, T::ZERO);
                yj.clear();
                yj.resize(rows, T::ZERO);
                for j in 0..k {
                    for c in 0..cols {
                        xj[c] = x[c * k + j];
                    }
                    yj.iter_mut().for_each(|v| *v = T::ZERO);
                    // `x` is already in the bound index space here, so
                    // stay below the reorder wrapper.
                    self.spmv_permuted(xj, yj);
                    for r in 0..rows {
                        y[r * k + j] += yj[r];
                    }
                }
            }
        }
    }

    /// Multi-RHS `Y = A·X` (zeroing first).
    pub fn spmm_into(&self, x: &[T], y: &mut [T], k: usize) {
        y.iter_mut().for_each(|v| *v = T::ZERO);
        self.spmm(x, y, k);
    }

    /// The Table-1-style stats row for the bound matrix.
    pub fn profile(&self) -> Vec<crate::formats::BlockStats> {
        paper_profile(&self.csr)
    }

    /// Parallel hybrid pass: each pool worker owns a contiguous run of
    /// schedule segments (balanced by nnz at build time) and writes the
    /// disjoint `y` rows those segments cover — the same syncless-merge
    /// shape as the other parallel paths. Serves both SpMV (`k == 1`)
    /// and SpMM (`k > 1`) epochs.
    fn hybrid_parallel(
        &self,
        hm: &HybridMatrix<T>,
        chunks: &[(usize, usize)],
        x: &[T],
        y: &mut [T],
        k: usize,
    ) {
        let pool = self.pool.as_ref().expect("parallel hybrid needs the pool");
        debug_assert_eq!(chunks.len(), pool.n_threads());
        let y_all = SendSlice::new(y);
        pool.run(|ctx: crate::parallel::WorkerCtx<'_>| {
            let (s0, s1) = chunks[ctx.tid];
            for seg in &hm.segments[s0..s1] {
                // SAFETY: segments are ordered and disjoint in rows, and
                // chunks are contiguous disjoint segment ranges, so no
                // two workers touch the same `y` rows; the borrow
                // outlives the blocked `run` call.
                let part = unsafe {
                    y_all.subslice_mut(seg.row_begin * k, seg.row_end * k)
                };
                if k == 1 {
                    seg.spmv(x, part);
                } else {
                    seg.spmm(x, part, k);
                }
            }
        });
    }

    /// Parallel tiled-β pass: the 2-D `(panel, tile)` schedule on the
    /// pool. Workers own disjoint contiguous **row-panel** ranges
    /// (balanced by nnz at build time) so no two workers touch the
    /// same `y` rows and no atomics are needed; each worker walks its
    /// panels' column tiles as an inner sequential loop, which is what
    /// keeps its `x` window cache-resident.
    fn tiled_block_parallel(
        &self,
        tm: &TiledMatrix<T>,
        chunks: &[(usize, usize)],
        x: &[T],
        y: &mut [T],
        k: usize,
        test: bool,
    ) {
        let pool = self.pool.as_ref().expect("parallel tiled needs the pool");
        debug_assert_eq!(chunks.len(), pool.n_threads());
        let y_all = SendSlice::new(y);
        let attach = self.scratch_attach;
        pool.run(|ctx: crate::parallel::WorkerCtx<'_>| {
            let (p0, p1) = chunks[ctx.tid];
            if p0 == p1 {
                return;
            }
            let row_begin = tm.panels[p0].row_begin;
            let row_end = tm.panels[p1 - 1].row_end;
            // SAFETY: panels are ordered and disjoint in rows and
            // chunks are contiguous disjoint panel ranges, so no two
            // workers touch the same `y` rows; the borrow outlives the
            // blocked `run` call.
            let part =
                unsafe { y_all.subslice_mut(row_begin * k, row_end * k) };
            if k == 1 {
                tm.spmv_panels(p0, p1, x, part, test);
            } else {
                let sums =
                    ctx.locals.get_or_insert_with(attach, Vec::<T>::new);
                tm.spmm_panels(p0, p1, x, part, k, sums);
            }
        });
    }

    /// Parallel tiled-hybrid pass: workers own disjoint contiguous
    /// runs of tiled segments (the same nnz-balanced split as the flat
    /// hybrid path); within a segment the `(panel, tile)` walk is
    /// sequential for locality.
    fn tiled_hybrid_parallel(
        &self,
        th: &TiledHybrid<T>,
        chunks: &[(usize, usize)],
        x: &[T],
        y: &mut [T],
        k: usize,
    ) {
        let pool = self.pool.as_ref().expect("parallel tiled needs the pool");
        debug_assert_eq!(chunks.len(), pool.n_threads());
        let y_all = SendSlice::new(y);
        let attach = self.scratch_attach;
        pool.run(|ctx: crate::parallel::WorkerCtx<'_>| {
            let (s0, s1) = chunks[ctx.tid];
            let sums =
                ctx.locals.get_or_insert_with(attach, Vec::<T>::new);
            for seg in &th.segments[s0..s1] {
                // SAFETY: segments are ordered and disjoint in rows and
                // chunks are contiguous disjoint segment ranges; the
                // borrow outlives the blocked `run` call.
                let part = unsafe {
                    y_all.subslice_mut(seg.row_begin * k, seg.row_end * k)
                };
                if k == 1 {
                    seg.spmv(x, part);
                } else {
                    seg.spmm(x, part, k, sums);
                }
            }
        });
    }

    /// Row-chunked parallel CSR: each **pool** worker owns a disjoint
    /// contiguous row range (balanced by nnz at build time) and writes
    /// its own `y` slice — same syncless-merge shape as the β runtime,
    /// on the same persistent workers (no per-call spawn).
    fn spmv_csr_parallel(
        &self,
        chunks: &[(usize, usize)],
        x: &[T],
        y: &mut [T],
    ) {
        assert_eq!(x.len(), self.csr.cols);
        assert_eq!(y.len(), self.csr.rows);
        let pool = self.pool.as_ref().expect("chunked CSR needs the pool");
        debug_assert_eq!(chunks.len(), pool.n_threads());
        let y_all = SendSlice::new(y);
        pool.run(|ctx: crate::parallel::WorkerCtx<'_>| {
            let (r0, r1) = chunks[ctx.tid];
            if r0 == r1 {
                return;
            }
            // SAFETY: chunks are contiguous and disjoint across
            // workers; the borrow outlives the blocked `run` call.
            let part = unsafe { y_all.subslice_mut(r0, r1) };
            csr_kernel::spmv_rows(&self.csr, r0, r1, x, part);
        });
    }
}

impl<'r, T: Scalar> SpmvEngineBuilder<'r, T> {
    /// Worker threads (1 = sequential path).
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = n.max(1);
        self
    }

    /// NUMA-style array splitting for the parallel β path.
    pub fn numa_split(mut self, on: bool) -> Self {
        self.numa_split = on;
        self
    }

    /// Explicit kernel override (skips the predictor). Any
    /// [`KernelKind`] is accepted, including `Csr` and `Csr5`.
    pub fn kernel(mut self, k: KernelKind) -> Self {
        self.kernel = Some(k);
        self
    }

    /// Candidate kernels for predictor-driven selection.
    pub fn candidates(mut self, kinds: &[KernelKind]) -> Self {
        self.candidates = kinds.to_vec();
        self
    }

    /// Rows per panel for the hybrid and tiled schedules (must be a
    /// positive multiple of 8; used by [`KernelKind::Hybrid`],
    /// [`KernelKind::Tiled`] and tiled β storages).
    pub fn panel_rows(mut self, rows: usize) -> Self {
        self.panel_rows = rows;
        self
    }

    /// Fixed column tile width: the built storage executes
    /// cache-blocked, each `(panel, tile)` pass touching only an
    /// `n`-column window of `x`. `n == 0` means auto-size (the same
    /// spelling as `tiled(0)`). Applies to β kernels (tiled block
    /// spans) and to the hybrid schedule (every segment tiled); the
    /// CSR/CSR5 baselines have no tiled form and ignore it.
    pub fn tile_cols(mut self, n: usize) -> Self {
        self.tiling = Some(if n == 0 {
            TileCols::Auto
        } else {
            TileCols::Fixed(n)
        });
        self
    }

    /// Auto-sized column tiling: the tile width is chosen so the `x`
    /// window fills half the detected per-core L2
    /// ([`crate::formats::auto_tile_cols`]; `SPC5_L2_BYTES` overrides
    /// the detection). Same applicability as
    /// [`SpmvEngineBuilder::tile_cols`].
    pub fn tile_auto(mut self) -> Self {
        self.tiling = Some(TileCols::Auto);
        self
    }

    /// Applies a bandwidth/fill-improving reordering to the matrix at
    /// build time (paper §"Matrix permutation/reordering"). The engine
    /// stores the permuted matrix and transparently permutes `x`/`y`
    /// in every `spmv`/`spmm`, so callers keep their original index
    /// space. [`ReorderKind::Rcm`] needs a square matrix.
    pub fn reorder(mut self, kind: ReorderKind) -> Self {
        self.reorder = Some(kind);
        self
    }

    /// Performance records the predictor selects from.
    pub fn records<'b>(self, store: &'b RecordStore) -> SpmvEngineBuilder<'b, T> {
        SpmvEngineBuilder {
            csr: self.csr,
            threads: self.threads,
            numa_split: self.numa_split,
            kernel: self.kernel,
            candidates: self.candidates,
            records: Some(store),
            panel_rows: self.panel_rows,
            reorder: self.reorder,
            tiling: self.tiling,
        }
    }

    /// Selects the kernel (override > predictor > β(1,8) default),
    /// converts the storage once, and returns the ready engine.
    pub fn build(self) -> anyhow::Result<SpmvEngine<T>> {
        let SpmvEngineBuilder {
            csr,
            threads,
            numa_split,
            kernel,
            candidates,
            records,
            panel_rows,
            reorder: reorder_kind,
            tiling,
        } = self;

        // Build-time reordering: permute first so block-fill profiling,
        // kernel selection and conversion all see the improved shape.
        let (csr, reorder_state) = match reorder_kind {
            None => (csr, None),
            Some(ReorderKind::Rcm) => {
                anyhow::ensure!(
                    csr.rows == csr.cols,
                    "RCM reordering needs a square matrix \
                     ({}x{} given)",
                    csr.rows,
                    csr.cols
                );
                let p = reorder::cuthill_mckee(&csr);
                let permuted = reorder::permute(&csr, &p, &p);
                let st = ReorderState::new(ReorderKind::Rcm, p.clone(), p);
                (permuted, Some(st))
            }
            Some(ReorderKind::ColPack) => {
                let rows = Permutation::identity(csr.rows);
                let cols = reorder::column_pack(&csr);
                let permuted = reorder::permute(&csr, &rows, &cols);
                let st = ReorderState::new(ReorderKind::ColPack, rows, cols);
                (permuted, Some(st))
            }
        };

        let (kernel, predicted) = match kernel {
            Some(k) => (k, None),
            None => {
                let sel = records.and_then(|store| {
                    if threads > 1 {
                        select_parallel(&csr, store, &candidates, threads)
                    } else {
                        select_sequential(&csr, store, &candidates)
                    }
                });
                match sel {
                    Some(s) => (s.kernel, Some(s.predicted_gflops)),
                    None => (KernelKind::Beta(1, 8), None),
                }
            }
        };

        // One persistent pool per engine lifetime: spawned here, shared
        // by whichever parallel path the kernel choice needs, reused by
        // every solver iteration and service batch afterwards. CSR5 has
        // no parallel path (the reference kernel carries open-row state
        // across tiles), so it never gets idle parked workers.
        let parallel_kernel = !matches!(kernel, KernelKind::Csr5);
        let pool = (threads > 1 && parallel_kernel)
            .then(|| Arc::new(WorkerPool::new(threads)));

        let storage = match kernel {
            KernelKind::Csr => {
                let chunks = if threads > 1 {
                    csr_row_chunks(&csr, threads)
                } else {
                    Vec::new()
                };
                Storage::Csr { chunks }
            }
            KernelKind::Csr5 => {
                Storage::Csr5(csr5::Csr5Matrix::from_csr(&csr))
            }
            KernelKind::Hybrid => {
                let hm = compile_hybrid(
                    &csr, panel_rows, &candidates, records, threads,
                )?;
                match tiling {
                    // builder.tile_cols / tile_auto lift the flat
                    // hybrid schedule into the column-tiled world.
                    Some(tc) => {
                        let th = TiledHybrid::from_hybrid(&hm, tc)?;
                        let chunks = if threads > 1 {
                            nnz_chunks(th.segments.iter().map(|s| s.nnz), threads)
                        } else {
                            Vec::new()
                        };
                        Storage::TiledHybrid { th, chunks }
                    }
                    None => {
                        let chunks = if threads > 1 {
                            nnz_chunks(hm.segments.iter().map(|s| s.nnz), threads)
                        } else {
                            Vec::new()
                        };
                        Storage::Hybrid { hm, chunks }
                    }
                }
            }
            KernelKind::Tiled(w) => {
                // The tiled kernel is the cache-blocked execution of
                // the hybrid row-panel schedule. An inline width
                // (`tiled(n)`) wins over the builder's tiling setting;
                // `tiled` alone defers to it, defaulting to auto.
                let hm = compile_hybrid(
                    &csr, panel_rows, &candidates, records, threads,
                )?;
                let tc = if w > 0 {
                    TileCols::Fixed(w as usize)
                } else {
                    tiling.unwrap_or(TileCols::Auto)
                };
                let th = TiledHybrid::from_hybrid(&hm, tc)?;
                let chunks = if threads > 1 {
                    nnz_chunks(th.segments.iter().map(|s| s.nnz), threads)
                } else {
                    Vec::new()
                };
                Storage::TiledHybrid { th, chunks }
            }
            KernelKind::Beta(..) | KernelKind::BetaTest(..) => {
                let bs = kernel.block_size().expect("β kernel has a size");
                match tiling {
                    // Cache-blocked β: `(panel, tile)` spans over one
                    // converted block matrix. Parallelism is the 2-D
                    // panel split on the pool (the NUMA array-split
                    // strategy has no tiled form and is not applied
                    // here).
                    Some(tcfg) => {
                        let block = csr_to_block(&csr, bs)?;
                        let tile_cols = tcfg.resolve::<T>(csr.cols);
                        let tm = TiledMatrix::from_block(
                            &block, panel_rows, tile_cols,
                        )?;
                        let chunks = if threads > 1 {
                            nnz_chunks(tm.panels.iter().map(|p| p.nnz), threads)
                        } else {
                            Vec::new()
                        };
                        Storage::TiledBlock { tm, chunks }
                    }
                    None => {
                        let block = csr_to_block(&csr, bs)?;
                        let test =
                            matches!(kernel, KernelKind::BetaTest(..));
                        match &pool {
                            Some(pool) => {
                                let strategy = if numa_split {
                                    ParallelStrategy::NumaSplit
                                } else {
                                    ParallelStrategy::Shared
                                };
                                Storage::BlockParallel(
                                    ParallelSpmv::with_pool(
                                        block,
                                        Arc::clone(pool),
                                        strategy,
                                        test,
                                    ),
                                )
                            }
                            None => Storage::Block(block),
                        }
                    }
                }
            }
        };

        Ok(SpmvEngine {
            csr,
            kernel,
            predicted_gflops: predicted,
            storage,
            threads,
            pool,
            reorder: reorder_state,
            baseline_spmm_scratch: Mutex::new((Vec::new(), Vec::new())),
            scratch_attach: crate::parallel::pool::next_attach_id(),
        })
    }
}

/// Compiles the hybrid row-panel schedule for an engine build: the
/// builder's candidate kernels filtered per precision, the schedule
/// split sized to the worker count, and the predictor's fitted
/// sequential GFlop/s surface supplied when records exist (the panel
/// decision models single-span kernel speed). Shared by the flat
/// hybrid and the tiled storages.
fn compile_hybrid<T: Scalar>(
    csr: &Csr<T>,
    panel_rows: usize,
    candidates: &[KernelKind],
    records: Option<&RecordStore>,
    threads: usize,
) -> Result<HybridMatrix<T>, crate::formats::FormatError> {
    let cfg = HybridConfig {
        panel_rows,
        candidates: hybrid_candidates::<T>(candidates),
        // Ask the schedule compiler for ≥ one segment per worker, else
        // a homogeneous matrix merges into a single segment and
        // parallelism collapses.
        split: threads,
    };
    let kinds: Vec<KernelKind> = std::iter::once(KernelKind::Csr)
        .chain(
            cfg.candidates
                .iter()
                .map(|bs| KernelKind::Beta(bs.r as u8, bs.c as u8)),
        )
        .collect();
    let models = records
        .map(|store| crate::predictor::select::fit_sequential(store, &kinds));
    HybridMatrix::from_csr(csr, &cfg, models.as_ref())
}

/// β candidate sizes for the hybrid panel compiler: the builder's
/// candidate kernels filtered to sizes valid at this precision — or,
/// when the builder still holds the default f64 list, the precision's
/// own default set (so an f32 hybrid engine considers the 16-lane
/// sizes it has AVX-512 kernels for).
fn hybrid_candidates<T: Scalar>(kinds: &[KernelKind]) -> Vec<BlockSize> {
    if kinds == KernelKind::SPC5_KERNELS {
        return HybridConfig::for_scalar::<T>().candidates;
    }
    let mut sizes: Vec<BlockSize> = kinds
        .iter()
        .filter_map(|k| k.block_size())
        .filter(|bs| bs.validate_for::<T>().is_ok())
        .collect();
    sizes.sort_unstable();
    sizes.dedup();
    if sizes.is_empty() {
        HybridConfig::for_scalar::<T>().candidates
    } else {
        sizes
    }
}

/// Splits an ordered work list into `n` contiguous runs of
/// approximately equal weight via the paper's prefix rule — the one
/// balancing routine behind the hybrid-segment, tiled-panel and
/// tiled-segment parallel splits.
fn nnz_chunks(
    nnzs: impl Iterator<Item = usize>,
    n: usize,
) -> Vec<(usize, usize)> {
    let mut prefix = vec![0u32];
    let mut acc = 0u64;
    for w in nnzs {
        acc += w as u64;
        prefix.push(u32::try_from(acc).expect("nnz fits the u32 prefix"));
    }
    balanced_prefix_split(&prefix, n)
}

/// Splits `0..rows` into `n` contiguous chunks with approximately equal
/// nnz — the paper's balancing rule applied to the rowptr prefix (the
/// same [`crate::parallel::balanced_prefix_split`] the β runtime uses
/// on its block prefix).
fn csr_row_chunks<T: Scalar>(csr: &Csr<T>, n: usize) -> Vec<(usize, usize)> {
    crate::parallel::balanced_prefix_split(&csr.rowptr, n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::suite;
    use crate::predictor::PerfRecord;

    #[test]
    fn explicit_kernel_used() {
        let csr = suite::poisson2d(16);
        let e = SpmvEngine::builder(csr)
            .kernel(KernelKind::Beta(4, 4))
            .build()
            .unwrap();
        assert_eq!(e.kernel(), KernelKind::Beta(4, 4));
    }

    #[test]
    fn defaults_to_1x8_without_records() {
        let csr = suite::poisson2d(8);
        let e = SpmvEngine::builder(csr).build().unwrap();
        assert_eq!(e.kernel(), KernelKind::Beta(1, 8));
        assert!(e.predicted_gflops().is_none());
    }

    #[test]
    fn serves_csr_and_csr5_baselines() {
        // The facade must dispatch the paper's own baselines (this used
        // to be a construction error).
        let csr = suite::poisson2d(14);
        let x: Vec<f64> = (0..csr.cols).map(|i| (i % 9) as f64 - 4.0).collect();
        let mut want = vec![0.0; csr.rows];
        csr.spmv_ref(&x, &mut want);
        for kernel in [KernelKind::Csr, KernelKind::Csr5] {
            for threads in [1usize, 3] {
                let e = SpmvEngine::builder(csr.clone())
                    .kernel(kernel)
                    .threads(threads)
                    .build()
                    .unwrap();
                assert_eq!(e.kernel(), kernel);
                let mut y = vec![0.0; csr.rows];
                e.spmv_into(&x, &mut y);
                crate::testkit::assert_close(
                    &y,
                    &want,
                    1e-9,
                    &format!("{kernel} t={threads}"),
                );
            }
        }
    }

    #[test]
    fn csr_row_chunks_cover_disjointly() {
        let csr = suite::circuit(3_000, 3, 4, 11);
        for n in [1usize, 2, 5, 16] {
            let chunks = csr_row_chunks(&csr, n);
            assert_eq!(chunks.len(), n);
            assert_eq!(chunks[0].0, 0);
            assert_eq!(chunks.last().unwrap().1, csr.rows);
            for w in chunks.windows(2) {
                assert_eq!(w[0].1, w[1].0);
            }
        }
    }

    #[test]
    fn f32_engine_serves_wide_kernel() {
        let csr32: Csr<f32> = suite::poisson2d(12).to_precision();
        let e = SpmvEngine::builder(csr32.clone())
            .kernel(KernelKind::Beta(1, 16))
            .build()
            .unwrap();
        let x: Vec<f32> = (0..csr32.cols).map(|i| (i % 5) as f32 * 0.5).collect();
        let mut y = vec![0.0f32; csr32.rows];
        e.spmv_into(&x, &mut y);
        let mut want = vec![0.0f32; csr32.rows];
        csr32.spmv_ref(&x, &mut want);
        for i in 0..csr32.rows {
            assert!((y[i] - want[i]).abs() <= 2e-4 * want[i].abs().max(1.0));
        }
    }

    #[test]
    fn wide_kernel_rejected_for_f64() {
        let csr = suite::poisson2d(6);
        let err = SpmvEngine::builder(csr)
            .kernel(KernelKind::Beta(1, 16))
            .build();
        assert!(err.is_err(), "β(1,16) is f32-only");
    }

    #[test]
    fn predictor_drives_selection() {
        let csr = suite::dense(64, 3);
        let mut store = RecordStore::new();
        // Plant records that make β(4,8) the clear winner at high fill.
        for i in 0..12 {
            let avg = 1.0 + i as f64 * 3.0;
            store.push(PerfRecord {
                matrix: format!("m{i}"),
                kernel: KernelKind::Beta(4, 8),
                avg_nnz_per_block: avg,
                threads: 1,
                tile_cols: 0,
                gflops: 0.5 + 0.1 * avg,
            });
            store.push(PerfRecord {
                matrix: format!("m{i}"),
                kernel: KernelKind::Beta(1, 8),
                avg_nnz_per_block: (1.0 + i as f64 * 0.6).min(8.0),
                threads: 1,
                tile_cols: 0,
                gflops: 1.0,
            });
        }
        let e = SpmvEngine::builder(csr)
            .candidates(&[KernelKind::Beta(1, 8), KernelKind::Beta(4, 8)])
            .records(&store)
            .build()
            .unwrap();
        assert_eq!(e.kernel(), KernelKind::Beta(4, 8));
        assert!(e.predicted_gflops().unwrap() > 1.0);
    }

    #[test]
    fn engine_pool_exists_only_when_parallel() {
        let csr = suite::poisson2d(8);
        let seq = SpmvEngine::builder(csr.clone()).build().unwrap();
        assert!(seq.pool().is_none());
        let par =
            SpmvEngine::builder(csr.clone()).threads(3).build().unwrap();
        assert_eq!(par.pool().unwrap().n_threads(), 3);
        // CSR5 is sequential by construction: no idle parked workers
        // even when threads are requested.
        let csr5 = SpmvEngine::builder(csr)
            .kernel(KernelKind::Csr5)
            .threads(4)
            .build()
            .unwrap();
        assert!(csr5.pool().is_none());
    }

    #[test]
    fn spmm_matches_k_single_spmvs_across_storages() {
        let csr = suite::fem_blocked(260, 3, 5, 9);
        let mut rng = crate::util::Rng::new(77);
        for k in [2usize, 3, 8] {
            let x: Vec<f64> = (0..csr.cols * k)
                .map(|_| rng.range_f64(-1.0, 1.0))
                .collect();
            for (kernel, threads) in [
                (KernelKind::Beta(2, 8), 1usize),
                (KernelKind::Beta(2, 8), 4),
                (KernelKind::Csr, 3),
                (KernelKind::Csr5, 1),
            ] {
                let e = SpmvEngine::builder(csr.clone())
                    .kernel(kernel)
                    .threads(threads)
                    .build()
                    .unwrap();
                let mut y = vec![0.0; csr.rows * k];
                e.spmm_into(&x, &mut y, k);
                // Oracle: k independent single-vector engine calls.
                for j in 0..k {
                    let xj: Vec<f64> =
                        (0..csr.cols).map(|c| x[c * k + j]).collect();
                    let mut want = vec![0.0; csr.rows];
                    e.spmv_into(&xj, &mut want);
                    for r in 0..csr.rows {
                        assert!(
                            (y[r * k + j] - want[r]).abs()
                                <= 1e-9 * want[r].abs().max(1.0),
                            "{kernel} t={threads} k={k} j={j} row {r}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn hybrid_engine_matches_reference_seq_and_par() {
        let csr = suite::mixed_band_scatter(2_048, 5);
        let x: Vec<f64> = (0..csr.cols).map(|i| (i % 9) as f64 - 4.0).collect();
        let mut want = vec![0.0; csr.rows];
        csr.spmv_ref(&x, &mut want);
        for threads in [1usize, 3] {
            let e = SpmvEngine::builder(csr.clone())
                .kernel(KernelKind::Hybrid)
                .panel_rows(128)
                .threads(threads)
                .build()
                .unwrap();
            assert_eq!(e.kernel(), KernelKind::Hybrid);
            let hm = e.hybrid().expect("hybrid storage");
            hm.validate().unwrap();
            assert!(hm.n_segments() >= 2, "mixed matrix should split");
            let mut y = vec![0.0; csr.rows];
            e.spmv_into(&x, &mut y);
            crate::testkit::assert_close(
                &y,
                &want,
                1e-9,
                &format!("hybrid t={threads}"),
            );
        }
    }

    #[test]
    fn hybrid_engine_spmm_matches_k_spmvs() {
        let csr = suite::mixed_band_scatter(1_536, 11);
        let k = 4usize;
        let mut rng = crate::util::Rng::new(3);
        let x: Vec<f64> =
            (0..csr.cols * k).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        for threads in [1usize, 3] {
            let e = SpmvEngine::builder(csr.clone())
                .kernel(KernelKind::Hybrid)
                .panel_rows(64)
                .threads(threads)
                .build()
                .unwrap();
            let mut y = vec![0.0; csr.rows * k];
            e.spmm_into(&x, &mut y, k);
            for j in 0..k {
                let xj: Vec<f64> =
                    (0..csr.cols).map(|c| x[c * k + j]).collect();
                let mut want = vec![0.0; csr.rows];
                e.spmv_into(&xj, &mut want);
                for r in 0..csr.rows {
                    assert!(
                        (y[r * k + j] - want[r]).abs()
                            <= 1e-9 * want[r].abs().max(1.0),
                        "t={threads} j={j} row {r}"
                    );
                }
            }
        }
    }

    #[test]
    fn hybrid_rejects_bad_panel_rows() {
        let csr = suite::poisson2d(8);
        let err = SpmvEngine::builder(csr)
            .kernel(KernelKind::Hybrid)
            .panel_rows(12)
            .build();
        assert!(err.is_err(), "panel_rows=12 must be rejected");
    }

    #[test]
    fn reorder_preserves_spmv_and_spmm_semantics() {
        use crate::matrix::ReorderKind;
        // Shuffled structured matrix: reordering changes the internal
        // layout, but engine products must stay in the caller's index
        // space for every kernel class.
        let m = suite::quantum_clusters(400, 3, 8, 6, 5);
        let mut rng = crate::util::Rng::new(2);
        let mut perm: Vec<u32> = (0..m.rows as u32).collect();
        rng.shuffle(&mut perm);
        let p = crate::matrix::reorder::Permutation { perm };
        let csr = crate::matrix::reorder::permute(&m, &p, &p);

        let x: Vec<f64> = (0..csr.cols).map(|i| (i % 7) as f64 - 3.0).collect();
        let mut want = vec![0.0; csr.rows];
        csr.spmv_ref(&x, &mut want);
        for kind in [ReorderKind::Rcm, ReorderKind::ColPack] {
            for kernel in [
                KernelKind::Beta(2, 4),
                KernelKind::Csr,
                KernelKind::Hybrid,
                KernelKind::Tiled(128),
            ] {
                let e = SpmvEngine::builder(csr.clone())
                    .kernel(kernel)
                    .reorder(kind)
                    .panel_rows(64)
                    .build()
                    .unwrap();
                assert_eq!(e.reorder_kind(), Some(kind));
                let mut y = vec![0.0; csr.rows];
                e.spmv_into(&x, &mut y);
                crate::testkit::assert_close(
                    &y,
                    &want,
                    1e-9,
                    &format!("{kind} {kernel}"),
                );
                // spmm path under reordering.
                let k = 3usize;
                let xk: Vec<f64> = (0..csr.cols * k)
                    .map(|i| ((i * 5) % 13) as f64 * 0.25 - 1.5)
                    .collect();
                let mut yk = vec![0.0; csr.rows * k];
                e.spmm_into(&xk, &mut yk, k);
                for j in 0..k {
                    let xj: Vec<f64> =
                        (0..csr.cols).map(|c| xk[c * k + j]).collect();
                    let mut wj = vec![0.0; csr.rows];
                    csr.spmv_ref(&xj, &mut wj);
                    for r in 0..csr.rows {
                        assert!(
                            (yk[r * k + j] - wj[r]).abs()
                                <= 1e-9 * wj[r].abs().max(1.0),
                            "{kind} {kernel} spmm j={j} row {r}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn rcm_reorder_requires_square() {
        use crate::matrix::ReorderKind;
        let csr = suite::rect_runs(40, 400, 3, 20, 1);
        assert!(SpmvEngine::builder(csr.clone())
            .reorder(ReorderKind::Rcm)
            .build()
            .is_err());
        // Column packing has no squareness requirement.
        SpmvEngine::builder(csr)
            .reorder(ReorderKind::ColPack)
            .kernel(KernelKind::Csr)
            .build()
            .unwrap();
    }

    #[test]
    fn reorder_improves_fill_on_shuffled_band() {
        use crate::matrix::ReorderKind;
        // RCM at build time must improve the β(2,8) fill the engine
        // sees (the reason to wire reordering into the engine at all).
        let band = suite::banded(600, 6, 1.0, 3);
        let mut rng = crate::util::Rng::new(7);
        let mut perm: Vec<u32> = (0..600).collect();
        rng.shuffle(&mut perm);
        let p = crate::matrix::reorder::Permutation { perm };
        let shuffled = crate::matrix::reorder::permute(&band, &p, &p);
        let bs = crate::formats::BlockSize::new(2, 8);
        let fill_before =
            crate::formats::stats::block_stats(&shuffled, bs).avg_nnz_per_block;
        let e = SpmvEngine::builder(shuffled)
            .kernel(KernelKind::Beta(2, 8))
            .reorder(ReorderKind::Rcm)
            .build()
            .unwrap();
        let fill_after =
            crate::formats::stats::block_stats(e.csr(), bs).avg_nnz_per_block;
        assert!(
            fill_after > fill_before * 1.2,
            "RCM should recover fill: {fill_before:.2} -> {fill_after:.2}"
        );
    }

    #[test]
    fn tiled_kernel_matches_reference_seq_and_par() {
        let csr = suite::mixed_band_scatter(2_048, 5);
        let x: Vec<f64> = (0..csr.cols).map(|i| (i % 9) as f64 - 4.0).collect();
        let mut want = vec![0.0; csr.rows];
        csr.spmv_ref(&x, &mut want);
        for threads in [1usize, 3] {
            for kernel in [KernelKind::Tiled(0), KernelKind::Tiled(256)] {
                let e = SpmvEngine::builder(csr.clone())
                    .kernel(kernel)
                    .panel_rows(128)
                    .threads(threads)
                    .build()
                    .unwrap();
                assert_eq!(e.kernel(), kernel);
                let th = e.tiled_hybrid().expect("tiled hybrid storage");
                th.validate().unwrap();
                let want_tile = match kernel {
                    KernelKind::Tiled(0) => {
                        crate::formats::auto_tile_cols::<f64>(csr.cols)
                    }
                    KernelKind::Tiled(w) => w as usize,
                    _ => unreachable!(),
                };
                assert_eq!(e.tile_cols(), Some(want_tile));
                let mut y = vec![0.0; csr.rows];
                e.spmv_into(&x, &mut y);
                crate::testkit::assert_close(
                    &y,
                    &want,
                    1e-9,
                    &format!("{kernel} t={threads}"),
                );
            }
        }
    }

    #[test]
    fn tiled_beta_builder_matches_flat_engine() {
        let csr = suite::fem_blocked(400, 3, 6, 21);
        let x: Vec<f64> = (0..csr.cols).map(|i| (i % 7) as f64 - 3.0).collect();
        let mut want = vec![0.0; csr.rows];
        csr.spmv_ref(&x, &mut want);
        for kernel in [KernelKind::Beta(2, 8), KernelKind::BetaTest(2, 4)] {
            for threads in [1usize, 4] {
                let e = SpmvEngine::builder(csr.clone())
                    .kernel(kernel)
                    .tile_cols(96)
                    .panel_rows(64)
                    .threads(threads)
                    .build()
                    .unwrap();
                assert_eq!(e.tile_cols(), Some(96));
                let tm = e.tiled().expect("tiled β storage");
                tm.validate().unwrap();
                let mut y = vec![0.0; csr.rows];
                e.spmv_into(&x, &mut y);
                crate::testkit::assert_close(
                    &y,
                    &want,
                    1e-9,
                    &format!("tiled {kernel} t={threads}"),
                );
            }
        }
        // Baselines have no tiled form: the setting is ignored, not an
        // error.
        let e = SpmvEngine::builder(csr.clone())
            .kernel(KernelKind::Csr)
            .tile_cols(96)
            .build()
            .unwrap();
        assert_eq!(e.tile_cols(), None);
        // tile_cols(0) spells auto, consistently with `tiled(0)`.
        let e = SpmvEngine::builder(csr.clone())
            .kernel(KernelKind::Beta(2, 8))
            .tile_cols(0)
            .build()
            .unwrap();
        assert_eq!(
            e.tile_cols(),
            Some(crate::formats::auto_tile_cols::<f64>(csr.cols))
        );
    }

    #[test]
    fn tiled_engine_spmm_matches_k_spmvs() {
        let csr = suite::mixed_band_scatter(1_536, 11);
        let k = 4usize;
        let mut rng = crate::util::Rng::new(5);
        let x: Vec<f64> =
            (0..csr.cols * k).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        for threads in [1usize, 3] {
            let e = SpmvEngine::builder(csr.clone())
                .kernel(KernelKind::Tiled(192))
                .panel_rows(64)
                .threads(threads)
                .build()
                .unwrap();
            let mut y = vec![0.0; csr.rows * k];
            e.spmm_into(&x, &mut y, k);
            for j in 0..k {
                let xj: Vec<f64> =
                    (0..csr.cols).map(|c| x[c * k + j]).collect();
                let mut want = vec![0.0; csr.rows];
                e.spmv_into(&xj, &mut want);
                for r in 0..csr.rows {
                    assert!(
                        (y[r * k + j] - want[r]).abs()
                            <= 1e-9 * want[r].abs().max(1.0),
                        "t={threads} j={j} row {r}"
                    );
                }
            }
        }
    }

    #[test]
    fn baseline_spmm_reuses_engine_scratch() {
        // The CSR fallback must keep working when spmm is called twice
        // with different k (scratch is resized, not assumed fresh).
        let csr = suite::poisson2d(12);
        let e = SpmvEngine::builder(csr.clone())
            .kernel(KernelKind::Csr)
            .build()
            .unwrap();
        for k in [3usize, 2, 5] {
            let x: Vec<f64> = (0..csr.cols * k)
                .map(|i| ((i * 7) % 13) as f64 * 0.5 - 3.0)
                .collect();
            let mut y = vec![0.0; csr.rows * k];
            e.spmm_into(&x, &mut y, k);
            for j in 0..k {
                let xj: Vec<f64> =
                    (0..csr.cols).map(|c| x[c * k + j]).collect();
                let mut want = vec![0.0; csr.rows];
                csr.spmv_ref(&xj, &mut want);
                for r in 0..csr.rows {
                    assert!(
                        (y[r * k + j] - want[r]).abs()
                            <= 1e-9 * want[r].abs().max(1.0),
                        "k={k} j={j} row {r}"
                    );
                }
            }
        }
    }

    #[test]
    fn engine_spmv_matches_reference_seq_and_par() {
        let csr = suite::fem_blocked(300, 3, 5, 17);
        let x: Vec<f64> = (0..csr.cols).map(|i| (i % 7) as f64 - 3.0).collect();
        let mut want = vec![0.0; csr.rows];
        csr.spmv_ref(&x, &mut want);
        for threads in [1usize, 4] {
            for numa in [false, true] {
                let e = SpmvEngine::builder(csr.clone())
                    .threads(threads)
                    .numa_split(numa)
                    .kernel(KernelKind::Beta(2, 8))
                    .build()
                    .unwrap();
                let mut y = vec![0.0; csr.rows];
                e.spmv_into(&x, &mut y);
                crate::testkit::assert_close(
                    &y,
                    &want,
                    1e-9,
                    &format!("t={threads} numa={numa}"),
                );
            }
        }
    }
}
