//! `SpmvEngine` — the user-facing facade tying the library together.
//!
//! Given a CSR matrix, the engine:
//! 1. computes the cheap `Avg(r,c)` profile (no conversion),
//! 2. consults the record store to select the most promising kernel
//!    (paper §Performance prediction) — or takes an explicit override,
//! 3. converts once into the selected `β(r,c)` storage,
//! 4. serves `spmv` calls sequentially or through the parallel runtime.

use crate::formats::stats::paper_profile;
use crate::formats::{csr_to_block, BlockMatrix};
use crate::kernels::{spmv_block, KernelKind};
use crate::matrix::Csr;
use crate::parallel::{ParallelSpmv, ParallelStrategy};
use crate::predictor::{select_parallel, select_sequential, RecordStore};

/// Engine construction options.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Worker threads (1 = sequential path).
    pub threads: usize,
    /// NUMA-style array splitting for the parallel path.
    pub numa_split: bool,
    /// Kernel override; `None` lets the predictor choose.
    pub kernel: Option<KernelKind>,
    /// Candidate kernels for prediction.
    pub candidates: Vec<KernelKind>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            threads: 1,
            numa_split: false,
            kernel: None,
            candidates: KernelKind::SPC5_KERNELS.to_vec(),
        }
    }
}

/// A matrix bound to its chosen kernel and storage, ready to serve.
pub struct SpmvEngine {
    csr: Csr,
    kernel: KernelKind,
    predicted_gflops: Option<f64>,
    block: Option<BlockMatrix>,
    parallel: Option<ParallelSpmv>,
    threads: usize,
}

impl SpmvEngine {
    /// Builds the engine; consults `records` when no kernel override is
    /// given (falls back to β(1,8) — the cheapest conversion, as the
    /// paper recommends — when there are no records to predict from).
    pub fn new(
        csr: Csr,
        cfg: &EngineConfig,
        records: Option<&RecordStore>,
    ) -> anyhow::Result<SpmvEngine> {
        let (kernel, predicted) = match cfg.kernel {
            Some(k) => (k, None),
            None => {
                let sel = records.and_then(|store| {
                    if cfg.threads > 1 {
                        select_parallel(&csr, store, &cfg.candidates, cfg.threads)
                    } else {
                        select_sequential(&csr, store, &cfg.candidates)
                    }
                });
                match sel {
                    Some(s) => (s.kernel, Some(s.predicted_gflops)),
                    None => (KernelKind::Beta(1, 8), None),
                }
            }
        };

        let bs = kernel
            .block_size()
            .ok_or_else(|| anyhow::anyhow!("engine serves β kernels; got {kernel}"))?;
        let block = csr_to_block(&csr, bs)?;
        let test = matches!(kernel, KernelKind::BetaTest(..));

        let (block, parallel) = if cfg.threads > 1 {
            let strategy = if cfg.numa_split {
                ParallelStrategy::NumaSplit
            } else {
                ParallelStrategy::Shared
            };
            (None, Some(ParallelSpmv::new(block, cfg.threads, strategy, test)))
        } else {
            (Some(block), None)
        };

        Ok(SpmvEngine {
            csr,
            kernel,
            predicted_gflops: predicted,
            block,
            parallel,
            threads: cfg.threads,
        })
    }

    /// The kernel serving this matrix.
    pub fn kernel(&self) -> KernelKind {
        self.kernel
    }

    /// Predicted GFlop/s, when the predictor made the choice.
    pub fn predicted_gflops(&self) -> Option<f64> {
        self.predicted_gflops
    }

    /// The bound matrix.
    pub fn csr(&self) -> &Csr {
        &self.csr
    }

    /// Worker threads.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// `y += A·x` through the chosen kernel and runtime.
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        match (&self.parallel, &self.block) {
            (Some(p), _) => p.spmv(x, y),
            (None, Some(bm)) => spmv_block(
                bm,
                x,
                y,
                matches!(self.kernel, KernelKind::BetaTest(..)),
            ),
            _ => unreachable!("engine always holds one storage"),
        }
    }

    /// `y = A·x` (zeroing first).
    pub fn spmv_into(&self, x: &[f64], y: &mut [f64]) {
        y.iter_mut().for_each(|v| *v = 0.0);
        self.spmv(x, y);
    }

    /// The Table-1-style stats row for the bound matrix.
    pub fn profile(&self) -> Vec<crate::formats::BlockStats> {
        paper_profile(&self.csr)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::suite;
    use crate::predictor::PerfRecord;

    #[test]
    fn explicit_kernel_used() {
        let csr = suite::poisson2d(16);
        let cfg = EngineConfig {
            kernel: Some(KernelKind::Beta(4, 4)),
            ..Default::default()
        };
        let e = SpmvEngine::new(csr, &cfg, None).unwrap();
        assert_eq!(e.kernel(), KernelKind::Beta(4, 4));
    }

    #[test]
    fn defaults_to_1x8_without_records() {
        let csr = suite::poisson2d(8);
        let e = SpmvEngine::new(csr, &EngineConfig::default(), None).unwrap();
        assert_eq!(e.kernel(), KernelKind::Beta(1, 8));
        assert!(e.predicted_gflops().is_none());
    }

    #[test]
    fn predictor_drives_selection() {
        let csr = suite::dense(64, 3);
        let mut store = RecordStore::new();
        // Plant records that make β(4,8) the clear winner at high fill.
        for i in 0..12 {
            let avg = 1.0 + i as f64 * 3.0;
            store.push(PerfRecord {
                matrix: format!("m{i}"),
                kernel: KernelKind::Beta(4, 8),
                avg_nnz_per_block: avg,
                threads: 1,
                gflops: 0.5 + 0.1 * avg,
            });
            store.push(PerfRecord {
                matrix: format!("m{i}"),
                kernel: KernelKind::Beta(1, 8),
                avg_nnz_per_block: (1.0 + i as f64 * 0.6).min(8.0),
                threads: 1,
                gflops: 1.0,
            });
        }
        let cfg = EngineConfig {
            candidates: vec![KernelKind::Beta(1, 8), KernelKind::Beta(4, 8)],
            ..Default::default()
        };
        let e = SpmvEngine::new(csr, &cfg, Some(&store)).unwrap();
        assert_eq!(e.kernel(), KernelKind::Beta(4, 8));
        assert!(e.predicted_gflops().unwrap() > 1.0);
    }

    #[test]
    fn rejects_non_beta_kernel() {
        let csr = suite::poisson2d(4);
        let cfg = EngineConfig {
            kernel: Some(KernelKind::Csr),
            ..Default::default()
        };
        assert!(SpmvEngine::new(csr, &cfg, None).is_err());
    }

    #[test]
    fn engine_spmv_matches_reference_seq_and_par() {
        let csr = suite::fem_blocked(300, 3, 5, 17);
        let x: Vec<f64> = (0..csr.cols).map(|i| (i % 7) as f64 - 3.0).collect();
        let mut want = vec![0.0; csr.rows];
        csr.spmv_ref(&x, &mut want);
        for threads in [1usize, 4] {
            for numa in [false, true] {
                let cfg = EngineConfig {
                    threads,
                    numa_split: numa,
                    kernel: Some(KernelKind::Beta(2, 8)),
                    ..Default::default()
                };
                let e = SpmvEngine::new(csr.clone(), &cfg, None).unwrap();
                let mut y = vec![0.0; csr.rows];
                e.spmv_into(&x, &mut y);
                crate::testkit::assert_close(
                    &y,
                    &want,
                    1e-9,
                    &format!("t={threads} numa={numa}"),
                );
            }
        }
    }
}
