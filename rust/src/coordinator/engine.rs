//! `SpmvEngine<T>` — the user-facing facade tying the library together.
//!
//! Built through the fluent [`SpmvEngine::builder`]:
//!
//! ```no_run
//! use spc5::{Csr, KernelKind, SpmvEngine};
//! # fn demo(csr: Csr, store: &spc5::predictor::RecordStore) -> anyhow::Result<()> {
//! let engine = SpmvEngine::builder(csr)
//!     .threads(4)
//!     .numa_split(true)
//!     .records(store)                      // predictor picks the kernel
//!     .candidates(&KernelKind::ALL)        // ... among these
//!     .build()?;
//! # Ok(()) }
//! ```
//!
//! The build is an **inspector–executor** pipeline with a first-class
//! plan between the halves:
//!
//! 1. **inspect** — [`SpmvEngineBuilder::plan`] computes the cheap
//!    `Avg(r,c)` profile, consults the record store (or takes an
//!    explicit override), ranks the hybrid panels, and resolves every
//!    knob into a serializable [`SpmvPlan`] — converting nothing;
//! 2. **instantiate** — [`SpmvEngine::from_plan`] converts the matrix
//!    once into the planned storage and wires the runtime, skipping
//!    selection entirely. A [`MatrixFingerprint`] check refuses plans
//!    inspected on a different matrix;
//! 3. [`SpmvEngineBuilder::build`] is exactly (1) + (2), so
//!    `plan() → JSON → from_plan()` reproduces the built engine
//!    bit-for-bit; [`SpmvEngineBuilder::plan_cache`] persists plans
//!    keyed by fingerprint so repeat workloads skip inspection.
//!
//! The built engine holds **one** [`SparseStorage`] trait object —
//! `β(r,c)` block storage (sequential or the pool-parallel
//! [`crate::parallel::ParallelSpmv`]), the CSR baseline (row-chunked
//! across threads), the CSR5 comparator, the hybrid row-panel schedule
//! and its cache-blocked tiled forms all serve `spmv`/`spmm` through
//! the same object-safe surface; there is no per-kernel dispatch left
//! on the product paths.
//!
//! Two build-time levers ride on the builder:
//! [`SpmvEngineBuilder::panel_rows`] tunes the hybrid panel height and
//! [`SpmvEngineBuilder::reorder`] applies RCM / column-packing before
//! profiling and conversion (products transparently permute x/y, so
//! callers keep their original index space).
//!
//! With `threads > 1` the engine owns **one** [`WorkerPool`] for its
//! lifetime: every parallel storage runs its epochs on it, and every
//! `spmv`/`spmm` afterwards — including each iteration of the Krylov
//! solvers and each batch of the serving layer — is an epoch handoff
//! to the same long-lived workers. No per-call thread spawning
//! anywhere on the hot path.
//!
//! [`SpmvEngine::spmm`] is the multi-RHS entry (`Y += A·X`, `k`
//! right-hand sides in one matrix traversal) that the service's
//! micro-batching dispatcher coalesces concurrent requests into.

use super::plan::{MatrixFingerprint, PlanCache, SpmvPlan, PLAN_VERSION};
use crate::formats::stats::paper_profile;
use crate::formats::{
    csr_to_block, BetaTestStorage, BlockSize, Csr5Storage, CsrStorage,
    HybridConfig, HybridMatrix, PoolExec, SparseStorage, TileCols,
    TiledHybrid, TiledMatrix,
};
use crate::kernels::{csr5, KernelKind, TuneParams};
use crate::matrix::reorder::{self, Permutation, ReorderKind};
use crate::matrix::Csr;
use crate::parallel::{ParallelSpmv, ParallelStrategy, WorkerPool};
use crate::predictor::{select_parallel, select_sequential, RecordStore};
use crate::scalar::Scalar;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

/// The permutations a reordering engine applies around every product:
/// the bound matrix is `B[i,j] = A[rows[i], cols[j]]`, so `x` is
/// gathered through `cols` on the way in and `y` scattered through
/// `rows` on the way out — callers keep the original index space.
struct ReorderState<T: Scalar> {
    kind: ReorderKind,
    rows: Permutation,
    cols: Permutation,
    /// Reusable gather/scatter buffers `(xp, yp)` — allocating them
    /// per call would reintroduce the hot-path allocation the pool
    /// runtime removed. The lock is uncontended in practice (products
    /// on one engine are serialized by their callers); it exists so
    /// `spmv(&self, ..)` stays shareable.
    scratch: Mutex<(Vec<T>, Vec<T>)>,
}

impl<T: Scalar> ReorderState<T> {
    fn new(kind: ReorderKind, rows: Permutation, cols: Permutation) -> Self {
        ReorderState { kind, rows, cols, scratch: Mutex::new((Vec::new(), Vec::new())) }
    }
}

/// A matrix bound to its planned kernel and storage, ready to serve.
pub struct SpmvEngine<T: Scalar = f64> {
    /// The bound (possibly permuted) matrix — shared with the CSR
    /// baseline storage rather than copied.
    csr: Arc<Csr<T>>,
    /// The plan this engine was instantiated from (what `build()`
    /// inspected or `from_plan()` was handed).
    plan: SpmvPlan,
    /// The one executor: every kernel class behind the same trait.
    storage: Box<dyn SparseStorage<T>>,
    /// The storage's nnz-balanced work split for the pool, computed
    /// once at build ([`SparseStorage::par_split`]); empty when the
    /// storage runs sequentially or schedules itself.
    chunks: Vec<(usize, usize)>,
    /// The persistent runtime every parallel path runs on, created
    /// once at build time (`None` when `threads == 1`).
    pool: Option<Arc<WorkerPool>>,
    /// Build-time reordering; when present, `csr` is the *permuted*
    /// matrix and every `spmv`/`spmm` transparently permutes x/y.
    reorder: Option<ReorderState<T>>,
    /// Pool attach id for per-worker SpMM accumulator scratch on the
    /// tiled parallel paths.
    scratch_attach: u64,
}

/// Fluent configuration for [`SpmvEngine`] — the inspector half of the
/// engine's inspector–executor split (see the module docs).
pub struct SpmvEngineBuilder<'r, T: Scalar = f64> {
    csr: Csr<T>,
    threads: usize,
    numa_split: bool,
    kernel: Option<KernelKind>,
    candidates: Vec<KernelKind>,
    /// Whether `.candidates(..)` was called explicitly (an explicit
    /// list conflicts with a non-hybrid kernel override).
    candidates_set: bool,
    records: Option<&'r RecordStore>,
    panel_rows: usize,
    reorder: Option<ReorderKind>,
    tiling: Option<TileCols>,
    plan_cache: Option<PathBuf>,
    tune: Option<TuneParams>,
    tune_profile: Option<PathBuf>,
}

impl<T: Scalar> SpmvEngine<T> {
    /// Starts building an engine for `csr`. Defaults: 1 thread, no
    /// NUMA split, predictor-driven kernel selection over
    /// [`KernelKind::SPC5_KERNELS`] (falling back to β(1,8) — the
    /// cheapest conversion, as the paper recommends — when no records
    /// are supplied).
    pub fn builder(csr: Csr<T>) -> SpmvEngineBuilder<'static, T> {
        SpmvEngineBuilder {
            csr,
            threads: 1,
            numa_split: false,
            kernel: None,
            candidates: KernelKind::SPC5_KERNELS.to_vec(),
            candidates_set: false,
            records: None,
            panel_rows: crate::formats::hybrid::DEFAULT_PANEL_ROWS,
            reorder: None,
            tiling: None,
            plan_cache: None,
            tune: None,
            tune_profile: None,
        }
    }

    /// Instantiates an engine from a previously inspected plan —
    /// the executor half: conversion and runtime wiring only, no
    /// selection. Fails when `csr` does not match the plan's
    /// [`MatrixFingerprint`] (the plan was inspected on a different
    /// matrix) or when the plan is internally inconsistent.
    pub fn from_plan(csr: Csr<T>, plan: &SpmvPlan) -> anyhow::Result<Self> {
        let fp = MatrixFingerprint::of(&csr);
        anyhow::ensure!(
            fp == plan.fingerprint,
            "plan fingerprint mismatch: plan was inspected on {} but this \
             matrix is {} — refusing to instantiate",
            plan.fingerprint.key(),
            fp.key()
        );
        // The plan crossed a serialization boundary: re-validate its
        // schedule during conversion.
        Self::instantiate(csr, plan.clone(), None, false)
    }

    /// The kernel serving this matrix.
    pub fn kernel(&self) -> KernelKind {
        self.plan.kernel
    }

    /// Predicted GFlop/s, when the predictor made the choice.
    pub fn predicted_gflops(&self) -> Option<f64> {
        self.plan.predicted_gflops
    }

    /// The plan this engine executes (inspect once, introspect
    /// forever: serialize it with [`SpmvPlan::to_json`] to reuse the
    /// decision elsewhere).
    pub fn plan(&self) -> &SpmvPlan {
        &self.plan
    }

    /// The bound matrix.
    pub fn csr(&self) -> &Csr<T> {
        &self.csr
    }

    /// Worker threads.
    pub fn threads(&self) -> usize {
        self.plan.threads
    }

    /// The engine's persistent worker pool (`None` when sequential).
    /// Shared by every parallel storage, the solvers and the serving
    /// layer for the engine's whole lifetime.
    pub fn pool(&self) -> Option<&Arc<WorkerPool>> {
        self.pool.as_ref()
    }

    /// The reordering applied at build time, if any.
    pub fn reorder_kind(&self) -> Option<ReorderKind> {
        self.reorder.as_ref().map(|r| r.kind)
    }

    /// The unified storage executor.
    pub fn storage(&self) -> &dyn SparseStorage<T> {
        &*self.storage
    }

    /// For hybrid engines: the compiled panel schedule (downcast
    /// convenience over [`SpmvEngine::storage`]).
    pub fn hybrid(&self) -> Option<&HybridMatrix<T>> {
        self.storage.as_any().downcast_ref::<HybridMatrix<T>>()
    }

    /// For tiled β engines: the `(panel, tile)` schedule (downcast
    /// convenience).
    pub fn tiled(&self) -> Option<&TiledMatrix<T>> {
        let any = self.storage.as_any();
        any.downcast_ref::<TiledMatrix<T>>().or_else(|| {
            match any.downcast_ref::<BetaTestStorage<T>>() {
                Some(BetaTestStorage::Tiled(tm)) => Some(tm),
                _ => None,
            }
        })
    }

    /// For tiled hybrid engines: the tiled segment schedule (downcast
    /// convenience).
    pub fn tiled_hybrid(&self) -> Option<&TiledHybrid<T>> {
        self.storage.as_any().downcast_ref::<TiledHybrid<T>>()
    }

    /// Resolved column tile width, when the engine runs cache-blocked
    /// (`None` = flat schedule).
    pub fn tile_cols(&self) -> Option<usize> {
        self.storage.tile_cols()
    }

    /// `y += A·x` through the planned kernel and runtime. When the
    /// engine was built with a reordering, `x`/`y` stay in the
    /// caller's original index space — the permutation is applied
    /// internally around the product.
    pub fn spmv(&self, x: &[T], y: &mut [T]) {
        match &self.reorder {
            None => self.spmv_permuted(x, y),
            Some(st) => {
                let mut guard = st.scratch.lock().unwrap_or_else(|e| e.into_inner());
                let (xp, yp) = &mut *guard;
                xp.clear();
                xp.extend(st.cols.perm.iter().map(|&old| x[old as usize]));
                yp.clear();
                yp.resize(self.csr.rows, T::ZERO);
                self.spmv_permuted(xp, yp);
                for (new_r, &old_r) in st.rows.perm.iter().enumerate() {
                    y[old_r as usize] += yp[new_r];
                }
            }
        }
    }

    /// The pooled execution context, when this engine both has a pool
    /// and a chunked storage split (self-scheduling storages like the
    /// parallel β runtime keep their split internal and run through
    /// the sequential entry point).
    fn pool_exec(&self) -> Option<PoolExec<'_>> {
        let pool = self.pool.as_deref()?;
        if self.chunks.is_empty() {
            return None;
        }
        Some(PoolExec {
            pool,
            chunks: &self.chunks,
            scratch_attach: self.scratch_attach,
        })
    }

    /// `y += B·x` in the bound (possibly permuted) index space — one
    /// trait call, no per-kernel dispatch.
    fn spmv_permuted(&self, x: &[T], y: &mut [T]) {
        match self.pool_exec() {
            Some(exec) => self.storage.spmv_pooled(exec, x, y),
            None => self.storage.spmv_seq(x, y),
        }
    }

    /// `y = A·x` (zeroing first).
    pub fn spmv_into(&self, x: &[T], y: &mut [T]) {
        y.iter_mut().for_each(|v| *v = T::ZERO);
        self.spmv(x, y);
    }

    /// Multi-RHS `Y += A·X`: `x` holds `k` right-hand sides row-major
    /// (`x[c*k + j]` = vector `j` at position `c`, see
    /// [`crate::kernels::spmm`]), `y` likewise `[rows × k]`. The block
    /// storages traverse the matrix **once** for all `k` vectors — the
    /// batching lever the serving layer uses; the CSR/CSR5 baselines
    /// fall back to `k` single-vector passes through storage-owned
    /// scratch.
    pub fn spmm(&self, x: &[T], y: &mut [T], k: usize) {
        assert!(k > 0);
        assert_eq!(x.len(), self.csr.cols * k, "x must be cols*k");
        assert_eq!(y.len(), self.csr.rows * k, "y must be rows*k");
        if k == 1 {
            return self.spmv(x, y);
        }
        match &self.reorder {
            None => self.spmm_permuted(x, y, k),
            Some(st) => {
                let mut guard = st.scratch.lock().unwrap_or_else(|e| e.into_inner());
                let (xp, yp) = &mut *guard;
                xp.clear();
                xp.resize(x.len(), T::ZERO);
                for (new_c, &old_c) in st.cols.perm.iter().enumerate() {
                    let old_c = old_c as usize;
                    xp[new_c * k..(new_c + 1) * k]
                        .copy_from_slice(&x[old_c * k..(old_c + 1) * k]);
                }
                yp.clear();
                yp.resize(y.len(), T::ZERO);
                self.spmm_permuted(xp, yp, k);
                for (new_r, &old_r) in st.rows.perm.iter().enumerate() {
                    let old_r = old_r as usize;
                    for j in 0..k {
                        y[old_r * k + j] += yp[new_r * k + j];
                    }
                }
            }
        }
    }

    /// Multi-RHS product in the bound (possibly permuted) index space.
    fn spmm_permuted(&self, x: &[T], y: &mut [T], k: usize) {
        self.storage.spmm(self.pool_exec(), x, y, k);
    }

    /// Multi-RHS `Y = A·X` (zeroing first).
    pub fn spmm_into(&self, x: &[T], y: &mut [T], k: usize) {
        y.iter_mut().for_each(|v| *v = T::ZERO);
        self.spmm(x, y, k);
    }

    /// The Table-1-style stats row for the bound matrix.
    pub fn profile(&self) -> Vec<crate::formats::BlockStats> {
        paper_profile(&self.csr)
    }

    /// The executor half: converts `csr` into the planned storage and
    /// wires the runtime. No selection, no records — everything the
    /// build needs is in the plan. `pre` carries the already-permuted
    /// matrix when the caller's inspection just computed it (so
    /// `build()` pays the reordering once); `trusted_schedule` is set
    /// only for schedules produced in-process this call — anything
    /// that crossed a serialization boundary is re-validated.
    fn instantiate(
        csr: Csr<T>,
        mut plan: SpmvPlan,
        pre: Option<(Csr<T>, ReorderState<T>)>,
        trusted_schedule: bool,
    ) -> anyhow::Result<Self> {
        // A plan-level variant fans out to every hybrid segment that
        // has no override of its own, so the assembled schedule (and
        // the plan this engine reports) is explicit about what it runs.
        if let Some(t) = plan.tune {
            for e in &mut plan.schedule {
                e.tune.get_or_insert(t);
            }
        }
        // Build-time reordering: permute first so conversion sees the
        // same improved shape the inspection ranked.
        let (csr, reorder_state) = match pre {
            Some((permuted, st)) => {
                debug_assert_eq!(Some(st.kind), plan.reorder);
                (permuted, Some(st))
            }
            None => match plan.reorder {
                None => (csr, None),
                Some(ReorderKind::Rcm) => {
                    anyhow::ensure!(
                        csr.rows == csr.cols,
                        "RCM reordering needs a square matrix \
                         ({}x{} given)",
                        csr.rows,
                        csr.cols
                    );
                    let p = reorder::cuthill_mckee(&csr);
                    let permuted = reorder::permute(&csr, &p, &p);
                    let st =
                        ReorderState::new(ReorderKind::Rcm, p.clone(), p);
                    (permuted, Some(st))
                }
                Some(ReorderKind::ColPack) => {
                    let rows = Permutation::identity(csr.rows);
                    let cols = reorder::column_pack(&csr);
                    let permuted = reorder::permute(&csr, &rows, &cols);
                    let st =
                        ReorderState::new(ReorderKind::ColPack, rows, cols);
                    (permuted, Some(st))
                }
            },
        };
        let csr = Arc::new(csr);
        let threads = plan.threads;

        // One persistent pool per engine lifetime: spawned here, shared
        // by whichever parallel path the planned kernel needs, reused
        // by every solver iteration and service batch afterwards. CSR5
        // has no parallel path (the reference kernel carries open-row
        // state across tiles), so it never gets idle parked workers.
        let parallel_kernel = !matches!(plan.kernel, KernelKind::Csr5);
        let pool = (threads > 1 && parallel_kernel)
            .then(|| Arc::new(WorkerPool::new(threads)));

        let storage: Box<dyn SparseStorage<T>> = match plan.kernel {
            KernelKind::Csr => Box::new(CsrStorage::new(Arc::clone(&csr))),
            KernelKind::Csr5 => {
                Box::new(Csr5Storage::new(csr5::Csr5Matrix::from_csr(&csr)))
            }
            KernelKind::Hybrid | KernelKind::Tiled(_) => {
                // The schedule was planned at inspection; conversion
                // reproduces it segment for segment. Deserialized
                // schedules are re-validated, in-process ones skip the
                // second O(nnz) walk.
                let hm = if trusted_schedule {
                    HybridMatrix::from_schedule_trusted(
                        &csr,
                        plan.panel_rows,
                        &plan.schedule,
                    )?
                } else {
                    HybridMatrix::from_schedule(
                        &csr,
                        plan.panel_rows,
                        &plan.schedule,
                    )?
                };
                match plan.tile_cols {
                    Some(tc) => Box::new(TiledHybrid::from_hybrid(
                        &hm,
                        TileCols::Fixed(tc),
                    )?),
                    None => {
                        anyhow::ensure!(
                            !matches!(plan.kernel, KernelKind::Tiled(_)),
                            "plan: tiled kernel without a resolved \
                             tile_cols"
                        );
                        Box::new(hm)
                    }
                }
            }
            KernelKind::Beta(..) | KernelKind::BetaTest(..) => {
                let bs = plan.kernel.block_size().expect("β kernel has a size");
                let test = matches!(plan.kernel, KernelKind::BetaTest(..));
                let mut block = csr_to_block(&csr, bs)?;
                // The planned variant rides on the storage: every span
                // call afterwards dispatches it without re-resolution.
                if let Some(t) = plan.tune {
                    block.tune = t;
                }
                match plan.tile_cols {
                    // Cache-blocked β: `(panel, tile)` spans over one
                    // converted block matrix. Parallelism is the 2-D
                    // panel split on the pool (the NUMA array-split
                    // strategy has no tiled form and is not applied
                    // here).
                    Some(tc) => {
                        let tm = TiledMatrix::from_block(
                            &block,
                            plan.panel_rows,
                            tc,
                        )?;
                        if test {
                            Box::new(BetaTestStorage::Tiled(tm))
                        } else {
                            Box::new(tm)
                        }
                    }
                    None => match &pool {
                        Some(pool) => {
                            let strategy = if plan.numa_split {
                                ParallelStrategy::NumaSplit
                            } else {
                                ParallelStrategy::Shared
                            };
                            Box::new(ParallelSpmv::with_pool(
                                block,
                                Arc::clone(pool),
                                strategy,
                                test,
                            ))
                        }
                        None => {
                            if test {
                                Box::new(BetaTestStorage::Flat(block))
                            } else {
                                Box::new(block)
                            }
                        }
                    },
                }
            }
        };

        // The storage's own work split, balanced once here — the hot
        // path never re-balances. Empty for sequential and
        // self-scheduling storages.
        let chunks = if pool.is_some() {
            storage.par_split(threads)
        } else {
            Vec::new()
        };

        Ok(SpmvEngine {
            csr,
            plan,
            storage,
            chunks,
            pool,
            reorder: reorder_state,
            scratch_attach: crate::parallel::pool::next_attach_id(),
        })
    }
}

impl<'r, T: Scalar> SpmvEngineBuilder<'r, T> {
    /// Worker threads (1 = sequential path).
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = n.max(1);
        self
    }

    /// NUMA-style array splitting for the parallel β path.
    pub fn numa_split(mut self, on: bool) -> Self {
        self.numa_split = on;
        self
    }

    /// Explicit kernel override (skips the predictor). Any
    /// [`KernelKind`] is accepted, including `Csr` and `Csr5`.
    pub fn kernel(mut self, k: KernelKind) -> Self {
        self.kernel = Some(k);
        self
    }

    /// Candidate kernels for predictor-driven selection (and β sizes
    /// for the hybrid panel compiler). Conflicts with a non-hybrid
    /// explicit [`SpmvEngineBuilder::kernel`] override — the override
    /// leaves nothing to select.
    pub fn candidates(mut self, kinds: &[KernelKind]) -> Self {
        self.candidates = kinds.to_vec();
        self.candidates_set = true;
        self
    }

    /// Rows per panel for the hybrid and tiled schedules (must be a
    /// positive multiple of 8; used by [`KernelKind::Hybrid`],
    /// [`KernelKind::Tiled`] and tiled β storages).
    pub fn panel_rows(mut self, rows: usize) -> Self {
        self.panel_rows = rows;
        self
    }

    /// Fixed column tile width: the built storage executes
    /// cache-blocked, each `(panel, tile)` pass touching only an
    /// `n`-column window of `x`. `n == 0` means auto-size (the same
    /// spelling as `tiled(0)`). Applies to β kernels (tiled block
    /// spans) and to the hybrid schedule (every segment tiled); an
    /// explicit CSR/CSR5 kernel has no tiled form and rejects it at
    /// plan time.
    pub fn tile_cols(mut self, n: usize) -> Self {
        self.tiling = Some(if n == 0 {
            TileCols::Auto
        } else {
            TileCols::Fixed(n)
        });
        self
    }

    /// Auto-sized column tiling: the tile width is chosen so the `x`
    /// window fills half the detected per-core L2
    /// ([`crate::formats::auto_tile_cols`]; `SPC5_L2_BYTES` overrides
    /// the detection). Same applicability as
    /// [`SpmvEngineBuilder::tile_cols`].
    pub fn tile_auto(mut self) -> Self {
        self.tiling = Some(TileCols::Auto);
        self
    }

    /// Applies a bandwidth/fill-improving reordering to the matrix at
    /// build time (paper §"Matrix permutation/reordering"). The engine
    /// stores the permuted matrix and transparently permutes `x`/`y`
    /// in every `spmv`/`spmm`, so callers keep their original index
    /// space. [`ReorderKind::Rcm`] needs a square matrix.
    pub fn reorder(mut self, kind: ReorderKind) -> Self {
        self.reorder = Some(kind);
        self
    }

    /// Explicit kernel-variant override for the β hot loops (prefetch
    /// distances, x-prefetch, unrolling — see
    /// [`crate::kernels::TuneParams`]). Skips the machine profile; the
    /// plan carries the variant so `from_plan` reproduces it exactly.
    /// Without this (or a profile hit) the plan stores `None` and
    /// instantiation runs the process default.
    pub fn tune(mut self, t: TuneParams) -> Self {
        self.tune = Some(t);
        self
    }

    /// Machine tune profile (written by `spc5 tune`) consulted at plan
    /// time: the planned kernel — and, for hybrid schedules, each β
    /// segment — gets the profile's winning variant. An explicit
    /// [`SpmvEngineBuilder::tune`] override takes precedence.
    pub fn tune_profile(mut self, path: impl Into<PathBuf>) -> Self {
        self.tune_profile = Some(path.into());
        self
    }

    /// Performance records the predictor selects from.
    pub fn records<'b>(self, store: &'b RecordStore) -> SpmvEngineBuilder<'b, T> {
        SpmvEngineBuilder {
            csr: self.csr,
            threads: self.threads,
            numa_split: self.numa_split,
            kernel: self.kernel,
            candidates: self.candidates,
            candidates_set: self.candidates_set,
            records: Some(store),
            panel_rows: self.panel_rows,
            reorder: self.reorder,
            tiling: self.tiling,
            plan_cache: self.plan_cache,
            tune: self.tune,
            tune_profile: self.tune_profile,
        }
    }

    /// Persistent plan cache: `build()` first looks up a plan for this
    /// matrix's fingerprint (and thread count) in the JSON store at
    /// `path` and instantiates from it — skipping inspection entirely
    /// — when the cached plan is compatible with the builder's
    /// settings; on a miss it plans, stores and saves. A missing file
    /// is an empty cache.
    pub fn plan_cache(mut self, path: impl Into<PathBuf>) -> Self {
        self.plan_cache = Some(path.into());
        self
    }

    /// The **inspection** phase: runs the predictor/analysis and
    /// resolves every build decision into a serializable [`SpmvPlan`]
    /// without converting anything. `build()` is exactly this followed
    /// by [`SpmvEngine::from_plan`]-style instantiation.
    pub fn plan(&self) -> anyhow::Result<SpmvPlan> {
        Ok(self.inspect()?.0)
    }

    /// [`SpmvEngineBuilder::plan`] plus the permuted matrix and its
    /// permutations when a reordering is configured — `build()` hands
    /// them to instantiation so the permutation is computed once.
    #[allow(clippy::type_complexity)]
    fn inspect(
        &self,
    ) -> anyhow::Result<(SpmvPlan, Option<(Csr<T>, ReorderState<T>)>)> {
        // --- configuration conflicts fail at inspection time. ---
        if let Some(k) = self.kernel {
            if self.candidates_set
                && !matches!(k, KernelKind::Hybrid | KernelKind::Tiled(_))
            {
                anyhow::bail!(
                    "explicit kernel {k} conflicts with candidates(..): \
                     the override leaves nothing to select (candidates \
                     only feed the hybrid/tiled panel compiler)"
                );
            }
            if matches!(k, KernelKind::Csr | KernelKind::Csr5)
                && self.tiling.is_some()
            {
                anyhow::bail!(
                    "tile_cols/tile_auto has no effect on the {k} \
                     baseline: it has no tiled form"
                );
            }
            if let Some(bs) = k.block_size() {
                bs.validate_for::<T>()?;
            }
        }
        let needs_panels = self.tiling.is_some()
            || matches!(
                self.kernel,
                Some(KernelKind::Hybrid | KernelKind::Tiled(_))
            );
        if needs_panels && (self.panel_rows == 0 || self.panel_rows % 8 != 0)
        {
            anyhow::bail!(
                "panel_rows must be a positive multiple of 8, got {}",
                self.panel_rows
            );
        }

        // Fingerprint the matrix the caller holds (pre-reorder): that
        // is what `from_plan` will be handed.
        let fingerprint = MatrixFingerprint::of(&self.csr);

        // Inspection sees the reordered shape (selection and panel
        // ranking must rank what conversion will convert); the permuted
        // matrix is returned so `build()` converts it directly instead
        // of permuting a second time.
        let pre: Option<(Csr<T>, ReorderState<T>)> = match self.reorder {
            None => None,
            Some(ReorderKind::Rcm) => {
                anyhow::ensure!(
                    self.csr.rows == self.csr.cols,
                    "RCM reordering needs a square matrix ({}x{} given)",
                    self.csr.rows,
                    self.csr.cols
                );
                let p = reorder::cuthill_mckee(&self.csr);
                let permuted = reorder::permute(&self.csr, &p, &p);
                let st = ReorderState::new(ReorderKind::Rcm, p.clone(), p);
                Some((permuted, st))
            }
            Some(ReorderKind::ColPack) => {
                let rows = Permutation::identity(self.csr.rows);
                let cols = reorder::column_pack(&self.csr);
                let permuted = reorder::permute(&self.csr, &rows, &cols);
                let st = ReorderState::new(ReorderKind::ColPack, rows, cols);
                Some((permuted, st))
            }
        };
        let csr_view: &Csr<T> = match &pre {
            Some((permuted, _)) => permuted,
            None => &self.csr,
        };

        // Kernel selection: override > predictor > β(1,8) default.
        let (kernel, predicted) = match self.kernel {
            Some(k) => (k, None),
            None => {
                let sel = self.records.and_then(|store| {
                    if self.threads > 1 {
                        select_parallel(
                            csr_view,
                            store,
                            &self.candidates,
                            self.threads,
                        )
                    } else {
                        select_sequential(csr_view, store, &self.candidates)
                    }
                });
                match sel {
                    Some(s) => (s.kernel, Some(s.predicted_gflops)),
                    None => (KernelKind::Beta(1, 8), None),
                }
            }
        };

        // Resolve the column tile width now, so instantiation does not
        // depend on the executing machine's detected cache. An inline
        // `tiled(n)` width wins over the builder's tiling setting;
        // `tiled` alone defers to it, defaulting to auto. A
        // predictor-selected baseline ignores the tiling lever (it has
        // no tiled form).
        let tile_cols: Option<usize> = match kernel {
            KernelKind::Tiled(w) => Some(if w > 0 {
                w as usize
            } else {
                self.tiling
                    .unwrap_or(TileCols::Auto)
                    .resolve::<T>(csr_view.cols)
            }),
            KernelKind::Beta(..)
            | KernelKind::BetaTest(..)
            | KernelKind::Hybrid => {
                self.tiling.map(|t| t.resolve::<T>(csr_view.cols))
            }
            KernelKind::Csr | KernelKind::Csr5 => None,
        };

        // Kernel-variant resolution: explicit override > machine tune
        // profile > none (instantiation then runs the process default).
        // Resolved here so the serialized plan pins the exact variant —
        // a tuned plan replayed by `from_plan` is bit-for-bit the same
        // build, profile file or not.
        let profile = match (&self.tune, &self.tune_profile) {
            (None, Some(path)) => {
                match crate::tuner::TuneProfile::load(path) {
                    Ok(p) => Some(p),
                    // A typo'd path stays a hard error; a corrupt
                    // profile was quarantined by `load` — degrade to
                    // the baseline variant with a recorded downgrade.
                    Err(e) if e.is_missing() => return Err(e.into()),
                    Err(e) => {
                        crate::util::durable::record_degrade(
                            crate::util::durable::DegradeEvent {
                                artifact: crate::tuner::TuneProfile::ARTIFACT
                                    .into(),
                                path: path.display().to_string(),
                                reason: e.to_string(),
                                fallback: "baseline variant".into(),
                            },
                        );
                        None
                    }
                }
            }
            _ => None,
        };
        let tune = self.tune.or_else(|| {
            profile.as_ref().and_then(|p| p.lookup(kernel, self.threads))
        });

        // Rank the hybrid panels and record the compiled schedule, so
        // instantiation needs neither records nor fitted surfaces.
        let mut schedule = match kernel {
            KernelKind::Hybrid | KernelKind::Tiled(_) => {
                let cfg = HybridConfig {
                    panel_rows: self.panel_rows,
                    candidates: hybrid_candidates::<T>(&self.candidates),
                    // Ask the schedule compiler for ≥ one segment per
                    // worker, else a homogeneous matrix merges into a
                    // single segment and parallelism collapses.
                    split: self.threads,
                };
                let kinds: Vec<KernelKind> =
                    std::iter::once(KernelKind::Csr)
                        .chain(cfg.candidates.iter().map(|bs| {
                            KernelKind::Beta(bs.r as u8, bs.c as u8)
                        }))
                        .collect();
                let models = self.records.map(|store| {
                    crate::predictor::select::fit_sequential(store, &kinds)
                });
                HybridMatrix::<T>::plan_schedule(
                    csr_view,
                    &cfg,
                    models.as_ref(),
                )?
            }
            _ => Vec::new(),
        };

        // Per-segment variants: a profile-planned hybrid schedule gives
        // each β segment the winner swept for *its* block size, not one
        // compromise variant for the whole matrix. (An explicit builder
        // override instead becomes the plan-level tune, which
        // instantiation fans out to every segment.)
        if let Some(prof) = &profile {
            for e in &mut schedule {
                if let crate::formats::hybrid::PanelKernel::Beta(bs) =
                    e.kernel
                {
                    e.tune = prof.lookup(
                        KernelKind::Beta(bs.r as u8, bs.c as u8),
                        self.threads,
                    );
                }
            }
        }

        Ok((
            SpmvPlan {
                version: PLAN_VERSION,
                fingerprint,
                kernel,
                threads: self.threads,
                numa_split: self.numa_split,
                reorder: self.reorder,
                panel_rows: self.panel_rows,
                tile_cols,
                predicted_gflops: predicted,
                tune,
                schedule,
            },
            pre,
        ))
    }

    /// Whether a cached plan can serve this builder configuration
    /// as-is (same runtime shape, and any explicit overrides agree).
    fn plan_compatible(&self, p: &SpmvPlan) -> bool {
        let tile_ok = match self.tiling {
            Some(TileCols::Fixed(n)) => p.tile_cols == Some(n),
            Some(TileCols::Auto) => p.tile_cols.is_some(),
            None => {
                matches!(p.kernel, KernelKind::Tiled(_))
                    || p.tile_cols.is_none()
            }
        };
        let kernel_ok = match self.kernel {
            None => true,
            Some(k) => k == p.kernel,
        };
        // An explicit variant override must match exactly; otherwise
        // any cached tuning decision (profile-planned or none) serves.
        let tune_ok = match self.tune {
            None => true,
            Some(t) => p.tune == Some(t),
        };
        p.numa_split == self.numa_split
            && p.reorder == self.reorder
            && p.panel_rows == self.panel_rows
            && kernel_ok
            && tile_ok
            && tune_ok
    }

    /// The plan `cache` would serve this builder, if any. Scans every
    /// entry for this matrix's fingerprint and thread count — distinct
    /// builder configurations coexist in one cache, so the first
    /// *compatible* plan wins, not the first fingerprint match.
    pub fn cached_plan(&self, cache: &PlanCache) -> Option<SpmvPlan> {
        let fp = MatrixFingerprint::of(&self.csr);
        cache
            .plans
            .iter()
            .find(|p| {
                p.fingerprint == fp
                    && p.threads == self.threads
                    && self.plan_compatible(p)
            })
            .cloned()
    }

    /// The executor half against an already-resolved plan: equivalent
    /// to [`SpmvEngine::from_plan`] with this builder's matrix (the
    /// plan's fingerprint guard applies). Lets callers snapshot a
    /// compatible plan out of a shared cache, drop the cache lock,
    /// and pay conversion and pool spawn outside it.
    pub fn build_from_plan(
        self,
        plan: &SpmvPlan,
    ) -> anyhow::Result<SpmvEngine<T>> {
        SpmvEngine::from_plan(self.csr, plan)
    }

    /// [`build`](Self::build) against an **in-memory** [`PlanCache`]:
    /// a hit skips inspection entirely, a miss plans and inserts the
    /// new plan into `cache` — the caller decides when (and whether)
    /// to persist. This is the multi-tenant registry's cold-start
    /// path, where one shared cache serves many matrices without a
    /// load/save round-trip per tenant.
    pub fn build_with_cache(
        self,
        cache: &mut PlanCache,
    ) -> anyhow::Result<SpmvEngine<T>> {
        match self.cached_plan(cache) {
            // External data: the schedule gets re-validated.
            Some(plan) => SpmvEngine::instantiate(self.csr, plan, None, false),
            None => {
                let (plan, pre) = self.inspect()?;
                cache.insert(plan.clone());
                SpmvEngine::instantiate(self.csr, plan, pre, true)
            }
        }
    }

    /// Inspect + instantiate: plans (or loads a cached plan) and
    /// converts the storage once, returning the ready engine.
    pub fn build(mut self) -> anyhow::Result<SpmvEngine<T>> {
        match self.plan_cache.take() {
            Some(path) => {
                // A corrupt cache was quarantined by `load`: degrade
                // to an empty cache, re-plan, and persist the
                // repaired store below — a poisoned file must not
                // take cold starts down with it.
                let mut cache = match PlanCache::load(&path) {
                    Ok(c) => c,
                    Err(e) => {
                        crate::util::durable::record_degrade(
                            crate::util::durable::DegradeEvent {
                                artifact: PlanCache::ARTIFACT.into(),
                                path: path.display().to_string(),
                                reason: e.to_string(),
                                fallback: "re-plan and persist repaired cache"
                                    .into(),
                            },
                        );
                        PlanCache::new()
                    }
                };
                let hit = self.cached_plan(&cache).is_some();
                let engine = self.build_with_cache(&mut cache)?;
                if !hit {
                    cache.save(&path)?;
                }
                Ok(engine)
            }
            None => {
                let (plan, pre) = self.inspect()?;
                SpmvEngine::instantiate(self.csr, plan, pre, true)
            }
        }
    }
}

/// β candidate sizes for the hybrid panel compiler: the builder's
/// candidate kernels filtered to sizes valid at this precision — or,
/// when the builder still holds the default f64 list, the precision's
/// own default set (so an f32 hybrid engine considers the 16-lane
/// sizes it has AVX-512 kernels for).
fn hybrid_candidates<T: Scalar>(kinds: &[KernelKind]) -> Vec<BlockSize> {
    if kinds == KernelKind::SPC5_KERNELS {
        return HybridConfig::for_scalar::<T>().candidates;
    }
    let mut sizes: Vec<BlockSize> = kinds
        .iter()
        .filter_map(|k| k.block_size())
        .filter(|bs| bs.validate_for::<T>().is_ok())
        .collect();
    sizes.sort_unstable();
    sizes.dedup();
    if sizes.is_empty() {
        HybridConfig::for_scalar::<T>().candidates
    } else {
        sizes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::suite;
    use crate::predictor::PerfRecord;

    #[test]
    fn explicit_kernel_used() {
        let csr = suite::poisson2d(16);
        let e = SpmvEngine::builder(csr)
            .kernel(KernelKind::Beta(4, 4))
            .build()
            .unwrap();
        assert_eq!(e.kernel(), KernelKind::Beta(4, 4));
    }

    #[test]
    fn defaults_to_1x8_without_records() {
        let csr = suite::poisson2d(8);
        let e = SpmvEngine::builder(csr).build().unwrap();
        assert_eq!(e.kernel(), KernelKind::Beta(1, 8));
        assert!(e.predicted_gflops().is_none());
    }

    #[test]
    fn serves_csr_and_csr5_baselines() {
        // The facade must dispatch the paper's own baselines (this used
        // to be a construction error).
        let csr = suite::poisson2d(14);
        let x: Vec<f64> = (0..csr.cols).map(|i| (i % 9) as f64 - 4.0).collect();
        let mut want = vec![0.0; csr.rows];
        csr.spmv_ref(&x, &mut want);
        for kernel in [KernelKind::Csr, KernelKind::Csr5] {
            for threads in [1usize, 3] {
                let e = SpmvEngine::builder(csr.clone())
                    .kernel(kernel)
                    .threads(threads)
                    .build()
                    .unwrap();
                assert_eq!(e.kernel(), kernel);
                let mut y = vec![0.0; csr.rows];
                e.spmv_into(&x, &mut y);
                crate::testkit::assert_close(
                    &y,
                    &want,
                    1e-9,
                    &format!("{kernel} t={threads}"),
                );
            }
        }
    }

    #[test]
    fn f32_engine_serves_wide_kernel() {
        let csr32: Csr<f32> = suite::poisson2d(12).to_precision();
        let e = SpmvEngine::builder(csr32.clone())
            .kernel(KernelKind::Beta(1, 16))
            .build()
            .unwrap();
        let x: Vec<f32> = (0..csr32.cols).map(|i| (i % 5) as f32 * 0.5).collect();
        let mut y = vec![0.0f32; csr32.rows];
        e.spmv_into(&x, &mut y);
        let mut want = vec![0.0f32; csr32.rows];
        csr32.spmv_ref(&x, &mut want);
        for i in 0..csr32.rows {
            assert!((y[i] - want[i]).abs() <= 2e-4 * want[i].abs().max(1.0));
        }
    }

    #[test]
    fn wide_kernel_rejected_for_f64() {
        let csr = suite::poisson2d(6);
        let err = SpmvEngine::builder(csr)
            .kernel(KernelKind::Beta(1, 16))
            .build();
        assert!(err.is_err(), "β(1,16) is f32-only");
    }

    #[test]
    fn predictor_drives_selection() {
        let csr = suite::dense(64, 3);
        let mut store = RecordStore::new();
        // Plant records that make β(4,8) the clear winner at high fill.
        for i in 0..12 {
            let avg = 1.0 + i as f64 * 3.0;
            store.push(PerfRecord {
                matrix: format!("m{i}"),
                kernel: KernelKind::Beta(4, 8),
                avg_nnz_per_block: avg,
                threads: 1,
                tile_cols: 0,
                tune: Default::default(),
                gflops: 0.5 + 0.1 * avg,
            });
            store.push(PerfRecord {
                matrix: format!("m{i}"),
                kernel: KernelKind::Beta(1, 8),
                avg_nnz_per_block: (1.0 + i as f64 * 0.6).min(8.0),
                threads: 1,
                tile_cols: 0,
                tune: Default::default(),
                gflops: 1.0,
            });
        }
        let e = SpmvEngine::builder(csr)
            .candidates(&[KernelKind::Beta(1, 8), KernelKind::Beta(4, 8)])
            .records(&store)
            .build()
            .unwrap();
        assert_eq!(e.kernel(), KernelKind::Beta(4, 8));
        assert!(e.predicted_gflops().unwrap() > 1.0);
    }

    #[test]
    fn engine_pool_exists_only_when_parallel() {
        let csr = suite::poisson2d(8);
        let seq = SpmvEngine::builder(csr.clone()).build().unwrap();
        assert!(seq.pool().is_none());
        let par =
            SpmvEngine::builder(csr.clone()).threads(3).build().unwrap();
        assert_eq!(par.pool().unwrap().n_threads(), 3);
        // CSR5 is sequential by construction: no idle parked workers
        // even when threads are requested.
        let csr5 = SpmvEngine::builder(csr)
            .kernel(KernelKind::Csr5)
            .threads(4)
            .build()
            .unwrap();
        assert!(csr5.pool().is_none());
    }

    #[test]
    fn spmm_matches_k_single_spmvs_across_storages() {
        let csr = suite::fem_blocked(260, 3, 5, 9);
        let mut rng = crate::util::Rng::new(77);
        for k in [2usize, 3, 8] {
            let x: Vec<f64> = (0..csr.cols * k)
                .map(|_| rng.range_f64(-1.0, 1.0))
                .collect();
            for (kernel, threads) in [
                (KernelKind::Beta(2, 8), 1usize),
                (KernelKind::Beta(2, 8), 4),
                (KernelKind::Csr, 3),
                (KernelKind::Csr5, 1),
            ] {
                let e = SpmvEngine::builder(csr.clone())
                    .kernel(kernel)
                    .threads(threads)
                    .build()
                    .unwrap();
                let mut y = vec![0.0; csr.rows * k];
                e.spmm_into(&x, &mut y, k);
                // Oracle: k independent single-vector engine calls.
                for j in 0..k {
                    let xj: Vec<f64> =
                        (0..csr.cols).map(|c| x[c * k + j]).collect();
                    let mut want = vec![0.0; csr.rows];
                    e.spmv_into(&xj, &mut want);
                    for r in 0..csr.rows {
                        assert!(
                            (y[r * k + j] - want[r]).abs()
                                <= 1e-9 * want[r].abs().max(1.0),
                            "{kernel} t={threads} k={k} j={j} row {r}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn hybrid_engine_matches_reference_seq_and_par() {
        let csr = suite::mixed_band_scatter(2_048, 5);
        let x: Vec<f64> = (0..csr.cols).map(|i| (i % 9) as f64 - 4.0).collect();
        let mut want = vec![0.0; csr.rows];
        csr.spmv_ref(&x, &mut want);
        for threads in [1usize, 3] {
            let e = SpmvEngine::builder(csr.clone())
                .kernel(KernelKind::Hybrid)
                .panel_rows(128)
                .threads(threads)
                .build()
                .unwrap();
            assert_eq!(e.kernel(), KernelKind::Hybrid);
            let hm = e.hybrid().expect("hybrid storage");
            hm.validate().unwrap();
            assert!(hm.n_segments() >= 2, "mixed matrix should split");
            let mut y = vec![0.0; csr.rows];
            e.spmv_into(&x, &mut y);
            crate::testkit::assert_close(
                &y,
                &want,
                1e-9,
                &format!("hybrid t={threads}"),
            );
        }
    }

    #[test]
    fn hybrid_engine_spmm_matches_k_spmvs() {
        let csr = suite::mixed_band_scatter(1_536, 11);
        let k = 4usize;
        let mut rng = crate::util::Rng::new(3);
        let x: Vec<f64> =
            (0..csr.cols * k).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        for threads in [1usize, 3] {
            let e = SpmvEngine::builder(csr.clone())
                .kernel(KernelKind::Hybrid)
                .panel_rows(64)
                .threads(threads)
                .build()
                .unwrap();
            let mut y = vec![0.0; csr.rows * k];
            e.spmm_into(&x, &mut y, k);
            for j in 0..k {
                let xj: Vec<f64> =
                    (0..csr.cols).map(|c| x[c * k + j]).collect();
                let mut want = vec![0.0; csr.rows];
                e.spmv_into(&xj, &mut want);
                for r in 0..csr.rows {
                    assert!(
                        (y[r * k + j] - want[r]).abs()
                            <= 1e-9 * want[r].abs().max(1.0),
                        "t={threads} j={j} row {r}"
                    );
                }
            }
        }
    }

    #[test]
    fn hybrid_rejects_bad_panel_rows() {
        let csr = suite::poisson2d(8);
        let err = SpmvEngine::builder(csr)
            .kernel(KernelKind::Hybrid)
            .panel_rows(12)
            .build();
        assert!(err.is_err(), "panel_rows=12 must be rejected");
    }

    #[test]
    fn reorder_preserves_spmv_and_spmm_semantics() {
        use crate::matrix::ReorderKind;
        // Shuffled structured matrix: reordering changes the internal
        // layout, but engine products must stay in the caller's index
        // space for every kernel class.
        let m = suite::quantum_clusters(400, 3, 8, 6, 5);
        let mut rng = crate::util::Rng::new(2);
        let mut perm: Vec<u32> = (0..m.rows as u32).collect();
        rng.shuffle(&mut perm);
        let p = crate::matrix::reorder::Permutation { perm };
        let csr = crate::matrix::reorder::permute(&m, &p, &p);

        let x: Vec<f64> = (0..csr.cols).map(|i| (i % 7) as f64 - 3.0).collect();
        let mut want = vec![0.0; csr.rows];
        csr.spmv_ref(&x, &mut want);
        for kind in [ReorderKind::Rcm, ReorderKind::ColPack] {
            for kernel in [
                KernelKind::Beta(2, 4),
                KernelKind::Csr,
                KernelKind::Hybrid,
                KernelKind::Tiled(128),
            ] {
                let e = SpmvEngine::builder(csr.clone())
                    .kernel(kernel)
                    .reorder(kind)
                    .panel_rows(64)
                    .build()
                    .unwrap();
                assert_eq!(e.reorder_kind(), Some(kind));
                let mut y = vec![0.0; csr.rows];
                e.spmv_into(&x, &mut y);
                crate::testkit::assert_close(
                    &y,
                    &want,
                    1e-9,
                    &format!("{kind} {kernel}"),
                );
                // spmm path under reordering.
                let k = 3usize;
                let xk: Vec<f64> = (0..csr.cols * k)
                    .map(|i| ((i * 5) % 13) as f64 * 0.25 - 1.5)
                    .collect();
                let mut yk = vec![0.0; csr.rows * k];
                e.spmm_into(&xk, &mut yk, k);
                for j in 0..k {
                    let xj: Vec<f64> =
                        (0..csr.cols).map(|c| xk[c * k + j]).collect();
                    let mut wj = vec![0.0; csr.rows];
                    csr.spmv_ref(&xj, &mut wj);
                    for r in 0..csr.rows {
                        assert!(
                            (yk[r * k + j] - wj[r]).abs()
                                <= 1e-9 * wj[r].abs().max(1.0),
                            "{kind} {kernel} spmm j={j} row {r}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn rcm_reorder_requires_square() {
        use crate::matrix::ReorderKind;
        let csr = suite::rect_runs(40, 400, 3, 20, 1);
        assert!(SpmvEngine::builder(csr.clone())
            .reorder(ReorderKind::Rcm)
            .build()
            .is_err());
        // The pure inspection phase rejects it too.
        assert!(SpmvEngine::builder(csr.clone())
            .reorder(ReorderKind::Rcm)
            .plan()
            .is_err());
        // Column packing has no squareness requirement.
        SpmvEngine::builder(csr)
            .reorder(ReorderKind::ColPack)
            .kernel(KernelKind::Csr)
            .build()
            .unwrap();
    }

    #[test]
    fn reorder_improves_fill_on_shuffled_band() {
        use crate::matrix::ReorderKind;
        // RCM at build time must improve the β(2,8) fill the engine
        // sees (the reason to wire reordering into the engine at all).
        let band = suite::banded(600, 6, 1.0, 3);
        let mut rng = crate::util::Rng::new(7);
        let mut perm: Vec<u32> = (0..600).collect();
        rng.shuffle(&mut perm);
        let p = crate::matrix::reorder::Permutation { perm };
        let shuffled = crate::matrix::reorder::permute(&band, &p, &p);
        let bs = crate::formats::BlockSize::new(2, 8);
        let fill_before =
            crate::formats::stats::block_stats(&shuffled, bs).avg_nnz_per_block;
        let e = SpmvEngine::builder(shuffled)
            .kernel(KernelKind::Beta(2, 8))
            .reorder(ReorderKind::Rcm)
            .build()
            .unwrap();
        let fill_after =
            crate::formats::stats::block_stats(e.csr(), bs).avg_nnz_per_block;
        assert!(
            fill_after > fill_before * 1.2,
            "RCM should recover fill: {fill_before:.2} -> {fill_after:.2}"
        );
    }

    #[test]
    fn tiled_kernel_matches_reference_seq_and_par() {
        let csr = suite::mixed_band_scatter(2_048, 5);
        let x: Vec<f64> = (0..csr.cols).map(|i| (i % 9) as f64 - 4.0).collect();
        let mut want = vec![0.0; csr.rows];
        csr.spmv_ref(&x, &mut want);
        for threads in [1usize, 3] {
            for kernel in [KernelKind::Tiled(0), KernelKind::Tiled(256)] {
                let e = SpmvEngine::builder(csr.clone())
                    .kernel(kernel)
                    .panel_rows(128)
                    .threads(threads)
                    .build()
                    .unwrap();
                assert_eq!(e.kernel(), kernel);
                let th = e.tiled_hybrid().expect("tiled hybrid storage");
                th.validate().unwrap();
                let want_tile = match kernel {
                    KernelKind::Tiled(0) => {
                        crate::formats::auto_tile_cols::<f64>(csr.cols)
                    }
                    KernelKind::Tiled(w) => w as usize,
                    _ => unreachable!(),
                };
                assert_eq!(e.tile_cols(), Some(want_tile));
                let mut y = vec![0.0; csr.rows];
                e.spmv_into(&x, &mut y);
                crate::testkit::assert_close(
                    &y,
                    &want,
                    1e-9,
                    &format!("{kernel} t={threads}"),
                );
            }
        }
    }

    #[test]
    fn tiled_beta_builder_matches_flat_engine() {
        let csr = suite::fem_blocked(400, 3, 6, 21);
        let x: Vec<f64> = (0..csr.cols).map(|i| (i % 7) as f64 - 3.0).collect();
        let mut want = vec![0.0; csr.rows];
        csr.spmv_ref(&x, &mut want);
        for kernel in [KernelKind::Beta(2, 8), KernelKind::BetaTest(2, 4)] {
            for threads in [1usize, 4] {
                let e = SpmvEngine::builder(csr.clone())
                    .kernel(kernel)
                    .tile_cols(96)
                    .panel_rows(64)
                    .threads(threads)
                    .build()
                    .unwrap();
                assert_eq!(e.tile_cols(), Some(96));
                let tm = e.tiled().expect("tiled β storage");
                tm.validate().unwrap();
                let mut y = vec![0.0; csr.rows];
                e.spmv_into(&x, &mut y);
                crate::testkit::assert_close(
                    &y,
                    &want,
                    1e-9,
                    &format!("tiled {kernel} t={threads}"),
                );
            }
        }
        // Baselines have no tiled form: requesting one is a plan-time
        // configuration error, not a silent no-op (this used to be
        // ignored).
        for kernel in [KernelKind::Csr, KernelKind::Csr5] {
            let err = SpmvEngine::builder(csr.clone())
                .kernel(kernel)
                .tile_cols(96)
                .build();
            assert!(err.is_err(), "{kernel} must reject tile_cols");
        }
        // tile_cols(0) spells auto, consistently with `tiled(0)`.
        let e = SpmvEngine::builder(csr.clone())
            .kernel(KernelKind::Beta(2, 8))
            .tile_cols(0)
            .build()
            .unwrap();
        assert_eq!(
            e.tile_cols(),
            Some(crate::formats::auto_tile_cols::<f64>(csr.cols))
        );
    }

    #[test]
    fn tiled_engine_spmm_matches_k_spmvs() {
        let csr = suite::mixed_band_scatter(1_536, 11);
        let k = 4usize;
        let mut rng = crate::util::Rng::new(5);
        let x: Vec<f64> =
            (0..csr.cols * k).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        for threads in [1usize, 3] {
            let e = SpmvEngine::builder(csr.clone())
                .kernel(KernelKind::Tiled(192))
                .panel_rows(64)
                .threads(threads)
                .build()
                .unwrap();
            let mut y = vec![0.0; csr.rows * k];
            e.spmm_into(&x, &mut y, k);
            for j in 0..k {
                let xj: Vec<f64> =
                    (0..csr.cols).map(|c| x[c * k + j]).collect();
                let mut want = vec![0.0; csr.rows];
                e.spmv_into(&xj, &mut want);
                for r in 0..csr.rows {
                    assert!(
                        (y[r * k + j] - want[r]).abs()
                            <= 1e-9 * want[r].abs().max(1.0),
                        "t={threads} j={j} row {r}"
                    );
                }
            }
        }
    }

    #[test]
    fn baseline_spmm_reuses_engine_scratch() {
        // The CSR fallback must keep working when spmm is called twice
        // with different k (scratch is resized, not assumed fresh).
        let csr = suite::poisson2d(12);
        let e = SpmvEngine::builder(csr.clone())
            .kernel(KernelKind::Csr)
            .build()
            .unwrap();
        for k in [3usize, 2, 5] {
            let x: Vec<f64> = (0..csr.cols * k)
                .map(|i| ((i * 7) % 13) as f64 * 0.5 - 3.0)
                .collect();
            let mut y = vec![0.0; csr.rows * k];
            e.spmm_into(&x, &mut y, k);
            for j in 0..k {
                let xj: Vec<f64> =
                    (0..csr.cols).map(|c| x[c * k + j]).collect();
                let mut want = vec![0.0; csr.rows];
                csr.spmv_ref(&xj, &mut want);
                for r in 0..csr.rows {
                    assert!(
                        (y[r * k + j] - want[r]).abs()
                            <= 1e-9 * want[r].abs().max(1.0),
                        "k={k} j={j} row {r}"
                    );
                }
            }
        }
    }

    #[test]
    fn engine_spmv_matches_reference_seq_and_par() {
        let csr = suite::fem_blocked(300, 3, 5, 17);
        let x: Vec<f64> = (0..csr.cols).map(|i| (i % 7) as f64 - 3.0).collect();
        let mut want = vec![0.0; csr.rows];
        csr.spmv_ref(&x, &mut want);
        for threads in [1usize, 4] {
            for numa in [false, true] {
                let e = SpmvEngine::builder(csr.clone())
                    .threads(threads)
                    .numa_split(numa)
                    .kernel(KernelKind::Beta(2, 8))
                    .build()
                    .unwrap();
                let mut y = vec![0.0; csr.rows];
                e.spmv_into(&x, &mut y);
                crate::testkit::assert_close(
                    &y,
                    &want,
                    1e-9,
                    &format!("t={threads} numa={numa}"),
                );
            }
        }
    }

    #[test]
    fn kernel_and_candidates_conflict() {
        let csr = suite::poisson2d(8);
        // A non-hybrid explicit kernel leaves nothing to select.
        let err = SpmvEngine::builder(csr.clone())
            .kernel(KernelKind::Beta(2, 8))
            .candidates(&[KernelKind::Beta(1, 8)])
            .build();
        assert!(err.is_err(), "kernel + candidates must conflict");
        // Hybrid/tiled kernels legitimately consume candidates (the
        // panel compiler selects per panel).
        SpmvEngine::builder(csr.clone())
            .kernel(KernelKind::Hybrid)
            .candidates(&[KernelKind::Beta(1, 8), KernelKind::Beta(2, 8)])
            .build()
            .unwrap();
        SpmvEngine::builder(csr)
            .kernel(KernelKind::Tiled(128))
            .candidates(&[KernelKind::Beta(1, 8)])
            .build()
            .unwrap();
    }

    #[test]
    fn storage_reports_kernel_kind() {
        // The unified storage agrees with the plan across classes.
        let csr = suite::mixed_band_scatter(1_024, 7);
        for kernel in [
            KernelKind::Csr,
            KernelKind::Csr5,
            KernelKind::Beta(2, 4),
            KernelKind::BetaTest(2, 4),
            KernelKind::Hybrid,
        ] {
            let e = SpmvEngine::builder(csr.clone())
                .kernel(kernel)
                .build()
                .unwrap();
            assert_eq!(e.storage().kernel_kind(), kernel, "{kernel}");
            e.storage().validate().unwrap();
        }
    }

    #[test]
    fn tuned_build_is_bit_identical_to_default() {
        // Every variant reorders only *when* streams are touched, never
        // the FMA order — tuned engines must agree with the default
        // build to the last bit, across kernel classes and runtimes.
        let csr = suite::mixed_band_scatter(1_024, 7);
        let x: Vec<f64> = (0..csr.cols).map(|i| (i % 9) as f64 - 4.0).collect();
        for kernel in [
            KernelKind::Beta(2, 8),
            KernelKind::Hybrid,
            KernelKind::Tiled(192),
        ] {
            for threads in [1usize, 3] {
                let base = SpmvEngine::builder(csr.clone())
                    .kernel(kernel)
                    .panel_rows(64)
                    .threads(threads)
                    .build()
                    .unwrap();
                let mut want = vec![0.0; csr.rows];
                base.spmv_into(&x, &mut want);
                for &t in &crate::kernels::VARIANT_TABLE {
                    let e = SpmvEngine::builder(csr.clone())
                        .kernel(kernel)
                        .panel_rows(64)
                        .threads(threads)
                        .tune(t)
                        .build()
                        .unwrap();
                    assert_eq!(e.plan().tune, Some(t));
                    let mut y = vec![0.0; csr.rows];
                    e.spmv_into(&x, &mut y);
                    assert_eq!(
                        y,
                        want,
                        "variant {} {kernel} t={threads} diverged",
                        t.label()
                    );
                }
            }
        }
    }

    #[test]
    fn tuned_plan_round_trips_through_from_plan() {
        // plan() → JSON → from_plan must reproduce the tuned build
        // exactly: plan-level tune, fanned-out segment tunes and all.
        let csr = suite::mixed_band_scatter(1_024, 7);
        let t = crate::kernels::VARIANT_TABLE[3];
        let b = SpmvEngine::builder(csr.clone())
            .kernel(KernelKind::Hybrid)
            .panel_rows(64)
            .tune(t);
        let plan = b.plan().unwrap();
        assert_eq!(plan.tune, Some(t));
        let text = plan.to_json();
        let back = SpmvPlan::from_json(&text).unwrap();
        let e = SpmvEngine::from_plan(csr.clone(), &back).unwrap();
        assert_eq!(e.plan().tune, Some(t));
        // Instantiation fans the plan-level variant out to every
        // segment, so the engine's reported schedule is explicit.
        assert!(e.plan().schedule.iter().all(|s| s.tune == Some(t)));
        let x: Vec<f64> = (0..csr.cols).map(|i| (i % 5) as f64 - 2.0).collect();
        let mut want = vec![0.0; csr.rows];
        csr.spmv_ref(&x, &mut want);
        let mut y = vec![0.0; csr.rows];
        e.spmv_into(&x, &mut y);
        crate::testkit::assert_close(&y, &want, 1e-9, "tuned from_plan");
    }
}
