//! Sharded serving front-end: one matrix, many engines.
//!
//! [`ShardedService`] row-partitions a matrix into nnz-balanced
//! shards (via [`crate::parallel::balanced_row_ranges`] over the CSR
//! row pointer), builds an independent [`SpmvEngine`] — its own
//! kernel storage, worker pool and optional NUMA-local arrays — per
//! shard, and runs one [`SpmvService`] dispatcher per shard. A
//! request is admitted **once** at the front-end's [`AdmissionGate`],
//! fanned out to every shard, and the per-shard `y` slices are
//! concatenated back into one response on receive.
//!
//! ```text
//!            submit(x)                 recv() → y
//!               │                          ▲
//!        AdmissionGate (capacity,      fan-in: concat
//!        Block/Reject/Timeout)         y₀ ‖ y₁ ‖ … ‖ yₙ
//!               │                          │
//!       ┌───────┼──────────┐       ┌───────┼──────────┐
//!       ▼       ▼          ▼       │       │          │
//!   shard 0  shard 1 …  shard n    │       │          │
//!   rows     rows          rows    │       │          │
//!   [0,r₁)   [r₁,r₂)    [rₙ,rows)  │       │          │
//!   engine₀  engine₁    engineₙ ───┴───────┴──────────┘
//! ```
//!
//! Shard boundaries are aligned to the 8-row β interval, so each
//! shard's block structure is exactly the full matrix's restricted to
//! its rows — the sharded product is **bit-identical** to the
//! single-engine one for the same kernel configuration.
//!
//! Per-shard queues use `Block` at the gate's capacity: because the
//! gate already bounds cluster-wide in-flight requests to that same
//! capacity, shard queues can never fill, so the fan-out never blocks
//! or rejects mid-request (no partially-admitted requests). The
//! fan-out loop itself is serialized by a mutex so concurrent
//! submitters cannot interleave differently across shards — the
//! in-order fan-in depends on every shard seeing the same request
//! order. A shard failure mid-fan-out poisons the whole service
//! (gate and every shard close), so later calls report `Stopped`
//! rather than assembling responses from different requests.

use super::engine::SpmvEngine;
use super::service::{
    LatencyPercentiles, RecvTimeoutError, Request, Response, ServiceError,
    ServiceStats, SpmvService,
};
use super::serving::{AdmissionGate, PushError, QueuePolicy};
use crate::kernels::KernelKind;
use crate::matrix::Csr;
use crate::parallel::balanced_row_ranges;
use crate::scalar::Scalar;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Shard-boundary alignment: the β formats group rows into 8-row
/// intervals and form blocks jointly across an interval, so cuts on
/// this boundary preserve the full matrix's block partitioning.
pub const SHARD_ROW_ALIGN: usize = 8;

/// How to cut and drive the shards.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardConfig {
    /// Requested shard count (the effective count can be lower for
    /// tiny matrices; see [`ShardedService::n_shards`]).
    pub shards: usize,
    /// Worker threads per shard engine (1 = sequential shard).
    pub threads_per_shard: usize,
    /// First-touch NUMA placement inside each shard's pool.
    pub numa_split: bool,
    /// Kernel for every shard; `None` lets each shard's inspector
    /// choose (may differ per shard — pin a kernel when bit-identical
    /// results against a single engine are required).
    pub kernel: Option<KernelKind>,
    /// Per-shard micro-batching limit (as [`SpmvService::start`]).
    pub max_batch: usize,
    /// Front-end admission policy (capacity + overflow behavior).
    pub queue: QueuePolicy,
}

impl Default for ShardConfig {
    fn default() -> Self {
        ShardConfig {
            shards: 2,
            threads_per_shard: 1,
            numa_split: false,
            kernel: None,
            max_batch: 8,
            queue: QueuePolicy::default(),
        }
    }
}

/// Cluster-level statistics: per-shard snapshots plus front-end
/// admission counters.
#[derive(Clone, Debug)]
pub struct ClusterStats {
    /// Fully assembled responses handed to clients.
    pub served: usize,
    /// Requests refused at the admission gate.
    pub rejected: usize,
    /// Highest cluster-wide in-flight count (≤ capacity).
    pub in_flight_high_water: usize,
    /// One [`ServiceStats`] per shard, in row order.
    pub shards: Vec<ServiceStats>,
}

impl ClusterStats {
    /// Collapses the per-shard stats into one service-shaped view:
    /// counters are summed, latency percentiles take the **max**
    /// across shards (a request completes when its slowest shard
    /// does, so the max is the conservative critical-path estimate),
    /// and the queue-depth high-water is the front-end gate's.
    pub fn rollup(&self) -> ServiceStats {
        let mut batches = 0usize;
        let mut batch_hist: Vec<usize> = Vec::new();
        let mut total = LatencyPercentiles::default();
        let mut queue = LatencyPercentiles::default();
        let mut compute = LatencyPercentiles::default();
        for s in &self.shards {
            batches += s.batches;
            for (i, &c) in s.batch_hist.iter().enumerate() {
                if batch_hist.len() <= i {
                    batch_hist.resize(i + 1, 0);
                }
                batch_hist[i] += c;
            }
            total = max_pct(
                total,
                LatencyPercentiles {
                    p50_s: s.p50_s,
                    p95_s: s.p95_s,
                    p99_s: s.p99_s,
                },
            );
            queue = max_pct(queue, s.queue);
            compute = max_pct(compute, s.compute);
        }
        ServiceStats {
            served: self.served,
            rejected: self.rejected,
            batches,
            p50_s: total.p50_s,
            p95_s: total.p95_s,
            p99_s: total.p99_s,
            queue,
            compute,
            queue_depth_high_water: self.in_flight_high_water,
            batch_hist,
        }
    }
}

fn max_pct(a: LatencyPercentiles, b: LatencyPercentiles) -> LatencyPercentiles {
    LatencyPercentiles {
        p50_s: a.p50_s.max(b.p50_s),
        p95_s: a.p95_s.max(b.p95_s),
        p99_s: a.p99_s.max(b.p99_s),
    }
}

/// A partially assembled fan-in: per-shard responses collected so far
/// for the oldest outstanding request. Survives a `recv_timeout`
/// deadline so a later receive resumes where it stopped.
struct PartialFanIn<T: Scalar> {
    parts: Vec<Option<Response<T>>>,
}

/// The sharded front-end (see module docs). `Sync`: submissions and
/// receives may come from different threads; concurrent receivers
/// serialize on the fan-in state.
pub struct ShardedService<T: Scalar = f64> {
    shards: Vec<SpmvService<T>>,
    /// `row_bounds[i]..row_bounds[i+1]` = shard `i`'s rows.
    row_bounds: Vec<usize>,
    gate: AdmissionGate,
    rows: usize,
    cols: usize,
    /// Serializes the fan-out loop: every shard queue must see
    /// requests in the same order, because the in-order fan-in pairs
    /// each shard's next response with the oldest request. Without
    /// this, two concurrent submitters could interleave differently
    /// across shards and `recv` would concatenate `y` slices from
    /// different requests.
    fan_out: Mutex<()>,
    partial: Mutex<PartialFanIn<T>>,
    assembled: AtomicUsize,
    rejected: AtomicUsize,
}

impl<T: Scalar> ShardedService<T> {
    /// Cuts `csr` into at most `cfg.shards` row shards (8-row-aligned,
    /// nnz-balanced, empty shards dropped), builds one engine and one
    /// dispatcher per shard, and opens the admission gate.
    pub fn start(
        csr: Csr<T>,
        cfg: ShardConfig,
    ) -> anyhow::Result<ShardedService<T>> {
        anyhow::ensure!(cfg.shards >= 1, "shard count must be >= 1");
        anyhow::ensure!(cfg.max_batch >= 1, "max_batch must be >= 1");
        anyhow::ensure!(csr.rows > 0, "cannot shard an empty matrix");
        let (rows, cols) = (csr.rows, csr.cols);

        let ranges =
            balanced_row_ranges(&csr.rowptr, cfg.shards, SHARD_ROW_ALIGN);
        let mut shards = Vec::with_capacity(ranges.len());
        let mut row_bounds = Vec::with_capacity(ranges.len() + 1);
        row_bounds.push(0usize);
        for &(r0, r1) in &ranges {
            let sub = csr.row_slice(r0, r1);
            let mut builder = SpmvEngine::builder(sub)
                .threads(cfg.threads_per_shard)
                .numa_split(cfg.numa_split);
            if let Some(kernel) = cfg.kernel {
                builder = builder.kernel(kernel);
            }
            let engine = builder.build()?;
            // Block at the gate's capacity: the gate admits at most
            // `capacity` cluster-wide, so these queues never fill and
            // a fan-out submit can never block or reject.
            shards.push(SpmvService::start_with_policy(
                engine,
                cfg.max_batch,
                QueuePolicy::Block { capacity: cfg.queue.capacity() },
            ));
            row_bounds.push(r1);
        }
        let n = shards.len();
        Ok(ShardedService {
            shards,
            row_bounds,
            gate: AdmissionGate::new(cfg.queue),
            rows,
            cols,
            fan_out: Mutex::new(()),
            partial: Mutex::new(PartialFanIn { parts: (0..n).map(|_| None).collect() }),
            assembled: AtomicUsize::new(0),
            rejected: AtomicUsize::new(0),
        })
    }

    /// Effective shard count (≤ the configured one for tiny matrices).
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Row boundaries: shard `i` serves rows
    /// `row_bounds()[i]..row_bounds()[i+1]`.
    pub fn row_bounds(&self) -> &[usize] {
        &self.row_bounds
    }

    /// Rows of the full served matrix.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Columns of the full served matrix.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// The front-end admission policy.
    pub fn policy(&self) -> QueuePolicy {
        self.gate.policy()
    }

    /// Fully assembled responses handed to clients so far.
    pub fn served(&self) -> usize {
        self.assembled.load(Ordering::Relaxed)
    }

    /// Requests refused at the admission gate so far.
    pub fn rejected(&self) -> usize {
        self.rejected.load(Ordering::Relaxed)
    }

    /// Admits the request at the front-end gate, then fans it out to
    /// every shard. Exactly one admission decision per request: by the
    /// time the gate says yes, no shard queue can be full.
    pub fn submit(&self, req: Request<T>) -> Result<(), ServiceError> {
        if req.x.len() != self.cols {
            return Err(ServiceError::ShapeMismatch {
                expected: self.cols,
                got: req.x.len(),
            });
        }
        match self.gate.acquire() {
            Ok(()) => {}
            Err(PushError::Full) => {
                self.rejected.fetch_add(1, Ordering::Relaxed);
                return Err(ServiceError::Overloaded {
                    capacity: self.gate.capacity(),
                });
            }
            Err(PushError::Closed) => return Err(ServiceError::Stopped),
        }
        let Request { id, mut x } = req;
        let n = self.shards.len();
        // One submitter fans out at a time (see the `fan_out` field
        // docs). The critical section is short: shard queues run
        // `Block` at the gate's capacity and the gate already bounds
        // in-flight to that capacity, so no shard submit can block.
        let serialized =
            self.fan_out.lock().unwrap_or_else(|e| e.into_inner());
        for (i, shard) in self.shards.iter().enumerate() {
            // The last shard takes ownership; earlier ones clone.
            let part =
                if i + 1 == n { std::mem::take(&mut x) } else { x.clone() };
            if let Err(e) = shard.submit(Request { id, x: part }) {
                // A shard dispatcher died (kernel panic) mid-fan-out:
                // earlier shards hold this request while later ones
                // never saw it, so the per-shard response streams can
                // never agree again. Poison the whole service — close
                // the gate and every shard — so subsequent submits
                // and receives report `Stopped` instead of assembling
                // responses that belong to different requests.
                self.gate.close();
                for s in &self.shards {
                    s.close();
                }
                drop(serialized);
                return Err(e);
            }
        }
        drop(serialized);
        Ok(())
    }

    /// Blocks for the next fully assembled response.
    pub fn recv(&self) -> Option<Response<T>> {
        self.recv_deadline(None).ok()
    }

    /// Waits up to `wait` for the next fully assembled response. On
    /// timeout the per-shard responses gathered so far are kept; a
    /// later receive resumes the assembly — nothing is lost.
    pub fn recv_timeout(
        &self,
        wait: Duration,
    ) -> Result<Response<T>, RecvTimeoutError> {
        self.recv_deadline(Instant::now().checked_add(wait))
    }

    /// Fan-in: one response per shard, in shard order, assembled into
    /// the full-length `y`. Per-shard dispatchers answer in submission
    /// order, so the next response of every shard belongs to the
    /// oldest unassembled request.
    fn recv_deadline(
        &self,
        deadline: Option<Instant>,
    ) -> Result<Response<T>, RecvTimeoutError> {
        let mut partial =
            self.partial.lock().unwrap_or_else(|e| e.into_inner());
        for (i, shard) in self.shards.iter().enumerate() {
            if partial.parts[i].is_some() {
                continue;
            }
            let resp = match deadline {
                None => shard.recv().ok_or(RecvTimeoutError::Stopped)?,
                Some(dl) => {
                    let left = dl.saturating_duration_since(Instant::now());
                    // A zero budget degrades to a try-recv; collected
                    // parts stay in `partial` when this errs out.
                    shard.recv_timeout(left)?
                }
            };
            partial.parts[i] = Some(resp);
        }
        let parts: Vec<Response<T>> = partial
            .parts
            .iter_mut()
            .map(|p| p.take().expect("all shards answered"))
            .collect();
        drop(partial);

        let id = parts[0].id;
        // Release-build check, not a debug_assert: a desynchronized
        // fan-in must fail loudly rather than silently hand back a `y`
        // stitched from different requests. Unreachable with the
        // serialized fan-out and the poison-on-partial-fan-out path.
        assert!(
            parts.iter().all(|p| p.id == id),
            "shard fan-in desynchronized"
        );
        let mut y = Vec::with_capacity(self.rows);
        let mut queue_s = 0.0f64;
        let mut compute_s = 0.0f64;
        for p in parts {
            y.extend_from_slice(&p.y);
            // A request is as slow as its slowest shard.
            queue_s = queue_s.max(p.queue_s);
            compute_s = compute_s.max(p.compute_s);
        }
        self.gate.release();
        self.assembled.fetch_add(1, Ordering::Relaxed);
        Ok(Response { id, y, latency_s: queue_s + compute_s, queue_s, compute_s })
    }

    /// Cluster-level snapshot: admission counters plus one
    /// [`ServiceStats`] per shard (see [`ClusterStats::rollup`]).
    pub fn stats(&self) -> ClusterStats {
        ClusterStats {
            served: self.served(),
            rejected: self.rejected(),
            in_flight_high_water: self.gate.high_water(),
            shards: self.shards.iter().map(|s| s.stats()).collect(),
        }
    }

    /// Graceful shutdown: closes the gate (blocked submitters wake
    /// with [`ServiceError::Stopped`]), drains every shard and returns
    /// the number of requests every shard completed.
    pub fn shutdown(self) -> usize {
        self.shutdown_ref()
    }

    /// [`shutdown`](Self::shutdown) through a shared reference — for
    /// services shared via `Arc` (the tenant registry). Idempotent.
    pub fn shutdown_ref(&self) -> usize {
        self.gate.close();
        let mut served = 0usize;
        for (i, shard) in self.shards.iter().enumerate() {
            let n = shard.shutdown_ref();
            // Every fully fanned-out request reached every shard, so
            // the per-shard counts agree (barring a poisoned partial
            // fan-out, where shard 0's count is the upper bound);
            // report shard 0's.
            if i == 0 {
                served = n;
            }
        }
        served
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::suite;

    fn small_cfg(shards: usize) -> ShardConfig {
        ShardConfig {
            shards,
            kernel: Some(KernelKind::Beta(1, 8)),
            queue: QueuePolicy::Block { capacity: 64 },
            ..ShardConfig::default()
        }
    }

    #[test]
    fn sharded_service_serves_correct_results() {
        let csr = suite::fem_blocked(400, 3, 5, 3);
        let service =
            ShardedService::start(csr.clone(), small_cfg(3)).unwrap();
        assert!(service.n_shards() >= 2, "matrix large enough to shard");
        assert_eq!(service.row_bounds()[0], 0);
        assert_eq!(*service.row_bounds().last().unwrap(), csr.rows);

        for id in 0..12u64 {
            let x: Vec<f64> = (0..csr.cols)
                .map(|i| ((i as u64 + 3 * id) % 17) as f64 * 0.25)
                .collect();
            service.submit(Request { id, x }).unwrap();
        }
        for _ in 0..12 {
            let resp = service.recv().expect("assembled response");
            assert_eq!(resp.y.len(), csr.rows);
            let x: Vec<f64> = (0..csr.cols)
                .map(|i| ((i as u64 + 3 * resp.id) % 17) as f64 * 0.25)
                .collect();
            let mut want = vec![0.0; csr.rows];
            csr.spmv_ref(&x, &mut want);
            crate::testkit::assert_close(&resp.y, &want, 1e-9, "sharded");
        }
        let stats = service.stats();
        assert_eq!(stats.served, 12);
        assert_eq!(stats.rejected, 0);
        assert_eq!(stats.shards.len(), service.n_shards());
        let rollup = stats.rollup();
        assert_eq!(rollup.served, 12);
        assert_eq!(service.shutdown(), 12);
    }

    #[test]
    fn concurrent_submitters_fan_out_consistently() {
        // Several threads submit through the shared front-end at once:
        // the serialized fan-out must keep every shard's queue in the
        // same order, so each assembled response matches its own
        // request's reference product (this test raced and assembled
        // mismatched y slices before the fan-out lock existed).
        let csr = suite::fem_blocked(400, 3, 5, 3);
        let service =
            ShardedService::start(csr.clone(), small_cfg(3)).unwrap();
        let n_threads = 4usize;
        let per = 8usize;
        std::thread::scope(|s| {
            for t in 0..n_threads {
                let service = &service;
                let csr = &csr;
                s.spawn(move || {
                    for k in 0..per {
                        let id = (t * per + k) as u64;
                        let x: Vec<f64> = (0..csr.cols)
                            .map(|i| {
                                ((i as u64 + 7 * id) % 23) as f64 * 0.125
                            })
                            .collect();
                        service.submit(Request { id, x }).unwrap();
                    }
                });
            }
        });
        for _ in 0..n_threads * per {
            let resp = service.recv().expect("assembled response");
            let x: Vec<f64> = (0..csr.cols)
                .map(|i| ((i as u64 + 7 * resp.id) % 23) as f64 * 0.125)
                .collect();
            let mut want = vec![0.0; csr.rows];
            csr.spmv_ref(&x, &mut want);
            crate::testkit::assert_close(
                &resp.y,
                &want,
                1e-9,
                "concurrent fan-out",
            );
        }
        assert_eq!(service.shutdown(), n_threads * per);
    }

    #[test]
    fn sharded_gate_rejects_when_full() {
        let csr = suite::fem_blocked(200, 3, 5, 3);
        let cfg = ShardConfig {
            shards: 2,
            queue: QueuePolicy::Reject { capacity: 2 },
            ..small_cfg(2)
        };
        let service = ShardedService::start(csr.clone(), cfg).unwrap();
        let x = vec![1.0; csr.cols];
        service.submit(Request { id: 0, x: x.clone() }).unwrap();
        service.submit(Request { id: 1, x: x.clone() }).unwrap();
        assert_eq!(
            service.submit(Request { id: 2, x: x.clone() }),
            Err(ServiceError::Overloaded { capacity: 2 })
        );
        assert_eq!(service.rejected(), 1);
        // Receiving frees the cluster-wide slot.
        service.recv().unwrap();
        service.submit(Request { id: 3, x }).unwrap();
        service.recv().unwrap();
        service.recv().unwrap();
        let stats = service.stats();
        assert!(stats.in_flight_high_water <= 2);
        assert_eq!(service.shutdown(), 3);
    }

    #[test]
    fn sharded_recv_timeout_resumes_partial_fan_in() {
        let csr = suite::fem_blocked(200, 3, 5, 3);
        let service =
            ShardedService::start(csr.clone(), small_cfg(2)).unwrap();
        // Nothing outstanding: the deadline elapses empty-handed.
        assert_eq!(
            service.recv_timeout(Duration::from_millis(20)).unwrap_err(),
            RecvTimeoutError::Timeout
        );
        let x = vec![0.5; csr.cols];
        service.submit(Request { id: 5, x }).unwrap();
        // A generous deadline assembles the full response.
        let resp = service.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(resp.id, 5);
        assert_eq!(resp.y.len(), csr.rows);
        assert_eq!(service.shutdown(), 1);
    }

    #[test]
    fn sharded_shape_mismatch_rejected_before_admission() {
        let csr = suite::fem_blocked(200, 3, 5, 3);
        let cols = csr.cols;
        let service = ShardedService::start(csr, small_cfg(2)).unwrap();
        let err = service
            .submit(Request { id: 0, x: vec![1.0; cols + 1] })
            .unwrap_err();
        assert_eq!(
            err,
            ServiceError::ShapeMismatch { expected: cols, got: cols + 1 }
        );
        // The bad request never claimed a slot.
        let stats = service.stats();
        assert_eq!(stats.in_flight_high_water, 0);
        assert_eq!(service.shutdown(), 0);
    }
}
