//! Sharded serving front-end: one matrix, many engines, supervised.
//!
//! [`ShardedService`] row-partitions a matrix into nnz-balanced
//! shards (via [`crate::parallel::balanced_row_ranges`] over the CSR
//! row pointer), builds an independent [`SpmvEngine`] — its own
//! kernel storage, worker pool and optional NUMA-local arrays — per
//! shard, and runs one [`SpmvService`] dispatcher per shard. A
//! request is admitted **once** at the front-end's [`AdmissionGate`],
//! fanned out to every shard, and the per-shard `y` slices are
//! concatenated back into one response on receive.
//!
//! ```text
//!            submit(x)                 recv() → y
//!               │                          ▲
//!        AdmissionGate (capacity,      fan-in: concat
//!        Block/Reject/Timeout)         y₀ ‖ y₁ ‖ … ‖ yₙ
//!               │                          │
//!       ┌───────┼──────────┐       ┌───────┼──────────┐
//!       ▼       ▼          ▼       │       │          │
//!   shard 0  shard 1 …  shard n    │       │          │
//!   rows     rows          rows    │       │          │
//!   [0,r₁)   [r₁,r₂)    [rₙ,rows)  │       │          │
//!   engine₀  engine₁    engineₙ ───┴───────┴──────────┘
//! ```
//!
//! Shard boundaries are aligned to the 8-row β interval, so each
//! shard's block structure is exactly the full matrix's restricted to
//! its rows — the sharded product is **bit-identical** to the
//! single-engine one for the same kernel configuration.
//!
//! Per-shard queues use `Block` at the gate's capacity: because the
//! gate already bounds cluster-wide in-flight requests to that same
//! capacity, shard queues can never fill, so the fan-out never blocks
//! or rejects mid-request (no partially-admitted requests). The
//! fan-out loop itself is serialized by a mutex so concurrent
//! submitters cannot interleave differently across shards — the
//! in-order fan-in depends on every shard seeing the same request
//! order.
//!
//! ## Supervision
//!
//! Each shard slot retains the shard's sub-`Csr` and its serialized
//! [`SpmvPlan`], so a dead dispatcher (kernel panic — injected
//! through [`crate::faults`] or real) is **restarted**, not fatal:
//!
//! ```text
//!   shard dispatcher panics (FailGuard sets `failed`)
//!        │
//!        ▼  first submit/recv that notices (under the fan-out lock)
//!   recover():
//!     1. fail the in-flight generation — every fully fanned-out
//!        request becomes a failure token; blocked receivers wake
//!        with RecvError::Failed { shard, generation }
//!     2. drain the live shards' copies of those requests so their
//!        response streams start clean for the next generation
//!     3. consume restart budget; if exhausted → poison everything
//!        (the old fail-stop behavior, now the circuit-breaker limit)
//!     4. rebuild the dead shard's engine via SpmvEngine::from_plan
//!        (bit-identical reconstruction), start a fresh dispatcher at
//!        generation g+1, resume serving
//! ```
//!
//! Requests are stamped with the serving generation at submit; a
//! failure aborts exactly the stamped generation. Later submissions
//! are served by the restarted shard and remain bit-identical to the
//! single-engine oracle (the restart replays the retained plan).

use super::engine::SpmvEngine;
use super::plan::SpmvPlan;
use super::service::{
    HealthReport, LatencyPercentiles, RecvError, Request, Response,
    ServiceError, ServiceStats, ShardHealth, SpmvService,
};
use super::serving::{AdmissionGate, PushError, QueuePolicy};
use crate::faults::{self, FaultPlan};
use crate::kernels::KernelKind;
use crate::matrix::Csr;
use crate::parallel::balanced_row_ranges;
use crate::scalar::Scalar;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, RwLock};
use std::time::{Duration, Instant};

/// Shard-boundary alignment: the β formats group rows into 8-row
/// intervals and form blocks jointly across an interval, so cuts on
/// this boundary preserve the full matrix's block partitioning.
pub const SHARD_ROW_ALIGN: usize = 8;

/// Circuit breaker for supervised restarts: at most `max_restarts`
/// shard restarts within any sliding `window`; exceeding it poisons
/// the whole service (the pre-supervision fail-stop behavior).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RestartBudget {
    pub max_restarts: usize,
    pub window: Duration,
}

impl Default for RestartBudget {
    fn default() -> Self {
        RestartBudget {
            max_restarts: 8,
            window: Duration::from_secs(60),
        }
    }
}

/// How to cut and drive the shards.
#[derive(Clone, Debug)]
pub struct ShardConfig {
    /// Requested shard count (the effective count can be lower for
    /// tiny matrices; see [`ShardedService::n_shards`]).
    pub shards: usize,
    /// Worker threads per shard engine (1 = sequential shard).
    pub threads_per_shard: usize,
    /// First-touch NUMA placement inside each shard's pool.
    pub numa_split: bool,
    /// Kernel for every shard; `None` lets each shard's inspector
    /// choose (may differ per shard — pin a kernel when bit-identical
    /// results against a single engine are required).
    pub kernel: Option<KernelKind>,
    /// Per-shard micro-batching limit (as [`SpmvService::start`]).
    pub max_batch: usize,
    /// Front-end admission policy (capacity + overflow behavior).
    pub queue: QueuePolicy,
    /// Restart circuit breaker (see [`RestartBudget`]).
    pub budget: RestartBudget,
    /// Fault plan checked at this cluster's injection sites; `None`
    /// falls back to the process-global plan ([`faults::global`]).
    pub faults: Option<Arc<FaultPlan>>,
}

impl Default for ShardConfig {
    fn default() -> Self {
        ShardConfig {
            shards: 2,
            threads_per_shard: 1,
            numa_split: false,
            kernel: None,
            max_batch: 8,
            queue: QueuePolicy::default(),
            budget: RestartBudget::default(),
            faults: None,
        }
    }
}

/// Cluster-level statistics: per-shard snapshots plus front-end
/// admission counters.
#[derive(Clone, Debug)]
pub struct ClusterStats {
    /// Fully assembled responses handed to clients.
    pub served: usize,
    /// Requests refused at the admission gate.
    pub rejected: usize,
    /// Highest cluster-wide in-flight count (≤ capacity).
    pub in_flight_high_water: usize,
    /// Supervised shard restarts performed so far.
    pub restarts: usize,
    /// One [`ServiceStats`] per shard, in row order.
    pub shards: Vec<ServiceStats>,
}

impl ClusterStats {
    /// Collapses the per-shard stats into one service-shaped view:
    /// counters are summed, latency percentiles take the **max**
    /// across shards (a request completes when its slowest shard
    /// does, so the max is the conservative critical-path estimate),
    /// and the queue-depth high-water is the front-end gate's.
    pub fn rollup(&self) -> ServiceStats {
        let mut batches = 0usize;
        let mut batch_hist: Vec<usize> = Vec::new();
        let mut total = LatencyPercentiles::default();
        let mut queue = LatencyPercentiles::default();
        let mut compute = LatencyPercentiles::default();
        for s in &self.shards {
            batches += s.batches;
            for (i, &c) in s.batch_hist.iter().enumerate() {
                if batch_hist.len() <= i {
                    batch_hist.resize(i + 1, 0);
                }
                batch_hist[i] += c;
            }
            total = max_pct(
                total,
                LatencyPercentiles {
                    p50_s: s.p50_s,
                    p95_s: s.p95_s,
                    p99_s: s.p99_s,
                },
            );
            queue = max_pct(queue, s.queue);
            compute = max_pct(compute, s.compute);
        }
        ServiceStats {
            served: self.served,
            rejected: self.rejected,
            batches,
            p50_s: total.p50_s,
            p95_s: total.p95_s,
            p99_s: total.p99_s,
            queue,
            compute,
            queue_depth_high_water: self.in_flight_high_water,
            batch_hist,
        }
    }
}

fn max_pct(a: LatencyPercentiles, b: LatencyPercentiles) -> LatencyPercentiles {
    LatencyPercentiles {
        p50_s: a.p50_s.max(b.p50_s),
        p95_s: a.p95_s.max(b.p95_s),
        p99_s: a.p99_s.max(b.p99_s),
    }
}

/// One supervised shard: the running service plus everything needed
/// to rebuild it bit-identically after a dispatcher death.
struct ShardSlot<T: Scalar> {
    /// `Arc` so blocking work (fan-in receives, drains) can run on a
    /// clone without holding the slot lock.
    service: Arc<SpmvService<T>>,
    /// The shard's rows of the served matrix — `from_plan` input.
    sub: Csr<T>,
    /// The shard's inspected plan: restart replays it exactly.
    plan: SpmvPlan,
    health: ShardHealth,
    restarts: usize,
    generation: u64,
    last_fault: Option<String>,
}

/// Fan-in bookkeeping: per-shard responses collected so far for the
/// oldest outstanding request (survives a `recv_timeout` deadline)
/// and failure tokens awaiting delivery.
struct FanInState<T: Scalar> {
    parts: Vec<Option<Response<T>>>,
    /// `(shard, generation)` failure tokens: one per request aborted
    /// by a shard failure, delivered through `recv` as
    /// [`RecvError::Failed`].
    failed: VecDeque<(usize, u64)>,
}

/// The sharded front-end (see module docs). `Sync`: submissions and
/// receives may come from different threads; concurrent receivers
/// serialize on the fan-in state.
pub struct ShardedService<T: Scalar = f64> {
    shards: Vec<RwLock<ShardSlot<T>>>,
    /// `row_bounds[i]..row_bounds[i+1]` = shard `i`'s rows.
    row_bounds: Vec<usize>,
    gate: AdmissionGate,
    rows: usize,
    cols: usize,
    max_batch: usize,
    /// Per-shard queue capacity (the gate's, see module docs).
    shard_capacity: usize,
    faults: Option<Arc<FaultPlan>>,
    budget: RestartBudget,
    /// Serializes the fan-out loop: every shard queue must see
    /// requests in the same order, because the in-order fan-in pairs
    /// each shard's next response with the oldest request. Also the
    /// recovery lock — lock order is always
    /// `fan_out` → `fan_in` → `pending`.
    fan_out: Mutex<()>,
    /// Receivers may block in a shard `recv` while holding this lock;
    /// `submit` must never need it, or a consumer waiting for work
    /// would wedge the producer about to provide it. That is why the
    /// pending queue lives in its own mutex below.
    fan_in: Mutex<FanInState<T>>,
    /// `(id, generation)` of every fully fanned-out, unassembled
    /// request, oldest first. Pushed under `fan_out` (submit), popped
    /// under `fan_in` (assembly) — a thread holding both (recovery)
    /// sees it frozen.
    pending: Mutex<VecDeque<(u64, u64)>>,
    /// Serving generation; bumped on every recovery pass.
    generation: AtomicU64,
    /// Sliding-window log of restart instants (the budget).
    restart_times: Mutex<VecDeque<Instant>>,
    restarts: AtomicUsize,
    poisoned: AtomicBool,
    /// `(shard, generation)` of the failure that poisoned the service.
    poison_cause: Mutex<Option<(usize, u64)>>,
    assembled: AtomicUsize,
    rejected: AtomicUsize,
}

impl<T: Scalar> ShardedService<T> {
    /// Cuts `csr` into at most `cfg.shards` row shards (8-row-aligned,
    /// nnz-balanced, empty shards dropped), builds one engine and one
    /// dispatcher per shard, and opens the admission gate.
    pub fn start(
        csr: Csr<T>,
        cfg: ShardConfig,
    ) -> anyhow::Result<ShardedService<T>> {
        anyhow::ensure!(cfg.shards >= 1, "shard count must be >= 1");
        anyhow::ensure!(cfg.max_batch >= 1, "max_batch must be >= 1");
        anyhow::ensure!(csr.rows > 0, "cannot shard an empty matrix");
        let (rows, cols) = (csr.rows, csr.cols);
        let faults = cfg.faults.clone().or_else(faults::global);
        let shard_capacity = cfg.queue.capacity();

        let ranges =
            balanced_row_ranges(&csr.rowptr, cfg.shards, SHARD_ROW_ALIGN);
        let mut shards = Vec::with_capacity(ranges.len());
        let mut row_bounds = Vec::with_capacity(ranges.len() + 1);
        row_bounds.push(0usize);
        for (i, &(r0, r1)) in ranges.iter().enumerate() {
            let sub = csr.row_slice(r0, r1);
            let mut builder = SpmvEngine::builder(sub)
                .threads(cfg.threads_per_shard)
                .numa_split(cfg.numa_split);
            if let Some(kernel) = cfg.kernel {
                builder = builder.kernel(kernel);
            }
            let engine = builder.build()?;
            // Retained for restart-from-plan: the sub-matrix and the
            // inspected plan reproduce this engine bit-for-bit.
            let sub = engine.csr().clone();
            let plan = engine.plan().clone();
            // Block at the gate's capacity: the gate admits at most
            // `capacity` cluster-wide, so these queues never fill and
            // a fan-out submit can never block or reject.
            let service = SpmvService::start_shard(
                engine,
                cfg.max_batch,
                QueuePolicy::Block { capacity: shard_capacity },
                i,
                0,
                faults.clone(),
            );
            shards.push(RwLock::new(ShardSlot {
                service: Arc::new(service),
                sub,
                plan,
                health: ShardHealth::Up,
                restarts: 0,
                generation: 0,
                last_fault: None,
            }));
            row_bounds.push(r1);
        }
        let n = shards.len();
        Ok(ShardedService {
            shards,
            row_bounds,
            gate: AdmissionGate::new(cfg.queue),
            rows,
            cols,
            max_batch: cfg.max_batch,
            shard_capacity,
            faults,
            budget: cfg.budget,
            fan_out: Mutex::new(()),
            fan_in: Mutex::new(FanInState {
                parts: (0..n).map(|_| None).collect(),
                failed: VecDeque::new(),
            }),
            pending: Mutex::new(VecDeque::new()),
            generation: AtomicU64::new(0),
            restart_times: Mutex::new(VecDeque::new()),
            restarts: AtomicUsize::new(0),
            poisoned: AtomicBool::new(false),
            poison_cause: Mutex::new(None),
            assembled: AtomicUsize::new(0),
            rejected: AtomicUsize::new(0),
        })
    }

    /// Effective shard count (≤ the configured one for tiny matrices).
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Row boundaries: shard `i` serves rows
    /// `row_bounds()[i]..row_bounds()[i+1]`.
    pub fn row_bounds(&self) -> &[usize] {
        &self.row_bounds
    }

    /// Rows of the full served matrix.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Columns of the full served matrix.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// The front-end admission policy.
    pub fn policy(&self) -> QueuePolicy {
        self.gate.policy()
    }

    /// Fully assembled responses handed to clients so far.
    pub fn served(&self) -> usize {
        self.assembled.load(Ordering::Relaxed)
    }

    /// Requests refused at the admission gate so far.
    pub fn rejected(&self) -> usize {
        self.rejected.load(Ordering::Relaxed)
    }

    /// The current serving generation (bumped on every recovery).
    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::Acquire)
    }

    /// Supervised restarts performed so far.
    pub fn restarts(&self) -> usize {
        self.restarts.load(Ordering::Relaxed)
    }

    /// True once the restart budget was exhausted (or a restart
    /// itself failed) and the service shut down for good.
    pub fn poisoned(&self) -> bool {
        self.poisoned.load(Ordering::Acquire)
    }

    /// Health snapshot of every shard, in row order.
    pub fn health(&self) -> Vec<HealthReport> {
        self.shards
            .iter()
            .enumerate()
            .map(|(i, slot)| {
                let s = slot.read().unwrap_or_else(|e| e.into_inner());
                HealthReport {
                    shard: i,
                    health: s.health,
                    generation: s.generation,
                    restarts: s.restarts,
                    last_fault: s.last_fault.clone(),
                }
            })
            .collect()
    }

    fn slot_service(&self, i: usize) -> Arc<SpmvService<T>> {
        Arc::clone(
            &self.shards[i]
                .read()
                .unwrap_or_else(|e| e.into_inner())
                .service,
        )
    }

    /// The error submits/receives report once poisoned.
    fn poison_error(&self) -> (usize, u64) {
        self.poison_cause
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .unwrap_or((0, self.generation()))
    }

    /// Admits the request at the front-end gate, then fans it out to
    /// every shard. Exactly one admission decision per request: by the
    /// time the gate says yes, no shard queue can be full. A shard
    /// failure mid-fan-out triggers recovery (see module docs); this
    /// request is aborted with [`ServiceError::ShardFailed`] and the
    /// restarted shard serves subsequent submissions.
    pub fn submit(&self, req: Request<T>) -> Result<(), ServiceError> {
        if req.x.len() != self.cols {
            return Err(ServiceError::ShapeMismatch {
                expected: self.cols,
                got: req.x.len(),
            });
        }
        match self.gate.acquire() {
            Ok(()) => {}
            Err(PushError::Full) => {
                self.rejected.fetch_add(1, Ordering::Relaxed);
                return Err(ServiceError::Overloaded {
                    capacity: self.gate.capacity(),
                });
            }
            Err(PushError::Closed) => {
                if self.poisoned() {
                    let (shard, generation) = self.poison_error();
                    return Err(ServiceError::ShardFailed {
                        shard,
                        generation,
                    });
                }
                return Err(ServiceError::Stopped);
            }
        }
        let Request { id, mut x } = req;
        let n = self.shards.len();
        // One submitter fans out at a time (see the `fan_out` field
        // docs). The critical section is short: shard queues run
        // `Block` at the gate's capacity and the gate already bounds
        // in-flight to that capacity, so no shard submit can block.
        let serialized =
            self.fan_out.lock().unwrap_or_else(|e| e.into_inner());
        let generation = self.generation.load(Ordering::Acquire);
        // Record the pending entry *before* fanning out, so a receiver
        // can never see a shard response whose request it does not
        // know about. Note: the pending queue, not the fan-in state —
        // a receiver blocked in a shard `recv` holds the fan-in lock,
        // and a submit must never wait on it.
        {
            let mut pending =
                self.pending.lock().unwrap_or_else(|e| e.into_inner());
            pending.push_back((id, generation));
        }
        for i in 0..n {
            let shard = self.slot_service(i);
            // The last shard takes ownership; earlier ones clone.
            let part =
                if i + 1 == n { std::mem::take(&mut x) } else { x.clone() };
            if let Err(e) = shard.submit(Request { id, x: part }) {
                if !shard.failed() && !self.poisoned() {
                    // Clean shutdown raced this submit: withdraw the
                    // pending entry (ours is the newest — fan-out is
                    // serialized) and report the stop.
                    let mut pending = self
                        .pending
                        .lock()
                        .unwrap_or_else(|e| e.into_inner());
                    let popped = pending.pop_back();
                    debug_assert_eq!(popped, Some((id, generation)));
                    drop(pending);
                    self.gate.release();
                    drop(serialized);
                    return Err(e);
                }
                // A shard dispatcher died (kernel panic) mid-fan-out:
                // shards 0..i hold this request while later ones never
                // saw it. Recover: fail the fanned-out generation,
                // drain the live shards' copies (including the `i`
                // copies of this request), restart the dead shard(s).
                let cause = self.recover(&serialized, i, true);
                drop(serialized);
                return Err(ServiceError::ShardFailed {
                    shard: cause,
                    generation,
                });
            }
        }
        drop(serialized);
        Ok(())
    }

    /// Blocks for the next fully assembled response.
    /// [`RecvError::Stopped`] means clean shutdown;
    /// [`RecvError::Failed`] reports one aborted request of a failed
    /// generation (or, after poisoning, the terminal failure).
    pub fn recv(&self) -> Result<Response<T>, RecvError> {
        self.recv_deadline(None)
    }

    /// Waits up to `wait` for the next fully assembled response. On
    /// timeout the per-shard responses gathered so far are kept; a
    /// later receive resumes the assembly — nothing is lost.
    pub fn recv_timeout(
        &self,
        wait: Duration,
    ) -> Result<Response<T>, RecvError> {
        self.recv_deadline(Instant::now().checked_add(wait))
    }

    /// Fan-in: one response per shard, in shard order, assembled into
    /// the full-length `y`. Per-shard dispatchers answer in submission
    /// order, so the next response of every shard belongs to the
    /// oldest unassembled request. A dead shard discovered here
    /// triggers recovery, after which the loop delivers the failure
    /// tokens recovery queued.
    fn recv_deadline(
        &self,
        deadline: Option<Instant>,
    ) -> Result<Response<T>, RecvError> {
        loop {
            let mut dead_seen = false;
            {
                let mut fi =
                    self.fan_in.lock().unwrap_or_else(|e| e.into_inner());
                // Failure tokens first: they are older than anything
                // still assembling.
                if let Some((shard, generation)) = fi.failed.pop_front() {
                    return Err(RecvError::Failed { shard, generation });
                }
                let n = self.shards.len();
                let mut i = 0;
                while i < n {
                    if fi.parts[i].is_some() {
                        i += 1;
                        continue;
                    }
                    let shard = self.slot_service(i);
                    let got = match deadline {
                        None => shard.recv(),
                        Some(dl) => {
                            // A zero budget degrades to a try-recv;
                            // collected parts stay in `fi` when this
                            // errs out.
                            let left =
                                dl.saturating_duration_since(Instant::now());
                            shard.recv_timeout(left)
                        }
                    };
                    match got {
                        Ok(resp) => {
                            fi.parts[i] = Some(resp);
                            i += 1;
                        }
                        Err(RecvError::Timeout) => {
                            return Err(RecvError::Timeout)
                        }
                        Err(RecvError::Stopped) => {
                            if shard.failed() || self.poisoned() {
                                dead_seen = true;
                                break;
                            }
                            return Err(RecvError::Stopped);
                        }
                        Err(RecvError::Failed { .. }) => {
                            dead_seen = true;
                            break;
                        }
                    }
                }
                if !dead_seen {
                    return Ok(self.assemble(&mut fi));
                }
            } // drop fan_in before recovery: lock order is fan_out → fan_in
            if self.poisoned() {
                // Recovery already ran and gave up; drain any queued
                // tokens on the next loop pass, else report the cause.
                let fi =
                    self.fan_in.lock().unwrap_or_else(|e| e.into_inner());
                if fi.failed.is_empty() {
                    let (shard, generation) = self.poison_error();
                    return Err(RecvError::Failed { shard, generation });
                }
                continue;
            }
            let serialized =
                self.fan_out.lock().unwrap_or_else(|e| e.into_inner());
            self.recover(&serialized, 0, false);
            drop(serialized);
            // Loop: deliver a failure token, resume serving, or
            // observe the poisoned end state.
        }
    }

    /// Concatenates one collected response per shard into the full
    /// answer for the oldest pending request.
    fn assemble(&self, fi: &mut FanInState<T>) -> Response<T> {
        let parts: Vec<Response<T>> = fi
            .parts
            .iter_mut()
            .map(|p| p.take().expect("all shards answered"))
            .collect();
        let (id, _gen) = self
            .pending
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .pop_front()
            .expect("response implies a pending request");
        // Release-build check, not a debug_assert: a desynchronized
        // fan-in must fail loudly rather than silently hand back a `y`
        // stitched from different requests. Unreachable with the
        // serialized fan-out and the supervised recovery path.
        assert!(
            parts.iter().all(|p| p.id == id),
            "shard fan-in desynchronized"
        );
        let mut y = Vec::with_capacity(self.rows);
        let mut queue_s = 0.0f64;
        let mut compute_s = 0.0f64;
        for p in parts {
            y.extend_from_slice(&p.y);
            // A request is as slow as its slowest shard.
            queue_s = queue_s.max(p.queue_s);
            compute_s = compute_s.max(p.compute_s);
        }
        self.gate.release();
        self.assembled.fetch_add(1, Ordering::Relaxed);
        Response { id, y, latency_s: queue_s + compute_s, queue_s, compute_s }
    }

    /// Consumes `k` restart slots from the sliding-window budget;
    /// false = circuit breaker trips.
    fn consume_budget(&self, k: usize) -> bool {
        let mut log = self
            .restart_times
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let now = Instant::now();
        while log
            .front()
            .map_or(false, |t| now.duration_since(*t) > self.budget.window)
        {
            log.pop_front();
        }
        if log.len() + k > self.budget.max_restarts {
            return false;
        }
        for _ in 0..k {
            log.push_back(now);
        }
        true
    }

    /// Supervised recovery. Caller holds the fan-out lock (`_fo`),
    /// which excludes submitters and other recoverers; this routine
    /// additionally holds the fan-in lock throughout, so no receiver
    /// can interleave with the drains.
    ///
    /// `current_fanned` / `current_is_pending`: when called from a
    /// failed submit, the caller's request reached shards
    /// `0..current_fanned` and sits as the *newest* pending entry; it
    /// is withdrawn here (no failure token — the submit call itself
    /// reports the error) but its fanned-out copies are drained like
    /// any other. From the receive path both are zero/false.
    ///
    /// Returns the shard index blamed for the failure.
    fn recover(
        &self,
        _fo: &MutexGuard<'_, ()>,
        current_fanned: usize,
        current_is_pending: bool,
    ) -> usize {
        let n = self.shards.len();
        let mut fi = self.fan_in.lock().unwrap_or_else(|e| e.into_inner());
        // Spurious call — another recoverer got here first (the
        // receive path races for the fan-out lock). Touch nothing:
        // the pending requests and collected parts are healthy state
        // of the *new* generation now.
        let any_dead = (0..n).any(|j| {
            let slot =
                self.shards[j].read().unwrap_or_else(|e| e.into_inner());
            slot.health != ShardHealth::Poisoned && slot.service.failed()
        });
        if !any_dead {
            return 0;
        }
        // Responses already collected count toward the drain targets.
        let mut drained: Vec<usize> = (0..n)
            .map(|j| usize::from(fi.parts[j].take().is_some()))
            .collect();
        // Frozen while fan-out and fan-in are both held: pushes need
        // the former, assembly pops need the latter.
        let full = self
            .pending
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .len()
            - usize::from(current_is_pending);
        let mut cause: Option<usize> = None;

        loop {
            let dead: Vec<usize> = (0..n)
                .filter(|&j| {
                    let slot = self.shards[j]
                        .read()
                        .unwrap_or_else(|e| e.into_inner());
                    slot.health != ShardHealth::Poisoned
                        && slot.service.failed()
                })
                .collect();
            if dead.is_empty() {
                break;
            }
            cause.get_or_insert(dead[0]);
            for &j in &dead {
                let mut slot = self.shards[j]
                    .write()
                    .unwrap_or_else(|e| e.into_inner());
                slot.last_fault = Some(format!(
                    "dispatcher panic (generation {})",
                    slot.generation
                ));
                slot.health = ShardHealth::Restarting;
            }
            // Circuit breaker: repeated failures stop being restarted.
            if !self.consume_budget(dead.len()) {
                let c = cause.unwrap_or(dead[0]);
                self.poison(&mut fi, c, current_is_pending);
                return c;
            }
            // Drain the live shards' responses for the aborted
            // generation, so the next generation's fan-in starts
            // aligned. A shard dying mid-drain joins the dead set on
            // the next pass.
            let mut drain_hit_failure = false;
            'live: for j in 0..n {
                if dead.contains(&j) {
                    continue;
                }
                let target = full + usize::from(j < current_fanned);
                while drained[j] < target {
                    let svc = self.slot_service(j);
                    match svc.recv() {
                        Ok(_) => drained[j] += 1,
                        Err(_) => {
                            drain_hit_failure = true;
                            continue 'live;
                        }
                    }
                }
            }
            // Restart every dead shard at the next generation: replay
            // the retained plan over the retained sub-matrix — a
            // bit-identical engine reconstruction.
            let next_gen =
                self.generation.fetch_add(1, Ordering::AcqRel) + 1;
            for &j in &dead {
                let mut slot = self.shards[j]
                    .write()
                    .unwrap_or_else(|e| e.into_inner());
                let engine =
                    match SpmvEngine::from_plan(slot.sub.clone(), &slot.plan)
                    {
                        Ok(e) => e,
                        Err(err) => {
                            slot.last_fault =
                                Some(format!("restart failed: {err}"));
                            drop(slot);
                            let c = cause.unwrap_or(j);
                            self.poison(&mut fi, c, current_is_pending);
                            return c;
                        }
                    };
                let fresh = SpmvService::start_shard(
                    engine,
                    self.max_batch,
                    QueuePolicy::Block { capacity: self.shard_capacity },
                    j,
                    next_gen,
                    self.faults.clone(),
                );
                let old = std::mem::replace(
                    &mut slot.service,
                    Arc::new(fresh),
                );
                old.close();
                slot.generation = next_gen;
                slot.restarts += 1;
                slot.health = ShardHealth::Up;
                self.restarts.fetch_add(1, Ordering::Relaxed);
                // The fresh shard has nothing to drain: mark its
                // target met so a later pass does not block on an
                // empty channel.
                drained[j] = full + usize::from(j < current_fanned);
            }
            if !drain_hit_failure {
                break;
            }
        }

        // Fail the aborted generation: one token per fully fanned-out
        // request (the submit-path caller's own request is withdrawn
        // without a token — its error is the return value). Slots are
        // released for every withdrawn entry.
        let c = cause.unwrap_or(0);
        let entries: Vec<(u64, u64)> = self
            .pending
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .drain(..)
            .collect();
        let tokens = entries.len() - usize::from(current_is_pending);
        for &(_, generation) in &entries[..tokens] {
            fi.failed.push_back((c, generation));
        }
        for _ in 0..entries.len() {
            self.gate.release();
        }
        c
    }

    /// Terminal failure: close the gate and every shard, mark all
    /// shards poisoned, convert the outstanding generation into
    /// failure tokens so nothing hangs. Fault-free shutdown never
    /// comes here — [`shutdown_ref`](Self::shutdown_ref) stays the
    /// clean-stop path.
    fn poison(
        &self,
        fi: &mut FanInState<T>,
        cause_shard: usize,
        current_is_pending: bool,
    ) {
        self.poisoned.store(true, Ordering::Release);
        {
            let mut pc = self
                .poison_cause
                .lock()
                .unwrap_or_else(|e| e.into_inner());
            if pc.is_none() {
                *pc = Some((cause_shard, self.generation()));
            }
        }
        self.gate.close();
        for slot in &self.shards {
            let mut s = slot.write().unwrap_or_else(|e| e.into_inner());
            s.health = ShardHealth::Poisoned;
            s.service.close();
        }
        for p in fi.parts.iter_mut() {
            *p = None;
        }
        let entries: Vec<(u64, u64)> = self
            .pending
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .drain(..)
            .collect();
        let tokens = entries.len() - usize::from(current_is_pending);
        for &(_, generation) in &entries[..tokens] {
            fi.failed.push_back((cause_shard, generation));
        }
        for _ in 0..entries.len() {
            self.gate.release();
        }
    }

    /// Cluster-level snapshot: admission counters plus one
    /// [`ServiceStats`] per shard (see [`ClusterStats::rollup`]).
    pub fn stats(&self) -> ClusterStats {
        ClusterStats {
            served: self.served(),
            rejected: self.rejected(),
            in_flight_high_water: self.gate.high_water(),
            restarts: self.restarts(),
            shards: (0..self.shards.len())
                .map(|i| self.slot_service(i).stats())
                .collect(),
        }
    }

    /// Graceful shutdown: closes the gate (blocked submitters wake
    /// with [`ServiceError::Stopped`]), drains every shard and returns
    /// the number of fully assembled responses delivered to clients.
    pub fn shutdown(self) -> usize {
        self.shutdown_ref()
    }

    /// [`shutdown`](Self::shutdown) through a shared reference — for
    /// services shared via `Arc` (the tenant registry). Idempotent.
    pub fn shutdown_ref(&self) -> usize {
        self.gate.close();
        for (i, _) in self.shards.iter().enumerate() {
            self.slot_service(i).shutdown_ref();
        }
        // Per-shard counts disagree with the client's view once a
        // generation aborted (drained copies still count per shard);
        // the assembled total is the meaningful figure.
        self.served()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faults::{Action, FaultRule, SiteKind};
    use crate::matrix::suite;

    fn small_cfg(shards: usize) -> ShardConfig {
        ShardConfig {
            shards,
            kernel: Some(KernelKind::Beta(1, 8)),
            queue: QueuePolicy::Block { capacity: 64 },
            ..ShardConfig::default()
        }
    }

    #[test]
    fn sharded_service_serves_correct_results() {
        let csr = suite::fem_blocked(400, 3, 5, 3);
        let service =
            ShardedService::start(csr.clone(), small_cfg(3)).unwrap();
        assert!(service.n_shards() >= 2, "matrix large enough to shard");
        assert_eq!(service.row_bounds()[0], 0);
        assert_eq!(*service.row_bounds().last().unwrap(), csr.rows);

        for id in 0..12u64 {
            let x: Vec<f64> = (0..csr.cols)
                .map(|i| ((i as u64 + 3 * id) % 17) as f64 * 0.25)
                .collect();
            service.submit(Request { id, x }).unwrap();
        }
        for _ in 0..12 {
            let resp = service.recv().expect("assembled response");
            assert_eq!(resp.y.len(), csr.rows);
            let x: Vec<f64> = (0..csr.cols)
                .map(|i| ((i as u64 + 3 * resp.id) % 17) as f64 * 0.25)
                .collect();
            let mut want = vec![0.0; csr.rows];
            csr.spmv_ref(&x, &mut want);
            crate::testkit::assert_close(&resp.y, &want, 1e-9, "sharded");
        }
        let stats = service.stats();
        assert_eq!(stats.served, 12);
        assert_eq!(stats.rejected, 0);
        assert_eq!(stats.restarts, 0);
        assert_eq!(stats.shards.len(), service.n_shards());
        let rollup = stats.rollup();
        assert_eq!(rollup.served, 12);
        for h in service.health() {
            assert_eq!(h.health, ShardHealth::Up);
            assert_eq!(h.generation, 0);
            assert_eq!(h.restarts, 0);
        }
        assert_eq!(service.shutdown(), 12);
    }

    #[test]
    fn concurrent_submitters_fan_out_consistently() {
        // Several threads submit through the shared front-end at once:
        // the serialized fan-out must keep every shard's queue in the
        // same order, so each assembled response matches its own
        // request's reference product (this test raced and assembled
        // mismatched y slices before the fan-out lock existed).
        let csr = suite::fem_blocked(400, 3, 5, 3);
        let service =
            ShardedService::start(csr.clone(), small_cfg(3)).unwrap();
        let n_threads = 4usize;
        let per = 8usize;
        std::thread::scope(|s| {
            for t in 0..n_threads {
                let service = &service;
                let csr = &csr;
                s.spawn(move || {
                    for k in 0..per {
                        let id = (t * per + k) as u64;
                        let x: Vec<f64> = (0..csr.cols)
                            .map(|i| {
                                ((i as u64 + 7 * id) % 23) as f64 * 0.125
                            })
                            .collect();
                        service.submit(Request { id, x }).unwrap();
                    }
                });
            }
        });
        for _ in 0..n_threads * per {
            let resp = service.recv().expect("assembled response");
            let x: Vec<f64> = (0..csr.cols)
                .map(|i| ((i as u64 + 7 * resp.id) % 23) as f64 * 0.125)
                .collect();
            let mut want = vec![0.0; csr.rows];
            csr.spmv_ref(&x, &mut want);
            crate::testkit::assert_close(
                &resp.y,
                &want,
                1e-9,
                "concurrent fan-out",
            );
        }
        assert_eq!(service.shutdown(), n_threads * per);
    }

    #[test]
    fn sharded_gate_rejects_when_full() {
        let csr = suite::fem_blocked(200, 3, 5, 3);
        let cfg = ShardConfig {
            shards: 2,
            queue: QueuePolicy::Reject { capacity: 2 },
            ..small_cfg(2)
        };
        let service = ShardedService::start(csr.clone(), cfg).unwrap();
        let x = vec![1.0; csr.cols];
        service.submit(Request { id: 0, x: x.clone() }).unwrap();
        service.submit(Request { id: 1, x: x.clone() }).unwrap();
        assert_eq!(
            service.submit(Request { id: 2, x: x.clone() }),
            Err(ServiceError::Overloaded { capacity: 2 })
        );
        assert_eq!(service.rejected(), 1);
        // Receiving frees the cluster-wide slot.
        service.recv().unwrap();
        service.submit(Request { id: 3, x }).unwrap();
        service.recv().unwrap();
        service.recv().unwrap();
        let stats = service.stats();
        assert!(stats.in_flight_high_water <= 2);
        assert_eq!(service.shutdown(), 3);
    }

    #[test]
    fn sharded_recv_timeout_resumes_partial_fan_in() {
        let csr = suite::fem_blocked(200, 3, 5, 3);
        let service =
            ShardedService::start(csr.clone(), small_cfg(2)).unwrap();
        // Nothing outstanding: the deadline elapses empty-handed.
        assert_eq!(
            service.recv_timeout(Duration::from_millis(20)).unwrap_err(),
            RecvError::Timeout
        );
        let x = vec![0.5; csr.cols];
        service.submit(Request { id: 5, x }).unwrap();
        // A generous deadline assembles the full response.
        let resp = service.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(resp.id, 5);
        assert_eq!(resp.y.len(), csr.rows);
        assert_eq!(service.shutdown(), 1);
    }

    #[test]
    fn sharded_shape_mismatch_rejected_before_admission() {
        let csr = suite::fem_blocked(200, 3, 5, 3);
        let cols = csr.cols;
        let service = ShardedService::start(csr, small_cfg(2)).unwrap();
        let err = service
            .submit(Request { id: 0, x: vec![1.0; cols + 1] })
            .unwrap_err();
        assert_eq!(
            err,
            ServiceError::ShapeMismatch { expected: cols, got: cols + 1 }
        );
        // The bad request never claimed a slot.
        let stats = service.stats();
        assert_eq!(stats.in_flight_high_water, 0);
        assert_eq!(service.shutdown(), 0);
    }

    #[test]
    fn shard_panic_restarts_and_resumes_serving() {
        let csr = suite::fem_blocked(400, 3, 5, 3);
        // Kill shard 1's dispatcher on its first batch, once.
        let plan = Arc::new(FaultPlan::new(
            vec![FaultRule::new(SiteKind::Compute, Action::Panic)
                .shard(1)
                .nth(0)],
            0,
        ));
        let cfg = ShardConfig {
            faults: Some(Arc::clone(&plan)),
            ..small_cfg(3)
        };
        let service = ShardedService::start(csr.clone(), cfg).unwrap();
        assert!(service.n_shards() >= 2);

        let x0: Vec<f64> = (0..csr.cols).map(|i| (i % 7) as f64).collect();
        service.submit(Request { id: 0, x: x0 }).unwrap();
        // The faulted generation fails with the typed error.
        assert_eq!(
            service.recv().unwrap_err(),
            RecvError::Failed { shard: 1, generation: 0 }
        );
        assert_eq!(plan.fired(), 1);
        assert_eq!(service.restarts(), 1);
        assert!(!service.poisoned());
        let health = service.health();
        assert_eq!(health[1].health, ShardHealth::Up);
        assert_eq!(health[1].restarts, 1);
        assert_eq!(health[1].generation, 1);
        assert!(health[1].last_fault.as_deref().unwrap().contains("panic"));
        assert_eq!(health[0].restarts, 0);

        // Subsequent submissions are served by the restarted shard,
        // bit-identical to the reference product.
        for id in 1..6u64 {
            let x: Vec<f64> = (0..csr.cols)
                .map(|i| ((i as u64 + 5 * id) % 13) as f64 * 0.5)
                .collect();
            service.submit(Request { id, x }).unwrap();
        }
        for _ in 1..6 {
            let resp = service.recv().expect("post-restart response");
            let x: Vec<f64> = (0..csr.cols)
                .map(|i| ((i as u64 + 5 * resp.id) % 13) as f64 * 0.5)
                .collect();
            let mut want = vec![0.0; csr.rows];
            csr.spmv_ref(&x, &mut want);
            assert_eq!(resp.y, want, "restarted shard must be bit-identical");
        }
        assert_eq!(service.shutdown(), 5);
    }

    #[test]
    fn restart_budget_exhaustion_poisons() {
        let csr = suite::fem_blocked(200, 3, 5, 3);
        // Shard 0 panics on every batch: the first failure consumes
        // the whole budget, the second trips the breaker.
        let plan = Arc::new(FaultPlan::new(
            vec![FaultRule::new(SiteKind::Compute, Action::Panic)
                .shard(0)
                .every(1)],
            0,
        ));
        let cfg = ShardConfig {
            faults: Some(plan),
            budget: RestartBudget {
                max_restarts: 1,
                window: Duration::from_secs(3600),
            },
            ..small_cfg(2)
        };
        let service = ShardedService::start(csr.clone(), cfg).unwrap();
        let x = vec![1.0; csr.cols];

        service.submit(Request { id: 0, x: x.clone() }).unwrap();
        assert_eq!(
            service.recv().unwrap_err(),
            RecvError::Failed { shard: 0, generation: 0 }
        );
        assert_eq!(service.restarts(), 1);

        // The restarted shard dies again; the budget is spent, so the
        // breaker poisons the whole service — and nothing hangs.
        service.submit(Request { id: 1, x: x.clone() }).unwrap();
        assert_eq!(
            service.recv().unwrap_err(),
            RecvError::Failed { shard: 0, generation: 1 }
        );
        assert!(service.poisoned());
        for h in service.health() {
            assert_eq!(h.health, ShardHealth::Poisoned);
        }
        // Subsequent submits and receives report the terminal failure.
        assert!(matches!(
            service.submit(Request { id: 2, x }),
            Err(ServiceError::ShardFailed { shard: 0, .. })
        ));
        assert!(matches!(
            service.recv_timeout(Duration::from_secs(5)),
            Err(RecvError::Failed { shard: 0, .. })
        ));
        assert_eq!(service.shutdown(), 0);
    }
}
