//! Plan-aware preconditioners for the Krylov solvers: `z = M⁻¹·r`
//! behind one object-safe trait, built from a matrix (analysis path)
//! or from a persisted [`super::SolvePlan`] decision (planned path,
//! which skips the level analysis a repeat solve already paid for).
//!
//! Three concrete `M`:
//! - [`Jacobi`] — diagonal scaling; **errors** on a zero or missing
//!   diagonal instead of silently substituting the identity (the old
//!   [`super::pcg_jacobi`] leniency, kept only in that shim).
//! - [`SymGs`] — `sweeps` symmetric Gauss–Seidel sweeps over the
//!   [`TriangularSplit`], level-scheduled on the engine's worker pool
//!   when the dependency levels are wide enough to pay for the epochs.
//! - [`Ilu0`] — ILU(0): an incomplete LU factorization on the matrix's
//!   own sparsity pattern, applied with the masked block-based
//!   triangular solves of [`crate::kernels::sptrsv`] (the factors are
//!   stored in the same β format the SpMV kernels run on), or with the
//!   level-scheduled CSR solves when parallel is worthwhile. Both
//!   paths are bit-identical, so the choice is pure scheduling.

use std::sync::Arc;

use crate::formats::{csr_to_block, BlockMatrix, BlockSize};
use crate::kernels::sptrsv::{
    sptrsv_lower_block, sptrsv_lower_levels, sptrsv_upper_block,
    sptrsv_upper_levels,
};
use crate::kernels::symgs::{symgs, symgs_levels};
use crate::matrix::{Csr, TriangularSplit};
use crate::parallel::{
    lower_levels, upper_levels, LevelSchedule, LevelSummary, WorkerPool,
};
use crate::scalar::Scalar;

/// β size the ILU(0) factors are stored at for the sequential block
/// solves — valid at every supported precision (`c = 4 ≤` mask bits).
const ILU_BLOCK: BlockSize = BlockSize { r: 2, c: 4 };

/// Errors from preconditioner construction/factorization.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PrecondError {
    /// The matrix is not square.
    NotSquare { rows: usize, cols: usize },
    /// A diagonal entry is zero or structurally missing (Jacobi,
    /// SymGS).
    ZeroDiagonal { row: usize },
    /// ILU(0) hit a zero (or structurally missing) pivot.
    ZeroPivot { row: usize },
}

impl std::fmt::Display for PrecondError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            PrecondError::NotSquare { rows, cols } => {
                write!(f, "preconditioner needs a square matrix, got {rows}x{cols}")
            }
            PrecondError::ZeroDiagonal { row } => {
                write!(f, "zero or missing diagonal at row {row}")
            }
            PrecondError::ZeroPivot { row } => {
                write!(f, "ilu(0) pivot is zero at row {row}")
            }
        }
    }
}

impl std::error::Error for PrecondError {}

/// `z = M⁻¹·r`. Implementations are `Send + Sync` so a built
/// preconditioner can ride along with the engine across threads.
pub trait Preconditioner<T: Scalar>: Send + Sync {
    /// Applies the preconditioner: writes `z = M⁻¹·r` (overwrites `z`).
    fn apply(&self, r: &[T], z: &mut [T]);
    /// Stable name for reports and plans (`jacobi`, `symgs(2)`, ...).
    fn name(&self) -> String;
    /// The level-schedule decision this preconditioner runs under, if
    /// it has triangular solves to schedule.
    fn level_summary(&self) -> Option<LevelSummary> {
        None
    }
}

/// The identity "preconditioner" (`z = r`) — plain CG through the
/// preconditioned driver.
pub struct IdentityPrecond;

impl<T: Scalar> Preconditioner<T> for IdentityPrecond {
    fn apply(&self, r: &[T], z: &mut [T]) {
        z.copy_from_slice(r);
    }
    fn name(&self) -> String {
        "none".into()
    }
}

/// Diagonal (Jacobi) preconditioner: `z = D⁻¹·r`.
pub struct Jacobi<T: Scalar = f64> {
    dinv: Vec<T>,
}

impl<T: Scalar> Jacobi<T> {
    /// Extracts and inverts the diagonal. Unlike the historical
    /// [`super::pcg_jacobi`] behavior, a zero **or structurally
    /// missing** diagonal entry is an error — silently substituting
    /// `1` turned a broken preconditioner into slow, hard-to-diagnose
    /// convergence.
    pub fn new(csr: &Csr<T>) -> Result<Self, PrecondError> {
        if csr.rows != csr.cols {
            return Err(PrecondError::NotSquare {
                rows: csr.rows,
                cols: csr.cols,
            });
        }
        let mut dinv = vec![T::ZERO; csr.rows];
        for r in 0..csr.rows {
            let mut d = T::ZERO;
            for k in csr.row_range(r) {
                if csr.colidx[k] as usize == r {
                    d = csr.values[k];
                }
            }
            if d == T::ZERO {
                return Err(PrecondError::ZeroDiagonal { row: r });
            }
            dinv[r] = T::ONE / d;
        }
        Ok(Jacobi { dinv })
    }
}

impl<T: Scalar> Preconditioner<T> for Jacobi<T> {
    fn apply(&self, r: &[T], z: &mut [T]) {
        for i in 0..z.len() {
            z[i] = r[i] * self.dinv[i];
        }
    }
    fn name(&self) -> String {
        "jacobi".into()
    }
}

/// Forward+backward level schedules plus the pool to run them on.
struct SolveLevels {
    fwd: LevelSchedule,
    bwd: LevelSchedule,
    pool: Arc<WorkerPool>,
}

/// Symmetric Gauss–Seidel preconditioner: `sweeps` forward+backward
/// sweeps of `(D+L) x = b − U x` / `(D+U) x = b − L x` starting from
/// `z = 0`.
pub struct SymGs<T: Scalar = f64> {
    split: TriangularSplit<T>,
    sweeps: usize,
    levels: Option<SolveLevels>,
    summary: LevelSummary,
}

impl<T: Scalar> SymGs<T> {
    /// Builds the split and decides sequential vs level-scheduled
    /// execution from the lower triangle's dependency levels.
    pub fn new(
        csr: &Csr<T>,
        sweeps: usize,
        pool: Option<&Arc<WorkerPool>>,
    ) -> Result<Self, PrecondError> {
        Self::with_decision(csr, sweeps, pool, None)
    }

    /// Like [`SymGs::new`], but when `planned` carries a previous
    /// run's [`LevelSummary`] the sequential-vs-parallel decision is
    /// reused: a planned-sequential build skips the level analysis
    /// entirely, a planned-parallel build rebuilds the (cheap,
    /// `O(nnz)`) level sets but not the decision.
    pub fn with_decision(
        csr: &Csr<T>,
        sweeps: usize,
        pool: Option<&Arc<WorkerPool>>,
        planned: Option<LevelSummary>,
    ) -> Result<Self, PrecondError> {
        if csr.rows != csr.cols {
            return Err(PrecondError::NotSquare {
                rows: csr.rows,
                cols: csr.cols,
            });
        }
        let split = csr
            .triangular_split()
            .map_err(|_| PrecondError::NotSquare {
                rows: csr.rows,
                cols: csr.cols,
            })?;
        if let Some(&row) = split.missing_diagonals().first() {
            return Err(PrecondError::ZeroDiagonal { row });
        }
        let sweeps = sweeps.max(1);
        let (summary, levels) = schedule_triangles(
            &split.lower,
            &split.upper,
            pool,
            planned,
        );
        Ok(SymGs { split, sweeps, levels, summary })
    }
}

impl<T: Scalar> Preconditioner<T> for SymGs<T> {
    fn apply(&self, r: &[T], z: &mut [T]) {
        z.iter_mut().for_each(|v| *v = T::ZERO);
        match &self.levels {
            Some(lv) => symgs_levels(
                &self.split,
                &lv.fwd,
                &lv.bwd,
                &lv.pool,
                r,
                z,
                self.sweeps,
            ),
            None => symgs(&self.split, r, z, self.sweeps),
        }
    }
    fn name(&self) -> String {
        format!("symgs({})", self.sweeps)
    }
    fn level_summary(&self) -> Option<LevelSummary> {
        Some(self.summary)
    }
}

/// ILU(0) preconditioner: `M = L·U` on the sparsity pattern of `A`,
/// applied as a forward solve with unit-diagonal `L` followed by a
/// backward solve with `U`.
pub struct Ilu0<T: Scalar = f64> {
    /// Strict lower triangle of `L` (unit diagonal implied).
    lower: Csr<T>,
    lower_block: BlockMatrix<T>,
    /// Unit diagonal for the forward solve (`x / 1` is exact).
    ones: Vec<T>,
    /// Strict upper triangle of `U`.
    upper: Csr<T>,
    upper_block: BlockMatrix<T>,
    udiag: Vec<T>,
    levels: Option<SolveLevels>,
    summary: LevelSummary,
}

impl<T: Scalar> Ilu0<T> {
    /// Factors `A ≈ L·U` on `A`'s own pattern (IKJ variant with a
    /// dense column→position scatter) and prepares both execution
    /// paths. The factors share `A`'s triangle sparsity, so the level
    /// sets are identical to a SymGS schedule on the same matrix.
    pub fn new(
        csr: &Csr<T>,
        pool: Option<&Arc<WorkerPool>>,
    ) -> Result<Self, PrecondError> {
        Self::with_decision(csr, pool, None)
    }

    /// Planned-decision variant; see [`SymGs::with_decision`].
    pub fn with_decision(
        csr: &Csr<T>,
        pool: Option<&Arc<WorkerPool>>,
        planned: Option<LevelSummary>,
    ) -> Result<Self, PrecondError> {
        let (lower, upper, udiag) = ilu0_factor(csr)?;
        let n = udiag.len();
        let lower_block =
            csr_to_block(&lower, ILU_BLOCK).expect("ILU_BLOCK valid");
        let upper_block =
            csr_to_block(&upper, ILU_BLOCK).expect("ILU_BLOCK valid");
        let (summary, levels) =
            schedule_triangles(&lower, &upper, pool, planned);
        Ok(Ilu0 {
            lower,
            lower_block,
            ones: vec![T::ONE; n],
            upper,
            upper_block,
            udiag,
            levels,
            summary,
        })
    }
}

impl<T: Scalar> Preconditioner<T> for Ilu0<T> {
    fn apply(&self, r: &[T], z: &mut [T]) {
        let mut y = vec![T::ZERO; r.len()];
        match &self.levels {
            Some(lv) => {
                sptrsv_lower_levels(
                    &self.lower,
                    &self.ones,
                    &lv.fwd,
                    &lv.pool,
                    r,
                    &mut y,
                );
                sptrsv_upper_levels(
                    &self.upper,
                    &self.udiag,
                    &lv.bwd,
                    &lv.pool,
                    &y,
                    z,
                );
            }
            None => {
                sptrsv_lower_block(&self.lower_block, &self.ones, r, &mut y);
                sptrsv_upper_block(&self.upper_block, &self.udiag, &y, z);
            }
        }
    }
    fn name(&self) -> String {
        "ilu0".into()
    }
    fn level_summary(&self) -> Option<LevelSummary> {
        Some(self.summary)
    }
}

/// Builds (or reuses) the level-scheduling decision for a pair of
/// triangles. Returns the summary to persist and the schedules when
/// parallel execution won.
fn schedule_triangles<T: Scalar>(
    lower: &Csr<T>,
    upper: &Csr<T>,
    pool: Option<&Arc<WorkerPool>>,
    planned: Option<LevelSummary>,
) -> (LevelSummary, Option<SolveLevels>) {
    let threads = pool.map_or(1, |p| p.n_threads());
    match (planned, pool) {
        // Planned sequential: trust the decision, skip the analysis.
        (Some(s), _) if !s.parallel => (s, None),
        (Some(s), None) => (LevelSummary { parallel: false, ..s }, None),
        // Planned parallel with a pool: rebuild the (cheap) level
        // sets, keep the decision.
        (Some(s), Some(pool)) => {
            let fwd = lower_levels(lower);
            let bwd = upper_levels(upper);
            (
                s,
                Some(SolveLevels { fwd, bwd, pool: Arc::clone(pool) }),
            )
        }
        (None, _) => {
            let fwd = lower_levels(lower);
            let parallel =
                pool.is_some() && fwd.parallel_worthwhile(threads);
            let summary = fwd.summary(parallel);
            let levels = if parallel {
                Some(SolveLevels {
                    fwd,
                    bwd: upper_levels(upper),
                    pool: Arc::clone(pool.unwrap()),
                })
            } else {
                None
            };
            (summary, levels)
        }
    }
}

/// ILU(0): incomplete LU on `A`'s pattern. Returns the strict lower
/// triangle of `L` (unit diagonal implied), the strict upper triangle
/// of `U`, and `U`'s diagonal.
#[allow(clippy::type_complexity)]
fn ilu0_factor<T: Scalar>(
    csr: &Csr<T>,
) -> Result<(Csr<T>, Csr<T>, Vec<T>), PrecondError> {
    let n = csr.rows;
    if csr.rows != csr.cols {
        return Err(PrecondError::NotSquare {
            rows: csr.rows,
            cols: csr.cols,
        });
    }
    // Diagonal positions up front: a structurally missing pivot is an
    // immediate error.
    let mut diag_pos = vec![usize::MAX; n];
    for r in 0..n {
        for k in csr.row_range(r) {
            if csr.colidx[k] as usize == r {
                diag_pos[r] = k;
            }
        }
        if diag_pos[r] == usize::MAX {
            return Err(PrecondError::ZeroPivot { row: r });
        }
    }
    let mut luval = csr.values.clone();
    // Dense column → position scatter for the current row (usize::MAX
    // = column absent from the row's pattern).
    let mut pos = vec![usize::MAX; n];
    for i in 0..n {
        for k in csr.row_range(i) {
            pos[csr.colidx[k] as usize] = k;
        }
        // IKJ: eliminate with every row k < i present in row i's
        // pattern, in ascending column order (CSR columns are sorted).
        for kk in csr.row_range(i) {
            let k = csr.colidx[kk] as usize;
            if k >= i {
                break;
            }
            let ukk = luval[diag_pos[k]];
            if ukk == T::ZERO {
                return Err(PrecondError::ZeroPivot { row: k });
            }
            let lik = luval[kk] / ukk;
            luval[kk] = lik;
            for jj in diag_pos[k] + 1..csr.rowptr[k + 1] as usize {
                let p = pos[csr.colidx[jj] as usize];
                if p != usize::MAX {
                    luval[p] -= lik * luval[jj];
                }
            }
        }
        if luval[diag_pos[i]] == T::ZERO {
            return Err(PrecondError::ZeroPivot { row: i });
        }
        for k in csr.row_range(i) {
            pos[csr.colidx[k] as usize] = usize::MAX;
        }
    }
    // Split the in-place factor into L (strict lower) / U (diag +
    // strict upper).
    let mut lo_ptr = Vec::with_capacity(n + 1);
    let mut lo_ci = Vec::new();
    let mut lo_v = Vec::new();
    let mut up_ptr = Vec::with_capacity(n + 1);
    let mut up_ci = Vec::new();
    let mut up_v = Vec::new();
    let mut udiag = vec![T::ZERO; n];
    lo_ptr.push(0u32);
    up_ptr.push(0u32);
    for r in 0..n {
        for k in csr.row_range(r) {
            let c = csr.colidx[k] as usize;
            match c.cmp(&r) {
                std::cmp::Ordering::Less => {
                    lo_ci.push(c as u32);
                    lo_v.push(luval[k]);
                }
                std::cmp::Ordering::Equal => udiag[r] = luval[k],
                std::cmp::Ordering::Greater => {
                    up_ci.push(c as u32);
                    up_v.push(luval[k]);
                }
            }
        }
        lo_ptr.push(lo_ci.len() as u32);
        up_ptr.push(up_ci.len() as u32);
    }
    let lower = Csr {
        rows: n,
        cols: n,
        rowptr: lo_ptr,
        colidx: lo_ci,
        values: lo_v,
    };
    let upper = Csr {
        rows: n,
        cols: n,
        rowptr: up_ptr,
        colidx: up_ci,
        values: up_v,
    };
    Ok((lower, upper, udiag))
}

/// Parsed preconditioner choice — the CLI/plan-level name for a
/// preconditioner, buildable against any matrix.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrecondKind {
    /// No preconditioning (identity `M`).
    None,
    /// Diagonal scaling.
    Jacobi,
    /// Symmetric Gauss–Seidel with the given sweep count.
    SymGs {
        /// Forward+backward sweep pairs per application.
        sweeps: usize,
    },
    /// Incomplete LU on the matrix's own pattern.
    Ilu0,
}

impl PrecondKind {
    /// Parses `none`, `jacobi`, `symgs` (= 1 sweep), `symgs(n)`,
    /// `ilu0`. Trailing garbage is rejected.
    pub fn parse(s: &str) -> Option<PrecondKind> {
        let t = s.trim().to_ascii_lowercase();
        match t.as_str() {
            "none" | "identity" => return Some(PrecondKind::None),
            "jacobi" => return Some(PrecondKind::Jacobi),
            "symgs" => return Some(PrecondKind::SymGs { sweeps: 1 }),
            "ilu0" => return Some(PrecondKind::Ilu0),
            _ => {}
        }
        let inner = t.strip_prefix("symgs(")?.strip_suffix(')')?;
        let sweeps: usize = inner.trim().parse().ok()?;
        if sweeps == 0 {
            return None;
        }
        Some(PrecondKind::SymGs { sweeps })
    }

    /// Whether triangular solves (and hence a level schedule) are
    /// involved.
    pub fn has_levels(&self) -> bool {
        matches!(self, PrecondKind::SymGs { .. } | PrecondKind::Ilu0)
    }

    /// Builds the preconditioner against `csr`, analyzing the level
    /// structure from scratch.
    pub fn build<T: Scalar>(
        &self,
        csr: &Csr<T>,
        pool: Option<&Arc<WorkerPool>>,
    ) -> Result<Box<dyn Preconditioner<T>>, PrecondError> {
        self.build_planned(csr, pool, None)
    }

    /// Builds the preconditioner reusing a persisted level-schedule
    /// decision (from a [`super::SolvePlan`]); `None` falls back to
    /// fresh analysis.
    pub fn build_planned<T: Scalar>(
        &self,
        csr: &Csr<T>,
        pool: Option<&Arc<WorkerPool>>,
        planned: Option<LevelSummary>,
    ) -> Result<Box<dyn Preconditioner<T>>, PrecondError> {
        Ok(match *self {
            PrecondKind::None => Box::new(IdentityPrecond),
            PrecondKind::Jacobi => Box::new(Jacobi::new(csr)?),
            PrecondKind::SymGs { sweeps } => {
                Box::new(SymGs::with_decision(csr, sweeps, pool, planned)?)
            }
            PrecondKind::Ilu0 => {
                Box::new(Ilu0::with_decision(csr, pool, planned)?)
            }
        })
    }
}

impl std::fmt::Display for PrecondKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            PrecondKind::None => write!(f, "none"),
            PrecondKind::Jacobi => write!(f, "jacobi"),
            PrecondKind::SymGs { sweeps } => write!(f, "symgs({sweeps})"),
            PrecondKind::Ilu0 => write!(f, "ilu0"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::suite;

    #[test]
    fn parse_and_display_round_trip() {
        for k in [
            PrecondKind::None,
            PrecondKind::Jacobi,
            PrecondKind::SymGs { sweeps: 1 },
            PrecondKind::SymGs { sweeps: 3 },
            PrecondKind::Ilu0,
        ] {
            assert_eq!(PrecondKind::parse(&k.to_string()), Some(k));
        }
        assert_eq!(
            PrecondKind::parse("symgs"),
            Some(PrecondKind::SymGs { sweeps: 1 })
        );
        assert_eq!(PrecondKind::parse("symgs(0)"), None);
        assert_eq!(PrecondKind::parse("symgs(2)x"), None);
        assert_eq!(PrecondKind::parse("nope"), None);
    }

    #[test]
    fn jacobi_rejects_zero_and_missing_diagonal() {
        // Row 1 has an explicit zero diagonal.
        let a = Csr::from_raw(
            2,
            2,
            vec![0, 1, 3],
            vec![0, 0, 1],
            vec![2.0, 1.0, 0.0],
        )
        .unwrap();
        assert_eq!(
            Jacobi::new(&a).err(),
            Some(PrecondError::ZeroDiagonal { row: 1 })
        );
        // Row 0 has no diagonal entry at all.
        let b = Csr::from_raw(2, 2, vec![0, 1, 2], vec![1, 1], vec![1.0, 1.0])
            .unwrap();
        assert_eq!(
            Jacobi::new(&b).err(),
            Some(PrecondError::ZeroDiagonal { row: 0 })
        );
    }

    #[test]
    fn ilu0_is_exact_for_triangular_pattern_fill() {
        // On a tridiagonal matrix ILU(0) is a *complete* LU (no fill
        // outside the pattern exists), so M⁻¹·r solves A·z = r
        // exactly: check A·z ≈ r.
        let n = 64usize;
        let mut rowptr = vec![0u32];
        let mut colidx = Vec::new();
        let mut values = Vec::new();
        for i in 0..n {
            if i > 0 {
                colidx.push((i - 1) as u32);
                values.push(-1.0);
            }
            colidx.push(i as u32);
            values.push(2.0);
            if i + 1 < n {
                colidx.push((i + 1) as u32);
                values.push(-1.0);
            }
            rowptr.push(colidx.len() as u32);
        }
        let a = Csr::from_raw(n, n, rowptr, colidx, values).unwrap();
        let m = Ilu0::new(&a, None).unwrap();
        let n = a.rows;
        let r: Vec<f64> = (0..n).map(|i| ((i * 3) % 5) as f64 - 2.0).collect();
        let mut z = vec![0.0; n];
        m.apply(&r, &mut z);
        let mut az = vec![0.0; n];
        a.spmv_ref(&z, &mut az);
        for i in 0..n {
            assert!((az[i] - r[i]).abs() < 1e-10, "row {i}");
        }
    }

    #[test]
    fn ilu0_reports_zero_pivot() {
        // A singular leading 1x1 block: a11 = 0 with no lower
        // neighbors → pivot 0.
        let a = Csr::from_raw(
            2,
            2,
            vec![0, 2, 4],
            vec![0, 1, 0, 1],
            vec![0.0, 1.0, 1.0, 1.0],
        )
        .unwrap();
        assert_eq!(
            Ilu0::<f64>::new(&a, None).err(),
            Some(PrecondError::ZeroPivot { row: 0 })
        );
    }

    #[test]
    fn symgs_apply_matches_direct_sweeps() {
        let a = suite::poisson2d(10);
        let split = a.triangular_split().unwrap();
        let m = SymGs::new(&a, 2, None).unwrap();
        let n = a.rows;
        let r: Vec<f64> = (0..n).map(|i| (i % 4) as f64 - 1.5).collect();
        let mut z = vec![0.0; n];
        m.apply(&r, &mut z);
        let mut want = vec![0.0; n];
        crate::kernels::symgs::symgs(&split, &r, &mut want, 2);
        assert_eq!(z, want);
        assert!(m.level_summary().is_some());
    }

    #[test]
    fn planned_sequential_build_skips_analysis_but_matches() {
        let a = suite::poisson2d(12);
        let kind = PrecondKind::SymGs { sweeps: 1 };
        let fresh = kind.build(&a, None).unwrap();
        let summary = fresh.level_summary().unwrap();
        assert!(!summary.parallel);
        let planned = kind.build_planned(&a, None, Some(summary)).unwrap();
        let n = a.rows;
        let r: Vec<f64> = (0..n).map(|i| (i % 3) as f64).collect();
        let mut z1 = vec![0.0; n];
        fresh.apply(&r, &mut z1);
        let mut z2 = vec![0.0; n];
        planned.apply(&r, &mut z2);
        assert_eq!(z1, z2);
    }

    #[test]
    fn parallel_and_sequential_ilu0_agree_bitwise() {
        let a = suite::poisson2d(48);
        let pool = Arc::new(WorkerPool::new(4));
        let seq = Ilu0::new(&a, None).unwrap();
        let par = Ilu0::new(&a, Some(&pool)).unwrap();
        assert!(par.level_summary().unwrap().parallel);
        let n = a.rows;
        let r: Vec<f64> = (0..n).map(|i| ((i * 7) % 11) as f64 - 5.0).collect();
        let mut z1 = vec![0.0; n];
        seq.apply(&r, &mut z1);
        let mut z2 = vec![0.0; n];
        par.apply(&r, &mut z2);
        assert_eq!(z1, z2);
    }
}
