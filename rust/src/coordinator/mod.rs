//! L3 coordination: the [`SpmvEngine`] facade (inspect → plan →
//! instantiate → execute, built through the fluent
//! [`SpmvEngine::builder`] and serving every [`crate::KernelKind`]),
//! the serializable [`SpmvPlan`] / [`PlanCache`] inspector–executor
//! artifacts, the native Krylov solvers, and the request-loop service
//! used by the `spmv_server` example. All of it generic over the
//! precision ([`crate::scalar::Scalar`], `f64` by default).

pub mod cg;
pub mod engine;
pub mod plan;
pub mod service;
pub mod solvers;

pub use cg::{cg_solve, CgReport};
pub use engine::{SpmvEngine, SpmvEngineBuilder};
pub use plan::{MatrixFingerprint, PlanCache, SpmvPlan};
pub use service::{
    Request, Response, ServiceError, ServiceStats, SpmvService,
};
pub use solvers::{bicgstab, pcg_jacobi};
