//! L3 coordination: the [`SpmvEngine`] facade (inspect → plan →
//! instantiate → execute, built through the fluent
//! [`SpmvEngine::builder`] and serving every [`crate::KernelKind`]),
//! the serializable [`SpmvPlan`] / [`PlanCache`] inspector–executor
//! artifacts, the native Krylov solvers with their plan-aware
//! preconditioners ([`Preconditioner`] in [`precond`], persisted as
//! [`SolvePlan`]s), and the serving tier: the
//! micro-batching [`SpmvService`], the admission-control primitives
//! ([`QueuePolicy`] and friends in [`serving`]), the row-sharded
//! [`ShardedService`] front-end, and the fingerprint-keyed
//! [`TenantRegistry`] that hosts many matrices in one process. All of
//! it generic over the precision ([`crate::scalar::Scalar`], `f64` by
//! default).

pub mod cg;
pub mod cluster;
pub mod engine;
pub mod plan;
pub mod precond;
pub mod service;
pub mod serving;
pub mod solve_plan;
pub mod solvers;
pub mod tenant;

pub use cg::{cg_solve, CgReport};
pub use cluster::{
    ClusterStats, RestartBudget, ShardConfig, ShardedService,
    SHARD_ROW_ALIGN,
};
pub use engine::{SpmvEngine, SpmvEngineBuilder};
pub use plan::{MatrixFingerprint, PlanCache, SpmvPlan};
pub use service::{
    HealthReport, LatencyPercentiles, RecvError, RecvTimeoutError, Request,
    Response, ServiceError, ServiceStats, ShardHealth, SpmvService,
    LATENCY_WINDOW,
};
pub use serving::{
    AdmissionGate, BoundedQueue, PushError, QueuePolicy,
    DEFAULT_QUEUE_CAPACITY,
};
pub use precond::{
    IdentityPrecond, Ilu0, Jacobi, PrecondError, PrecondKind, Preconditioner,
    SymGs,
};
pub use solve_plan::{
    solve_from_plan, SolvePlan, SolverKind, SOLVE_PLAN_VERSION,
};
pub use solvers::{bicgstab, pcg_jacobi, pcg_with};
pub use tenant::{RegistryStats, TenantConfig, TenantRegistry, TenantStats};
