//! L3 coordination: the [`SpmvEngine`] facade (stats → predict →
//! convert → dispatch), the native CG solver, and the request-loop
//! service used by the `spmv_server` example.

pub mod cg;
pub mod engine;
pub mod service;
pub mod solvers;

pub use cg::{cg_solve, CgReport};
pub use engine::{EngineConfig, SpmvEngine};
pub use service::{Request, Response, SpmvService};
pub use solvers::{bicgstab, pcg_jacobi};
