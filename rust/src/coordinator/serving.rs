//! Admission control primitives for the serving tier.
//!
//! The original `SpmvService` fed its dispatcher through an unbounded
//! `mpsc::channel`: a client faster than the engine would grow the
//! queue (and resident memory) without limit, and `submit` could never
//! say "no". This module replaces that with explicit admission
//! control:
//!
//! - [`QueuePolicy`] — what happens when the service already holds
//!   `capacity` in-flight requests: `Block` the submitter, `Reject`
//!   immediately, or wait up to a `Timeout` then reject.
//! - [`BoundedQueue`] — a Mutex+Condvar MPMC queue whose *in-flight*
//!   count (accepted but not yet delivered back to the client) is
//!   capped at `capacity`. The dispatcher `pop`s work; the slot is
//!   only freed by [`BoundedQueue::release`] when the client receives
//!   the response, so `capacity` bounds end-to-end outstanding work —
//!   including computed-but-undelivered responses.
//! - [`AdmissionGate`] — the same policy logic without a queue; the
//!   sharded front-end admits once at the cluster edge and then fans
//!   out to per-shard queues that are guaranteed never to fill.
//!
//! Closing either primitive wakes every blocked submitter with
//! [`PushError::Closed`] (the caller gets an error, nothing is
//! silently dropped) while already-accepted items continue to drain
//! through `pop` until empty.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Default in-flight cap when callers do not choose one.
pub const DEFAULT_QUEUE_CAPACITY: usize = 1024;

/// What `submit` does once `capacity` requests are in flight.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QueuePolicy {
    /// Wait until a slot frees (backpressure; never drops). A single
    /// thread that submits past `capacity` without receiving responses
    /// will wait forever — pair blocking submission with a consumer.
    Block {
        /// Maximum in-flight requests.
        capacity: usize,
    },
    /// Fail fast with `Overloaded` while full (load shedding).
    Reject {
        /// Maximum in-flight requests.
        capacity: usize,
    },
    /// Wait up to `wait` for a slot, then fail with `Overloaded`.
    Timeout {
        /// Maximum in-flight requests.
        capacity: usize,
        /// Longest time a submitter may wait for admission.
        wait: Duration,
    },
}

impl QueuePolicy {
    /// The in-flight cap, regardless of the overflow behavior.
    pub fn capacity(&self) -> usize {
        match *self {
            QueuePolicy::Block { capacity }
            | QueuePolicy::Reject { capacity }
            | QueuePolicy::Timeout { capacity, .. } => capacity,
        }
    }
}

impl Default for QueuePolicy {
    /// Backpressure with a generous cap — the closest behavior to the
    /// old unbounded channel that still bounds memory.
    fn default() -> Self {
        QueuePolicy::Block { capacity: DEFAULT_QUEUE_CAPACITY }
    }
}

/// Why an admission attempt failed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PushError {
    /// The policy gave up while `capacity` requests were in flight
    /// (`Reject` immediately, `Timeout` after its deadline).
    Full,
    /// The queue was closed (service shut down).
    Closed,
}

struct QueueInner<M> {
    items: VecDeque<M>,
    in_flight: usize,
    high_water: usize,
    closed: bool,
}

/// Bounded MPMC queue with policy-controlled admission.
///
/// A slot is held from successful [`push`](Self::push) until
/// [`release`](Self::release) — *not* until `pop` — so the capacity
/// bounds everything the service still owes a response for.
pub struct BoundedQueue<M> {
    policy: QueuePolicy,
    inner: Mutex<QueueInner<M>>,
    /// Signalled by `release` / `close`; awaited by blocked pushers.
    not_full: Condvar,
    /// Signalled by `push` / `close`; awaited by `pop`.
    not_empty: Condvar,
}

fn relock<G>(r: Result<G, std::sync::PoisonError<G>>) -> G {
    r.unwrap_or_else(|e| e.into_inner())
}

impl<M> BoundedQueue<M> {
    /// Creates an empty queue. Panics on a zero capacity, which could
    /// never admit anything.
    pub fn new(policy: QueuePolicy) -> Self {
        assert!(policy.capacity() > 0, "queue capacity must be >= 1");
        BoundedQueue {
            policy,
            inner: Mutex::new(QueueInner {
                items: VecDeque::new(),
                in_flight: 0,
                high_water: 0,
                closed: false,
            }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
        }
    }

    fn lock(&self) -> MutexGuard<'_, QueueInner<M>> {
        relock(self.inner.lock())
    }

    /// Tries to admit `item` under the queue's policy. On success the
    /// in-flight count has been incremented and the dispatcher has
    /// been woken.
    pub fn push(&self, item: M) -> Result<(), PushError> {
        let cap = self.policy.capacity();
        // Deadline is fixed at entry so repeated wakeups cannot extend
        // the wait. `None` for non-timeout policies (or an unbounded
        // `wait` overflowing `Instant`), meaning "wait forever".
        let deadline = match self.policy {
            QueuePolicy::Timeout { wait, .. } => Instant::now().checked_add(wait),
            _ => None,
        };
        let mut g = self.lock();
        loop {
            if g.closed {
                return Err(PushError::Closed);
            }
            if g.in_flight < cap {
                break;
            }
            g = match self.policy {
                QueuePolicy::Reject { .. } => return Err(PushError::Full),
                QueuePolicy::Block { .. } => relock(self.not_full.wait(g)),
                QueuePolicy::Timeout { .. } => match deadline {
                    None => relock(self.not_full.wait(g)),
                    Some(dl) => {
                        let now = Instant::now();
                        if now >= dl {
                            return Err(PushError::Full);
                        }
                        relock(self.not_full.wait_timeout(g, dl - now)).0
                    }
                },
            };
        }
        g.in_flight += 1;
        if g.in_flight > g.high_water {
            g.high_water = g.in_flight;
        }
        g.items.push_back(item);
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocks for the next item. Returns `None` only once the queue is
    /// closed **and** drained, so accepted work always reaches the
    /// dispatcher even during shutdown.
    pub fn pop(&self) -> Option<M> {
        let mut g = self.lock();
        loop {
            if let Some(m) = g.items.pop_front() {
                return Some(m);
            }
            if g.closed {
                return None;
            }
            g = relock(self.not_empty.wait(g));
        }
    }

    /// Non-blocking pop (batch coalescing).
    pub fn try_pop(&self) -> Option<M> {
        self.lock().items.pop_front()
    }

    /// Frees one in-flight slot (the client received its response) and
    /// wakes one blocked pusher.
    pub fn release(&self) {
        let mut g = self.lock();
        g.in_flight = g.in_flight.saturating_sub(1);
        drop(g);
        self.not_full.notify_one();
    }

    /// Closes the queue: every blocked pusher wakes with
    /// [`PushError::Closed`]; `pop` keeps draining accepted items.
    pub fn close(&self) {
        let mut g = self.lock();
        g.closed = true;
        drop(g);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Queued (not yet popped) items.
    pub fn len(&self) -> usize {
        self.lock().items.len()
    }

    /// Whether no items are waiting for the dispatcher.
    pub fn is_empty(&self) -> bool {
        self.lock().items.is_empty()
    }

    /// Accepted-but-unreleased requests.
    pub fn in_flight(&self) -> usize {
        self.lock().in_flight
    }

    /// Highest in-flight count ever observed (≤ capacity).
    pub fn high_water(&self) -> usize {
        self.lock().high_water
    }

    /// The admission cap.
    pub fn capacity(&self) -> usize {
        self.policy.capacity()
    }

    /// The admission policy.
    pub fn policy(&self) -> QueuePolicy {
        self.policy
    }
}

struct GateInner {
    in_flight: usize,
    high_water: usize,
    closed: bool,
}

/// Counter-only admission control: the same policy semantics as
/// [`BoundedQueue`] without carrying items. The sharded front-end
/// acquires here once per request before fanning out, and releases
/// when the assembled response is handed to the client.
pub struct AdmissionGate {
    policy: QueuePolicy,
    inner: Mutex<GateInner>,
    freed: Condvar,
}

impl AdmissionGate {
    /// Creates an open gate. Panics on a zero capacity.
    pub fn new(policy: QueuePolicy) -> Self {
        assert!(policy.capacity() > 0, "gate capacity must be >= 1");
        AdmissionGate {
            policy,
            inner: Mutex::new(GateInner {
                in_flight: 0,
                high_water: 0,
                closed: false,
            }),
            freed: Condvar::new(),
        }
    }

    fn lock(&self) -> MutexGuard<'_, GateInner> {
        relock(self.inner.lock())
    }

    /// Claims one in-flight slot under the gate's policy.
    pub fn acquire(&self) -> Result<(), PushError> {
        let cap = self.policy.capacity();
        let deadline = match self.policy {
            QueuePolicy::Timeout { wait, .. } => Instant::now().checked_add(wait),
            _ => None,
        };
        let mut g = self.lock();
        loop {
            if g.closed {
                return Err(PushError::Closed);
            }
            if g.in_flight < cap {
                break;
            }
            g = match self.policy {
                QueuePolicy::Reject { .. } => return Err(PushError::Full),
                QueuePolicy::Block { .. } => relock(self.freed.wait(g)),
                QueuePolicy::Timeout { .. } => match deadline {
                    None => relock(self.freed.wait(g)),
                    Some(dl) => {
                        let now = Instant::now();
                        if now >= dl {
                            return Err(PushError::Full);
                        }
                        relock(self.freed.wait_timeout(g, dl - now)).0
                    }
                },
            };
        }
        g.in_flight += 1;
        if g.in_flight > g.high_water {
            g.high_water = g.in_flight;
        }
        Ok(())
    }

    /// Returns one slot and wakes one blocked acquirer.
    pub fn release(&self) {
        let mut g = self.lock();
        g.in_flight = g.in_flight.saturating_sub(1);
        drop(g);
        self.freed.notify_one();
    }

    /// Closes the gate; every blocked acquirer wakes with
    /// [`PushError::Closed`].
    pub fn close(&self) {
        let mut g = self.lock();
        g.closed = true;
        drop(g);
        self.freed.notify_all();
    }

    /// Currently claimed slots.
    pub fn in_flight(&self) -> usize {
        self.lock().in_flight
    }

    /// Highest claimed count ever observed (≤ capacity).
    pub fn high_water(&self) -> usize {
        self.lock().high_water
    }

    /// The admission cap.
    pub fn capacity(&self) -> usize {
        self.policy.capacity()
    }

    /// The admission policy.
    pub fn policy(&self) -> QueuePolicy {
        self.policy
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn reject_is_exact_at_capacity() {
        let q = BoundedQueue::new(QueuePolicy::Reject { capacity: 3 });
        for i in 0..3 {
            assert_eq!(q.push(i), Ok(()));
        }
        assert_eq!(q.push(99), Err(PushError::Full));
        assert_eq!(q.in_flight(), 3);
        assert_eq!(q.high_water(), 3);
        // Popping does NOT free the slot …
        assert_eq!(q.pop(), Some(0));
        assert_eq!(q.push(99), Err(PushError::Full));
        // … releasing does.
        q.release();
        assert_eq!(q.push(99), Ok(()));
        assert_eq!(q.high_water(), 3);
    }

    #[test]
    fn timeout_waits_full_deadline_then_rejects() {
        let wait = Duration::from_millis(40);
        let q = BoundedQueue::new(QueuePolicy::Timeout { capacity: 1, wait });
        q.push(1u32).unwrap();
        let t0 = Instant::now();
        assert_eq!(q.push(2), Err(PushError::Full));
        assert!(
            t0.elapsed() >= wait,
            "rejected after {:?}, before the {wait:?} deadline",
            t0.elapsed()
        );
        q.release();
        assert_eq!(q.push(2), Ok(()));
    }

    #[test]
    fn timeout_admits_when_slot_frees_in_time() {
        let q = std::sync::Arc::new(BoundedQueue::new(QueuePolicy::Timeout {
            capacity: 1,
            wait: Duration::from_secs(10),
        }));
        q.push(1u32).unwrap();
        let q2 = q.clone();
        let freer = thread::spawn(move || {
            thread::sleep(Duration::from_millis(20));
            q2.release();
        });
        // Admitted long before the 10 s deadline.
        assert_eq!(q.push(2), Ok(()));
        freer.join().unwrap();
    }

    #[test]
    fn block_waits_until_released() {
        let q = BoundedQueue::new(QueuePolicy::Block { capacity: 1 });
        q.push(10u32).unwrap();
        thread::scope(|s| {
            s.spawn(|| {
                // Blocks until the main thread releases, then succeeds.
                assert_eq!(q.push(11), Ok(()));
            });
            thread::sleep(Duration::from_millis(20));
            q.release();
        });
        assert_eq!(q.in_flight(), 1);
        assert_eq!(q.pop(), Some(10));
        assert_eq!(q.pop().or_else(|| q.try_pop()), Some(11));
    }

    #[test]
    fn close_unblocks_pushers_and_drains_accepted_items() {
        let q = BoundedQueue::new(QueuePolicy::Block { capacity: 1 });
        q.push(1u32).unwrap();
        thread::scope(|s| {
            s.spawn(|| {
                // Either blocked at close time or sees `closed` on
                // entry — both must yield Closed, never a hang or a
                // silent drop.
                assert_eq!(q.push(2), Err(PushError::Closed));
            });
            thread::sleep(Duration::from_millis(20));
            q.close();
        });
        // The accepted item still drains after close …
        assert_eq!(q.pop(), Some(1));
        // … and then pop reports exhaustion instead of blocking.
        assert_eq!(q.pop(), None);
        assert_eq!(q.push(3), Err(PushError::Closed));
    }

    #[test]
    fn gate_mirrors_queue_semantics() {
        let gate = AdmissionGate::new(QueuePolicy::Reject { capacity: 2 });
        assert_eq!(gate.acquire(), Ok(()));
        assert_eq!(gate.acquire(), Ok(()));
        assert_eq!(gate.acquire(), Err(PushError::Full));
        assert_eq!(gate.high_water(), 2);
        gate.release();
        assert_eq!(gate.acquire(), Ok(()));
        gate.close();
        assert_eq!(gate.acquire(), Err(PushError::Closed));
        assert_eq!(gate.high_water(), 2);
    }

    #[test]
    fn gate_block_wakes_on_release_and_close() {
        let gate = AdmissionGate::new(QueuePolicy::Block { capacity: 1 });
        gate.acquire().unwrap();
        thread::scope(|s| {
            s.spawn(|| assert_eq!(gate.acquire(), Ok(())));
            thread::sleep(Duration::from_millis(20));
            gate.release();
        });
        thread::scope(|s| {
            s.spawn(|| assert_eq!(gate.acquire(), Err(PushError::Closed)));
            thread::sleep(Duration::from_millis(20));
            gate.close();
        });
    }

    #[test]
    fn default_policy_is_bounded_block() {
        let p = QueuePolicy::default();
        assert_eq!(p, QueuePolicy::Block { capacity: DEFAULT_QUEUE_CAPACITY });
        assert_eq!(p.capacity(), DEFAULT_QUEUE_CAPACITY);
    }
}
