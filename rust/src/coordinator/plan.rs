//! Inspector–executor plan artifacts: [`SpmvPlan`],
//! [`MatrixFingerprint`] and the persistent [`PlanCache`].
//!
//! The paper's practical claim is that the best kernel can be
//! *predicted from previous executions* — but a prediction that lives
//! only inside an opaque `build()` call is re-paid on every repeat
//! workload and can be neither inspected nor shipped. Following MKL's
//! inspector–executor split (the paper's comparison target) and the
//! format-selection literature, the plan is a first-class artifact:
//!
//! 1. **inspect** — [`crate::SpmvEngineBuilder::plan`] runs the cheap
//!    scans, the predictor and the hybrid panel ranking, converting
//!    nothing, and returns a plain [`SpmvPlan`];
//! 2. **serialize** — [`SpmvPlan::to_json`] / [`SpmvPlan::from_json`]
//!    round-trip the plan through serde-free JSON (the vendor set has
//!    no serde), so plans travel between processes and machines;
//! 3. **instantiate** — [`crate::SpmvEngine::from_plan`] converts the
//!    storage exactly as planned, skipping selection entirely. A
//!    [`MatrixFingerprint`] recorded in the plan refuses instantiation
//!    against the wrong matrix;
//! 4. **cache** — [`PlanCache`] persists `{fingerprint → plan}` as a
//!    JSON store ([`crate::SpmvEngineBuilder::plan_cache`]), so the
//!    predictor's "previous executions" survive as *executable plans*,
//!    not just performance records.
//!
//! The plan records every decision the builder makes: the kernel kind
//! (with resolved block size), the resolved column tile width, the
//! compiled hybrid row-panel schedule (per-segment row range and
//! kernel — so instantiation reproduces the schedule bit-for-bit
//! without the predictor's fitted surfaces), the reorder kind, thread
//! count, NUMA split and the predicted GFlop/s.

use crate::formats::stats::count_blocks;
use crate::formats::{BlockSize, PanelKernel, ScheduleEntry};
use crate::kernels::{KernelKind, TuneParams};
use crate::matrix::reorder::ReorderKind;
use crate::matrix::Csr;
use crate::scalar::Scalar;
use crate::util::durable::{self, RawState, StateError, StateErrorKind};
use crate::util::json::Json;
use std::path::Path;

/// Current plan schema version.
pub const PLAN_VERSION: u32 = 1;

/// A cheap structural identity of a sparse matrix: dimensions, nnz and
/// a hash of the block-occupancy profile (the six paper-size block
/// counts — the same no-conversion scans the predictor features on)
/// mixed with the element precision. Value-blind by design: every
/// decision a plan records depends only on structure, so two matrices
/// with identical sparsity patterns share plans — but **not** across
/// precisions (resolved tile widths and valid β sizes differ between
/// f32 and f64, so an f32 plan must refuse an f64 build rather than
/// fail inside conversion).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct MatrixFingerprint {
    pub rows: usize,
    pub cols: usize,
    pub nnz: usize,
    /// FNV-1a over the scalar's byte width and the `β(r,c)` block
    /// counts of [`BlockSize::PAPER_SIZES`].
    pub stats_hash: u64,
}

impl MatrixFingerprint {
    /// Computes the fingerprint with the cheap block-count scans (no
    /// conversion).
    pub fn of<T: Scalar>(csr: &Csr<T>) -> MatrixFingerprint {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut mix = |v: u64| {
            for byte in v.to_le_bytes() {
                h ^= byte as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01b3);
            }
        };
        mix(std::mem::size_of::<T>() as u64);
        for bs in BlockSize::PAPER_SIZES {
            mix(count_blocks(csr, bs) as u64);
        }
        MatrixFingerprint {
            rows: csr.rows,
            cols: csr.cols,
            nnz: csr.nnz(),
            stats_hash: h,
        }
    }

    /// A short stable key string (used by [`PlanCache`] reporting and
    /// error messages).
    pub fn key(&self) -> String {
        format!(
            "{}x{}/{}nnz/{:016x}",
            self.rows, self.cols, self.nnz, self.stats_hash
        )
    }

    fn to_json(self) -> Json {
        Json::obj(vec![
            ("rows", Json::Num(self.rows as f64)),
            ("cols", Json::Num(self.cols as f64)),
            ("nnz", Json::Num(self.nnz as f64)),
            // u64 exceeds f64's 2^53 integer range: store as hex text.
            ("stats_hash", Json::Str(format!("{:016x}", self.stats_hash))),
        ])
    }

    fn from_json(v: &Json) -> anyhow::Result<MatrixFingerprint> {
        let num = |k: &str| -> anyhow::Result<f64> {
            v.get(k)
                .and_then(|n| n.as_f64())
                .ok_or_else(|| anyhow::anyhow!("fingerprint: missing {k}"))
        };
        let dim = |k: &str| -> anyhow::Result<usize> {
            let n = num(k)?;
            anyhow::ensure!(
                n >= 0.0 && n.fract() == 0.0,
                "fingerprint: {k} must be a non-negative integer, got {n}"
            );
            Ok(n as usize)
        };
        let hash_s = v
            .get("stats_hash")
            .and_then(|s| s.as_str())
            .ok_or_else(|| anyhow::anyhow!("fingerprint: missing stats_hash"))?;
        let stats_hash = u64::from_str_radix(hash_s, 16)
            .map_err(|_| anyhow::anyhow!("fingerprint: bad stats_hash '{hash_s}'"))?;
        Ok(MatrixFingerprint {
            rows: dim("rows")?,
            cols: dim("cols")?,
            nnz: dim("nnz")?,
            stats_hash,
        })
    }
}

/// Every decision an engine build makes, as a plain serializable
/// record — see the module docs for the lifecycle.
#[derive(Clone, Debug, PartialEq)]
pub struct SpmvPlan {
    /// Schema version ([`PLAN_VERSION`]).
    pub version: u32,
    /// Identity of the matrix this plan was inspected on;
    /// [`crate::SpmvEngine::from_plan`] refuses any other matrix.
    pub fingerprint: MatrixFingerprint,
    /// The selected kernel, block size resolved (e.g. `b(4,8)`,
    /// `hybrid`, `tiled(4096)`).
    pub kernel: KernelKind,
    /// Worker threads the engine will run with (1 = sequential).
    pub threads: usize,
    /// NUMA-style array splitting for the parallel β path.
    pub numa_split: bool,
    /// Build-time reordering applied before profiling and conversion.
    pub reorder: Option<ReorderKind>,
    /// Rows per panel for the hybrid/tiled schedules.
    pub panel_rows: usize,
    /// Resolved column tile width when the plan executes cache-blocked
    /// (`None` = flat schedule). Auto-sizing is resolved at *plan*
    /// time, so instantiation does not depend on the executing
    /// machine's detected cache.
    pub tile_cols: Option<usize>,
    /// Predicted GFlop/s when the predictor made the choice.
    pub predicted_gflops: Option<f64>,
    /// Resolved kernel variant for the β hot loops (`None` = the
    /// process default, i.e. the baseline variant). Like `tile_cols`,
    /// this is a machine-dependent choice resolved at *plan* time —
    /// `plan()` consults the machine's [`crate::tuner::TuneProfile`] —
    /// so a serialized plan reproduces the tuned variant bit-for-bit
    /// on instantiation.
    pub tune: Option<TuneParams>,
    /// The compiled hybrid row-panel schedule (empty for non-hybrid
    /// kernels): per-segment row range, panel kernel and optional
    /// per-segment variant override, so instantiation reproduces the
    /// exact segments without records.
    pub schedule: Vec<ScheduleEntry>,
}

/// Serializes a kernel variant as a plan/cache JSON object.
fn tune_to_json(t: TuneParams) -> Json {
    Json::obj(vec![
        ("hpd", Json::Num(t.header_prefetch_dist as f64)),
        ("vpd", Json::Num(t.value_prefetch_dist as f64)),
        ("pfx", Json::Bool(t.prefetch_x)),
        ("unroll", Json::Num(t.unroll as f64)),
    ])
}

/// Parses a kernel variant object; every field is required so a tuned
/// plan either reproduces its variant exactly or fails loudly.
fn tune_from_json(v: &Json) -> anyhow::Result<TuneParams> {
    let num = |k: &str| -> anyhow::Result<u8> {
        let n = v
            .get(k)
            .and_then(|n| n.as_f64())
            .ok_or_else(|| anyhow::anyhow!("tune: missing {k}"))?;
        anyhow::ensure!(
            n >= 0.0 && n <= u8::MAX as f64 && n.fract() == 0.0,
            "tune: {k} must be an integer in 0..=255, got {n}"
        );
        Ok(n as u8)
    };
    let t = TuneParams {
        header_prefetch_dist: num("hpd")?,
        value_prefetch_dist: num("vpd")?,
        prefetch_x: v
            .get("pfx")
            .and_then(|b| b.as_bool())
            .ok_or_else(|| anyhow::anyhow!("tune: missing pfx"))?,
        unroll: num("unroll")?,
    };
    anyhow::ensure!(
        t.unroll == 1 || t.unroll == 2,
        "tune: unroll must be 1 or 2, got {}",
        t.unroll
    );
    Ok(t)
}

impl SpmvPlan {
    /// Serializes to JSON text.
    pub fn to_json(&self) -> String {
        let mut fields = vec![
            ("version", Json::Num(self.version as f64)),
            ("fingerprint", self.fingerprint.to_json()),
            ("kernel", Json::Str(self.kernel.to_string())),
            ("threads", Json::Num(self.threads as f64)),
            ("numa_split", Json::Bool(self.numa_split)),
            ("panel_rows", Json::Num(self.panel_rows as f64)),
        ];
        if let Some(r) = self.reorder {
            fields.push(("reorder", Json::Str(r.to_string())));
        }
        if let Some(tc) = self.tile_cols {
            fields.push(("tile_cols", Json::Num(tc as f64)));
        }
        if let Some(g) = self.predicted_gflops {
            fields.push(("predicted_gflops", Json::Num(g)));
        }
        if let Some(t) = self.tune {
            fields.push(("tune", tune_to_json(t)));
        }
        if !self.schedule.is_empty() {
            let segs: Vec<Json> = self
                .schedule
                .iter()
                .map(|s| {
                    let mut seg = vec![
                        ("row_begin", Json::Num(s.row_begin as f64)),
                        ("row_end", Json::Num(s.row_end as f64)),
                        ("kernel", Json::Str(s.kernel.to_string())),
                    ];
                    if let Some(t) = s.tune {
                        seg.push(("tune", tune_to_json(t)));
                    }
                    Json::obj(seg)
                })
                .collect();
            fields.push(("schedule", Json::Arr(segs)));
        }
        Json::obj(fields).to_string()
    }

    /// Parses from JSON text, rejecting malformed plans (unknown
    /// kernel spellings, negative or fractional dimensions, missing
    /// fields) with a descriptive error.
    pub fn from_json(text: &str) -> anyhow::Result<SpmvPlan> {
        let v = Json::parse(text)?;
        Self::from_json_value(&v)
    }

    pub(crate) fn from_json_value(v: &Json) -> anyhow::Result<SpmvPlan> {
        let num = |k: &str| -> anyhow::Result<f64> {
            v.get(k)
                .and_then(|n| n.as_f64())
                .ok_or_else(|| anyhow::anyhow!("plan: missing {k}"))
        };
        let dim = |k: &str| -> anyhow::Result<usize> {
            let n = num(k)?;
            anyhow::ensure!(
                n >= 0.0 && n.fract() == 0.0,
                "plan: {k} must be a non-negative integer, got {n}"
            );
            Ok(n as usize)
        };
        let version = dim("version")? as u32;
        anyhow::ensure!(
            version >= 1 && version <= PLAN_VERSION,
            "plan: unsupported version {version} (this build understands \
             1..={PLAN_VERSION})"
        );
        let fingerprint = MatrixFingerprint::from_json(
            v.get("fingerprint")
                .ok_or_else(|| anyhow::anyhow!("plan: missing fingerprint"))?,
        )?;
        let kernel_s = v
            .get("kernel")
            .and_then(|s| s.as_str())
            .ok_or_else(|| anyhow::anyhow!("plan: missing kernel"))?;
        let kernel = KernelKind::parse(kernel_s).ok_or_else(|| {
            anyhow::anyhow!("plan: unknown kernel '{kernel_s}'")
        })?;
        let threads = dim("threads")?.max(1);
        let numa_split = matches!(v.get("numa_split"), Some(Json::Bool(true)));
        let reorder = match v.get("reorder").and_then(|s| s.as_str()) {
            None => None,
            Some(r) => Some(ReorderKind::parse(r).ok_or_else(|| {
                anyhow::anyhow!("plan: unknown reorder '{r}'")
            })?),
        };
        let panel_rows = dim("panel_rows")?;
        let tile_cols = match v.get("tile_cols") {
            None => None,
            Some(_) => {
                let tc = dim("tile_cols")?;
                anyhow::ensure!(tc > 0, "plan: tile_cols must be positive");
                Some(tc)
            }
        };
        let predicted_gflops =
            v.get("predicted_gflops").and_then(|g| g.as_f64());
        // Pre-autotuner plans have no "tune": None instantiates the
        // process default (baseline) variant, exactly what they ran.
        let tune = match v.get("tune") {
            None => None,
            Some(t) => Some(tune_from_json(t)?),
        };
        let mut schedule = Vec::new();
        if let Some(arr) = v.get("schedule").and_then(|a| a.as_arr()) {
            for (i, seg) in arr.iter().enumerate() {
                let sdim = |k: &str| -> anyhow::Result<usize> {
                    let n = seg.get(k).and_then(|n| n.as_f64()).ok_or_else(
                        || anyhow::anyhow!("plan: segment {i}: missing {k}"),
                    )?;
                    anyhow::ensure!(
                        n >= 0.0 && n.fract() == 0.0,
                        "plan: segment {i}: {k} must be a non-negative \
                         integer"
                    );
                    Ok(n as usize)
                };
                let ks = seg
                    .get("kernel")
                    .and_then(|s| s.as_str())
                    .ok_or_else(|| {
                        anyhow::anyhow!("plan: segment {i}: missing kernel")
                    })?;
                let kernel = PanelKernel::parse(ks).ok_or_else(|| {
                    anyhow::anyhow!(
                        "plan: segment {i}: unknown panel kernel '{ks}'"
                    )
                })?;
                let seg_tune = match seg.get("tune") {
                    None => None,
                    Some(t) => Some(tune_from_json(t).map_err(|e| {
                        anyhow::anyhow!("plan: segment {i}: {e}")
                    })?),
                };
                schedule.push(ScheduleEntry {
                    row_begin: sdim("row_begin")?,
                    row_end: sdim("row_end")?,
                    kernel,
                    tune: seg_tune,
                });
            }
        }
        Ok(SpmvPlan {
            version,
            fingerprint,
            kernel,
            threads,
            numa_split,
            reorder,
            panel_rows,
            tile_cols,
            predicted_gflops,
            tune,
            schedule,
        })
    }

    /// Saves the plan to a file, envelope-framed and atomically
    /// (see [`crate::util::durable`]).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<(), StateError> {
        durable::save_state(
            Self::ARTIFACT,
            path.as_ref(),
            &format!("{}\n", self.to_json()),
        )
    }

    /// Loads a plan from a file. A missing file is an error (a plan
    /// path is always explicitly named); a corrupt file — bad
    /// envelope, checksum mismatch, malformed JSON — is quarantined
    /// to `<name>.corrupt-<n>` and reported as a typed
    /// [`StateError`]. Legacy (pre-envelope) files load unverified.
    pub fn load(path: impl AsRef<Path>) -> Result<SpmvPlan, StateError> {
        let path = path.as_ref();
        match durable::read_state(Self::ARTIFACT, path)? {
            RawState::Missing => Err(StateError {
                artifact: Self::ARTIFACT,
                path: path.to_path_buf(),
                kind: StateErrorKind::Io(std::io::Error::new(
                    std::io::ErrorKind::NotFound,
                    "no such file",
                )),
                quarantined_to: None,
            }),
            RawState::Empty => Err(StateError {
                artifact: Self::ARTIFACT,
                path: path.to_path_buf(),
                kind: StateErrorKind::Malformed("file is empty".into()),
                quarantined_to: None,
            }),
            RawState::Payload { text, .. } => Self::from_json(&text)
                .map_err(|e| {
                    durable::quarantined(
                        Self::ARTIFACT,
                        path,
                        StateErrorKind::Malformed(e.to_string()),
                    )
                }),
        }
    }
}

impl SpmvPlan {
    /// Artifact label used in [`StateError`] and degradation events.
    pub const ARTIFACT: &'static str = "plan";
}

/// A persistent `{fingerprint → plan}` store: plan once, instantiate
/// engines from the cache in milliseconds on every repeat workload.
/// Distinct build configurations (threads, numa, reorder, panel rows,
/// tiling, kernel) keep distinct entries — two services sharing one
/// cache file with different settings do not evict each other — while
/// re-planning the *same* configuration replaces its entry (latest
/// wins, bounded growth).
#[derive(Clone, Debug, Default)]
pub struct PlanCache {
    pub plans: Vec<SpmvPlan>,
}

/// Whether two plans describe the same build configuration (everything
/// but the predicted speed and the compiled schedule).
fn same_config(a: &SpmvPlan, b: &SpmvPlan) -> bool {
    a.fingerprint == b.fingerprint
        && a.threads == b.threads
        && a.numa_split == b.numa_split
        && a.reorder == b.reorder
        && a.panel_rows == b.panel_rows
        && a.tile_cols == b.tile_cols
        && a.kernel == b.kernel
        && a.tune == b.tune
}

impl PlanCache {
    pub fn new() -> PlanCache {
        PlanCache::default()
    }

    /// The most recently inserted plan for a matrix at a thread count
    /// (builders with stricter requirements filter the [`PlanCache::plans`]
    /// list themselves).
    pub fn find(
        &self,
        fp: &MatrixFingerprint,
        threads: usize,
    ) -> Option<&SpmvPlan> {
        self.plans
            .iter()
            .find(|p| p.fingerprint == *fp && p.threads == threads.max(1))
    }

    /// Inserts a plan: replaces the entry with the same configuration
    /// ([`same_config`] — fingerprint, threads, numa, reorder, panel
    /// rows, tile width, kernel), otherwise adds it at the front so
    /// lookups prefer the newest plan.
    pub fn insert(&mut self, plan: SpmvPlan) {
        let key = self.plans.iter().position(|p| same_config(p, &plan));
        match key {
            Some(i) => self.plans[i] = plan,
            None => self.plans.insert(0, plan),
        }
    }

    /// Serializes the whole store to JSON text.
    pub fn to_json(&self) -> String {
        let arr: Vec<Json> = self
            .plans
            .iter()
            .map(|p| Json::parse(&p.to_json()).expect("plan emits valid json"))
            .collect();
        Json::obj(vec![
            ("version", Json::Num(PLAN_VERSION as f64)),
            ("plans", Json::Arr(arr)),
        ])
        .to_string()
    }

    /// Parses a store from JSON text.
    pub fn from_json(text: &str) -> anyhow::Result<PlanCache> {
        let v = Json::parse(text)?;
        let arr = v
            .get("plans")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| anyhow::anyhow!("plan cache: missing 'plans'"))?;
        let mut cache = PlanCache::new();
        for (i, p) in arr.iter().enumerate() {
            let plan = SpmvPlan::from_json_value(p)
                .map_err(|e| anyhow::anyhow!("plan cache entry {i}: {e}"))?;
            // The serialized order is the lookup priority order
            // (newest first): preserve it, keeping the first of any
            // duplicated configuration.
            if !cache.plans.iter().any(|q| same_config(q, &plan)) {
                cache.plans.push(plan);
            }
        }
        Ok(cache)
    }

    /// Artifact label used in [`StateError`] / degradation events.
    pub const ARTIFACT: &'static str = "plan-cache";

    /// Loads a store from a file; a missing file is an empty cache
    /// (first run), an empty or whitespace-only file is an empty
    /// cache with a warning (a crashed first save must not poison
    /// every future cold start), a corrupt file is quarantined and
    /// reported as a typed [`StateError`]. Legacy (pre-envelope)
    /// files load unverified.
    pub fn load(path: impl AsRef<Path>) -> Result<PlanCache, StateError> {
        let path = path.as_ref();
        match durable::read_state(Self::ARTIFACT, path)? {
            RawState::Missing => Ok(PlanCache::new()),
            RawState::Empty => {
                eprintln!(
                    "spc5: plan cache {} is empty; starting fresh",
                    path.display()
                );
                Ok(PlanCache::new())
            }
            RawState::Payload { text, .. } => Self::from_json(&text)
                .map_err(|e| {
                    durable::quarantined(
                        Self::ARTIFACT,
                        path,
                        StateErrorKind::Malformed(e.to_string()),
                    )
                }),
        }
    }

    /// Saves the store to a file, envelope-framed and atomically.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<(), StateError> {
        durable::save_state(
            Self::ARTIFACT,
            path.as_ref(),
            &format!("{}\n", self.to_json()),
        )
    }

    pub fn len(&self) -> usize {
        self.plans.len()
    }

    pub fn is_empty(&self) -> bool {
        self.plans.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::suite;

    fn sample_plan() -> SpmvPlan {
        SpmvPlan {
            version: PLAN_VERSION,
            fingerprint: MatrixFingerprint {
                rows: 100,
                cols: 120,
                nnz: 999,
                stats_hash: 0xdead_beef_cafe_f00d,
            },
            kernel: KernelKind::Hybrid,
            threads: 4,
            numa_split: true,
            reorder: Some(ReorderKind::Rcm),
            panel_rows: 64,
            tile_cols: Some(4096),
            predicted_gflops: Some(2.75),
            tune: Some(crate::kernels::VARIANT_TABLE[3]),
            schedule: vec![
                ScheduleEntry {
                    row_begin: 0,
                    row_end: 64,
                    kernel: PanelKernel::Beta(BlockSize::new(2, 8)),
                    tune: Some(crate::kernels::VARIANT_TABLE[1]),
                },
                ScheduleEntry {
                    row_begin: 64,
                    row_end: 100,
                    kernel: PanelKernel::Csr,
                    tune: None,
                },
            ],
        }
    }

    #[test]
    fn plan_json_roundtrip() {
        let p = sample_plan();
        let back = SpmvPlan::from_json(&p.to_json()).unwrap();
        assert_eq!(p, back);
        // Optional fields absent.
        let mut q = sample_plan();
        q.reorder = None;
        q.tile_cols = None;
        q.predicted_gflops = None;
        q.tune = None;
        q.schedule.clear();
        let back = SpmvPlan::from_json(&q.to_json()).unwrap();
        assert_eq!(q, back);
    }

    #[test]
    fn pre_tuning_plan_json_still_loads() {
        // A plan serialized before the autotuner existed has no "tune"
        // keys anywhere: it must load with `tune: None` (plan and
        // segments), which instantiates the baseline variant.
        let text = r#"{"version":1,
            "fingerprint":{"rows":100,"cols":120,"nnz":999,
                           "stats_hash":"deadbeefcafef00d"},
            "kernel":"hybrid","threads":4,"numa_split":false,
            "panel_rows":64,
            "schedule":[{"row_begin":0,"row_end":100,"kernel":"b(2,8)"}]}"#;
        let p = SpmvPlan::from_json(text).unwrap();
        assert_eq!(p.tune, None);
        assert_eq!(p.schedule[0].tune, None);
        // And it re-serializes without inventing tuning fields.
        assert!(!p.to_json().contains("tune"));
    }

    #[test]
    fn tuned_plan_rejects_partial_tune_object() {
        // A "tune" object missing a field must fail loudly, not
        // silently fall back to a different variant than was measured.
        let mut good = sample_plan();
        good.schedule.clear();
        let text = good
            .to_json()
            .replace(r#""pfx":false,"#, "")
            .replace(r#""pfx":true,"#, "");
        assert!(SpmvPlan::from_json(&text).is_err());
    }

    #[test]
    fn plan_rejects_malformed() {
        let good = sample_plan().to_json();
        // Unknown kernel spelling.
        let bad = good.replace("\"hybrid\"", "\"turbokernel\"");
        assert!(SpmvPlan::from_json(&bad).is_err());
        // Negative tile width.
        let bad = good.replace("\"tile_cols\":4096", "\"tile_cols\":-4");
        assert!(SpmvPlan::from_json(&bad).is_err());
        // Bad segment kernel.
        let bad = good.replace("\"b(2,8)\"", "\"csr5\"");
        assert!(SpmvPlan::from_json(&bad).is_err());
        // Future schema version.
        let bad = good.replace("\"version\":1", "\"version\":99");
        assert!(SpmvPlan::from_json(&bad).is_err());
        // Not even JSON.
        assert!(SpmvPlan::from_json("{nope").is_err());
        // Missing fingerprint.
        assert!(SpmvPlan::from_json(r#"{"version":1,"kernel":"csr"}"#)
            .is_err());
    }

    #[test]
    fn fingerprint_distinguishes_structure_not_values() {
        let a = suite::poisson2d(12);
        let fa = MatrixFingerprint::of(&a);
        assert_eq!(fa, MatrixFingerprint::of(&a), "deterministic");
        // Same pattern, different values → same fingerprint.
        let mut b = a.clone();
        for v in &mut b.values {
            *v *= 3.25;
        }
        assert_eq!(fa, MatrixFingerprint::of(&b));
        // Different structure → different fingerprint.
        let c = suite::poisson2d(13);
        assert_ne!(fa, MatrixFingerprint::of(&c));
        let d = suite::uniform_scatter(a.rows, 5, 3);
        assert_ne!(fa, MatrixFingerprint::of(&d));
        // Different precision → different fingerprint (plans resolve
        // tile widths and β sizes per precision, so they must not
        // cross).
        let a32: crate::matrix::Csr<f32> = a.to_precision();
        assert_ne!(fa, MatrixFingerprint::of(&a32));
    }

    #[test]
    fn cache_roundtrip_and_replacement() {
        let mut cache = PlanCache::new();
        let p = sample_plan();
        cache.insert(p.clone());
        // Re-inserting the same configuration replaces (latest wins,
        // bounded growth) — even when the re-plan chose a different
        // schedule.
        let mut p1b = sample_plan();
        p1b.predicted_gflops = Some(9.9);
        cache.insert(p1b);
        assert_eq!(cache.len(), 1);
        assert_eq!(
            cache.find(&p.fingerprint, 4).unwrap().predicted_gflops,
            Some(9.9)
        );
        // A different configuration (here: kernel) coexists instead of
        // evicting — and the newest entry wins lookups.
        let mut p2 = sample_plan();
        p2.kernel = KernelKind::Csr;
        p2.tile_cols = None;
        p2.schedule.clear();
        cache.insert(p2.clone());
        assert_eq!(cache.len(), 2);
        assert_eq!(
            cache.find(&p.fingerprint, 4).unwrap().kernel,
            KernelKind::Csr
        );
        let mut p3 = sample_plan();
        p3.threads = 8;
        cache.insert(p3);
        assert_eq!(cache.len(), 3);

        let back = PlanCache::from_json(&cache.to_json()).unwrap();
        assert_eq!(back.len(), 3);
        assert_eq!(back.find(&p.fingerprint, 4), cache.find(&p.fingerprint, 4));
        assert!(back.find(&p.fingerprint, 2).is_none());
    }

    #[test]
    fn cache_missing_file_is_empty() {
        let cache =
            PlanCache::load("/definitely/not/a/real/path.json").unwrap();
        assert!(cache.is_empty());
    }
}
