//! Request-loop service — a thin serving layer over [`SpmvEngine`]
//! demonstrating the library in a long-running deployment (the
//! `spmv_server` example): requests arrive on a channel, a worker pool
//! answers them, per-request latency is recorded. Generic over the
//! engine's precision.
//!
//! The matrix and kernel are fixed at service construction (the
//! iterative-solver deployment); each request carries its own `x`.

use super::engine::SpmvEngine;
use crate::scalar::Scalar;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Arc;

/// One SpMV request.
pub struct Request<T: Scalar = f64> {
    pub id: u64,
    pub x: Vec<T>,
}

/// The answer to a [`Request`].
pub struct Response<T: Scalar = f64> {
    pub id: u64,
    pub y: Vec<T>,
    /// Service-side latency in seconds (queue + compute).
    pub latency_s: f64,
}

/// A running service instance.
pub struct SpmvService<T: Scalar = f64> {
    tx: Option<mpsc::Sender<(Request<T>, std::time::Instant)>>,
    rx_out: mpsc::Receiver<Response<T>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    served: Arc<AtomicUsize>,
}

impl<T: Scalar> SpmvService<T> {
    /// Spawns `workers` threads sharing the engine.
    pub fn start(engine: SpmvEngine<T>, workers: usize) -> SpmvService<T> {
        assert!(workers > 0);
        let engine = Arc::new(engine);
        let (tx, rx) = mpsc::channel::<(Request<T>, std::time::Instant)>();
        let rx = Arc::new(std::sync::Mutex::new(rx));
        let (tx_out, rx_out) = mpsc::channel::<Response<T>>();
        let served = Arc::new(AtomicUsize::new(0));

        let mut handles = Vec::with_capacity(workers);
        for _ in 0..workers {
            let rx = Arc::clone(&rx);
            let tx_out = tx_out.clone();
            let engine = Arc::clone(&engine);
            let served = Arc::clone(&served);
            handles.push(std::thread::spawn(move || loop {
                let msg = { rx.lock().unwrap().recv() };
                let Ok((req, enqueued)) = msg else {
                    break; // channel closed → shut down
                };
                let rows = engine.csr().rows;
                let mut y = vec![T::ZERO; rows];
                engine.spmv_into(&req.x, &mut y);
                served.fetch_add(1, Ordering::Relaxed);
                let _ = tx_out.send(Response {
                    id: req.id,
                    y,
                    latency_s: enqueued.elapsed().as_secs_f64(),
                });
            }));
        }
        SpmvService { tx: Some(tx), rx_out, workers: handles, served }
    }

    /// Enqueues a request.
    pub fn submit(&self, req: Request<T>) {
        self.tx
            .as_ref()
            .expect("service running")
            .send((req, std::time::Instant::now()))
            .expect("workers alive");
    }

    /// Blocks for the next response.
    pub fn recv(&self) -> Option<Response<T>> {
        self.rx_out.recv().ok()
    }

    /// Requests served so far.
    pub fn served(&self) -> usize {
        self.served.load(Ordering::Relaxed)
    }

    /// Graceful shutdown: waits for queued work, joins workers.
    pub fn shutdown(mut self) -> usize {
        drop(self.tx.take());
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        self.served()
    }
}

impl<T: Scalar> Drop for SpmvService<T> {
    fn drop(&mut self) {
        drop(self.tx.take());
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::KernelKind;
    use crate::matrix::{suite, Csr};

    #[test]
    fn serves_correct_results() {
        let csr = suite::poisson2d(12);
        let engine = SpmvEngine::builder(csr.clone()).build().unwrap();
        let service = SpmvService::start(engine, 3);

        let n_req = 20usize;
        for id in 0..n_req as u64 {
            let x: Vec<f64> =
                (0..csr.cols).map(|i| (i as u64 + id) as f64 * 0.01).collect();
            service.submit(Request { id, x });
        }
        let mut got = 0usize;
        while got < n_req {
            let resp = service.recv().expect("response");
            let x: Vec<f64> = (0..csr.cols)
                .map(|i| (i as u64 + resp.id) as f64 * 0.01)
                .collect();
            let mut want = vec![0.0; csr.rows];
            csr.spmv_ref(&x, &mut want);
            crate::testkit::assert_close(&resp.y, &want, 1e-9, "service");
            assert!(resp.latency_s >= 0.0);
            got += 1;
        }
        assert_eq!(service.shutdown(), n_req);
    }

    #[test]
    fn f32_service_serves_wide_blocks() {
        let csr32: Csr<f32> = suite::poisson2d(10).to_precision();
        let engine = SpmvEngine::builder(csr32.clone())
            .kernel(KernelKind::Beta(2, 16))
            .build()
            .unwrap();
        let service = SpmvService::start(engine, 2);
        for id in 0..8u64 {
            let x: Vec<f32> = (0..csr32.cols)
                .map(|i| ((i as u64 + id) % 13) as f32 * 0.1)
                .collect();
            service.submit(Request { id, x });
        }
        for _ in 0..8 {
            let resp = service.recv().expect("response");
            let x: Vec<f32> = (0..csr32.cols)
                .map(|i| ((i as u64 + resp.id) % 13) as f32 * 0.1)
                .collect();
            let mut want = vec![0.0f32; csr32.rows];
            csr32.spmv_ref(&x, &mut want);
            for i in 0..want.len() {
                assert!(
                    (resp.y[i] - want[i]).abs() <= 2e-4 * want[i].abs().max(1.0)
                );
            }
        }
        assert_eq!(service.shutdown(), 8);
    }

    #[test]
    fn service_over_csr_baseline() {
        let csr = suite::poisson2d(8);
        let engine = SpmvEngine::builder(csr.clone())
            .kernel(KernelKind::Csr)
            .build()
            .unwrap();
        let service = SpmvService::start(engine, 2);
        let x = vec![1.0; csr.cols];
        service.submit(Request { id: 0, x: x.clone() });
        let resp = service.recv().unwrap();
        let mut want = vec![0.0; csr.rows];
        csr.spmv_ref(&x, &mut want);
        crate::testkit::assert_close(&resp.y, &want, 1e-9, "csr service");
        assert_eq!(service.shutdown(), 1);
    }

    #[test]
    fn shutdown_without_requests() {
        let csr = suite::poisson2d(4);
        let engine = SpmvEngine::builder(csr).build().unwrap();
        let service = SpmvService::start(engine, 2);
        assert_eq!(service.shutdown(), 0);
    }
}
