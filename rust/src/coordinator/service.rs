//! Request-loop service — the serving layer over [`SpmvEngine`] used
//! by the `spmv_server` example, generic over the engine's precision.
//!
//! The matrix and kernel are fixed at service construction (the
//! iterative-solver deployment); each request carries its own `x`.
//!
//! ## Micro-batching dispatcher
//!
//! One dispatcher thread drains the request queue and **coalesces
//! concurrent requests against the same matrix into a single
//! multi-RHS product** routed through [`SpmvEngine::spmm`] (the block
//! kernels traverse the matrix once for all `k` right-hand sides —
//! amortizing matrix traffic across clients), falling back to the
//! single-vector SpMV when only one request is pending. The compute
//! itself runs on the engine's persistent [`crate::parallel::WorkerPool`]
//! when the engine is parallel — the service spawns no per-request
//! threads and shares the same runtime as the solvers.
//!
//! ## Admission control
//!
//! Requests flow through a [`BoundedQueue`] instead of an unbounded
//! channel: at most `capacity` requests are in flight (accepted but
//! not yet received back by the client), and [`SpmvService::submit`]
//! applies the service's [`QueuePolicy`] when full — block, reject
//! with [`ServiceError::Overloaded`], or wait up to a deadline. The
//! slot is freed when the client `recv`s the response, so the cap
//! bounds total resident request/response memory, not just the input
//! side.
//!
//! Per-request latency is recorded split into **queue** (admission →
//! dispatch) and **compute** (dispatch → response built) components;
//! [`SpmvService::stats`] exposes p50/p95/p99 for the total and for
//! each component, plus the batch-size histogram, rejection count and
//! the queue-depth high-water mark.

use super::engine::SpmvEngine;
use super::serving::{BoundedQueue, PushError, QueuePolicy};
use crate::faults::{self, FaultPlan, Site};
use crate::scalar::Scalar;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// One SpMV request.
pub struct Request<T: Scalar = f64> {
    pub id: u64,
    pub x: Vec<T>,
}

/// The answer to a [`Request`].
#[derive(Clone, Debug)]
pub struct Response<T: Scalar = f64> {
    pub id: u64,
    pub y: Vec<T>,
    /// Total service-side latency in seconds (`queue_s + compute_s`).
    pub latency_s: f64,
    /// Time spent queued before the dispatcher picked the request up.
    pub queue_s: f64,
    /// Time from dispatch to the response being built (batch compute
    /// plus unpacking; shared by every member of one batch).
    pub compute_s: f64,
}

impl<T: Scalar> std::fmt::Debug for Response<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Response")
            .field("id", &self.id)
            .field("rows", &self.y.len())
            .field("latency_s", &self.latency_s)
            .finish()
    }
}

/// Why a [`SpmvService::submit`] was rejected.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServiceError {
    /// The dispatcher is gone (service shut down or crashed); the
    /// request was not enqueued.
    Stopped,
    /// `x` does not match the served matrix's column count; accepting
    /// it would poison the whole batch it lands in.
    ShapeMismatch { expected: usize, got: usize },
    /// The bounded queue was full and the admission policy gave up
    /// (`Reject` immediately, `Timeout` after its deadline). The
    /// request was not enqueued; retry later or shed load.
    Overloaded { capacity: usize },
    /// The addressed tenant is not registered (registry-level routing;
    /// never returned by a single service).
    UnknownTenant,
    /// A shard's dispatcher died (injected or real kernel panic).
    /// `generation` is the serving generation the failure aborted —
    /// every request stamped with it is gone; the supervised sharded
    /// front-end restarts the shard and serves later generations,
    /// while a plain service stays down.
    ShardFailed { shard: usize, generation: u64 },
}

impl std::fmt::Display for ServiceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServiceError::Stopped => {
                write!(f, "service stopped: request not enqueued")
            }
            ServiceError::ShapeMismatch { expected, got } => write!(
                f,
                "request x has {got} entries, matrix expects {expected}"
            ),
            ServiceError::Overloaded { capacity } => write!(
                f,
                "service overloaded: {capacity} requests in flight"
            ),
            ServiceError::UnknownTenant => {
                write!(f, "no tenant registered under that fingerprint")
            }
            ServiceError::ShardFailed { shard, generation } => write!(
                f,
                "shard {shard} failed; generation {generation} aborted"
            ),
        }
    }
}

impl std::error::Error for ServiceError {}

/// Why a receive returned without a response. Distinguishes clean
/// shutdown ([`Stopped`](RecvError::Stopped)) from a dead dispatcher
/// ([`Failed`](RecvError::Failed)) — before PR 8 both surfaced as a
/// silent `None`/`Stopped`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RecvError {
    /// No response arrived within the deadline; the request (if any)
    /// is still in flight and a later receive can pick it up.
    Timeout,
    /// Clean shutdown: the dispatcher drained and exited normally.
    Stopped,
    /// The dispatcher died (panic). For the sharded front-end this
    /// aborts one serving `generation`: requests stamped with it are
    /// gone, but the shard restarts and later submissions succeed.
    Failed { shard: usize, generation: u64 },
}

/// Pre-PR-8 name of [`RecvError`] (same enum; `Failed` is new).
pub type RecvTimeoutError = RecvError;

impl std::fmt::Display for RecvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RecvError::Timeout => write!(f, "receive timed out"),
            RecvError::Stopped => write!(f, "service stopped"),
            RecvError::Failed { shard, generation } => write!(
                f,
                "shard {shard} failed; generation {generation} aborted"
            ),
        }
    }
}

impl std::error::Error for RecvError {}

/// Liveness of one serving shard (or a whole plain service).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardHealth {
    /// Serving normally.
    Up,
    /// Dead dispatcher detected; the supervisor is rebuilding the
    /// engine from the retained plan.
    Restarting,
    /// Permanently down: restart budget exhausted (or a plain,
    /// unsupervised service whose dispatcher died).
    Poisoned,
}

impl std::fmt::Display for ShardHealth {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ShardHealth::Up => write!(f, "up"),
            ShardHealth::Restarting => write!(f, "restarting"),
            ShardHealth::Poisoned => write!(f, "poisoned"),
        }
    }
}

/// One shard's (or service's) health snapshot, surfaced through
/// `spc5 serve` and [`super::tenant::TenantStats`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HealthReport {
    pub shard: usize,
    pub health: ShardHealth,
    /// Serving generation: bumped on every supervised restart.
    pub generation: u64,
    /// Restarts performed so far (0 for a plain service).
    pub restarts: usize,
    /// Human-readable description of the most recent fault, if any.
    pub last_fault: Option<String>,
}

/// One p50/p95/p99 set, in seconds (0.0 before anything is served).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LatencyPercentiles {
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
}

/// Service-level latency / batching statistics snapshot.
#[derive(Clone, Debug)]
pub struct ServiceStats {
    /// Requests completed.
    pub served: usize,
    /// Submissions refused with [`ServiceError::Overloaded`].
    pub rejected: usize,
    /// Dispatched batches (≤ served; smaller when coalescing happens).
    pub batches: usize,
    /// Total-latency percentiles in seconds over the most recent
    /// [`LATENCY_WINDOW`] requests (0.0 when nothing served yet).
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
    /// Queue-time (admission → dispatch) percentiles.
    pub queue: LatencyPercentiles,
    /// Compute-time (dispatch → response built) percentiles.
    pub compute: LatencyPercentiles,
    /// Highest in-flight count the bounded queue ever reached
    /// (≤ the policy's capacity — the bounded-memory witness).
    pub queue_depth_high_water: usize,
    /// `batch_hist[i]` = number of batches of size `i + 1`.
    pub batch_hist: Vec<usize>,
}

/// Latency samples kept for the percentiles — a bounded ring, so a
/// long-running deployment neither grows without bound nor pays more
/// than an O(window log window) sort per stats snapshot.
pub const LATENCY_WINDOW: usize = 4096;

/// Ring of the last [`LATENCY_WINDOW`] samples.
#[derive(Default)]
struct Ring {
    samples: Vec<f64>,
    /// Next slot to overwrite once the window is full.
    next: usize,
}

impl Ring {
    fn record(&mut self, v: f64) {
        if self.samples.len() < LATENCY_WINDOW {
            self.samples.push(v);
        } else {
            self.samples[self.next] = v;
            self.next = (self.next + 1) % LATENCY_WINDOW;
        }
    }
}

/// Sorts a sample clone and reads the three percentiles.
fn percentiles_of(mut samples: Vec<f64>) -> LatencyPercentiles {
    samples.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
    let pct = |p: f64| -> f64 {
        if samples.is_empty() {
            0.0
        } else {
            samples[((p * (samples.len() - 1) as f64).round()) as usize]
        }
    };
    LatencyPercentiles { p50_s: pct(0.50), p95_s: pct(0.95), p99_s: pct(0.99) }
}

#[derive(Default)]
struct StatsInner {
    total: Ring,
    queue: Ring,
    compute: Ring,
    batch_hist: Vec<usize>,
    batches: usize,
}

impl StatsInner {
    fn record_batch(&mut self, size: usize) {
        if self.batch_hist.len() < size {
            self.batch_hist.resize(size, 0);
        }
        self.batch_hist[size - 1] += 1;
        self.batches += 1;
    }

    fn record_latency(&mut self, queue_s: f64, compute_s: f64) {
        self.total.record(queue_s + compute_s);
        self.queue.record(queue_s);
        self.compute.record(compute_s);
    }
}

/// A running service instance (see module docs). `Sync`: the response
/// channel sits behind a mutex, so submissions and receives may come
/// from different threads (concurrent receivers serialize).
pub struct SpmvService<T: Scalar = f64> {
    queue: Arc<BoundedQueue<(Request<T>, Instant)>>,
    rx_out: Mutex<mpsc::Receiver<Response<T>>>,
    /// Behind a mutex so close/join works through `&self` — services
    /// shared via `Arc` (tenant registry) and the sharded front-end's
    /// poison path shut shards down without owning them.
    dispatcher: Mutex<Option<std::thread::JoinHandle<()>>>,
    served: Arc<AtomicUsize>,
    rejected: AtomicUsize,
    stats: Arc<Mutex<StatsInner>>,
    /// Set by the dispatcher's drop guard when it dies by panic —
    /// the bit that lets submit/recv distinguish failure from clean
    /// shutdown and the sharded supervisor detect a dead shard.
    failed: Arc<AtomicBool>,
    faults: Option<Arc<FaultPlan>>,
    /// Shard index and serving generation this instance serves under
    /// (0/0 for a plain standalone service).
    shard: usize,
    generation: u64,
    cols: usize,
    max_batch: usize,
}

impl<T: Scalar> SpmvService<T> {
    /// Starts the dispatcher over `engine` with the default admission
    /// policy ([`QueuePolicy::default`]: block at a generous cap),
    /// coalescing up to `max_batch` pending requests into one
    /// multi-RHS product. The parallel compute runs on the engine's
    /// own persistent pool; the service adds exactly one dispatcher
    /// thread.
    pub fn start(engine: SpmvEngine<T>, max_batch: usize) -> SpmvService<T> {
        Self::start_with_policy(engine, max_batch, QueuePolicy::default())
    }

    /// [`start`](Self::start) with an explicit admission policy.
    /// Fault injection follows the process-global plan
    /// ([`faults::global`], i.e. `SPC5_FAULTS`).
    pub fn start_with_policy(
        engine: SpmvEngine<T>,
        max_batch: usize,
        policy: QueuePolicy,
    ) -> SpmvService<T> {
        Self::start_shard(engine, max_batch, policy, 0, 0, faults::global())
    }

    /// Full-control constructor used by the sharded supervisor: the
    /// service serves shard `shard` under serving generation
    /// `generation`, checking `faults` at its injection sites.
    pub(crate) fn start_shard(
        engine: SpmvEngine<T>,
        max_batch: usize,
        policy: QueuePolicy,
        shard: usize,
        generation: u64,
        faults: Option<Arc<FaultPlan>>,
    ) -> SpmvService<T> {
        assert!(max_batch > 0);
        let (cols, rows) = (engine.csr().cols, engine.csr().rows);
        let queue =
            Arc::new(BoundedQueue::<(Request<T>, Instant)>::new(policy));
        // Responses still ride an unbounded channel: its population is
        // bounded by the queue's in-flight cap (slots are only freed
        // on client receive), and an unbounded send means the
        // dispatcher can never deadlock against a slow client.
        let (tx_out, rx_out) = mpsc::channel::<Response<T>>();
        let served = Arc::new(AtomicUsize::new(0));
        let stats = Arc::new(Mutex::new(StatsInner::default()));
        let failed = Arc::new(AtomicBool::new(false));

        let queue_d = Arc::clone(&queue);
        let served_d = Arc::clone(&served);
        let stats_d = Arc::clone(&stats);
        let failed_d = Arc::clone(&failed);
        let faults_d = faults.clone();
        let dispatcher = std::thread::Builder::new()
            .name("spc5-dispatch".into())
            .spawn(move || {
                // The guard keeps a sender clone alive until its own
                // drop, so when the dispatcher dies by panic the
                // failure flag is set and admission closed *before*
                // blocked receivers observe the channel disconnect —
                // they wake to `Failed`, never a misleading `Stopped`.
                let guard = FailGuard {
                    failed: failed_d,
                    queue: Arc::clone(&queue_d),
                    _tx: tx_out.clone(),
                };
                dispatch_loop(
                    engine, queue_d, tx_out, served_d, stats_d, rows,
                    max_batch, shard, faults_d,
                );
                drop(guard);
            })
            .expect("spawn dispatcher");

        SpmvService {
            queue,
            rx_out: Mutex::new(rx_out),
            dispatcher: Mutex::new(Some(dispatcher)),
            served,
            rejected: AtomicUsize::new(0),
            stats,
            failed,
            faults,
            shard,
            generation,
            cols,
            max_batch,
        }
    }

    /// Submits a request under the admission policy. Fails instead of
    /// panicking when the vector has the wrong length, the service is
    /// full ([`ServiceError::Overloaded`]), shut down, or dead after
    /// a dispatcher panic ([`ServiceError::ShardFailed`]).
    pub fn submit(&self, req: Request<T>) -> Result<(), ServiceError> {
        if req.x.len() != self.cols {
            return Err(ServiceError::ShapeMismatch {
                expected: self.cols,
                got: req.x.len(),
            });
        }
        faults::fire(
            &self.faults,
            Site::Submit { shard: self.shard, request: req.id },
        );
        match self.queue.push((req, Instant::now())) {
            Ok(()) => Ok(()),
            Err(PushError::Full) => {
                self.rejected.fetch_add(1, Ordering::Relaxed);
                Err(ServiceError::Overloaded {
                    capacity: self.queue.capacity(),
                })
            }
            Err(PushError::Closed) => {
                if self.failed.load(Ordering::Acquire) {
                    Err(ServiceError::ShardFailed {
                        shard: self.shard,
                        generation: self.generation,
                    })
                } else {
                    Err(ServiceError::Stopped)
                }
            }
        }
    }

    /// Blocks for the next response and frees its admission slot.
    /// [`RecvError::Stopped`] means clean shutdown;
    /// [`RecvError::Failed`] means the dispatcher died (the service
    /// is down and accepted-but-unanswered requests are lost).
    pub fn recv(&self) -> Result<Response<T>, RecvError> {
        let got = {
            let rx = self.rx_out.lock().unwrap_or_else(|e| e.into_inner());
            rx.recv()
        };
        match got {
            Ok(resp) => {
                faults::fire(
                    &self.faults,
                    Site::Recv { shard: self.shard },
                );
                self.queue.release();
                Ok(resp)
            }
            Err(mpsc::RecvError) => Err(self.disconnect_error()),
        }
    }

    /// Waits up to `wait` for the next response. On success the
    /// admission slot is freed exactly as in [`recv`](Self::recv); on
    /// timeout nothing is lost — the response arrives to a later
    /// receive call.
    pub fn recv_timeout(
        &self,
        wait: Duration,
    ) -> Result<Response<T>, RecvError> {
        let got = {
            let rx = self.rx_out.lock().unwrap_or_else(|e| e.into_inner());
            rx.recv_timeout(wait)
        };
        match got {
            Ok(resp) => {
                faults::fire(
                    &self.faults,
                    Site::Recv { shard: self.shard },
                );
                self.queue.release();
                Ok(resp)
            }
            Err(mpsc::RecvTimeoutError::Timeout) => Err(RecvError::Timeout),
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                Err(self.disconnect_error())
            }
        }
    }

    /// Classifies a response-channel disconnect: failure if the
    /// dispatcher died by panic, clean stop otherwise.
    fn disconnect_error(&self) -> RecvError {
        if self.failed.load(Ordering::Acquire) {
            RecvError::Failed {
                shard: self.shard,
                generation: self.generation,
            }
        } else {
            RecvError::Stopped
        }
    }

    /// True once the dispatcher has died by panic (a clean shutdown
    /// never sets this). The sharded supervisor polls this to detect
    /// dead shards.
    pub fn failed(&self) -> bool {
        self.failed.load(Ordering::Acquire)
    }

    /// The serving generation this instance was started under.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Health snapshot of this single service: [`ShardHealth::Up`]
    /// until the dispatcher dies, [`ShardHealth::Poisoned`] after (a
    /// plain service has no supervisor to restart it).
    pub fn health(&self) -> HealthReport {
        let health = if self.failed() {
            ShardHealth::Poisoned
        } else {
            ShardHealth::Up
        };
        HealthReport {
            shard: self.shard,
            health,
            generation: self.generation,
            restarts: 0,
            last_fault: None,
        }
    }

    /// Requests served so far.
    pub fn served(&self) -> usize {
        self.served.load(Ordering::Relaxed)
    }

    /// Submissions refused with [`ServiceError::Overloaded`] so far.
    pub fn rejected(&self) -> usize {
        self.rejected.load(Ordering::Relaxed)
    }

    /// The coalescing limit this service was started with.
    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    /// The admission policy this service was started with.
    pub fn policy(&self) -> QueuePolicy {
        self.queue.policy()
    }

    /// Snapshot of the latency percentiles and batch-size histogram.
    pub fn stats(&self) -> ServiceStats {
        // Hold the dispatcher-shared lock only for the cheap clones;
        // sort after releasing it so monitoring polls cannot stall the
        // dispatch hot path.
        let (total, queue, compute, batches, batch_hist) = {
            let inner =
                self.stats.lock().unwrap_or_else(|e| e.into_inner());
            (
                inner.total.samples.clone(),
                inner.queue.samples.clone(),
                inner.compute.samples.clone(),
                inner.batches,
                inner.batch_hist.clone(),
            )
        };
        let total = percentiles_of(total);
        ServiceStats {
            served: self.served(),
            rejected: self.rejected(),
            batches,
            p50_s: total.p50_s,
            p95_s: total.p95_s,
            p99_s: total.p99_s,
            queue: percentiles_of(queue),
            compute: percentiles_of(compute),
            queue_depth_high_water: self.queue.high_water(),
            batch_hist,
        }
    }

    /// Closes admission without joining the dispatcher: blocked and
    /// later submitters fail with [`ServiceError::Stopped`] while
    /// already-accepted requests keep draining. Once drained the
    /// dispatcher exits and pending receives report stopped. Used by
    /// the sharded front-end to poison every shard after a partial
    /// fan-out; idempotent.
    pub fn close(&self) {
        self.queue.close();
    }

    /// Graceful shutdown: closes admission (blocked submitters wake
    /// with [`ServiceError::Stopped`]), serves every already-accepted
    /// request, joins the dispatcher and returns the served count.
    /// Undelivered responses are dropped with the service.
    pub fn shutdown(self) -> usize {
        self.shutdown_ref()
    }

    /// [`shutdown`](Self::shutdown) through a shared reference — for
    /// services shared via `Arc` (the tenant registry), where no
    /// caller can take the service by value. Idempotent: later calls
    /// just report the served count.
    pub fn shutdown_ref(&self) -> usize {
        self.queue.close();
        let handle = {
            let mut d =
                self.dispatcher.lock().unwrap_or_else(|e| e.into_inner());
            d.take()
        };
        if let Some(h) = handle {
            let _ = h.join();
        }
        self.served()
    }
}

impl<T: Scalar> Drop for SpmvService<T> {
    fn drop(&mut self) {
        self.queue.close();
        let taken = self
            .dispatcher
            .get_mut()
            .unwrap_or_else(|e| e.into_inner())
            .take();
        if let Some(h) = taken {
            let _ = h.join();
        }
    }
}

/// Dispatcher-thread drop guard: converts a panic into the `failed`
/// flag plus a closed admission queue, *before* the response channel
/// disconnects (the guard holds its own sender clone, so receivers
/// cannot observe the disconnect until this guard is gone).
struct FailGuard<T: Scalar> {
    failed: Arc<AtomicBool>,
    queue: Arc<BoundedQueue<(Request<T>, Instant)>>,
    _tx: mpsc::Sender<Response<T>>,
}

impl<T: Scalar> Drop for FailGuard<T> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.failed.store(true, Ordering::Release);
            // Wake blocked submitters: they see Closed, then the
            // failed flag, and report ShardFailed.
            self.queue.close();
        }
    }
}

/// The dispatcher: blocking-pop one request, greedily drain whatever
/// else is already queued (up to `max_batch`), serve the batch through
/// one engine call, answer every member.
#[allow(clippy::too_many_arguments)]
fn dispatch_loop<T: Scalar>(
    engine: SpmvEngine<T>,
    queue: Arc<BoundedQueue<(Request<T>, Instant)>>,
    tx_out: mpsc::Sender<Response<T>>,
    served: Arc<AtomicUsize>,
    stats: Arc<Mutex<StatsInner>>,
    rows: usize,
    max_batch: usize,
    shard: usize,
    faults: Option<Arc<FaultPlan>>,
) {
    // Reused across batches: the packed X/Y panels.
    let mut xb: Vec<T> = Vec::new();
    let mut yb: Vec<T> = Vec::new();
    let mut batch: Vec<(Request<T>, Instant)> = Vec::new();

    loop {
        batch.clear();
        match queue.pop() {
            Some(first) => batch.push(first),
            None => return, // closed and drained → shut down
        }
        while batch.len() < max_batch {
            match queue.try_pop() {
                Some(next) => batch.push(next),
                None => break,
            }
        }

        // The `compute` injection site: a panic here kills this
        // dispatcher exactly where a real kernel panic would, with
        // the batch popped but unanswered.
        faults::fire(
            &faults,
            Site::Compute { shard, request: batch[0].0.id },
        );

        // Queue time ends for the whole batch at this instant; what
        // follows is compute.
        let dispatched = Instant::now();
        let k = batch.len();
        if k == 1 {
            // Single pending request: plain SpMV, no packing cost.
            let (req, enqueued) = &batch[0];
            let mut y = vec![T::ZERO; rows];
            engine.spmv_into(&req.x, &mut y);
            finish(
                &tx_out,
                &served,
                &stats,
                1,
                dispatched,
                [(req.id, y, enqueued)],
            );
        } else {
            // Coalesce: one [cols × k] panel, one matrix traversal.
            // Packed c-major/j-minor so every slot is written exactly
            // once (no redundant zero-fill on the dispatch hot path).
            let cols = engine.csr().cols;
            xb.clear();
            xb.reserve(cols * k);
            for c in 0..cols {
                for (req, _) in batch.iter() {
                    xb.push(req.x[c]);
                }
            }
            if yb.len() != rows * k {
                yb.resize(rows * k, T::ZERO);
            }
            engine.spmm_into(&xb, &mut yb, k);
            let members = batch.iter().enumerate().map(|(j, (req, enq))| {
                let y: Vec<T> = (0..rows).map(|r| yb[r * k + j]).collect();
                (req.id, y, enq)
            });
            finish(&tx_out, &served, &stats, k, dispatched, members);
        }
    }
}

/// Answers every member of one served batch and records statistics.
/// The stats lock is released before any response is sent, so a
/// concurrent `stats()` poll never delays delivery.
fn finish<'a, T: Scalar>(
    tx_out: &mpsc::Sender<Response<T>>,
    served: &AtomicUsize,
    stats: &Mutex<StatsInner>,
    batch_size: usize,
    dispatched: Instant,
    members: impl IntoIterator<Item = (u64, Vec<T>, &'a Instant)>,
) {
    // One compute stamp for the batch: the engine call plus unpacking
    // are shared work, indivisible per member.
    let compute_s = dispatched.elapsed().as_secs_f64();
    let responses: Vec<Response<T>> = members
        .into_iter()
        .map(|(id, y, enqueued)| {
            // Saturates to zero if clocks place enqueue after dispatch.
            let queue_s =
                dispatched.duration_since(*enqueued).as_secs_f64();
            Response { id, y, latency_s: queue_s + compute_s, queue_s, compute_s }
        })
        .collect();
    {
        let mut st = stats.lock().unwrap_or_else(|e| e.into_inner());
        st.record_batch(batch_size);
        for r in &responses {
            st.record_latency(r.queue_s, r.compute_s);
        }
    }
    for r in responses {
        served.fetch_add(1, Ordering::Relaxed);
        let _ = tx_out.send(r);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::KernelKind;
    use crate::matrix::{suite, Csr};

    #[test]
    fn serves_correct_results() {
        let csr = suite::poisson2d(12);
        let engine = SpmvEngine::builder(csr.clone()).build().unwrap();
        let service = SpmvService::start(engine, 4);

        let n_req = 20usize;
        for id in 0..n_req as u64 {
            let x: Vec<f64> =
                (0..csr.cols).map(|i| (i as u64 + id) as f64 * 0.01).collect();
            service.submit(Request { id, x }).unwrap();
        }
        let mut got = 0usize;
        while got < n_req {
            let resp = service.recv().expect("response");
            let x: Vec<f64> = (0..csr.cols)
                .map(|i| (i as u64 + resp.id) as f64 * 0.01)
                .collect();
            let mut want = vec![0.0; csr.rows];
            csr.spmv_ref(&x, &mut want);
            crate::testkit::assert_close(&resp.y, &want, 1e-9, "service");
            assert!(resp.latency_s >= 0.0);
            assert!(
                (resp.latency_s - (resp.queue_s + resp.compute_s)).abs()
                    < 1e-15,
                "latency must be the sum of its components"
            );
            got += 1;
        }
        assert_eq!(service.shutdown(), n_req);
    }

    #[test]
    fn f32_service_serves_wide_blocks() {
        let csr32: Csr<f32> = suite::poisson2d(10).to_precision();
        let engine = SpmvEngine::builder(csr32.clone())
            .kernel(KernelKind::Beta(2, 16))
            .build()
            .unwrap();
        let service = SpmvService::start(engine, 3);
        for id in 0..8u64 {
            let x: Vec<f32> = (0..csr32.cols)
                .map(|i| ((i as u64 + id) % 13) as f32 * 0.1)
                .collect();
            service.submit(Request { id, x }).unwrap();
        }
        for _ in 0..8 {
            let resp = service.recv().expect("response");
            let x: Vec<f32> = (0..csr32.cols)
                .map(|i| ((i as u64 + resp.id) % 13) as f32 * 0.1)
                .collect();
            let mut want = vec![0.0f32; csr32.rows];
            csr32.spmv_ref(&x, &mut want);
            for i in 0..want.len() {
                assert!(
                    (resp.y[i] - want[i]).abs() <= 2e-4 * want[i].abs().max(1.0)
                );
            }
        }
        assert_eq!(service.shutdown(), 8);
    }

    #[test]
    fn service_over_csr_baseline() {
        let csr = suite::poisson2d(8);
        let engine = SpmvEngine::builder(csr.clone())
            .kernel(KernelKind::Csr)
            .build()
            .unwrap();
        let service = SpmvService::start(engine, 2);
        let x = vec![1.0; csr.cols];
        service.submit(Request { id: 0, x: x.clone() }).unwrap();
        let resp = service.recv().unwrap();
        let mut want = vec![0.0; csr.rows];
        csr.spmv_ref(&x, &mut want);
        crate::testkit::assert_close(&resp.y, &want, 1e-9, "csr service");
        assert_eq!(service.shutdown(), 1);
    }

    #[test]
    fn shutdown_without_requests() {
        let csr = suite::poisson2d(4);
        let engine = SpmvEngine::builder(csr).build().unwrap();
        let service = SpmvService::start(engine, 2);
        assert_eq!(service.shutdown(), 0);
    }

    #[test]
    fn submit_rejects_wrong_shape() {
        let csr = suite::poisson2d(6);
        let cols = csr.cols;
        let engine = SpmvEngine::builder(csr).build().unwrap();
        let service = SpmvService::start(engine, 2);
        let err = service
            .submit(Request { id: 0, x: vec![1.0; cols + 3] })
            .unwrap_err();
        assert_eq!(
            err,
            ServiceError::ShapeMismatch { expected: cols, got: cols + 3 }
        );
        assert_eq!(service.shutdown(), 0);
    }

    #[test]
    fn batching_coalesces_and_stats_report() {
        // Submit a burst before reading any response: the dispatcher
        // must coalesce at least one multi-request batch, and the
        // histogram/percentiles must account for every request.
        let csr = suite::poisson2d(10);
        let engine = SpmvEngine::builder(csr.clone())
            .kernel(KernelKind::Beta(1, 8))
            .threads(2)
            .build()
            .unwrap();
        let service = SpmvService::start(engine, 8);
        let n = 40u64;
        for id in 0..n {
            let x: Vec<f64> = (0..csr.cols)
                .map(|i| ((i as u64 * 3 + id) % 11) as f64 * 0.2)
                .collect();
            service.submit(Request { id, x }).unwrap();
        }
        for _ in 0..n {
            let resp = service.recv().unwrap();
            let x: Vec<f64> = (0..csr.cols)
                .map(|i| ((i as u64 * 3 + resp.id) % 11) as f64 * 0.2)
                .collect();
            let mut want = vec![0.0; csr.rows];
            csr.spmv_ref(&x, &mut want);
            crate::testkit::assert_close(&resp.y, &want, 1e-9, "batched");
        }
        let stats = service.stats();
        assert_eq!(stats.served, n as usize);
        assert_eq!(stats.rejected, 0);
        assert!(stats.batches <= stats.served);
        let hist_total: usize = stats
            .batch_hist
            .iter()
            .enumerate()
            .map(|(i, &c)| (i + 1) * c)
            .sum();
        assert_eq!(hist_total, n as usize, "histogram covers all requests");
        assert!(stats.p50_s <= stats.p95_s && stats.p95_s <= stats.p99_s);
        assert!(stats.queue.p50_s <= stats.queue.p99_s);
        assert!(stats.compute.p50_s <= stats.compute.p99_s);
        // Default policy: bounded at DEFAULT_QUEUE_CAPACITY, and 40
        // outstanding requests can never exceed that.
        assert!(stats.queue_depth_high_water <= service.policy().capacity());
        assert!(stats.queue_depth_high_water >= 1);
        assert_eq!(service.shutdown(), n as usize);
    }

    #[test]
    fn max_batch_one_disables_coalescing() {
        let csr = suite::poisson2d(6);
        let engine = SpmvEngine::builder(csr.clone()).build().unwrap();
        let service = SpmvService::start(engine, 1);
        for id in 0..10u64 {
            let x = vec![0.5; csr.cols];
            service.submit(Request { id, x }).unwrap();
        }
        for _ in 0..10 {
            service.recv().unwrap();
        }
        let stats = service.stats();
        assert_eq!(stats.batches, 10);
        assert_eq!(stats.batch_hist, vec![10]);
        assert_eq!(service.shutdown(), 10);
    }

    #[test]
    fn recv_timeout_times_out_and_then_delivers() {
        let csr = suite::poisson2d(6);
        let engine = SpmvEngine::builder(csr.clone()).build().unwrap();
        let service = SpmvService::start(engine, 2);
        // Nothing submitted: the wait must elapse fully.
        let wait = Duration::from_millis(30);
        let t0 = Instant::now();
        assert_eq!(
            service.recv_timeout(wait).unwrap_err(),
            RecvTimeoutError::Timeout
        );
        assert!(t0.elapsed() >= wait);
        // Now a submitted request arrives well within a generous wait.
        service.submit(Request { id: 7, x: vec![1.0; csr.cols] }).unwrap();
        let resp = service.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(resp.id, 7);
        assert_eq!(service.shutdown(), 1);
    }

    #[test]
    fn reject_policy_bounds_in_flight_exactly() {
        let csr = suite::poisson2d(8);
        let cols = csr.cols;
        let engine = SpmvEngine::builder(csr).build().unwrap();
        let cap = 3usize;
        let service = SpmvService::start_with_policy(
            engine,
            2,
            QueuePolicy::Reject { capacity: cap },
        );
        // Exactly `cap` submissions are admitted …
        for id in 0..cap as u64 {
            service.submit(Request { id, x: vec![1.0; cols] }).unwrap();
        }
        // … and the next is refused even though the dispatcher may
        // already have computed responses: the slot frees on receive.
        assert_eq!(
            service.submit(Request { id: 99, x: vec![1.0; cols] }),
            Err(ServiceError::Overloaded { capacity: cap })
        );
        assert_eq!(service.rejected(), 1);
        // Receiving one response admits one more.
        service.recv().unwrap();
        service.submit(Request { id: 100, x: vec![1.0; cols] }).unwrap();
        for _ in 0..cap {
            service.recv().unwrap();
        }
        let stats = service.stats();
        assert_eq!(stats.rejected, 1);
        assert!(
            stats.queue_depth_high_water <= cap,
            "in-flight {} exceeded capacity {cap}",
            stats.queue_depth_high_water
        );
        // Every submission got a Response or an Overloaded: cap + 1
        // accepted (all received), 1 rejected.
        assert_eq!(service.shutdown(), cap + 1);
    }

    #[test]
    fn block_policy_never_drops() {
        let csr = suite::poisson2d(8);
        let cols = csr.cols;
        let engine = SpmvEngine::builder(csr).build().unwrap();
        let service = SpmvService::start_with_policy(
            engine,
            4,
            QueuePolicy::Block { capacity: 2 },
        );
        let n = 50usize;
        std::thread::scope(|s| {
            s.spawn(|| {
                for _ in 0..n {
                    service.recv().expect("blocked submitter's response");
                }
            });
            // Far more submissions than capacity: each blocks until
            // the consumer frees a slot; none may fail or drop.
            for id in 0..n as u64 {
                service.submit(Request { id, x: vec![0.5; cols] }).unwrap();
            }
        });
        assert_eq!(service.rejected(), 0);
        let stats = service.stats();
        assert!(stats.queue_depth_high_water <= 2);
        assert_eq!(service.shutdown(), n);
    }

    #[test]
    fn timeout_policy_respects_deadline() {
        let csr = suite::poisson2d(8);
        let cols = csr.cols;
        let engine = SpmvEngine::builder(csr).build().unwrap();
        let wait = Duration::from_millis(40);
        let service = SpmvService::start_with_policy(
            engine,
            2,
            QueuePolicy::Timeout { capacity: 1, wait },
        );
        service.submit(Request { id: 0, x: vec![1.0; cols] }).unwrap();
        // The slot stays held until recv, so this submission waits the
        // full deadline and then comes back Overloaded.
        let t0 = Instant::now();
        assert_eq!(
            service.submit(Request { id: 1, x: vec![1.0; cols] }),
            Err(ServiceError::Overloaded { capacity: 1 })
        );
        assert!(t0.elapsed() >= wait, "rejected before the deadline");
        service.recv().unwrap();
        // Slot freed: admitted immediately.
        service.submit(Request { id: 2, x: vec![1.0; cols] }).unwrap();
        service.recv().unwrap();
        assert_eq!(service.shutdown(), 2);
    }

    #[test]
    fn dispatcher_panic_reports_failed_not_stopped() {
        use crate::faults::{Action, FaultPlan, FaultRule, SiteKind};
        let csr = suite::poisson2d(8);
        let engine = SpmvEngine::builder(csr.clone()).build().unwrap();
        let plan = Arc::new(FaultPlan::new(
            vec![FaultRule::new(SiteKind::Compute, Action::Panic).nth(0)],
            0,
        ));
        let service = SpmvService::start_shard(
            engine,
            2,
            QueuePolicy::default(),
            3,
            7,
            Some(plan),
        );
        // A client already blocked in recv when the dispatcher dies
        // must wake with the typed failure, not a silent stop.
        std::thread::scope(|s| {
            let blocked = s.spawn(|| service.recv());
            std::thread::sleep(Duration::from_millis(20));
            let _ = service
                .submit(Request { id: 0, x: vec![1.0; csr.cols] });
            assert_eq!(
                blocked.join().unwrap().unwrap_err(),
                RecvError::Failed { shard: 3, generation: 7 }
            );
        });
        assert!(service.failed());
        assert_eq!(service.health().health, ShardHealth::Poisoned);
        // Submissions and bounded receives after the death are typed
        // failures too.
        assert_eq!(
            service.submit(Request { id: 1, x: vec![1.0; csr.cols] }),
            Err(ServiceError::ShardFailed { shard: 3, generation: 7 })
        );
        assert_eq!(
            service.recv_timeout(Duration::from_secs(5)).unwrap_err(),
            RecvError::Failed { shard: 3, generation: 7 }
        );
    }

    #[test]
    fn clean_shutdown_reports_stopped_to_blocked_receivers() {
        let csr = suite::poisson2d(6);
        let engine = SpmvEngine::builder(csr).build().unwrap();
        let service = SpmvService::start(engine, 2);
        std::thread::scope(|s| {
            let blocked = s.spawn(|| service.recv());
            std::thread::sleep(Duration::from_millis(20));
            service.shutdown_ref();
            assert_eq!(
                blocked.join().unwrap().unwrap_err(),
                RecvError::Stopped
            );
        });
        assert!(!service.failed());
        assert_eq!(service.health().health, ShardHealth::Up);
    }

    #[test]
    fn shutdown_with_full_queue_serves_accepted_requests() {
        let csr = suite::poisson2d(8);
        let cols = csr.cols;
        let engine = SpmvEngine::builder(csr).build().unwrap();
        let cap = 4usize;
        let service = SpmvService::start_with_policy(
            engine,
            2,
            QueuePolicy::Reject { capacity: cap },
        );
        // Fill to capacity and shut down without receiving anything:
        // shutdown must neither hang nor lose the accepted requests.
        for id in 0..cap as u64 {
            service.submit(Request { id, x: vec![1.0; cols] }).unwrap();
        }
        assert_eq!(service.shutdown(), cap);
    }
}
