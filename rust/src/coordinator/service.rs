//! Request-loop service — a thin serving layer over [`SpmvEngine`]
//! demonstrating the library in a long-running deployment (the
//! `spmv_server` example): requests arrive on a channel, a worker pool
//! answers them, per-request latency is recorded.
//!
//! The matrix and kernel are fixed at service construction (the
//! iterative-solver deployment); each request carries its own `x`.

use super::engine::SpmvEngine;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Arc;

/// One SpMV request.
pub struct Request {
    pub id: u64,
    pub x: Vec<f64>,
}

/// The answer to a [`Request`].
pub struct Response {
    pub id: u64,
    pub y: Vec<f64>,
    /// Service-side latency in seconds (queue + compute).
    pub latency_s: f64,
}

/// A running service instance.
pub struct SpmvService {
    tx: Option<mpsc::Sender<(Request, std::time::Instant)>>,
    rx_out: mpsc::Receiver<Response>,
    workers: Vec<std::thread::JoinHandle<()>>,
    served: Arc<AtomicUsize>,
}

impl SpmvService {
    /// Spawns `workers` threads sharing the engine.
    pub fn start(engine: SpmvEngine, workers: usize) -> SpmvService {
        assert!(workers > 0);
        let engine = Arc::new(engine);
        let (tx, rx) = mpsc::channel::<(Request, std::time::Instant)>();
        let rx = Arc::new(std::sync::Mutex::new(rx));
        let (tx_out, rx_out) = mpsc::channel::<Response>();
        let served = Arc::new(AtomicUsize::new(0));

        let mut handles = Vec::with_capacity(workers);
        for _ in 0..workers {
            let rx = Arc::clone(&rx);
            let tx_out = tx_out.clone();
            let engine = Arc::clone(&engine);
            let served = Arc::clone(&served);
            handles.push(std::thread::spawn(move || loop {
                let msg = { rx.lock().unwrap().recv() };
                let Ok((req, enqueued)) = msg else {
                    break; // channel closed → shut down
                };
                let rows = engine.csr().rows;
                let mut y = vec![0.0f64; rows];
                engine.spmv_into(&req.x, &mut y);
                served.fetch_add(1, Ordering::Relaxed);
                let _ = tx_out.send(Response {
                    id: req.id,
                    y,
                    latency_s: enqueued.elapsed().as_secs_f64(),
                });
            }));
        }
        SpmvService { tx: Some(tx), rx_out, workers: handles, served }
    }

    /// Enqueues a request.
    pub fn submit(&self, req: Request) {
        self.tx
            .as_ref()
            .expect("service running")
            .send((req, std::time::Instant::now()))
            .expect("workers alive");
    }

    /// Blocks for the next response.
    pub fn recv(&self) -> Option<Response> {
        self.rx_out.recv().ok()
    }

    /// Requests served so far.
    pub fn served(&self) -> usize {
        self.served.load(Ordering::Relaxed)
    }

    /// Graceful shutdown: waits for queued work, joins workers.
    pub fn shutdown(mut self) -> usize {
        drop(self.tx.take());
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        self.served()
    }
}

impl Drop for SpmvService {
    fn drop(&mut self) {
        drop(self.tx.take());
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::EngineConfig;
    use crate::matrix::suite;

    #[test]
    fn serves_correct_results() {
        let csr = suite::poisson2d(12);
        let engine =
            SpmvEngine::new(csr.clone(), &EngineConfig::default(), None).unwrap();
        let service = SpmvService::start(engine, 3);

        let n_req = 20usize;
        for id in 0..n_req as u64 {
            let x: Vec<f64> =
                (0..csr.cols).map(|i| (i as u64 + id) as f64 * 0.01).collect();
            service.submit(Request { id, x });
        }
        let mut got = 0usize;
        while got < n_req {
            let resp = service.recv().expect("response");
            let x: Vec<f64> = (0..csr.cols)
                .map(|i| (i as u64 + resp.id) as f64 * 0.01)
                .collect();
            let mut want = vec![0.0; csr.rows];
            csr.spmv_ref(&x, &mut want);
            crate::testkit::assert_close(&resp.y, &want, 1e-9, "service");
            assert!(resp.latency_s >= 0.0);
            got += 1;
        }
        assert_eq!(service.shutdown(), n_req);
    }

    #[test]
    fn shutdown_without_requests() {
        let csr = suite::poisson2d(4);
        let engine =
            SpmvEngine::new(csr, &EngineConfig::default(), None).unwrap();
        let service = SpmvService::start(engine, 2);
        assert_eq!(service.shutdown(), 0);
    }
}
