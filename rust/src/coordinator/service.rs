//! Request-loop service — the serving layer over [`SpmvEngine`] used
//! by the `spmv_server` example, generic over the engine's precision.
//!
//! The matrix and kernel are fixed at service construction (the
//! iterative-solver deployment); each request carries its own `x`.
//!
//! ## Micro-batching dispatcher
//!
//! One dispatcher thread drains the request queue and **coalesces
//! concurrent requests against the same matrix into a single
//! multi-RHS product** routed through [`SpmvEngine::spmm`] (the block
//! kernels traverse the matrix once for all `k` right-hand sides —
//! amortizing matrix traffic across clients), falling back to the
//! single-vector SpMV when only one request is pending. The compute
//! itself runs on the engine's persistent [`crate::parallel::WorkerPool`]
//! when the engine is parallel — the service spawns no per-request
//! threads and shares the same runtime as the solvers.
//!
//! Per-request latency (queue + compute) and per-batch size are
//! recorded; [`SpmvService::stats`] exposes p50/p95/p99 and the
//! batch-size histogram.

use super::engine::SpmvEngine;
use crate::scalar::Scalar;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

/// One SpMV request.
pub struct Request<T: Scalar = f64> {
    pub id: u64,
    pub x: Vec<T>,
}

/// The answer to a [`Request`].
pub struct Response<T: Scalar = f64> {
    pub id: u64,
    pub y: Vec<T>,
    /// Service-side latency in seconds (queue + compute).
    pub latency_s: f64,
}

/// Why a [`SpmvService::submit`] was rejected.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServiceError {
    /// The dispatcher is gone (service shut down or crashed); the
    /// request was not enqueued.
    Stopped,
    /// `x` does not match the served matrix's column count; accepting
    /// it would poison the whole batch it lands in.
    ShapeMismatch { expected: usize, got: usize },
}

impl std::fmt::Display for ServiceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServiceError::Stopped => {
                write!(f, "service stopped: request not enqueued")
            }
            ServiceError::ShapeMismatch { expected, got } => write!(
                f,
                "request x has {got} entries, matrix expects {expected}"
            ),
        }
    }
}

impl std::error::Error for ServiceError {}

/// Service-level latency / batching statistics snapshot.
#[derive(Clone, Debug)]
pub struct ServiceStats {
    /// Requests completed.
    pub served: usize,
    /// Dispatched batches (≤ served; smaller when coalescing happens).
    pub batches: usize,
    /// Latency percentiles in seconds over the most recent
    /// [`LATENCY_WINDOW`] requests (0.0 when nothing served yet).
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
    /// `batch_hist[i]` = number of batches of size `i + 1`.
    pub batch_hist: Vec<usize>,
}

/// Latency samples kept for the percentiles — a bounded ring, so a
/// long-running deployment neither grows without bound nor pays more
/// than an O(window log window) sort per stats snapshot.
pub const LATENCY_WINDOW: usize = 4096;

#[derive(Default)]
struct StatsInner {
    /// Ring of the last [`LATENCY_WINDOW`] per-request latencies.
    latencies_s: Vec<f64>,
    /// Next ring slot to overwrite once the window is full.
    next: usize,
    batch_hist: Vec<usize>,
    batches: usize,
}

impl StatsInner {
    fn record_batch(&mut self, size: usize) {
        if self.batch_hist.len() < size {
            self.batch_hist.resize(size, 0);
        }
        self.batch_hist[size - 1] += 1;
        self.batches += 1;
    }

    fn record_latency(&mut self, latency_s: f64) {
        if self.latencies_s.len() < LATENCY_WINDOW {
            self.latencies_s.push(latency_s);
        } else {
            self.latencies_s[self.next] = latency_s;
            self.next = (self.next + 1) % LATENCY_WINDOW;
        }
    }
}

/// A running service instance (see module docs).
pub struct SpmvService<T: Scalar = f64> {
    tx: Option<mpsc::Sender<(Request<T>, std::time::Instant)>>,
    rx_out: mpsc::Receiver<Response<T>>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
    served: Arc<AtomicUsize>,
    stats: Arc<Mutex<StatsInner>>,
    cols: usize,
    max_batch: usize,
}

impl<T: Scalar> SpmvService<T> {
    /// Starts the dispatcher over `engine`, coalescing up to
    /// `max_batch` pending requests into one multi-RHS product. The
    /// parallel compute runs on the engine's own persistent pool; the
    /// service adds exactly one dispatcher thread.
    pub fn start(engine: SpmvEngine<T>, max_batch: usize) -> SpmvService<T> {
        assert!(max_batch > 0);
        let (cols, rows) = (engine.csr().cols, engine.csr().rows);
        let (tx, rx) = mpsc::channel::<(Request<T>, std::time::Instant)>();
        let (tx_out, rx_out) = mpsc::channel::<Response<T>>();
        let served = Arc::new(AtomicUsize::new(0));
        let stats = Arc::new(Mutex::new(StatsInner::default()));

        let served_d = Arc::clone(&served);
        let stats_d = Arc::clone(&stats);
        let dispatcher = std::thread::Builder::new()
            .name("spc5-dispatch".into())
            .spawn(move || {
                dispatch_loop(
                    engine, rx, tx_out, served_d, stats_d, rows, max_batch,
                )
            })
            .expect("spawn dispatcher");

        SpmvService {
            tx: Some(tx),
            rx_out,
            dispatcher: Some(dispatcher),
            served,
            stats,
            cols,
            max_batch,
        }
    }

    /// Enqueues a request. Fails instead of panicking when the
    /// dispatcher is gone or the vector has the wrong length.
    pub fn submit(&self, req: Request<T>) -> Result<(), ServiceError> {
        if req.x.len() != self.cols {
            return Err(ServiceError::ShapeMismatch {
                expected: self.cols,
                got: req.x.len(),
            });
        }
        self.tx
            .as_ref()
            .ok_or(ServiceError::Stopped)?
            .send((req, std::time::Instant::now()))
            .map_err(|_| ServiceError::Stopped)
    }

    /// Blocks for the next response.
    pub fn recv(&self) -> Option<Response<T>> {
        self.rx_out.recv().ok()
    }

    /// Requests served so far.
    pub fn served(&self) -> usize {
        self.served.load(Ordering::Relaxed)
    }

    /// The coalescing limit this service was started with.
    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    /// Snapshot of the latency percentiles and batch-size histogram.
    pub fn stats(&self) -> ServiceStats {
        // Hold the dispatcher-shared lock only for the cheap clones;
        // sort after releasing it so monitoring polls cannot stall the
        // dispatch hot path.
        let (mut sorted, batches, batch_hist) = {
            let inner =
                self.stats.lock().unwrap_or_else(|e| e.into_inner());
            (
                inner.latencies_s.clone(),
                inner.batches,
                inner.batch_hist.clone(),
            )
        };
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
        let pct = |p: f64| -> f64 {
            if sorted.is_empty() {
                0.0
            } else {
                sorted[((p * (sorted.len() - 1) as f64).round()) as usize]
            }
        };
        ServiceStats {
            served: self.served(),
            batches,
            p50_s: pct(0.50),
            p95_s: pct(0.95),
            p99_s: pct(0.99),
            batch_hist,
        }
    }

    /// Graceful shutdown: waits for queued work, joins the dispatcher.
    pub fn shutdown(mut self) -> usize {
        drop(self.tx.take());
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
        self.served()
    }
}

impl<T: Scalar> Drop for SpmvService<T> {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
    }
}

/// The dispatcher: blocking-recv one request, greedily drain whatever
/// else is already queued (up to `max_batch`), serve the batch through
/// one engine call, answer every member.
#[allow(clippy::too_many_arguments)]
fn dispatch_loop<T: Scalar>(
    engine: SpmvEngine<T>,
    rx: mpsc::Receiver<(Request<T>, std::time::Instant)>,
    tx_out: mpsc::Sender<Response<T>>,
    served: Arc<AtomicUsize>,
    stats: Arc<Mutex<StatsInner>>,
    rows: usize,
    max_batch: usize,
) {
    // Reused across batches: the packed X/Y panels.
    let mut xb: Vec<T> = Vec::new();
    let mut yb: Vec<T> = Vec::new();
    let mut batch: Vec<(Request<T>, std::time::Instant)> = Vec::new();

    loop {
        batch.clear();
        match rx.recv() {
            Ok(first) => batch.push(first),
            Err(_) => return, // channel closed → drain done, shut down
        }
        while batch.len() < max_batch {
            match rx.try_recv() {
                Ok(next) => batch.push(next),
                Err(_) => break,
            }
        }

        let k = batch.len();
        if k == 1 {
            // Single pending request: plain SpMV, no packing cost.
            let (req, enqueued) = &batch[0];
            let mut y = vec![T::ZERO; rows];
            engine.spmv_into(&req.x, &mut y);
            finish(&tx_out, &served, &stats, 1, [(req.id, y, enqueued)]);
        } else {
            // Coalesce: one [cols × k] panel, one matrix traversal.
            // Packed c-major/j-minor so every slot is written exactly
            // once (no redundant zero-fill on the dispatch hot path).
            let cols = engine.csr().cols;
            xb.clear();
            xb.reserve(cols * k);
            for c in 0..cols {
                for (req, _) in batch.iter() {
                    xb.push(req.x[c]);
                }
            }
            if yb.len() != rows * k {
                yb.resize(rows * k, T::ZERO);
            }
            engine.spmm_into(&xb, &mut yb, k);
            let members = batch.iter().enumerate().map(|(j, (req, enq))| {
                let y: Vec<T> = (0..rows).map(|r| yb[r * k + j]).collect();
                (req.id, y, enq)
            });
            finish(&tx_out, &served, &stats, k, members);
        }
    }
}

/// Answers every member of one served batch and records statistics.
/// The stats lock is released before any response is sent, so a
/// concurrent `stats()` poll never delays delivery.
fn finish<'a, T: Scalar>(
    tx_out: &mpsc::Sender<Response<T>>,
    served: &AtomicUsize,
    stats: &Mutex<StatsInner>,
    batch_size: usize,
    members: impl IntoIterator<Item = (u64, Vec<T>, &'a std::time::Instant)>,
) {
    let responses: Vec<Response<T>> = members
        .into_iter()
        .map(|(id, y, enqueued)| Response {
            id,
            y,
            latency_s: enqueued.elapsed().as_secs_f64(),
        })
        .collect();
    {
        let mut st = stats.lock().unwrap_or_else(|e| e.into_inner());
        st.record_batch(batch_size);
        for r in &responses {
            st.record_latency(r.latency_s);
        }
    }
    for r in responses {
        served.fetch_add(1, Ordering::Relaxed);
        let _ = tx_out.send(r);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::KernelKind;
    use crate::matrix::{suite, Csr};

    #[test]
    fn serves_correct_results() {
        let csr = suite::poisson2d(12);
        let engine = SpmvEngine::builder(csr.clone()).build().unwrap();
        let service = SpmvService::start(engine, 4);

        let n_req = 20usize;
        for id in 0..n_req as u64 {
            let x: Vec<f64> =
                (0..csr.cols).map(|i| (i as u64 + id) as f64 * 0.01).collect();
            service.submit(Request { id, x }).unwrap();
        }
        let mut got = 0usize;
        while got < n_req {
            let resp = service.recv().expect("response");
            let x: Vec<f64> = (0..csr.cols)
                .map(|i| (i as u64 + resp.id) as f64 * 0.01)
                .collect();
            let mut want = vec![0.0; csr.rows];
            csr.spmv_ref(&x, &mut want);
            crate::testkit::assert_close(&resp.y, &want, 1e-9, "service");
            assert!(resp.latency_s >= 0.0);
            got += 1;
        }
        assert_eq!(service.shutdown(), n_req);
    }

    #[test]
    fn f32_service_serves_wide_blocks() {
        let csr32: Csr<f32> = suite::poisson2d(10).to_precision();
        let engine = SpmvEngine::builder(csr32.clone())
            .kernel(KernelKind::Beta(2, 16))
            .build()
            .unwrap();
        let service = SpmvService::start(engine, 3);
        for id in 0..8u64 {
            let x: Vec<f32> = (0..csr32.cols)
                .map(|i| ((i as u64 + id) % 13) as f32 * 0.1)
                .collect();
            service.submit(Request { id, x }).unwrap();
        }
        for _ in 0..8 {
            let resp = service.recv().expect("response");
            let x: Vec<f32> = (0..csr32.cols)
                .map(|i| ((i as u64 + resp.id) % 13) as f32 * 0.1)
                .collect();
            let mut want = vec![0.0f32; csr32.rows];
            csr32.spmv_ref(&x, &mut want);
            for i in 0..want.len() {
                assert!(
                    (resp.y[i] - want[i]).abs() <= 2e-4 * want[i].abs().max(1.0)
                );
            }
        }
        assert_eq!(service.shutdown(), 8);
    }

    #[test]
    fn service_over_csr_baseline() {
        let csr = suite::poisson2d(8);
        let engine = SpmvEngine::builder(csr.clone())
            .kernel(KernelKind::Csr)
            .build()
            .unwrap();
        let service = SpmvService::start(engine, 2);
        let x = vec![1.0; csr.cols];
        service.submit(Request { id: 0, x: x.clone() }).unwrap();
        let resp = service.recv().unwrap();
        let mut want = vec![0.0; csr.rows];
        csr.spmv_ref(&x, &mut want);
        crate::testkit::assert_close(&resp.y, &want, 1e-9, "csr service");
        assert_eq!(service.shutdown(), 1);
    }

    #[test]
    fn shutdown_without_requests() {
        let csr = suite::poisson2d(4);
        let engine = SpmvEngine::builder(csr).build().unwrap();
        let service = SpmvService::start(engine, 2);
        assert_eq!(service.shutdown(), 0);
    }

    #[test]
    fn submit_rejects_wrong_shape() {
        let csr = suite::poisson2d(6);
        let cols = csr.cols;
        let engine = SpmvEngine::builder(csr).build().unwrap();
        let service = SpmvService::start(engine, 2);
        let err = service
            .submit(Request { id: 0, x: vec![1.0; cols + 3] })
            .unwrap_err();
        assert_eq!(
            err,
            ServiceError::ShapeMismatch { expected: cols, got: cols + 3 }
        );
        assert_eq!(service.shutdown(), 0);
    }

    #[test]
    fn batching_coalesces_and_stats_report() {
        // Submit a burst before reading any response: the dispatcher
        // must coalesce at least one multi-request batch, and the
        // histogram/percentiles must account for every request.
        let csr = suite::poisson2d(10);
        let engine = SpmvEngine::builder(csr.clone())
            .kernel(KernelKind::Beta(1, 8))
            .threads(2)
            .build()
            .unwrap();
        let service = SpmvService::start(engine, 8);
        let n = 40u64;
        for id in 0..n {
            let x: Vec<f64> = (0..csr.cols)
                .map(|i| ((i as u64 * 3 + id) % 11) as f64 * 0.2)
                .collect();
            service.submit(Request { id, x }).unwrap();
        }
        for _ in 0..n {
            let resp = service.recv().unwrap();
            let x: Vec<f64> = (0..csr.cols)
                .map(|i| ((i as u64 * 3 + resp.id) % 11) as f64 * 0.2)
                .collect();
            let mut want = vec![0.0; csr.rows];
            csr.spmv_ref(&x, &mut want);
            crate::testkit::assert_close(&resp.y, &want, 1e-9, "batched");
        }
        let stats = service.stats();
        assert_eq!(stats.served, n as usize);
        assert!(stats.batches <= stats.served);
        let hist_total: usize = stats
            .batch_hist
            .iter()
            .enumerate()
            .map(|(i, &c)| (i + 1) * c)
            .sum();
        assert_eq!(hist_total, n as usize, "histogram covers all requests");
        assert!(stats.p50_s <= stats.p95_s && stats.p95_s <= stats.p99_s);
        assert_eq!(service.shutdown(), n as usize);
    }

    #[test]
    fn max_batch_one_disables_coalescing() {
        let csr = suite::poisson2d(6);
        let engine = SpmvEngine::builder(csr.clone()).build().unwrap();
        let service = SpmvService::start(engine, 1);
        for id in 0..10u64 {
            let x = vec![0.5; csr.cols];
            service.submit(Request { id, x }).unwrap();
        }
        for _ in 0..10 {
            service.recv().unwrap();
        }
        let stats = service.stats();
        assert_eq!(stats.batches, 10);
        assert_eq!(stats.batch_hist, vec![10]);
        assert_eq!(service.shutdown(), 10);
    }
}
