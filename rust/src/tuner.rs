//! Machine-level kernel autotuner.
//!
//! The β hot loops are compiled as a small table of monomorphized
//! variants ([`crate::kernels::VARIANT_TABLE`]) differing in prefetch
//! distances, `x`-prefetch and unrolling — knobs whose best setting
//! depends on the executing machine, not the matrix alone. This module
//! is the offline half of that machinery:
//!
//! 1. **sweep** — [`sweep`] benchmarks every variant × β kernel on a
//!    set of representative generators (or a user matrix), using the
//!    paper's 16-run-mean protocol;
//! 2. **profile** — the per-kernel winners are persisted as a
//!    machine-keyed [`TuneProfile`] JSON (`spc5 tune --out`), and every
//!    individual measurement feeds the predictor's
//!    [`crate::predictor::RecordStore`] (records carry the variant, so
//!    tuned and baseline measurements coexist);
//! 3. **plan** — `SpmvEngine::builder(..).tune_profile(path)` consults
//!    the profile at plan time: the planned kernel (and each β segment
//!    of a hybrid schedule) gets its winning variant pinned into the
//!    serializable [`crate::SpmvPlan`], which instantiation dispatches
//!    once per storage — never per block.
//!
//! The sweep is *safe to apply* by construction: every variant reorders
//! only prefetch hints and loop control, never the FMA order, so a
//! tuned engine is bit-identical to the baseline build (the
//! `tune_variants` differential tests pin this down).

use crate::formats::csr_to_block;
use crate::kernels::{spmv_block, KernelKind, TuneParams, VARIANT_TABLE};
use crate::matrix::{suite, Csr};
use crate::parallel::{ParallelSpmv, ParallelStrategy};
use crate::predictor::PerfRecord;
use crate::util::durable::{self, RawState, StateError, StateErrorKind};
use crate::util::json::Json;
use crate::util::timer::{mean_of_runs, spmv_gflops};
use std::path::Path;

/// One per-kernel sweep winner.
#[derive(Clone, Debug, PartialEq)]
pub struct TuneEntry {
    /// The β kernel the sweep ran (spelled `b(r,c)`; engine lookups
    /// for `bt(r,c)` fold onto the same entry — the test kernels run
    /// the same loops).
    pub kernel: KernelKind,
    /// Thread count the sweep ran at (`1` = sequential).
    pub threads: usize,
    /// The winning variant.
    pub tune: TuneParams,
    /// Mean GFlop/s of the winner across the sweep matrices.
    pub gflops: f64,
    /// Mean GFlop/s of the baseline variant on the same matrices —
    /// kept so the profile records the margin, not just the choice.
    pub baseline_gflops: f64,
}

/// Per-machine sweep results: which kernel variant to run for each β
/// kernel on *this* machine. Written by `spc5 tune`, consulted by
/// `SpmvEngineBuilder::tune_profile` at plan time.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TuneProfile {
    /// The machine the sweep ran on (CPU model + AVX-512 availability
    /// + core count) — a profile is only meaningful on the machine
    /// that produced it, so the key travels with the data.
    pub machine: String,
    pub entries: Vec<TuneEntry>,
}

/// The machine key a sweep stamps into its profile: CPU model name
/// (from `/proc/cpuinfo`, `unknown-cpu` elsewhere), AVX-512
/// availability and logical core count.
pub fn machine_key() -> String {
    let model = std::fs::read_to_string("/proc/cpuinfo")
        .ok()
        .and_then(|s| {
            s.lines()
                .find(|l| l.starts_with("model name"))
                .and_then(|l| l.split(':').nth(1))
                .map(|v| v.trim().to_string())
        })
        .unwrap_or_else(|| "unknown-cpu".to_string());
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    format!(
        "{model} | avx512={} | cores={cores}",
        crate::util::avx512_available()
    )
}

impl TuneProfile {
    /// The variant to run for `kernel` at `threads`, if the sweep
    /// covered it: an exact `(kernel, threads)` entry wins, else the
    /// same kernel at any thread count (prefetch behavior is mostly
    /// core-local), else `None`. `bt(r,c)` lookups fold onto the
    /// `b(r,c)` entry — the test kernels run the same loops.
    pub fn lookup(
        &self,
        kernel: KernelKind,
        threads: usize,
    ) -> Option<TuneParams> {
        let key = match kernel {
            KernelKind::BetaTest(r, c) => KernelKind::Beta(r, c),
            k => k,
        };
        self.entries
            .iter()
            .find(|e| e.kernel == key && e.threads == threads)
            .or_else(|| self.entries.iter().find(|e| e.kernel == key))
            .map(|e| e.tune)
    }

    /// Serializes to JSON text.
    pub fn to_json(&self) -> String {
        let entries: Vec<Json> = self
            .entries
            .iter()
            .map(|e| {
                Json::obj(vec![
                    ("kernel", Json::Str(e.kernel.to_string())),
                    ("threads", Json::Num(e.threads as f64)),
                    ("hpd", Json::Num(e.tune.header_prefetch_dist as f64)),
                    ("vpd", Json::Num(e.tune.value_prefetch_dist as f64)),
                    ("pfx", Json::Bool(e.tune.prefetch_x)),
                    ("unroll", Json::Num(e.tune.unroll as f64)),
                    ("gflops", Json::Num(e.gflops)),
                    ("baseline_gflops", Json::Num(e.baseline_gflops)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("version", Json::Num(1.0)),
            ("machine", Json::Str(self.machine.clone())),
            ("entries", Json::Arr(entries)),
        ])
        .to_string()
    }

    /// Parses from JSON text. Unlike the record store, every tuning
    /// field is **required** here: a partially specified profile would
    /// silently pin a different variant than the sweep measured.
    pub fn from_json(text: &str) -> anyhow::Result<Self> {
        let v = Json::parse(text)?;
        let machine = v
            .get("machine")
            .and_then(|m| m.as_str())
            .ok_or_else(|| anyhow::anyhow!("profile: missing machine"))?
            .to_string();
        let arr = v
            .get("entries")
            .and_then(|e| e.as_arr())
            .ok_or_else(|| anyhow::anyhow!("profile: missing entries"))?;
        let mut entries = Vec::with_capacity(arr.len());
        for (i, item) in arr.iter().enumerate() {
            let field = |k: &str| {
                item.get(k).ok_or_else(|| {
                    anyhow::anyhow!("profile entry {i}: missing {k}")
                })
            };
            let num = |k: &str| -> anyhow::Result<f64> {
                field(k)?.as_f64().ok_or_else(|| {
                    anyhow::anyhow!("profile entry {i}: {k} not a number")
                })
            };
            let kernel_s = field("kernel")?.as_str().ok_or_else(|| {
                anyhow::anyhow!("profile entry {i}: kernel not a string")
            })?;
            let kernel = KernelKind::parse(kernel_s).ok_or_else(|| {
                anyhow::anyhow!("profile entry {i}: bad kernel '{kernel_s}'")
            })?;
            let u8_field = |k: &str| -> anyhow::Result<u8> {
                let n = num(k)?;
                anyhow::ensure!(
                    n >= 0.0 && n <= 255.0 && n.fract() == 0.0,
                    "profile entry {i}: {k} out of range"
                );
                Ok(n as u8)
            };
            let unroll = u8_field("unroll")?;
            anyhow::ensure!(
                unroll == 1 || unroll == 2,
                "profile entry {i}: unroll must be 1 or 2"
            );
            entries.push(TuneEntry {
                kernel,
                threads: num("threads")? as usize,
                tune: TuneParams {
                    header_prefetch_dist: u8_field("hpd")?,
                    value_prefetch_dist: u8_field("vpd")?,
                    prefetch_x: field("pfx")?.as_bool().ok_or_else(|| {
                        anyhow::anyhow!("profile entry {i}: pfx not a bool")
                    })?,
                    unroll,
                },
                gflops: num("gflops")?,
                baseline_gflops: num("baseline_gflops")?,
            });
        }
        Ok(TuneProfile { machine, entries })
    }

    /// Artifact label used in [`StateError`] and degradation events.
    pub const ARTIFACT: &'static str = "tune-profile";

    /// Saves to a file, envelope-framed and atomically (see
    /// [`crate::util::durable`]).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<(), StateError> {
        durable::save_state(Self::ARTIFACT, path.as_ref(), &self.to_json())
    }

    /// Loads from a file. A missing file is a hard error (a typo'd
    /// `--tune-profile` path must not silently run untuned); an empty
    /// or corrupt file is quarantined and reported as a typed
    /// [`StateError`] — plan-time callers degrade to the baseline
    /// variant with a recorded downgrade. Legacy (pre-envelope) files
    /// load unverified.
    pub fn load(path: impl AsRef<Path>) -> Result<Self, StateError> {
        let path = path.as_ref();
        match durable::read_state(Self::ARTIFACT, path)? {
            RawState::Missing => Err(StateError {
                artifact: Self::ARTIFACT,
                path: path.to_path_buf(),
                kind: StateErrorKind::Io(std::io::Error::new(
                    std::io::ErrorKind::NotFound,
                    "no such file",
                )),
                quarantined_to: None,
            }),
            RawState::Empty => Err(durable::quarantined(
                Self::ARTIFACT,
                path,
                StateErrorKind::Malformed("file is empty".into()),
            )),
            RawState::Payload { text, .. } => Self::from_json(&text)
                .map_err(|e| {
                    durable::quarantined(
                        Self::ARTIFACT,
                        path,
                        StateErrorKind::Malformed(e.to_string()),
                    )
                }),
        }
    }
}

/// What [`sweep`] measures: which kernels, which variants, on which
/// matrices, at what thread count and measurement length.
pub struct SweepConfig {
    /// β kernels to sweep (non-β entries are skipped).
    pub kernels: Vec<KernelKind>,
    /// Indices into [`VARIANT_TABLE`]. Index 0 (the baseline) is
    /// always measured — it anchors `baseline_gflops`.
    pub variants: Vec<usize>,
    /// Thread count every measurement runs at (`1` = sequential).
    pub threads: usize,
    /// Runs per measurement (the paper uses 16; `quick` trims it).
    pub runs: usize,
    /// Named matrices the sweep averages over.
    pub matrices: Vec<(String, Csr)>,
}

impl SweepConfig {
    /// The full offline sweep: every distinct-β paper kernel × every
    /// variant, averaged over five structurally distinct generators.
    pub fn full() -> Self {
        SweepConfig {
            kernels: beta_kernels(),
            variants: (0..VARIANT_TABLE.len()).collect(),
            threads: 1,
            runs: crate::bench::RUNS,
            matrices: vec![
                ("fem".into(), suite::fem_blocked(1_500, 3, 6, 7)),
                ("poisson".into(), suite::poisson2d(64)),
                ("banded".into(), suite::banded(4_096, 16, 1.0, 3)),
                ("scatter".into(), suite::uniform_scatter(4_096, 20, 3)),
                ("dense".into(), suite::dense(384, 1)),
            ],
        }
    }

    /// A smoke-test sweep (`spc5 tune --quick`): two kernels, three
    /// variants, two small matrices, short runs — exercises the whole
    /// sweep → profile → plan pipeline in CI-friendly time.
    pub fn quick() -> Self {
        SweepConfig {
            kernels: vec![KernelKind::Beta(1, 8), KernelKind::Beta(2, 8)],
            variants: vec![0, 1, 3],
            threads: 1,
            runs: 4,
            matrices: vec![
                ("poisson".into(), suite::poisson2d(32)),
                ("fem".into(), suite::fem_blocked(400, 3, 5, 7)),
            ],
        }
    }
}

/// The distinct β block sizes of the paper's kernel set (the `bt`
/// spellings run the same loops and are not swept separately).
fn beta_kernels() -> Vec<KernelKind> {
    KernelKind::SPC5_KERNELS
        .iter()
        .copied()
        .filter(|k| matches!(k, KernelKind::Beta(..)))
        .collect()
}

/// One variant measurement: mean GFlop/s of `runs` products on `bm`'s
/// variant (already stamped into `bm.tune`).
fn measure_variant(
    bm: &crate::formats::BlockMatrix,
    threads: usize,
    runs: usize,
) -> f64 {
    let nnz = bm.nnz();
    let x = crate::bench::bench_vector(bm.cols, 0xBE7C);
    let mut y = vec![0.0f64; bm.rows];
    let seconds = if threads > 1 {
        let p = ParallelSpmv::new(
            bm.clone(),
            threads,
            ParallelStrategy::Shared,
            false,
        );
        mean_of_runs(runs, || p.spmv(&x, &mut y))
    } else {
        mean_of_runs(runs, || spmv_block(bm, &x, &mut y, false))
    };
    std::hint::black_box(&y);
    spmv_gflops(nnz, seconds)
}

/// Runs the sweep: for every β kernel in `cfg`, measures every
/// requested variant on every matrix, returns the machine profile of
/// per-kernel winners plus one [`PerfRecord`] per individual
/// measurement (for [`crate::predictor::RecordStore::push`], which
/// keys on the variant so tuned and baseline records coexist).
pub fn sweep(
    cfg: &SweepConfig,
) -> anyhow::Result<(TuneProfile, Vec<PerfRecord>)> {
    anyhow::ensure!(!cfg.matrices.is_empty(), "tune sweep: no matrices");
    anyhow::ensure!(cfg.runs > 0, "tune sweep: runs must be positive");
    // Baseline first, then the requested variants (deduplicated,
    // order-preserving) — index 0 anchors `baseline_gflops`.
    let mut variants: Vec<usize> = vec![0];
    for &v in &cfg.variants {
        anyhow::ensure!(
            v < VARIANT_TABLE.len(),
            "tune sweep: variant index {v} out of range"
        );
        if !variants.contains(&v) {
            variants.push(v);
        }
    }

    let mut profile = TuneProfile {
        machine: machine_key(),
        entries: Vec::new(),
    };
    let mut records = Vec::new();
    for &kernel in &cfg.kernels {
        let Some(bs) = kernel.block_size() else { continue };
        // One conversion per (kernel, matrix); the variant is a field
        // write, not a re-conversion.
        let mut converted = Vec::with_capacity(cfg.matrices.len());
        for (name, csr) in &cfg.matrices {
            converted.push((name.clone(), csr_to_block(csr, bs)?));
        }
        let mut best: Option<(TuneParams, f64)> = None;
        let mut baseline = 0.0f64;
        for &v in &variants {
            let tune = VARIANT_TABLE[v];
            let mut sum = 0.0f64;
            for (name, bm) in &mut converted {
                bm.tune = tune;
                let gflops = measure_variant(bm, cfg.threads, cfg.runs);
                sum += gflops;
                records.push(PerfRecord {
                    matrix: name.clone(),
                    kernel,
                    avg_nnz_per_block: bm.avg_nnz_per_block(),
                    threads: cfg.threads,
                    tile_cols: 0,
                    tune,
                    gflops,
                });
            }
            let mean = sum / converted.len() as f64;
            if v == 0 {
                baseline = mean;
            }
            // Strict >: ties keep the earlier (simpler) variant.
            let better = match best {
                None => true,
                Some((_, g)) => mean > g,
            };
            if better {
                best = Some((tune, mean));
            }
            eprintln!(
                "  tune {kernel} {}: {mean:.3} GFlop/s",
                tune.label()
            );
        }
        let (tune, gflops) = best.expect("variants is never empty");
        profile.entries.push(TuneEntry {
            kernel,
            threads: cfg.threads,
            tune,
            gflops,
            baseline_gflops: baseline,
        });
    }
    Ok((profile, records))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_config() -> SweepConfig {
        SweepConfig {
            kernels: vec![KernelKind::Beta(2, 4)],
            variants: vec![1],
            threads: 1,
            runs: 2,
            matrices: vec![("p".into(), suite::poisson2d(12))],
        }
    }

    #[test]
    fn profile_json_roundtrip() {
        let p = TuneProfile {
            machine: "test-machine | avx512=false | cores=2".into(),
            entries: vec![
                TuneEntry {
                    kernel: KernelKind::Beta(2, 8),
                    threads: 1,
                    tune: VARIANT_TABLE[3],
                    gflops: 3.4,
                    baseline_gflops: 3.1,
                },
                TuneEntry {
                    kernel: KernelKind::Beta(1, 8),
                    threads: 4,
                    tune: TuneParams::NO_PREFETCH,
                    gflops: 2.0,
                    baseline_gflops: 2.0,
                },
            ],
        };
        let back = TuneProfile::from_json(&p.to_json()).unwrap();
        assert_eq!(p, back);
    }

    #[test]
    fn profile_rejects_partial_tune() {
        // Our own (new) format: every tuning field is required, so a
        // hand-edited profile cannot silently pin a different variant.
        let p = TuneProfile {
            machine: "m".into(),
            entries: vec![TuneEntry {
                kernel: KernelKind::Beta(2, 8),
                threads: 1,
                tune: VARIANT_TABLE[0],
                gflops: 1.0,
                baseline_gflops: 1.0,
            }],
        };
        let good = p.to_json();
        // Keys serialize alphabetically; `vpd` is last in its object,
        // so it is stripped with its *leading* comma.
        for key in ["\"hpd\":8,", ",\"vpd\":2", "\"pfx\":false,", "\"unroll\":1,"] {
            let bad = good.replace(key, "");
            assert_ne!(bad, good, "pattern {key} not found in {good}");
            assert!(
                TuneProfile::from_json(&bad).is_err(),
                "stripped {key} must fail"
            );
        }
    }

    #[test]
    fn lookup_prefers_exact_threads_then_kernel() {
        let mk = |kernel, threads, v: usize| TuneEntry {
            kernel,
            threads,
            tune: VARIANT_TABLE[v],
            gflops: 1.0,
            baseline_gflops: 1.0,
        };
        let p = TuneProfile {
            machine: "m".into(),
            entries: vec![
                mk(KernelKind::Beta(2, 8), 1, 2),
                mk(KernelKind::Beta(2, 8), 4, 3),
                mk(KernelKind::Beta(1, 8), 1, 1),
            ],
        };
        assert_eq!(p.lookup(KernelKind::Beta(2, 8), 4), Some(VARIANT_TABLE[3]));
        assert_eq!(p.lookup(KernelKind::Beta(2, 8), 1), Some(VARIANT_TABLE[2]));
        // No entry at threads=2: same kernel at any thread count serves.
        assert_eq!(p.lookup(KernelKind::Beta(2, 8), 2), Some(VARIANT_TABLE[2]));
        // Test kernels fold onto the β entry (same loops).
        assert_eq!(
            p.lookup(KernelKind::BetaTest(1, 8), 1),
            Some(VARIANT_TABLE[1])
        );
        // Unswept kernels resolve to nothing (process default applies).
        assert_eq!(p.lookup(KernelKind::Beta(8, 4), 1), None);
        assert_eq!(p.lookup(KernelKind::Csr, 1), None);
    }

    #[test]
    fn sweep_produces_profile_and_records() {
        let cfg = tiny_config();
        let (profile, records) = sweep(&cfg).unwrap();
        assert_eq!(profile.entries.len(), 1);
        let e = &profile.entries[0];
        assert_eq!(e.kernel, KernelKind::Beta(2, 4));
        assert!(e.gflops > 0.0 && e.baseline_gflops > 0.0);
        // The winner can only be at least as fast as the baseline.
        assert!(e.gflops >= e.baseline_gflops);
        assert!(!profile.machine.is_empty());
        // One record per (matrix, variant): baseline + variant 1.
        assert_eq!(records.len(), 2);
        assert!(records.iter().any(|r| r.tune == VARIANT_TABLE[0]));
        assert!(records.iter().any(|r| r.tune == VARIANT_TABLE[1]));
        assert!(records.iter().all(|r| r.gflops > 0.0));
        // The profile feeds plan-time lookups.
        assert!(profile.lookup(KernelKind::Beta(2, 4), 1).is_some());
    }

    #[test]
    fn sweep_rejects_bad_config() {
        let mut cfg = tiny_config();
        cfg.variants = vec![VARIANT_TABLE.len()];
        assert!(sweep(&cfg).is_err(), "out-of-range variant index");
        let mut cfg = tiny_config();
        cfg.matrices.clear();
        assert!(sweep(&cfg).is_err(), "empty matrix list");
    }

    #[test]
    fn profile_file_roundtrip() {
        let (profile, _) = sweep(&tiny_config()).unwrap();
        let dir = std::env::temp_dir().join("spc5_test_tune");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("profile.json");
        profile.save(&path).unwrap();
        let back = TuneProfile::load(&path).unwrap();
        assert_eq!(profile, back);
        std::fs::remove_file(path).ok();
    }
}
