//! `spc5` — command-line interface to the SPC5-RS library.
//!
//! Subcommands:
//! - `stats   --set A|B | --matrix NAME | --mtx FILE` — Table 1/2 rows.
//! - `spmv    --matrix NAME [--kernel K] [--threads N] [--numa]` —
//!   one measured SpMV (16-run mean, like the paper); `--plan FILE`
//!   instantiates from a saved plan instead of selecting.
//! - `plan    --matrix NAME [--kernel K] [--threads N] [--save FILE]`
//!   — the inspection phase alone: print (and optionally save) the
//!   chosen `SpmvPlan` as JSON, converting nothing.
//! - `predict --matrix NAME [--threads N] [--records FILE]` — kernel
//!   selection from recorded performance.
//! - `cg      [--n N] [--iters K] [--engine native|xla]` — conjugate
//!   gradient on the 2D Poisson system; `xla` runs the AOT artifact.
//! - `solve   --matrix NAME | --mtx FILE [--solver cg|pcg|bicgstab]
//!   [--precond none|jacobi|symgs[(n)]|ilu0]` — preconditioned solve
//!   through the engine's kernels, reporting iterations, residual and
//!   per-phase time; `--save-plan FILE` persists the whole solve
//!   configuration (including the level-schedule decision) and
//!   `--plan FILE` replays it with no inspection or level analysis.
//! - `gen     --class CLASS --out FILE.mtx [--dim D]` — write a
//!   synthetic matrix in MatrixMarket format.
//! - `serve   --matrix NAME [--shards N] [--queue block|reject|timeout]
//!   [--chaos]` — drive synthetic load through the sharded,
//!   admission-controlled serving tier and report per-shard + rollup
//!   statistics plus health; `--chaos` injects a deterministic shard
//!   panic mid-stream (`SPC5_FAULTS` overrides the canned plan) as a
//!   self-healing smoke test.
//! - `tune    [--quick] [--out FILE] [--records FILE]` — offline
//!   machine-level autotuning: sweep every β kernel variant, persist
//!   the per-kernel winners as a machine-keyed tune profile (consulted
//!   by `plan`/`spmv` via `--tune-profile FILE`) and feed the record
//!   store.
//! - `kernels` — list kernels and CPU feature support.

use spc5::bench;
use spc5::coordinator::{
    cg_solve, QueuePolicy, RecvError, Request, ServiceError, ServiceStats,
    ShardConfig, ShardedService, SpmvEngine, SpmvPlan,
    DEFAULT_QUEUE_CAPACITY,
};
use spc5::formats::stats::paper_profile;
use spc5::kernels::KernelKind;
use spc5::matrix::{market, suite, Csr};
use spc5::predictor::{select_parallel, select_sequential, RecordStore};
use spc5::util::timer::{mean_of_runs, spmv_gflops};
use spc5::util::Rng;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    };
    std::process::exit(code);
}

/// Tiny argument parser: `--key value` pairs + positional subcommand.
struct Args {
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse(rest: &[String]) -> anyhow::Result<Args> {
        let mut flags = std::collections::HashMap::new();
        let mut i = 0;
        while i < rest.len() {
            let a = &rest[i];
            if let Some(key) = a.strip_prefix("--") {
                // boolean flags: --numa, --csv
                if i + 1 >= rest.len() || rest[i + 1].starts_with("--") {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                } else {
                    flags.insert(key.to_string(), rest[i + 1].clone());
                    i += 2;
                }
            } else {
                anyhow::bail!("unexpected argument '{a}'");
            }
        }
        Ok(Args { flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn get_usize(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects a number")),
        }
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

fn load_matrix(a: &Args) -> anyhow::Result<(String, Csr)> {
    if let Some(name) = a.get("matrix") {
        let sm = suite::by_name(name)
            .ok_or_else(|| anyhow::anyhow!("unknown suite matrix '{name}'"))?;
        Ok((sm.name.to_string(), sm.csr))
    } else if let Some(path) = a.get("mtx") {
        // Parse errors carry the file so the one-line CLI error names
        // exactly what was malformed ("FILE: matrix market parse error
        // at line N: ...").
        let coo = market::read_file(path)
            .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
        let csr =
            coo.to_csr().map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
        Ok((path.to_string(), csr))
    } else {
        anyhow::bail!("need --matrix NAME or --mtx FILE (see `spc5 stats --set A` for names)")
    }
}

fn run(args: &[String]) -> anyhow::Result<()> {
    let Some(cmd) = args.first() else {
        print_help();
        return Ok(());
    };
    let a = Args::parse(&args[1..])?;
    match cmd.as_str() {
        "stats" => cmd_stats(&a),
        "spmv" => cmd_spmv(&a),
        "plan" => cmd_plan(&a),
        "predict" => cmd_predict(&a),
        "cg" => cmd_cg(&a),
        "solve" => cmd_solve(&a),
        "gen" => cmd_gen(&a),
        "serve" => cmd_serve(&a),
        "tune" => cmd_tune(&a),
        "kernels" => cmd_kernels(),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => anyhow::bail!("unknown subcommand '{other}' (try `spc5 help`)"),
    }
}

fn print_help() {
    println!(
        "spc5 — block-based SpMV without zero padding (SPC5 reproduction)\n\
         \n\
         usage: spc5 <command> [--flags]\n\
         \n\
         commands:\n\
         \x20 stats    --set A|B | --matrix NAME | --mtx FILE   block-fill stats (Tables 1/2)\n\
         \x20 spmv     --matrix NAME [--kernel K] [--threads N] [--numa] [--precision f32|f64]\n\
         \x20          [--reorder rcm|colpack] [--panel-rows N]   (kernel `hybrid` = per-panel schedule)\n\
         \x20          [--tile-cols N | --tile-auto]   (cache-blocked column tiling; kernel\n\
         \x20          `tiled` / `tiled(N)` = tiled hybrid schedule)\n\
         \x20          [--plan FILE]        instantiate from a saved plan (skips selection)\n\
         \x20          [--plan-cache FILE]  plan once per fingerprint, reuse afterwards\n\
         \x20          [--tune-profile FILE] pin machine-tuned kernel variants at plan time\n\
         \x20 plan     --matrix NAME [--kernel K] [--threads N] [--numa] [--reorder ..]\n\
         \x20          [--panel-rows N] [--tile-cols N | --tile-auto] [--records FILE]\n\
         \x20          [--tune-profile FILE]\n\
         \x20          [--save FILE]        inspection only: print/save the SpmvPlan JSON\n\
         \x20 predict  --matrix NAME [--threads N] [--records FILE]\n\
         \x20 cg       [--n N] [--iters K] [--engine native|xla] [--threads N]\n\
         \x20 solve    --matrix NAME | --mtx FILE [--solver cg|pcg|bicgstab]\n\
         \x20          [--precond none|jacobi|symgs|symgs(n)|ilu0] [--kernel K]\n\
         \x20          [--threads N] [--iters K] [--tol T] [--rhs ones|rand] [--seed S]\n\
         \x20          [--save-plan FILE]   persist the whole solve configuration\n\
         \x20          [--plan FILE]        replay it (skips inspection + level analysis)\n\
         \x20 gen      --class CLASS --out FILE.mtx [--dim D] [--seed S]\n\
         \x20 serve    --matrix NAME [--shards N] [--threads N (per shard)] [--kernel K]\n\
         \x20          [--queue block|reject|timeout] [--capacity C] [--timeout-ms D]\n\
         \x20          [--max-batch B] [--requests R] [--burst K] [--numa]\n\
         \x20          drive synthetic load through the sharded serving tier\n\
         \x20 tune     [--quick] [--threads N] [--out FILE] [--records FILE]\n\
         \x20          [--matrix NAME | --mtx FILE]   sweep every β kernel variant and\n\
         \x20          save the machine-keyed tune profile (default tune.json)\n\
         \x20 kernels  list kernels + CPU support\n"
    );
}

fn cmd_stats(a: &Args) -> anyhow::Result<()> {
    let matrices: Vec<(String, Csr)> = if let Some(set) = a.get("set") {
        let list = match set.to_ascii_uppercase().as_str() {
            "A" => suite::set_a(),
            "B" => suite::set_b(),
            _ => anyhow::bail!("--set expects A or B"),
        };
        list.into_iter().map(|m| (m.name.to_string(), m.csr)).collect()
    } else {
        vec![load_matrix(a)?]
    };

    println!(
        "{:<20} {:>9} {:>11} {:>8}  {}",
        "name", "dim", "nnz", "nnz/row", "Avg(r,c) [fill%] for the six paper sizes"
    );
    for (name, csr) in matrices {
        let prof = paper_profile(&csr);
        let cells: Vec<String> = prof
            .iter()
            .map(|s| {
                format!(
                    "{}={:.1}({:.0}%)",
                    s.bs,
                    s.avg_nnz_per_block,
                    100.0 * s.fill_fraction
                )
            })
            .collect();
        println!(
            "{:<20} {:>9} {:>11} {:>8.1}  {}",
            name,
            csr.rows,
            csr.nnz(),
            csr.nnz_per_row(),
            cells.join(" ")
        );
    }
    Ok(())
}

/// Applies the shared engine-configuration flags (`--threads`,
/// `--numa`, `--panel-rows`, `--reorder`, `--tile-cols`/`--tile-auto`,
/// `--plan-cache`) to a builder at either precision.
fn apply_engine_flags<T: spc5::Scalar>(
    mut b: spc5::SpmvEngineBuilder<'static, T>,
    a: &Args,
    kernel: Option<KernelKind>,
) -> anyhow::Result<spc5::SpmvEngineBuilder<'static, T>> {
    b = b
        .threads(a.get_usize("threads", 1)?)
        .numa_split(a.has("numa"))
        .panel_rows(a.get_usize(
            "panel-rows",
            spc5::formats::hybrid::DEFAULT_PANEL_ROWS,
        )?);
    if let Some(k) = kernel {
        b = b.kernel(k);
    }
    if let Some(r) = a.get("reorder") {
        let kind = spc5::matrix::ReorderKind::parse(r).ok_or_else(|| {
            anyhow::anyhow!("bad --reorder '{r}' (expects rcm|colpack)")
        })?;
        b = b.reorder(kind);
    }
    if a.has("tile-auto") {
        b = b.tile_auto();
    }
    if let Some(v) = a.get("tile-cols") {
        // An explicit width wins over --tile-auto when both given.
        let n: usize = v.parse().map_err(|_| {
            anyhow::anyhow!("--tile-cols expects a number, got '{v}'")
        })?;
        b = b.tile_cols(n);
    }
    if let Some(path) = a.get("plan-cache") {
        b = b.plan_cache(path);
    }
    if let Some(path) = a.get("tune-profile") {
        b = b.tune_profile(path);
    }
    Ok(b)
}

fn parse_kernel_flag(a: &Args) -> anyhow::Result<Option<KernelKind>> {
    match a.get("kernel") {
        None => Ok(None),
        Some(k) => KernelKind::parse(k).map(Some).ok_or_else(|| {
            anyhow::anyhow!(
                "bad kernel '{k}' (try b(4,8), b32(1,16), csr, csr5, hybrid, \
                 tiled, tiled(4096))"
            )
        }),
    }
}

fn cmd_spmv(a: &Args) -> anyhow::Result<()> {
    let (name, csr) = load_matrix(a)?;
    let kernel_flag = parse_kernel_flag(a)?;
    let threads = a.get_usize("threads", 1)?;
    let numa = a.has("numa");
    let nnz = csr.nnz();

    let precision = a.get("precision").unwrap_or("f64");
    if precision != "f32" && precision != "f64" {
        anyhow::bail!("--precision expects f32 or f64, got '{precision}'");
    }

    // One engine serves every KernelKind — β kernels, CSR, CSR5 and
    // the hybrid panel schedule — at either precision.
    if precision == "f32" {
        anyhow::ensure!(
            !a.has("plan"),
            "--plan drives the f64 engine; drop --precision f32"
        );
        let b = apply_engine_flags(
            SpmvEngine::builder(csr.to_precision::<f32>()),
            a,
            Some(kernel_flag.unwrap_or(KernelKind::Beta(1, 8))),
        )?;
        let engine = b.build()?;
        let kernel = engine.kernel();
        let reorder_note = engine
            .reorder_kind()
            .map(|r| format!(" reorder={r}"))
            .unwrap_or_default();
        let tile_note = engine
            .tile_cols()
            .map(|t| format!(" tile={t}"))
            .unwrap_or_default();
        let x: Vec<f32> = bench::bench_vector(engine.csr().cols, 0xBE7C)
            .into_iter()
            .map(|v| v as f32)
            .collect();
        let mut y = vec![0.0f32; engine.csr().rows];
        let seconds = mean_of_runs(bench::RUNS, || engine.spmv(&x, &mut y));
        std::hint::black_box(&y);
        println!(
            "{name}: kernel={kernel} precision=f32 threads={threads} \
             numa={numa}{reorder_note}{tile_note} nnz={nnz} time={seconds:.6}s \
             gflops={:.3}",
            spmv_gflops(nnz, seconds)
        );
    } else {
        // `--plan FILE` instantiates the executor from a saved plan —
        // no selection, no re-inspection, fingerprint-checked.
        let engine = match a.get("plan") {
            Some(path) => {
                // The plan fixes the whole configuration; a flag that
                // would silently be overridden is an error, not a
                // no-op.
                for flag in [
                    "kernel",
                    "threads",
                    "numa",
                    "reorder",
                    "panel-rows",
                    "tile-cols",
                    "tile-auto",
                    "plan-cache",
                    "tune-profile",
                ] {
                    anyhow::ensure!(
                        !a.has(flag),
                        "--plan fixes the whole engine configuration; \
                         drop --{flag}"
                    );
                }
                let plan = SpmvPlan::load(path)?;
                SpmvEngine::from_plan(csr, &plan)?
            }
            None => {
                let b = apply_engine_flags(
                    SpmvEngine::builder(csr),
                    a,
                    Some(kernel_flag.unwrap_or(KernelKind::Beta(1, 8))),
                )?;
                b.build()?
            }
        };
        let kernel = engine.kernel();
        let reorder_note = engine
            .reorder_kind()
            .map(|r| format!(" reorder={r}"))
            .unwrap_or_default();
        let tile_note = engine
            .tile_cols()
            .map(|t| format!(" tile={t}"))
            .unwrap_or_default();
        let x = bench::bench_vector(engine.csr().cols, 0xBE7C);
        let mut y = vec![0.0f64; engine.csr().rows];
        let seconds = mean_of_runs(bench::RUNS, || engine.spmv(&x, &mut y));
        std::hint::black_box(&y);
        if kernel == KernelKind::Hybrid {
            if let Some(hm) = engine.hybrid() {
                let plan: Vec<String> = hm
                    .segments
                    .iter()
                    .map(|s| {
                        format!(
                            "rows {}..{} -> {} ({} nnz)",
                            s.row_begin, s.row_end, s.kernel, s.nnz
                        )
                    })
                    .collect();
                println!("hybrid schedule: {}", plan.join("; "));
            }
        }
        if let Some(th) = engine.tiled_hybrid() {
            println!(
                "tiled schedule: {} segments, {} (panel × tile) spans, \
                 tile width {} cols",
                th.n_segments(),
                th.n_spans(),
                th.tile_cols
            );
        }
        println!(
            "{name}: kernel={kernel} precision=f64 threads={} \
             numa={}{reorder_note}{tile_note} nnz={nnz} time={seconds:.6}s \
             gflops={:.3}",
            engine.threads(),
            engine.plan().numa_split,
            spmv_gflops(nnz, seconds)
        );
    }
    Ok(())
}

/// The inspection phase alone: select, rank and resolve — print the
/// resulting `SpmvPlan` as JSON, converting nothing. `--save FILE`
/// persists it for a later `spmv --plan FILE` (possibly on another
/// machine: the tile width is resolved at plan time).
fn cmd_plan(a: &Args) -> anyhow::Result<()> {
    let (name, csr) = load_matrix(a)?;
    let kernel_flag = parse_kernel_flag(a)?;
    let store = match a.get("records") {
        Some(path) => Some(RecordStore::load(path)?),
        None => None,
    };
    let b = apply_engine_flags(SpmvEngine::builder(csr), a, kernel_flag)?;
    let plan = match &store {
        Some(s) => b.records(s).plan()?,
        None => b.plan()?,
    };
    eprintln!(
        "plan for {name}: kernel={} threads={} tile={:?} segments={} \
         fingerprint={}",
        plan.kernel,
        plan.threads,
        plan.tile_cols,
        plan.schedule.len(),
        plan.fingerprint.key()
    );
    println!("{}", plan.to_json());
    if let Some(out) = a.get("save") {
        plan.save(out)?;
        eprintln!("saved plan to {out}");
    }
    Ok(())
}


fn cmd_predict(a: &Args) -> anyhow::Result<()> {
    let (name, csr) = load_matrix(a)?;
    let threads = a.get_usize("threads", 1)?;
    let path = a
        .get("records")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(bench::records_path);
    anyhow::ensure!(
        path.exists(),
        "no record store at {} — run `cargo bench --bench fig3_sequential` \
         (or fig4_parallel) first, or pass --records",
        path.display()
    );
    let store = RecordStore::load(&path)?;
    let kinds = KernelKind::SPC5_KERNELS;
    let sel = if threads > 1 {
        select_parallel(&csr, &store, &kinds, threads)
    } else {
        select_sequential(&csr, &store, &kinds)
    }
    .ok_or_else(|| anyhow::anyhow!("record store has no usable records"))?;
    println!("matrix {name} (threads={threads}):");
    for (k, p) in &sel.all {
        let marker = if *k == sel.kernel { " <= selected" } else { "" };
        println!("  {k:<12} predicted {p:.3} GFlop/s{marker}");
    }
    Ok(())
}

fn cmd_cg(a: &Args) -> anyhow::Result<()> {
    let n = a.get_usize("n", 64)?;
    let iters = a.get_usize("iters", 200)?;
    let threads = a.get_usize("threads", 1)?;
    let engine_kind = a.get("engine").unwrap_or("native");
    let csr = suite::poisson2d(n);
    let dim = csr.rows;
    let mut rng = Rng::new(0xC6);
    let b: Vec<f64> = (0..dim).map(|_| rng.range_f64(-1.0, 1.0)).collect();

    match engine_kind {
        "native" => {
            let engine =
                SpmvEngine::builder(csr.clone()).threads(threads).build()?;
            let mut x = vec![0.0; dim];
            let t = spc5::util::Timer::start();
            let report = cg_solve(&engine, &b, &mut x, iters, 1e-20);
            let secs = t.elapsed_s();
            let gflops = 2.0 * csr.nnz() as f64 * report.spmv_count as f64
                / secs
                / 1e9;
            println!(
                "native CG: n={n} dim={dim} kernel={} threads={threads} \
                 iters={} residual2={:.3e} converged={} time={:.3}s \
                 spmv-gflops={:.3}",
                engine.kernel(),
                report.iterations,
                report.residual_norm2,
                report.converged,
                secs,
                gflops
            );
        }
        "xla" => {
            let dir = a.get("artifacts").unwrap_or("artifacts");
            let mut engine = spc5::runtime::XlaEngine::new(dir)?;
            println!("PJRT platform: {}", engine.platform());
            engine.validate_matrix("cg", &csr)?;
            let w = engine.manifest.workload("cg")?.clone();
            anyhow::ensure!(
                w.iters == Some(iters),
                "artifact compiled for {} iters; pass --iters {} or re-run \
                 `make artifacts`",
                w.iters.unwrap_or(0),
                w.iters.unwrap_or(0)
            );
            let exe = engine.executor("cg")?;
            let x0 = vec![0.0f64; dim];
            let t = spc5::util::Timer::start();
            let out = exe.run_f64(&[&csr.values, &b, &x0])?;
            let secs = t.elapsed_s();
            let rs = out[1][0];
            println!(
                "xla CG: n={n} dim={dim} iters={iters} residual2={rs:.3e} \
                 time={:.3}s (single compiled executable, Pallas SpMV inside)",
                secs
            );
            // Cross-check against the native solution.
            let mut ax = vec![0.0; dim];
            csr.spmv_ref(&out[0], &mut ax);
            let err: f64 = ax
                .iter()
                .zip(&b)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                .sqrt();
            println!("xla CG: ‖A·x − b‖ = {err:.3e}");
        }
        other => anyhow::bail!("--engine expects native|xla, got '{other}'"),
    }
    Ok(())
}

/// Preconditioned Krylov solve through the engine's kernels. The
/// triangular preconditioners (`symgs`, `ilu0`) substitute over the
/// same blocked β storage that SpMV executes from; `--save-plan` /
/// `--plan` persist and replay the entire configuration — the inner
/// `SpmvPlan`, the preconditioner choice and the level-schedule
/// decision — so a repeat solve skips inspection and level analysis.
fn cmd_solve(a: &Args) -> anyhow::Result<()> {
    use spc5::coordinator::{
        bicgstab, pcg_with, solve_from_plan, PrecondKind, Preconditioner,
        SolvePlan, SolverKind, SOLVE_PLAN_VERSION,
    };

    let (name, csr) = load_matrix(a)?;
    anyhow::ensure!(
        csr.rows == csr.cols,
        "solve needs a square matrix; {name} is {}x{}",
        csr.rows,
        csr.cols
    );
    let dim = csr.rows;
    let iters = a.get_usize("iters", 2000)?;
    let tol: f64 = match a.get("tol") {
        None => 1e-10,
        Some(v) => v.parse().map_err(|_| {
            anyhow::anyhow!("--tol expects a number, got '{v}'")
        })?,
    };
    let tol2 = tol * tol;
    let b: Vec<f64> = match a.get("rhs").unwrap_or("ones") {
        "ones" => vec![1.0; dim],
        "rand" => {
            let mut rng = Rng::new(a.get_usize("seed", 0x50)? as u64);
            (0..dim).map(|_| rng.range_f64(-1.0, 1.0)).collect()
        }
        other => anyhow::bail!("--rhs expects ones|rand, got '{other}'"),
    };

    let (engine, precond, solver, kind, engine_s, precond_s) = match a.get("plan") {
        Some(path) => {
            // The plan fixes solver, preconditioner and engine; a flag
            // that would silently be overridden is an error, not a
            // no-op.
            for flag in ["solver", "precond", "kernel", "threads", "numa"] {
                anyhow::ensure!(
                    !a.has(flag),
                    "--plan fixes the whole solve configuration; drop \
                     --{flag}"
                );
            }
            let plan = SolvePlan::load(path)?;
            let t = spc5::util::Timer::start();
            let (engine, m) = solve_from_plan(csr, &plan)?;
            (engine, m, plan.solver, plan.precond, t.elapsed_s(), 0.0)
        }
        None => {
            let solver = match a.get("solver") {
                None => SolverKind::Pcg,
                Some(s) => SolverKind::parse(s).ok_or_else(|| {
                    anyhow::anyhow!(
                        "--solver expects cg|pcg|bicgstab, got '{s}'"
                    )
                })?,
            };
            let kind = match a.get("precond") {
                None if solver == SolverKind::Pcg => PrecondKind::Jacobi,
                None => PrecondKind::None,
                Some(p) => PrecondKind::parse(p).ok_or_else(|| {
                    anyhow::anyhow!(
                        "--precond expects none|jacobi|symgs|symgs(n)|ilu0, \
                         got '{p}'"
                    )
                })?,
            };
            if solver != SolverKind::Pcg && kind != PrecondKind::None {
                anyhow::bail!(
                    "{solver} runs unpreconditioned; use --solver pcg for \
                     --precond {kind}"
                );
            }
            let kernel =
                parse_kernel_flag(a)?.unwrap_or(KernelKind::Beta(1, 8));
            let t = spc5::util::Timer::start();
            let engine = SpmvEngine::builder(csr)
                .threads(a.get_usize("threads", 1)?)
                .numa_split(a.has("numa"))
                .kernel(kernel)
                .build()?;
            let engine_s = t.elapsed_s();
            let t = spc5::util::Timer::start();
            let m = kind
                .build(engine.csr(), engine.pool())
                .map_err(|e| anyhow::anyhow!("{name}: {e}"))?;
            (engine, m, solver, kind, engine_s, t.elapsed_s())
        }
    };

    if let Some(out) = a.get("save-plan") {
        let plan = SolvePlan {
            version: SOLVE_PLAN_VERSION,
            solver,
            precond: kind,
            levels: precond.level_summary(),
            spmv: engine.plan().clone(),
        };
        plan.save(out)?;
        eprintln!("saved solve plan to {out}");
    }

    let mut x = vec![0.0; dim];
    let t = spc5::util::Timer::start();
    let report = match solver {
        SolverKind::Cg => cg_solve(&engine, &b, &mut x, iters, tol2),
        SolverKind::Pcg => {
            pcg_with(&engine, precond.as_ref(), &b, &mut x, iters, tol2)
        }
        SolverKind::BiCgStab => bicgstab(&engine, &b, &mut x, iters, tol2),
    };
    let solve_s = t.elapsed_s();

    let level_note = precond
        .level_summary()
        .map(|s| {
            format!(
                " levels={} max-width={} parallel={}",
                s.n_levels, s.max_width, s.parallel
            )
        })
        .unwrap_or_default();
    println!(
        "{name}: solver={solver} precond={} kernel={} threads={} dim={dim} \
         iters={} residual2={:.3e} converged={} breakdown={}{level_note} \
         engine={engine_s:.3}s precond={precond_s:.3}s solve={solve_s:.3}s",
        precond.name(),
        engine.kernel(),
        engine.threads(),
        report.iterations,
        report.residual_norm2,
        report.converged,
        report.breakdown,
    );
    // Non-convergence is a result, not a CLI failure: the CI smoke run
    // and scripted sweeps read the report line and decide for
    // themselves.
    if !report.converged {
        eprintln!(
            "note: not converged after {} iterations (tol {tol:.1e})",
            report.iterations
        );
    }
    Ok(())
}

fn cmd_gen(a: &Args) -> anyhow::Result<()> {
    let class = a
        .get("class")
        .ok_or_else(|| anyhow::anyhow!("--class required (fem, stencil, circuit, rmat, scatter, dense, banded, web, contact, quantum)"))?;
    let out = a.get("out").ok_or_else(|| anyhow::anyhow!("--out required"))?;
    let dim = a.get_usize("dim", 4096)?;
    let seed = a.get_usize("seed", 1)? as u64;
    let csr = match class {
        "fem" => suite::fem_blocked(dim / 3, 3, 7, seed),
        "stencil" => {
            let s = (dim as f64).cbrt().ceil() as usize;
            suite::stencil3d(s, s, s)
        }
        "circuit" => suite::circuit(dim, 4, 8, seed),
        "rmat" => suite::rmat((dim as f64).log2().ceil() as u32, 16, seed),
        "scatter" => suite::uniform_scatter(dim, 20, seed),
        "dense" => suite::dense(dim.min(4096), seed),
        "banded" => suite::banded(dim, 16, 0.2, seed),
        "web" => suite::webgraph(dim, 14, 0.7, seed),
        "contact" => suite::contact_runs(dim, 3, 48, seed),
        "quantum" => suite::quantum_clusters(dim, 5, 12, 12, seed),
        other => anyhow::bail!("unknown class '{other}'"),
    };
    let mut coo = spc5::matrix::Coo::new(csr.rows, csr.cols);
    for r in 0..csr.rows {
        for k in csr.row_range(r) {
            coo.push(r, csr.colidx[k] as usize, csr.values[k]);
        }
    }
    market::write_file(out, &coo)?;
    println!(
        "wrote {out}: {}x{} nnz={} class={class}",
        csr.rows,
        csr.cols,
        csr.nnz()
    );
    Ok(())
}

/// One formatted statistics row for `spc5 serve` output.
fn serve_stats_row(label: &str, s: &ServiceStats) {
    println!(
        "  {label:<10} served={:<6} batches={:<5} total p50/p95/p99 = \
         {:.3}/{:.3}/{:.3} ms  queue p95={:.3} ms  compute p95={:.3} ms  \
         depth hw={}",
        s.served,
        s.batches,
        s.p50_s * 1e3,
        s.p95_s * 1e3,
        s.p99_s * 1e3,
        s.queue.p95_s * 1e3,
        s.compute.p95_s * 1e3,
        s.queue_depth_high_water
    );
}

/// Drives synthetic offered load through the sharded serving tier:
/// bursts of `--burst` requests (clamped below `--capacity` so a
/// `block` queue cannot deadlock the single driver thread), drained
/// between bursts, with per-shard and cluster-rollup statistics at
/// the end.
fn cmd_serve(a: &Args) -> anyhow::Result<()> {
    let (name, csr) = load_matrix(a)?;
    let kernel_flag = parse_kernel_flag(a)?;
    let shards = a.get_usize("shards", 2)?;
    let capacity = a.get_usize("capacity", DEFAULT_QUEUE_CAPACITY)?;
    let requests = a.get_usize("requests", 256)?;
    let burst = a.get_usize("burst", 16)?;
    let queue = match a.get("queue").unwrap_or("block") {
        "block" => QueuePolicy::Block { capacity },
        "reject" => QueuePolicy::Reject { capacity },
        "timeout" => QueuePolicy::Timeout {
            capacity,
            wait: std::time::Duration::from_millis(
                a.get_usize("timeout-ms", 100)? as u64,
            ),
        },
        other => {
            anyhow::bail!("--queue expects block|reject|timeout, got '{other}'")
        }
    };
    // --chaos: a canned deterministic shard panic (overridable with
    // SPC5_FAULTS) exercising the supervised-restart path end to end.
    let faults = if a.has("chaos") {
        let plan = match spc5::faults::global() {
            Some(plan) => plan,
            None => std::sync::Arc::new(
                spc5::faults::FaultPlan::parse(
                    "panic@compute:shard=0,nth=3",
                    0x5eed,
                )
                .map_err(|e| anyhow::anyhow!("canned chaos plan: {e}"))?,
            ),
        };
        Some(plan)
    } else {
        None
    };
    let cfg = ShardConfig {
        shards,
        threads_per_shard: a.get_usize("threads", 1)?,
        numa_split: a.has("numa"),
        kernel: kernel_flag,
        max_batch: a.get_usize("max-batch", 8)?,
        queue,
        faults: faults.clone(),
        ..ShardConfig::default()
    };
    let (rows, cols, nnz) = (csr.rows, csr.cols, csr.nnz());
    let service = ShardedService::start(csr, cfg)?;
    println!(
        "serving {name}: {rows}x{cols} nnz={nnz} shards={} policy={:?}{}",
        service.n_shards(),
        service.policy(),
        if faults.is_some() { " chaos=on" } else { "" }
    );

    let window = burst.clamp(1, capacity);
    let t = spc5::util::Timer::start();
    let mut rejected = 0usize;
    let mut failed = 0usize;
    let mut outstanding = 0usize;
    // Drains every outstanding request, counting aborted generations
    // (supervised restart in flight) instead of bailing on them.
    let drain = |outstanding: &mut usize,
                 failed: &mut usize|
     -> anyhow::Result<()> {
        while *outstanding > 0 {
            match service.recv() {
                Ok(_) => {}
                Err(RecvError::Failed { shard, generation }) => {
                    *failed += 1;
                    eprintln!(
                        "  fault: shard {shard} failed, generation \
                         {generation} aborted (restarting)"
                    );
                }
                Err(e) => {
                    anyhow::bail!("service stopped early: {e}")
                }
            }
            *outstanding -= 1;
        }
        Ok(())
    };
    for id in 0..requests as u64 {
        let x = bench::bench_vector(cols, 0xBE7C ^ id);
        match service.submit(Request { id, x }) {
            Ok(()) => outstanding += 1,
            Err(ServiceError::Overloaded { .. }) => rejected += 1,
            Err(ServiceError::ShardFailed { .. }) => failed += 1,
            Err(e) => return Err(e.into()),
        }
        if outstanding >= window {
            drain(&mut outstanding, &mut failed)?;
        }
    }
    drain(&mut outstanding, &mut failed)?;
    let wall = t.elapsed_s();

    let stats = service.stats();
    for (i, s) in stats.shards.iter().enumerate() {
        serve_stats_row(&format!("shard {i}"), s);
    }
    serve_stats_row("rollup", &stats.rollup());
    for h in service.health() {
        println!(
            "  health shard {}: {} generation={} restarts={}{}",
            h.shard,
            h.health,
            h.generation,
            h.restarts,
            match &h.last_fault {
                Some(f) => format!(" last_fault=\"{f}\""),
                None => String::new(),
            }
        );
    }
    println!(
        "  offered={requests} served={} rejected={rejected} failed={failed} \
         restarts={} in-flight hw={} wall={wall:.3}s throughput={:.3} gflops",
        stats.served,
        stats.restarts,
        stats.in_flight_high_water,
        2.0 * nnz as f64 * stats.served as f64 / wall / 1e9
    );
    if let Some(plan) = &faults {
        println!("  chaos: {} fault(s) fired", plan.fired());
        if stats.restarts == 0 && plan.fired() > 0 {
            anyhow::bail!("chaos fired but no shard restart was recorded");
        }
    }
    // Durable-state degradations (quarantined caches, profiles dropped
    // to baseline) are part of the serving report: the operator must
    // see that state was rebuilt even though the service stayed up.
    for e in spc5::util::durable::degrade_events() {
        println!("  degraded: {e}");
    }
    service.shutdown();
    Ok(())
}

/// Offline machine-level autotuning: sweep the β kernel-variant table
/// on representative generators (or one user matrix), print per-kernel
/// winners, save the machine-keyed profile and feed the record store.
fn cmd_tune(a: &Args) -> anyhow::Result<()> {
    use spc5::tuner::{sweep, SweepConfig};
    let mut cfg =
        if a.has("quick") { SweepConfig::quick() } else { SweepConfig::full() };
    cfg.threads = a.get_usize("threads", cfg.threads)?;
    if a.has("matrix") || a.has("mtx") {
        let (name, csr) = load_matrix(a)?;
        cfg.matrices = vec![(name, csr)];
    }
    eprintln!(
        "tune sweep: {} kernels x {} variants on {} matrices (threads={}, \
         {} runs/measurement)",
        cfg.kernels.len(),
        cfg.variants.len(),
        cfg.matrices.len(),
        cfg.threads,
        cfg.runs
    );
    let (profile, records) = sweep(&cfg)?;
    println!("machine: {}", profile.machine);
    println!(
        "{:<10} {:<10} {:>9} {:>12} {:>8}",
        "kernel", "variant", "gflops", "baseline", "speedup"
    );
    for e in &profile.entries {
        // Pre-render: width specs only pad types that honor `f.pad`.
        let kernel = e.kernel.to_string();
        let variant = e.tune.label();
        println!(
            "{kernel:<10} {variant:<10} {:>9.3} {:>12.3} {:>7.2}x",
            e.gflops,
            e.baseline_gflops,
            e.gflops / e.baseline_gflops.max(1e-12)
        );
    }
    let out = a.get("out").unwrap_or("tune.json");
    profile.save(out)?;
    eprintln!("saved tune profile to {out}");
    // Every individual measurement feeds the predictor store — the
    // records carry the variant, so they coexist with baseline runs.
    let rec_path = a
        .get("records")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(bench::records_path);
    let n = records.len();
    // A corrupt store must not lose a finished sweep: `load`
    // quarantines it, the downgrade is recorded, and the sweep records
    // seed a fresh store at the same path.
    let mut store = if rec_path.exists() {
        match RecordStore::load(&rec_path) {
            Ok(store) => store,
            Err(e) if e.is_missing() => RecordStore::new(),
            Err(e) => {
                spc5::util::durable::record_degrade(
                    spc5::util::DegradeEvent {
                        artifact: RecordStore::ARTIFACT.into(),
                        path: rec_path.display().to_string(),
                        reason: e.to_string(),
                        fallback: "re-seed store from this sweep".into(),
                    },
                );
                RecordStore::new()
            }
        }
    } else {
        RecordStore::new()
    };
    for r in records {
        store.push(r);
    }
    store.save(&rec_path)?;
    eprintln!("merged {n} sweep records into {}", rec_path.display());
    for e in spc5::util::durable::degrade_events() {
        eprintln!("degraded: {e}");
    }
    Ok(())
}

fn cmd_kernels() -> anyhow::Result<()> {
    println!(
        "AVX-512 available: {}",
        spc5::util::avx512_available()
    );
    println!("kernels:");
    for k in KernelKind::ALL {
        let simd = match k {
            KernelKind::Csr | KernelKind::Csr5 => "portable",
            _ => {
                if spc5::util::avx512_available() {
                    "avx512 vexpandpd"
                } else {
                    "scalar fallback"
                }
            }
        };
        println!("  {k:<12} [{simd}]");
    }
    println!("  {:<12} [per-row-panel β/CSR schedule]", KernelKind::Hybrid);
    println!(
        "  {:<12} [cache-blocked (panel × column-tile) hybrid schedule; \
         tiled(N) fixes the tile width, auto width = {} cols at f64]",
        KernelKind::Tiled(0),
        spc5::formats::auto_tile_cols::<f64>(usize::MAX / 2)
    );
    Ok(())
}
